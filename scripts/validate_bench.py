#!/usr/bin/env python3
"""Schema check for the BENCH_fig*.json perf artifacts (CI bench job).

Usage: validate_bench.py BENCH_fig15.json [BENCH_fig16.json ...]

Fails (exit 1) on any structural problem: the bench job must not upload
an artifact the perf-trajectory tooling cannot parse. Stdlib only — the
CI runner has no third-party packages.
"""
import json
import sys

NUM = (int, float)

# Required keys per figure: name -> (type tuple, nullable).
ROW_SCHEMAS = {
    15: {"series": (str,), "poll_us": NUM + (type(None),), "latency_ns": NUM},
    16: {
        "series": (str,),
        "ranks": NUM,
        "compute_us": NUM + (type(None),),
        "vtime_ms": NUM,
        "speedup": NUM,
    },
    17: {
        "collective": (str,),
        "nodes": NUM,
        "rpn": NUM,
        "flat_us": NUM,
        "hier_us": NUM,
        "speedup": NUM,
    },
    18: {"series": (str,), "rx_ns": NUM, "vtime_us": NUM},
    19: {
        "nodes": NUM,
        "shards": NUM,
        "vtime_ms": NUM,
        "host_ms": NUM,
        "clock_events": NUM,
        "cross_shard_events": NUM,
        "speedup_vs_1": NUM,
    },
    20: {
        "app": (str,),
        "series": (str,),
        "ranks": NUM,
        "vtime_ms": NUM,
        "busy_frac": NUM,
        "comm_frac": NUM,
        "overlap_frac": NUM,
    },
    21: {
        "collective": (str,),
        "nodes": NUM,
        "rpn": NUM,
        "ranks": NUM,
        "strategy": (str,),
        "compiles": NUM,
        "replay_events": NUM,
        "memo_hits": NUM,
        "closed_form_hits": NUM,
        "host_us": NUM,
    },
    22: {
        "scenario": (str,),
        "app": (str,),
        "vtime_us": NUM,
        "baseline_us": NUM,
        "survivors": NUM,
        "converged": (bool,),
        "replay_identical": (bool,),
    },
    23: {
        "app": (str,),
        "queue": (str,),
        "shards": NUM,
        "vtime_ms": NUM,
        "host_ms": NUM,
        "clock_events": NUM,
        "cross_shard_events": NUM,
        "cross_shard_batches": NUM,
        "events_per_host_ms": NUM,
        "speedup_vs_baseline": NUM,
    },
}

# fig16's overlap-profiler stamp: {"blocking": f, "nonblocking": f}.
OVERLAP_SCHEMA = {"blocking": NUM, "nonblocking": NUM}

CACHE_SCHEMA = {
    "calls": NUM,
    "cache": (bool,),
    "vtime_us": NUM,
    "hits": NUM,
    "misses": NUM,
    "plan_store_hits": NUM,
    "plan_store_misses": NUM,
}


def check_rows(rows, schema, what, path):
    if not isinstance(rows, list) or not rows:
        fail(path, f"{what} must be a non-empty array")
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            fail(path, f"{what}[{i}] is not an object")
        for key, types in schema.items():
            if key not in row:
                fail(path, f"{what}[{i}] missing key {key!r}")
            if not isinstance(row[key], types):
                fail(path, f"{what}[{i}].{key} has type {type(row[key]).__name__}")
        extra = set(row) - set(schema)
        if extra:
            fail(path, f"{what}[{i}] has unknown keys {sorted(extra)}")


def fail(path, msg):
    print(f"{path}: SCHEMA INVALID: {msg}", file=sys.stderr)
    sys.exit(1)


def validate(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        fail(path, "top level is not an object")
    if doc.get("schema_version") != 1:
        fail(path, f"schema_version {doc.get('schema_version')!r} != 1")
    fig = doc.get("fig")
    if fig not in ROW_SCHEMAS:
        fail(path, f"fig {fig!r} not one of {sorted(ROW_SCHEMAS)}")
    if doc.get("scale") not in ("quick", "default", "full"):
        fail(path, f"scale {doc.get('scale')!r} invalid")
    # Host wall-time of the emitter run (the perf-trajectory
    # denominator; every figure emits it since fig19 landed).
    if not isinstance(doc.get("elapsed_host_ns"), NUM):
        fail(path, f"elapsed_host_ns {doc.get('elapsed_host_ns')!r} is not a number")
    check_rows(doc.get("rows"), ROW_SCHEMAS[fig], "rows", path)
    allowed = {"schema_version", "fig", "scale", "rows", "elapsed_host_ns"}
    if fig == 17:
        check_rows(doc.get("cache"), CACHE_SCHEMA, "cache", path)
        allowed.add("cache")
    if fig == 16:
        check_rows([doc.get("overlap")], OVERLAP_SCHEMA, "overlap", path)
        allowed.add("overlap")
    extra = set(doc) - allowed
    if extra:
        fail(path, f"unknown top-level keys {sorted(extra)}")
    print(f"{path}: ok (fig {fig}, {len(doc['rows'])} rows)")


def main():
    if len(sys.argv) < 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    for path in sys.argv[1:]:
        validate(path)


if __name__ == "__main__":
    main()
