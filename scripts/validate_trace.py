#!/usr/bin/env python3
"""Structural check for Perfetto traces emitted by `repro ... --trace-format perfetto`.

Usage: validate_trace.py trace.json [trace2.json ...]

Fails (exit 1) if the document is not a well-formed Chrome/Perfetto
`trace_event` JSON, if any track's timestamps go backwards, if spans
were dropped by the recorder, if any required span category is absent
(the CI smoke run must exercise every instrumented subsystem), or if no
cross-rank flow arrow (send -> matching recv) is present. Stdlib only —
the CI runner has no third-party packages.
"""
import json
import sys

# Span categories the smoke run must produce at least one of: task
# execution, MPI request lifetimes, ingress-port service, collective
# rounds, and clock-lane lookahead waits (see rust/src/obs/mod.rs).
REQUIRED_CATS = {"task", "req", "port", "coll", "lane"}

PHASES = {"M", "X", "i", "b", "e", "s", "f"}


def fail(path, msg):
    print(f"{path}: TRACE INVALID: {msg}", file=sys.stderr)
    sys.exit(1)


def validate(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        fail(path, "top level is not an object")
    dropped = doc.get("otherData", {}).get("dropped_spans")
    if not isinstance(dropped, int):
        fail(path, "otherData.dropped_spans missing")
    if dropped != 0:
        fail(path, f"{dropped} spans dropped (ring overflow or contention)")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(path, "traceEvents must be a non-empty array")

    last_ts = {}  # (pid, tid) -> last seen ts
    cats = set()
    flow_src = {}  # flow id -> set of pids that emitted "s"
    flow_dst = {}  # flow id -> set of pids that emitted "f"
    async_open = {}  # (pid, id) -> open "b" count
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(path, f"traceEvents[{i}] is not an object")
        ph = ev.get("ph")
        if ph not in PHASES:
            fail(path, f"traceEvents[{i}] has unknown ph {ph!r}")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            fail(path, f"traceEvents[{i}] missing integer pid/tid")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(path, f"traceEvents[{i}] has bad ts {ts!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            fail(path, f"traceEvents[{i}] missing name")
        if not isinstance(ev.get("cat"), str) or not ev["cat"]:
            fail(path, f"traceEvents[{i}] missing cat")
        track = (ev["pid"], ev["tid"])
        if ts < last_ts.get(track, 0.0):
            fail(path, f"traceEvents[{i}] ts {ts} goes backwards on track {track}")
        last_ts[track] = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(path, f"traceEvents[{i}] X event has bad dur {dur!r}")
            cats.add(ev["cat"])
        elif ph in ("i", "b"):
            cats.add(ev["cat"])
        if ph in ("b", "e"):
            key = (ev["pid"], ev.get("id"))
            async_open[key] = async_open.get(key, 0) + (1 if ph == "b" else -1)
        if ph == "s":
            flow_src.setdefault(ev.get("id"), set()).add(ev["pid"])
        if ph == "f":
            flow_dst.setdefault(ev.get("id"), set()).add(ev["pid"])

    missing = REQUIRED_CATS - cats
    if missing:
        fail(path, f"no spans in required categories {sorted(missing)}")
    unbalanced = {k: v for k, v in async_open.items() if v != 0}
    if unbalanced:
        fail(path, f"{len(unbalanced)} async (b/e) spans unbalanced, e.g. "
                   f"{sorted(unbalanced)[:3]}")
    if not flow_src or not flow_dst:
        fail(path, "no flow events (s/f) at all")
    cross = [
        fid for fid, dsts in flow_dst.items()
        if any(d not in flow_src.get(fid, set()) for d in dsts)
        and fid in flow_src
    ]
    if not cross:
        fail(path, "no cross-rank flow arrow (s on one pid, f on another)")
    print(f"{path}: ok ({len(events)} events, {len(last_ts)} tracks, "
          f"{sorted(cats)} cats, {len(cross)} cross-rank flows)")


def main():
    if len(sys.argv) < 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    for path in sys.argv[1:]:
        validate(path)


if __name__ == "__main__":
    main()
