#!/usr/bin/env python3
"""Warn-only host wall-time delta between two BENCH_fig*.json documents.

Usage: bench_delta.py CURRENT.json [BASELINE.json]

Compares the `elapsed_host_ns` of the current emitter run against the
baseline (typically the artifact committed/downloaded from the previous
run) and prints a single summary line. Always exits 0: CI runners have
noisy, heterogeneous hosts, so a wall-time regression is a signal to
read, never a gate. A missing or unreadable baseline is reported and
skipped — the first run of a new figure has nothing to compare against.
Stdlib only.
"""
import json
import sys


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def main():
    if len(sys.argv) < 2:
        print(__doc__, file=sys.stderr)
        return
    cur_path = sys.argv[1]
    base_path = sys.argv[2] if len(sys.argv) > 2 else None
    try:
        cur = load(cur_path)
    except (OSError, ValueError) as e:
        print(f"bench-delta: cannot read current {cur_path}: {e}")
        return
    cur_ns = cur.get("elapsed_host_ns")
    if not isinstance(cur_ns, (int, float)) or cur_ns <= 0:
        print(f"bench-delta: {cur_path} has no usable elapsed_host_ns")
        return
    fig = cur.get("fig", "?")
    if base_path is None:
        print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms (no baseline given)")
        return
    try:
        base = load(base_path)
    except (OSError, ValueError) as e:
        print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms "
              f"(baseline {base_path} unavailable: {e})")
        return
    base_ns = base.get("elapsed_host_ns")
    if not isinstance(base_ns, (int, float)) or base_ns <= 0:
        print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms "
              f"(baseline has no usable elapsed_host_ns)")
        return
    delta = (cur_ns - base_ns) / base_ns * 100.0
    tag = "WARN slower" if delta > 10.0 else ("faster" if delta < -10.0 else "steady")
    print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms vs {base_ns / 1e6:.1f} ms "
          f"baseline ({delta:+.1f}%, {tag})")


if __name__ == "__main__":
    main()
