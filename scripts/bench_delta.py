#!/usr/bin/env python3
"""Host wall-time delta gate between two BENCH_fig*.json documents.

Usage: bench_delta.py CURRENT.json [BASELINE.json]

Compares the `elapsed_host_ns` of the current emitter run against the
baseline (typically the artifact committed/downloaded from the previous
run) and prints a single summary line.

Gating: for the perf-trajectory figures (19, 20, 21, 22, 23 — the
simulator throughput, overlap profiler, plan-compile, faults-matrix,
and event-queue sweep benches) a regression
beyond BENCH_DELTA_MAX_PCT (default 25%) **fails** with exit 1. Other
figures, and runs with no usable baseline, stay warn-only: the first run
of a new figure has nothing to compare against, and a missing baseline
must never block CI.

Overrides: set the BENCH_DELTA_MAX_PCT env var to widen/narrow the gate,
or set it to 0 (or a negative value) to disable gating entirely — the CI
workflow exports it from the `bench-delta-override` PR label path, so a
reviewer who accepts a known slowdown applies that label rather than
editing the workflow. Stdlib only.
"""
import json
import os
import sys

# Figures whose emitter wall time is a tracked perf trajectory; only
# these can fail the gate.
GATED_FIGS = {19, 20, 21, 22, 23}
DEFAULT_MAX_PCT = 25.0


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def max_pct():
    raw = os.environ.get("BENCH_DELTA_MAX_PCT", "")
    if not raw:
        return DEFAULT_MAX_PCT
    try:
        return float(raw)
    except ValueError:
        print(f"bench-delta: ignoring unparsable BENCH_DELTA_MAX_PCT={raw!r}")
        return DEFAULT_MAX_PCT


def main():
    if len(sys.argv) < 2:
        print(__doc__, file=sys.stderr)
        return
    cur_path = sys.argv[1]
    base_path = sys.argv[2] if len(sys.argv) > 2 else None
    try:
        cur = load(cur_path)
    except (OSError, ValueError) as e:
        print(f"bench-delta: cannot read current {cur_path}: {e}")
        return
    cur_ns = cur.get("elapsed_host_ns")
    if not isinstance(cur_ns, (int, float)) or cur_ns <= 0:
        print(f"bench-delta: {cur_path} has no usable elapsed_host_ns")
        return
    fig = cur.get("fig", "?")
    if base_path is None:
        print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms (no baseline given)")
        return
    try:
        base = load(base_path)
    except (OSError, ValueError) as e:
        print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms "
              f"(baseline {base_path} unavailable: {e})")
        return
    base_ns = base.get("elapsed_host_ns")
    if not isinstance(base_ns, (int, float)) or base_ns <= 0:
        print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms "
              f"(baseline has no usable elapsed_host_ns)")
        return
    delta = (cur_ns - base_ns) / base_ns * 100.0
    limit = max_pct()
    gated = fig in GATED_FIGS and limit > 0
    if gated and delta > limit:
        print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms vs "
              f"{base_ns / 1e6:.1f} ms baseline ({delta:+.1f}%, "
              f"FAIL: exceeds +{limit:.0f}% gate — set BENCH_DELTA_MAX_PCT "
              f"or apply the bench-delta-override label to accept)")
        sys.exit(1)
    tag = "WARN slower" if delta > 10.0 else ("faster" if delta < -10.0 else "steady")
    gate = f", gate +{limit:.0f}%" if gated else ""
    print(f"bench-delta: fig {fig}: {cur_ns / 1e6:.1f} ms vs {base_ns / 1e6:.1f} ms "
          f"baseline ({delta:+.1f}%, {tag}{gate})")


if __name__ == "__main__":
    main()
