//! Execution tracing (Fig 10) and dependency-graph recording (Fig 8).
//!
//! The tracer collects per-thread events stamped with *virtual* time; the
//! renderer produces Paraver-style ASCII Gantt charts and CSV. The graph
//! recorder captures the task dependency edges the runtime discovers at
//! registration time and emits Graphviz DOT.

pub mod gantt;
pub mod graph;
pub mod stalls;

use std::sync::Mutex;

use crate::sim::VNanos;

pub use gantt::{busy_fraction, render_gantt};
pub use graph::GraphRecorder;
pub use stalls::{format_stall_report, stall_report, CollStall};

/// What happened.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum EventKind {
    TaskStart,
    TaskEnd,
    /// Task paused via the pause/resume API.
    TaskBlock,
    /// Task was sent back to the scheduler.
    TaskUnblock,
    /// A worker granted its core to a paused task.
    TaskResumeGrant,
    /// Entering an MPI primitive.
    MpiStart,
    /// Leaving an MPI primitive.
    MpiEnd,
    /// A completed MPI operation's notification reached the runtime: a
    /// request continuation fired (callback mode) or the poll-scan
    /// retired the ticket (polling mode). Stamped at delivery time, so
    /// the gap to the task's `TaskUnblock` shows the notification
    /// latency of each completion pipeline.
    CompletionDelivered,
    /// The sharded progress engine drained one same-instant completion
    /// batch: `count` continuations of rank `shard` delivered in a single
    /// pass with one scheduler bulk-enqueue (see [`crate::progress`]).
    /// Stamped from the clock thread (worker = `u32::MAX` sentinel).
    BatchDelivered { shard: u32, count: u32 },
    /// One rank launched a collective schedule: the plan came from the
    /// communicator's persistent schedule cache (`cached`) or was
    /// compiled on the spot; `rounds` is this rank's round count and
    /// `(comm, seq)` the collective's cluster-wide identity (the
    /// communicator's context id and the call's first collective
    /// sequence number — what [`stalls`] groups by).
    CollScheduleCompiled { comm: u32, seq: u64, cached: bool, rounds: u32 },
    /// The collective engine posted round `round` of `total` of one
    /// rank's collective schedule (see `rmpi::coll_schedule`). Stamped
    /// from whichever thread delivered the previous round's last
    /// completion — often the clock thread (worker = `u32::MAX`).
    /// `(comm, seq)` as in [`EventKind::CollScheduleCompiled`].
    CollRoundAdvanced { comm: u32, seq: u64, round: u32, total: u32 },
    /// Free-form phase marker (e.g. "iteration 3").
    Phase,
}

impl EventKind {
    /// Annotation kinds are point events that may be stamped from
    /// non-worker threads (`Record::worker` is then the `u32::MAX`
    /// sentinel); lane-building trace consumers must skip them.
    pub fn is_annotation(self) -> bool {
        matches!(
            self,
            EventKind::CompletionDelivered
                | EventKind::BatchDelivered { .. }
                | EventKind::CollScheduleCompiled { .. }
                | EventKind::CollRoundAdvanced { .. }
        )
    }

    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::TaskStart => "task_start",
            EventKind::TaskEnd => "task_end",
            EventKind::TaskBlock => "task_block",
            EventKind::TaskUnblock => "task_unblock",
            EventKind::TaskResumeGrant => "resume_grant",
            EventKind::MpiStart => "mpi_start",
            EventKind::MpiEnd => "mpi_end",
            EventKind::CompletionDelivered => "completion_delivered",
            EventKind::BatchDelivered { .. } => "batch_delivered",
            EventKind::CollScheduleCompiled { .. } => "coll_schedule_compiled",
            EventKind::CollRoundAdvanced { .. } => "coll_round_advanced",
            EventKind::Phase => "phase",
        }
    }
}

/// One trace record.
#[derive(Clone, Debug)]
pub struct Record {
    pub t: VNanos,
    pub rank: u32,
    /// Worker lane within the rank. `u32::MAX` is a sentinel meaning
    /// "not a worker thread" — used by annotation records
    /// ([`EventKind::CompletionDelivered`], [`EventKind::BatchDelivered`])
    /// stamped from the clock thread, the polling leader, or a rank
    /// main. Lane-building consumers must skip annotation kinds (see
    /// `gantt.rs`).
    pub worker: u32,
    pub kind: EventKind,
    pub label: String,
    pub task_id: u64,
}

/// Shared, thread-safe event sink.
#[derive(Default)]
pub struct Tracer {
    records: Mutex<Vec<Record>>,
}

impl Tracer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn emit(&self, rec: Record) {
        self.records.lock().unwrap().push(rec);
    }

    /// Snapshot of all records sorted by time.
    pub fn snapshot(&self) -> Vec<Record> {
        let mut v = self.records.lock().unwrap().clone();
        v.sort_by_key(|r| (r.t, r.rank, r.worker));
        v
    }

    pub fn len(&self) -> usize {
        self.records.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// CSV dump: `t_ns,rank,worker,kind,task_id,label`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("t_ns,rank,worker,kind,task_id,label\n");
        for r in self.snapshot() {
            s.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.t,
                r.rank,
                r.worker,
                r.kind.as_str(),
                r.task_id,
                r.label.replace(',', ";")
            ));
        }
        s
    }
}
