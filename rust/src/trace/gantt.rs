//! ASCII Gantt rendering of traces (Fig 10).
//!
//! Rows are (rank, worker) lanes; columns are virtual-time buckets. Each
//! cell shows what the lane spent most of that bucket doing:
//! `#` task compute, `M` inside MPI, `b` paused (blocked task), `.` idle.

use std::collections::BTreeMap;

use super::{EventKind, Record};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum LaneState {
    Idle,
    Task,
    Mpi,
    Paused,
}

impl LaneState {
    fn glyph(self) -> char {
        match self {
            LaneState::Idle => '.',
            LaneState::Task => '#',
            LaneState::Mpi => 'M',
            LaneState::Paused => 'b',
        }
    }
}

/// Render records into an ASCII Gantt chart with `width` time buckets.
/// Lanes are sorted by (rank, worker). Returns the chart text.
pub fn render_gantt(records: &[Record], width: usize) -> String {
    if records.is_empty() {
        return String::from("(empty trace)\n");
    }
    let width = width.max(1);
    let t0 = records.iter().map(|r| r.t).min().unwrap();
    let t1 = records.iter().map(|r| r.t).max().unwrap();
    if t1 == t0 {
        // All records share one instant: there is no span to bucket, and
        // the old `max(t0 + 1)` fallback smeared a fake 1 ns span across
        // every column. Emit a labeled degenerate chart instead.
        return format!(
            "(degenerate trace: {} records at a single instant, t = {} ns)\n",
            records.len(),
            t0
        );
    }
    let span = (t1 - t0) as f64;

    // Build per-lane interval lists by replaying events in time order.
    // Annotation records are not lane occupancy — they may be stamped
    // from non-worker threads (the polling leader, the clock thread),
    // which must not create lanes.
    let mut by_lane: BTreeMap<(u32, u32), Vec<&Record>> = BTreeMap::new();
    for r in records {
        if r.kind.is_annotation() {
            continue;
        }
        by_lane.entry((r.rank, r.worker)).or_default().push(r);
    }

    let mut out = String::new();
    out.push_str(&format!(
        "gantt: {} lanes, {:.3} ms virtual span, {} buckets\n",
        by_lane.len(),
        span / 1e6,
        width
    ));
    for ((rank, worker), evs) in &by_lane {
        // occupancy[bucket] = dominant state
        let mut occupancy = vec![(0u64, LaneState::Idle); width];
        let mut state = LaneState::Idle;
        let mut since = t0;
        let mut fill = |from: u64, to: u64, st: LaneState, occ: &mut Vec<(u64, LaneState)>| {
            if to <= from || st == LaneState::Idle {
                return;
            }
            let b0 = (((from - t0) as f64 / span) * width as f64) as usize;
            let b1 = ((((to - t0) as f64 / span) * width as f64).ceil() as usize).min(width);
            for b in b0..b1 {
                let seg_from = from.max(t0 + ((b as f64 / width as f64) * span) as u64);
                let seg_to = to.min(t0 + (((b + 1) as f64 / width as f64) * span) as u64);
                let dur = seg_to.saturating_sub(seg_from);
                if dur > occ[b].0 {
                    occ[b] = (dur, st);
                }
            }
        };
        for r in evs.iter() {
            let new_state = match r.kind {
                EventKind::TaskStart | EventKind::TaskUnblock | EventKind::MpiEnd => {
                    Some(LaneState::Task)
                }
                EventKind::TaskEnd => Some(LaneState::Idle),
                EventKind::MpiStart => Some(LaneState::Mpi),
                EventKind::TaskBlock => Some(LaneState::Paused),
                _ => None,
            };
            if let Some(ns) = new_state {
                fill(since, r.t, state, &mut occupancy);
                state = ns;
                since = r.t;
            }
        }
        fill(since, t1, state, &mut occupancy);
        let row: String = occupancy.iter().map(|(_, st)| st.glyph()).collect();
        out.push_str(&format!("r{rank:02}w{worker:02} |{row}|\n"));
    }
    out.push_str("legend: '#' task  'M' in MPI  'b' paused  '.' idle\n");
    out
}

/// Aggregate busy fraction per rank (used by tests and EXPERIMENTS.md).
pub fn busy_fraction(records: &[Record]) -> BTreeMap<u32, f64> {
    let mut spans: BTreeMap<u32, (u64, u64)> = BTreeMap::new(); // rank -> (busy, lanes*span)
    if records.is_empty() {
        return BTreeMap::new();
    }
    let t0 = records.iter().map(|r| r.t).min().unwrap();
    let t1 = records.iter().map(|r| r.t).max().unwrap();
    let mut by_lane: BTreeMap<(u32, u32), Vec<&Record>> = BTreeMap::new();
    for r in records {
        // Annotation records (possibly off-worker) are not lanes; a
        // phantom lane would inflate the per-rank denominator below.
        if r.kind.is_annotation() {
            continue;
        }
        by_lane.entry((r.rank, r.worker)).or_default().push(r);
    }
    for ((rank, _), evs) in &by_lane {
        let mut busy = 0u64;
        let mut running = false;
        let mut since = t0;
        for r in evs.iter() {
            match r.kind {
                EventKind::TaskStart | EventKind::TaskUnblock => {
                    if !running {
                        running = true;
                        since = r.t;
                    }
                }
                EventKind::TaskEnd | EventKind::TaskBlock => {
                    if running {
                        busy += r.t - since;
                        running = false;
                    }
                }
                _ => {}
            }
        }
        if running {
            busy += t1 - since;
        }
        let e = spans.entry(*rank).or_insert((0, 0));
        e.0 += busy;
        e.1 += t1 - t0;
    }
    spans
        .into_iter()
        .map(|(rank, (busy, total))| (rank, busy as f64 / total.max(1) as f64))
        .collect()
}
