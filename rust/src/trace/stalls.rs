//! Collective stall diagnostic: which rank is holding a collective back?
//!
//! The collective engine stamps every schedule launch
//! ([`EventKind::CollScheduleCompiled`]) and every round advance
//! ([`EventKind::CollRoundAdvanced`]) with the collective's cluster-wide
//! identity `(comm, seq)`. This consumer replays a trace up to a chosen
//! virtual instant and reports, per in-flight collective, the rank
//! whose `rounds_advanced` is minimal and how long it has been sitting
//! there — the "who is late to the allreduce" question that is
//! otherwise answered by attaching a debugger to a hung job.
//!
//! Granularity: a rank's progress is measured in rounds *posted*. A
//! collective whose every rank posted all its rounds may still have
//! requests in flight for one final network latency; the diagnostic's
//! purpose is skew (a rank that has not entered, or is rounds behind),
//! which this granularity captures exactly. A rank with no records for
//! a group has not launched the collective at all — it is reported at
//! round 0, stalled since the group's earliest launch.
//!
//! A group in which *every* rank that entered posted all of its rounds
//! is never reported, even if some expected participant is absent: on
//! single-round schedules (alltoallv, flat gather) every entered rank
//! finishes its posts no matter who is missing, so blaming the absent
//! rank — a dead rank a shrunk communicator excluded, say — would be
//! noise, not diagnosis. A *genuine* stall always leaves some entered
//! rank short of its total (it cannot advance past the round gated on
//! the missing peer), and that rank's group is still reported.
//!
//! Exposed on the CLI as `repro stalls` (a deliberately skewed demo
//! run) and asserted in `tests/coll_topology.rs`.
//!
//! This post-run replay has a *live* counterpart since the fault
//! subsystem landed: [`crate::rmpi::faults`] runs a per-lane detector
//! tick on the clock thread (progress gauges stamped at request
//! completion) whose suspicion verdicts feed the stall-driven
//! re-rooting loop — see that module's "Detection and feedback" docs.
//! This replay stays the forensic tool; the live detector is the
//! control loop.

use std::collections::HashMap;

use crate::sim::VNanos;

use super::{EventKind, Record};

/// One in-flight collective at the report instant.
#[derive(Clone, Debug)]
pub struct CollStall {
    /// Communicator context id (world = 0).
    pub comm: u32,
    /// First collective sequence number of the call.
    pub seq: u64,
    /// Algorithm name ("barrier", "allreduce", ...).
    pub kind: String,
    /// Ranks that have launched this collective so far.
    pub entered: usize,
    /// Expected participants (the communicator size).
    pub participants: usize,
    /// The rank with minimal progress.
    pub laggard: u32,
    /// Rounds the laggard has posted (0 = has not entered).
    pub laggard_round: u32,
    /// The laggard's total rounds, when known (`None` before it
    /// launches — per-rank schedules differ under hierarchical plans).
    pub laggard_total: Option<u32>,
    /// Virtual time since the laggard last made progress (since the
    /// collective's first launch anywhere, for a rank that never
    /// entered).
    pub stalled_ns: u64,
}

#[derive(Default, Clone, Copy)]
struct RankProgress {
    round: u32,
    total: Option<u32>,
    last_t: VNanos,
    seen: bool,
}

/// Replay `records` up to virtual instant `at` and report every
/// collective that is still in flight there, most-stalled first.
/// `participants` is the communicator size (collectives are
/// communicator-wide, so a silent rank is a laggard, not a bystander).
pub fn stall_report(records: &[Record], at: VNanos, participants: usize) -> Vec<CollStall> {
    struct Group {
        kind: String,
        first_launch: VNanos,
        ranks: HashMap<u32, RankProgress>,
    }
    let mut groups: HashMap<(u32, u64), Group> = HashMap::new();
    for r in records {
        if r.t > at {
            continue;
        }
        let (comm, seq, round, total) = match r.kind {
            EventKind::CollScheduleCompiled { comm, seq, rounds, .. } => {
                (comm, seq, 0, Some(rounds))
            }
            EventKind::CollRoundAdvanced { comm, seq, round, total } => {
                (comm, seq, round, Some(total))
            }
            _ => continue,
        };
        let g = groups.entry((comm, seq)).or_insert_with(|| Group {
            kind: r.label.clone(),
            first_launch: r.t,
            ranks: HashMap::new(),
        });
        g.first_launch = g.first_launch.min(r.t);
        let p = g.ranks.entry(r.rank).or_default();
        p.seen = true;
        p.total = total.or(p.total);
        if round >= p.round {
            p.round = round;
            p.last_t = p.last_t.max(r.t);
        }
    }

    let mut out = Vec::new();
    for ((comm, seq), g) in groups {
        // Every rank that entered posted all of its rounds: the
        // collective ran to completion. Blaming a rank that has no
        // records — common on single-round schedules, where entered
        // ranks finish their posts regardless of who is absent, and
        // guaranteed when the collective ran on a shrunk communicator
        // smaller than `participants` — would be a false positive. A
        // genuine stall pins some entered rank below its total.
        if g.ranks.values().all(|p| p.total == Some(p.round)) {
            continue;
        }
        // Progress of every expected participant (absent = round 0,
        // stalled since the collective first appeared anywhere).
        let mut laggard: Option<(u32, RankProgress)> = None;
        let mut complete = true;
        for rank in 0..participants as u32 {
            let p = g.ranks.get(&rank).copied().unwrap_or(RankProgress {
                last_t: g.first_launch,
                ..RankProgress::default()
            });
            let done = p.seen && p.total == Some(p.round);
            if done {
                continue;
            }
            complete = false;
            // Least rounds posted wins; ties go to the longest-stalled.
            let worse = match &laggard {
                None => true,
                Some((_, best)) => {
                    p.round < best.round
                        || (p.round == best.round && p.last_t < best.last_t)
                }
            };
            if worse {
                laggard = Some((rank, p));
            }
        }
        if complete {
            continue;
        }
        let (rank, p) = laggard.expect("an incomplete group has a laggard");
        out.push(CollStall {
            comm,
            seq,
            kind: g.kind,
            entered: g.ranks.len(),
            participants,
            laggard: rank,
            laggard_round: p.round,
            laggard_total: p.total,
            stalled_ns: at.saturating_sub(p.last_t),
        });
    }
    out.sort_by(|a, b| b.stalled_ns.cmp(&a.stalled_ns).then(a.seq.cmp(&b.seq)));
    out
}

/// Render a stall report as the table `repro stalls` prints.
pub fn format_stall_report(stalls: &[CollStall], at: VNanos) -> String {
    if stalls.is_empty() {
        return format!("no collectives in flight at t={} us\n", at / 1_000);
    }
    let mut s = format!(
        "{:<6} {:>5} {:<12} {:>9} {:>8} {:>9} {:>12}\n",
        "comm", "seq", "kind", "entered", "laggard", "round", "stalled_us"
    );
    for st in stalls {
        let round = match st.laggard_total {
            Some(t) => format!("{}/{}", st.laggard_round, t),
            None => format!("{}/?", st.laggard_round),
        };
        s.push_str(&format!(
            "{:<6} {:>5} {:<12} {:>9} {:>8} {:>9} {:>12}\n",
            st.comm,
            st.seq,
            st.kind,
            format!("{}/{}", st.entered, st.participants),
            st.laggard,
            round,
            st.stalled_ns / 1_000
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: VNanos, rank: u32, kind: EventKind, label: &str) -> Record {
        Record { t, rank, worker: u32::MAX, kind, label: label.to_string(), task_id: 0 }
    }

    #[test]
    fn silent_rank_is_the_laggard() {
        let recs = vec![
            rec(
                0,
                0,
                EventKind::CollScheduleCompiled { comm: 0, seq: 0, cached: false, rounds: 2 },
                "barrier",
            ),
            rec(
                0,
                0,
                EventKind::CollRoundAdvanced { comm: 0, seq: 0, round: 1, total: 2 },
                "barrier",
            ),
            rec(
                0,
                1,
                EventKind::CollScheduleCompiled { comm: 0, seq: 0, cached: false, rounds: 2 },
                "barrier",
            ),
            rec(
                0,
                1,
                EventKind::CollRoundAdvanced { comm: 0, seq: 0, round: 1, total: 2 },
                "barrier",
            ),
        ];
        // Rank 2 never appears: it is the laggard at round 0.
        let r = stall_report(&recs, 5_000, 3);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].laggard, 2);
        assert_eq!(r[0].laggard_round, 0);
        assert_eq!(r[0].entered, 2);
        assert_eq!(r[0].stalled_ns, 5_000);
        assert_eq!(r[0].kind, "barrier");
    }

    #[test]
    fn all_entered_at_total_suppresses_absent_rank_blame() {
        // Regression: a 1-round collective where every entered rank
        // advanced to rounds_total used to blame the absent rank (min
        // rounds = 0) even though the collective plainly completed —
        // e.g. a shrunk communicator running 3-wide while the caller
        // still passes the 4-rank world size.
        let mut recs = Vec::new();
        for rank in 0..3 {
            recs.push(rec(
                0,
                rank,
                EventKind::CollScheduleCompiled { comm: 7, seq: 4, cached: false, rounds: 1 },
                "alltoallv",
            ));
            recs.push(rec(
                200,
                rank,
                EventKind::CollRoundAdvanced { comm: 7, seq: 4, round: 1, total: 1 },
                "alltoallv",
            ));
        }
        // Rank 3 never enters; with every entered rank at 1/1 the group
        // is complete, not stalled on rank 3.
        assert!(stall_report(&recs, 10_000, 4).is_empty());

        // Contrast: same shape but one entered rank short of its total
        // is a genuine stall and the group is still reported, with
        // blame on a rank at round 0 exactly as before.
        let mut hung = recs.clone();
        hung.retain(|r| {
            !(r.rank == 2 && matches!(r.kind, EventKind::CollRoundAdvanced { .. }))
        });
        let r = stall_report(&hung, 10_000, 4);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].laggard_round, 0);
        assert!(r[0].laggard == 2 || r[0].laggard == 3);
    }

    #[test]
    fn completed_collectives_drop_out() {
        let mut recs = Vec::new();
        for rank in 0..2 {
            recs.push(rec(
                0,
                rank,
                EventKind::CollScheduleCompiled { comm: 0, seq: 0, cached: false, rounds: 1 },
                "gather",
            ));
            recs.push(rec(
                100,
                rank,
                EventKind::CollRoundAdvanced { comm: 0, seq: 0, round: 1, total: 1 },
                "gather",
            ));
        }
        assert!(stall_report(&recs, 10_000, 2).is_empty());
        // But mid-flight (before the advances) it is reported.
        let early = stall_report(&recs, 50, 2);
        assert_eq!(early.len(), 1);
        assert_eq!(early[0].laggard_round, 0);
    }
}
