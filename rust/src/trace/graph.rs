//! Task dependency graph recording -> Graphviz DOT (Fig 8).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Records nodes (tasks) and edges (dependencies) as the runtime discovers
/// them at access-registration time.
#[derive(Default)]
pub struct GraphRecorder {
    inner: Mutex<GraphInner>,
}

#[derive(Default)]
struct GraphInner {
    /// task id -> (label, rank)
    nodes: BTreeMap<u64, (String, u32)>,
    /// (from, to, via-object label)
    edges: Vec<(u64, u64, String)>,
}

impl GraphRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_node(&self, id: u64, label: &str, rank: u32) {
        self.inner
            .lock()
            .unwrap()
            .nodes
            .insert(id, (label.to_string(), rank));
    }

    pub fn add_edge(&self, from: u64, to: u64, via: &str) {
        self.inner.lock().unwrap().edges.push((from, to, via.to_string()));
    }

    pub fn node_count(&self) -> usize {
        self.inner.lock().unwrap().nodes.len()
    }

    pub fn edge_count(&self) -> usize {
        self.inner.lock().unwrap().edges.len()
    }

    /// Edges as (from, to) pairs (tests).
    pub fn edges(&self) -> Vec<(u64, u64)> {
        self.inner
            .lock()
            .unwrap()
            .edges
            .iter()
            .map(|(f, t, _)| (*f, *t))
            .collect()
    }

    /// Render Graphviz DOT, clustering nodes by rank like Fig 8. Edges
    /// whose object label matches `highlight` (e.g. the sentinel) are drawn
    /// red — the paper's "red dependencies".
    pub fn to_dot(&self, highlight: &str) -> String {
        let g = self.inner.lock().unwrap();
        let mut s = String::from("digraph deps {\n  rankdir=TB;\n  node [shape=box,fontsize=9];\n");
        let mut by_rank: BTreeMap<u32, Vec<u64>> = BTreeMap::new();
        for (id, (_, rank)) in &g.nodes {
            by_rank.entry(*rank).or_default().push(*id);
        }
        for (rank, ids) in &by_rank {
            s.push_str(&format!(
                "  subgraph cluster_rank{rank} {{\n    label=\"rank {rank}\";\n"
            ));
            for id in ids {
                let (label, _) = &g.nodes[id];
                s.push_str(&format!("    t{id} [label=\"{label}\"];\n"));
            }
            s.push_str("  }\n");
        }
        let mut seen = std::collections::HashSet::new();
        for (from, to, via) in &g.edges {
            if !seen.insert((*from, *to)) {
                continue; // fuse duplicate edges
            }
            let attr = if !highlight.is_empty() && via.contains(highlight) {
                " [color=red,penwidth=2]"
            } else {
                ""
            };
            s.push_str(&format!("  t{from} -> t{to}{attr};\n"));
        }
        s.push_str("}\n");
        s
    }
}
