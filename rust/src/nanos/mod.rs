//! `nanos` — a Nanos6-like task runtime with the paper's three APIs.
//!
//! This is the OmpSs-2/Nanos6 substrate the paper extends (Section 2.1 and
//! Section 4), rebuilt in Rust:
//!
//! * **Tasks with data dependencies** — object-granularity in/out/inout
//!   accesses; reader/writer access groups per dependency object give the
//!   OmpSs ordering semantics ([`deps`]).
//! * **Pause/resume API** (Section 4.1) — [`api::get_current_blocking_context`],
//!   [`api::block_current_task`], [`api::unblock_task`].  Pausing a task
//!   releases its *virtual core* to the scheduler (waking an idle worker or
//!   spawning a substitute — Nanos6's thread-leasing scheme, which is what
//!   makes the paper's blocking mode cost "threads and stacks proportional
//!   to in-flight MPI operations").
//! * **External events API** (Section 4.3) — [`api::get_current_event_counter`],
//!   [`api::increase_current_task_event_counter`],
//!   [`api::decrease_task_event_counter`].  A task's dependencies are
//!   released only when its body finished *and* its event counter hit zero.
//! * **Polling services API** (Section 4.2) — [`Runtime::register_polling_service`]
//!   and a leader thread that serves callbacks every `poll_interval` of
//!   virtual time plus opportunistic polling by idle workers (Section 4.5).
//!
//! All blocking points park through [`crate::sim::Clock`], so the runtime
//! runs under virtual time (see `sim` module docs).

pub mod api;
pub mod deps;
pub mod polling;
pub mod runtime;
pub mod scheduler;
pub mod task;
pub mod worker;

pub use api::{
    block_current_task, current_clock, decrease_task_event_counter,
    get_current_blocking_context, get_current_event_counter,
    increase_current_task_event_counter, unblock_task, work,
};
pub use deps::{DepObj, Mode};
pub use runtime::{CompletionMode, Runtime, RuntimeConfig, TaskBuilder};
pub use task::{BlockingContext, EventCounter};
