//! Task objects: state, event counter, blocking contexts.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::sim::clock::Token;

use super::deps::Access;
use super::runtime::Rt;

pub(crate) type TaskBody = Box<dyn FnOnce() + Send + 'static>;

/// Internal task representation.
pub struct TaskInner {
    pub id: u64,
    pub label: String,
    pub(crate) rt: Weak<Rt>,
    pub(crate) body: Mutex<Option<TaskBody>>,
    /// Pending completion events. Initialized to 1 (the running body,
    /// Section 4.6); external events add to it. Dependencies are released
    /// when it reaches zero.
    pub(crate) events: AtomicU32,
    /// Unsatisfied predecessor accesses + 1 registration sentinel.
    pub(crate) preds: AtomicU32,
    pub(crate) accesses: Vec<Access>,
    /// Current blocking context (one pause/resume round trip, Section 4.1).
    pub(crate) blocking: Mutex<Option<Arc<BlockCtx>>>,
    pub(crate) completed: AtomicBool,
}

impl TaskInner {
    /// Satisfy one predecessor access; enqueue as ready when all are met.
    pub(crate) fn dec_pred(self: &Arc<Self>) {
        if self.preds.fetch_sub(1, Ordering::AcqRel) == 1 {
            if let Some(rt) = self.rt.upgrade() {
                rt.sched.enqueue_new(self.clone(), &rt);
            }
        }
    }

    /// Body finished: drop one event; maybe fully complete.
    pub(crate) fn body_finished(self: &Arc<Self>) {
        self.dec_events(1);
    }

    pub(crate) fn inc_events(&self, n: u32) {
        let prev = self.events.fetch_add(n, Ordering::AcqRel);
        assert!(prev > 0, "task {} bound events after completion", self.id);
    }

    pub(crate) fn dec_events(self: &Arc<Self>, n: u32) {
        let prev = self.events.fetch_sub(n, Ordering::AcqRel);
        assert!(prev >= n, "task {} event counter underflow", self.id);
        if prev == n {
            self.fully_complete();
        }
    }

    /// [`TaskInner::dec_events`] for *external*-event fulfilment paths,
    /// counted per applied operation (`Rt::n_event_decs`): the metric
    /// the drain-time coalescing reduces from O(events) to O(tasks) per
    /// completion wave.
    pub(crate) fn dec_events_counted(self: &Arc<Self>, n: u32) {
        if let Some(rt) = self.rt.upgrade() {
            rt.n_event_decs.fetch_add(1, Ordering::Relaxed);
        }
        self.dec_events(n);
    }

    /// Body done and all external events fulfilled: release dependencies
    /// (Section 4.6) and notify taskwait.
    fn fully_complete(self: &Arc<Self>) {
        self.completed.store(true, Ordering::Release);
        if let Some(rt) = self.rt.upgrade() {
            for acc in &self.accesses {
                acc.obj.release(self);
            }
            rt.task_fully_completed(self);
        }
    }
}

/// State machine of one pause/resume round trip.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum CtxState {
    /// Created; neither block nor unblock happened.
    Armed,
    /// `unblock_task` arrived before `block_current_task`.
    UnblockedEarly,
    /// The task is parked waiting for a core grant.
    Waiting,
    /// A worker transferred its core; the parked thread may resume.
    Granted,
}

/// Runtime-internal blocking context (opaque to users, Section 4.1).
pub struct BlockCtx {
    pub(crate) st: Mutex<CtxState>,
    pub(crate) token: Arc<Token>,
    pub(crate) rt: Weak<Rt>,
    pub(crate) task_id: u64,
    pub(crate) task_label: String,
}

/// Opaque handle returned by `get_current_blocking_context` — the paper's
/// `void*` blocking context.
#[derive(Clone)]
pub struct BlockingContext(pub(crate) Arc<BlockCtx>);

/// Opaque handle returned by `get_current_event_counter` — the paper's
/// `void*` event counter. Cloneable and sendable to the fulfilling thread.
#[derive(Clone)]
pub struct EventCounter(pub(crate) Arc<TaskInner>);

impl std::fmt::Debug for EventCounter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "EventCounter(task {})", self.0.id)
    }
}
