//! Ready queue + virtual-core licensing + idle-worker pool.
//!
//! A worker must hold a *core license* to execute task code.  Pausing a
//! task (Section 4.1 / 4.4) releases the license so another worker can
//! pick up ready work; resuming transfers a license back to the parked
//! thread (Nanos6's thread-leasing scheme).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::sim::WaitQueue;

use super::task::{BlockCtx, CtxState, TaskInner};
use super::runtime::Rt;

/// Unit of schedulable work.
pub(crate) enum Item {
    /// A dependency-satisfied task ready for first execution.
    New(Arc<TaskInner>),
    /// A paused task whose `unblock_task` arrived; granting it a core
    /// resumes its parked thread (Section 4.4).
    Resume(Arc<BlockCtx>),
}

pub(crate) struct SchedState {
    pub free_cores: usize,
    pub ready: VecDeque<Item>,
    /// Workers parked on `work_q`.
    pub idle: usize,
    pub workers_total: usize,
    pub shutdown: bool,
}

pub(crate) struct Scheduler {
    pub st: Mutex<SchedState>,
    pub work_q: WaitQueue,
    pub max_workers: usize,
}

impl Scheduler {
    pub fn new(cores: usize, max_workers: usize) -> Self {
        Scheduler {
            st: Mutex::new(SchedState {
                free_cores: cores,
                ready: VecDeque::new(),
                idle: 0,
                workers_total: 0,
                shutdown: false,
            }),
            work_q: WaitQueue::new(),
            max_workers,
        }
    }

    /// Enqueue a freshly-ready task.
    pub fn enqueue_new(&self, task: Arc<TaskInner>, rt: &Arc<Rt>) {
        self.enqueue(Item::New(task), rt);
    }

    /// Enqueue a resume grant for an unblocked task.
    pub fn enqueue_resume(&self, ctx: Arc<BlockCtx>, rt: &Arc<Rt>) {
        self.enqueue(Item::Resume(ctx), rt);
    }

    fn enqueue(&self, item: Item, rt: &Arc<Rt>) {
        let mut g = self.st.lock().unwrap();
        g.ready.push_back(item);
        self.kick(&mut g, rt);
    }

    /// Ensure someone will serve the ready queue: wake an idle worker, or
    /// spawn a substitute if a core is free but every worker is occupied
    /// (all running tasks, parked in raw blocking calls, or paused).
    fn kick(&self, g: &mut SchedState, rt: &Arc<Rt>) {
        if g.free_cores == 0 || g.ready.is_empty() {
            return;
        }
        if g.idle > 0 {
            self.work_q.notify_one(&rt.clock);
        } else if g.workers_total < self.max_workers {
            g.workers_total += 1;
            super::worker::spawn_worker(rt.clone(), g.workers_total - 1);
        } else {
            // At the substitute-worker cap with no idle worker: if every
            // worker is parked inside a paused task, nothing can serve the
            // ready queue — the runtime wedges (the thread-explosion limit
            // of blocking mode the paper warns about). Warn loudly; the
            // clock's deadlock detector reports the hang.
            eprintln!(
                "nanos[{}]: worker cap {} reached with ready work pending — \
                 blocking-mode thread explosion (see RuntimeConfig::max_workers)",
                rt.cfg.label, self.max_workers
            );
        }
    }

    /// Worker main fetch: blocks (passively) until an item + core license
    /// is available, polling services opportunistically before idling
    /// (Section 4.5). Returns `None` on shutdown.
    pub fn next(&self, rt: &Arc<Rt>) -> Option<Item> {
        let mut g = self.st.lock().unwrap();
        loop {
            if g.shutdown && g.ready.is_empty() {
                return None;
            }
            if g.free_cores > 0 {
                if let Some(item) = g.ready.pop_front() {
                    g.free_cores -= 1;
                    return Some(item);
                }
            }
            // Serve polling callbacks before letting the core go idle.
            drop(g);
            rt.polling.poll_once();
            g = self.st.lock().unwrap();
            if g.free_cores > 0 && !g.ready.is_empty() {
                continue;
            }
            if g.shutdown && g.ready.is_empty() {
                return None;
            }
            g.idle += 1;
            let tok = self.work_q.enqueue();
            drop(g);
            rt.clock.passive_wait(&tok);
            g = self.st.lock().unwrap();
            g.idle -= 1;
        }
    }

    /// Return a license after finishing a task body. Only notifies idle
    /// workers (never spawns): the caller re-enters `next` immediately and
    /// will serve remaining work itself.
    pub fn release_core(&self, rt: &Arc<Rt>) {
        let mut g = self.st.lock().unwrap();
        g.free_cores += 1;
        if !g.ready.is_empty() && g.idle > 0 {
            self.work_q.notify_one(&rt.clock);
        }
    }

    /// Release the license because the current task paused. Wakes/spawns a
    /// substitute worker if there is ready work to pick up.
    pub fn release_core_for_block(&self, rt: &Arc<Rt>) {
        let mut g = self.st.lock().unwrap();
        g.free_cores += 1;
        self.kick(&mut g, rt);
    }

    /// Grant the calling worker's license to a paused task's thread.
    /// The caller no longer holds a license afterwards.
    pub fn grant_core(&self, ctx: &Arc<BlockCtx>, rt: &Arc<Rt>) {
        {
            let mut st = ctx.st.lock().unwrap();
            debug_assert_eq!(*st, CtxState::Waiting, "grant on non-waiting ctx");
            *st = CtxState::Granted;
        }
        rt.clock.wake(&ctx.token);
    }

    pub fn begin_shutdown(&self, rt: &Arc<Rt>) {
        let mut g = self.st.lock().unwrap();
        g.shutdown = true;
        drop(g);
        self.work_q.notify_all(&rt.clock);
    }

    /// Diagnostics: (free cores, ready length, idle, total workers).
    pub fn stats(&self) -> (usize, usize, usize, usize) {
        let g = self.st.lock().unwrap();
        (g.free_cores, g.ready.len(), g.idle, g.workers_total)
    }

    pub fn is_shutdown(&self) -> bool {
        self.st.lock().unwrap().shutdown
    }

    /// Total workers ever spawned (paper: thread cost of blocking mode).
    pub fn workers_spawned(&self) -> usize {
        self.st.lock().unwrap().workers_total
    }

    pub(crate) fn register_initial_worker(&self) -> usize {
        let mut g = self.st.lock().unwrap();
        g.workers_total += 1;
        g.workers_total - 1
    }
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (fc, rq, idle, tot) = self.stats();
        write!(
            f,
            "Scheduler {{ free_cores: {fc}, ready: {rq}, idle: {idle}, workers: {tot} }}"
        )
    }
}
