//! Ready queues + virtual-core licensing + idle-worker pool.
//!
//! A worker must hold a *core license* to execute task code.  Pausing a
//! task (Section 4.1 / 4.4) releases the license so another worker can
//! pick up ready work; resuming transfers a license back to the parked
//! thread (Nanos6's thread-leasing scheme).
//!
//! Ready work is held in **per-worker local deques plus a shared
//! injector**: a worker enqueuing onto its own runtime pushes to its local
//! deque; off-runtime threads (rank mains, the clock thread, polling
//! leaders) and bulk resume batches from the sharded progress engine
//! ([`crate::progress`]) land on the injector. Workers pop local-first,
//! then the injector, then steal from the back of other locals — so a
//! completion wave's resume burst spreads across workers without
//! funnelling through a single queue mutex. Core licensing is unchanged:
//! the license handshake still runs under the small `st` mutex, which no
//! longer guards any queue.
//!
//! The [`DeferredEnqueue`] scope is the bulk-enqueue half of the progress
//! engine: while a shard batch drains, `enqueue_new`/`enqueue_resume`
//! collect items per runtime instead of inserting them, and the drain
//! hands each runtime one [`Scheduler::enqueue_bulk`] — one queue-lock +
//! one kick per shard-batch instead of one per continuation.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::WaitQueue;

use super::runtime::Rt;
use super::task::{BlockCtx, CtxState, TaskInner};
use super::worker;

/// Unit of schedulable work.
pub(crate) enum Item {
    /// A dependency-satisfied task ready for first execution.
    New(Arc<TaskInner>),
    /// A paused task whose `unblock_task` arrived; granting it a core
    /// resumes its parked thread (Section 4.4).
    Resume(Arc<BlockCtx>),
}

pub(crate) struct SchedState {
    pub free_cores: usize,
    /// Workers parked on `work_q`.
    pub idle: usize,
    pub workers_total: usize,
    pub shutdown: bool,
}

pub(crate) struct Scheduler {
    pub st: Mutex<SchedState>,
    /// Shared overflow/injector queue: off-runtime pushes and bulk
    /// resume batches land here.
    injector: Mutex<VecDeque<Item>>,
    /// Per-worker local deques (one slot per configured core; workers map
    /// by `index % slots`, so substitute workers share the slot of the
    /// core they stand in for).
    locals: Vec<Mutex<VecDeque<Item>>>,
    /// Total queued items across injector + locals. Push-then-increment
    /// / pop-then-decrement, so readers may transiently see it *under*
    /// (item pushed, count not yet bumped) or *over* (item popped, count
    /// not yet dropped). Neither direction is load-bearing on its own:
    /// a zero read never proves emptiness — every enqueue path calls
    /// `kick` only after its own increment, which is what makes the
    /// park/wake protocol in `next` lost-wakeup-free.
    ready_len: AtomicUsize,
    pub work_q: WaitQueue,
    pub max_workers: usize,
    /// Queue-lock acquisitions that inserted task resumes — the metric
    /// the sharded progress engine amortizes (one per resume under
    /// direct delivery, one per shard-batch under sharded delivery).
    resume_lock_ops: AtomicU64,
    /// Bulk inserts performed (shard-batch drains).
    bulk_enqueues: AtomicU64,
    /// Items taken from another worker's local deque.
    steals: AtomicU64,
    /// Failed steal probes: a victim deque locked and found empty. The
    /// adaptive last-victim order below exists to keep this low on wide
    /// runtimes.
    steal_probes: AtomicU64,
    /// Per-slot memory of the last successful steal victim: a loaded
    /// deque (one worker spawning or receiving a resume burst) tends to
    /// stay loaded, so re-probing it first skips most of the
    /// round-robin scan. `usize::MAX` = no memory yet.
    last_victim: Vec<AtomicUsize>,
}

/// Deferred items grouped by target runtime.
pub(crate) type DeferredGroups = Vec<(Arc<Rt>, Vec<Item>)>;

thread_local! {
    /// Active [`DeferredEnqueue`] scope of this thread: items grouped by
    /// target runtime, awaiting one bulk insert each.
    static DEFER: RefCell<Option<DeferredGroups>> = const { RefCell::new(None) };
}

/// RAII scope collecting `enqueue_new`/`enqueue_resume` calls on the
/// current thread into per-runtime batches instead of inserting them.
/// Used by [`crate::progress::Shard`] while draining a completion batch;
/// finish with [`DeferredEnqueue::finish`] and hand each group to
/// [`Scheduler::enqueue_bulk`].
pub(crate) struct DeferredEnqueue(());

impl DeferredEnqueue {
    pub(crate) fn begin() -> DeferredEnqueue {
        DEFER.with(|d| {
            let mut b = d.borrow_mut();
            assert!(b.is_none(), "nested DeferredEnqueue scopes");
            *b = Some(Vec::new());
        });
        DeferredEnqueue(())
    }

    /// Close the scope and return the collected per-runtime batches.
    pub(crate) fn finish(self) -> DeferredGroups {
        DEFER.with(|d| d.borrow_mut().take()).unwrap_or_default()
    }
}

impl Drop for DeferredEnqueue {
    fn drop(&mut self) {
        // Panic-unwind safety: never leave a stale scope on the thread.
        DEFER.with(|d| {
            d.borrow_mut().take();
        });
    }
}

/// Try to divert `item` into the thread's active deferral scope.
/// Returns the item back when no scope is active.
fn defer_push(rt: &Arc<Rt>, item: Item) -> Option<Item> {
    DEFER.with(|d| {
        let mut b = d.borrow_mut();
        match b.as_mut() {
            Some(groups) => {
                if let Some((_, items)) =
                    groups.iter_mut().find(|(r, _)| Arc::ptr_eq(r, rt))
                {
                    items.push(item);
                } else {
                    groups.push((rt.clone(), vec![item]));
                }
                None
            }
            None => Some(item),
        }
    })
}

impl Scheduler {
    pub fn new(cores: usize, max_workers: usize) -> Self {
        Scheduler {
            st: Mutex::new(SchedState {
                free_cores: cores,
                idle: 0,
                workers_total: 0,
                shutdown: false,
            }),
            injector: Mutex::new(VecDeque::new()),
            locals: (0..cores.max(1)).map(|_| Mutex::new(VecDeque::new())).collect(),
            ready_len: AtomicUsize::new(0),
            work_q: WaitQueue::new(),
            max_workers,
            resume_lock_ops: AtomicU64::new(0),
            bulk_enqueues: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            steal_probes: AtomicU64::new(0),
            last_victim: (0..cores.max(1))
                .map(|_| AtomicUsize::new(usize::MAX))
                .collect(),
        }
    }

    fn ready_count(&self) -> usize {
        self.ready_len.load(Ordering::Acquire)
    }

    /// Enqueue a freshly-ready task.
    pub fn enqueue_new(&self, task: Arc<TaskInner>, rt: &Arc<Rt>) {
        self.enqueue_item(Item::New(task), rt);
    }

    /// Enqueue a resume grant for an unblocked task.
    pub fn enqueue_resume(&self, ctx: Arc<BlockCtx>, rt: &Arc<Rt>) {
        self.enqueue_item(Item::Resume(ctx), rt);
    }

    fn enqueue_item(&self, item: Item, rt: &Arc<Rt>) {
        // A shard drain on this thread collects instead of inserting.
        let Some(item) = defer_push(rt, item) else { return };
        if matches!(item, Item::Resume(_)) {
            self.resume_lock_ops.fetch_add(1, Ordering::Relaxed);
        }
        self.push_item(item, rt);
        let mut g = self.st.lock().unwrap();
        self.kick(&mut g, rt, 1);
    }

    /// Insert a whole batch (a drained shard's resumes) with one queue
    /// lock and one kick — the bulk half of the progress engine.
    pub(crate) fn enqueue_bulk(&self, items: Vec<Item>, rt: &Arc<Rt>) {
        if items.is_empty() {
            return;
        }
        let n = items.len();
        if items.iter().any(|i| matches!(i, Item::Resume(_))) {
            self.resume_lock_ops.fetch_add(1, Ordering::Relaxed);
        }
        self.bulk_enqueues.fetch_add(1, Ordering::Relaxed);
        self.injector.lock().unwrap().extend(items);
        self.ready_len.fetch_add(n, Ordering::AcqRel);
        let mut g = self.st.lock().unwrap();
        self.kick(&mut g, rt, n);
    }

    /// The local slot of the calling thread, when it is a worker of
    /// *this* scheduler's runtime.
    fn local_slot(&self, rt: &Arc<Rt>) -> Option<usize> {
        let cur = worker::current_rt()?;
        if !Arc::ptr_eq(&cur, rt) {
            return None;
        }
        let w = worker::worker_id();
        if w == usize::MAX {
            None // attached rank main, not a worker
        } else {
            Some(w % self.locals.len())
        }
    }

    fn push_item(&self, item: Item, rt: &Arc<Rt>) {
        match self.local_slot(rt) {
            Some(slot) => self.locals[slot].lock().unwrap().push_back(item),
            None => self.injector.lock().unwrap().push_back(item),
        }
        self.ready_len.fetch_add(1, Ordering::AcqRel);
    }

    /// Pop ready work for worker slot `wslot`: local deque first, then
    /// the injector, then steal from the back of other locals — probing
    /// the slot's last successful victim first, falling back to a
    /// round-robin scan (adaptive steal order).
    fn try_pop(&self, wslot: usize, rt: &Arc<Rt>) -> Option<Item> {
        if let Some(item) = self.locals[wslot].lock().unwrap().pop_front() {
            self.ready_len.fetch_sub(1, Ordering::AcqRel);
            return Some(item);
        }
        if let Some(item) = self.injector.lock().unwrap().pop_front() {
            self.ready_len.fetch_sub(1, Ordering::AcqRel);
            return Some(item);
        }
        let n = self.locals.len();
        let remembered = self.last_victim[wslot].load(Ordering::Relaxed);
        if remembered < n && remembered != wslot {
            if let Some(item) = self.steal_from(remembered, rt) {
                return Some(item);
            }
        }
        for k in 1..n {
            let victim = (wslot + k) % n;
            if victim == remembered {
                continue; // already probed above
            }
            if let Some(item) = self.steal_from(victim, rt) {
                self.last_victim[wslot].store(victim, Ordering::Relaxed);
                return Some(item);
            }
        }
        None
    }

    /// One steal probe against `victim`'s deque; counts misses.
    fn steal_from(&self, victim: usize, rt: &Arc<Rt>) -> Option<Item> {
        match self.locals[victim].lock().unwrap().pop_back() {
            Some(item) => {
                self.ready_len.fetch_sub(1, Ordering::AcqRel);
                self.steals.fetch_add(1, Ordering::Relaxed);
                if let Some(obs) = rt.cfg.obs.as_ref() {
                    let wid = worker::worker_id();
                    let w = if wid == usize::MAX { u32::MAX } else { wid as u32 };
                    obs.record(crate::obs::Span::point(
                        crate::obs::Track::Worker { rank: rt.cfg.rank, worker: w },
                        crate::obs::SpanKind::Steal,
                        rt.clock.now(),
                        "steal",
                        victim as u64,
                    ));
                }
                Some(item)
            }
            None => {
                self.steal_probes.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Ensure up to `want` ready items will be served: wake idle workers,
    /// or spawn substitutes while a core is free and every worker is
    /// occupied (running tasks, parked in raw blocking calls, or paused).
    fn kick(&self, g: &mut SchedState, rt: &Arc<Rt>, want: usize) {
        let mut want = want.min(g.free_cores).min(self.ready_count());
        if want == 0 {
            return;
        }
        // Credit idle workers whether or not a token is still parked:
        // a worker counted in `idle` whose token was already popped is
        // mid-wakeup and will re-check the queues before re-parking, so
        // spawning a substitute for it would only inflate the thread
        // count (a reported metric).
        let idle_wakes = want.min(g.idle);
        for _ in 0..idle_wakes {
            self.work_q.notify_one(&rt.clock);
        }
        want -= idle_wakes;
        // Never spawn once shutdown began: a teardown straggler (e.g. an
        // observer continuation fired by the clock's stop-drain) may
        // still enqueue, but creating a worker on a stopping/stopped
        // clock would leak a thread; surviving workers drain the queues
        // before exiting.
        if g.shutdown {
            return;
        }
        while want > 0 && g.workers_total < self.max_workers {
            g.workers_total += 1;
            super::worker::spawn_worker(rt.clone(), g.workers_total - 1);
            want -= 1;
        }
        if want > 0 && g.idle == 0 {
            // At the substitute-worker cap with no idle worker: if every
            // worker is parked inside a paused task, nothing can serve the
            // ready queue — the runtime wedges (the thread-explosion limit
            // of blocking mode the paper warns about). Warn loudly; the
            // clock's deadlock detector reports the hang.
            eprintln!(
                "nanos[{}]: worker cap {} reached with ready work pending — \
                 blocking-mode thread explosion (see RuntimeConfig::max_workers)",
                rt.cfg.label, self.max_workers
            );
        }
    }

    /// Worker main fetch: blocks (passively) until an item + core license
    /// is available, polling services opportunistically before idling
    /// (Section 4.5). Returns `None` on shutdown.
    pub fn next(&self, rt: &Arc<Rt>, worker_index: usize) -> Option<Item> {
        let wslot = worker_index % self.locals.len();
        let mut g = self.st.lock().unwrap();
        loop {
            if g.shutdown && self.ready_count() == 0 {
                return None;
            }
            if g.free_cores > 0 && self.ready_count() > 0 {
                g.free_cores -= 1;
                drop(g);
                if let Some(item) = self.try_pop(wslot, rt) {
                    return Some(item);
                }
                // Raced with other workers for the last items: hand the
                // license back and re-evaluate.
                g = self.st.lock().unwrap();
                g.free_cores += 1;
                continue;
            }
            // Serve polling callbacks before letting the core go idle.
            drop(g);
            rt.polling.poll_once();
            g = self.st.lock().unwrap();
            if g.free_cores > 0 && self.ready_count() > 0 {
                continue;
            }
            if g.shutdown && self.ready_count() == 0 {
                return None;
            }
            g.idle += 1;
            let tok = self.work_q.enqueue();
            drop(g);
            rt.clock.passive_wait(&tok);
            g = self.st.lock().unwrap();
            g.idle -= 1;
        }
    }

    /// Return a license after finishing a task body. Only notifies idle
    /// workers (never spawns): the caller re-enters `next` immediately and
    /// will serve remaining work itself.
    pub fn release_core(&self, rt: &Arc<Rt>) {
        let mut g = self.st.lock().unwrap();
        g.free_cores += 1;
        if self.ready_count() > 0 && g.idle > 0 {
            self.work_q.notify_one(&rt.clock);
        }
    }

    /// Release the license because the current task paused. Wakes/spawns a
    /// substitute worker if there is ready work to pick up.
    pub fn release_core_for_block(&self, rt: &Arc<Rt>) {
        let mut g = self.st.lock().unwrap();
        g.free_cores += 1;
        self.kick(&mut g, rt, 1);
    }

    /// Grant the calling worker's license to a paused task's thread.
    /// The caller no longer holds a license afterwards.
    pub fn grant_core(&self, ctx: &Arc<BlockCtx>, rt: &Arc<Rt>) {
        {
            let mut st = ctx.st.lock().unwrap();
            debug_assert_eq!(*st, CtxState::Waiting, "grant on non-waiting ctx");
            *st = CtxState::Granted;
        }
        rt.clock.wake(&ctx.token);
    }

    pub fn begin_shutdown(&self, rt: &Arc<Rt>) {
        let mut g = self.st.lock().unwrap();
        g.shutdown = true;
        drop(g);
        self.work_q.notify_all(&rt.clock);
    }

    /// Diagnostics: (free cores, ready length, idle, total workers).
    pub fn stats(&self) -> (usize, usize, usize, usize) {
        let g = self.st.lock().unwrap();
        (g.free_cores, self.ready_count(), g.idle, g.workers_total)
    }

    /// Delivery-path counters: (resume-enqueue lock acquisitions, bulk
    /// enqueues, work steals, failed steal probes).
    pub fn counters(&self) -> (u64, u64, u64, u64) {
        (
            self.resume_lock_ops.load(Ordering::Relaxed),
            self.bulk_enqueues.load(Ordering::Relaxed),
            self.steals.load(Ordering::Relaxed),
            self.steal_probes.load(Ordering::Relaxed),
        )
    }

    pub fn is_shutdown(&self) -> bool {
        self.st.lock().unwrap().shutdown
    }

    /// Total workers ever spawned (paper: thread cost of blocking mode).
    pub fn workers_spawned(&self) -> usize {
        self.st.lock().unwrap().workers_total
    }

    pub(crate) fn register_initial_worker(&self) -> usize {
        let mut g = self.st.lock().unwrap();
        g.workers_total += 1;
        g.workers_total - 1
    }
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (fc, rq, idle, tot) = self.stats();
        write!(
            f,
            "Scheduler {{ free_cores: {fc}, ready: {rq}, idle: {idle}, workers: {tot} }}"
        )
    }
}
