//! Runtime facade: task creation, taskwait, lifecycle.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::{Clock, VNanos, WaitQueue};
use crate::trace::{EventKind, GraphRecorder, Record, Tracer};

use super::deps::{Access, DepObj, Mode};
use super::polling::{PollingRegistry, PollingService};
use super::scheduler::Scheduler;
use super::task::{TaskBody, TaskInner};
use super::worker;

/// Globally-unique task ids (across all runtimes/ranks, for Fig 8 graphs).
static NEXT_TASK_ID: AtomicU64 = AtomicU64::new(1);

/// Virtual-time costs of runtime operations. These model the *measured*
/// overheads of a real task runtime (Nanos6-class numbers) and are what
/// makes Section 6.2's blocking-vs-events comparison meaningful under
/// virtual time: pausing a task really costs two context switches; a
/// TAMPI ticket does not.
///
/// Defaults are zero (unit tests assert exact virtual times); apps and
/// benches use [`RuntimeCosts::realistic`] via `ClusterConfig`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeCosts {
    /// Creating + submitting a task (allocation, queueing).
    pub task_spawn_ns: u64,
    /// Registering one dependency access at submission.
    pub per_access_ns: u64,
    /// Scheduling + dispatch overhead per task execution.
    pub task_exec_ns: u64,
    /// Pausing a task: context switch out + core handoff.
    pub pause_ns: u64,
    /// Resuming a paused task: grant + context switch in.
    pub resume_ns: u64,
    /// Binding/fulfilling one external event (atomic + ticket bookkeeping).
    pub event_ns: u64,
}

impl RuntimeCosts {
    /// Nanos6-class overheads (order-of-magnitude of published
    /// measurements on Xeon-class cores).
    pub fn realistic() -> RuntimeCosts {
        RuntimeCosts {
            task_spawn_ns: 500,
            per_access_ns: 150,
            task_exec_ns: 300,
            pause_ns: 1_500,
            resume_ns: 1_500,
            event_ns: 120,
        }
    }

    /// No modeled overheads (exact-time unit tests).
    pub fn zero() -> RuntimeCosts {
        RuntimeCosts::default()
    }
}

/// How the TAMPI interop layer learns that a pending MPI operation
/// completed (Section 6 wiring; see `crate::tampi` module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CompletionMode {
    /// Paper-faithful baseline: pending operations file tickets and a
    /// polling service re-scans them every `poll_interval` (plus
    /// opportunistic idle-worker passes). O(pending) work per pass;
    /// completion latency is bounded by the polling period. Preserved
    /// for figure reproduction.
    Polling,
    /// Completion continuations: a callback attached to each pending
    /// request pushes the notification from the exact virtual instant
    /// the operation completes. No tickets, no scan, no polling latency.
    #[default]
    Callback,
}

/// Configuration of one rank's runtime instance.
#[derive(Clone)]
pub struct RuntimeConfig {
    /// Virtual cores (hardware threads) this rank owns.
    pub cores: usize,
    /// Leader-thread polling period in virtual ns (Section 4.5; Nanos6
    /// uses 1 ms — configurable here because the experiment is time-scaled).
    pub poll_interval: VNanos,
    /// Label used in thread names and traces (e.g. "rank3").
    pub label: String,
    /// Rank id for tracing.
    pub rank: u32,
    /// Stack size of worker threads. Paused tasks keep a whole worker
    /// stack alive — exactly the cost the paper's non-blocking mode avoids.
    pub worker_stack: usize,
    /// Hard cap on substitute workers (safety valve; the paper's blocking
    /// mode grows threads proportionally to in-flight operations).
    pub max_workers: usize,
    pub tracer: Option<Arc<Tracer>>,
    pub graph: Option<Arc<GraphRecorder>>,
    /// Observability bundle (spans + metrics). Set by the universe;
    /// `None` for standalone runtimes. Emission sites only read
    /// `Clock::now()` — recording never perturbs virtual time.
    pub obs: Option<Arc<crate::obs::RunObs>>,
    /// Modeled runtime operation costs (virtual ns).
    pub costs: RuntimeCosts,
    /// How TAMPI on this runtime is notified of MPI completions.
    pub completion_mode: CompletionMode,
    /// Clock lane this rank's threads (workers + leader) run under
    /// (0 on a single-lane clock; set by the universe from its
    /// node-to-shard partition).
    pub clock_lane: usize,
}

impl RuntimeConfig {
    pub fn new(cores: usize) -> Self {
        RuntimeConfig {
            cores,
            poll_interval: crate::sim::us(50),
            label: "rt".into(),
            rank: 0,
            worker_stack: 512 * 1024,
            max_workers: cores + 16 * 1024,
            tracer: None,
            graph: None,
            obs: None,
            costs: RuntimeCosts::zero(),
            completion_mode: CompletionMode::default(),
            clock_lane: 0,
        }
    }
}

/// Runtime internals (shared by workers, leader, API functions).
pub struct Rt {
    pub clock: Arc<Clock>,
    pub cfg: RuntimeConfig,
    pub(crate) sched: Scheduler,
    pub(crate) polling: PollingRegistry,
    pending: Mutex<usize>,
    tw_q: WaitQueue,
    shutdown: AtomicBool,
    /// Statistics: tasks created / paused (for EXPERIMENTS.md).
    pub(crate) n_tasks: AtomicU64,
    pub(crate) n_pauses: AtomicU64,
    /// External-event decrement operations applied (each `dec_events(n)`
    /// from the events API counts once; drain-time coalescing makes this
    /// O(tasks) instead of O(events) per completion wave).
    pub(crate) n_event_decs: AtomicU64,
    /// Panics captured from task bodies (re-raised at taskwait).
    task_panics: Mutex<Vec<String>>,
}

impl Rt {
    pub(crate) fn trace(&self, kind: EventKind, worker: usize, label: &str, task_id: u64) {
        if let Some(tr) = &self.cfg.tracer {
            tr.emit(Record {
                t: self.clock.now(),
                rank: self.cfg.rank,
                worker: worker as u32,
                kind,
                label: label.to_string(),
                task_id,
            });
        }
    }

    pub(crate) fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    pub(crate) fn record_task_panic(&self, msg: String) {
        self.task_panics.lock().unwrap().push(msg);
    }

    pub(crate) fn task_fully_completed(&self, _task: &Arc<TaskInner>) {
        let mut g = self.pending.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            drop(g);
            self.tw_q.notify_all(&self.clock);
        }
    }
}

/// Public handle to one rank's task runtime.
#[derive(Clone)]
pub struct Runtime {
    pub(crate) rt: Arc<Rt>,
}

impl Runtime {
    /// Create the runtime and start its `cores` workers plus the polling
    /// leader thread. The calling thread must be clock-registered (or be
    /// about to hand the runtime to sim threads).
    pub fn new(clock: Arc<Clock>, cfg: RuntimeConfig) -> Runtime {
        let rt = Arc::new(Rt {
            clock,
            sched: Scheduler::new(cfg.cores, cfg.max_workers),
            polling: PollingRegistry::new(),
            pending: Mutex::new(0),
            tw_q: WaitQueue::new(),
            shutdown: AtomicBool::new(false),
            n_tasks: AtomicU64::new(0),
            n_pauses: AtomicU64::new(0),
            n_event_decs: AtomicU64::new(0),
            task_panics: Mutex::new(Vec::new()),
            cfg,
        });
        for _ in 0..rt.cfg.cores {
            let idx = rt.sched.register_initial_worker();
            worker::spawn_worker(rt.clone(), idx);
        }
        // Polling leader (registered on this rank's clock lane — the
        // creating thread may run on a different lane, or none).
        rt.clock.register_thread_on(rt.cfg.clock_lane);
        let weak = Arc::downgrade(&rt);
        std::thread::Builder::new()
            .name(format!("{}-leader", rt.cfg.label))
            .stack_size(128 * 1024)
            .spawn(move || super::polling::leader_main(weak))
            .expect("spawn leader");
        Runtime { rt }
    }

    /// Begin building a task.
    pub fn task(&self) -> TaskBuilder {
        TaskBuilder {
            rt: self.rt.clone(),
            label: String::new(),
            accesses: Vec::new(),
        }
    }

    /// Create a named dependency object.
    pub fn dep(&self, label: impl Into<String>) -> DepObj {
        DepObj::new(label)
    }

    /// Block the calling (non-worker) thread until every submitted task has
    /// fully completed — body finished *and* external events fulfilled.
    pub fn taskwait(&self) {
        // Settle any accumulated spawn-cost debt before waiting.
        self.rt.clock.flush_debt();
        loop {
            let tok = {
                let g = self.rt.pending.lock().unwrap();
                if *g == 0 {
                    break;
                }
                self.rt.tw_q.enqueue()
            };
            self.rt.clock.passive_wait(&tok);
        }
        // Surface task-body panics at the synchronization point.
        let panics = std::mem::take(&mut *self.rt.task_panics.lock().unwrap());
        if !panics.is_empty() {
            panic!("task panic(s): {}", panics.join("; "));
        }
    }

    /// Number of not-fully-completed tasks.
    pub fn pending_tasks(&self) -> usize {
        *self.rt.pending.lock().unwrap()
    }

    /// Register a polling service (Section 4.2).
    pub fn register_polling_service(&self, name: impl Into<String>, f: PollingService) {
        self.rt.polling.register(name, f, &self.rt);
    }

    /// Register a *hinted* polling service: it promises to report its
    /// pending-work count through [`Runtime::polling_hint_add`]/`_sub`,
    /// letting the leader thread park while nothing is in flight.
    pub fn register_polling_service_hinted(&self, name: impl Into<String>, f: PollingService) {
        self.rt.polling.register_hinted(name, f, &self.rt);
    }

    /// Report pending-work units for hinted polling services.
    pub fn polling_hint_add(&self, n: usize) {
        self.rt.polling.hint_add(n, &self.rt);
    }

    pub fn polling_hint_sub(&self, n: usize) {
        self.rt.polling.hint_sub(n);
    }

    /// Modeled runtime costs.
    pub fn costs(&self) -> &RuntimeCosts {
        &self.rt.cfg.costs
    }

    /// How TAMPI on this runtime is notified of MPI completions.
    pub fn completion_mode(&self) -> CompletionMode {
        self.rt.cfg.completion_mode
    }

    /// Weak handle to the runtime internals (for registry closures that
    /// must not keep the runtime alive).
    pub fn downgrade(&self) -> std::sync::Weak<Rt> {
        Arc::downgrade(&self.rt)
    }

    /// Unregister a polling service; returns whether it existed.
    pub fn unregister_polling_service(&self, name: &str) -> bool {
        self.rt.polling.unregister(name)
    }

    /// Attach the calling thread to this runtime (rank-main threads call
    /// this once so API helpers and task submission work).
    pub fn attach(&self) {
        worker::attach_thread(self.rt.clone());
    }

    pub fn detach(&self) {
        worker::detach_thread();
    }

    /// Graceful shutdown: workers and leader exit once the ready queue
    /// drains. Call only after `taskwait`.
    pub fn shutdown(&self) {
        self.rt.shutdown.store(true, Ordering::Release);
        self.rt.sched.begin_shutdown(&self.rt);
        self.rt.polling.wake_leader(&self.rt.clock);
    }

    pub fn clock(&self) -> &Arc<Clock> {
        &self.rt.clock
    }

    /// Scheduler delivery-path counters: (queue-lock acquisitions that
    /// inserted task resumes, bulk enqueues from shard-batch drains,
    /// items stolen from other workers' local deques, failed steal
    /// probes). The first is the metric the sharded progress engine
    /// ([`crate::progress`]) reduces from O(resumes) to O(shard-batches)
    /// on completion waves; the last is what the adaptive steal order
    /// cuts.
    pub fn sched_counters(&self) -> (u64, u64, u64, u64) {
        self.rt.sched.counters()
    }

    /// External-event decrement operations applied on this runtime (see
    /// `RunStats::event_dec_ops`).
    pub fn event_dec_ops(&self) -> u64 {
        self.rt.n_event_decs.load(Ordering::Relaxed)
    }

    /// (tasks created, pauses performed, workers spawned).
    pub fn stats(&self) -> (u64, u64, usize) {
        (
            self.rt.n_tasks.load(Ordering::Relaxed),
            self.rt.n_pauses.load(Ordering::Relaxed),
            self.rt.sched.workers_spawned(),
        )
    }
}

/// Builder for one task: label, dependencies, body.
pub struct TaskBuilder {
    rt: Arc<Rt>,
    label: String,
    accesses: Vec<(DepObj, Mode)>,
}

impl TaskBuilder {
    pub fn label(mut self, l: impl Into<String>) -> Self {
        self.label = l.into();
        self
    }

    /// Declare a dependency access.
    pub fn dep(mut self, obj: &DepObj, mode: Mode) -> Self {
        self.accesses.push((obj.clone(), mode));
        self
    }

    pub fn depends_in(self, obj: &DepObj) -> Self {
        self.dep(obj, Mode::In)
    }

    pub fn depends_out(self, obj: &DepObj) -> Self {
        self.dep(obj, Mode::Out)
    }

    pub fn depends_inout(self, obj: &DepObj) -> Self {
        self.dep(obj, Mode::InOut)
    }

    /// Provide the body and submit the task. Returns its id.
    pub fn spawn(self, body: impl FnOnce() + Send + 'static) -> u64 {
        self.spawn_boxed(Box::new(body))
    }

    pub fn spawn_boxed(self, body: TaskBody) -> u64 {
        let rt = self.rt;
        let id = NEXT_TASK_ID.fetch_add(1, Ordering::Relaxed);
        rt.n_tasks.fetch_add(1, Ordering::Relaxed);
        // Task creation cost, charged (as debt) to the submitting thread.
        let c = &rt.cfg.costs;
        Clock::add_debt(c.task_spawn_ns + c.per_access_ns * self.accesses.len() as u64);
        let task = Arc::new(TaskInner {
            id,
            label: if self.label.is_empty() {
                format!("task{id}")
            } else {
                self.label
            },
            rt: Arc::downgrade(&rt),
            body: Mutex::new(Some(body)),
            events: std::sync::atomic::AtomicU32::new(1),
            preds: std::sync::atomic::AtomicU32::new(1),
            accesses: self
                .accesses
                .iter()
                .map(|(o, m)| Access { obj: o.0.clone(), mode: *m })
                .collect(),
            blocking: Mutex::new(None),
            completed: AtomicBool::new(false),
        });
        {
            let mut g = rt.pending.lock().unwrap();
            *g += 1;
        }
        let record = rt.cfg.graph.is_some();
        if let Some(gr) = &rt.cfg.graph {
            gr.add_node(id, &task.label, rt.cfg.rank);
        }
        for (obj, mode) in &self.accesses {
            task.preds.fetch_add(1, Ordering::AcqRel);
            let (satisfied, preds) = obj.0.register(&task, *mode, record);
            if satisfied {
                task.preds.fetch_sub(1, Ordering::AcqRel);
            }
            if let Some(gr) = &rt.cfg.graph {
                for (pid, _plabel) in preds {
                    gr.add_edge(pid, id, obj.label());
                }
            }
        }
        // Drop the registration sentinel; may enqueue the task.
        task.dec_pred();
        id
    }
}
