//! Worker threads: execute tasks, transfer cores on resume grants.

use std::cell::RefCell;
use std::sync::Arc;

use super::runtime::Rt;
use super::scheduler::Item;
use super::task::TaskInner;
use crate::trace::EventKind;

thread_local! {
    /// (runtime, current task) of the executing worker thread.
    pub(crate) static CURRENT: RefCell<Option<(Arc<Rt>, Option<Arc<TaskInner>>)>> =
        const { RefCell::new(None) };
    /// Worker index within its runtime (for tracing).
    pub(crate) static WORKER_ID: RefCell<usize> = const { RefCell::new(usize::MAX) };
}

/// Read the current (runtime, task) pair, if on a worker thread in a task.
pub(crate) fn current() -> Option<(Arc<Rt>, Arc<TaskInner>)> {
    CURRENT.with(|c| {
        c.borrow()
            .as_ref()
            .and_then(|(rt, t)| t.as_ref().map(|t| (rt.clone(), t.clone())))
    })
}

/// Read the current runtime (worker or attached rank-main thread).
pub(crate) fn current_rt() -> Option<Arc<Rt>> {
    CURRENT.with(|c| c.borrow().as_ref().map(|(rt, _)| rt.clone()))
}

pub(crate) fn worker_id() -> usize {
    WORKER_ID.with(|w| *w.borrow())
}

/// Attach a non-worker thread (a rank main) to a runtime so it can submit
/// tasks, call taskwait, and use clock helpers.
pub(crate) fn attach_thread(rt: Arc<Rt>) {
    CURRENT.with(|c| *c.borrow_mut() = Some((rt, None)));
}

pub(crate) fn detach_thread() {
    CURRENT.with(|c| *c.borrow_mut() = None);
}

/// Spawn one worker thread. Called with the scheduler lock held by the
/// spawner (the total was already incremented).
pub(crate) fn spawn_worker(rt: Arc<Rt>, index: usize) {
    let stack = rt.cfg.worker_stack;
    let name = format!("{}-w{}", rt.cfg.label, index);
    // Register on the rank's lane: substitute workers may be spawned from
    // threads bound elsewhere, but the credit must land where the new
    // worker will debit it.
    rt.clock.register_thread_on(rt.cfg.clock_lane);
    let rt2 = rt.clone();
    std::thread::Builder::new()
        .name(name)
        .stack_size(stack)
        .spawn(move || worker_main(rt2, index))
        .expect("spawn worker");
}

fn worker_main(rt: Arc<Rt>, index: usize) {
    crate::sim::Clock::bind_lane(rt.cfg.clock_lane);
    WORKER_ID.with(|w| *w.borrow_mut() = index);
    CURRENT.with(|c| *c.borrow_mut() = Some((rt.clone(), None)));
    loop {
        let Some(item) = rt.sched.next(&rt, index) else { break };
        match item {
            Item::New(task) => {
                run_task(&rt, &task);
                rt.sched.release_core(&rt);
            }
            Item::Resume(ctx) => {
                // Transfer our license to the parked thread and loop back
                // (we are now license-less; `next` re-acquires).
                rt.trace(EventKind::TaskResumeGrant, index, &ctx.task_label, ctx.task_id);
                rt.sched.grant_core(&ctx, &rt);
            }
        }
    }
    CURRENT.with(|c| *c.borrow_mut() = None);
    rt.clock.deregister_thread();
}

fn run_task(rt: &Arc<Rt>, task: &Arc<TaskInner>) {
    let body = task
        .body
        .lock()
        .unwrap()
        .take()
        .expect("task scheduled twice");
    CURRENT.with(|c| c.borrow_mut().as_mut().unwrap().1 = Some(task.clone()));
    crate::sim::Clock::add_debt(rt.cfg.costs.task_exec_ns);
    rt.trace(EventKind::TaskStart, worker_id(), &task.label, task.id);
    let span_t0 = rt.cfg.obs.as_ref().map(|_| rt.clock.now());
    // Contain task panics: record, then release dependencies anyway so the
    // failure surfaces at taskwait instead of hanging the simulation.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body));
    if let Err(e) = result {
        let msg = e
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "unknown panic".into());
        rt.record_task_panic(format!("task '{}' (id {}): {}", task.label, task.id, msg));
    }
    rt.trace(EventKind::TaskEnd, worker_id(), &task.label, task.id);
    // Settle this task's modeled overheads while still holding the core.
    rt.clock.flush_debt();
    if let (Some(obs), Some(t0)) = (rt.cfg.obs.as_ref(), span_t0) {
        let wid = worker_id();
        let worker = if wid == usize::MAX { u32::MAX } else { wid as u32 };
        obs.record(crate::obs::Span::interval(
            crate::obs::Track::Worker { rank: rt.cfg.rank, worker },
            crate::obs::SpanKind::TaskExec,
            t0,
            rt.clock.now(),
            "task",
            task.id,
        ));
    }
    CURRENT.with(|c| c.borrow_mut().as_mut().unwrap().1 = None);
    task.body_finished();
}
