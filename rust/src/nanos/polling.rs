//! Polling services (Section 4.2 / 4.5).
//!
//! Callbacks registered here are served (a) by a leader thread at every
//! `poll_interval` of virtual time and (b) opportunistically by workers
//! before their core goes idle.  Callbacks may not support concurrent
//! execution (Section 4.5), so a run lock serializes service passes;
//! workers use try-lock and skip if a pass is already running.
//!
//! **Hinted services**: a service registered with [`PollingRegistry::
//! register_hinted`] promises to report its pending-work count through
//! [`PollingRegistry::hint_add`]/[`hint_sub`]. When every service is
//! hinted and no work is pending, the leader parks entirely instead of
//! ticking — long quiescent phases then generate zero clock events
//! (essential for cluster-scale virtual-time runs). TAMPI's poll-scan
//! baseline uses this: its hint is the in-flight ticket count.
//!
//! **Completion modes**: this registry is the notification path only for
//! [`super::runtime::CompletionMode::Polling`] — the paper-faithful
//! baseline in which TAMPI files tickets and a service re-scans them per
//! pass, bounding completion latency by `poll_interval`. Under the
//! default [`super::runtime::CompletionMode::Callback`] TAMPI attaches
//! request continuations instead and registers *no* service here: the
//! leader stays parked and completions are pushed from the point where
//! the request completes (see `crate::tampi` module docs). The registry
//! itself stays — it serves the paper's Section 4.2 API, user services,
//! and polling-mode collective waits.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, TryLockError, Weak};

use crate::sim::WaitQueue;

use super::runtime::Rt;

/// A polling callback: returns `true` when its purpose has been attained
/// (it is then automatically unregistered, Section 4.2).
pub type PollingService = Box<dyn FnMut() -> bool + Send>;

struct Service {
    name: String,
    f: PollingService,
    hinted: bool,
}

#[derive(Default)]
pub struct PollingRegistry {
    services: Mutex<Vec<Service>>,
    /// Wakes the leader when it parked (empty registry / zero hints).
    arrivals: WaitQueue,
    /// Pending-work units reported by hinted services.
    pending_hint: AtomicUsize,
    /// Services that did not promise hints (leader must keep ticking).
    unhinted: AtomicUsize,
}

impl PollingRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a callback under `name` (debug label).
    pub fn register(&self, name: impl Into<String>, f: PollingService, rt: &Rt) {
        self.register_inner(name.into(), f, false, rt);
    }

    /// Register a callback that reports pending work via hints.
    pub fn register_hinted(&self, name: impl Into<String>, f: PollingService, rt: &Rt) {
        self.register_inner(name.into(), f, true, rt);
    }

    fn register_inner(&self, name: String, f: PollingService, hinted: bool, rt: &Rt) {
        let mut g = self.services.lock().unwrap();
        if !hinted {
            self.unhinted.fetch_add(1, Ordering::AcqRel);
        }
        g.push(Service { name, f, hinted });
        drop(g);
        // The leader may be parked waiting for reasons to poll.
        self.arrivals.notify_all(&rt.clock);
    }

    /// Remove the callback registered under `name`. Returns once the
    /// callback can no longer run (the registry lock serializes passes).
    pub fn unregister(&self, name: &str) -> bool {
        let mut g = self.services.lock().unwrap();
        let before = g.len();
        g.retain(|s| {
            if s.name == name {
                if !s.hinted {
                    self.unhinted.fetch_sub(1, Ordering::AcqRel);
                }
                false
            } else {
                true
            }
        });
        g.len() != before
    }

    /// Report `n` new pending-work units (wakes a parked leader).
    pub fn hint_add(&self, n: usize, rt: &Rt) {
        if n == 0 {
            return;
        }
        self.pending_hint.fetch_add(n, Ordering::AcqRel);
        self.arrivals.notify_all(&rt.clock);
    }

    /// Report `n` retired pending-work units.
    pub fn hint_sub(&self, n: usize) {
        if n > 0 {
            self.pending_hint.fetch_sub(n, Ordering::AcqRel);
        }
    }

    /// True when the leader has nothing to tick for.
    pub fn leader_idle(&self) -> bool {
        (self.unhinted.load(Ordering::Acquire) == 0
            && self.pending_hint.load(Ordering::Acquire) == 0)
            || self.is_empty()
    }

    /// Run one pass over all services; drop the ones that report done.
    /// Skips (returns false) if another pass is in progress.
    pub fn poll_once(&self) -> bool {
        let mut g = match self.services.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => return false,
            Err(e) => panic!("polling registry poisoned: {e}"),
        };
        let mut i = 0;
        while i < g.len() {
            if (g[i].f)() {
                if !g[i].hinted {
                    self.unhinted.fetch_sub(1, Ordering::AcqRel);
                }
                g.remove(i);
            } else {
                i += 1;
            }
        }
        true
    }

    /// Wake a parked leader (shutdown path).
    pub(crate) fn wake_leader(&self, clock: &crate::sim::Clock) {
        self.arrivals.notify_all(clock);
    }

    pub fn len(&self) -> usize {
        self.services.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Leader thread: serves the registry every `poll_interval` of virtual
/// time (Nanos6 uses 1 ms; ours is configurable because the simulated
/// cluster is time-scaled). Parks entirely while there is nothing to
/// poll for — no services, or only hinted services with zero pending
/// work — so idle phases cost no clock events and an application with no
/// progress mechanism still deadlocks detectably (Section 5).
pub(crate) fn leader_main(rt_weak: Weak<Rt>) {
    let mut bound = false;
    loop {
        let Some(rt) = rt_weak.upgrade() else { return };
        if !bound {
            // Bind to the rank's lane so sleeps/parks debit the counter
            // `Runtime::new` credited on registration.
            crate::sim::Clock::bind_lane(rt.cfg.clock_lane);
            bound = true;
        }
        if rt.is_shutdown() {
            rt.clock.deregister_thread();
            return;
        }
        if rt.polling.leader_idle() {
            // Park until something needs polling (or shutdown). The token
            // is enqueued before the final idle check, so a concurrent
            // hint_add cannot be lost.
            let tok = rt.polling.arrivals.enqueue();
            if !rt.polling.leader_idle() || rt.is_shutdown() {
                continue; // stale token is woken later and ignored
            }
            let clock = rt.clock.clone();
            drop(rt);
            clock.passive_wait(&tok);
            continue;
        }
        rt.polling.poll_once();
        let interval = rt.cfg.poll_interval;
        let clock = rt.clock.clone();
        drop(rt);
        clock.sleep(interval);
    }
}
