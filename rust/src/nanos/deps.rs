//! Object-granularity data dependencies (the OmpSs-2 model restricted to
//! whole objects, which is what both benchmarks use).
//!
//! Every [`DepObj`] keeps a FIFO of *access groups*: a group is either a
//! set of concurrent readers or a single writer (out/inout). An access is
//! satisfied when its group reaches the head of the queue. When a task
//! fully completes (body + external events), each of its accesses retires
//! from its head group; an emptied head group unblocks the next group's
//! members.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::task::TaskInner;

static NEXT_OBJ_ID: AtomicU64 = AtomicU64::new(1);

/// Access mode of a task on a dependency object.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Mode {
    /// Read (`in(...)`)
    In,
    /// Write (`out(...)`): ordered like a writer (no renaming).
    Out,
    /// Read-write (`inout(...)`)
    InOut,
}

impl Mode {
    pub fn is_write(self) -> bool {
        !matches!(self, Mode::In)
    }
}

/// A dependency object — the unit over which tasks declare accesses.
/// Cheap to clone (shared handle).
#[derive(Clone)]
pub struct DepObj(pub(crate) Arc<DepObjInner>);

impl DepObj {
    pub fn new(label: impl Into<String>) -> Self {
        DepObj(Arc::new(DepObjInner {
            id: NEXT_OBJ_ID.fetch_add(1, Ordering::Relaxed),
            label: label.into(),
            q: Mutex::new(ObjQueue { groups: VecDeque::new() }),
        }))
    }

    pub fn id(&self) -> u64 {
        self.0.id
    }

    pub fn label(&self) -> &str {
        &self.0.label
    }
}

pub struct DepObjInner {
    pub id: u64,
    pub label: String,
    q: Mutex<ObjQueue>,
}

struct ObjQueue {
    groups: VecDeque<Group>,
}

struct Group {
    writer: bool,
    members: Vec<Arc<TaskInner>>,
    /// Members that have not yet fully completed.
    remaining: usize,
}

/// One registered access of a task (held by the task for release).
pub struct Access {
    pub obj: Arc<DepObjInner>,
    pub mode: Mode,
}

impl DepObjInner {
    /// Register `task`'s access. Returns `(satisfied, predecessors)`:
    /// whether the access is immediately satisfied, and — for dependency-
    /// graph recording — the ids/labels of the tasks it must wait for.
    pub(crate) fn register(
        &self,
        task: &Arc<TaskInner>,
        mode: Mode,
        record_edges: bool,
    ) -> (bool, Vec<(u64, String)>) {
        let mut q = self.q.lock().unwrap();
        let writer = mode.is_write();
        let mut edges = Vec::new();
        if q.groups.is_empty() {
            q.groups.push_back(Group {
                writer,
                members: vec![task.clone()],
                remaining: 1,
            });
            return (true, edges);
        }
        let can_join_back = !writer && !q.groups.back().unwrap().writer;
        if can_join_back {
            if record_edges && q.groups.len() >= 2 {
                let prev = &q.groups[q.groups.len() - 2];
                for m in &prev.members {
                    edges.push((m.id, m.label.clone()));
                }
            }
            let head = q.groups.len() == 1;
            let back = q.groups.back_mut().unwrap();
            back.members.push(task.clone());
            back.remaining += 1;
            (head, edges)
        } else {
            if record_edges {
                let prev = q.groups.back().unwrap();
                for m in &prev.members {
                    edges.push((m.id, m.label.clone()));
                }
            }
            q.groups.push_back(Group {
                writer,
                members: vec![task.clone()],
                remaining: 1,
            });
            (false, edges)
        }
    }

    /// Retire `task`'s access after full completion. If the head group
    /// empties, satisfy every member of the next group.
    pub(crate) fn release(&self, task: &Arc<TaskInner>) {
        let next: Vec<Arc<TaskInner>> = {
            let mut q = self.q.lock().unwrap();
            let head = q
                .groups
                .front_mut()
                .unwrap_or_else(|| panic!("release on empty queue (obj {})", self.id));
            debug_assert!(
                head.members.iter().any(|m| m.id == task.id),
                "task {} releasing obj {} but not in head group",
                task.id,
                self.id
            );
            head.remaining -= 1;
            if head.remaining > 0 {
                return;
            }
            q.groups.pop_front();
            match q.groups.front() {
                Some(g) => g.members.clone(),
                None => return,
            }
        };
        for t in &next {
            t.dec_pred();
        }
    }

    /// Diagnostics: number of queued access groups.
    pub fn queue_len(&self) -> usize {
        self.q.lock().unwrap().groups.len()
    }
}
