//! The paper's runtime APIs (Section 4), in their C shape.
//!
//! * Pause/resume: [`get_current_blocking_context`], [`block_current_task`],
//!   [`unblock_task`] (Section 4.1).
//! * External events: [`get_current_event_counter`],
//!   [`increase_current_task_event_counter`],
//!   [`decrease_task_event_counter`] (Section 4.3).
//!
//! Polling services (Section 4.2) live on [`super::Runtime`] because they
//! are per-runtime, not per-task.

use std::cell::RefCell;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::sim::{Clock, Token, VNanos};
use crate::trace::EventKind;

use super::task::{BlockCtx, BlockingContext, CtxState, EventCounter, TaskInner};
use super::worker;

/// Deferred external-event decrements grouped by task.
type DecGroups = Vec<(Arc<TaskInner>, u32)>;

thread_local! {
    /// Active [`DeferredEventDecs`] scope of this thread: per-task
    /// external-event decrements awaiting one coalesced `dec_events(n)`.
    static DEC_DEFER: RefCell<Option<DecGroups>> = const { RefCell::new(None) };
}

/// RAII scope coalescing [`decrease_task_event_counter`] calls on the
/// current thread into one `dec_events(n)` per task. Opened by
/// [`crate::progress::Shard`] while draining a completion batch: a
/// collective wave that fulfils many external events of the *same* task
/// (e.g. an iwaitall over 2(n-1) transposition requests) then touches
/// the task's counter — and potentially releases its dependencies —
/// once, not once per continuation. Close with
/// [`DeferredEventDecs::finish`] *inside* the drain's bulk-enqueue scope
/// so released successors join the batch insert.
pub(crate) struct DeferredEventDecs(());

impl DeferredEventDecs {
    pub(crate) fn begin() -> DeferredEventDecs {
        DEC_DEFER.with(|d| {
            let mut b = d.borrow_mut();
            assert!(b.is_none(), "nested DeferredEventDecs scopes");
            *b = Some(Vec::new());
        });
        DeferredEventDecs(())
    }

    /// Apply the coalesced decrements (one `dec_events(n)` per task, in
    /// first-decrement order) and close the scope.
    pub(crate) fn finish(self) {
        let groups = DEC_DEFER.with(|d| d.borrow_mut().take()).unwrap_or_default();
        for (task, n) in groups {
            task.dec_events_counted(n);
        }
    }
}

impl Drop for DeferredEventDecs {
    fn drop(&mut self) {
        // Panic-unwind safety: never leave a stale scope on the thread.
        DEC_DEFER.with(|d| {
            d.borrow_mut().take();
        });
    }
}

/// Inform the runtime that the current task is about to enter a
/// pause-resume cycle; returns the blocking context for one round trip.
/// Requesting a new context invalidates the previous one (Section 4.1).
///
/// Panics if called outside a task.
pub fn get_current_blocking_context() -> BlockingContext {
    let (rt, task) = worker::current().expect("blocking context outside a task");
    let ctx = Arc::new(BlockCtx {
        st: Mutex::new(CtxState::Armed),
        token: Token::new(),
        rt: Arc::downgrade(&rt),
        task_id: task.id,
        task_label: task.label.clone(),
    });
    *task.blocking.lock().unwrap() = Some(ctx.clone());
    BlockingContext(ctx)
}

/// Suspend the invoking task (Section 4.1). The virtual core is released
/// to the scheduler — waking an idle worker or spawning a substitute — and
/// the calling thread parks until [`unblock_task`] leads a worker to grant
/// it a core again.
///
/// If the matching `unblock_task` already happened, returns immediately
/// (the round trip is consumed without releasing the core).
pub fn block_current_task(ctx: &BlockingContext) {
    let ctx = &ctx.0;
    let rt = ctx.rt.upgrade().expect("runtime gone");
    {
        let mut st = ctx.st.lock().unwrap();
        match *st {
            CtxState::UnblockedEarly => {
                *st = CtxState::Granted; // consumed; keep the core
                return;
            }
            CtxState::Armed => *st = CtxState::Waiting,
            s => panic!("block_current_task on context in state {s:?}"),
        }
    }
    rt.n_pauses.fetch_add(1, Ordering::Relaxed);
    rt.trace(EventKind::TaskBlock, worker::worker_id(), &ctx.task_label, ctx.task_id);
    let pause_t0 = rt.cfg.obs.as_ref().map(|_| rt.clock.now());
    // Context-switch costs are charged in ONE clock event after the core
    // grant (pause side as debt): same total virtual time, but half the
    // real thread parks per round trip (§Perf opt-1).
    crate::sim::Clock::add_debt(rt.cfg.costs.pause_ns);
    rt.sched.release_core_for_block(&rt);
    rt.clock.passive_wait(&ctx.token);
    rt.clock.work(rt.cfg.costs.resume_ns);
    rt.trace(EventKind::TaskUnblock, worker::worker_id(), &ctx.task_label, ctx.task_id);
    if let (Some(obs), Some(t0)) = (rt.cfg.obs.as_ref(), pause_t0) {
        let t1 = rt.clock.now();
        let wid = worker::worker_id();
        let worker = if wid == usize::MAX { u32::MAX } else { wid as u32 };
        obs.pause_ns.record(t1.saturating_sub(t0));
        obs.record(crate::obs::Span::interval(
            crate::obs::Track::Worker { rank: rt.cfg.rank, worker },
            crate::obs::SpanKind::TaskPause,
            t0,
            t1,
            "pause",
            ctx.task_id,
        ));
    }
}

/// Mark the task associated with `ctx` resumable (Section 4.1). Callable
/// from any thread (polling services, other tasks, clock callbacks).
pub fn unblock_task(ctx: &BlockingContext) {
    let ctx = &ctx.0;
    let push = {
        let mut st = ctx.st.lock().unwrap();
        match *st {
            CtxState::Armed => {
                *st = CtxState::UnblockedEarly;
                false
            }
            CtxState::Waiting => true,
            s => panic!("unblock_task on context in state {s:?}"),
        }
    };
    if push {
        let rt = ctx.rt.upgrade().expect("runtime gone");
        rt.sched.enqueue_resume(ctx.clone(), &rt);
    }
}

/// Return the event counter of the invoking task (Section 4.3).
///
/// Panics if called outside a task.
pub fn get_current_event_counter() -> EventCounter {
    let (_, task) = worker::current().expect("event counter outside a task");
    EventCounter(task)
}

/// Atomically bind `increment` external events to the calling task
/// (Section 4.3). Only the task itself may increase its counter.
pub fn increase_current_task_event_counter(counter: &EventCounter, increment: u32) {
    let (rt, task) = worker::current().expect("increase outside a task");
    assert_eq!(
        task.id, counter.0.id,
        "only the owning task may bind its external events"
    );
    crate::sim::Clock::add_debt(rt.cfg.costs.event_ns * increment as u64);
    counter.0.inc_events(increment);
}

/// Fulfil `decrement` external events of the counter's task (Section 4.3).
/// Callable from any thread. When the counter reaches zero and the task
/// body has finished, the task's dependencies are released.
///
/// Inside a shard drain ([`DeferredEventDecs`] scope) decrements are
/// coalesced per task and applied once at the end of the batch —
/// observationally identical (all at the same virtual instant, before
/// the batch's bulk enqueue), one atomic RMW per task per wave.
pub fn decrease_task_event_counter(counter: &EventCounter, decrement: u32) {
    let deferred = DEC_DEFER.with(|d| {
        let mut b = d.borrow_mut();
        match b.as_mut() {
            Some(groups) => {
                if let Some((_, n)) =
                    groups.iter_mut().find(|(t, _)| Arc::ptr_eq(t, &counter.0))
                {
                    *n += decrement;
                } else {
                    groups.push((counter.0.clone(), decrement));
                }
                true
            }
            None => false,
        }
    });
    if !deferred {
        counter.0.dec_events_counted(decrement);
    }
}

/// Advance the calling thread's virtual core by `cost` ns of "work".
pub fn work(cost: VNanos) {
    if let Some(rt) = worker::current_rt() {
        rt.clock.work(cost);
    } else {
        panic!("nanos::work outside a sim thread");
    }
}

/// The clock of the runtime the calling thread is attached to.
pub fn current_clock() -> Arc<Clock> {
    worker::current_rt().expect("no runtime attached").clock.clone()
}

/// Whether the calling thread is currently executing a task body.
pub fn in_task() -> bool {
    worker::current().is_some()
}

/// Handle to the runtime the calling thread belongs to, if any.
pub fn current_runtime() -> Option<super::Runtime> {
    worker::current_rt().map(|rt| super::Runtime { rt })
}

/// Emit a trace record attributed to the current task (no-op when not in
/// a task or tracing is disabled).
pub fn trace_current(kind: EventKind, what: &str) {
    if let Some((rt, task)) = worker::current() {
        rt.trace(kind, worker::worker_id(), what, task.id);
    }
}
