//! `repro` — CLI launcher for the TAMPI reproduction.
//!
//! Subcommands:
//!   gs        run one Gauss-Seidel experiment (Section 7.1); with
//!             --inject rank-fail|drop|straggler it instead runs the
//!             fault-injection recovery scenario (apps::recovery) and
//!             asserts seed-replay bit-identity + convergence
//!   ifsker    run one IFSKer experiment (Section 7.2); --inject as above
//!   figures   regenerate paper figures (8-14) + extension figs 15-22
//!             into bench_out/; with --json <path> figs 15-22 emit
//!             the machine-readable document instead (CI perf artifact)
//!   stalls    collective stall diagnostic on a deliberately skewed run
//!             (which rank's rounds_advanced holds a collective back)
//!   calibrate measure the compute cost model on this host
//!
//! `gs` and `ifsker` accept `--completion callback|poll` (notification
//! pipeline), `--delivery sharded|direct` (continuation delivery via
//! the sharded progress engine vs the inline baseline), `--topology
//! hier|flat` (node-hierarchical vs flat collective schedules),
//! `--residual-every N` + `--residual blk|nonblk` (periodic residual
//! allreduce: blocking in-task vs fire-and-forget `iallreduce` riding
//! the schedule-driven collective engine), and the network-model
//! overrides `--net-rx <ns>` (per-message ingress-port processing — the
//! congestion knob) + `--eager <bytes>` (rendezvous threshold), so
//! congestion regimes are reachable without recompiling. Both also take
//! `--clock-shards N` (parallel simulation lanes; results bit-identical
//! to 1 — see `crate::sim`), `--clock-queue heap|calendar` (per-lane
//! event-queue implementation; also bit-identical — calendar is the
//! default), and `--trace <path>` with `--trace-format
//! csv|gantt|perfetto` (`csv` keeps the classic CSV dump + printed
//! Gantt; `perfetto` records typed spans — see `crate::obs` — and
//! writes a Chrome/Perfetto `trace_event` JSON). `figures
//! --fig 18` takes `--net-rx`/`--eager` too (fig 18 then runs at
//! exactly that point instead of its sweep); the other figures pin
//! their network models and reject the knobs.
//!
//! Examples:
//!   repro gs --version interop-nonblk --rows 4096 --cols 4096 \
//!            --block 256 --iters 50 --nodes 4 --cores 4 --compute model
//!   repro gs --version interop-blk --delivery direct --completion poll
//!   repro gs --version interop-nonblk --net-rx 400 --eager 16384
//!   repro figures --fig 15 --scale quick
//!   repro figures --fig 17 --scale quick --json BENCH_fig17.json
//!   repro figures --fig 18 --scale quick --net-rx 800
//!   repro ifsker --version interop-blk --grid 65536 --nodes 2 --cores 4
//!   repro stalls --ranks 4 --skew-ms 20

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use tampi_repro::apps::gauss_seidel::{self, GsParams, GsVersion};
use tampi_repro::apps::ifsker::{self, IfsParams, IfsVersion};
use tampi_repro::apps::Compute;
use tampi_repro::bench::{self, Scale};
use tampi_repro::sim::ms;
use tampi_repro::trace::{GraphRecorder, Tracer};

fn parse_args(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            eprintln!("unexpected argument: {}", args[i]);
            std::process::exit(2);
        }
    }
    m
}

fn get<T: std::str::FromStr>(m: &HashMap<String, String>, k: &str, default: T) -> T {
    m.get(k)
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{k}: {v}")))
        .unwrap_or(default)
}

fn compute_of(m: &HashMap<String, String>) -> Compute {
    match m.get("compute").map(String::as_str).unwrap_or("native") {
        "native" => Compute::Native,
        "pjrt" => Compute::Pjrt,
        "model" => Compute::Model,
        other => {
            eprintln!("unknown --compute {other} (native|pjrt|model)");
            std::process::exit(2);
        }
    }
}

fn completion_of(m: &HashMap<String, String>) -> tampi_repro::nanos::CompletionMode {
    match m.get("completion").map(String::as_str).unwrap_or("callback") {
        "callback" => tampi_repro::nanos::CompletionMode::Callback,
        "poll" | "polling" => tampi_repro::nanos::CompletionMode::Polling,
        other => {
            eprintln!("unknown --completion {other} (callback|poll)");
            std::process::exit(2);
        }
    }
}

fn delivery_of(m: &HashMap<String, String>) -> tampi_repro::progress::DeliveryMode {
    match m.get("delivery").map(String::as_str).unwrap_or("sharded") {
        "sharded" => tampi_repro::progress::DeliveryMode::Sharded,
        "direct" => tampi_repro::progress::DeliveryMode::Direct,
        other => {
            eprintln!("unknown --delivery {other} (direct|sharded)");
            std::process::exit(2);
        }
    }
}

fn clock_queue_of(m: &HashMap<String, String>) -> tampi_repro::sim::ClockQueueKind {
    match m.get("clock-queue").map(String::as_str) {
        None => tampi_repro::sim::ClockQueueKind::default(),
        Some(v) => tampi_repro::sim::ClockQueueKind::parse(v).unwrap_or_else(|| {
            eprintln!("unknown --clock-queue {v} (heap|calendar)");
            std::process::exit(2);
        }),
    }
}

fn topology_of(m: &HashMap<String, String>) -> tampi_repro::rmpi::TopologyMode {
    match m.get("topology").map(String::as_str).unwrap_or("hier") {
        "hier" | "hierarchical" => tampi_repro::rmpi::TopologyMode::Hierarchical,
        "flat" => tampi_repro::rmpi::TopologyMode::Flat,
        other => {
            eprintln!("unknown --topology {other} (hier|flat)");
            std::process::exit(2);
        }
    }
}

/// Parse a CLI value or exit 2 with a clear message (the unknown-`--fig`
/// convention: a typo must not abort with a panic backtrace).
fn parse_or_die<T: std::str::FromStr>(v: &str, knob: &str) -> T {
    v.parse().unwrap_or_else(|_| {
        eprintln!("bad --{knob}: {v}");
        std::process::exit(2);
    })
}

/// Apply the `--net-rx <ns>` / `--eager <bytes>` NetworkModel overrides
/// (shared by `gs`, `ifsker` and `figures`).
fn apply_net_overrides(m: &HashMap<String, String>, net: &mut tampi_repro::rmpi::NetworkModel) {
    if let Some(v) = m.get("net-rx") {
        net.rx_ns = parse_or_die(v, "net-rx");
    }
    if let Some(v) = m.get("eager") {
        net.eager_threshold = parse_or_die(v, "eager");
    }
}

/// Output format of `--trace <path>` (shared by `gs` and `ifsker`).
#[derive(Clone, Copy, PartialEq, Eq)]
enum TraceFormat {
    /// CSV event dump + printed ASCII Gantt (the classic behavior).
    Csv,
    /// ASCII Gantt chart written to the file (and printed).
    Gantt,
    /// Chrome/Perfetto `trace_event` JSON from the typed span recorder.
    Perfetto,
}

fn trace_format_of(m: &HashMap<String, String>) -> TraceFormat {
    match m.get("trace-format").map(String::as_str).unwrap_or("csv") {
        "csv" => TraceFormat::Csv,
        "gantt" => TraceFormat::Gantt,
        "perfetto" => TraceFormat::Perfetto,
        other => {
            eprintln!("unknown --trace-format {other} (csv|gantt|perfetto)");
            std::process::exit(2);
        }
    }
}

/// Write the captured trace to `--trace <path>` in the selected format.
/// One helper for the `gs` and `ifsker` arms, which used to duplicate
/// the write + Gantt-print block.
fn dump_trace(
    m: &HashMap<String, String>,
    fmt: TraceFormat,
    tracer: &Option<Arc<Tracer>>,
    spans: &Option<Arc<tampi_repro::obs::SpanSink>>,
) {
    let Some(path) = m.get("trace") else { return };
    match fmt {
        TraceFormat::Csv => {
            let t = tracer.as_ref().expect("csv trace needs a tracer");
            std::fs::write(path, t.to_csv()).expect("write trace");
            println!("  trace -> {path}");
            println!("{}", tampi_repro::trace::render_gantt(&t.snapshot(), 100));
        }
        TraceFormat::Gantt => {
            let t = tracer.as_ref().expect("gantt trace needs a tracer");
            let chart = tampi_repro::trace::render_gantt(&t.snapshot(), 100);
            std::fs::write(path, &chart).expect("write trace");
            println!("  trace -> {path}");
            println!("{chart}");
        }
        TraceFormat::Perfetto => {
            let s = spans.as_ref().expect("perfetto trace needs a span sink");
            let json = tampi_repro::obs::perfetto::export(&s.snapshot(), s.dropped());
            std::fs::write(path, &json).expect("write trace");
            println!(
                "  trace -> {path} (perfetto, {} dropped spans)",
                s.dropped()
            );
        }
    }
}

fn residual_nonblocking_of(m: &HashMap<String, String>) -> bool {
    // Default matches the library default (GsParams/IfsParams): blocking.
    match m.get("residual").map(String::as_str).unwrap_or("blk") {
        "nonblk" | "nonblocking" => true,
        "blk" | "blocking" => false,
        other => {
            eprintln!("unknown --residual {other} (blk|nonblk)");
            std::process::exit(2);
        }
    }
}

/// `repro gs|ifsker --inject rank-fail|drop|straggler`: run the
/// shrink-and-continue recovery driver (see `apps::recovery`) under the
/// selected injection, twice with the same seed (replay), plus a
/// fault-free reference at the size the recovery lands on, and assert:
///
/// * **seed-replay bit-identity** — both injected runs agree on virtual
///   time and checksum exactly (deterministic injection);
/// * **convergence** — the recovered solve's checksum is bit-identical
///   to the fault-free reference (rank failure: a clean run on the
///   survivor count; drop/straggler: a clean run at full size, since
///   those injections perturb timing, never data).
///
/// Non-zero exit on any mismatch — this is the CI faults-matrix entry
/// point, composable with `--delivery` and `--clock-shards`.
fn cmd_inject(app: &str, m: &HashMap<String, String>) {
    use tampi_repro::apps::recovery::{self, GsShrinkParams, IfsShrinkParams, ShrinkParams};
    use tampi_repro::rmpi::FaultsConfig;

    let kind = m.get("inject").map(String::as_str).unwrap_or_default();
    let nodes = get(m, "nodes", 4usize);
    let seed = get(m, "seed", 42u64);
    let pre = get(m, "pre-iters", 4usize);
    let iters = get(m, "iters", 12usize);
    let faults = match kind {
        "rank-fail" => FaultsConfig::new(seed).with_rank_fail(1, 20_000),
        // 20% of messages dropped and retransmitted after timeout.
        "drop" => FaultsConfig::new(seed).with_drop(200_000),
        // Rank 1: 4x compute, +2us ingress per message.
        "straggler" => FaultsConfig::new(seed).with_straggler(1, 2_000, 4),
        other => {
            eprintln!("unknown --inject {other} (rank-fail|drop|straggler)");
            std::process::exit(2);
        }
    };
    let mut base = ShrinkParams::new(nodes, 1, pre, iters);
    base.clock_shards = get(m, "clock-shards", 1usize);
    base.clock_queue = clock_queue_of(m);
    base.delivery_mode = delivery_of(m);
    base.deadline = Some(ms(get(m, "deadline-ms", 600_000u64)));
    base.faults = Some(faults);
    let ref_nodes = if kind == "rank-fail" { nodes - 1 } else { nodes };
    let mut refp = ShrinkParams::new(ref_nodes, 1, 0, iters);
    refp.clock_shards = base.clock_shards;
    refp.clock_queue = base.clock_queue;
    refp.delivery_mode = base.delivery_mode;
    refp.deadline = base.deadline;

    let (run, replay, reference) = if app == "gs" {
        let rows = get(m, "rows", 24usize);
        let cols = get(m, "cols", 64usize);
        let p = GsShrinkParams::new(base, rows, cols);
        let pr = GsShrinkParams::new(refp, rows, cols);
        (
            recovery::run_gs_shrink(&p).expect("inject run"),
            recovery::run_gs_shrink(&p).expect("inject replay"),
            recovery::run_gs_shrink(&pr).expect("reference run"),
        )
    } else {
        let grid = get(m, "grid", 144usize);
        let nf = get(m, "fields", 2usize);
        let p = IfsShrinkParams::new(base, grid, nf);
        let pr = IfsShrinkParams::new(refp, grid, nf);
        (
            recovery::run_ifs_shrink(&p).expect("inject run"),
            recovery::run_ifs_shrink(&p).expect("inject replay"),
            recovery::run_ifs_shrink(&pr).expect("reference run"),
        )
    };
    println!(
        "{app} --inject {kind}: nodes={nodes} survivors={} vtime={:.3} ms checksum={:.6}",
        run.survivors,
        run.vtime_ns as f64 / 1e6,
        run.checksum
    );
    if let Some(fs) = &run.stats.faults {
        println!(
            "  faults: drops={} retransmits={} failed_reqs={} detections={}",
            fs.drops, fs.retransmits, fs.failed_reqs, fs.detections
        );
    }
    let identical =
        run.vtime_ns == replay.vtime_ns && run.checksum.to_bits() == replay.checksum.to_bits();
    let converged = run.checksum.is_finite()
        && run.checksum != 0.0
        && run.checksum.to_bits() == reference.checksum.to_bits();
    if !identical {
        eprintln!(
            "FAILED: seed replay diverged (vtime {} vs {}, checksum {:?} vs {:?})",
            run.vtime_ns,
            replay.vtime_ns,
            run.checksum,
            replay.checksum
        );
        std::process::exit(1);
    }
    if !converged {
        eprintln!(
            "FAILED: recovered checksum {:?} != fault-free reference {:?}",
            run.checksum, reference.checksum
        );
        std::process::exit(1);
    }
    println!("  inject {kind} PASS (replay bit-identical, converged to reference)");
}

fn cmd_gs(m: HashMap<String, String>) {
    if m.contains_key("inject") {
        return cmd_inject("gs", &m);
    }
    let version = m
        .get("version")
        .and_then(|v| GsVersion::parse(v))
        .unwrap_or(GsVersion::InteropNonBlk);
    let mut p = GsParams::new(
        get(&m, "rows", 1024),
        get(&m, "cols", 1024),
        get(&m, "block", 256),
        get(&m, "iters", 20),
        get(&m, "nodes", 2),
        get(&m, "cores", 2),
        version,
    );
    p.compute = compute_of(&m);
    p.completion_mode = completion_of(&m);
    p.delivery_mode = delivery_of(&m);
    p.topology = topology_of(&m);
    p.residual_every = get(&m, "residual-every", 0usize);
    p.residual_nonblocking = residual_nonblocking_of(&m);
    p.clock_shards = get(&m, "clock-shards", 1usize);
    p.clock_queue = clock_queue_of(&m);
    p.cell_ns = get(&m, "cell-ns", p.cell_ns);
    apply_net_overrides(&m, &mut p.net);
    p.deadline = Some(ms(get(&m, "deadline-ms", 600_000u64)));
    let fmt = trace_format_of(&m);
    let tracer = (m.get("trace").is_some() && fmt != TraceFormat::Perfetto)
        .then(|| Arc::new(Tracer::new()));
    let spans = (m.get("trace").is_some() && fmt == TraceFormat::Perfetto)
        .then(|| tampi_repro::obs::SpanSink::new(1 << 20));
    let graph = m.get("graph").map(|_| Arc::new(GraphRecorder::new()));
    p.tracer = tracer.clone();
    p.spans = spans.clone();
    p.graph = graph.clone();

    let wall = Instant::now();
    match gauss_seidel::run(&p) {
        Ok(out) => {
            println!(
                "gs {} nodes={} cores={} {}x{} block={} iters={} compute={:?}",
                version.name(),
                p.nodes,
                p.cores_per_node,
                p.rows,
                p.cols,
                p.block,
                p.iters,
                p.compute
            );
            println!(
                "  vtime: {:.3} ms | {:.2e} cells/s | checksum {:.6}",
                out.vtime_ns as f64 / 1e6,
                out.cells_per_sec(&p),
                out.checksum
            );
            println!(
                "  tasks={} pauses={} workers={} | wall {:.2}s",
                out.stats.tasks,
                out.stats.pauses,
                out.stats.workers,
                wall.elapsed().as_secs_f64()
            );
        }
        Err(e) => {
            eprintln!("FAILED: {e}");
            std::process::exit(1);
        }
    }
    dump_trace(&m, fmt, &tracer, &spans);
    if let (Some(g), Some(path)) = (&graph, m.get("graph")) {
        std::fs::write(path, g.to_dot("sentinel")).expect("write dot");
        println!("  graph -> {path} ({} edges)", g.edge_count());
    }
}

fn cmd_ifsker(m: HashMap<String, String>) {
    if m.contains_key("inject") {
        return cmd_inject("ifsker", &m);
    }
    let version = m
        .get("version")
        .and_then(|v| IfsVersion::parse(v))
        .unwrap_or(IfsVersion::InteropNonBlk);
    let mut p = IfsParams::new(
        get(&m, "grid", 16 * 1024),
        get(&m, "fields", 8),
        get(&m, "steps", 10),
        get(&m, "nodes", 2),
        get(&m, "cores", 4),
        version,
    );
    p.compute = compute_of(&m);
    p.completion_mode = completion_of(&m);
    p.delivery_mode = delivery_of(&m);
    p.topology = topology_of(&m);
    p.residual_every = get(&m, "residual-every", 0usize);
    p.residual_nonblocking = residual_nonblocking_of(&m);
    p.clock_shards = get(&m, "clock-shards", 1usize);
    p.clock_queue = clock_queue_of(&m);
    apply_net_overrides(&m, &mut p.net);
    p.deadline = Some(ms(get(&m, "deadline-ms", 600_000u64)));
    let fmt = trace_format_of(&m);
    let tracer = (m.get("trace").is_some() && fmt != TraceFormat::Perfetto)
        .then(|| Arc::new(Tracer::new()));
    let spans = (m.get("trace").is_some() && fmt == TraceFormat::Perfetto)
        .then(|| tampi_repro::obs::SpanSink::new(1 << 20));
    p.tracer = tracer.clone();
    p.spans = spans.clone();
    let wall = Instant::now();
    match ifsker::run(&p) {
        Ok(out) => {
            println!(
                "ifsker {} nodes={} ranks/node={} grid={} fields={} steps={} compute={:?}",
                version.name(),
                p.nodes,
                p.cores_per_node,
                p.gridpoints,
                p.fields,
                p.steps,
                p.compute
            );
            println!(
                "  vtime: {:.3} ms | {:.2e} gp-steps/s | checksum {:.6}",
                out.vtime_ns as f64 / 1e6,
                out.throughput(&p),
                out.checksum
            );
            println!(
                "  tasks={} pauses={} workers={} | wall {:.2}s",
                out.stats.tasks,
                out.stats.pauses,
                out.stats.workers,
                wall.elapsed().as_secs_f64()
            );
        }
        Err(e) => {
            eprintln!("FAILED: {e}");
            std::process::exit(1);
        }
    }
    dump_trace(&m, fmt, &tracer, &spans);
}

const KNOWN_FIGS: [&str; 17] = [
    "8", "9", "10", "11", "12", "13", "14", "15", "16", "17", "18", "19", "20", "21", "22", "23",
    "all",
];

fn cmd_figures(m: HashMap<String, String>) {
    let scale = m
        .get("scale")
        .and_then(|s| Scale::parse(s))
        .unwrap_or_else(Scale::from_env);
    let which = m.get("fig").map(String::as_str).unwrap_or("all");
    // Reject unknown figures up front with a non-zero exit (regression-
    // tested in tests/coll_topology.rs): a typo must not silently run
    // nothing — or everything.
    if !KNOWN_FIGS.contains(&which) {
        eprintln!(
            "unknown figure {which} (valid: 8 9 10 11 12 13 14 15 16 17 18 19 20 21 22 23 | all)"
        );
        std::process::exit(2);
    }
    // `--net-rx` pins fig 18's congestion sweep to one point and
    // `--eager` moves its rendezvous threshold. Every other figure pins
    // its own network model, so accepting the knobs there would emit
    // wrong-labeled data — reject instead of silently ignoring.
    let net_rx: Option<u64> = m.get("net-rx").map(|v| parse_or_die(v, "net-rx"));
    let net_eager: Option<usize> = m.get("eager").map(|v| parse_or_die(v, "eager"));
    if (net_rx.is_some() || net_eager.is_some()) && which != "18" {
        eprintln!("--net-rx/--eager only apply to --fig 18 (other figures pin their models)");
        std::process::exit(2);
    }
    // `--json` replaces the text run: the machine-readable document is
    // built from the same rows, so running the sweep a second time for
    // the table would double the bench job's cost for no information.
    if let Some(path) = m.get("json") {
        let json = match which {
            "15" => bench::fig15_json(scale),
            "16" => bench::fig16_json(scale),
            "17" => bench::fig17_json(scale),
            "18" => bench::fig18_json(scale, net_rx, net_eager),
            "19" => bench::fig19_json(scale),
            "20" => bench::fig20_json(scale),
            "21" => bench::fig21_json(scale),
            "22" => bench::fig22_json(scale),
            "23" => bench::fig23_json(scale),
            other => {
                eprintln!(
                    "--json requires a machine-readable figure (--fig 15|16|17|18|19|20|21|22|23), got {other}"
                );
                std::process::exit(2);
            }
        };
        std::fs::write(path, &json).expect("write bench json");
        println!("fig {which} json -> {path}");
        return;
    }
    let run_fig = |n: &str| {
        let wall = Instant::now();
        match n {
            "8" => {
                for (name, dot, edges) in bench::fig08() {
                    let p = bench::write_output(&format!("fig08_{name}.dot"), &dot);
                    println!("fig08 {name}: {edges} edges -> {}", p.display());
                }
            }
            "10" => {
                for (name, gantt, csv, busy) in bench::fig10(scale) {
                    let p = bench::write_output(&format!("fig10_{name}.csv"), &csv);
                    bench::write_output(&format!("fig10_{name}.gantt.txt"), &gantt);
                    println!("fig10 {name} -> {}\n{gantt}", p.display());
                    for (rank, f) in busy {
                        println!("  rank {rank}: busy {:.1}%", f * 100.0);
                    }
                }
            }
            "15" => {
                let report = bench::fig15_report(scale);
                println!("{report}");
                let p = bench::write_output("fig15_completion_latency.txt", &report);
                println!("fig15 -> {}", p.display());
            }
            "16" => {
                let report = bench::fig16_report(scale);
                println!("{report}");
                let p = bench::write_output("fig16_coll_overlap.txt", &report);
                println!("fig16 -> {}", p.display());
            }
            "17" => {
                let report = bench::fig17_report(scale);
                println!("{report}");
                let p = bench::write_output("fig17_coll_topology.txt", &report);
                println!("fig17 -> {}", p.display());
            }
            "18" => {
                let report = bench::fig18_report(scale, net_rx, net_eager);
                println!("{report}");
                let p = bench::write_output("fig18_incast.txt", &report);
                println!("fig18 -> {}", p.display());
            }
            "19" => {
                let report = bench::fig19_report(scale);
                println!("{report}");
                let p = bench::write_output("fig19_clock_shards.txt", &report);
                println!("fig19 -> {}", p.display());
            }
            "20" => {
                let report = bench::fig20_report(scale);
                println!("{report}");
                let p = bench::write_output("fig20_overlap.txt", &report);
                println!("fig20 -> {}", p.display());
            }
            "21" => {
                let report = bench::fig21_report(scale);
                println!("{report}");
                let p = bench::write_output("fig21_plan_compile.txt", &report);
                println!("fig21 -> {}", p.display());
            }
            "22" => {
                let report = bench::fig22_report(scale);
                println!("{report}");
                let p = bench::write_output("fig22_faults.txt", &report);
                println!("fig22 -> {}", p.display());
            }
            "23" => {
                let report = bench::fig23_report(scale);
                println!("{report}");
                let p = bench::write_output("fig23_queue_throughput.txt", &report);
                println!("fig23 -> {}", p.display());
            }
            other => {
                let rows = match other {
                    "9" => bench::fig09(scale),
                    "11" => bench::fig11(scale),
                    "12" => bench::fig12(scale),
                    "13" => bench::fig13(scale),
                    "14" => bench::fig14(scale),
                    _ => unreachable!("filtered by KNOWN_FIGS"),
                };
                let table = bench::format_table(&rows);
                println!("=== Figure {other} ({scale:?}) ===\n{table}");
                bench::write_output(&format!("fig{other:0>2}.txt"), &table);
            }
        }
        println!("(fig {n} took {:.1}s wall)\n", wall.elapsed().as_secs_f64());
    };
    if which == "all" {
        // Derived from KNOWN_FIGS so a future figure cannot be accepted
        // by --fig N yet silently dropped from --fig all.
        for &f in KNOWN_FIGS.iter().filter(|&&f| f != "all") {
            run_fig(f);
        }
    } else {
        run_fig(which);
    }
}

/// `repro stalls`: run a deliberately skewed cluster (the last rank
/// enters its collectives `--skew-ms` late), snapshot the trace halfway
/// through the skew, and print which rank the stall diagnostic blames.
fn cmd_stalls(m: HashMap<String, String>) {
    use tampi_repro::rmpi::{ClusterConfig, Universe};

    let ranks = get(&m, "ranks", 4usize);
    let skew = ms(get(&m, "skew-ms", 20u64));
    let tracer = Arc::new(Tracer::new());
    let mut cfg = ClusterConfig::new(ranks, 1, 0);
    cfg.tracer = Some(tracer.clone());
    cfg.deadline = Some(ms(600_000));
    Universe::run(cfg, move |ctx| {
        if ctx.rank == ctx.size - 1 {
            ctx.clock.sleep(skew); // the straggler every cluster has
        }
        ctx.comm.barrier();
        let mut v = [ctx.rank as f64];
        ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
    })
    .expect("stalls scenario");
    let records = tracer.snapshot();
    let at = skew / 2;
    let report = tampi_repro::trace::stall_report(&records, at, ranks);
    println!(
        "=== collective stall report at t={} ms (rank {} enters {} ms late) ===",
        at / 1_000_000,
        ranks - 1,
        skew / 1_000_000
    );
    print!("{}", tampi_repro::trace::format_stall_report(&report, at));
    let done = tampi_repro::trace::stall_report(&records, skew * 2, ranks);
    println!(
        "after the straggler arrives (t={} ms): {} collectives in flight",
        2 * skew / 1_000_000,
        done.len()
    );
}

fn cmd_calibrate() {
    use tampi_repro::apps::gauss_seidel::sweep_native;
    println!("calibrating native Gauss-Seidel cell cost...");
    for b in [128usize, 256, 512] {
        let mut u = vec![0.5f32; b * b];
        let h = vec![0f32; b];
        let t = Instant::now();
        let reps = (64 * 1024 * 1024 / (b * b)).max(4);
        for _ in 0..reps {
            sweep_native(&mut u, b, b, &h, &h, &h, &h);
        }
        let ns = t.elapsed().as_nanos() as f64 / (reps * b * b) as f64;
        println!("  block {b}: {ns:.2} ns/cell (native)");
    }
    // Also skips stub builds (no `pjrt` feature), which fail every
    // load by design even when the artifact files exist on disk.
    if tampi_repro::runtime::available("gs_block_256") {
        for b in [128usize, 256] {
            let k = tampi_repro::runtime::GsKernel::load(b).expect("kernel");
            let u = vec![0.5f32; b * b];
            let h = vec![0f32; b];
            let _ = k.sweep(&u, &h, &h, &h, &h).unwrap(); // warm-up
            let t = Instant::now();
            let reps = 16;
            for _ in 0..reps {
                let _ = k.sweep(&u, &h, &h, &h, &h).unwrap();
            }
            let ns = t.elapsed().as_nanos() as f64 / (reps * b * b) as f64;
            println!("  block {b}: {ns:.2} ns/cell (pjrt, incl. transfers)");
        }
    } else {
        println!("  (artifacts not built; skipping PJRT calibration)");
    }
    println!(
        "model default: {} ns/cell (override with GsParams::cell_ns)",
        tampi_repro::apps::DEFAULT_GS_CELL_NS
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("usage: repro <gs|ifsker|figures|stalls|calibrate> [--key value ...]");
        std::process::exit(2);
    };
    let m = parse_args(rest);
    match cmd.as_str() {
        "gs" => cmd_gs(m),
        "ifsker" => cmd_ifsker(m),
        "figures" => cmd_figures(m),
        "stalls" => cmd_stalls(m),
        "calibrate" => cmd_calibrate(),
        other => {
            eprintln!("unknown command {other}");
            std::process::exit(2);
        }
    }
}
