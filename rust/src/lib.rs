//! # TAMPI reproduction
//!
//! Production-quality reproduction of *"Integrating Blocking and
//! Non-Blocking MPI Primitives with Task-Based Programming Models"*
//! (K. Sala et al., Parallel Computing 2019) — the **Task-Aware MPI
//! (TAMPI)** library — including every substrate the paper depends on:
//!
//! * [`sim`] — virtual-time execution engine (the "cluster"),
//! * [`nanos`] — a Nanos6-like task runtime with the paper's pause/resume,
//!   external-events and polling-services APIs (Section 4),
//! * [`rmpi`] — an MPI-like message-passing library with communicators,
//!   matching semantics, requests and collectives,
//! * [`tampi`] — the paper's contribution: `MPI_TASK_MULTIPLE` blocking
//!   mode and `TAMPI_Iwait`/`TAMPI_Iwaitall` non-blocking mode (Section 6),
//! * [`progress`] — the sharded progress engine: per-rank completion
//!   shards, same-instant batched continuation waves, and bulk resume
//!   enqueues into the scheduler's per-worker ready queues,
//! * [`runtime`] — PJRT bridge executing the AOT-compiled JAX/Pallas
//!   compute kernels from `artifacts/*.hlo.txt`,
//! * [`apps`] — the paper's two benchmarks: Gauss-Seidel (five + one
//!   versions, Section 7.1) and IFSKer (Section 7.2),
//! * [`trace`] — execution traces (Fig 10), dependency graphs (Fig 8),
//!   and the collective stall diagnostic (`trace::stalls`),
//! * [`obs`] — the observability layer: typed spans in per-thread ring
//!   buffers, a Perfetto `trace_event` exporter, a metrics registry
//!   (counters/gauges/log2 histograms on `RunStats::metrics`), and the
//!   fig20 computation/communication overlap profiler,
//! * [`bench`] — the figure-regeneration harness (Figs 9-14 plus
//!   extension Figs 15-20 with machine-readable JSON output for CI).

pub mod apps;
pub mod bench;
pub mod nanos;
pub mod obs;
pub mod progress;
pub mod rmpi;
pub mod runtime;
pub mod sim;
pub mod tampi;
pub mod trace;
pub mod util;
