//! Virtual-time execution substrate.
//!
//! The reproduction host has a single physical core, so wall-clock speedup
//! of a threaded runtime is meaningless.  Instead, the whole stack runs
//! under *virtual time*: threads are real OS threads (the `nanos` runtime
//! really parks workers, really hands cores over on task pause/resume),
//! but every blocking point goes through [`Clock`], which only advances
//! the virtual clock when **all registered threads are passive**
//! (quiescence).  Virtual "work" ([`Clock::work`]) parks the thread until
//! the clock has advanced past its duration, so 3 000+ virtual cores
//! multiplex onto one physical core while producing the same timelines a
//! real cluster would.
//!
//! Invariants:
//! * `active` counts threads that are running or runnable.  It is
//!   decremented by a thread just before it parks on a [`Token`] and
//!   re-incremented *by the waker* on its behalf (activity transfer), so
//!   the count can never spuriously reach zero while a wake-up is in
//!   flight.
//! * The clock thread advances time only at `active == 0`, firing the
//!   earliest pending event batch.  `active == 0` is stable: no thread
//!   can become active except through the clock thread or a waker (and
//!   all wakers are themselves active threads).
//! * Quiescence with no pending events is a global deadlock; the clock
//!   reports it (this reproduces Section 5 of the paper faithfully).

pub mod clock;
pub mod sync;

pub use clock::{Clock, Token};
pub use sync::WaitQueue;

/// Nanoseconds of virtual time.
pub type VNanos = u64;

/// Convenience: microseconds -> ns.
pub const fn us(n: u64) -> VNanos {
    n * 1_000
}

/// Convenience: milliseconds -> ns.
pub const fn ms(n: u64) -> VNanos {
    n * 1_000_000
}
