//! Virtual-time execution substrate: a two-level discrete-event core.
//!
//! The reproduction host has few physical cores, so wall-clock speedup
//! of a threaded runtime is meaningless.  Instead, the whole stack runs
//! under *virtual time*: threads are real OS threads (the `nanos`
//! runtime really parks workers, really hands cores over on task
//! pause/resume), but every blocking point goes through [`Clock`], so
//! 3 000+ virtual cores multiplex onto a handful of physical cores
//! while producing the same timelines a real cluster would.
//!
//! The clock is organized in two levels:
//!
//! **Level 1 — per-shard quiescence.** Virtual time is sharded into
//! *lanes* (one per group of simulated nodes; a single lane by
//! default). Each lane has its own event heap, driver thread, and
//! `active` counter, and only advances when **all of its registered
//! threads are passive** (quiescence).  Virtual "work"
//! ([`Clock::work`]) parks the thread until its lane has advanced past
//! the work's duration.
//!
//! **Level 2 — cross-shard conservative lookahead.** Lanes synchronize
//! pessimistically (classic conservative PDES): each lane publishes a
//! lower bound `lb` on any event it may still create, and a quiescent
//! lane fires its head batch at `t` only while `t < lb[other] + L` for
//! every other lane, where the lookahead `L[other → me]` comes from a
//! per-lane-pair matrix derived from the network model (intra-node
//! latency for lanes sharing a node, inter-node otherwise — this is
//! what makes finer-than-node lanes legal).  Cross-lane events (port
//! resolutions, completion deliveries) are deposited into the owning
//! lane's heap with the same `(at, seq)` tie-break used within a lane,
//! so the merged order is independent of host scheduling and the run is
//! bit-identical to the single-lane engine at equal seeds.  See
//! [`clock`] for the full protocol (lb maintenance, zero-latency
//! feedback obligations, strictness of the bound).
//!
//! Invariants:
//! * `active` (per lane) counts threads that are running or runnable.
//!   It is decremented by a thread just before it parks on a [`Token`]
//!   and re-incremented *by the waker* on its behalf (activity
//!   transfer), so the count can never spuriously reach zero while a
//!   wake-up is in flight.
//! * Wakes are intra-lane: every completion is routed to the lane of
//!   the thread it may wake ([`Clock::call_at_on`]), so no lane's
//!   quiescence can be broken from the outside except through its own
//!   event heap.
//! * A lane's driver advances time only at `active == 0`, firing the
//!   earliest pending event batch its horizon allows.  `active == 0`
//!   is stable: no thread can become active except through the lane's
//!   driver or an intra-lane waker.
//! * Quiescence with no pending events across **all** lanes is a global
//!   deadlock; the clock reports it (this reproduces Section 5 of the
//!   paper faithfully).

pub mod clock;
pub mod sync;

pub use clock::{Clock, ClockCounters, ClockQueueKind, Token};
pub use sync::WaitQueue;

/// Nanoseconds of virtual time.
pub type VNanos = u64;

/// Convenience: microseconds -> ns.
pub const fn us(n: u64) -> VNanos {
    n * 1_000
}

/// Convenience: milliseconds -> ns.
pub const fn ms(n: u64) -> VNanos {
    n * 1_000_000
}
