//! FIFO wait queues built on clock tokens.
//!
//! Usage pattern (condvar-style, lost-wakeup-free when `enqueue` happens
//! under the same lock the waker holds while calling `notify_*`):
//!
//! ```ignore
//! let mut g = state.lock().unwrap();
//! loop {
//!     if pred(&g) { break; }
//!     let tok = queue.enqueue();
//!     drop(g);
//!     clock.passive_wait(&tok);
//!     g = state.lock().unwrap();
//! }
//! ```

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::clock::{Clock, Token};

/// FIFO queue of parked sim threads.
#[derive(Default)]
pub struct WaitQueue {
    q: Mutex<VecDeque<Arc<Token>>>,
}

impl WaitQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register the calling thread as a waiter; park on the returned token
    /// with [`Clock::passive_wait`].
    pub fn enqueue(&self) -> Arc<Token> {
        let tok = Token::new();
        self.q.lock().unwrap().push_back(tok.clone());
        tok
    }

    /// Enqueue an existing token (used to park one thread on several
    /// queues at once, e.g. MPI_Waitany). Waking is idempotent, so the
    /// same token may be notified by multiple queues.
    pub fn enqueue_token(&self, tok: Arc<Token>) {
        self.q.lock().unwrap().push_back(tok);
    }

    /// Wake the oldest waiter, if any.
    pub fn notify_one(&self, clock: &Clock) -> bool {
        let tok = self.q.lock().unwrap().pop_front();
        match tok {
            Some(t) => {
                clock.wake(&t);
                true
            }
            None => false,
        }
    }

    /// Wake every current waiter; returns how many were woken.
    pub fn notify_all(&self, clock: &Clock) -> usize {
        let drained: Vec<_> = self.q.lock().unwrap().drain(..).collect();
        let n = drained.len();
        for t in drained {
            clock.wake(&t);
        }
        n
    }

    /// Remove a specific (not yet woken) token from the queue, e.g. the
    /// shared waitany token still parked on requests that did not
    /// complete. Returns whether a copy was present.
    pub fn remove(&self, tok: &Arc<Token>) -> bool {
        let mut g = self.q.lock().unwrap();
        let before = g.len();
        g.retain(|t| !Arc::ptr_eq(t, tok));
        g.len() != before
    }

    /// Drop every queued token without waking it. Only sound when all
    /// queued tokens are already woken (or abandoned): used to reset a
    /// recycled `ReqState`'s waiter queue, whose tokens were all
    /// notified at completion time.
    pub fn clear(&self) {
        self.q.lock().unwrap().clear();
    }

    /// Number of parked waiters (diagnostics).
    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
