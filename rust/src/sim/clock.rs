//! The virtual clock: quiescence-driven discrete-event time, sharded
//! into per-lane event queues synchronized by conservative lookahead.
//!
//! ## One lane (the classic engine)
//!
//! With a single lane ([`Clock::start`]) this is the original engine:
//! one event queue, one driver thread, and the quiescence rule — the
//! driver fires the earliest pending batch only when every registered
//! thread is passive (`active == 0`).
//!
//! ## Many lanes (conservative PDES)
//!
//! [`Clock::start_lanes`] splits the queue into `n` *lanes* (groups of
//! simulated ranks), each with its own event queue, quiescence counter,
//! and driver thread. Lanes synchronize with classic conservative
//! lookahead (Chandy–Misra–Bryant): every lane publishes a *lower
//! bound* `lb` — a promise that it will never create another event
//! before `lb` — and a quiescent lane may fire its head batch at time
//! `t` only when, for every other lane `s`,
//!
//! ```text
//! t < lb[s] + L[s → me]
//! ```
//!
//! ### The per-pair lookahead matrix
//!
//! `L` is a full `n × n` matrix, not a scalar: `L[s → me]` is the
//! minimum virtual latency of *any* event lane `s` can create in lane
//! `me`. The Universe derives it from the `NetworkModel` — lane pairs
//! that share a node get the intra-node wire latency, pairs that never
//! share a node get the (larger) inter-node latency. This is what makes
//! *finer-than-node* lanes legal: with the old scalar
//! (`inter_latency_ns`) two lanes inside one node would have promised
//! each other more slack than the intra-node wire actually provides.
//! Every off-diagonal entry must be non-zero — a zero-latency pair
//! cannot be split conservatively ([`Clock::start_lanes`] asserts it).
//!
//! The inequality is strict: an event from `s` may land exactly at
//! `lb[s] + L`, and same-instant cross-lane arrivals must already be in
//! the queue (or parked on their port) before the instant fires — that
//! is what keeps port resolve passes complete and deadline assignment a
//! pure function of virtual history (see `rmpi::net::ports`).
//!
//! `lb` maintenance is the safety core:
//! * a push into a lane *lowers* its `lb` under the lane lock, so a
//!   pending early event is never hidden from peers;
//! * the driver *raises* `lb` only while holding the lock at
//!   `active == 0` (to the queue head, or `u64::MAX` when empty) — at
//!   that point no thread of the lane can create earlier work;
//! * while a batch at `t` fires, `lb` stays at `t` (the firing actions
//!   may push same-instant follow-ups).
//!
//! ### The calendar queue
//!
//! Each lane stores its pending events in a calendar queue
//! ([`ClockQueueKind::Calendar`], the default): a ring of
//! fixed-width time buckets covering a near window, with a binary-heap
//! overflow for events beyond it. Pushes into the window are O(1)
//! bucket appends; pops walk a cursor across the buckets, lazily
//! sorting only the cursor bucket (descending, so the minimum pops from
//! the back in O(1)). When the window is exhausted the queue *rebases*
//! onto the earliest far event and redistributes the far heap's
//! near-window slice into the buckets. Bucket vectors are reused across
//! rebases, so steady-state operation allocates nothing.
//!
//! **Why bit-identity survives the queue swap:** the queue is only ever
//! observed through `peek`/`pop`, and both always compare the near
//! window's minimum against the far heap's minimum and return the
//! *global* `(at, seq)` minimum — below-window pushes (a lagging
//! `lane.now` after a rebase) simply live in the far heap and win the
//! comparison when due. Pop order is therefore the total `(at, seq)`
//! order regardless of internal bucket layout, which is exactly the
//! order the binary heap produced ([`ClockQueueKind::BinaryHeap`] is
//! kept selectable for A/B benchmarking — fig23 asserts the identity).
//!
//! ### Batched cross-lane transfer
//!
//! A firing batch often creates many events for the *same* destination
//! lane (a drain delivering k completions). Driver threads therefore
//! *stage* cross-lane pushes thread-locally and flush them per
//! destination as one lock acquisition, one `(at, seq)` run, one `lb`
//! adjustment (the batch minimum), and one condvar notify — instead of
//! k of each. Staging is safe because the flush happens while the
//! origin lane is still firing at `t`: its `lb` stays pinned at `t`, so
//! every destination is bounded by `t + L` (or `t` itself under a
//! feedback obligation, see below) and cannot overtake any staged event
//! (all staged times are `≥ t` for feedback, `≥ t + L` otherwise).
//! [`Clock::end_feedback`] flushes the stage *before* releasing the
//! obligation, so the zero-latency completion is always in the
//! destination queue by the time the bound relaxes.
//!
//! **Feedback obligations.** One event class is faster than the wire:
//! a rendezvous *sender* completion is zero-latency feedback from the
//! receiver's lane back to the sender's lane at the delivery instant.
//! Each such in-flight send registers an obligation
//! ([`Clock::begin_feedback`]); while `obligations[from → to] > 0`,
//! lane `to` drops the `+ L` term for lane `from` and bounds itself by
//! `lb[from]` alone. The obligation is released only after the
//! completion event is pushed into the sender's queue (where the head
//! accounts for it).
//!
//! **Invariant: wakes are intra-lane.** [`Clock::wake`] credits the
//! lane the token parked on; all completion events are routed to the
//! owning rank's lane precisely so that every wake happens on the lane
//! of the woken thread. Cross-lane communication goes through events
//! ([`Clock::call_at_on`]) only.
//!
//! Deadlock: a lane that is quiescent with an empty queue verifies the
//! whole cluster by locking every lane in index order — with all locks
//! held, no push or wake can be in flight (staged cross-lane events
//! only exist while their origin lane is firing, which the check also
//! excludes), so "all lanes passive, all queues empty, none firing,
//! threads registered" is a true global deadlock (the paper's Section 5
//! scenario).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::VNanos;

thread_local! {
    /// Accrued virtual CPU cost not yet turned into a clock event.
    static DEBT: std::cell::Cell<VNanos> = const { std::cell::Cell::new(0) };
    /// Clock lane the current thread belongs to (0 unless bound).
    static LANE: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
    /// Reusable one-shot token for `work_exact` (hot-path alloc saver).
    static WORK_TOKEN: std::cell::RefCell<Option<Arc<Token>>> =
        const { std::cell::RefCell::new(None) };
    /// Cross-lane staging area, installed only on lane driver threads:
    /// pushes into other lanes made while firing a batch are parked
    /// here and flushed as one batch per destination (see module docs).
    static STAGE: std::cell::RefCell<Option<CrossStage>> =
        const { std::cell::RefCell::new(None) };
}

/// One-shot wake token a thread parks on.
///
/// Lifecycle: created -> (optionally) parked on via [`Clock::passive_wait`]
/// -> woken exactly once via [`Clock::wake`] or a timer event.
pub struct Token {
    state: Mutex<TokState>,
    cv: Condvar,
}

#[derive(Default)]
struct TokState {
    woken: bool,
    /// True while the owning thread has decremented `active` and parked.
    passive: bool,
    /// Lane whose `active` count the parked thread came off of (valid
    /// while `passive`); the waker credits this lane back.
    lane: usize,
}

impl Token {
    pub fn new() -> Arc<Self> {
        Arc::new(Token { state: Mutex::new(TokState::default()), cv: Condvar::new() })
    }
}

impl Default for Token {
    fn default() -> Self {
        Token { state: Mutex::new(TokState::default()), cv: Condvar::new() }
    }
}

/// RAII guard from [`Clock::hold`]: releases its activity credit (one
/// per lane) on drop.
pub struct ClockHold {
    clock: Arc<Clock>,
}

impl Drop for ClockHold {
    fn drop(&mut self) {
        for lane in 0..self.clock.lanes.len() {
            self.clock.enter_passive(lane);
        }
    }
}

enum Action {
    Wake(Arc<Token>),
    /// Runs on the lane's driver thread at quiescence; must not block on
    /// sim primitives.  Used for network delivery completions.
    Call(Box<dyn FnOnce() + Send>),
}

struct EventEntry {
    at: VNanos,
    seq: u64,
    action: Action,
}

impl PartialEq for EventEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for EventEntry {}
impl PartialOrd for EventEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EventEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Which event-queue implementation each clock lane uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClockQueueKind {
    /// The classic `BinaryHeap<Reverse<EventEntry>>` (PR-6 engine;
    /// selectable for A/B benchmarking, fig23).
    BinaryHeap,
    /// Calendar queue: O(1) amortized push/pop inside the near-horizon
    /// bucket window, heap fallback for far events (see module docs).
    #[default]
    Calendar,
}

impl ClockQueueKind {
    /// Parse a CLI spelling (`heap`/`binary-heap` or `calendar`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "heap" | "binary-heap" | "binaryheap" => Some(ClockQueueKind::BinaryHeap),
            "calendar" | "cal" => Some(ClockQueueKind::Calendar),
            _ => None,
        }
    }

    /// Stable label for reports and JSON rows.
    pub fn label(self) -> &'static str {
        match self {
            ClockQueueKind::BinaryHeap => "heap",
            ClockQueueKind::Calendar => "calendar",
        }
    }
}

/// Number of near-window buckets per lane.
const CAL_BUCKETS: usize = 256;
/// log2 of the bucket width in virtual ns (1024 ns buckets — a few
/// `call_cpu_ns` quanta; wire latencies span a handful of buckets).
const CAL_SHIFT: u32 = 10;
/// Virtual width of the whole near window.
const CAL_SPAN: u64 = (CAL_BUCKETS as u64) << CAL_SHIFT;

/// Calendar queue: near-window time buckets + far-event heap. Pop order
/// is the global `(at, seq)` minimum by construction — every peek/pop
/// compares the cursor bucket's minimum with the far heap's top.
struct CalendarQueue {
    /// `buckets[i]` covers virtual `[base + i·W, base + (i+1)·W)`.
    /// Only the cursor bucket is kept sorted (descending, min at the
    /// back); the vectors are reused across rebases.
    buckets: Vec<Vec<EventEntry>>,
    /// Virtual time of bucket 0's lower edge (bucket-width aligned).
    base: VNanos,
    /// Cursor: buckets below it are empty.
    cur: usize,
    /// Whether `buckets[cur]` is currently sorted descending.
    cur_sorted: bool,
    /// Events outside the near window (including below-base pushes).
    far: BinaryHeap<Reverse<EventEntry>>,
    /// Events currently held in buckets.
    near_len: usize,
}

impl CalendarQueue {
    fn new() -> CalendarQueue {
        CalendarQueue {
            buckets: (0..CAL_BUCKETS).map(|_| Vec::new()).collect(),
            base: 0,
            cur: 0,
            cur_sorted: true,
            far: BinaryHeap::new(),
            near_len: 0,
        }
    }

    fn is_empty(&self) -> bool {
        self.near_len == 0 && self.far.is_empty()
    }

    fn push(&mut self, e: EventEntry) {
        if e.at < self.base || e.at >= self.base.saturating_add(CAL_SPAN) {
            self.far.push(Reverse(e));
            return;
        }
        let idx = ((e.at - self.base) >> CAL_SHIFT) as usize;
        if idx < self.cur {
            self.cur = idx;
            self.cur_sorted = false;
        }
        if idx == self.cur && self.cur_sorted {
            // Keep the cursor bucket sorted (descending by (at, seq)):
            // O(log) find + shift, but same-bucket inserts behind the
            // cursor minimum are rare on the hot path.
            let key = (e.at, e.seq);
            let pos = self.buckets[idx].partition_point(|x| (x.at, x.seq) > key);
            self.buckets[idx].insert(pos, e);
        } else {
            self.buckets[idx].push(e);
        }
        self.near_len += 1;
    }

    /// Advance the cursor to the first non-empty bucket, rebasing the
    /// window onto the far heap when the near window is exhausted, and
    /// lazily sort the cursor bucket.
    fn settle(&mut self) {
        loop {
            while self.cur < CAL_BUCKETS && self.buckets[self.cur].is_empty() {
                self.cur += 1;
                self.cur_sorted = false;
            }
            if self.cur < CAL_BUCKETS || self.far.is_empty() {
                break;
            }
            // Near window exhausted: rebase onto the earliest far event
            // and pull the far heap's new near-window slice into the
            // (empty, capacity-retaining) buckets.
            let head_at = self.far.peek().expect("non-empty far").0.at;
            self.base = (head_at >> CAL_SHIFT) << CAL_SHIFT;
            self.cur = 0;
            self.cur_sorted = false;
            let end = self.base.saturating_add(CAL_SPAN);
            while let Some(Reverse(e)) = self.far.peek() {
                if e.at >= end {
                    break;
                }
                let Reverse(e) = self.far.pop().expect("peeked");
                let idx = ((e.at - self.base) >> CAL_SHIFT) as usize;
                self.buckets[idx].push(e);
                self.near_len += 1;
            }
        }
        if self.cur < CAL_BUCKETS && !self.cur_sorted {
            self.buckets[self.cur].sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
            self.cur_sorted = true;
        }
    }

    /// Key of the cursor bucket's minimum, if any (call after `settle`).
    fn near_key(&self) -> Option<(VNanos, u64)> {
        if self.cur < CAL_BUCKETS {
            self.buckets[self.cur].last().map(|e| (e.at, e.seq))
        } else {
            None
        }
    }

    fn peek_key(&mut self) -> Option<(VNanos, u64)> {
        self.settle();
        let near = self.near_key();
        let far = self.far.peek().map(|Reverse(e)| (e.at, e.seq));
        match (near, far) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    fn pop(&mut self) -> Option<EventEntry> {
        self.settle();
        let near = self.near_key();
        let far = self.far.peek().map(|Reverse(e)| (e.at, e.seq));
        let take_near = match (near, far) {
            (None, None) => return None,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some(a), Some(b)) => a <= b,
        };
        if take_near {
            self.near_len -= 1;
            self.buckets[self.cur].pop()
        } else {
            self.far.pop().map(|Reverse(e)| e)
        }
    }
}

/// A lane's pending-event store: binary heap or calendar queue, both
/// popping in strict global `(at, seq)` order.
enum EventQueue {
    Heap(BinaryHeap<Reverse<EventEntry>>),
    Calendar(CalendarQueue),
}

impl EventQueue {
    fn new(kind: ClockQueueKind) -> EventQueue {
        match kind {
            ClockQueueKind::BinaryHeap => EventQueue::Heap(BinaryHeap::new()),
            ClockQueueKind::Calendar => EventQueue::Calendar(CalendarQueue::new()),
        }
    }

    fn push(&mut self, e: EventEntry) {
        match self {
            EventQueue::Heap(h) => h.push(Reverse(e)),
            EventQueue::Calendar(c) => c.push(e),
        }
    }

    /// `(at, seq)` of the globally earliest pending event. `&mut`
    /// because the calendar queue settles its cursor lazily.
    fn peek_key(&mut self) -> Option<(VNanos, u64)> {
        match self {
            EventQueue::Heap(h) => h.peek().map(|Reverse(e)| (e.at, e.seq)),
            EventQueue::Calendar(c) => c.peek_key(),
        }
    }

    fn pop(&mut self) -> Option<EventEntry> {
        match self {
            EventQueue::Heap(h) => h.pop().map(|Reverse(e)| e),
            EventQueue::Calendar(c) => c.pop(),
        }
    }

    fn is_empty(&self) -> bool {
        match self {
            EventQueue::Heap(h) => h.is_empty(),
            EventQueue::Calendar(c) => c.is_empty(),
        }
    }
}

/// Per-driver-thread staging area for cross-lane pushes (flushed as one
/// locked batch per destination lane; see module docs).
struct CrossStage {
    per_lane: Vec<Vec<(VNanos, Action)>>,
    staged: usize,
}

impl CrossStage {
    fn new(lanes: usize) -> CrossStage {
        CrossStage { per_lane: (0..lanes).map(|_| Vec::new()).collect(), staged: 0 }
    }
}

struct LaneState {
    events: EventQueue,
    seq: u64,
    stopped: bool,
}

/// One shard of virtual time: its own event queue, quiescence counter,
/// and published lower bound.
struct Lane {
    state: Mutex<LaneState>,
    tick_cv: Condvar,
    now: AtomicU64,
    /// Threads of this lane currently running or runnable.
    active: AtomicUsize,
    /// Published promise: this lane will never create an event before
    /// `lb`. Lowered under the lane lock by pushes; raised only by the
    /// driver at quiescence. `u64::MAX` = idle with nothing scheduled.
    lb: AtomicU64,
    /// True while the driver fires a batch (its actions may still push).
    firing: AtomicBool,
}

impl Lane {
    fn new(queue: ClockQueueKind) -> Lane {
        Lane {
            state: Mutex::new(LaneState {
                events: EventQueue::new(queue),
                seq: 0,
                stopped: false,
            }),
            tick_cv: Condvar::new(),
            now: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            lb: AtomicU64::new(0),
            firing: AtomicBool::new(false),
        }
    }
}

/// Clock throughput counters (see `RunStats` plumbing in `rmpi`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClockCounters {
    /// Events fired across all lanes.
    pub events: u64,
    /// Same-instant batches fired across all lanes.
    pub batches: u64,
    /// Events pushed into a lane other than the pusher's own.
    pub cross_lane: u64,
    /// Staged cross-lane flushes: one per (firing batch, destination
    /// lane) pair — each covers one lock + one notify for the whole
    /// event group.
    pub cross_batches: u64,
    /// `work`/`sleep` advances that reused the thread-local token
    /// instead of allocating a fresh one.
    pub work_tokens_reused: u64,
}

/// Virtual clock shared by every thread of a simulated cluster.
pub struct Clock {
    lanes: Vec<Lane>,
    /// Conservative lookahead matrix, `[from_lane * n + to_lane]` in
    /// virtual ns: the minimum latency of any event lane `from` can
    /// create in lane `to`. All off-diagonal entries are non-zero when
    /// `n > 1` (asserted at construction); never consulted when `n == 1`.
    lookahead: Vec<VNanos>,
    /// Threads registered with the clock (diagnostics + deadlock gate).
    registered: AtomicUsize,
    /// Set when quiescence is reached with no pending events.
    deadlocked: AtomicBool,
    panic_on_deadlock: AtomicBool,
    /// Feedback-obligation matrix, `[from_lane * n + to_lane]`: while
    /// an entry is non-zero, lane `to` bounds itself by `lb[from]`
    /// without the `+ lookahead` term (see module docs).
    obligations: Vec<AtomicU64>,
    n_events: AtomicU64,
    n_batches: AtomicU64,
    n_cross: AtomicU64,
    n_cross_batches: AtomicU64,
    n_token_reuse: AtomicU64,
    /// Observability hook (set by the Universe when span recording is
    /// on): lane drivers emit a `LaneWait` span for every stretch they
    /// spend horizon-blocked on a peer's conservative-lookahead bound.
    /// Read only on the cold blocked→fire edge; never consulted on the
    /// hot firing path, and emission never touches virtual time.
    obs: Mutex<Option<Arc<crate::obs::RunObs>>>,
}

impl Clock {
    /// Create a single-lane clock and start its driver thread (the
    /// classic engine; every existing caller goes through here).
    pub fn start() -> (Arc<Clock>, JoinHandle<()>) {
        let (clock, mut handles) = Self::start_sharded(1, 0);
        (clock, handles.pop().expect("one driver"))
    }

    /// Create a clock with `lanes` shards of virtual time using a
    /// *uniform* lookahead (the scalar façade over
    /// [`Clock::start_lanes`]). `lookahead` is the minimum cross-lane
    /// delivery latency in virtual ns and must be non-zero when
    /// `lanes > 1` (a zero-latency network cannot be sharded
    /// conservatively).
    pub fn start_sharded(lanes: usize, lookahead: VNanos) -> (Arc<Clock>, Vec<JoinHandle<()>>) {
        Self::start_lanes(lanes, vec![lookahead; lanes * lanes], ClockQueueKind::default())
    }

    /// Create a clock with `lanes` shards of virtual time, a full
    /// per-pair `lookahead` matrix (`[from * lanes + to]`, virtual ns),
    /// and the given event-queue implementation; start one driver
    /// thread per lane. Every off-diagonal matrix entry must be
    /// non-zero when `lanes > 1`.
    pub fn start_lanes(
        lanes: usize,
        lookahead: Vec<VNanos>,
        queue: ClockQueueKind,
    ) -> (Arc<Clock>, Vec<JoinHandle<()>>) {
        assert!(lanes >= 1, "need at least one clock lane");
        assert_eq!(lookahead.len(), lanes * lanes, "lookahead matrix must be lanes x lanes");
        if lanes > 1 {
            for from in 0..lanes {
                for to in 0..lanes {
                    assert!(
                        from == to || lookahead[from * lanes + to] > 0,
                        "clock sharding requires non-zero lookahead for every lane \
                         pair (zero {from} -> {to}): a zero-latency pair cannot be \
                         split conservatively"
                    );
                }
            }
        }
        let clock = Arc::new(Clock {
            lanes: (0..lanes).map(|_| Lane::new(queue)).collect(),
            lookahead,
            registered: AtomicUsize::new(0),
            deadlocked: AtomicBool::new(false),
            panic_on_deadlock: AtomicBool::new(true),
            obligations: (0..lanes * lanes).map(|_| AtomicU64::new(0)).collect(),
            n_events: AtomicU64::new(0),
            n_batches: AtomicU64::new(0),
            n_cross: AtomicU64::new(0),
            n_cross_batches: AtomicU64::new(0),
            n_token_reuse: AtomicU64::new(0),
            obs: Mutex::new(None),
        });
        let handles = (0..lanes)
            .map(|i| {
                let c = clock.clone();
                let name = if lanes == 1 {
                    "sim-clock".to_string()
                } else {
                    format!("sim-clock-{i}")
                };
                std::thread::Builder::new()
                    .name(name)
                    .spawn(move || c.run(i))
                    .expect("spawn clock thread")
            })
            .collect();
        (clock, handles)
    }

    /// Number of clock lanes.
    pub fn num_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Bind the calling thread to a clock lane. Every simulated thread
    /// of a multi-lane clock must bind before touching the clock; lane
    /// 0 is the default for unbound threads (and the only lane of a
    /// single-lane clock).
    pub fn bind_lane(lane: usize) {
        LANE.with(|l| l.set(lane));
    }

    /// Lane the calling thread is bound to.
    pub fn current_lane() -> usize {
        LANE.with(|l| l.get())
    }

    fn lane_of_caller(&self) -> usize {
        Self::current_lane().min(self.lanes.len() - 1)
    }

    /// Current virtual time of the calling thread's lane, in ns.
    pub fn now(&self) -> VNanos {
        self.lanes[self.lane_of_caller()].now.load(Ordering::Acquire)
    }

    /// Maximum virtual time over all lanes (orchestrator diagnostics;
    /// equals [`Clock::now`] on a single-lane clock).
    pub fn max_now(&self) -> VNanos {
        self.lanes
            .iter()
            .map(|l| l.now.load(Ordering::Acquire))
            .max()
            .unwrap_or(0)
    }

    /// Whether a global deadlock was detected.
    pub fn deadlocked(&self) -> bool {
        self.deadlocked.load(Ordering::Acquire)
    }

    /// Configure deadlock behaviour: panic (default) or set a flag and halt.
    pub fn set_panic_on_deadlock(&self, panic: bool) {
        self.panic_on_deadlock.store(panic, Ordering::Release);
    }

    /// Attach the run's observability bundle; from now on, lane
    /// drivers record `LaneWait` spans for horizon-blocked stretches.
    pub fn set_obs(&self, obs: Arc<crate::obs::RunObs>) {
        *self.obs.lock().unwrap() = Some(obs);
    }

    /// Snapshot of the clock throughput counters.
    pub fn counters(&self) -> ClockCounters {
        ClockCounters {
            events: self.n_events.load(Ordering::Relaxed),
            batches: self.n_batches.load(Ordering::Relaxed),
            cross_lane: self.n_cross.load(Ordering::Relaxed),
            cross_batches: self.n_cross_batches.load(Ordering::Relaxed),
            work_tokens_reused: self.n_token_reuse.load(Ordering::Relaxed),
        }
    }

    /// A thread joins the simulation on the caller's lane.
    pub fn register_thread(&self) {
        self.register_thread_on(Self::current_lane());
    }

    /// A thread joins the simulation on `lane` (it is active from now
    /// on). Used by spawners that pre-register a child thread before it
    /// binds its own lane; the child must [`Clock::bind_lane`] to the
    /// same lane. Must not be called while the lane could be quiescent
    /// (the spawner is itself active on some lane, or holds
    /// [`Clock::hold`]).
    pub fn register_thread_on(&self, lane: usize) {
        self.registered.fetch_add(1, Ordering::AcqRel);
        self.lanes[lane.min(self.lanes.len() - 1)]
            .active
            .fetch_add(1, Ordering::AcqRel);
    }

    /// A thread leaves the simulation for good.
    pub fn deregister_thread(&self) {
        self.registered.fetch_sub(1, Ordering::AcqRel);
        self.enter_passive(self.lane_of_caller());
    }

    /// Keep every lane from advancing (and from declaring deadlock)
    /// while an orchestrating thread is still wiring the simulation up:
    /// workers may already be parked before any registered thread
    /// exists, which would otherwise look like quiescence.
    pub fn hold(self: &Arc<Self>) -> ClockHold {
        for lane in &self.lanes {
            lane.active.fetch_add(1, Ordering::AcqRel);
        }
        ClockHold { clock: self.clone() }
    }

    /// Stop every lane driver (call after all sim threads exited/parked).
    pub fn stop(&self) {
        for lane in &self.lanes {
            let mut st = lane.state.lock().unwrap();
            st.stopped = true;
            lane.tick_cv.notify_all();
        }
    }

    fn enter_passive(&self, lane_idx: usize) {
        let lane = &self.lanes[lane_idx];
        if lane.active.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Possibly quiescent: nudge the lane driver. Lock + notify so
            // the wake-up cannot be missed between its check and wait.
            let _g = lane.state.lock().unwrap();
            lane.tick_cv.notify_all();
        }
    }

    /// Wake a token (activity transfer: the waker credits the wakee's
    /// lane). Wakes must be intra-lane on a multi-lane clock — route
    /// cross-lane completions through [`Clock::call_at_on`] instead.
    pub fn wake(&self, token: &Token) {
        let mut st = token.state.lock().unwrap();
        if st.woken {
            return; // already woken (idempotent)
        }
        st.woken = true;
        if st.passive {
            self.lanes[st.lane.min(self.lanes.len() - 1)]
                .active
                .fetch_add(1, Ordering::AcqRel);
        }
        token.cv.notify_one();
    }

    /// Park until the token is woken. The caller must be an active,
    /// registered sim thread on its bound lane.
    pub fn passive_wait(&self, token: &Token) {
        let lane = self.lane_of_caller();
        let mut st = token.state.lock().unwrap();
        if st.woken {
            return; // fast path: never went passive, no accounting
        }
        st.passive = true;
        st.lane = lane;
        drop(st);
        self.enter_passive(lane);
        let mut st = token.state.lock().unwrap();
        while !st.woken {
            st = token.cv.wait(st).unwrap();
        }
        st.passive = false;
        // The waker incremented our lane's `active` on our behalf.
    }

    /// Schedule `token` to be woken at absolute virtual time `at` (on
    /// the caller's lane).
    pub fn schedule_wake(&self, at: VNanos, token: Arc<Token>) {
        self.push_event_on(self.lane_of_caller(), at, Action::Wake(token));
    }

    /// Schedule `f` to run on the caller's lane driver at virtual time
    /// `at`. `f` must not block on sim primitives (it may call
    /// [`Clock::wake`]).
    pub fn call_at(&self, at: VNanos, f: impl FnOnce() + Send + 'static) {
        self.push_event_on(self.lane_of_caller(), at, Action::Call(Box::new(f)));
    }

    /// Schedule `f` to run on `lane`'s driver at virtual time `at` (the
    /// cross-shard mailbox: deliveries land on the owning rank's lane).
    pub fn call_at_on(&self, lane: usize, at: VNanos, f: impl FnOnce() + Send + 'static) {
        self.push_event_on(lane, at, Action::Call(Box::new(f)));
    }

    /// Run `f` at virtual time `at` on `lane` (caller's lane if `None`):
    /// inline when the caller is already on that lane and `at` has
    /// passed, else as a scheduled event. The completion-delivery shape
    /// of `rmpi::match_engine`.
    pub fn run_at_on(&self, lane: Option<usize>, at: VNanos, f: impl FnOnce() + Send + 'static) {
        let cur = self.lane_of_caller();
        let target = lane.unwrap_or(cur).min(self.lanes.len() - 1);
        if target == cur && at <= self.now() {
            f();
        } else {
            self.push_event_on(target, at, Action::Call(Box::new(f)));
        }
    }

    /// Register an in-flight zero-latency feedback path from lane
    /// `from` into lane `to` (a rendezvous sender completion): until
    /// released, lane `to` bounds itself by `lb[from]` without the
    /// lookahead term. Call while the sender's thread is still active
    /// on lane `to`.
    pub fn begin_feedback(&self, from: usize, to: usize) {
        let n = self.lanes.len();
        if n == 1 || from == to {
            return;
        }
        self.obligations[from * n + to].fetch_add(1, Ordering::AcqRel);
    }

    /// Release a feedback obligation. Call only after the completion
    /// event was pushed into lane `to`'s queue (the head then accounts
    /// for it).
    pub fn end_feedback(&self, from: usize, to: usize) {
        let n = self.lanes.len();
        if n == 1 || from == to {
            return;
        }
        // The completion event may still be sitting in this driver's
        // cross-lane stage: it must be in `to`'s queue before the
        // obligation releases, or `to` could advance past it.
        self.flush_stage();
        let prev = self.obligations[from * n + to].fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "feedback obligation released without begin");
        // The bound for `to` just rose from lb[from] to lb[from] + L:
        // its driver may now be able to advance.
        let lane = &self.lanes[to];
        let _g = lane.state.lock().unwrap();
        lane.tick_cv.notify_all();
    }

    fn push_event_on(&self, lane_idx: usize, at: VNanos, action: Action) {
        let lane_idx = lane_idx.min(self.lanes.len() - 1);
        if lane_idx != Self::current_lane() {
            self.n_cross.fetch_add(1, Ordering::Relaxed);
            // Driver threads stage cross-lane pushes while firing and
            // flush them one locked batch per destination lane. Safe
            // because the origin lane's lb pins every destination below
            // any staged event time until the flush (module docs).
            let leftover = STAGE.with(|s| match s.borrow_mut().as_mut() {
                Some(stage) => {
                    stage.per_lane[lane_idx].push((at, action));
                    stage.staged += 1;
                    None
                }
                None => Some(action),
            });
            match leftover {
                Some(action) => self.push_direct(lane_idx, at, action),
                None => {}
            }
            return;
        }
        self.push_direct(lane_idx, at, action);
    }

    fn push_direct(&self, lane_idx: usize, at: VNanos, action: Action) {
        let lane = &self.lanes[lane_idx];
        let mut st = lane.state.lock().unwrap();
        let seq = st.seq;
        st.seq += 1;
        let at = at.max(lane.now.load(Ordering::Acquire));
        let earlier_head = st.events.peek_key().map_or(true, |(h, _)| at < h);
        st.events.push(EventEntry { at, seq, action });
        // Safety-critical lb maintenance: a pending event must never sit
        // below the lane's published lower bound (peers advance to
        // lb + lookahead). All lb writes happen under the lane lock.
        if at < lane.lb.load(Ordering::Acquire) {
            lane.lb.store(at, Ordering::Release);
        }
        // Only notify when the driver may actually be waiting: it waits
        // either quiescent (for any event / horizon change) or not at
        // all while threads are active — in which case a push that does
        // not improve the head cannot unblock anything.
        let quiescent = lane.active.load(Ordering::Acquire) == 0;
        if quiescent || earlier_head {
            lane.tick_cv.notify_all();
        }
    }

    /// Flush the calling driver thread's cross-lane stage: one lock
    /// acquisition, one contiguous `(at, seq)` run, one `lb` adjustment
    /// (the batch minimum), and one notify per destination lane. No-op
    /// on threads without a stage (non-drivers push directly).
    fn flush_stage(&self) {
        STAGE.with(|s| {
            let mut s = s.borrow_mut();
            let Some(stage) = s.as_mut() else { return };
            if stage.staged == 0 {
                return;
            }
            stage.staged = 0;
            for (dest_idx, pending) in stage.per_lane.iter_mut().enumerate() {
                if pending.is_empty() {
                    continue;
                }
                let lane = &self.lanes[dest_idx];
                let mut st = lane.state.lock().unwrap();
                let now = lane.now.load(Ordering::Acquire);
                let mut batch_min = u64::MAX;
                for (at, action) in pending.drain(..) {
                    let at = at.max(now);
                    let seq = st.seq;
                    st.seq += 1;
                    st.events.push(EventEntry { at, seq, action });
                    batch_min = batch_min.min(at);
                }
                if batch_min < lane.lb.load(Ordering::Acquire) {
                    lane.lb.store(batch_min, Ordering::Release);
                }
                lane.tick_cv.notify_all();
                drop(st);
                self.n_cross_batches.fetch_add(1, Ordering::Relaxed);
            }
        });
    }

    /// May this lane fire its head batch at `t` without risking an
    /// earlier cross-lane arrival? (Strict bound; see module docs.)
    fn horizon_allows(&self, me: usize, t: VNanos) -> bool {
        let n = self.lanes.len();
        for s in 0..n {
            if s == me {
                continue;
            }
            let lb = self.lanes[s].lb.load(Ordering::Acquire);
            let bound = if self.obligations[s * n + me].load(Ordering::Acquire) > 0 {
                // Zero-latency feedback pending: `s` may push at exactly
                // its own position, never below it, so `t == lb[s]` is
                // safe (a same-instant arrival lands in a later batch at
                // the same instant, as in the single-lane engine) —
                // and non-strict here keeps mutually-obligated lanes
                // with equal heads from deadlocking on each other.
                lb.saturating_add(1)
            } else {
                lb.saturating_add(self.lookahead[s * n + me])
            };
            if t >= bound {
                return false;
            }
        }
        true
    }

    /// Nudge every other lane driver: this lane's published bound rose.
    fn notify_peers(&self, me: usize) {
        for (i, lane) in self.lanes.iter().enumerate() {
            if i == me {
                continue;
            }
            let _g = lane.state.lock().unwrap();
            lane.tick_cv.notify_all();
        }
    }

    /// Global deadlock test: lock every lane in index order (pushes and
    /// wakes are then excluded — every waker is an active thread or a
    /// firing driver, and staged cross-lane events only exist while
    /// their origin lane is firing) and verify total quiescence.
    fn check_global_deadlock(&self) -> bool {
        let guards: Vec<_> = self.lanes.iter().map(|l| l.state.lock().unwrap()).collect();
        for (lane, g) in self.lanes.iter().zip(guards.iter()) {
            if lane.firing.load(Ordering::Acquire)
                || lane.active.load(Ordering::Acquire) != 0
                || !g.events.is_empty()
            {
                return false;
            }
        }
        true
    }

    fn declare_deadlock(&self) {
        self.deadlocked.store(true, Ordering::Release);
        if self.panic_on_deadlock.load(Ordering::Acquire) {
            panic!(
                "sim::Clock deadlock: {} registered threads are all \
                 passive with no pending events (t={} ns). This is \
                 the Section-5 scenario: blocking operations inside \
                 tasks with no progress mechanism.",
                self.registered.load(Ordering::Acquire),
                self.max_now()
            );
        }
    }

    /// Record `ns` of virtual CPU cost for the calling thread without
    /// parking. The debt is folded into the next [`Clock::work`] /
    /// [`Clock::flush_debt`] on this thread — this keeps high-frequency
    /// costs (task spawns, scheduling) from generating one clock event
    /// each.
    pub fn add_debt(ns: VNanos) {
        DEBT.with(|d| d.set(d.get() + ns));
    }

    /// Take and reset the calling thread's accumulated debt.
    pub fn take_debt() -> VNanos {
        DEBT.with(|d| d.replace(0))
    }

    /// Park for the thread's accumulated debt, if any.
    pub fn flush_debt(&self) {
        let d = Self::take_debt();
        if d > 0 {
            self.work_exact(d);
        }
    }

    /// Advance virtual time by `d` plus any accumulated debt for the
    /// calling thread ("do d ns of work on my virtual core"). The thread
    /// parks; the clock advances once everyone else is passive too.
    pub fn work(&self, d: VNanos) {
        let d = d + Self::take_debt();
        self.work_exact(d);
    }

    fn work_exact(&self, d: VNanos) {
        if d == 0 {
            return;
        }
        // Hot path: one `work` per task body / debt flush. Reuse a
        // thread-local token instead of allocating a fresh Arc<Token>
        // per advance; the token is strictly thread-owned (scheduled,
        // consumed by the driver's wake, then reset here).
        let token = WORK_TOKEN.with(|slot| {
            let mut slot = slot.borrow_mut();
            match &*slot {
                Some(tok) => {
                    let mut st = tok.state.lock().unwrap();
                    debug_assert!(!st.passive, "work token reused while parked");
                    st.woken = false;
                    drop(st);
                    self.n_token_reuse.fetch_add(1, Ordering::Relaxed);
                    tok.clone()
                }
                None => {
                    let tok = Token::new();
                    *slot = Some(tok.clone());
                    tok
                }
            }
        });
        self.schedule_wake(self.now() + d, token.clone());
        self.passive_wait(&token);
    }

    /// Alias of [`Clock::work`] with sleep naming for timers.
    pub fn sleep(&self, d: VNanos) {
        self.work(d);
    }

    /// Driver loop of one lane.
    fn run(&self, idx: usize) {
        Self::bind_lane(idx);
        let multi = self.lanes.len() > 1;
        if multi {
            // Install the cross-lane staging area (driver threads only;
            // single-lane clocks never push cross-lane).
            let n = self.lanes.len();
            STAGE.with(|s| *s.borrow_mut() = Some(CrossStage::new(n)));
        }
        let lane = &self.lanes[idx];
        // Virtual instant at which this lane first found itself
        // horizon-blocked on a peer's bound (None = not blocked). The
        // matching LaneWait span is emitted when the head finally fires.
        let mut blocked_since: Option<VNanos> = None;
        // Reusable firing buffers — the hot loop allocates nothing.
        let mut batch: Vec<EventEntry> = Vec::new();
        let mut st = lane.state.lock().unwrap();
        loop {
            if st.stopped {
                // Fire actions already due at the current instant before
                // exiting (e.g. sharded-delivery drains scheduled at the
                // final instant): `stop` may race the last quiescence
                // pass, and a straggler continuation must not be lost.
                // Future-time events are still discarded, as before.
                let now = lane.now.load(Ordering::Acquire);
                while let Some((at, _)) = st.events.peek_key() {
                    if at > now {
                        break;
                    }
                    batch.push(st.events.pop().expect("peeked"));
                }
                if batch.is_empty() {
                    return;
                }
                drop(st);
                self.n_events.fetch_add(batch.len() as u64, Ordering::Relaxed);
                self.n_batches.fetch_add(1, Ordering::Relaxed);
                lane.firing.store(true, Ordering::Release);
                for e in batch.drain(..) {
                    match e.action {
                        Action::Wake(tok) => self.wake(&tok),
                        Action::Call(f) => f(),
                    }
                }
                self.flush_stage();
                lane.firing.store(false, Ordering::Release);
                st = lane.state.lock().unwrap();
                continue;
            }
            if lane.active.load(Ordering::Acquire) == 0 {
                // Quiescent: publish the tightest sound bound, then fire
                // the earliest batch if the cross-lane horizon allows.
                if let Some((t, _)) = st.events.peek_key() {
                    let prev_lb = lane.lb.load(Ordering::Acquire);
                    if t > prev_lb {
                        // Safe to raise: no thread of this lane can run
                        // before the head fires (active == 0 under lock).
                        lane.lb.store(t, Ordering::Release);
                    }
                    if !multi || self.horizon_allows(idx, t) {
                        if let Some(since) = blocked_since.take() {
                            // Cold edge: this batch was held back by a
                            // peer's lookahead bound. Record the stall
                            // (reads time only — no debt, no events).
                            let obs = self.obs.lock().unwrap().clone();
                            if let Some(obs) = obs {
                                obs.record(crate::obs::Span::interval(
                                    crate::obs::Track::Lane { lane: idx as u32 },
                                    crate::obs::SpanKind::LaneWait,
                                    since,
                                    t,
                                    "lane-wait",
                                    idx as u64,
                                ));
                            }
                        }
                        lane.now.store(t, Ordering::Release);
                        // lb stays at t while the batch fires: its
                        // actions may push same-instant follow-ups.
                        lane.firing.store(true, Ordering::Release);
                        while let Some((at, _)) = st.events.peek_key() {
                            if at > t {
                                break;
                            }
                            batch.push(st.events.pop().expect("peeked"));
                        }
                        drop(st);
                        self.n_events.fetch_add(batch.len() as u64, Ordering::Relaxed);
                        self.n_batches.fetch_add(1, Ordering::Relaxed);
                        for e in batch.drain(..) {
                            match e.action {
                                Action::Wake(tok) => self.wake(&tok),
                                Action::Call(f) => f(),
                            }
                        }
                        // Staged cross-lane pushes land now, while lb is
                        // still pinned at t (destinations cannot have
                        // overtaken any staged event time).
                        self.flush_stage();
                        lane.firing.store(false, Ordering::Release);
                        st = lane.state.lock().unwrap();
                        continue;
                    }
                    // Horizon-blocked: remember when the stall began
                    // (first detection only; the span closes when the
                    // head finally fires).
                    blocked_since.get_or_insert(lane.now.load(Ordering::Acquire));
                    if multi && t > prev_lb {
                        // Blocked on a peer's bound, but our own bound
                        // rose: let peers re-check their horizons, then
                        // re-evaluate (a push may have landed meanwhile).
                        drop(st);
                        self.notify_peers(idx);
                        st = lane.state.lock().unwrap();
                        continue;
                    }
                    // Horizon-blocked with nothing new to publish: wait
                    // (peers notify on lb raises; timeout as backstop).
                } else {
                    let prev_lb = lane.lb.load(Ordering::Acquire);
                    if prev_lb != u64::MAX {
                        lane.lb.store(u64::MAX, Ordering::Release);
                        if multi {
                            drop(st);
                            self.notify_peers(idx);
                            st = lane.state.lock().unwrap();
                            continue;
                        }
                    }
                    if self.registered.load(Ordering::Acquire) > 0 {
                        let dead = if multi {
                            // Verify across all lanes without holding our
                            // own lock (index-order locking inside).
                            drop(st);
                            let dead = self.check_global_deadlock();
                            st = lane.state.lock().unwrap();
                            dead
                        } else {
                            // Single lane: quiescent + empty is global.
                            true
                        };
                        if dead && !st.stopped {
                            self.declare_deadlock();
                            // Halt quietly: leave threads parked, wait
                            // for stop().
                            while !st.stopped {
                                st = if multi {
                                    lane.tick_cv
                                        .wait_timeout(st, Duration::from_millis(1))
                                        .unwrap()
                                        .0
                                } else {
                                    lane.tick_cv.wait(st).unwrap()
                                };
                            }
                            continue; // stop-drain at loop top (queue empty -> return)
                        }
                    }
                }
            }
            st = if multi {
                // Timeout backstop: peer lb raises notify us, but a
                // missed edge must not hang the lane forever.
                lane.tick_cv
                    .wait_timeout(st, Duration::from_millis(1))
                    .unwrap()
                    .0
            } else {
                lane.tick_cv.wait(st).unwrap()
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Feed both queue kinds an adversarial push sequence (duplicated
    /// instants, below-window backfill after rebase, far-future spikes)
    /// and assert identical pop order: the total `(at, seq)` order.
    #[test]
    fn queue_kinds_pop_in_identical_total_order() {
        let pushes: Vec<VNanos> = {
            // Deterministic pseudo-random times spanning several
            // rebase windows, with heavy same-instant duplication.
            let mut x: u64 = 0x9e37_79b9_7f4a_7c15;
            (0..4096)
                .map(|i| {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    let spread = x % (CAL_SPAN * 3);
                    if i % 7 == 0 { spread & !1023 } else { spread }
                })
                .collect()
        };
        let run = |kind: ClockQueueKind| -> Vec<(VNanos, u64)> {
            let mut q = EventQueue::new(kind);
            let mut out = Vec::new();
            let mut seq = 0u64;
            // Interleave pushes and pops so the calendar queue rebases
            // mid-stream and receives below-window pushes afterwards.
            for chunk in pushes.chunks(64) {
                for &at in chunk {
                    q.push(EventEntry { at, seq, action: Action::Call(Box::new(|| {})) });
                    seq += 1;
                }
                for _ in 0..32 {
                    if let Some(e) = q.pop() {
                        out.push((e.at, e.seq));
                    }
                }
            }
            while let Some(e) = q.pop() {
                out.push((e.at, e.seq));
            }
            out
        };
        let heap = run(ClockQueueKind::BinaryHeap);
        let cal = run(ClockQueueKind::Calendar);
        assert_eq!(heap.len(), pushes.len());
        assert_eq!(heap, cal, "calendar queue must pop in the heap's total order");
        // And that order is the non-decreasing (at, seq) total order
        // within each drain segment: verify global sortedness of a
        // fully-drained queue separately.
        let mut q = EventQueue::new(ClockQueueKind::Calendar);
        for (i, &at) in pushes.iter().enumerate() {
            q.push(EventEntry { at, seq: i as u64, action: Action::Call(Box::new(|| {})) });
        }
        let mut prev = (0, 0);
        let mut n = 0;
        while let Some(e) = q.pop() {
            assert!((e.at, e.seq) >= prev, "out of order: {:?} after {:?}", (e.at, e.seq), prev);
            prev = (e.at, e.seq);
            n += 1;
        }
        assert_eq!(n, pushes.len());
    }
}
