//! The virtual clock: quiescence-driven discrete-event time.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::VNanos;

thread_local! {
    /// Accrued virtual CPU cost not yet turned into a clock event.
    static DEBT: std::cell::Cell<VNanos> = const { std::cell::Cell::new(0) };
}

/// One-shot wake token a thread parks on.
///
/// Lifecycle: created -> (optionally) parked on via [`Clock::passive_wait`]
/// -> woken exactly once via [`Clock::wake`] or a timer event.
pub struct Token {
    state: Mutex<TokState>,
    cv: Condvar,
}

#[derive(Default)]
struct TokState {
    woken: bool,
    /// True while the owning thread has decremented `active` and parked.
    passive: bool,
}

impl Token {
    pub fn new() -> Arc<Self> {
        Arc::new(Token { state: Mutex::new(TokState::default()), cv: Condvar::new() })
    }
}

impl Default for Token {
    fn default() -> Self {
        Token { state: Mutex::new(TokState::default()), cv: Condvar::new() }
    }
}

/// RAII guard from [`Clock::hold`]: releases its activity credit on drop.
pub struct ClockHold {
    clock: Arc<Clock>,
}

impl Drop for ClockHold {
    fn drop(&mut self) {
        self.clock.enter_passive();
    }
}

enum Action {
    Wake(Arc<Token>),
    /// Runs on the clock thread at quiescence; must not block on sim
    /// primitives.  Used for network delivery completions.
    Call(Box<dyn FnOnce() + Send>),
}

struct EventEntry {
    at: VNanos,
    seq: u64,
    action: Action,
}

impl PartialEq for EventEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for EventEntry {}
impl PartialOrd for EventEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EventEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

struct ClockState {
    events: BinaryHeap<Reverse<EventEntry>>,
    seq: u64,
    stopped: bool,
}

/// Virtual clock shared by every thread of a simulated cluster.
pub struct Clock {
    state: Mutex<ClockState>,
    tick_cv: Condvar,
    now: AtomicU64,
    /// Threads currently running or runnable (see module docs).
    active: AtomicUsize,
    /// Threads registered with the clock (diagnostics only).
    registered: AtomicUsize,
    /// Set when quiescence is reached with no pending events.
    deadlocked: AtomicBool,
    panic_on_deadlock: AtomicBool,
}

impl Clock {
    /// Create the clock and start its driver thread.
    pub fn start() -> (Arc<Clock>, JoinHandle<()>) {
        let clock = Arc::new(Clock {
            state: Mutex::new(ClockState {
                events: BinaryHeap::new(),
                seq: 0,
                stopped: false,
            }),
            tick_cv: Condvar::new(),
            now: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            registered: AtomicUsize::new(0),
            deadlocked: AtomicBool::new(false),
            panic_on_deadlock: AtomicBool::new(true),
        });
        let c = clock.clone();
        let handle = std::thread::Builder::new()
            .name("sim-clock".into())
            .spawn(move || c.run())
            .expect("spawn clock thread");
        (clock, handle)
    }

    /// Current virtual time in ns.
    pub fn now(&self) -> VNanos {
        self.now.load(Ordering::Acquire)
    }

    /// Whether a global deadlock was detected.
    pub fn deadlocked(&self) -> bool {
        self.deadlocked.load(Ordering::Acquire)
    }

    /// Configure deadlock behaviour: panic (default) or set a flag and halt.
    pub fn set_panic_on_deadlock(&self, panic: bool) {
        self.panic_on_deadlock.store(panic, Ordering::Release);
    }

    /// A thread joins the simulation (it is active from now on).
    pub fn register_thread(&self) {
        self.registered.fetch_add(1, Ordering::AcqRel);
        self.active.fetch_add(1, Ordering::AcqRel);
    }

    /// A thread leaves the simulation for good.
    pub fn deregister_thread(&self) {
        self.registered.fetch_sub(1, Ordering::AcqRel);
        self.enter_passive();
    }

    /// Keep the clock from advancing (and from declaring deadlock) while
    /// an orchestrating thread is still wiring the simulation up: workers
    /// may already be parked before any registered thread exists, which
    /// would otherwise look like quiescence.
    pub fn hold(self: &Arc<Self>) -> ClockHold {
        self.active.fetch_add(1, Ordering::AcqRel);
        ClockHold { clock: self.clone() }
    }

    /// Stop the clock thread (call after all sim threads exited/parked).
    pub fn stop(&self) {
        let mut st = self.state.lock().unwrap();
        st.stopped = true;
        self.tick_cv.notify_all();
    }

    fn enter_passive(&self) {
        if self.active.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Possibly quiescent: nudge the clock thread. Lock + notify so
            // the wake-up cannot be missed between its check and wait.
            let _g = self.state.lock().unwrap();
            self.tick_cv.notify_all();
        }
    }

    /// Wake a token (activity transfer: the waker credits the wakee).
    pub fn wake(&self, token: &Token) {
        let mut st = token.state.lock().unwrap();
        if st.woken {
            return; // already woken (idempotent)
        }
        st.woken = true;
        if st.passive {
            self.active.fetch_add(1, Ordering::AcqRel);
        }
        token.cv.notify_one();
    }

    /// Park until the token is woken. The caller must be an active,
    /// registered sim thread.
    pub fn passive_wait(&self, token: &Token) {
        let mut st = token.state.lock().unwrap();
        if st.woken {
            return; // fast path: never went passive, no accounting
        }
        st.passive = true;
        drop(st);
        self.enter_passive();
        let mut st = token.state.lock().unwrap();
        while !st.woken {
            st = token.cv.wait(st).unwrap();
        }
        st.passive = false;
        // The waker incremented `active` on our behalf.
    }

    /// Schedule `token` to be woken at absolute virtual time `at`.
    pub fn schedule_wake(&self, at: VNanos, token: Arc<Token>) {
        self.push_event(at, Action::Wake(token));
    }

    /// Schedule `f` to run on the clock thread at virtual time `at`.
    /// `f` must not block on sim primitives (it may call [`Clock::wake`]).
    pub fn call_at(&self, at: VNanos, f: impl FnOnce() + Send + 'static) {
        self.push_event(at, Action::Call(Box::new(f)));
    }

    fn push_event(&self, at: VNanos, action: Action) {
        let mut st = self.state.lock().unwrap();
        let seq = st.seq;
        st.seq += 1;
        let at = at.max(self.now());
        st.events.push(Reverse(EventEntry { at, seq, action }));
        // A new event may unblock a quiescent clock.
        self.tick_cv.notify_all();
    }

    /// Record `ns` of virtual CPU cost for the calling thread without
    /// parking. The debt is folded into the next [`Clock::work`] /
    /// [`Clock::flush_debt`] on this thread — this keeps high-frequency
    /// costs (task spawns, scheduling) from generating one clock event
    /// each.
    pub fn add_debt(ns: VNanos) {
        DEBT.with(|d| d.set(d.get() + ns));
    }

    /// Take and reset the calling thread's accumulated debt.
    pub fn take_debt() -> VNanos {
        DEBT.with(|d| d.replace(0))
    }

    /// Park for the thread's accumulated debt, if any.
    pub fn flush_debt(&self) {
        let d = Self::take_debt();
        if d > 0 {
            self.work_exact(d);
        }
    }

    /// Advance virtual time by `d` plus any accumulated debt for the
    /// calling thread ("do d ns of work on my virtual core"). The thread
    /// parks; the clock advances once everyone else is passive too.
    pub fn work(&self, d: VNanos) {
        let d = d + Self::take_debt();
        self.work_exact(d);
    }

    fn work_exact(&self, d: VNanos) {
        if d == 0 {
            return;
        }
        let token = Token::new();
        self.schedule_wake(self.now() + d, token.clone());
        self.passive_wait(&token);
    }

    /// Alias of [`Clock::work`] with sleep naming for timers.
    pub fn sleep(&self, d: VNanos) {
        self.work(d);
    }

    /// Clock driver loop.
    fn run(&self) {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.stopped {
                // Fire actions already due at the current instant before
                // exiting (e.g. sharded-delivery drains scheduled at the
                // final instant): `stop` may race the last quiescence
                // pass, and a straggler continuation must not be lost.
                // Future-time events are still discarded, as before.
                let now = self.now();
                let mut due = Vec::new();
                while let Some(Reverse(e)) = st.events.peek() {
                    if e.at > now {
                        break;
                    }
                    due.push(st.events.pop().unwrap().0);
                }
                if due.is_empty() {
                    return;
                }
                drop(st);
                for e in due {
                    match e.action {
                        Action::Wake(tok) => self.wake(&tok),
                        Action::Call(f) => f(),
                    }
                }
                st = self.state.lock().unwrap();
                continue;
            }
            if self.active.load(Ordering::Acquire) == 0 {
                // Quiescent. Fire the earliest batch or report deadlock.
                if let Some(Reverse(head)) = st.events.peek() {
                    let t = head.at;
                    self.now.store(t, Ordering::Release);
                    let mut batch = Vec::new();
                    while let Some(Reverse(e)) = st.events.peek() {
                        if e.at > t {
                            break;
                        }
                        batch.push(st.events.pop().unwrap().0);
                    }
                    drop(st);
                    for e in batch {
                        match e.action {
                            Action::Wake(tok) => self.wake(&tok),
                            Action::Call(f) => f(),
                        }
                    }
                    st = self.state.lock().unwrap();
                    continue;
                } else if self.registered.load(Ordering::Acquire) > 0 {
                    // Threads exist, none can run, nothing scheduled.
                    self.deadlocked.store(true, Ordering::Release);
                    if self.panic_on_deadlock.load(Ordering::Acquire) {
                        panic!(
                            "sim::Clock deadlock: {} registered threads are all \
                             passive with no pending events (t={} ns). This is \
                             the Section-5 scenario: blocking operations inside \
                             tasks with no progress mechanism.",
                            self.registered.load(Ordering::Acquire),
                            self.now()
                        );
                    }
                    // Halt quietly: leave threads parked, wait for stop().
                    while !st.stopped {
                        st = self.tick_cv.wait(st).unwrap();
                    }
                    return;
                }
            }
            st = self.tick_cv.wait(st).unwrap();
        }
    }
}
