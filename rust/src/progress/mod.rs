//! `progress` — the sharded progress engine: completion delivery as a
//! subsystem of its own.
//!
//! PR 1 replaced TAMPI's poll-scan with push continuations
//! ([`crate::rmpi::Request::on_complete`]), but every completion still
//! funnelled through two per-runtime globals: continuations fired inline
//! inside [`ReqState::complete`](crate::rmpi::request::ReqState), and each
//! resulting task resume took the scheduler mutex once. A same-instant
//! completion wave — an alltoallv landing on a thousand-rank virtual
//! cluster — therefore serialized on one lock, once per request.
//!
//! This module removes that last global serialization point with the
//! pipeline **shard → batch → bulk-enqueue**:
//!
//! 1. **Per-rank completion shards** ([`Shard`]). Every request created
//!    through a [`Comm`](crate::rmpi::Comm) on a
//!    [`DeliveryMode::Sharded`] universe is stamped with the shard of its
//!    *owning* rank (the rank that posted the receive / issued the send).
//!    [`ReqState::complete`](crate::rmpi::request::ReqState) — whether it
//!    runs inline on a rank thread or deferred on the clock thread via
//!    `Clock::call_at` — deposits the request's continuations into that
//!    shard instead of firing them under global state. A wildcard-source
//!    receive is routed by its poster, not by whichever thread happens to
//!    deliver the matching message.
//! 2. **Batched wave delivery.** Deposits landing at the same virtual
//!    instant accumulate in the shard; the first deposit schedules one
//!    drain event at that instant, so a collective's completion wave is
//!    drained as a single batch per shard (traced as
//!    [`EventKind::BatchDelivered`](crate::trace::EventKind)).
//! 3. **Bulk enqueue.** While a batch drains, task resumes produced by the
//!    continuations are collected (a thread-local scope in
//!    [`crate::nanos::scheduler`]) and handed to each runtime's scheduler
//!    as one bulk insert that takes the scheduler lock once per
//!    shard-batch instead of once per continuation. The scheduler's
//!    per-worker ready deques + shared injector (work stealing) spread the
//!    resulting burst across workers without re-serializing it.
//!
//! The shape follows the paper's Sections 4.1/4.4 (pause/resume is the
//! delivery target; core licensing is preserved end-to-end) and the MPI
//! Continuations line of work: Schuchart et al. (arXiv:2112.11978) argue
//! completion callbacks deserve a dedicated, decoupled notification
//! engine rather than ad-hoc firing inside the communication path, and
//! Zhou et al., *MPI Progress For All* (arXiv:2405.13807) make the case
//! for explicit, parallelizable progress domains — here, one domain per
//! virtual rank.
//!
//! [`DeliveryMode::Direct`] preserves the PR-1 baseline (continuations
//! fire inline at the completion point, one scheduler-lock acquisition
//! per resume) for figure runs and A/B tests; both modes produce
//! identical application results and identical virtual times — only the
//! lock traffic differs (see `bench::completion_wave`).
//!
//! The engine also *drives collectives*: every collective compiles into
//! a schedule of rounds ([`crate::rmpi::coll_schedule`]) whose advance
//! continuations ride this same pipeline — under `Sharded` delivery a
//! round's completion wave lands as one shard batch whose drain posts
//! the next round (and coalesces same-task external-event decrements
//! into one `dec_events(n)`), tying the paper's Section 4.6 event
//! counters and Section 6.1 collective interception to the shard →
//! batch → bulk-enqueue pipeline.

pub mod shard;

use std::sync::Arc;

use crate::trace::Tracer;

pub use shard::{Shard, ShardStats};

/// How completion continuations reach the scheduler.
///
/// Selectable alongside [`crate::nanos::CompletionMode`] (which chooses
/// *whether* completions are discovered by poll-scan or pushed by
/// continuations); this knob chooses *how* pushed continuations are
/// delivered. Set via `ClusterConfig::delivery_mode` /
/// `with_delivery_mode`, or `repro ... --delivery direct|sharded`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeliveryMode {
    /// PR-1 baseline: continuations fire inline at the completion point;
    /// every task resume takes the scheduler lock individually.
    Direct,
    /// Sharded progress engine: continuations are deposited into the
    /// owning rank's shard, drained in same-instant batches, and their
    /// resumes bulk-enqueued (one scheduler-lock acquisition per
    /// shard-batch).
    #[default]
    Sharded,
}

/// Aggregate delivery statistics over all shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Batches drained (one scheduler bulk-enqueue each).
    pub batches: u64,
    /// Continuations delivered through shards.
    pub delivered: u64,
    /// Largest single batch.
    pub max_batch: u64,
}

/// One universe's progress engine: a [`Shard`] per virtual rank (empty
/// under [`DeliveryMode::Direct`], where requests stay unrouted and
/// continuations fire inline).
pub struct ProgressEngine {
    mode: DeliveryMode,
    shards: Vec<Arc<Shard>>,
}

impl ProgressEngine {
    /// Build the engine for a `ranks`-rank universe. The tracer, when
    /// present, receives one `EventKind::BatchDelivered` record per
    /// drained batch.
    pub fn new(
        ranks: usize,
        mode: DeliveryMode,
        tracer: Option<Arc<Tracer>>,
    ) -> Arc<ProgressEngine> {
        let shards = match mode {
            DeliveryMode::Direct => Vec::new(),
            DeliveryMode::Sharded => (0..ranks.max(1))
                .map(|r| Arc::new(Shard::new(r as u32, tracer.clone())))
                .collect(),
        };
        Arc::new(ProgressEngine { mode, shards })
    }

    pub fn mode(&self) -> DeliveryMode {
        self.mode
    }

    /// Number of shards (0 under [`DeliveryMode::Direct`]).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning `rank`'s completions; `None` under `Direct`.
    pub(crate) fn shard_for(&self, rank: usize) -> Option<Arc<Shard>> {
        self.shards.get(rank).cloned()
    }

    /// Delivery statistics of one rank's shard (zeros under `Direct`).
    pub fn shard_stats(&self, rank: usize) -> ShardStats {
        self.shards
            .get(rank)
            .map(|s| s.stats())
            .unwrap_or_default()
    }

    /// Aggregate statistics across all shards.
    pub fn stats(&self) -> EngineStats {
        let mut agg = EngineStats::default();
        for s in &self.shards {
            let st = s.stats();
            agg.batches += st.batches;
            agg.delivered += st.delivered;
            agg.max_batch = agg.max_batch.max(st.max_batch);
        }
        agg
    }
}

impl std::fmt::Debug for ProgressEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "ProgressEngine {{ mode: {:?}, shards: {}, batches: {}, delivered: {} }}",
            self.mode,
            self.shards.len(),
            s.batches,
            s.delivered
        )
    }
}
