//! Per-rank completion shards: deposit → same-instant batch → drain.
//!
//! A shard owns the pending (continuation, status) pairs of one virtual
//! rank. [`Shard::deposit`] is called by
//! [`ReqState::complete`](crate::rmpi::request::ReqState) from whichever
//! thread delivers the completion — a rank main, a worker, or the clock
//! thread for deferred network deliveries. The first deposit at a given
//! virtual instant schedules exactly one drain event *at that same
//! instant* (`Clock::call_at` clamps to `now`), so every completion of a
//! same-instant wave that lands before the drain fires is folded into one
//! batch. Virtual time cannot advance past the instant while the drain
//! event is pending, so batching never delays a notification in virtual
//! time — it only amortizes real lock traffic.
//!
//! The drain runs on the clock thread: it opens a
//! [`DeferredEnqueue`](crate::nanos::scheduler::DeferredEnqueue) scope,
//! fires the batch's continuations (which call `nanos::unblock_task` /
//! `decrease_task_event_counter` as usual), and then hands the collected
//! task resumes to each runtime's scheduler as one bulk insert — the
//! scheduler lock is taken once per shard-batch, not once per
//! continuation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::nanos::scheduler::DeferredEnqueue;
use crate::rmpi::request::Continuation;
use crate::rmpi::Status;
use crate::sim::{Clock, VNanos};
use crate::trace::{EventKind, Record, Tracer};

/// Delivery statistics of one shard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Batches drained.
    pub batches: u64,
    /// Continuations delivered.
    pub delivered: u64,
    /// Largest single batch.
    pub max_batch: u64,
}

/// One virtual rank's completion shard.
pub struct Shard {
    rank: u32,
    tracer: Option<Arc<Tracer>>,
    /// Continuations deposited but not yet drained, each with the final
    /// status of its request. Non-empty exactly while a drain event is
    /// pending on the clock.
    pending: Mutex<Vec<(Continuation, Status)>>,
    batches: AtomicU64,
    delivered: AtomicU64,
    max_batch: AtomicU64,
}

impl Shard {
    pub(crate) fn new(rank: u32, tracer: Option<Arc<Tracer>>) -> Shard {
        Shard {
            rank,
            tracer,
            pending: Mutex::new(Vec::new()),
            batches: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
        }
    }

    /// Virtual rank this shard serves.
    pub fn rank(&self) -> u32 {
        self.rank
    }

    pub fn stats(&self) -> ShardStats {
        ShardStats {
            batches: self.batches.load(Ordering::Acquire),
            delivered: self.delivered.load(Ordering::Acquire),
            max_batch: self.max_batch.load(Ordering::Acquire),
        }
    }

    /// Deposit a completed request's continuations for batched delivery.
    /// The first deposit into an empty shard schedules one drain at the
    /// current virtual instant; later same-instant deposits ride along.
    pub(crate) fn deposit(self: &Arc<Self>, clock: &Clock, cbs: Vec<Continuation>, st: Status) {
        debug_assert!(!cbs.is_empty(), "empty deposit");
        let schedule = {
            let mut g = self.pending.lock().unwrap();
            let was_empty = g.is_empty();
            g.extend(cbs.into_iter().map(|f| (f, st)));
            was_empty
        };
        if schedule {
            let shard = self.clone();
            let at = clock.now();
            clock.call_at(at, move || shard.drain(at));
        }
    }

    /// Drain everything deposited for one virtual instant as one batch.
    /// Runs on the clock thread (`Clock::call_at` contract: must not park
    /// on sim primitives — and does not).
    fn drain(&self, at: VNanos) {
        let batch = std::mem::take(&mut *self.pending.lock().unwrap());
        if batch.is_empty() {
            return;
        }
        let count = batch.len() as u64;
        // Publish stats and the trace record *before* firing: a rank
        // thread woken by a continuation below (e.g. taskwait returning)
        // must already observe this batch in the shard's counters.
        self.batches.fetch_add(1, Ordering::AcqRel);
        self.delivered.fetch_add(count, Ordering::AcqRel);
        self.max_batch.fetch_max(count, Ordering::AcqRel);
        if let Some(tr) = &self.tracer {
            tr.emit(Record {
                t: at,
                rank: self.rank,
                // Annotation record from the clock thread (see
                // `trace::Record::worker` sentinel docs).
                worker: u32::MAX,
                kind: EventKind::BatchDelivered { shard: self.rank, count: count as u32 },
                label: format!("{count} completions"),
                task_id: 0,
            });
        }
        let scope = DeferredEnqueue::begin();
        for (f, st) in batch {
            f(st);
        }
        for (rt, items) in scope.finish() {
            rt.sched.enqueue_bulk(items, &rt);
        }
    }
}

impl std::fmt::Debug for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "Shard {{ rank: {}, batches: {}, delivered: {}, max_batch: {} }}",
            self.rank, s.batches, s.delivered, s.max_batch
        )
    }
}
