//! Per-rank completion shards: deposit → same-instant batch → drain.
//!
//! A shard owns the pending (continuation, status) pairs of one virtual
//! rank. [`Shard::deposit`] is called by
//! [`ReqState::complete`](crate::rmpi::request::ReqState) from whichever
//! thread delivers the completion — a rank main, a worker, or the clock
//! thread for deferred network deliveries. The first deposit at a given
//! virtual instant schedules exactly one drain event *at that same
//! instant* (`Clock::call_at` clamps to `now`), so every completion of a
//! same-instant wave that lands before the drain fires is folded into one
//! batch. Virtual time cannot advance past the instant while the drain
//! event is pending, so batching never delays a notification in virtual
//! time — it only amortizes real lock traffic.
//!
//! The pending list is a **lock-free MPSC stack** (Treiber push from the
//! depositors — rank threads plus the clock thread — single-consumer
//! swap in the drain): the completion hot path's last lock is gone; a
//! deposit is one CAS per continuation. The empty→non-empty transition
//! (the CAS that observed a null head) is what schedules the drain, so
//! exactly one drain event exists per batch — the same protocol the
//! previous mutexed Vec used, with identical observable counts
//! ([`ShardStats`]).
//!
//! The drain runs on the clock thread: it opens a
//! [`DeferredEnqueue`](crate::nanos::scheduler::DeferredEnqueue) scope
//! *and* a [`DeferredEventDecs`](crate::nanos::api) scope, fires the
//! batch's continuations (which call `nanos::unblock_task` /
//! `decrease_task_event_counter` as usual), applies the coalesced
//! per-task event decrements (one `dec_events(n)` per task per wave —
//! collective completion waves routinely fulfil many events of one
//! task), and then hands the collected task resumes to each runtime's
//! scheduler as one bulk insert — the scheduler lock is taken once per
//! shard-batch, not once per continuation.

use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::Arc;

use crate::nanos::api::DeferredEventDecs;
use crate::nanos::scheduler::DeferredEnqueue;
use crate::rmpi::request::Continuation;
use crate::rmpi::Status;
use crate::sim::{Clock, VNanos};
use crate::trace::{EventKind, Record, Tracer};

/// Delivery statistics of one shard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Batches drained.
    pub batches: u64,
    /// Continuations delivered.
    pub delivered: u64,
    /// Largest single batch.
    pub max_batch: u64,
}

/// One node of the pending stack.
struct Node {
    cont: Continuation,
    st: Status,
    next: *mut Node,
}

thread_local! {
    /// Reusable drain scratch. Drains run on the clock thread and fire
    /// actions strictly sequentially (no reentrancy: a continuation's
    /// deposit schedules a *new* event, it never drains inline), so one
    /// buffer per thread suffices and its capacity is retained across
    /// batches instead of reallocating per drain.
    static DRAIN_SCRATCH: std::cell::RefCell<Vec<(Continuation, Status)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// One virtual rank's completion shard.
pub struct Shard {
    rank: u32,
    tracer: Option<Arc<Tracer>>,
    /// Head of the lock-free pending stack (LIFO; the drain reverses to
    /// deposit order). Non-null exactly while a drain event is pending
    /// on the clock.
    pending: AtomicPtr<Node>,
    batches: AtomicU64,
    delivered: AtomicU64,
    max_batch: AtomicU64,
}

impl Shard {
    pub(crate) fn new(rank: u32, tracer: Option<Arc<Tracer>>) -> Shard {
        Shard {
            rank,
            tracer,
            pending: AtomicPtr::new(ptr::null_mut()),
            batches: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
        }
    }

    /// Virtual rank this shard serves.
    pub fn rank(&self) -> u32 {
        self.rank
    }

    pub fn stats(&self) -> ShardStats {
        ShardStats {
            batches: self.batches.load(Ordering::Acquire),
            delivered: self.delivered.load(Ordering::Acquire),
            max_batch: self.max_batch.load(Ordering::Acquire),
        }
    }

    /// Deposit a completed request's continuations for batched delivery.
    /// Lock-free: one CAS push per continuation; the push that turned
    /// the stack non-empty schedules one drain at the current virtual
    /// instant; later same-instant deposits ride along.
    pub(crate) fn deposit(self: &Arc<Self>, clock: &Clock, cbs: Vec<Continuation>, st: Status) {
        debug_assert!(!cbs.is_empty(), "empty deposit");
        let mut schedule = false;
        for cont in cbs {
            let node = Box::into_raw(Box::new(Node { cont, st, next: ptr::null_mut() }));
            loop {
                let head = self.pending.load(Ordering::Acquire);
                // SAFETY: `node` is ours until the CAS publishes it.
                unsafe { (*node).next = head };
                if self
                    .pending
                    .compare_exchange_weak(head, node, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    if head.is_null() {
                        schedule = true;
                    }
                    break;
                }
            }
        }
        if schedule {
            let shard = self.clone();
            let at = clock.now();
            clock.call_at(at, move || shard.drain(at));
        }
    }

    /// Drain everything deposited for one virtual instant as one batch.
    /// Runs on the clock thread (`Clock::call_at` contract: must not park
    /// on sim primitives — and does not). Single consumer: one atomic
    /// swap detaches the whole stack.
    fn drain(&self, at: VNanos) {
        let mut head = self.pending.swap(ptr::null_mut(), Ordering::AcqRel);
        if head.is_null() {
            return;
        }
        // Reverse the LIFO chain back into deposit order, reusing the
        // thread's scratch buffer (capacity survives across batches).
        let mut batch: Vec<(Continuation, Status)> =
            DRAIN_SCRATCH.with(|s| std::mem::take(&mut *s.borrow_mut()));
        debug_assert!(batch.is_empty());
        while !head.is_null() {
            // SAFETY: detached exclusively by the swap above.
            let node = unsafe { Box::from_raw(head) };
            head = node.next;
            batch.push((node.cont, node.st));
        }
        batch.reverse();
        let count = batch.len() as u64;
        // Publish stats and the trace record *before* firing: a rank
        // thread woken by a continuation below (e.g. taskwait returning)
        // must already observe this batch in the shard's counters.
        self.batches.fetch_add(1, Ordering::AcqRel);
        self.delivered.fetch_add(count, Ordering::AcqRel);
        self.max_batch.fetch_max(count, Ordering::AcqRel);
        if let Some(tr) = &self.tracer {
            tr.emit(Record {
                t: at,
                rank: self.rank,
                // Annotation record from the clock thread (see
                // `trace::Record::worker` sentinel docs).
                worker: u32::MAX,
                kind: EventKind::BatchDelivered { shard: self.rank, count: count as u32 },
                label: format!("{count} completions"),
                task_id: 0,
            });
        }
        let scope = DeferredEnqueue::begin();
        let decs = DeferredEventDecs::begin();
        for (f, st) in batch.drain(..) {
            f(st);
        }
        DRAIN_SCRATCH.with(|s| *s.borrow_mut() = batch);
        // Apply coalesced event decrements first: a released successor's
        // enqueue must join the bulk insert below.
        decs.finish();
        for (rt, items) in scope.finish() {
            rt.sched.enqueue_bulk(items, &rt);
        }
    }
}

impl Drop for Shard {
    fn drop(&mut self) {
        // Free any undrained nodes (teardown with a pending batch).
        let mut head = self.pending.swap(ptr::null_mut(), Ordering::AcqRel);
        while !head.is_null() {
            // SAFETY: exclusive access in Drop.
            let node = unsafe { Box::from_raw(head) };
            head = node.next;
        }
    }
}

impl std::fmt::Debug for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "Shard {{ rank: {}, batches: {}, delivered: {}, max_batch: {} }}",
            self.rank, s.batches, s.delivered, s.max_batch
        )
    }
}
