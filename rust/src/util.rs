//! Shared utilities: deterministic PRNG and stats helpers.

/// SplitMix64 — deterministic, dependency-free PRNG for tests/benches.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_f64_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = SplitMix64::new(3);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }
}
