//! Dependency-free stand-in for the PJRT bridge (default build).
//!
//! The reproduction host's registry is offline, so the default build
//! carries no external crates; the real XLA-backed bridge in `pjrt.rs`
//! compiles only with the `pjrt` feature (which requires vendoring the
//! `xla` and `anyhow` crates). The stub keeps the full API surface:
//! every load fails with a clean error naming the artifact — exactly
//! the behaviour of a missing `make artifacts`. Callers decide what
//! that means: the PJRT tests skip themselves, while an app run that
//! explicitly requests `Compute::Pjrt` aborts with the error (use
//! `Compute::Native`/`Compute::Model` in stub builds).

use std::fmt;

/// Error type of the stub bridge (API-compatible with `anyhow::Error`
/// for the operations the apps and tests exercise: `Display`, `Debug`,
/// `std::error::Error`).
pub struct PjrtError(String);

impl fmt::Display for PjrtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for PjrtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for PjrtError {}

pub type Result<T> = std::result::Result<T, PjrtError>;

fn unavailable(name: &str) -> PjrtError {
    PjrtError(format!(
        "loading artifact {name} from {}: this build has no XLA/PJRT backend (the \
         `pjrt` feature is disabled); use Compute::Native or Compute::Model",
        super::artifacts_dir().join(format!("{name}.hlo.txt")).display()
    ))
}

/// A compiled artifact (stub: never successfully constructed).
pub struct LoadedExe {
    pub name: String,
}

impl LoadedExe {
    /// API parity with the real bridge; unreachable in stub builds
    /// because [`load`] never hands out a `LoadedExe`.
    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable(&self.name))
    }
}

/// Load + compile an artifact by name — always fails in stub builds,
/// with an error naming the artifact and the path that would be read.
pub fn load(name: &str) -> Result<&'static LoadedExe> {
    Err(unavailable(name))
}

/// Typed wrapper for the Gauss-Seidel block kernel artifact.
pub struct GsKernel {
    pub block: usize,
}

impl GsKernel {
    /// Always fails in stub builds (see [`load`]).
    pub fn load(block: usize) -> Result<GsKernel> {
        Err(unavailable(&format!("gs_block_{block}")))
    }

    /// API parity; unreachable in stub builds.
    pub fn sweep(
        &self,
        _u: &[f32],
        _top: &[f32],
        _bottom: &[f32],
        _left: &[f32],
        _right: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        Err(unavailable(&format!("gs_block_{}", self.block)))
    }
}

/// Typed wrapper for the IFSKer timestep artifact.
pub struct IfsKernel {
    pub nf: usize,
    pub n: usize,
}

impl IfsKernel {
    /// Always fails in stub builds (see [`load`]).
    pub fn load(nf: usize, n: usize) -> Result<IfsKernel> {
        Err(unavailable(&format!("ifs_step_f{nf}_n{n}")))
    }

    /// API parity; unreachable in stub builds.
    pub fn step(&self, _fields: &[f32]) -> Result<(Vec<f32>, f32)> {
        Err(unavailable(&format!("ifs_step_f{}_n{}", self.nf, self.n)))
    }
}
