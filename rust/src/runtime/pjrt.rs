//! Real XLA/PJRT backend (compiled only with the `pjrt` feature).
//!
//! Requires the `xla` and `anyhow` crates to be vendored and listed in
//! `[dependencies]`; the default build uses [`super::stub`] instead.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Mutex, OnceLock};

use anyhow::{Context, Result};

use super::artifacts_dir;

struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, &'static LoadedExe>>,
    /// Serializes every PJRT call (compile/execute/transfer). The xla
    /// crate uses `Rc` internally, so cross-thread use is only sound if
    /// all operations (including internal clones/drops) are mutually
    /// excluded — which this lock guarantees. The host has one physical
    /// core, so serialization costs nothing.
    pjrt_lock: Mutex<()>,
}

// SAFETY: all accesses to the Rc-based internals go through `pjrt_lock`
// (see `LoadedExe::run_f32` and `load`); objects are never dropped.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

/// A compiled artifact. Leaked into 'static so executables can be shared
/// freely across threads for the process lifetime.
pub struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

// SAFETY: see Engine (all PJRT calls serialize on the engine lock).
unsafe impl Send for LoadedExe {}
unsafe impl Sync for LoadedExe {}

static ENGINE: OnceLock<Engine> = OnceLock::new();

fn engine() -> &'static Engine {
    ENGINE.get_or_init(|| Engine {
        client: xla::PjRtClient::cpu().expect("PJRT CPU client"),
        cache: Mutex::new(HashMap::new()),
        pjrt_lock: Mutex::new(()),
    })
}

/// Load + compile an artifact by name (e.g. `gs_block_256`), cached.
pub fn load(name: &str) -> Result<&'static LoadedExe> {
    let eng = engine();
    let mut cache = eng.cache.lock().unwrap();
    if let Some(&e) = cache.get(name) {
        // Copy the 'static inner reference out of the guard-borrowed
        // map entry (a bare `Ok(e)` would borrow from the guard).
        return Ok(e);
    }
    let path = artifacts_dir().join(format!("{name}.hlo.txt"));
    let _g = eng.pjrt_lock.lock().unwrap();
    let exe = compile(&eng.client, &path)
        .with_context(|| format!("loading artifact {name} from {}", path.display()))?;
    drop(_g);
    let leaked: &'static LoadedExe = Box::leak(Box::new(LoadedExe {
        exe,
        name: name.to_string(),
    }));
    cache.insert(name.to_string(), leaked);
    Ok(leaked)
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .map_err(|e| anyhow::anyhow!("parse HLO text: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("PJRT compile: {e:?}"))
}

impl LoadedExe {
    /// Execute with f32 inputs of the given shapes; returns the tuple
    /// elements as flat f32 vectors (artifacts are lowered with
    /// `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let _g = engine().pjrt_lock.lock().unwrap();
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let lit = if dims.len() == 1 {
                lit
            } else {
                lit.reshape(dims)
                    .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?
            };
            lits.push(lit);
        }
        let out = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        drop(_g);
        parts
            .iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Typed wrapper for the Gauss-Seidel block kernel artifact.
pub struct GsKernel {
    exe: &'static LoadedExe,
    pub block: usize,
}

impl GsKernel {
    /// Load `gs_block_{block}` (block ∈ {32, 64, 128, 256, 512}).
    pub fn load(block: usize) -> Result<GsKernel> {
        Ok(GsKernel { exe: load(&format!("gs_block_{block}"))?, block })
    }

    /// One sweep: returns (new block, sum of squared change).
    pub fn sweep(
        &self,
        u: &[f32],
        top: &[f32],
        bottom: &[f32],
        left: &[f32],
        right: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        let b = self.block;
        assert_eq!(u.len(), b * b);
        assert!(top.len() == b && bottom.len() == b && left.len() == b && right.len() == b);
        let bi = b as i64;
        let out = self.exe.run_f32(&[
            (u, &[bi, bi][..]),
            (top, &[bi][..]),
            (bottom, &[bi][..]),
            (left, &[bi][..]),
            (right, &[bi][..]),
        ])?;
        anyhow::ensure!(out.len() == 2, "gs artifact must return (block, delta)");
        let delta = out[1][0];
        let mut it = out.into_iter();
        Ok((it.next().unwrap(), delta))
    }
}

/// Typed wrapper for the IFSKer timestep artifact.
///
/// The DFT transform matrices travel as runtime arguments (HLO text
/// elides large constants — see aot.py); they are loaded once from the
/// `ifs_consts_n{n}.bin` side file aot.py emits.
pub struct IfsKernel {
    exe: &'static LoadedExe,
    pub nf: usize,
    pub n: usize,
    ft: Vec<f32>,
    finvt: Vec<f32>,
    damp: Vec<f32>,
}

impl IfsKernel {
    /// Load `ifs_step_f{nf}_n{n}` plus its constants (aot.py IFS_SIZES).
    pub fn load(nf: usize, n: usize) -> Result<IfsKernel> {
        let exe = load(&format!("ifs_step_f{nf}_n{n}"))?;
        let cpath = artifacts_dir().join(format!("ifs_consts_n{n}.bin"));
        let bytes = std::fs::read(&cpath)
            .with_context(|| format!("reading {}", cpath.display()))?;
        let want = (2 * n * n + n) * 4;
        anyhow::ensure!(
            bytes.len() == want,
            "ifs consts size {} != {}",
            bytes.len(),
            want
        );
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let ft = floats[0..n * n].to_vec();
        let finvt = floats[n * n..2 * n * n].to_vec();
        let damp = floats[2 * n * n..].to_vec();
        Ok(IfsKernel { exe, nf, n, ft, finvt, damp })
    }

    /// One timestep over the field chunk; returns (fields, l2 norm).
    pub fn step(&self, fields: &[f32]) -> Result<(Vec<f32>, f32)> {
        assert_eq!(fields.len(), self.nf * self.n);
        let ni = self.n as i64;
        let out = self.exe.run_f32(&[
            (fields, &[self.nf as i64, ni][..]),
            (&self.ft, &[ni, ni][..]),
            (&self.finvt, &[ni, ni][..]),
            (&self.damp, &[ni][..]),
        ])?;
        anyhow::ensure!(out.len() == 2, "ifs artifact must return (fields, norm)");
        let norm = out[1][0];
        let mut it = out.into_iter();
        Ok((it.next().unwrap(), norm))
    }
}
