//! PJRT bridge: load AOT-compiled HLO artifacts and execute them from the
//! Rust hot path.
//!
//! `make artifacts` (Python, build-time only) lowers the JAX/Pallas compute
//! graphs to HLO *text* (see `python/compile/aot.py` for why text, not
//! serialized protos). This module compiles them once on the PJRT CPU
//! client and exposes typed entry points for the apps:
//!
//! * [`GsKernel`] — one Gauss-Seidel sweep over a `(B, B)` block with four
//!   halo vectors -> `(new_block, delta)`.
//! * [`IfsKernel`] — one IFSKer timestep over an `(nf, n)` field chunk.
//!
//! Executables are compiled lazily and cached per (artifact, shape).
//! Execution is serialized per executable with a mutex: the harness host
//! has one physical core, so concurrency would only add contention.
//!
//! ## Build flavours
//!
//! The XLA/PJRT implementation lives in `pjrt.rs` behind the `pjrt`
//! cargo feature (its `xla`/`anyhow` dependencies must be vendored — the
//! reproduction host's registry is offline). The default build compiles
//! the API-compatible stub in `stub.rs` instead: loads fail cleanly
//! with an error naming the artifact, exactly as when `make artifacts`
//! has not run — PJRT tests skip themselves, and an app run explicitly
//! requesting `Compute::Pjrt` aborts with that error (use
//! `Compute::Native` or `Compute::Model` in stub builds).

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{load, GsKernel, IfsKernel, LoadedExe};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{load, GsKernel, IfsKernel, LoadedExe};

/// Root of the artifacts directory (override with env `TAMPI_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("TAMPI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Whether `artifact` can actually be loaded by this build: the real
/// backend must be compiled in (the `pjrt` feature — stub builds fail
/// every load by design, even when the files exist on disk) *and* the
/// artifact file must exist. Gate every optional PJRT code path on this,
/// not on file existence alone.
pub fn available(artifact: &str) -> bool {
    cfg!(feature = "pjrt") && artifacts_dir().join(format!("{artifact}.hlo.txt")).exists()
}
