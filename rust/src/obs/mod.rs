//! Observability: typed spans, a metrics registry, and the overlap
//! profiler for the task-aware runtime.
//!
//! The paper's headline claim (Sections 4–6) is that TAMPI "naturally
//! overlaps computation and communication phases". This subsystem makes
//! that claim *measurable* instead of inferable: every interesting
//! interval of a simulated run — task execution and task pause
//! (Section 4's pause/resume protocol), MPI operation lifetime from
//! post to completion (Section 5's blocking and Section 6's
//! non-blocking modes), collective schedule rounds, ingress-port busy
//! intervals, clock-lane lookahead waits, steal attempts — is deposited
//! as a typed [`Span`] into a per-thread bounded ring buffer.
//!
//! Design constraints, in order:
//!
//! 1. **Tracing must not perturb virtual time.** Every emission site
//!    only *reads* `Clock::now()`; none adds debt, schedules events, or
//!    blocks on sim primitives. A run with a [`SpanSink`] attached is
//!    bit-identical (checksum, vtime, counters) to the same run without
//!    one — asserted in `rust/tests/obs_spans.rs`.
//! 2. **Deposits never block.** Each thread owns its own ring
//!    ([`ThreadRing`]) registered once in the sink; the deposit path is
//!    a `try_lock` that can only ever contend with a snapshot reader
//!    (never with another depositor), and on contention the span is
//!    counted as dropped rather than waited for. Rings are bounded:
//!    when full the *oldest* span is evicted and counted.
//! 3. **Always-on metrics.** The [`metrics::Registry`] (counters,
//!    gauges, log2-bucket histograms) costs a handful of relaxed
//!    atomics per event and is therefore attached to every run,
//!    independent of span recording; its snapshot rides on
//!    `RunStats::metrics`.
//!
//! Consumers: [`perfetto::export`] renders a merged snapshot as a
//! Chrome/Perfetto `trace_event` JSON document (one track per
//! (rank, worker), per ingress port, per collective engine, and per
//! clock lane, with flow events linking send→matching-recv and
//! round→round); [`overlap::overlap_by_rank`] integrates the span
//! timeline into per-rank busy/comm/overlapped fractions — the fig20
//! quantification of the paper's central claim.

pub mod metrics;
pub mod overlap;
pub mod perfetto;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::VNanos;

use metrics::{Counter, Gauge, Hist, Registry};

/// What a span measures. The variants map onto the paper's phases:
/// `TaskExec`/`TaskPause` are Section 4's task lifecycle, `MpiCall` is
/// the in-task window of a (blocking) call, `MpiReq` is the full
/// post→completion lifetime of a request (Section 6's non-blocking
/// window), `CollRound` one advance of a compiled collective schedule,
/// `PortBusy` one message's receiver-processing interval on an ingress
/// port, `LaneWait` a clock lane stalled on a peer's conservative
/// lookahead bound, `Send`/`Deliver` the point endpoints of a message
/// flow, and `Steal` a successful work-steal.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum SpanKind {
    TaskExec,
    TaskPause,
    MpiCall,
    MpiReq,
    Send,
    Deliver,
    CollRound,
    PortBusy,
    LaneWait,
    Steal,
}

impl SpanKind {
    /// Stable category string (Perfetto `cat`, validator keys).
    pub fn cat(self) -> &'static str {
        match self {
            SpanKind::TaskExec => "task",
            SpanKind::TaskPause => "pause",
            SpanKind::MpiCall => "mpi",
            SpanKind::MpiReq => "req",
            SpanKind::Send => "send",
            SpanKind::Deliver => "deliver",
            SpanKind::CollRound => "coll",
            SpanKind::PortBusy => "port",
            SpanKind::LaneWait => "lane",
            SpanKind::Steal => "steal",
        }
    }
}

/// Timeline a span belongs to. Exported as one Perfetto track each:
/// workers (and the off-worker "main" lane, `worker == u32::MAX`) per
/// rank, the rank's ingress port, its collective engine, its in-flight
/// MPI requests, and the simulation clock's lanes.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Track {
    Worker { rank: u32, worker: u32 },
    Port { rank: u32 },
    Coll { rank: u32 },
    Reqs { rank: u32 },
    Lane { lane: u32 },
}

impl Track {
    /// Rank that owns the track (`None` for clock lanes).
    pub fn rank(self) -> Option<u32> {
        match self {
            Track::Worker { rank, .. }
            | Track::Port { rank }
            | Track::Coll { rank }
            | Track::Reqs { rank } => Some(rank),
            Track::Lane { .. } => None,
        }
    }
}

/// One recorded interval (or point, when `t0 == t1`) in virtual time.
/// `flow_in`/`flow_out` (0 = none) carry deterministic flow ids — see
/// [`fid`] — that the exporter turns into Perfetto flow arrows:
/// `flow_out` on the producing span matches `flow_in` on the consuming
/// one (send → matching recv delivery, collective round k → k+1).
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub track: Track,
    pub kind: SpanKind,
    pub t0: VNanos,
    pub t1: VNanos,
    /// Static label (task labels are not copied here; `id` carries the
    /// task/request identity instead, keeping `Span: Copy`).
    pub label: &'static str,
    /// Task id, request id, or round number — kind-dependent.
    pub id: u64,
    pub flow_in: u64,
    pub flow_out: u64,
}

impl Span {
    /// Interval span with no flows.
    pub fn interval(track: Track, kind: SpanKind, t0: VNanos, t1: VNanos, label: &'static str, id: u64) -> Span {
        Span { track, kind, t0, t1: t1.max(t0), label, id, flow_in: 0, flow_out: 0 }
    }

    /// Point span (instant event in the export).
    pub fn point(track: Track, kind: SpanKind, t: VNanos, label: &'static str, id: u64) -> Span {
        Span::interval(track, kind, t, t, label, id)
    }

    pub fn with_flow_out(mut self, f: u64) -> Span {
        self.flow_out = f;
        self
    }

    pub fn with_flow_in(mut self, f: u64) -> Span {
        self.flow_in = f;
        self
    }
}

/// Deterministic 64-bit flow id over the parts that identify a message
/// or round (FNV-1a; never 0, so 0 can mean "no flow"). Both endpoints
/// of a flow derive the same id independently — the sender from its
/// `MsgKey`, the receiver's delivery from the same key — with no id
/// threading through the engine.
pub fn fid(parts: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &p in parts {
        for b in p.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h | 1
}

/// One thread's bounded span buffer. Deposits are wait-free from the
/// owning thread's point of view: `try_lock` only ever contends with a
/// snapshot reader, and a contended deposit is dropped (counted), not
/// blocked on. When full, the oldest span is evicted (counted).
pub struct ThreadRing {
    buf: Mutex<VecDeque<Span>>,
    dropped: AtomicU64,
}

impl ThreadRing {
    fn new(capacity: usize) -> ThreadRing {
        ThreadRing {
            buf: Mutex::new(VecDeque::with_capacity(capacity.min(1024))),
            dropped: AtomicU64::new(0),
        }
    }

    fn push(&self, span: Span, capacity: usize) {
        match self.buf.try_lock() {
            Ok(mut buf) => {
                if buf.len() >= capacity {
                    buf.pop_front();
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
                buf.push_back(span);
            }
            // Snapshot in progress on this ring: never wait on the
            // deposit path (the depositor may be the clock driver).
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Unique id per sink so a thread-local ring cached for one sink is
/// never reused for another (e.g. two runs in one test process).
static SINK_IDS: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (sink id, this thread's ring in that sink) — registered on the
    /// first deposit, reused for every later one.
    static THREAD_RING: std::cell::RefCell<Option<(u64, Arc<ThreadRing>)>> =
        const { std::cell::RefCell::new(None) };
}

/// The per-run span collector: a registry of per-thread rings plus the
/// shared drop counter. Cheap to clone (`Arc`), safe to deposit into
/// from any thread (workers, rank mains, clock drivers).
pub struct SpanSink {
    id: u64,
    capacity: usize,
    rings: Mutex<Vec<Arc<ThreadRing>>>,
    /// Spans lost to ring eviction or deposit contention, summed over
    /// all rings at snapshot time plus this sink-level count.
    extra_dropped: AtomicU64,
}

impl SpanSink {
    /// A sink whose per-thread rings hold up to `capacity` spans each.
    pub fn new(capacity: usize) -> Arc<SpanSink> {
        Arc::new(SpanSink {
            id: SINK_IDS.fetch_add(1, Ordering::Relaxed),
            capacity: capacity.max(16),
            rings: Mutex::new(Vec::new()),
            extra_dropped: AtomicU64::new(0),
        })
    }

    /// Deposit one span into the calling thread's ring (registering the
    /// ring on first use). Never blocks; never touches virtual time.
    pub fn record(self: &Arc<Self>, span: Span) {
        THREAD_RING.with(|cell| {
            let mut cell = cell.borrow_mut();
            let ring = match &*cell {
                Some((id, ring)) if *id == self.id => ring.clone(),
                _ => {
                    let ring = Arc::new(ThreadRing::new(self.capacity));
                    self.rings.lock().unwrap().push(ring.clone());
                    *cell = Some((self.id, ring.clone()));
                    ring
                }
            };
            ring.push(span, self.capacity);
        });
    }

    /// Merge every thread's ring into one list sorted by
    /// `(t0, t1, track, kind, id)` — a deterministic order for any
    /// fixed span *set* (the set itself can legitimately differ across
    /// runs for host-scheduling-dependent kinds like `Steal`).
    pub fn snapshot(&self) -> Vec<Span> {
        let rings = self.rings.lock().unwrap();
        let mut out = Vec::new();
        for ring in rings.iter() {
            let buf = ring.buf.lock().unwrap();
            out.extend(buf.iter().copied());
        }
        out.sort_by_key(|s| (s.t0, s.t1, s.track, s.kind, s.id));
        out
    }

    /// Total spans dropped so far (ring eviction + deposit contention).
    pub fn dropped(&self) -> u64 {
        let rings = self.rings.lock().unwrap();
        rings
            .iter()
            .map(|r| r.dropped.load(Ordering::Relaxed))
            .sum::<u64>()
            + self.extra_dropped.load(Ordering::Relaxed)
    }
}

/// Per-run observability bundle: the optional span sink plus the
/// always-on metrics registry with its hot instruments pre-resolved
/// (so emission sites never touch the registry's name maps).
pub struct RunObs {
    pub spans: Option<Arc<SpanSink>>,
    pub metrics: Arc<Registry>,
    /// Request completion → task resumption latency (virtual ns), the
    /// fig15 quantity as a distribution.
    pub completion_latency_ns: Arc<Hist>,
    /// Port queueing delay: how long a message waited behind earlier
    /// arrivals before its `rx_ns` service began.
    pub port_queue_ns: Arc<Hist>,
    /// Task pause duration (block → unblock, Section 4).
    pub pause_ns: Arc<Hist>,
    /// Spans deposited through this bundle.
    pub spans_recorded: Arc<Counter>,
    /// High-water mark of messages parked on any single ingress port.
    pub port_backlog: Arc<Gauge>,
}

impl RunObs {
    pub fn new(spans: Option<Arc<SpanSink>>) -> Arc<RunObs> {
        let metrics = Registry::new();
        let completion_latency_ns = metrics.histogram("completion_latency_ns");
        let port_queue_ns = metrics.histogram("port_queue_ns");
        let pause_ns = metrics.histogram("pause_ns");
        let spans_recorded = metrics.counter("spans_recorded");
        let port_backlog = metrics.gauge("port_backlog");
        Arc::new(RunObs {
            spans,
            metrics,
            completion_latency_ns,
            port_queue_ns,
            pause_ns,
            spans_recorded,
            port_backlog,
        })
    }

    /// Whether span recording is on (metrics always are).
    pub fn enabled(&self) -> bool {
        self.spans.is_some()
    }

    /// Deposit a span if recording is on. The no-sink path is one
    /// branch — cheap enough to leave unconditionally in hot code.
    pub fn record(&self, span: Span) {
        if let Some(sink) = &self.spans {
            self.spans_recorded.inc();
            sink.record(span);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest_beyond_capacity() {
        let sink = SpanSink::new(16);
        let tr = Track::Worker { rank: 0, worker: 0 };
        for i in 0..40u64 {
            sink.record(Span::interval(tr, SpanKind::TaskExec, i, i + 1, "task", i));
        }
        let snap = sink.snapshot();
        assert_eq!(snap.len(), 16);
        assert_eq!(sink.dropped(), 24);
        // The survivors are the newest 16, still in time order.
        assert_eq!(snap.first().unwrap().id, 24);
        assert_eq!(snap.last().unwrap().id, 39);
    }

    #[test]
    fn snapshot_merges_threads() {
        let sink = SpanSink::new(1024);
        let tr = Track::Worker { rank: 0, worker: 1 };
        let s2 = sink.clone();
        let h = std::thread::spawn(move || {
            for i in 0..10u64 {
                s2.record(Span::point(tr, SpanKind::Steal, 100 + i, "steal", i));
            }
        });
        for i in 0..10u64 {
            sink.record(Span::interval(
                Track::Worker { rank: 0, worker: 0 },
                SpanKind::TaskExec,
                i,
                i + 5,
                "task",
                i,
            ));
        }
        h.join().unwrap();
        let snap = sink.snapshot();
        assert_eq!(snap.len(), 20);
        assert_eq!(sink.dropped(), 0);
        assert!(snap.windows(2).all(|w| w[0].t0 <= w[1].t0), "snapshot not sorted");
    }

    #[test]
    fn fid_is_stable_and_nonzero() {
        let a = fid(&[1, 2, 3]);
        assert_eq!(a, fid(&[1, 2, 3]));
        assert_ne!(a, fid(&[3, 2, 1]));
        assert_ne!(fid(&[]), 0);
    }
}
