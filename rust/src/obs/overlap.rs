//! The overlap profiler: integrate a span timeline into per-rank
//! busy / communication / overlapped time, quantifying the paper's
//! central claim — that task-aware MPI "naturally overlaps computation
//! and communication phases" — as a single fraction per rank.
//!
//! Definitions (all in virtual ns, per rank):
//!
//! * **busy** — union over tasks of (that task's `TaskExec` interval
//!   minus its own `TaskPause` intervals). Subtracting per *task* (not
//!   per worker lane) is what makes this correct under Section 4's
//!   pause/resume protocol: while task A is paused its core runs task
//!   B, whose exec interval covers the same wall of virtual time — the
//!   rank stays busy through B even though A is blocked.
//! * **comm** — union of every in-flight communication interval the
//!   rank owns: request lifetimes (`MpiReq`, post → completion),
//!   collective schedule rounds (`CollRound`), and ingress-port service
//!   intervals (`PortBusy`).
//! * **overlapped** — `busy ∩ comm`: virtual time where the rank was
//!   computing *while* communication it owns was in flight.
//!
//! The headline number is `overlapped / comm` — 0 for a rank that
//! always stops to communicate, →1 for one whose communication hides
//! entirely behind compute. Blocking task-aware mode loses pause /
//! resume bookkeeping and scheduling gaps inside every comm window;
//! the non-blocking mode (Section 6) does not, which is exactly what
//! fig20 measures.

use std::collections::BTreeMap;

use super::{Span, SpanKind};

/// Per-rank integration result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RankOverlap {
    pub rank: u32,
    /// Virtual span of the rank's timeline (max t1 − min t0).
    pub span_ns: u64,
    pub busy_ns: u64,
    pub comm_ns: u64,
    pub overlap_ns: u64,
}

impl RankOverlap {
    pub fn busy_frac(&self) -> f64 {
        frac(self.busy_ns, self.span_ns)
    }

    pub fn comm_frac(&self) -> f64 {
        frac(self.comm_ns, self.span_ns)
    }

    /// The headline: fraction of in-flight-communication time the rank
    /// spent computing.
    pub fn overlap_frac(&self) -> f64 {
        frac(self.overlap_ns, self.comm_ns)
    }
}

fn frac(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// Integrate a merged span snapshot into per-rank overlap accounting.
/// Clock-lane spans (no owning rank) are ignored.
pub fn overlap_by_rank(spans: &[Span]) -> Vec<RankOverlap> {
    // Per rank: task id -> (exec intervals, pause intervals); comm list.
    struct Acc {
        tasks: BTreeMap<u64, (Vec<(u64, u64)>, Vec<(u64, u64)>)>,
        comm: Vec<(u64, u64)>,
        t_min: u64,
        t_max: u64,
    }
    let mut ranks: BTreeMap<u32, Acc> = BTreeMap::new();
    for s in spans {
        let Some(rank) = s.track.rank() else { continue };
        let acc = ranks.entry(rank).or_insert_with(|| Acc {
            tasks: BTreeMap::new(),
            comm: Vec::new(),
            t_min: u64::MAX,
            t_max: 0,
        });
        acc.t_min = acc.t_min.min(s.t0);
        acc.t_max = acc.t_max.max(s.t1);
        match s.kind {
            SpanKind::TaskExec => acc.tasks.entry(s.id).or_default().0.push((s.t0, s.t1)),
            SpanKind::TaskPause => acc.tasks.entry(s.id).or_default().1.push((s.t0, s.t1)),
            SpanKind::MpiReq | SpanKind::CollRound | SpanKind::PortBusy => {
                acc.comm.push((s.t0, s.t1))
            }
            _ => {}
        }
    }
    ranks
        .into_iter()
        .map(|(rank, acc)| {
            let mut busy = Vec::new();
            for (_, (exec, pause)) in acc.tasks {
                busy.extend(subtract(normalize(exec), normalize(pause)));
            }
            let busy = normalize(busy);
            let comm = normalize(acc.comm);
            let overlap = intersect(&busy, &comm);
            RankOverlap {
                rank,
                span_ns: acc.t_max.saturating_sub(acc.t_min),
                busy_ns: total(&busy),
                comm_ns: total(&comm),
                overlap_ns: total(&overlap),
            }
        })
        .collect()
}

/// Cluster-level summary: totals over all ranks.
pub fn overlap_summary(per_rank: &[RankOverlap]) -> RankOverlap {
    let mut out = RankOverlap { rank: u32::MAX, span_ns: 0, busy_ns: 0, comm_ns: 0, overlap_ns: 0 };
    for r in per_rank {
        out.span_ns += r.span_ns;
        out.busy_ns += r.busy_ns;
        out.comm_ns += r.comm_ns;
        out.overlap_ns += r.overlap_ns;
    }
    out
}

/// Sort + merge overlapping/adjacent intervals; drops empty ones.
fn normalize(mut v: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    v.retain(|&(a, b)| b > a);
    v.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(v.len());
    for (a, b) in v {
        match out.last_mut() {
            Some((_, pb)) if a <= *pb => *pb = (*pb).max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// `a − b`, both normalized.
fn subtract(a: Vec<(u64, u64)>, b: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    let mut out = Vec::with_capacity(a.len());
    let mut bi = 0;
    for (mut lo, hi) in a {
        while lo < hi {
            // Skip b-intervals entirely before lo.
            while bi < b.len() && b[bi].1 <= lo {
                bi += 1;
            }
            match b.get(bi) {
                Some(&(blo, bhi)) if blo < hi => {
                    if blo > lo {
                        out.push((lo, blo));
                    }
                    lo = bhi.max(lo);
                }
                _ => {
                    out.push((lo, hi));
                    break;
                }
            }
        }
        // `bi` may point at an interval that also clips the next `a`
        // entry; step back one so the skip loop re-evaluates it.
        bi = bi.saturating_sub(1);
    }
    normalize(out)
}

/// `a ∩ b`, both normalized.
fn intersect(a: &[(u64, u64)], b: &[(u64, u64)]) -> Vec<(u64, u64)> {
    let (mut i, mut j) = (0, 0);
    let mut out = Vec::new();
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            out.push((lo, hi));
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    out
}

fn total(v: &[(u64, u64)]) -> u64 {
    v.iter().map(|&(a, b)| b - a).sum()
}

#[cfg(test)]
mod tests {
    use super::super::{Span, SpanKind, Track};
    use super::*;

    #[test]
    fn interval_algebra() {
        let a = normalize(vec![(0, 10), (5, 12), (20, 30), (12, 13)]);
        assert_eq!(a, vec![(0, 13), (20, 30)]);
        assert_eq!(subtract(a.clone(), vec![(4, 6), (25, 40)]), vec![(0, 4), (6, 13), (20, 25)]);
        assert_eq!(intersect(&a, &[(4, 6), (25, 40)]), vec![(4, 6), (25, 30)]);
        assert_eq!(total(&a), 23);
    }

    #[test]
    fn subtract_interval_spanning_two_sources() {
        // One b-interval clips the tail of a[0] AND the head of a[1].
        let a = vec![(0, 10), (20, 30)];
        let b = vec![(8, 22)];
        assert_eq!(subtract(a, b), vec![(0, 8), (22, 30)]);
    }

    #[test]
    fn pause_of_one_task_does_not_erase_anothers_exec() {
        let w = |worker| Track::Worker { rank: 0, worker };
        let spans = [
            // Task 1 runs [0,100] but is paused [10,90] (blocking recv).
            Span::interval(w(0), SpanKind::TaskExec, 0, 100, "task", 1),
            Span::interval(w(0), SpanKind::TaskPause, 10, 90, "pause", 1),
            // Task 2 computes [10,90] on the freed core.
            Span::interval(w(1), SpanKind::TaskExec, 10, 90, "task", 2),
            // The recv request is in flight [5,95].
            Span::interval(Track::Reqs { rank: 0 }, SpanKind::MpiReq, 5, 95, "req", 7),
        ];
        let per = overlap_by_rank(&spans);
        assert_eq!(per.len(), 1);
        let r = per[0];
        assert_eq!(r.busy_ns, 100, "busy = [0,10)+[10,90)+[90,100]");
        assert_eq!(r.comm_ns, 90);
        assert_eq!(r.overlap_ns, 90);
        assert!((r.overlap_frac() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn no_comm_means_zero_overlap_fraction() {
        let spans = [Span::interval(
            Track::Worker { rank: 3, worker: 0 },
            SpanKind::TaskExec,
            0,
            50,
            "task",
            1,
        )];
        let r = overlap_by_rank(&spans)[0];
        assert_eq!(r.comm_ns, 0);
        assert_eq!(r.overlap_frac(), 0.0);
        assert_eq!(r.busy_ns, 50);
    }
}
