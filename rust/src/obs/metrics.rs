//! Virtual-time metrics registry: counters, gauges (with high-water
//! marks), and log2-bucket histograms.
//!
//! All instruments are lock-free relaxed atomics on the record path;
//! the registry's name maps are only touched at registration time, so
//! hot sites hold their `Arc<...>` handles directly (see
//! [`crate::obs::RunObs`]). Values are virtual nanoseconds or plain
//! counts — never host time — so snapshots of deterministic quantities
//! (completion latency, port queueing, pause durations) are identical
//! across host runs, shard counts, and delivery modes.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge that also tracks its high-water mark (the snapshot
/// reports the hwm — for a simulation that ends quiescent, the last
/// value is almost always 0 and the peak is the interesting number).
#[derive(Default)]
pub struct Gauge {
    value: AtomicU64,
    hwm: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
        self.hwm.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn high_water(&self) -> u64 {
        self.hwm.load(Ordering::Relaxed)
    }
}

/// Number of log2 buckets: bucket `i` holds values `v` with
/// `floor(log2(v)) + 1 == i` (bucket 0 holds `v == 0`), i.e. bucket
/// upper bounds 0, 1, 3, 7, ..., 2^63-1 — enough for any `u64`.
pub const HIST_BUCKETS: usize = 65;

/// Log2-bucket histogram with exact count/sum/min/max.
pub struct Hist {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

impl Hist {
    /// Bucket index for a value: 0 for 0, else `floor(log2(v)) + 1`.
    pub fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let count = self.count.load(Ordering::Relaxed);
        HistSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max: self.max.load(Ordering::Relaxed),
            buckets: self
                .buckets
                .iter()
                .enumerate()
                .filter_map(|(i, b)| {
                    let n = b.load(Ordering::Relaxed);
                    (n > 0).then_some((i as u32, n))
                })
                .collect(),
        }
    }
}

/// Point-in-time copy of one histogram: exact moments plus the
/// non-empty `(bucket index, count)` pairs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub buckets: Vec<(u32, u64)>,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// Name-keyed instrument registry. Registration (`counter`/`gauge`/
/// `histogram`) is get-or-create and may take a lock; recording through
/// the returned handles never does.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<&'static str, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<&'static str, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<&'static str, Arc<Hist>>>,
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        self.counters.lock().unwrap().entry(name).or_default().clone()
    }

    pub fn gauge(&self, name: &'static str) -> Arc<Gauge> {
        self.gauges.lock().unwrap().entry(name).or_default().clone()
    }

    pub fn histogram(&self, name: &'static str) -> Arc<Hist> {
        self.hists.lock().unwrap().entry(name).or_default().clone()
    }

    /// Copy every instrument (gauges report their high-water mark).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), v.high_water()))
                .collect(),
            hists: self
                .hists
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), v.snapshot()))
                .collect(),
        }
    }
}

/// Point-in-time copy of a whole [`Registry`]; rides on
/// `rmpi::RunStats::metrics`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    /// High-water marks.
    pub gauges: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl MetricsSnapshot {
    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_buckets() {
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 1);
        assert_eq!(Hist::bucket_of(2), 2);
        assert_eq!(Hist::bucket_of(3), 2);
        assert_eq!(Hist::bucket_of(4), 3);
        assert_eq!(Hist::bucket_of(1024), 11);
        assert_eq!(Hist::bucket_of(u64::MAX), 64);
    }

    #[test]
    fn hist_moments_and_snapshot() {
        let h = Hist::default();
        for v in [0u64, 1, 3, 3, 1024] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1031);
        assert_eq!(s.min, 0);
        assert_eq!(s.max, 1024);
        assert_eq!(s.buckets, vec![(0, 1), (1, 1), (2, 2), (11, 1)]);
        assert!((s.mean() - 206.2).abs() < 1e-9);
    }

    #[test]
    fn registry_reuses_instruments() {
        let r = Registry::new();
        r.counter("a").inc();
        r.counter("a").add(2);
        r.gauge("g").set(7);
        r.gauge("g").set(3);
        let s = r.snapshot();
        assert_eq!(s.counters["a"], 3);
        assert_eq!(s.gauges["g"], 7, "gauge snapshot reports the high-water mark");
    }
}
