//! Chrome/Perfetto `trace_event` JSON exporter.
//!
//! Renders a merged span snapshot (see [`super::SpanSink::snapshot`])
//! as a JSON document loadable by <https://ui.perfetto.dev> or
//! `chrome://tracing`: one *process* per rank (plus one for the
//! simulation clock) and one *thread track* per worker (including the
//! off-worker "main" lane), per ingress port, per collective engine,
//! per request timeline, and per clock lane. Interval spans become `X`
//! complete events, point spans become `i` instants, request lifetimes
//! become `b`/`e` async pairs (they legitimately overlap on a rank's
//! request track), and `flow_in`/`flow_out` ids become `s`→`f` flow
//! arrows — send → matching recv delivery, collective round → round.
//!
//! Timestamps are virtual time: `ts`/`dur` are microseconds with ns
//! resolution (the trace_event unit), so the timeline reads directly
//! in simulated time. Events are globally sorted by instant, which
//! makes `ts` non-decreasing within every track — the property
//! `scripts/validate_trace.py` checks.

use std::fmt::Write as _;

use super::{Span, SpanKind, Track};

/// pid used for the simulation clock's lane tracks (ranks use their
/// own index; real rank counts stay far below this).
const CLOCK_PID: u32 = 1_000_000;

fn pid_tid(track: Track) -> (u32, u32) {
    match track {
        Track::Worker { rank, worker } => {
            (rank, if worker == u32::MAX { 0 } else { worker.saturating_add(1) })
        }
        Track::Port { rank } => (rank, 900),
        Track::Coll { rank } => (rank, 910),
        Track::Reqs { rank } => (rank, 920),
        Track::Lane { lane } => (CLOCK_PID, lane),
    }
}

fn thread_name(track: Track) -> String {
    match track {
        Track::Worker { worker, .. } if worker == u32::MAX => "main".to_string(),
        Track::Worker { worker, .. } => format!("worker {worker}"),
        Track::Port { .. } => "ingress port".to_string(),
        Track::Coll { .. } => "collectives".to_string(),
        Track::Reqs { .. } => "mpi requests".to_string(),
        Track::Lane { lane } => format!("lane {lane}"),
    }
}

/// µs with ns resolution, as the literal JSON number text.
fn us(t_ns: u64) -> String {
    format!("{:.3}", t_ns as f64 / 1000.0)
}

/// Export a merged snapshot plus its dropped-span count as a complete
/// Chrome/Perfetto JSON document.
pub fn export(spans: &[Span], dropped: u64) -> String {
    // (sort instant ns, phase rank, rendered event) — phase rank keeps
    // metadata first and orders same-instant begin/end sanely.
    let mut events: Vec<(u64, u8, String)> = Vec::with_capacity(spans.len() * 2 + 16);

    // Track metadata: name every process and thread we will emit onto.
    let mut seen_tracks: Vec<Track> = spans.iter().map(|s| s.track).collect();
    seen_tracks.sort_unstable();
    seen_tracks.dedup();
    let mut seen_pids: Vec<u32> = Vec::new();
    for &track in &seen_tracks {
        let (pid, tid) = pid_tid(track);
        if !seen_pids.contains(&pid) {
            seen_pids.push(pid);
            let pname = if pid == CLOCK_PID {
                "sim clock".to_string()
            } else {
                format!("rank {pid}")
            };
            events.push((
                0,
                0,
                format!(
                    "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{pid},\"tid\":0,\
                     \"args\":{{\"name\":\"{pname}\"}}}}"
                ),
            ));
        }
        events.push((
            0,
            0,
            format!(
                "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{pid},\"tid\":{tid},\
                 \"args\":{{\"name\":\"{}\"}}}}",
                thread_name(track)
            ),
        ));
    }

    for s in spans {
        let (pid, tid) = pid_tid(s.track);
        let cat = s.kind.cat();
        let common = format!(
            "\"cat\":\"{cat}\",\"name\":\"{}\",\"pid\":{pid},\"tid\":{tid}",
            s.label
        );
        let args = format!("\"args\":{{\"id\":{}}}", s.id);
        match s.kind {
            // Request lifetimes overlap on their rank's track: async pair.
            SpanKind::MpiReq => {
                events.push((
                    s.t0,
                    1,
                    format!(
                        "{{\"ph\":\"b\",{common},\"id\":{},\"ts\":{},{args}}}",
                        s.id,
                        us(s.t0)
                    ),
                ));
                events.push((
                    s.t1,
                    6,
                    format!("{{\"ph\":\"e\",{common},\"id\":{},\"ts\":{}}}", s.id, us(s.t1)),
                ));
            }
            _ if s.t1 == s.t0 => {
                events.push((
                    s.t0,
                    3,
                    format!("{{\"ph\":\"i\",{common},\"s\":\"t\",\"ts\":{},{args}}}", us(s.t0)),
                ));
            }
            _ => {
                events.push((
                    s.t0,
                    2,
                    format!(
                        "{{\"ph\":\"X\",{common},\"ts\":{},\"dur\":{},{args}}}",
                        us(s.t0),
                        us(s.t1 - s.t0)
                    ),
                ));
            }
        }
        if s.flow_out != 0 {
            // Producer end: anchor at the span's end (its start for
            // points) so round→round arrows leave the finished round.
            let ts = if s.t1 == s.t0 { s.t0 } else { s.t1 };
            events.push((
                ts,
                4,
                format!(
                    "{{\"ph\":\"s\",\"cat\":\"flow\",\"name\":\"flow\",\"id\":{},\
                     \"pid\":{pid},\"tid\":{tid},\"ts\":{}}}",
                    s.flow_out,
                    us(ts)
                ),
            ));
        }
        if s.flow_in != 0 {
            events.push((
                s.t0,
                5,
                format!(
                    "{{\"ph\":\"f\",\"bp\":\"e\",\"cat\":\"flow\",\"name\":\"flow\",\
                     \"id\":{},\"pid\":{pid},\"tid\":{tid},\"ts\":{}}}",
                    s.flow_in,
                    us(s.t0)
                ),
            ));
        }
    }

    events.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    let mut out = String::with_capacity(events.len() * 96 + 128);
    out.push_str("{\"displayTimeUnit\":\"ns\",\"otherData\":{\"dropped_spans\":");
    let _ = write!(out, "{dropped}");
    out.push_str("},\"traceEvents\":[\n");
    for (i, (_, _, e)) in events.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(e);
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::super::{fid, Span, SpanKind, Track};
    use super::*;

    #[test]
    fn export_shape_and_flows() {
        let f = fid(&[1, 2, 3]);
        let spans = [
            Span::interval(Track::Worker { rank: 0, worker: 0 }, SpanKind::TaskExec, 0, 2000, "task", 1),
            Span::point(Track::Worker { rank: 0, worker: 0 }, SpanKind::Send, 500, "isend", 0)
                .with_flow_out(f),
            Span::point(Track::Port { rank: 1 }, SpanKind::Deliver, 1500, "deliver", 0)
                .with_flow_in(f),
            Span::interval(Track::Reqs { rank: 1 }, SpanKind::MpiReq, 100, 1500, "recv", 9),
            Span::interval(Track::Lane { lane: 0 }, SpanKind::LaneWait, 0, 400, "lane-wait", 0),
        ];
        let json = export(&spans, 3);
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"dropped_spans\":3"));
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("\"sim clock\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"b\""));
        assert!(json.contains("\"ph\":\"e\""));
        assert!(json.contains(&format!("\"ph\":\"s\",\"cat\":\"flow\",\"name\":\"flow\",\"id\":{f}")));
        assert!(json.contains("\"ph\":\"f\",\"bp\":\"e\""));
        // X at t0=0 lasts 2 µs.
        assert!(json.contains("\"ts\":0.000,\"dur\":2.000"));
    }
}
