//! Topology-aware collective plans and the persistent schedule cache.
//!
//! PR 3's schedule engine treated the cluster as flat: binomial and
//! dissemination rounds crossed the node boundary as cheaply as they
//! stayed inside it, and every collective call recompiled its schedule
//! from scratch. This module separates *what a collective's rounds look
//! like* (a [`CollPlan`]: pure per-rank structure — peers, phases,
//! buffer regions — with no buffers bound) from *running them*
//! ([`super::coll_schedule`] instantiates a plan against the caller's
//! buffers and launches it), which buys two things at once:
//!
//! 1. **Node-hierarchical schedules.** The compiler knows the node
//!    hierarchy ([`super::universe::ClusterConfig`]'s `ranks_per_node`;
//!    the intra- vs inter-node link classes of [`NetworkModel`]) and
//!    emits leader-staged plans — intra-node gather/reduce to a node
//!    leader, an inter-node tree among leaders, intra-node bcast/scatter
//!    fan-out — the shape MPICH's collective extensions compile
//!    (arXiv:2402.12274).
//! 2. **Persistent schedules.** Plans are cached per communicator in a
//!    [`SchedCache`] keyed by `(collective kind, root, shape)` — the
//!    moral equivalent of MPI-4 persistent collectives
//!    (`MPI_Allreduce_init`): the per-iteration residual `iallreduce`
//!    of gauss_seidel/ifsker compiles once and every later call reuses
//!    the compiled rounds. Hits and misses are counted cluster-wide
//!    ([`crate::rmpi::RunStats::sched_cache`]) and each launch is traced as
//!    [`crate::trace::EventKind::CollScheduleCompiled`] `{ cached }`. The
//!    cache lives on the communicator handle, so dropping a
//!    communicator (or `dup`ing a fresh one) drops/starts its schedule
//!    store — the MPI persistent-request lifetime.
//!
//! ## Selection has no cost arithmetic of its own
//!
//! The flat-vs-hierarchical decision *is* the network model: each
//! candidate shape is priced by the exact critical path of its
//! [`WireRound`] lowering under the same link classes and the same
//! ingress-port serialization law ([`super::net::ports::PortClock`])
//! the live engine charges message by message. Compiler-estimated and
//! engine-observed critical paths are equal (the parity test in
//! `tests/net_ports.rs` asserts this exactly, per collective, with and
//! without receiver processing), so `TopologyMode::Hierarchical` can
//! never lose to `Flat`. The pricing uses only values every rank
//! agrees on (communicator size, node shape, payload bytes), so all
//! ranks of one collective always pick the same plan shape — a
//! mismatch would deadlock the rounds.
//!
//! ## The plan compilation service: three tiers of not repeating work
//!
//! Exactness used to be priced naively: every rank's first cache miss
//! built *all-rank* candidate plans and replayed full wire schedules
//! through [`super::net::model::critical_path`] — O(n²) events for an
//! alltoall, O(n³) aggregate on a cold communicator. The compile path
//! is now a service with three tiers, cheapest first:
//!
//! 1. **Cluster-wide [`PlanStore`]** (one per universe, on
//!    [`super::comm::UniState`]): compiled *cluster plans* — the
//!    all-rank plan vector one compile already produces — are stored
//!    once under `(comm shape signature, NetworkModel fingerprint,
//!    TopologyMode, SchedKey)` and every rank takes a cheap per-rank
//!    view (an `Arc` role slice). n identical compiles become one:
//!    concurrent first calls coalesce on the store's slot lock, and
//!    dup'd communicators of the same shape share the same entries.
//!    The per-communicator [`SchedCache`] survives as a thin per-comm
//!    index into the store, preserving drop semantics (a dropped
//!    communicator drops its index; the store keeps the plan for the
//!    next congruent communicator) and the per-call
//!    [`crate::rmpi::RunStats::sched_cache`] accounting.
//! 2. **Memoized replays** ([`ReplayMemo`], owned by the store): inside
//!    and across compiles, candidate wire schedules are keyed by a
//!    structural digest and replayed once — the flat-vs-hier comparison
//!    of an allreduce shares its tree replays with the bcast of the
//!    same payload, and repeated cache-off compiles (the fig17 cold
//!    baseline) stop re-replaying identical candidates.
//! 3. **Closed forms for regular shapes**: tree and reduce lowerings
//!    have exact linear-time evaluations (each port's arrivals are
//!    known once its subtree is priced — no event heap), and the
//!    uniform-blocked layouts the hierarchy compiler emits admit O(1)
//!    formulas for gather fan-in, the leader-staged barrier, and both
//!    alltoall shapes. Every closed form is *asserted equal to the
//!    event-driven replay* in debug builds (and by the equality-matrix
//!    tests), so the parity contract above still gates correctness;
//!    irregular shapes simply fall back to tier 2.
//!
//! fig21 (`repro figures --fig 21`) sweeps a cold alltoall compile over
//! rank counts for the three strategies (per-rank replay, cluster-wide,
//! closed-form) in host time and replay events;
//! [`crate::rmpi::RunStats::plan_store`] carries the per-run counters.
//!
//! ## Reduction bit-identity is a contract — unless the op opts out
//!
//! `reduce`/`allreduce` results must be bit-identical between flat and
//! hierarchical runs (and across delivery modes and wait styles), so
//! the combiner order is pinned to the flat binomial tree's fixed child
//! order. On the blocked rank layout the flat binomial tree is already
//! node-hierarchical whenever the node blocks align with its subtrees
//! (power-of-two ranks-per-node, root on a node boundary — always true
//! for allreduce's internal root-0 reduce): non-leaf edges stay
//! intra-node and leader-to-leader edges carry the inter-node traffic.
//! When the blocks do not align, restructuring the tree would change
//! the combine association (different floating-point rounding), so the
//! compiler keeps the flat tree by default.
//!
//! Ops wrapped in [`crate::rmpi::collectives::Commutative`] (the
//! `commutative()` marker) declare reordering safe, which frees the
//! compiler to re-root the combine tree hierarchically: members combine
//! into their node leader, leaders combine along an inter-node binomial
//! tree (the reverse of the hierarchical broadcast tree). Marked and
//! unmarked ops cache under distinct keys ([`CollKind::ReduceComm`] /
//! [`CollKind::AllreduceComm`]), and unmarked ops keep the flat tree in
//! every topology mode (asserted in tests).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::net::model::{critical_path, critical_path_counted};
use super::net::ports::PortClock;
use super::net::{NetworkModel, WireOp, WireRound};
use crate::obs::metrics::{Counter, Hist, Registry};

/// How the schedule compiler sees the cluster.
///
/// Carried by `ClusterConfig::topology` (default `Hierarchical`). Flat
/// reproduces the PR-3 schedules exactly; Hierarchical enables the
/// cost-driven node-aware shapes above (degenerating to flat when the
/// cluster has one node, one rank per node, or the wire replay says
/// flat is cheaper).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum TopologyMode {
    /// Ignore the node boundary (PR-3 behaviour).
    Flat,
    /// Compile node-hierarchical schedules where the network model says
    /// they win.
    #[default]
    Hierarchical,
}

/// Collective algorithm identity (part of the cache key).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) enum CollKind {
    Barrier,
    Bcast,
    Reduce,
    /// Reduce with a [`commutative`](crate::rmpi::collectives::commutative)
    /// op: the combine tree may re-root, so plans are shape-dependent
    /// and cached separately from the pinned-order `Reduce`.
    ReduceComm,
    Allreduce,
    /// Allreduce over a commutative op (re-rootable combine half).
    AllreduceComm,
    Gather,
    Alltoall,
    Alltoallv,
}

/// Payload shape (the rest of the cache key): what a compiled plan
/// depends on besides the algorithm and root — byte sizes, so the
/// critical-path comparison is exact for any element type. Alltoallv
/// carries no shape at all: its counts are per-rank values the plan
/// shape must not depend on (see [`compile_plan`]), so every signature
/// shares the one pairwise plan (and the key stays O(1) — no cloned
/// count vectors in the cache). Pinned-order `Reduce` is also
/// shapeless (its binomial tree depends only on size and root);
/// `ReduceComm` carries bytes because re-rooting is cost-driven.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) enum ShapeKey {
    /// Shapeless (barrier, pinned-order reduce, alltoallv).
    None,
    /// Byte length of the single buffer (bcast/reduce-comm/allreduce).
    Bytes(usize),
    /// Per-rank chunk byte length (gather, uniform alltoall).
    ChunkBytes(usize),
}

/// Cache key of one compiled schedule: `(collective kind, root, shape,
/// avoid)` on one communicator (the cache itself is per-communicator).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) struct SchedKey {
    pub kind: CollKind,
    pub root: usize,
    pub shape: ShapeKey,
    /// Comm-rank bitset of detected stragglers the compiler must route
    /// tree interior positions away from (`Comm::set_avoid`). Part of
    /// the key on purpose: raising the mask retires every previously
    /// compiled plan through the ordinary cache-miss path — the
    /// stall-driven invalidation contract.
    pub avoid: u64,
}

/// One dissemination/fan round of a token collective (barrier): token
/// sends and receives with their tag phases.
pub(crate) struct TokenRound {
    pub sends: Vec<(usize, u32)>,
    pub recvs: Vec<(usize, u32)>,
}

/// Barrier plan: a list of token rounds.
pub(crate) struct TokenPlan {
    pub rounds: Vec<TokenRound>,
}

/// Broadcast plan: receive the payload from one parent (None at the
/// root), then forward it to a fixed child list in one send round.
pub(crate) struct TreePlan {
    pub recv_from: Option<usize>,
    pub send_to: Vec<usize>,
}

/// Reduce plan: receive child contributions (combined *in this exact
/// order* — the bit-identity contract), then forward the partial to the
/// parent (None at the root).
pub(crate) struct ReducePlan {
    pub children: Vec<usize>,
    pub parent: Option<usize>,
}

/// One aggregated node block arriving at the gather root.
pub(crate) struct GatherBlock {
    pub leader: usize,
    pub first_rank: usize,
    pub nranks: usize,
}

/// Gather plan, by role.
pub(crate) enum GatherPlan {
    /// Send the chunk to `to` (the root, or this node's leader under
    /// the staged plan).
    Leaf { to: usize },
    /// Stage the node's chunks (members excludes self) and forward the
    /// contiguous block to the root.
    Leader { members: Vec<usize>, root: usize, node_base: usize },
    /// Receive direct chunks plus aggregated node blocks.
    Root { direct: Vec<usize>, blocks: Vec<GatherBlock> },
}

/// Leader-staged uniform alltoall plan (flat alltoall(v) needs no plan
/// data beyond the shape; the element chunk binds at instantiation).
pub(crate) struct AlltoallHier {
    /// Rank lists per node, ascending (uniform, contiguous).
    pub nodes_list: Vec<Vec<usize>>,
    pub my_node: usize,
    pub is_leader: bool,
}

/// A compiled per-rank collective plan.
pub(crate) enum CollPlan {
    Barrier(TokenPlan),
    Bcast(TreePlan),
    Reduce(ReducePlan),
    Allreduce { reduce: ReducePlan, bcast: TreePlan },
    Gather(GatherPlan),
    /// Pairwise exchange; shape (counts/displacements) supplied at
    /// instantiation time. Used by alltoallv always and by uniform
    /// alltoall when staging would not pay.
    AlltoallvFlat,
    AlltoallHier(AlltoallHier),
}

/// Per-communicator plan index (MPI persistent-request analogue).
/// Shared by clones of one rank's communicator handle; `Comm::dup`
/// starts a fresh index and dropping the communicator drops it. Since
/// the plan compilation service, entries are per-rank views into the
/// universe-level [`PlanStore`], so an index miss is usually satisfied
/// without compiling — the per-call hit/miss accounting lives in
/// `Comm::plan_for`, not here.
#[derive(Default)]
pub(crate) struct SchedCache {
    map: Mutex<HashMap<SchedKey, Arc<CollPlan>>>,
}

impl SchedCache {
    /// Look the key up, resolving (and storing) on a miss. Returns the
    /// plan and whether this was an index hit. The resolver runs
    /// *outside* the map lock so concurrent collectives on sibling
    /// communicators never serialize behind a compile; if two calls
    /// race the same key, the first insert wins and the loser's
    /// (store-shared, hence identical) plan is dropped.
    pub fn get_or_compile(
        &self,
        key: &SchedKey,
        compile: impl FnOnce() -> Arc<CollPlan>,
    ) -> (Arc<CollPlan>, bool) {
        if let Some(p) = self.map.lock().unwrap().get(key) {
            return (p.clone(), true);
        }
        let p = compile();
        let mut g = self.map.lock().unwrap();
        (g.entry(*key).or_insert(p).clone(), false)
    }

    /// Distinct plans currently indexed.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------------
// The cluster-wide plan compilation service (tier 1 of the module
// docs): compile a SchedKey once per universe, not once per rank.
// ---------------------------------------------------------------------

/// Full identity of one compiled cluster plan. `shape_sig`/`net_sig`/
/// `mode` pin everything a compile reads besides the [`SchedKey`]: the
/// communicator shape (size + node map) and the network model. Today
/// every communicator in a universe shares one shape and one model, so
/// these fields are constant per store — they are part of the key so
/// congruence stays explicit when multi-job universes arrive.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct PlanKey {
    shape_sig: u64,
    net_sig: u64,
    mode: TopologyMode,
    sched: SchedKey,
}

/// Order-sensitive FNV-1a digest of a communicator shape (size plus the
/// node of every rank) — the `comm shape` component of [`PlanKey`].
fn shape_signature(node_of: &[usize]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    mix(node_of.len() as u64);
    for &nd in node_of {
        mix(nd as u64);
    }
    h
}

/// One compiled all-rank plan vector with per-rank `Arc` views. The
/// `touched` bits make the per-call `RunStats::sched_cache` accounting
/// deterministic: each rank's *first* view of a cluster plan counts as
/// its compile miss (exactly the call that would have compiled before
/// the service existed — same virtual-time debt, same counters), and
/// every later view (a dup'd congruent communicator) is a hit.
pub(crate) struct ClusterPlan {
    views: Vec<Arc<CollPlan>>,
    touched: Vec<AtomicBool>,
}

impl ClusterPlan {
    fn new(plans: Vec<CollPlan>) -> ClusterPlan {
        let touched = (0..plans.len()).map(|_| AtomicBool::new(false)).collect();
        ClusterPlan { views: plans.into_iter().map(Arc::new).collect(), touched }
    }

    /// This rank's role slice of the cluster plan.
    pub fn view(&self, rank: usize) -> Arc<CollPlan> {
        self.views[rank].clone()
    }

    /// True exactly once per rank (per-rank program order, so the
    /// answer never depends on host-thread races across ranks).
    pub fn first_touch(&self, rank: usize) -> bool {
        !self.touched[rank].swap(true, Ordering::Relaxed)
    }
}

/// Host-side compile instrumentation shared by every compile through
/// one store: replay heap events, memo hits, closed-form hits. Counts
/// are host-scoped diagnostics (concurrent compiles interleave), never
/// inputs to virtual time.
#[derive(Default)]
pub(crate) struct CompileStats {
    pub replay_events: AtomicU64,
    pub memo_hits: AtomicU64,
    pub closed_form_hits: AtomicU64,
}

impl CompileStats {
    pub fn replay_events(&self) -> u64 {
        self.replay_events.load(Ordering::Relaxed)
    }

    pub fn memo_hits(&self) -> u64 {
        self.memo_hits.load(Ordering::Relaxed)
    }

    pub fn closed_form_hits(&self) -> u64 {
        self.closed_form_hits.load(Ordering::Relaxed)
    }
}

/// Tier-2 memo: candidate wire schedules keyed by a structural digest,
/// each replayed through [`critical_path`] at most once per store. The
/// digest covers only schedule structure (round/peer/byte lists), so a
/// memo must never be shared across node maps or network models — the
/// owning [`PlanStore`] is keyed by both, and standalone probes own
/// their own.
#[derive(Default)]
pub(crate) struct ReplayMemo {
    map: Mutex<HashMap<(u64, u64), u64>>,
}

impl ReplayMemo {
    fn get(&self, key: (u64, u64)) -> Option<u64> {
        self.map.lock().unwrap().get(&key).copied()
    }

    fn put(&self, key: (u64, u64), v: u64) {
        self.map.lock().unwrap().insert(key, v);
    }

    /// Distinct schedules replayed so far.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

/// Double-lane structural digest of a wire schedule (two independent
/// 64-bit mixes ≈ one 128-bit key: collisions would silently corrupt
/// plan selection, so a single 64-bit FNV over thousands of schedules
/// is not enough margin).
fn sched_sig(scheds: &[Vec<WireRound>]) -> (u64, u64) {
    let mut h1 = 0xcbf2_9ce4_8422_2325u64;
    let mut h2 = 0x9e37_79b9_7f4a_7c15u64;
    let mut mix = |v: u64| {
        h1 = (h1 ^ v).wrapping_mul(0x100_0000_01b3);
        h2 = (h2 ^ v.rotate_left(29)).wrapping_mul(0xff51_afd7_ed55_8ccd);
        h2 ^= h2 >> 31;
    };
    mix(scheds.len() as u64);
    for rounds in scheds {
        mix(0xa5a5);
        mix(rounds.len() as u64);
        for r in rounds {
            mix(r.sends.len() as u64);
            for op in &r.sends {
                mix(op.peer as u64);
                mix(op.bytes as u64);
            }
            mix(r.recvs.len() as u64);
            for op in &r.recvs {
                mix(op.peer as u64);
                mix(op.bytes as u64);
            }
        }
    }
    (h1, h2)
}

/// Universe-level plan compilation service (one per
/// [`super::comm::UniState`]): cluster plans compiled exactly once per
/// [`PlanKey`], with the tier-2 replay memo and compile instrumentation
/// riding along. Lookups coalesce: concurrent first calls for one key
/// block on the slot's `OnceLock` and exactly one runs the compiler, so
/// cold-communicator compile work is O(1) compiles per `SchedKey`
/// cluster-wide. `hits`/`misses` land in the owning registry as
/// `plan_store_hits`/`plan_store_misses`; compile wall time lands in
/// the `plan_compile_ns` histogram (host nanoseconds — diagnostics,
/// never virtual time).
pub(crate) struct PlanStore {
    shape_sig: u64,
    net_sig: u64,
    mode: TopologyMode,
    slots: Mutex<HashMap<PlanKey, Arc<OnceLock<Arc<ClusterPlan>>>>>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    compile_ns: Arc<Hist>,
    pub stats: CompileStats,
    pub memo: ReplayMemo,
}

impl PlanStore {
    pub fn new(
        node_of: &[usize],
        net: &NetworkModel,
        mode: TopologyMode,
        metrics: &Registry,
    ) -> PlanStore {
        PlanStore {
            shape_sig: shape_signature(node_of),
            net_sig: net.fingerprint(),
            mode,
            slots: Mutex::new(HashMap::new()),
            hits: metrics.counter("plan_store_hits"),
            misses: metrics.counter("plan_store_misses"),
            compile_ns: metrics.histogram("plan_compile_ns"),
            stats: CompileStats::default(),
            memo: ReplayMemo::default(),
        }
    }

    /// Standalone store backed by a throwaway registry (bench probes,
    /// tests).
    #[allow(dead_code)]
    pub fn standalone(node_of: &[usize], net: &NetworkModel, mode: TopologyMode) -> PlanStore {
        PlanStore::new(node_of, net, mode, &Registry::new())
    }

    /// The cluster plan for `sched`, compiling at most once per key
    /// store-wide. Returns the plan and whether this lookup found it
    /// already compiled (a store hit).
    pub fn get_or_compile(
        &self,
        sched: SchedKey,
        compile: impl FnOnce() -> Vec<CollPlan>,
    ) -> (Arc<ClusterPlan>, bool) {
        let key = PlanKey {
            shape_sig: self.shape_sig,
            net_sig: self.net_sig,
            mode: self.mode,
            sched,
        };
        let slot = self.slots.lock().unwrap().entry(key).or_default().clone();
        let mut compiled = false;
        let plan = slot
            .get_or_init(|| {
                let t0 = std::time::Instant::now();
                let p = Arc::new(ClusterPlan::new(compile()));
                self.compile_ns.record(t0.elapsed().as_nanos() as u64);
                compiled = true;
                p
            })
            .clone();
        if compiled {
            self.misses.inc();
        } else {
            self.hits.inc();
        }
        (plan, !compiled)
    }

    /// Distinct cluster plans compiled.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// Store lookups satisfied by an already-compiled plan.
    pub fn hit_count(&self) -> u64 {
        self.hits.get()
    }

    /// Store lookups that ran the compiler (one per distinct key).
    pub fn miss_count(&self) -> u64 {
        self.misses.get()
    }
}

/// Everything the compiler may depend on. All fields are identical on
/// every rank except `rank` itself, and plan-shape decisions never use
/// `rank` (only roles derived from it), so all ranks agree on shapes.
///
/// `memo`, `stats`, and `closed_form` configure the cost tiers (module
/// docs): none of them can change a cost *value* — the memo caches
/// exact replays and the closed forms are asserted equal to them — only
/// how much host work computing it takes.
pub(crate) struct TopoCtx<'a> {
    pub rank: usize,
    pub size: usize,
    pub node_of: &'a [usize],
    pub mode: TopologyMode,
    pub net: &'a NetworkModel,
    /// Tier-2 replay memo (None: every replay runs).
    pub memo: Option<&'a ReplayMemo>,
    /// Compile instrumentation sink (None: uncounted).
    pub stats: Option<&'a CompileStats>,
    /// Whether tier-3 closed forms may replace event-driven replays.
    /// `false` forces the replay path — the fig21 baseline tiers.
    pub closed_form: bool,
}

impl<'a> TopoCtx<'a> {
    /// A context wired for service use: closed forms on, no shared
    /// memo/instrumentation. `Comm::plan_for` attaches the universe
    /// store's memo and stats on top of this.
    pub fn service(
        rank: usize,
        size: usize,
        node_of: &'a [usize],
        mode: TopologyMode,
        net: &'a NetworkModel,
    ) -> TopoCtx<'a> {
        TopoCtx { rank, size, node_of, mode, net, memo: None, stats: None, closed_form: true }
    }
}

/// ceil(log2(n)) for n >= 1.
fn ceil_log2(n: usize) -> u64 {
    debug_assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()) as u64
}

impl TopoCtx<'_> {
    /// Rank lists per node, ascending within each node.
    fn nodes_list(&self) -> Vec<Vec<usize>> {
        let n_nodes = self.node_of.iter().copied().max().unwrap_or(0) + 1;
        let mut nodes: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
        for (r, &nd) in self.node_of.iter().enumerate() {
            nodes[nd].push(r);
        }
        nodes
    }

    /// The hierarchy the compiler may exploit: `Some((nodes_list, rpn))`
    /// when hierarchical mode is on and the layout is uniform blocked
    /// (equal-size nodes of contiguous ranks) with more than one node
    /// and more than one rank per node.
    fn hierarchy(&self) -> Option<(Vec<Vec<usize>>, usize)> {
        if self.mode != TopologyMode::Hierarchical {
            return None;
        }
        let nodes = self.nodes_list();
        if nodes.len() < 2 {
            return None;
        }
        let rpn = nodes[0].len();
        if rpn < 2 {
            return None;
        }
        for (b, members) in nodes.iter().enumerate() {
            if members.len() != rpn {
                return None;
            }
            for (i, &r) in members.iter().enumerate() {
                if r != b * rpn + i {
                    return None;
                }
            }
        }
        Some((nodes, rpn))
    }

    /// Replay a candidate's wire schedules through the network model —
    /// the compiler's cost oracle of record (see module docs), memoized
    /// by structural digest when the context carries a [`ReplayMemo`].
    fn cost(&self, scheds: &[Vec<WireRound>]) -> u64 {
        if let Some(memo) = self.memo {
            let key = sched_sig(scheds);
            if let Some(v) = memo.get(key) {
                if let Some(s) = self.stats {
                    s.memo_hits.fetch_add(1, Ordering::Relaxed);
                }
                return v;
            }
            let v = self.replay(scheds);
            memo.put(key, v);
            return v;
        }
        self.replay(scheds)
    }

    /// The uncached exact replay, with heap events charged to `stats`.
    fn replay(&self, scheds: &[Vec<WireRound>]) -> u64 {
        let (v, events) = critical_path_counted(scheds, self.node_of, self.net);
        if let Some(s) = self.stats {
            s.replay_events.fetch_add(events, Ordering::Relaxed);
        }
        v
    }

    fn note_closed_form(&self) {
        if let Some(s) = self.stats {
            s.closed_form_hits.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Cost of a tree (broadcast-shaped) lowering at `bytes`. Closed
    /// form: every port receives exactly one message, so completion is
    /// a per-edge DP from the root — exact for any tree, any node map,
    /// both protocols (asserted against the replay in debug builds).
    fn cost_tree(&self, parents: &[Option<usize>], bytes: usize) -> u64 {
        if self.closed_form {
            let v = closed_tree_cost(parents, bytes, self.node_of, self.net);
            self.note_closed_form();
            debug_assert_eq!(
                v,
                critical_path(&tree_wire(parents, bytes), self.node_of, self.net),
                "closed-form tree cost must equal the event-driven replay"
            );
            return v;
        }
        self.cost(&tree_wire(parents, bytes))
    }

    /// Cost of a reduce (fan-in) lowering at `bytes`. Closed form:
    /// messages flow child->parent only, so each port's arrivals are
    /// known once its subtree is priced — a bottom-up DP applying the
    /// identical `PortClock` law in the identical service order.
    fn cost_reduce(&self, plans: &[ReducePlan], bytes: usize) -> u64 {
        if self.closed_form {
            let v = closed_reduce_cost(plans, bytes, self.node_of, self.net);
            self.note_closed_form();
            debug_assert_eq!(
                v,
                critical_path(&reduce_wire(plans, bytes), self.node_of, self.net),
                "closed-form reduce cost must equal the event-driven replay"
            );
            return v;
        }
        self.cost(&reduce_wire(plans, bytes))
    }

    /// Cost of the flat dissemination barrier: node boundaries cut
    /// through the rotating partner pattern asymmetrically, so there is
    /// no closed form — this is the one lowering that always replays
    /// (tier 2).
    fn cost_tokens_flat(&self, plans: &[TokenPlan]) -> u64 {
        self.cost(&token_wire(plans))
    }

    /// Cost of the leader-staged barrier. Closed form (uniform blocked
    /// layout guaranteed by [`TopoCtx::hierarchy`]): three phase sums.
    fn cost_tokens_hier(&self, plans: &[TokenPlan], l: usize, rpn: usize) -> u64 {
        if self.closed_form {
            let v = closed_hier_barrier_cost(l, rpn, self.net);
            self.note_closed_form();
            debug_assert_eq!(
                v,
                critical_path(&token_wire(plans), self.node_of, self.net),
                "closed-form hier-barrier cost must equal the event-driven replay"
            );
            return v;
        }
        self.cost(&token_wire(plans))
    }

    /// Cost of a gather lowering at chunk size `cb`. Closed form: every
    /// port's arrival set is known a priori (leaf sends post at 0,
    /// leaders forward at their fan-in completion), so leader and root
    /// ports are priced by a sorted port-law loop — exact for flat and
    /// staged plans on any node map.
    fn cost_gather(&self, plans: &[GatherPlan], cb: usize) -> u64 {
        if self.closed_form {
            let v = closed_gather_cost(plans, cb, self.node_of, self.net);
            self.note_closed_form();
            debug_assert_eq!(
                v,
                critical_path(&gather_wire(plans, cb), self.node_of, self.net),
                "closed-form gather cost must equal the event-driven replay"
            );
            return v;
        }
        self.cost(&gather_wire(plans, cb))
    }

    /// Cost of the pairwise uniform alltoall at chunk size `cb`. Closed
    /// form (uniform blocked layouts only — the O(n²)-event schedule
    /// collapses to two same-instant arrival batches per port);
    /// irregular maps fall back to the replay.
    fn cost_alltoall_flat(&self, cb: usize) -> u64 {
        if self.closed_form {
            if let Some((l, rpn)) = uniform_blocked(self.node_of) {
                let v = closed_alltoall_flat_cost(l, rpn, cb, self.net);
                self.note_closed_form();
                debug_assert_eq!(
                    v,
                    critical_path(&alltoall_flat_wire(self.size, cb), self.node_of, self.net),
                    "closed-form flat-alltoall cost must equal the event-driven replay"
                );
                return v;
            }
        }
        self.cost(&alltoall_flat_wire(self.size, cb))
    }

    /// Cost of the leader-staged uniform alltoall. Closed form (uniform
    /// blocked layout guaranteed by [`TopoCtx::hierarchy`]): three
    /// phase sums over same-instant arrival batches.
    fn cost_alltoall_hier(&self, nodes_list: &[Vec<usize>], cb: usize) -> u64 {
        if self.closed_form {
            let l = nodes_list.len();
            let rpn = nodes_list[0].len();
            let v = closed_alltoall_hier_cost(l, rpn, cb, self.net);
            self.note_closed_form();
            debug_assert_eq!(
                v,
                critical_path(
                    &alltoall_hier_wire(nodes_list, self.size, cb),
                    self.node_of,
                    self.net
                ),
                "closed-form hier-alltoall cost must equal the event-driven replay"
            );
            return v;
        }
        self.cost(&alltoall_hier_wire(nodes_list, self.size, cb))
    }
}

// ---------------------------------------------------------------------
// Tier-3 closed forms. Each computes the *exact* critical path of one
// lowering family without the event heap, by exploiting what the family
// guarantees about port arrival sets. Soundness argument per function;
// every caller debug-asserts equality with `critical_path` (and the
// closed_form_matches_replay test sweeps them against irregular maps,
// both protocols, and rx ∈ {0, 400}).
// ---------------------------------------------------------------------

/// `Some((nodes, ranks_per_node))` when `node_of` is the uniform
/// blocked layout (rank r on node r / rpn). Unlike
/// [`TopoCtx::hierarchy`] this accepts one node or one rank per node —
/// it gates closed forms, not plan shapes.
fn uniform_blocked(node_of: &[usize]) -> Option<(usize, usize)> {
    let n = node_of.len();
    if n == 0 {
        return None;
    }
    let l = *node_of.last().unwrap() + 1;
    if n % l != 0 {
        return None;
    }
    let rpn = n / l;
    for (r, &nd) in node_of.iter().enumerate() {
        if nd != r / rpn {
            return None;
        }
    }
    Some((l, rpn))
}

/// Exact tree (broadcast) critical path. Each rank receives exactly one
/// message, so no port ever queues: a child's receive completes at
/// `parent_done + transfer + rx`, its sends post there, and the
/// critical path is the max completion. Rendezvous senders finish at
/// their last delivery, which is bounded by the max child completion,
/// so the recv side dominates for both protocols.
fn closed_tree_cost(
    parents: &[Option<usize>],
    bytes: usize,
    node_of: &[usize],
    net: &NetworkModel,
) -> u64 {
    let n = parents.len();
    if n <= 1 {
        return 0;
    }
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut root = 0;
    for (r, p) in parents.iter().enumerate() {
        match p {
            Some(p) => children[*p].push(r),
            None => root = r,
        }
    }
    let mut done = vec![0u64; n];
    let mut crit = 0;
    let mut stack = vec![root];
    while let Some(r) = stack.pop() {
        for &c in &children[r] {
            done[c] = done[r] + net.transfer_ns(bytes, node_of[r] == node_of[c]) + net.rx_ns;
            crit = crit.max(done[c]);
            stack.push(c);
        }
    }
    crit
}

/// Exact reduce (fan-in) critical path. Each port receives only from
/// its children, whose send instants are known once their subtrees are
/// priced; serving the arrivals in the replay's order — `(arrival,
/// sender post instant, src)`; the emission tie-break can never be
/// reached with one message per child — through the identical
/// [`PortClock`] law reproduces the heap exactly, bottom-up.
fn closed_reduce_cost(
    plans: &[ReducePlan],
    bytes: usize,
    node_of: &[usize],
    net: &NetworkModel,
) -> u64 {
    let n = plans.len();
    if n <= 1 {
        return 0;
    }
    let mut root = 0;
    for (r, p) in plans.iter().enumerate() {
        if p.parent.is_none() {
            root = r;
        }
    }
    // Parents-first order; iterate reversed for children-first.
    let mut order = Vec::with_capacity(n);
    let mut stack = vec![root];
    while let Some(r) = stack.pop() {
        order.push(r);
        stack.extend(plans[r].children.iter().copied());
    }
    let mut recv_done = vec![0u64; n];
    for &r in order.iter().rev() {
        if plans[r].children.is_empty() {
            continue;
        }
        let mut arrivals: Vec<(u64, u64, usize)> = plans[r]
            .children
            .iter()
            .map(|&c| {
                let t = recv_done[c] + net.transfer_ns(bytes, node_of[c] == node_of[r]);
                (t, recv_done[c], c)
            })
            .collect();
        arrivals.sort_unstable();
        let mut port = PortClock::default();
        let mut done = 0;
        for (arrival, _, _) in arrivals {
            done = port.service(arrival, net.rx_ns);
        }
        recv_done[r] = done;
    }
    recv_done[root]
}

/// Exact gather critical path (flat or leader-staged, any node map).
/// Leaf sends post at 0; a leader's block forwards at its fan-in
/// completion; the root port serves direct chunks and blocks in
/// `(arrival, sender post instant, src)` order. All fan-in ports serve
/// disjoint sender sets, so each is an independent port-law loop.
fn closed_gather_cost(
    plans: &[GatherPlan],
    cb: usize,
    node_of: &[usize],
    net: &NetworkModel,
) -> u64 {
    let mut root = 0;
    let mut leader_done: HashMap<usize, u64> = HashMap::new();
    for (r, p) in plans.iter().enumerate() {
        match p {
            GatherPlan::Root { .. } => root = r,
            GatherPlan::Leader { members, .. } => {
                let mut arrivals: Vec<(u64, u64, usize)> = members
                    .iter()
                    .map(|&m| (net.transfer_ns(cb, node_of[m] == node_of[r]), 0, m))
                    .collect();
                arrivals.sort_unstable();
                let mut port = PortClock::default();
                let mut done = 0;
                for (arrival, _, _) in arrivals {
                    done = port.service(arrival, net.rx_ns);
                }
                leader_done.insert(r, done);
            }
            GatherPlan::Leaf { .. } => {}
        }
    }
    let GatherPlan::Root { direct, blocks } = &plans[root] else {
        return 0;
    };
    let mut arrivals: Vec<(u64, u64, usize)> = direct
        .iter()
        .map(|&s| (net.transfer_ns(cb, node_of[s] == node_of[root]), 0, s))
        .collect();
    for b in blocks {
        let posted = leader_done[&b.leader];
        let t = posted + net.transfer_ns(b.nranks * cb, node_of[b.leader] == node_of[root]);
        arrivals.push((t, posted, b.leader));
    }
    arrivals.sort_unstable();
    let mut port = PortClock::default();
    let mut done = 0;
    for (arrival, _, _) in arrivals {
        done = port.service(arrival, net.rx_ns);
    }
    done
}

/// Exact leader-staged barrier critical path on the uniform blocked
/// layout ([`hier_barrier`]'s three phases). Check-in tokens arrive at
/// every leader port together at `intra(1)`; each dissemination round
/// delivers one token to an idle-again port (`inter(1) > 0` separates
/// the rounds); the release token reaches idle member ports.
fn closed_hier_barrier_cost(l: usize, rpn: usize, net: &NetworkModel) -> u64 {
    let check_in = net.transfer_ns(1, true) + (rpn as u64 - 1) * net.rx_ns;
    let dissem = check_in + ceil_log2(l) * (net.transfer_ns(1, false) + net.rx_ns);
    dissem + net.transfer_ns(1, true) + net.rx_ns
}

/// Exact pairwise uniform-alltoall critical path on the uniform blocked
/// layout. Every port sees two same-instant arrival batches — `rpn - 1`
/// intra chunks and `n - rpn` inter chunks — served batch by batch in
/// arrival order under the port law; by symmetry every rank's last
/// delivery is bounded by its own port's last ready instant, covering
/// rendezvous too.
fn closed_alltoall_flat_cost(l: usize, rpn: usize, cb: usize, net: &NetworkModel) -> u64 {
    let n = l * rpn;
    if n <= 1 {
        return 0;
    }
    let batches = {
        let intra = (net.transfer_ns(cb, true), (rpn - 1) as u64);
        let inter = (net.transfer_ns(cb, false), (n - rpn) as u64);
        if intra.0 <= inter.0 {
            [intra, inter]
        } else {
            [inter, intra]
        }
    };
    let mut busy = 0u64;
    for (arrival, count) in batches {
        if count > 0 {
            busy = busy.max(arrival) + count * net.rx_ns;
        }
    }
    busy
}

/// Exact leader-staged uniform-alltoall critical path
/// ([`alltoall_hier_wire`]'s three phases on the uniform blocked
/// layout): member chunks fan into the leader port together, the
/// leader exchange lands `l - 1` same-instant blocks per leader port,
/// and the return chunks reach otherwise-idle member ports.
fn closed_alltoall_hier_cost(l: usize, rpn: usize, cb: usize, net: &NetworkModel) -> u64 {
    let n = l * rpn;
    let fan_in = net.transfer_ns(n * cb, true) + (rpn as u64 - 1) * net.rx_ns;
    let exchange = fan_in + net.transfer_ns(rpn * rpn * cb, false) + (l as u64 - 1) * net.rx_ns;
    exchange + net.transfer_ns(n * cb, true) + net.rx_ns
}

/// Compile the *cluster plan* for `key`: every rank's role slice at
/// once. This is the unit the [`PlanStore`] caches — selection already
/// builds all-rank candidates, so producing all views costs one
/// selection, not n. Pure: same inputs, same plans — which is what
/// makes the store sound.
pub(crate) fn compile_cluster_plans(key: &SchedKey, ctx: &TopoCtx) -> Vec<CollPlan> {
    let n = ctx.size;
    match (key.kind, key.shape) {
        (CollKind::Barrier, _) => {
            barrier_plans(ctx).into_iter().map(CollPlan::Barrier).collect()
        }
        (CollKind::Bcast, ShapeKey::Bytes(b)) => {
            let parents = bcast_parents_selected(ctx, key.root, b, key.avoid);
            (0..n).map(|r| CollPlan::Bcast(plan_from_parents(&parents, r))).collect()
        }
        // Pinned-order reduce ignores the avoid mask: restructuring its
        // tree would change the floating-point association, which the
        // unmarked op did not permit. Only [`commutative`]-marked
        // combines (`ReduceComm`/`AllreduceComm`) re-root.
        (CollKind::Reduce, _) => (0..n)
            .map(|r| CollPlan::Reduce(flat_reduce_plan(r, n, key.root)))
            .collect(),
        (CollKind::ReduceComm, ShapeKey::Bytes(b)) => {
            reduce_comm_plans(ctx, key.root, b, key.avoid)
                .into_iter()
                .map(CollPlan::Reduce)
                .collect()
        }
        (CollKind::Allreduce, ShapeKey::Bytes(b)) => {
            let parents = bcast_parents_selected(ctx, 0, b, key.avoid);
            (0..n)
                .map(|r| CollPlan::Allreduce {
                    reduce: flat_reduce_plan(r, n, 0),
                    bcast: plan_from_parents(&parents, r),
                })
                .collect()
        }
        (CollKind::AllreduceComm, ShapeKey::Bytes(b)) => {
            let parents = bcast_parents_selected(ctx, 0, b, key.avoid);
            reduce_comm_plans(ctx, 0, b, key.avoid)
                .into_iter()
                .enumerate()
                .map(|(r, reduce)| CollPlan::Allreduce {
                    reduce,
                    bcast: plan_from_parents(&parents, r),
                })
                .collect()
        }
        (CollKind::Gather, ShapeKey::ChunkBytes(cb)) => {
            gather_plans(ctx, key.root, cb).into_iter().map(CollPlan::Gather).collect()
        }
        (CollKind::Alltoall, ShapeKey::ChunkBytes(cb)) => match alltoall_shape(ctx, cb) {
            Some(nodes) => (0..n)
                .map(|r| {
                    let my_node = ctx.node_of[r];
                    CollPlan::AlltoallHier(AlltoallHier {
                        is_leader: r == nodes[my_node][0],
                        my_node,
                        nodes_list: nodes.clone(),
                    })
                })
                .collect(),
            None => (0..n).map(|_| CollPlan::AlltoallvFlat).collect(),
        },
        // Alltoallv counts are per-rank values: basing the plan shape on
        // them would let ranks disagree (deadlock), and leaders cannot
        // size staging buffers without a count exchange — the same
        // reason real MPI ships hierarchical alltoall but not
        // alltoallv. Always pairwise.
        (CollKind::Alltoallv, _) => (0..n).map(|_| CollPlan::AlltoallvFlat).collect(),
        other => unreachable!("inconsistent schedule key: {other:?}"),
    }
}

/// Compile the plan for `key` on `ctx.rank` alone — the store-less
/// path (cache off, fig21's per-rank baseline): full selection, one
/// view kept.
pub(crate) fn compile_plan(key: &SchedKey, ctx: &TopoCtx) -> CollPlan {
    compile_cluster_plans(key, ctx).swap_remove(ctx.rank)
}

/// Compiler-side critical-path estimate of one blocking collective on a
/// `nodes x ranks_per_node` cluster, all ranks entering at t = 0: the
/// virtual instant the last rank's schedule completes. This is the
/// exact quantity the live engine produces for the same run (with CPU
/// call costs zeroed — the estimate prices the wire schedule, not
/// caller-side library overhead), because both go through the identical
/// selection and the identical port law; `tests/net_ports.rs` pins the
/// equality per collective. `payload_bytes` is the buffer byte length
/// (bcast/reduce/allreduce) or the per-rank chunk byte length
/// (gather/alltoall); ignored for barrier. `reduce-comm` /
/// `allreduce-comm` estimate the commutative (re-rootable) variants.
pub fn estimate_critical_path(
    collective: &str,
    root: usize,
    payload_bytes: usize,
    nodes: usize,
    ranks_per_node: usize,
    mode: TopologyMode,
    net: &NetworkModel,
) -> u64 {
    let size = nodes * ranks_per_node;
    let node_of: Vec<usize> = (0..size).map(|r| r / ranks_per_node).collect();
    let ctx = TopoCtx::service(0, size, &node_of, mode, net);
    let b = payload_bytes;
    // Selection already priced the chosen candidate exactly whenever a
    // flat-vs-hier comparison ran; reuse that cost. When nothing was
    // priced (no hierarchy), price the selected — invariably flat —
    // shape through the same tiered oracle.
    match collective {
        "barrier" => {
            let (plans, cost) = barrier_select(&ctx);
            cost.unwrap_or_else(|| ctx.cost_tokens_flat(&plans))
        }
        "bcast" => {
            let (parents, cost) = bcast_select(&ctx, root, b, 0);
            cost.unwrap_or_else(|| ctx.cost_tree(&parents, b))
        }
        "reduce" => ctx.cost_reduce(&flat_reduce_plans(size, root), b),
        "reduce-comm" => {
            let (plans, cost) = reduce_comm_select(&ctx, root, b, 0);
            cost.unwrap_or_else(|| ctx.cost_reduce(&plans, b))
        }
        // The two allreduce phases share ports (a rank's bcast receive
        // queues behind its late reduce fan-in), so the concatenated
        // schedule has no per-phase closed form: always replay it.
        "allreduce" | "allreduce-comm" => {
            let reduce = if collective == "allreduce" {
                flat_reduce_plans(size, 0)
            } else {
                reduce_comm_plans(&ctx, 0, b, 0)
            };
            let mut w = reduce_wire(&reduce, b);
            for (r, tree) in tree_wire(&bcast_parents_selected(&ctx, 0, b, 0), b)
                .into_iter()
                .enumerate()
            {
                w[r].extend(tree);
            }
            ctx.cost(&w)
        }
        "gather" => {
            let (plans, cost) = gather_select(&ctx, root, b);
            cost.unwrap_or_else(|| ctx.cost_gather(&plans, b))
        }
        "alltoall" => {
            let (_, cost) = alltoall_select(&ctx, b);
            cost.unwrap_or_else(|| ctx.cost_alltoall_flat(b))
        }
        other => panic!("unknown collective {other}"),
    }
}

// ---------------------------------------------------------------------
// Wire lowerings: candidate plans -> the net::model IR. Pure structure
// (peers and byte counts per round), mirroring the coll_schedule
// instantiators one-to-one; all timing lives in net::model.
// ---------------------------------------------------------------------

fn token_wire(plans: &[TokenPlan]) -> Vec<Vec<WireRound>> {
    plans
        .iter()
        .map(|p| {
            p.rounds
                .iter()
                .map(|r| WireRound {
                    sends: r.sends.iter().map(|&(to, _)| WireOp { peer: to, bytes: 1 }).collect(),
                    recvs: r
                        .recvs
                        .iter()
                        .map(|&(from, _)| WireOp { peer: from, bytes: 1 })
                        .collect(),
                })
                .collect()
        })
        .collect()
}

/// Tree lowering (broadcast shape): a receive round below the root,
/// then one send round to all children — exactly
/// [`super::coll_schedule::instantiate_bcast`]'s rounds.
fn tree_wire(parents: &[Option<usize>], bytes: usize) -> Vec<Vec<WireRound>> {
    let n = parents.len();
    (0..n)
        .map(|r| {
            if n == 1 {
                return Vec::new();
            }
            let mut rounds = Vec::new();
            if let Some(p) = parents[r] {
                rounds.push(WireRound {
                    sends: vec![],
                    recvs: vec![WireOp { peer: p, bytes }],
                });
            }
            rounds.push(WireRound {
                sends: (0..n)
                    .filter(|&c| parents[c] == Some(r))
                    .map(|c| WireOp { peer: c, bytes })
                    .collect(),
                recvs: vec![],
            });
            rounds
        })
        .collect()
}

/// Reduce lowering: child receives, then the combine/forward round —
/// exactly [`super::coll_schedule::instantiate_reduce`]'s rounds.
fn reduce_wire(plans: &[ReducePlan], bytes: usize) -> Vec<Vec<WireRound>> {
    let n = plans.len();
    plans
        .iter()
        .map(|p| {
            if n == 1 {
                return Vec::new();
            }
            let mut rounds = Vec::new();
            if !p.children.is_empty() {
                rounds.push(WireRound {
                    sends: vec![],
                    recvs: p.children.iter().map(|&c| WireOp { peer: c, bytes }).collect(),
                });
            }
            rounds.push(WireRound {
                sends: p.parent.iter().map(|&pa| WireOp { peer: pa, bytes }).collect(),
                recvs: vec![],
            });
            rounds
        })
        .collect()
}

fn gather_wire(plans: &[GatherPlan], cb: usize) -> Vec<Vec<WireRound>> {
    plans
        .iter()
        .map(|p| match p {
            GatherPlan::Leaf { to } => vec![WireRound {
                sends: vec![WireOp { peer: *to, bytes: cb }],
                recvs: vec![],
            }],
            GatherPlan::Leader { members, root, .. } => vec![
                WireRound {
                    sends: vec![],
                    recvs: members.iter().map(|&m| WireOp { peer: m, bytes: cb }).collect(),
                },
                WireRound {
                    sends: vec![WireOp { peer: *root, bytes: (members.len() + 1) * cb }],
                    recvs: vec![],
                },
            ],
            GatherPlan::Root { direct, blocks } => {
                let mut recvs: Vec<WireOp> =
                    direct.iter().map(|&r| WireOp { peer: r, bytes: cb }).collect();
                recvs.extend(
                    blocks.iter().map(|b| WireOp { peer: b.leader, bytes: b.nranks * cb }),
                );
                vec![WireRound { sends: vec![], recvs }]
            }
        })
        .collect()
}

/// Pairwise uniform alltoall: one round of all-to-all sends/receives
/// (the self chunk is a local copy) — the flat alltoallv shape.
fn alltoall_flat_wire(n: usize, cb: usize) -> Vec<Vec<WireRound>> {
    (0..n)
        .map(|r| {
            vec![WireRound {
                sends: (0..n).filter(|&d| d != r).map(|d| WireOp { peer: d, bytes: cb }).collect(),
                recvs: (0..n).filter(|&s| s != r).map(|s| WireOp { peer: s, bytes: cb }).collect(),
            }]
        })
        .collect()
}

/// Leader-staged uniform alltoall — exactly
/// [`super::coll_schedule::instantiate_alltoall_hier`]'s three phases.
fn alltoall_hier_wire(nodes_list: &[Vec<usize>], n: usize, cb: usize) -> Vec<Vec<WireRound>> {
    let l = nodes_list.len();
    let rpn = nodes_list[0].len();
    (0..n)
        .map(|r| {
            let my_node = r / rpn;
            let leader = nodes_list[my_node][0];
            if r != leader {
                return vec![WireRound {
                    sends: vec![WireOp { peer: leader, bytes: n * cb }],
                    recvs: vec![WireOp { peer: leader, bytes: n * cb }],
                }];
            }
            let members: Vec<usize> = nodes_list[my_node][1..].to_vec();
            let peers: Vec<usize> = (0..l)
                .filter(|&b| b != my_node)
                .map(|b| nodes_list[b][0])
                .collect();
            vec![
                WireRound {
                    sends: vec![],
                    recvs: members.iter().map(|&m| WireOp { peer: m, bytes: n * cb }).collect(),
                },
                WireRound {
                    sends: peers
                        .iter()
                        .map(|&p| WireOp { peer: p, bytes: rpn * rpn * cb })
                        .collect(),
                    recvs: peers
                        .iter()
                        .map(|&p| WireOp { peer: p, bytes: rpn * rpn * cb })
                        .collect(),
                },
                WireRound {
                    sends: members.iter().map(|&m| WireOp { peer: m, bytes: n * cb }).collect(),
                    recvs: vec![],
                },
            ]
        })
        .collect()
}

// ---------------------------------------------------------------------
// Barrier
// ---------------------------------------------------------------------

/// Flat dissemination barrier: round k exchanges a token with the rank
/// `2^k` away (phase = round index).
fn flat_barrier(rank: usize, n: usize) -> TokenPlan {
    let mut rounds = Vec::new();
    let mut d = 1usize;
    let mut phase = 0u32;
    while d < n {
        rounds.push(TokenRound {
            sends: vec![((rank + d) % n, phase)],
            recvs: vec![((rank + n - d) % n, phase)],
        });
        d <<= 1;
        phase += 1;
    }
    TokenPlan { rounds }
}

/// Leader-staged barrier for one rank: members check in with their
/// leader (phase 0), the leaders run a dissemination barrier among
/// themselves (phases 1..=log2(L)), then each leader releases its
/// members (the final phase).
fn hier_barrier(rank: usize, nodes: &[Vec<usize>], node_of: &[usize]) -> TokenPlan {
    let l = nodes.len();
    let my_node = node_of[rank];
    let leaders: Vec<usize> = nodes.iter().map(|m| m[0]).collect();
    let leader = leaders[my_node];
    let release = 1 + ceil_log2(l) as u32;
    if rank != leader {
        return TokenPlan {
            rounds: vec![TokenRound {
                sends: vec![(leader, 0)],
                recvs: vec![(leader, release)],
            }],
        };
    }
    let mut rounds = Vec::new();
    let members: Vec<usize> = nodes[my_node][1..].to_vec();
    rounds.push(TokenRound {
        sends: Vec::new(),
        recvs: members.iter().map(|&m| (m, 0)).collect(),
    });
    let li = my_node;
    let mut d = 1usize;
    let mut phase = 1u32;
    while d < l {
        rounds.push(TokenRound {
            sends: vec![(leaders[(li + d) % l], phase)],
            recvs: vec![(leaders[(li + l - d) % l], phase)],
        });
        d <<= 1;
        phase += 1;
    }
    rounds.push(TokenRound {
        sends: members.iter().map(|&m| (m, release)).collect(),
        recvs: Vec::new(),
    });
    TokenPlan { rounds }
}

/// All-rank barrier plans of the selected shape (flat unless the
/// staged candidate is strictly cheaper), plus the selected shape's
/// exact cost when a comparison priced it (None: no hierarchy, nothing
/// was priced).
fn barrier_select(ctx: &TopoCtx) -> (Vec<TokenPlan>, Option<u64>) {
    let n = ctx.size;
    if n == 1 {
        return (vec![TokenPlan { rounds: Vec::new() }], Some(0));
    }
    let flat: Vec<TokenPlan> = (0..n).map(|r| flat_barrier(r, n)).collect();
    let Some((nodes, rpn)) = ctx.hierarchy() else {
        return (flat, None);
    };
    let hier: Vec<TokenPlan> = (0..n).map(|r| hier_barrier(r, &nodes, ctx.node_of)).collect();
    let ch = ctx.cost_tokens_hier(&hier, nodes.len(), rpn);
    let cf = ctx.cost_tokens_flat(&flat);
    if ch < cf {
        (hier, Some(ch))
    } else {
        (flat, Some(cf))
    }
}

fn barrier_plans(ctx: &TopoCtx) -> Vec<TokenPlan> {
    barrier_select(ctx).0
}

#[cfg(test)]
pub(crate) fn compile_barrier(ctx: &TopoCtx) -> TokenPlan {
    barrier_plans(ctx).swap_remove(ctx.rank)
}

// ---------------------------------------------------------------------
// Bcast
// ---------------------------------------------------------------------

/// Binomial children of position `i` among `m` positions (increasing
/// distance — the fixed combine order), and its parent.
fn binomial_children(i: usize, m: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut k = 1usize;
    while i + k < m && (i & k) == 0 {
        out.push(i + k);
        k <<= 1;
    }
    out
}

fn binomial_parent(i: usize) -> Option<usize> {
    if i == 0 {
        None
    } else {
        Some(i & (i - 1))
    }
}

/// Flat binary broadcast tree in virtual-rank space (PR-3 shape), as a
/// parent array.
fn flat_bcast_parents(n: usize, root: usize) -> Vec<Option<usize>> {
    (0..n)
        .map(|rank| {
            let vr = (rank + n - root) % n;
            if vr == 0 {
                None
            } else {
                Some(((vr - 1) / 2 + root) % n)
            }
        })
        .collect()
}

/// Hierarchical broadcast tree: the root represents its own node,
/// other nodes are represented by their leader; representatives form a
/// binomial tree in virtual-node space and each runs a binomial tree
/// over its node's members.
/// `avoid` (comm-rank bitset) steers representative election: a node's
/// representative is its first member *not* in the mask, so a detected
/// straggler is pushed to a leaf of its node's intra tree and out of
/// every inter-node hop. The root represents its own node regardless —
/// the caller chose it as the data source. A node whose members are all
/// avoided falls back to its first member (someone must relay).
fn hier_bcast_parents(
    n: usize,
    root: usize,
    nodes: &[Vec<usize>],
    node_of: &[usize],
    avoid: u64,
) -> Vec<Option<usize>> {
    let l = nodes.len();
    let root_node = node_of[root];
    let avoided = |r: usize| r < 64 && avoid & (1u64 << r) != 0;
    let rep = |node: usize| {
        if node == root_node {
            root
        } else {
            nodes[node]
                .iter()
                .copied()
                .find(|&m| !avoided(m))
                .unwrap_or(nodes[node][0])
        }
    };
    (0..n)
        .map(|rank| {
            let my_node = node_of[rank];
            if rank == rep(my_node) {
                let vnode = (my_node + l - root_node) % l;
                return binomial_parent(vnode).map(|pv| rep((pv + root_node) % l));
            }
            // Intra order: representative first, then the remaining
            // members ascending — with avoided members pushed to the
            // tail, where the binomial tree keeps them leaf-most (no
            // healthy rank ever waits behind a straggler's forward).
            let mut intra: Vec<usize> = vec![rep(my_node)];
            let rest =
                nodes[my_node].iter().copied().filter(|&r| r != rep(my_node));
            let (slow, fast): (Vec<usize>, Vec<usize>) = rest.partition(|&r| avoided(r));
            intra.extend(fast);
            intra.extend(slow);
            let pos = intra.iter().position(|&r| r == rank).unwrap();
            Some(intra[binomial_parent(pos).unwrap()])
        })
        .collect()
}

/// Plan view of a parent array for one rank: receive from the parent,
/// forward to the children (ascending — sends post concurrently, so
/// the order carries no semantics).
fn plan_from_parents(parents: &[Option<usize>], rank: usize) -> TreePlan {
    TreePlan {
        recv_from: parents[rank],
        send_to: (0..parents.len()).filter(|&c| parents[c] == Some(rank)).collect(),
    }
}

/// The selected broadcast tree as a parent array (with the selected
/// tree's exact cost when a comparison priced it): flat unless the
/// hierarchical tree is strictly cheaper at the exact payload byte
/// size (the shape key carries bytes, not elements).
///
/// A non-zero `avoid` mask overrides the cost race: the wire model
/// prices every rank identically, so it cannot see the *measured*
/// slowness the mask encodes — when a hierarchy exists, the re-rooted
/// hierarchical tree (straggler demoted to a leaf) is taken
/// unconditionally. Without a hierarchy there is nothing to re-root
/// and the flat shape stands.
fn bcast_select(
    ctx: &TopoCtx,
    root: usize,
    bytes: usize,
    avoid: u64,
) -> (Vec<Option<usize>>, Option<u64>) {
    let n = ctx.size;
    if n == 1 {
        return (vec![None], Some(0));
    }
    let flat = flat_bcast_parents(n, root);
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return (flat, None);
    };
    let hier = hier_bcast_parents(n, root, &nodes, ctx.node_of, avoid);
    let ch = ctx.cost_tree(&hier, bytes);
    if avoid != 0 {
        return (hier, Some(ch));
    }
    let cf = ctx.cost_tree(&flat, bytes);
    if ch < cf {
        (hier, Some(ch))
    } else {
        (flat, Some(cf))
    }
}

fn bcast_parents_selected(
    ctx: &TopoCtx,
    root: usize,
    bytes: usize,
    avoid: u64,
) -> Vec<Option<usize>> {
    bcast_select(ctx, root, bytes, avoid).0
}

// ---------------------------------------------------------------------
// Reduce
// ---------------------------------------------------------------------

/// Binomial reduce tree in virtual-rank space. The child order *is* the
/// combine order, and (see module docs) it is pinned for unmarked ops:
/// on blocked layouts with aligned node blocks this tree is already
/// node-hierarchical, and restructuring it otherwise would change the
/// floating-point association. Identical under both topology modes.
fn flat_reduce_plan(rank: usize, n: usize, root: usize) -> ReducePlan {
    if n == 1 {
        return ReducePlan { children: Vec::new(), parent: None };
    }
    let vr = (rank + n - root) % n;
    let children = binomial_children(vr, n).into_iter().map(|c| (c + root) % n).collect();
    let parent = binomial_parent(vr).map(|p| (p + root) % n);
    ReducePlan { children, parent }
}

fn flat_reduce_plans(n: usize, root: usize) -> Vec<ReducePlan> {
    (0..n).map(|r| flat_reduce_plan(r, n, root)).collect()
}

/// Reduce plans from an arbitrary parent tree (the commutative
/// relaxation): children ascending — a deterministic combine order,
/// valid because the op declared reordering safe.
fn reduce_plans_from_parents(parents: &[Option<usize>]) -> Vec<ReducePlan> {
    let n = parents.len();
    (0..n)
        .map(|r| ReducePlan {
            children: (0..n).filter(|&c| parents[c] == Some(r)).collect(),
            parent: parents[r],
        })
        .collect()
}

/// All-rank reduce plans for a [`commutative`] op: the flat binomial
/// tree unless re-rooting through node leaders (the reverse of the
/// hierarchical broadcast tree) is strictly cheaper under the wire
/// replay.
///
/// [`commutative`]: crate::rmpi::collectives::commutative
fn reduce_comm_select(
    ctx: &TopoCtx,
    root: usize,
    bytes: usize,
    avoid: u64,
) -> (Vec<ReducePlan>, Option<u64>) {
    let n = ctx.size;
    let flat = flat_reduce_plans(n, root);
    if n == 1 {
        return (flat, Some(0));
    }
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return (flat, None);
    };
    let hier =
        reduce_plans_from_parents(&hier_bcast_parents(n, root, &nodes, ctx.node_of, avoid));
    let ch = ctx.cost_reduce(&hier, bytes);
    // Same override as `bcast_select`: a non-zero avoid mask encodes
    // measured slowness the wire model cannot price, so the re-rooted
    // tree wins unconditionally.
    if avoid != 0 {
        return (hier, Some(ch));
    }
    let cf = ctx.cost_reduce(&flat, bytes);
    if ch < cf {
        (hier, Some(ch))
    } else {
        (flat, Some(cf))
    }
}

fn reduce_comm_plans(ctx: &TopoCtx, root: usize, bytes: usize, avoid: u64) -> Vec<ReducePlan> {
    reduce_comm_select(ctx, root, bytes, avoid).0
}

// ---------------------------------------------------------------------
// Gather
// ---------------------------------------------------------------------

fn flat_gather_plans(n: usize, root: usize) -> Vec<GatherPlan> {
    (0..n)
        .map(|r| {
            if r == root {
                GatherPlan::Root {
                    direct: (0..n).filter(|&x| x != root).collect(),
                    blocks: Vec::new(),
                }
            } else {
                GatherPlan::Leaf { to: root }
            }
        })
        .collect()
}

/// All-rank gather plans: flat single-hop fan-in unless leader staging
/// is strictly cheaper under the wire replay. Flat pays one inter-node
/// hop but the root's port processes n-1 messages; staging absorbs the
/// fan-in at node leaders, so the root sees one block per node — worth
/// it exactly when per-message processing dominates.
fn gather_select(ctx: &TopoCtx, root: usize, cb: usize) -> (Vec<GatherPlan>, Option<u64>) {
    let n = ctx.size;
    let flat = flat_gather_plans(n, root);
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return (flat, None);
    };
    let root_node = ctx.node_of[root];
    let staged: Vec<GatherPlan> = (0..n)
        .map(|r| {
            let my_node = ctx.node_of[r];
            if r == root {
                GatherPlan::Root {
                    direct: nodes[root_node].iter().copied().filter(|&x| x != root).collect(),
                    blocks: nodes
                        .iter()
                        .enumerate()
                        .filter(|&(b, _)| b != root_node)
                        .map(|(_, members)| GatherBlock {
                            leader: members[0],
                            first_rank: members[0],
                            nranks: members.len(),
                        })
                        .collect(),
                }
            } else if my_node == root_node {
                GatherPlan::Leaf { to: root }
            } else if r == nodes[my_node][0] {
                GatherPlan::Leader {
                    members: nodes[my_node][1..].to_vec(),
                    root,
                    node_base: nodes[my_node][0],
                }
            } else {
                GatherPlan::Leaf { to: nodes[my_node][0] }
            }
        })
        .collect();
    let ch = ctx.cost_gather(&staged, cb);
    let cf = ctx.cost_gather(&flat, cb);
    if ch < cf {
        (staged, Some(ch))
    } else {
        (flat, Some(cf))
    }
}

fn gather_plans(ctx: &TopoCtx, root: usize, cb: usize) -> Vec<GatherPlan> {
    gather_select(ctx, root, cb).0
}

#[cfg(test)]
pub(crate) fn compile_gather(ctx: &TopoCtx, root: usize, cb: usize) -> GatherPlan {
    gather_plans(ctx, root, cb).swap_remove(ctx.rank)
}

// ---------------------------------------------------------------------
// Alltoall
// ---------------------------------------------------------------------

/// `Some(nodes_list)` when the leader-staged uniform alltoall is
/// strictly cheaper than pairwise under the wire replay. Flat: every
/// rank's port processes n-1 incoming messages in one round. Staged:
/// three rounds with inflated payloads but O(rpn + nodes) messages per
/// port.
fn alltoall_select(ctx: &TopoCtx, cb: usize) -> (Option<Vec<Vec<usize>>>, Option<u64>) {
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return (None, None);
    };
    let ch = ctx.cost_alltoall_hier(&nodes, cb);
    let cf = ctx.cost_alltoall_flat(cb);
    if ch < cf {
        (Some(nodes), Some(ch))
    } else {
        (None, Some(cf))
    }
}

fn alltoall_shape(ctx: &TopoCtx, cb: usize) -> Option<Vec<Vec<usize>>> {
    alltoall_select(ctx, cb).0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(
        rank: usize,
        node_of: &'a [usize],
        mode: TopologyMode,
        net: &'a NetworkModel,
    ) -> TopoCtx<'a> {
        TopoCtx::service(rank, node_of.len(), node_of, mode, net)
    }

    fn blocked(nodes: usize, rpn: usize) -> Vec<usize> {
        (0..nodes * rpn).map(|r| r / rpn).collect()
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn hierarchy_degenerates_to_flat() {
        let net = NetworkModel::default();
        // One rank per node: no hierarchy to exploit.
        let node_of = blocked(8, 1);
        for r in 0..8 {
            let c = ctx(r, &node_of, TopologyMode::Hierarchical, &net);
            assert!(c.hierarchy().is_none());
            let p = compile_barrier(&c);
            assert_eq!(p.rounds.len(), 3, "flat dissemination on rank {r}");
        }
        // One node: likewise.
        let node_of = blocked(1, 8);
        assert!(ctx(0, &node_of, TopologyMode::Hierarchical, &net).hierarchy().is_none());
    }

    #[test]
    fn hierarchical_barrier_round_shape() {
        let net = NetworkModel::default();
        let node_of = blocked(4, 4);
        // Leader: check-in + log2(4) dissemination rounds + release.
        let leader = compile_barrier(&ctx(4, &node_of, TopologyMode::Hierarchical, &net));
        assert_eq!(leader.rounds.len(), 1 + 2 + 1);
        // Member: one round (token out, release in).
        let member = compile_barrier(&ctx(5, &node_of, TopologyMode::Hierarchical, &net));
        assert_eq!(member.rounds.len(), 1);
        assert_eq!(member.rounds[0].sends, vec![(4, 0)]);
        assert_eq!(member.rounds[0].recvs, vec![(4, 3)]);
    }

    #[test]
    fn reduce_plan_identical_across_modes() {
        // The pinned-order (unmarked-op) reduce never re-roots: the
        // combine order is a bit-identity contract.
        let node_of = blocked(2, 4);
        for r in 0..8 {
            let f = flat_reduce_plan(r, node_of.len(), 0);
            let key =
                SchedKey { kind: CollKind::Reduce, root: 0, shape: ShapeKey::None, avoid: 0 };
            let net = NetworkModel { rx_ns: 400, ..NetworkModel::default() };
            let c = ctx(r, &node_of, TopologyMode::Hierarchical, &net);
            let CollPlan::Reduce(h) = compile_plan(&key, &c) else {
                panic!("reduce plan")
            };
            assert_eq!(f.children, h.children, "combine order is a contract (rank {r})");
            assert_eq!(f.parent, h.parent);
        }
    }

    #[test]
    fn commutative_reduce_reroots_when_cheaper() {
        // Non-power-of-two ranks-per-node (2 nodes x 6): the flat
        // binomial tree is not node-aligned and chains member partials
        // through serial intra hops, so with per-message processing the
        // leader-rooted tree is strictly cheaper and a commutative op
        // is allowed to take it.
        let node_of = blocked(2, 6);
        let net = NetworkModel { rx_ns: 400, ..NetworkModel::default() };
        let c = ctx(0, &node_of, TopologyMode::Hierarchical, &net);
        let comm = reduce_comm_plans(&c, 0, 8, 0);
        let flat = flat_reduce_plans(node_of.len(), 0);
        let rerooted = (0..node_of.len())
            .any(|r| comm[r].parent != flat[r].parent || comm[r].children != flat[r].children);
        assert!(rerooted, "commutative reduce must re-root in the fan-in regime");
        // Every node-1 member hangs off its leader in the re-rooted
        // tree (flat binomial gives 7 the parent 6 too, but 8's flat
        // parent is 0 — the re-rooted tree pulls it under leader 6).
        assert_eq!(comm[7].parent, Some(6), "member 7 -> leader 6");
        assert_eq!(comm[8].parent, Some(6), "member 8 -> leader 6");
        // The estimate agrees the re-rooted tree is not slower.
        let est_comm = estimate_critical_path(
            "reduce-comm",
            0,
            8,
            2,
            6,
            TopologyMode::Hierarchical,
            &net,
        );
        let est_flat =
            estimate_critical_path("reduce", 0, 8, 2, 6, TopologyMode::Hierarchical, &net);
        assert!(est_comm <= est_flat, "comm {est_comm} vs flat {est_flat}");
    }

    #[test]
    fn gather_stages_only_when_rx_pays() {
        let mut net = NetworkModel::default();
        let node_of = blocked(4, 8);
        // Free receiver processing: flat single-hop wins (8-byte chunk).
        net.rx_ns = 0;
        match compile_gather(&ctx(0, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Root { blocks, direct } => {
                assert!(blocks.is_empty());
                assert_eq!(direct.len(), 31);
            }
            _ => panic!("rank 0 must be the root"),
        }
        // Costly fan-in: the staged plan wins. Set through the
        // back-compat alias on purpose — same knob.
        net.set_coll_rx_ns(400);
        match compile_gather(&ctx(0, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Root { blocks, direct } => {
                assert_eq!(blocks.len(), 3);
                assert_eq!(direct.len(), 7);
            }
            _ => panic!("rank 0 must be the root"),
        }
        // Non-root-node leaders stage; their members send to them.
        match compile_gather(&ctx(8, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Leader { members, root, node_base } => {
                assert_eq!(members, (9..16).collect::<Vec<_>>());
                assert_eq!((root, node_base), (0, 8));
            }
            _ => panic!("rank 8 must lead node 1"),
        }
        match compile_gather(&ctx(9, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Leaf { to } => assert_eq!(to, 8),
            _ => panic!("rank 9 must feed its leader"),
        }
    }

    #[test]
    fn sched_cache_hits_and_misses() {
        let cache = SchedCache::default();
        let key =
            SchedKey { kind: CollKind::Barrier, root: 0, shape: ShapeKey::None, avoid: 0 };
        let (_, hit) = cache
            .get_or_compile(&key, || Arc::new(CollPlan::Barrier(TokenPlan { rounds: vec![] })));
        assert!(!hit);
        let (_, hit) = cache.get_or_compile(&key, || unreachable!("must hit"));
        assert!(hit);
        assert_eq!(cache.len(), 1);
        let key2 =
            SchedKey { kind: CollKind::Bcast, root: 0, shape: ShapeKey::Bytes(32), avoid: 0 };
        let (_, hit) = cache.get_or_compile(&key2, || {
            Arc::new(CollPlan::Bcast(TreePlan { recv_from: None, send_to: vec![] }))
        });
        assert!(!hit);
        assert_eq!(cache.len(), 2);
        // Commutative variants cache under their own kind.
        let key3 = SchedKey {
            kind: CollKind::AllreduceComm,
            root: 0,
            shape: ShapeKey::Bytes(32),
            avoid: 0,
        };
        let (_, hit) = cache.get_or_compile(&key3, || {
            Arc::new(CollPlan::Reduce(ReducePlan { children: vec![], parent: None }))
        });
        assert!(!hit);
        assert_eq!(cache.len(), 3);
    }

    /// Every closed form must equal the event-driven replay — the same
    /// contract the debug asserts enforce, swept explicitly across
    /// regular and irregular node maps, both protocols (the big bcast
    /// payload goes rendezvous), and rx ∈ {0, 400}. The irregular map
    /// exercises the per-edge/per-port DPs off the blocked layout; the
    /// uniform maps exercise the O(1) formulas.
    #[test]
    fn closed_form_matches_replay() {
        let maps: Vec<Vec<usize>> = vec![
            blocked(2, 4),
            blocked(4, 3),
            blocked(8, 1),
            blocked(1, 8),
            vec![0, 0, 0, 1, 1, 2, 2, 2], // irregular: unequal nodes
        ];
        for node_of in &maps {
            let n = node_of.len();
            for rx in [0u64, 400] {
                let net = NetworkModel { rx_ns: rx, ..NetworkModel::default() };
                for mode in [TopologyMode::Flat, TopologyMode::Hierarchical] {
                    let c = ctx(0, node_of, mode, &net);
                    for bytes in [8usize, 128 * 1024] {
                        // Trees: flat and (where defined) hierarchical.
                        let flat_tree = flat_bcast_parents(n, 1 % n);
                        assert_eq!(
                            closed_tree_cost(&flat_tree, bytes, node_of, &net),
                            c.replay(&tree_wire(&flat_tree, bytes)),
                        );
                        // Reduce trees, pinned and re-rooted shapes.
                        let flat_red = flat_reduce_plans(n, 0);
                        assert_eq!(
                            closed_reduce_cost(&flat_red, bytes, node_of, &net),
                            c.replay(&reduce_wire(&flat_red, bytes)),
                        );
                        if let Some((nodes, _)) = c.hierarchy() {
                            let ht = hier_bcast_parents(n, 0, &nodes, node_of, 0);
                            assert_eq!(
                                closed_tree_cost(&ht, bytes, node_of, &net),
                                c.replay(&tree_wire(&ht, bytes)),
                            );
                            let hr = reduce_plans_from_parents(&ht);
                            assert_eq!(
                                closed_reduce_cost(&hr, bytes, node_of, &net),
                                c.replay(&reduce_wire(&hr, bytes)),
                            );
                        }
                        // Gather: flat everywhere, staged under hierarchy.
                        let (gp, _) = gather_select(&c, 0, bytes);
                        assert_eq!(
                            closed_gather_cost(&gp, bytes, node_of, &net),
                            c.replay(&gather_wire(&gp, bytes)),
                        );
                        // Alltoall formulas need the uniform blocked map.
                        if let Some((l, rpn)) = uniform_blocked(node_of) {
                            assert_eq!(
                                closed_alltoall_flat_cost(l, rpn, bytes, &net),
                                c.replay(&alltoall_flat_wire(n, bytes)),
                            );
                        }
                        if let Some((nodes, rpn)) = c.hierarchy() {
                            assert_eq!(
                                closed_alltoall_hier_cost(nodes.len(), rpn, bytes, &net),
                                c.replay(&alltoall_hier_wire(&nodes, n, bytes)),
                            );
                        }
                    }
                    // Barrier formula (hierarchy shapes only).
                    if let Some((nodes, rpn)) = c.hierarchy() {
                        let hb: Vec<TokenPlan> =
                            (0..n).map(|r| hier_barrier(r, &nodes, node_of)).collect();
                        assert_eq!(
                            closed_hier_barrier_cost(nodes.len(), rpn, &net),
                            c.replay(&token_wire(&hb)),
                        );
                    }
                }
            }
        }
    }

    /// The memo returns the exact replay value and stops charging heap
    /// events for repeated schedules; the stats sink sees both sides.
    #[test]
    fn replay_memo_hits_and_counts() {
        let net = NetworkModel { rx_ns: 400, ..NetworkModel::default() };
        let node_of = blocked(2, 4);
        let memo = ReplayMemo::default();
        let stats = CompileStats::default();
        let mut c = ctx(0, &node_of, TopologyMode::Hierarchical, &net);
        c.memo = Some(&memo);
        c.stats = Some(&stats);
        let w = alltoall_flat_wire(8, 64);
        let cold = c.cost(&w);
        let events_after_cold = stats.replay_events();
        assert!(events_after_cold > 0, "cold replay must run the heap");
        assert_eq!(stats.memo_hits(), 0);
        assert_eq!(memo.len(), 1);
        let warm = c.cost(&w);
        assert_eq!(warm, cold, "memo must return the exact replay value");
        assert_eq!(stats.memo_hits(), 1);
        assert_eq!(stats.replay_events(), events_after_cold, "no new heap events on a hit");
        // A different schedule is a different key.
        assert_eq!(c.cost(&alltoall_flat_wire(8, 65)), c.replay(&alltoall_flat_wire(8, 65)));
        assert_eq!(memo.len(), 2);
    }

    /// The store compiles once per key and coalesces every later
    /// lookup; per-rank views are role slices of one cluster plan, and
    /// first_touch fires exactly once per rank.
    #[test]
    fn plan_store_compiles_once() {
        let net = NetworkModel { rx_ns: 400, ..NetworkModel::default() };
        let node_of = blocked(2, 4);
        let store = PlanStore::standalone(&node_of, &net, TopologyMode::Hierarchical);
        let key = SchedKey {
            kind: CollKind::Alltoall,
            root: 0,
            shape: ShapeKey::ChunkBytes(64),
            avoid: 0,
        };
        let mut compiles = 0;
        for rank in 0..node_of.len() {
            let mut c = ctx(rank, &node_of, TopologyMode::Hierarchical, &net);
            c.memo = Some(&store.memo);
            c.stats = Some(&store.stats);
            let (cluster, hit) = store.get_or_compile(key, || {
                compiles += 1;
                compile_cluster_plans(&key, &c)
            });
            assert_eq!(hit, rank != 0);
            assert!(cluster.first_touch(rank), "first touch per rank");
            assert!(!cluster.first_touch(rank), "second touch is not first");
            match &*cluster.view(rank) {
                CollPlan::AlltoallHier(h) => assert_eq!(h.is_leader, rank % 4 == 0),
                CollPlan::AlltoallvFlat => {}
                _ => panic!("alltoall plan expected"),
            }
        }
        assert_eq!(compiles, 1, "one compile cluster-wide");
        assert_eq!(store.len(), 1);
        assert_eq!(store.miss_count(), 1);
        assert_eq!(store.hit_count(), node_of.len() as u64 - 1);
        // A different shape is a different plan.
        let key2 = SchedKey {
            kind: CollKind::Alltoall,
            root: 0,
            shape: ShapeKey::ChunkBytes(8),
            avoid: 0,
        };
        let c = ctx(0, &node_of, TopologyMode::Hierarchical, &net);
        store.get_or_compile(key2, || compile_cluster_plans(&key2, &c));
        assert_eq!(store.len(), 2);
        assert_eq!(store.miss_count(), 2);
    }

    #[test]
    fn uniform_blocked_detection() {
        assert_eq!(uniform_blocked(&blocked(4, 4)), Some((4, 4)));
        assert_eq!(uniform_blocked(&blocked(1, 8)), Some((1, 8)));
        assert_eq!(uniform_blocked(&blocked(8, 1)), Some((8, 1)));
        assert_eq!(uniform_blocked(&[0, 0, 1]), None, "unequal blocks");
        assert_eq!(uniform_blocked(&[0, 1, 0, 1]), None, "interleaved");
        assert_eq!(uniform_blocked(&[]), None);
    }

    #[test]
    fn shape_and_sched_signatures_discriminate() {
        assert_ne!(shape_signature(&blocked(2, 4)), shape_signature(&blocked(4, 2)));
        assert_ne!(shape_signature(&blocked(2, 4)), shape_signature(&blocked(2, 3)));
        let a = sched_sig(&alltoall_flat_wire(8, 64));
        assert_eq!(a, sched_sig(&alltoall_flat_wire(8, 64)), "digest is deterministic");
        assert_ne!(a, sched_sig(&alltoall_flat_wire(8, 65)));
        assert_ne!(a, sched_sig(&alltoall_flat_wire(9, 64)));
    }
}
