//! Topology-aware collective plans and the persistent schedule cache.
//!
//! PR 3's schedule engine treated the cluster as flat: binomial and
//! dissemination rounds crossed the node boundary as cheaply as they
//! stayed inside it, and every collective call recompiled its schedule
//! from scratch. This module separates *what a collective's rounds look
//! like* (a [`CollPlan`]: pure per-rank structure — peers, phases,
//! buffer regions — with no buffers bound) from *running them*
//! ([`super::coll_schedule`] instantiates a plan against the caller's
//! buffers and launches it), which buys two things at once:
//!
//! 1. **Node-hierarchical schedules.** The compiler knows the node
//!    hierarchy ([`super::universe::ClusterConfig`]'s `ranks_per_node`;
//!    the intra- vs inter-node link classes of
//!    [`NetworkModel`]) and emits leader-staged plans — intra-node
//!    gather/reduce to a node leader, an inter-node tree among leaders,
//!    intra-node bcast/scatter fan-out — the shape MPICH's collective
//!    extensions compile (arXiv:2402.12274). Selection is cost-driven:
//!    for each collective the compiler estimates the critical path of
//!    the flat and hierarchical shapes under the universe's
//!    [`NetworkModel`] (link latencies plus the per-message receiver
//!    processing cost `coll_rx_ns`) and picks the cheaper one, so
//!    `TopologyMode::Hierarchical` can never lose to `Flat` by more
//!    than the estimate's error. The estimate uses only values every
//!    rank agrees on (communicator size, node shape, payload shape),
//!    so all ranks of one collective always pick the same plan shape —
//!    a mismatch would deadlock the rounds.
//! 2. **Persistent schedules.** Plans are cached per communicator in a
//!    [`SchedCache`] keyed by `(collective kind, root, shape)` — the
//!    moral equivalent of MPI-4 persistent collectives
//!    (`MPI_Allreduce_init`): the per-iteration residual `iallreduce`
//!    of gauss_seidel/ifsker compiles once and every later call reuses
//!    the compiled rounds. Hits and misses are counted cluster-wide
//!    ([`crate::rmpi::RunStats::sched_cache`]) and each launch is traced as
//!    [`crate::trace::EventKind::CollScheduleCompiled`] `{ cached }`. The
//!    cache lives on the communicator handle, so dropping a
//!    communicator (or `dup`ing a fresh one) drops/starts its schedule
//!    store — the MPI persistent-request lifetime.
//!
//! ## Reduction bit-identity is a contract
//!
//! `reduce`/`allreduce` results must be bit-identical between flat and
//! hierarchical runs (and across delivery modes and wait styles), so
//! the combiner order is pinned to the flat binomial tree's fixed child
//! order. On the blocked rank layout the flat binomial tree is already
//! node-hierarchical whenever the node blocks align with its subtrees
//! (power-of-two ranks-per-node, root on a node boundary — always true
//! for allreduce's internal root-0 reduce): non-leaf edges stay
//! intra-node and leader-to-leader edges carry the inter-node traffic.
//! When the blocks do not align, restructuring the tree would change
//! the combine association (different floating-point rounding), so the
//! compiler keeps the flat tree. The hierarchy win for `allreduce`
//! comes from its broadcast half, which has no combining and may be
//! re-rooted freely.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::net::NetworkModel;

/// How the schedule compiler sees the cluster.
///
/// Carried by `ClusterConfig::topology` (default `Hierarchical`). Flat
/// reproduces the PR-3 schedules exactly; Hierarchical enables the
/// cost-driven node-aware shapes above (degenerating to flat when the
/// cluster has one node, one rank per node, or the estimate says flat
/// is cheaper).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TopologyMode {
    /// Ignore the node boundary (PR-3 behaviour).
    Flat,
    /// Compile node-hierarchical schedules where the network model says
    /// they win.
    #[default]
    Hierarchical,
}

/// Collective algorithm identity (part of the cache key).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) enum CollKind {
    Barrier,
    Bcast,
    Reduce,
    Allreduce,
    Gather,
    Alltoall,
    Alltoallv,
}

/// Payload shape (the rest of the cache key): what a compiled plan
/// depends on besides the algorithm and root — byte sizes, so the
/// critical-path comparison is exact for any element type. Alltoallv
/// carries no shape at all: its counts are per-rank values the plan
/// shape must not depend on (see [`compile_plan`]), so every signature
/// shares the one pairwise plan (and the key stays O(1) — no cloned
/// count vectors in the cache).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) enum ShapeKey {
    /// Shapeless (barrier, alltoallv).
    None,
    /// Byte length of the single buffer (bcast/reduce/allreduce).
    Bytes(usize),
    /// Per-rank chunk byte length (gather, uniform alltoall).
    ChunkBytes(usize),
}

/// Cache key of one compiled schedule: `(collective kind, root, shape)`
/// on one communicator (the cache itself is per-communicator).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) struct SchedKey {
    pub kind: CollKind,
    pub root: usize,
    pub shape: ShapeKey,
}

/// One dissemination/fan round of a token collective (barrier): token
/// sends and receives with their tag phases.
pub(crate) struct TokenRound {
    pub sends: Vec<(usize, u32)>,
    pub recvs: Vec<(usize, u32)>,
}

/// Barrier plan: a list of token rounds.
pub(crate) struct TokenPlan {
    pub rounds: Vec<TokenRound>,
}

/// Broadcast plan: receive the payload from one parent (None at the
/// root), then forward it to a fixed child list in one send round.
pub(crate) struct TreePlan {
    pub recv_from: Option<usize>,
    pub send_to: Vec<usize>,
}

/// Reduce plan: receive child contributions (combined *in this exact
/// order* — the bit-identity contract), then forward the partial to the
/// parent (None at the root).
pub(crate) struct ReducePlan {
    pub children: Vec<usize>,
    pub parent: Option<usize>,
}

/// One aggregated node block arriving at the gather root.
pub(crate) struct GatherBlock {
    pub leader: usize,
    pub first_rank: usize,
    pub nranks: usize,
}

/// Gather plan, by role.
pub(crate) enum GatherPlan {
    /// Send the chunk to `to` (the root, or this node's leader under
    /// the staged plan).
    Leaf { to: usize },
    /// Stage the node's chunks (members excludes self) and forward the
    /// contiguous block to the root.
    Leader { members: Vec<usize>, root: usize, node_base: usize },
    /// Receive direct chunks plus aggregated node blocks.
    Root { direct: Vec<usize>, blocks: Vec<GatherBlock> },
}

/// Leader-staged uniform alltoall plan (flat alltoall(v) needs no plan
/// data beyond the shape; the element chunk binds at instantiation).
pub(crate) struct AlltoallHier {
    /// Rank lists per node, ascending (uniform, contiguous).
    pub nodes_list: Vec<Vec<usize>>,
    pub my_node: usize,
    pub is_leader: bool,
}

/// A compiled per-rank collective plan.
pub(crate) enum CollPlan {
    Barrier(TokenPlan),
    Bcast(TreePlan),
    Reduce(ReducePlan),
    Allreduce { reduce: ReducePlan, bcast: TreePlan },
    Gather(GatherPlan),
    /// Pairwise exchange; shape (counts/displacements) supplied at
    /// instantiation time. Used by alltoallv always and by uniform
    /// alltoall when staging would not pay.
    AlltoallvFlat,
    AlltoallHier(AlltoallHier),
}

/// Per-communicator persistent schedule store (MPI persistent-request
/// analogue). Shared by clones of one rank's communicator handle;
/// `Comm::dup` starts a fresh one and dropping the communicator drops
/// its plans.
#[derive(Default)]
pub(crate) struct SchedCache {
    map: Mutex<HashMap<SchedKey, Arc<CollPlan>>>,
}

impl SchedCache {
    /// Look the key up, compiling (and storing) on a miss. Returns the
    /// plan and whether this was a cache hit.
    pub fn get_or_compile(
        &self,
        key: &SchedKey,
        compile: impl FnOnce() -> CollPlan,
    ) -> (Arc<CollPlan>, bool) {
        let mut g = self.map.lock().unwrap();
        if let Some(p) = g.get(key) {
            return (p.clone(), true);
        }
        let p = Arc::new(compile());
        g.insert(*key, p.clone());
        (p, false)
    }

    /// Distinct plans currently cached.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

/// Everything the compiler may depend on. All fields are identical on
/// every rank except `rank` itself, and plan-shape decisions never use
/// `rank` (only roles derived from it), so all ranks agree on shapes.
pub(crate) struct TopoCtx<'a> {
    pub rank: usize,
    pub size: usize,
    pub node_of: &'a [usize],
    pub mode: TopologyMode,
    pub net: &'a NetworkModel,
}

/// ceil(log2(n)) for n >= 1.
fn ceil_log2(n: usize) -> u64 {
    debug_assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()) as u64
}

impl TopoCtx<'_> {
    /// Rank lists per node, ascending within each node.
    fn nodes_list(&self) -> Vec<Vec<usize>> {
        let n_nodes = self.node_of.iter().copied().max().unwrap_or(0) + 1;
        let mut nodes: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
        for (r, &nd) in self.node_of.iter().enumerate() {
            nodes[nd].push(r);
        }
        nodes
    }

    /// The hierarchy the compiler may exploit: `Some((nodes_list, rpn))`
    /// when hierarchical mode is on and the layout is uniform blocked
    /// (equal-size nodes of contiguous ranks) with more than one node
    /// and more than one rank per node.
    fn hierarchy(&self) -> Option<(Vec<Vec<usize>>, usize)> {
        if self.mode != TopologyMode::Hierarchical {
            return None;
        }
        let nodes = self.nodes_list();
        if nodes.len() < 2 {
            return None;
        }
        let rpn = nodes[0].len();
        if rpn < 2 {
            return None;
        }
        for (b, members) in nodes.iter().enumerate() {
            if members.len() != rpn {
                return None;
            }
            for (i, &r) in members.iter().enumerate() {
                if r != b * rpn + i {
                    return None;
                }
            }
        }
        Some((nodes, rpn))
    }

    fn t_intra(&self, bytes: usize) -> u64 {
        self.net.transfer_ns(bytes, true)
    }

    fn t_inter(&self, bytes: usize) -> u64 {
        self.net.transfer_ns(bytes, false)
    }

    fn rx(&self) -> u64 {
        self.net.coll_rx_ns
    }
}

/// Compile the plan for `key` on `ctx.rank`. Pure: same inputs, same
/// plan — which is what makes the cache sound.
pub(crate) fn compile_plan(key: &SchedKey, ctx: &TopoCtx) -> CollPlan {
    match (key.kind, key.shape) {
        (CollKind::Barrier, _) => CollPlan::Barrier(compile_barrier(ctx)),
        (CollKind::Bcast, ShapeKey::Bytes(b)) => {
            CollPlan::Bcast(compile_bcast(ctx, key.root, b))
        }
        (CollKind::Reduce, _) => CollPlan::Reduce(compile_reduce(ctx, key.root)),
        (CollKind::Allreduce, ShapeKey::Bytes(b)) => CollPlan::Allreduce {
            reduce: compile_reduce(ctx, 0),
            bcast: compile_bcast(ctx, 0, b),
        },
        (CollKind::Gather, ShapeKey::ChunkBytes(cb)) => {
            CollPlan::Gather(compile_gather(ctx, key.root, cb))
        }
        (CollKind::Alltoall, ShapeKey::ChunkBytes(cb)) => compile_alltoall(ctx, cb),
        // Alltoallv counts are per-rank values: basing the plan shape on
        // them would let ranks disagree (deadlock), and leaders cannot
        // size staging buffers without a count exchange — the same
        // reason real MPI ships hierarchical alltoall but not
        // alltoallv. Always pairwise.
        (CollKind::Alltoallv, _) => CollPlan::AlltoallvFlat,
        other => unreachable!("inconsistent schedule key: {other:?}"),
    }
}

// ---------------------------------------------------------------------
// Barrier
// ---------------------------------------------------------------------

/// Flat dissemination barrier: round k exchanges a token with the rank
/// `2^k` away (phase = round index).
fn flat_barrier(rank: usize, n: usize) -> TokenPlan {
    let mut rounds = Vec::new();
    let mut d = 1usize;
    let mut phase = 0u32;
    while d < n {
        rounds.push(TokenRound {
            sends: vec![((rank + d) % n, phase)],
            recvs: vec![((rank + n - d) % n, phase)],
        });
        d <<= 1;
        phase += 1;
    }
    TokenPlan { rounds }
}

/// Exact completion time of the flat dissemination barrier under
/// synchronized entry: per round, a rank's next post waits for the
/// token from `2^k` below (its own send is eager), plus the round's
/// receive processing.
fn flat_barrier_time(ctx: &TopoCtx) -> u64 {
    let n = ctx.size;
    let mut t = vec![0u64; n];
    let mut d = 1usize;
    while d < n {
        let prev = t.clone();
        for (r, tr) in t.iter_mut().enumerate() {
            let src = (r + n - d) % n;
            let hop = if ctx.node_of[src] == ctx.node_of[r] {
                ctx.t_intra(1)
            } else {
                ctx.t_inter(1)
            };
            *tr = (*tr).max(prev[src] + hop) + ctx.rx();
        }
        d <<= 1;
    }
    t.into_iter().max().unwrap_or(0)
}

/// Exact completion time of the leader-staged barrier under
/// synchronized entry (symmetric across nodes, so a closed recurrence).
fn hier_barrier_time(ctx: &TopoCtx, l: usize, rpn: usize) -> u64 {
    let check_in = ctx.t_intra(1) + (rpn as u64 - 1) * ctx.rx();
    let dissemination = ceil_log2(l) * (ctx.t_inter(1) + ctx.rx());
    let release = ctx.t_intra(1) + ctx.rx();
    check_in + dissemination + release
}

fn compile_barrier(ctx: &TopoCtx) -> TokenPlan {
    let n = ctx.size;
    if n == 1 {
        return TokenPlan { rounds: Vec::new() };
    }
    let Some((nodes, rpn)) = ctx.hierarchy() else {
        return flat_barrier(ctx.rank, n);
    };
    let l = nodes.len();
    if hier_barrier_time(ctx, l, rpn) >= flat_barrier_time(ctx) {
        return flat_barrier(ctx.rank, n);
    }
    // Hierarchical: members check in with their leader (phase 0), the
    // leaders run a dissemination barrier among themselves (phases
    // 1..=log2(L)), then each leader releases its members (phase REL).
    let my_node = ctx.node_of[ctx.rank];
    let leaders: Vec<usize> = nodes.iter().map(|m| m[0]).collect();
    let leader = leaders[my_node];
    let release = 1 + ceil_log2(l) as u32;
    if ctx.rank != leader {
        return TokenPlan {
            rounds: vec![TokenRound {
                sends: vec![(leader, 0)],
                recvs: vec![(leader, release)],
            }],
        };
    }
    let mut rounds = Vec::new();
    let members: Vec<usize> = nodes[my_node][1..].to_vec();
    rounds.push(TokenRound {
        sends: Vec::new(),
        recvs: members.iter().map(|&m| (m, 0)).collect(),
    });
    let li = my_node;
    let mut d = 1usize;
    let mut phase = 1u32;
    while d < l {
        rounds.push(TokenRound {
            sends: vec![(leaders[(li + d) % l], phase)],
            recvs: vec![(leaders[(li + l - d) % l], phase)],
        });
        d <<= 1;
        phase += 1;
    }
    rounds.push(TokenRound {
        sends: members.iter().map(|&m| (m, release)).collect(),
        recvs: Vec::new(),
    });
    TokenPlan { rounds }
}

// ---------------------------------------------------------------------
// Bcast
// ---------------------------------------------------------------------

/// Binomial children of position `i` among `m` positions (increasing
/// distance — the fixed combine order), and its parent.
fn binomial_children(i: usize, m: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut k = 1usize;
    while i + k < m && (i & k) == 0 {
        out.push(i + k);
        k <<= 1;
    }
    out
}

fn binomial_parent(i: usize) -> Option<usize> {
    if i == 0 {
        None
    } else {
        Some(i & (i - 1))
    }
}

/// Flat binary broadcast tree in virtual-rank space (PR-3 shape), as a
/// parent array.
fn flat_bcast_parents(n: usize, root: usize) -> Vec<Option<usize>> {
    (0..n)
        .map(|rank| {
            let vr = (rank + n - root) % n;
            if vr == 0 {
                None
            } else {
                Some(((vr - 1) / 2 + root) % n)
            }
        })
        .collect()
}

/// Hierarchical broadcast tree: the root represents its own node,
/// other nodes are represented by their leader; representatives form a
/// binomial tree in virtual-node space and each runs a binomial tree
/// over its node's members.
fn hier_bcast_parents(
    n: usize,
    root: usize,
    nodes: &[Vec<usize>],
    node_of: &[usize],
) -> Vec<Option<usize>> {
    let l = nodes.len();
    let root_node = node_of[root];
    let rep = |node: usize| if node == root_node { root } else { nodes[node][0] };
    (0..n)
        .map(|rank| {
            let my_node = node_of[rank];
            if rank == rep(my_node) {
                let vnode = (my_node + l - root_node) % l;
                return binomial_parent(vnode).map(|pv| rep((pv + root_node) % l));
            }
            // Intra order: representative first, then the remaining
            // members ascending.
            let mut intra: Vec<usize> = vec![rep(my_node)];
            intra.extend(nodes[my_node].iter().copied().filter(|&r| r != rep(my_node)));
            let pos = intra.iter().position(|&r| r == rank).unwrap();
            Some(intra[binomial_parent(pos).unwrap()])
        })
        .collect()
}

/// Exact completion time of a parent-tree broadcast under synchronized
/// entry: each rank receives one transfer (plus its receive-processing
/// charge) after its parent, parents forward to all children
/// concurrently.
fn tree_time(parents: &[Option<usize>], bytes: usize, ctx: &TopoCtx) -> u64 {
    let n = parents.len();
    let mut t: Vec<Option<u64>> = vec![None; n];
    for start in 0..n {
        // Walk up to the nearest resolved ancestor, then fill down.
        let mut chain = Vec::new();
        let mut r = start;
        while t[r].is_none() {
            chain.push(r);
            match parents[r] {
                Some(p) => r = p,
                None => break,
            }
        }
        for &c in chain.iter().rev() {
            t[c] = Some(match parents[c] {
                None => 0,
                Some(p) => {
                    let hop = if ctx.node_of[p] == ctx.node_of[c] {
                        ctx.t_intra(bytes)
                    } else {
                        ctx.t_inter(bytes)
                    };
                    t[p].expect("parent resolved") + hop + ctx.rx()
                }
            });
        }
    }
    (0..n).map(|r| t[r].unwrap_or(0)).max().unwrap_or(0)
}

/// Plan view of a parent array for one rank: receive from the parent,
/// forward to the children (ascending — sends post concurrently, so
/// the order carries no semantics).
fn plan_from_parents(parents: &[Option<usize>], rank: usize) -> TreePlan {
    TreePlan {
        recv_from: parents[rank],
        send_to: (0..parents.len()).filter(|&c| parents[c] == Some(rank)).collect(),
    }
}

fn compile_bcast(ctx: &TopoCtx, root: usize, bytes: usize) -> TreePlan {
    let n = ctx.size;
    if n == 1 {
        return TreePlan { recv_from: None, send_to: Vec::new() };
    }
    let flat = flat_bcast_parents(n, root);
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return plan_from_parents(&flat, ctx.rank);
    };
    // Exact critical paths of both candidate trees at the exact payload
    // byte size (the shape key carries bytes, not elements); ties keep
    // flat.
    let hier = hier_bcast_parents(n, root, &nodes, ctx.node_of);
    if tree_time(&hier, bytes, ctx) < tree_time(&flat, bytes, ctx) {
        plan_from_parents(&hier, ctx.rank)
    } else {
        plan_from_parents(&flat, ctx.rank)
    }
}

// ---------------------------------------------------------------------
// Reduce
// ---------------------------------------------------------------------

/// Binomial reduce tree in virtual-rank space. The child order *is* the
/// combine order, and (see module docs) it is pinned: on blocked
/// layouts with aligned node blocks this tree is already
/// node-hierarchical, and restructuring it otherwise would change the
/// floating-point association. Identical under both topology modes.
fn compile_reduce(ctx: &TopoCtx, root: usize) -> ReducePlan {
    let n = ctx.size;
    if n == 1 {
        return ReducePlan { children: Vec::new(), parent: None };
    }
    let vr = (ctx.rank + n - root) % n;
    let children = binomial_children(vr, n).into_iter().map(|c| (c + root) % n).collect();
    let parent = binomial_parent(vr).map(|p| (p + root) % n);
    ReducePlan { children, parent }
}

// ---------------------------------------------------------------------
// Gather
// ---------------------------------------------------------------------

fn flat_gather(ctx: &TopoCtx, root: usize) -> GatherPlan {
    if ctx.rank == root {
        GatherPlan::Root {
            direct: (0..ctx.size).filter(|&r| r != root).collect(),
            blocks: Vec::new(),
        }
    } else {
        GatherPlan::Leaf { to: root }
    }
}

fn compile_gather(ctx: &TopoCtx, root: usize, cb: usize) -> GatherPlan {
    let n = ctx.size;
    let Some((nodes, rpn)) = ctx.hierarchy() else {
        return flat_gather(ctx, root);
    };
    // Flat: one inter-node hop, but the root processes n-1 messages.
    // Staged: leaders absorb the fan-in, the root sees one block per
    // node — worth it exactly when per-message processing dominates.
    let l = nodes.len();
    let est_flat = ctx.t_inter(cb) + (n as u64 - 1) * ctx.rx();
    let est_hier = ctx.t_intra(cb)
        + (rpn as u64 - 1) * ctx.rx()
        + ctx.t_inter(cb * rpn)
        + ((l as u64 - 1) + (rpn as u64 - 1)) * ctx.rx();
    if est_hier > est_flat {
        return flat_gather(ctx, root);
    }
    let root_node = ctx.node_of[root];
    let my_node = ctx.node_of[ctx.rank];
    if ctx.rank == root {
        let direct = nodes[root_node].iter().copied().filter(|&r| r != root).collect();
        let blocks = nodes
            .iter()
            .enumerate()
            .filter(|&(b, _)| b != root_node)
            .map(|(_, members)| GatherBlock {
                leader: members[0],
                first_rank: members[0],
                nranks: members.len(),
            })
            .collect();
        GatherPlan::Root { direct, blocks }
    } else if my_node == root_node {
        GatherPlan::Leaf { to: root }
    } else if ctx.rank == nodes[my_node][0] {
        GatherPlan::Leader {
            members: nodes[my_node][1..].to_vec(),
            root,
            node_base: nodes[my_node][0],
        }
    } else {
        GatherPlan::Leaf { to: nodes[my_node][0] }
    }
}

// ---------------------------------------------------------------------
// Alltoall
// ---------------------------------------------------------------------

fn compile_alltoall(ctx: &TopoCtx, cb: usize) -> CollPlan {
    let n = ctx.size;
    let Some((nodes, rpn)) = ctx.hierarchy() else {
        return CollPlan::AlltoallvFlat;
    };
    // Flat: every rank processes n-1 incoming messages in one round.
    // Staged: three rounds (members -> leader, leader <-> leader node
    // blocks, leader -> members) with inflated payloads but O(rpn +
    // nodes) messages per processor.
    let l = nodes.len();
    let est_flat = ctx.t_inter(cb) + (n as u64 - 1) * ctx.rx();
    let est_hier = ctx.t_intra(n * cb)
        + (rpn as u64 - 1) * ctx.rx()
        + ctx.t_inter(rpn * rpn * cb)
        + (l as u64 - 1) * ctx.rx()
        + ctx.t_intra(n * cb)
        + (rpn as u64 - 1) * ctx.rx();
    if est_hier > est_flat {
        return CollPlan::AlltoallvFlat;
    }
    let my_node = ctx.node_of[ctx.rank];
    CollPlan::AlltoallHier(AlltoallHier {
        is_leader: ctx.rank == nodes[my_node][0],
        my_node,
        nodes_list: nodes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(
        rank: usize,
        node_of: &'a [usize],
        mode: TopologyMode,
        net: &'a NetworkModel,
    ) -> TopoCtx<'a> {
        TopoCtx { rank, size: node_of.len(), node_of, mode, net }
    }

    fn blocked(nodes: usize, rpn: usize) -> Vec<usize> {
        (0..nodes * rpn).map(|r| r / rpn).collect()
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn hierarchy_degenerates_to_flat() {
        let net = NetworkModel::default();
        // One rank per node: no hierarchy to exploit.
        let node_of = blocked(8, 1);
        for r in 0..8 {
            let c = ctx(r, &node_of, TopologyMode::Hierarchical, &net);
            assert!(c.hierarchy().is_none());
            let p = compile_barrier(&c);
            assert_eq!(p.rounds.len(), 3, "flat dissemination on rank {r}");
        }
        // One node: likewise.
        let node_of = blocked(1, 8);
        assert!(ctx(0, &node_of, TopologyMode::Hierarchical, &net).hierarchy().is_none());
    }

    #[test]
    fn hierarchical_barrier_round_shape() {
        let net = NetworkModel::default();
        let node_of = blocked(4, 4);
        // Leader: check-in + log2(4) dissemination rounds + release.
        let leader = compile_barrier(&ctx(4, &node_of, TopologyMode::Hierarchical, &net));
        assert_eq!(leader.rounds.len(), 1 + 2 + 1);
        // Member: one round (token out, release in).
        let member = compile_barrier(&ctx(5, &node_of, TopologyMode::Hierarchical, &net));
        assert_eq!(member.rounds.len(), 1);
        assert_eq!(member.rounds[0].sends, vec![(4, 0)]);
        assert_eq!(member.rounds[0].recvs, vec![(4, 3)]);
    }

    #[test]
    fn reduce_plan_identical_across_modes() {
        let net = NetworkModel::default();
        let node_of = blocked(2, 4);
        for r in 0..8 {
            let f = compile_reduce(&ctx(r, &node_of, TopologyMode::Flat, &net), 0);
            let h = compile_reduce(&ctx(r, &node_of, TopologyMode::Hierarchical, &net), 0);
            assert_eq!(f.children, h.children, "combine order is a contract (rank {r})");
            assert_eq!(f.parent, h.parent);
        }
    }

    #[test]
    fn gather_stages_only_when_rx_pays() {
        let mut net = NetworkModel::default();
        let node_of = blocked(4, 8);
        // Free receiver processing: flat single-hop wins (8-byte chunk).
        net.coll_rx_ns = 0;
        match compile_gather(&ctx(0, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Root { blocks, direct } => {
                assert!(blocks.is_empty());
                assert_eq!(direct.len(), 31);
            }
            _ => panic!("rank 0 must be the root"),
        }
        // Costly fan-in: the staged plan wins.
        net.coll_rx_ns = 400;
        match compile_gather(&ctx(0, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Root { blocks, direct } => {
                assert_eq!(blocks.len(), 3);
                assert_eq!(direct.len(), 7);
            }
            _ => panic!("rank 0 must be the root"),
        }
        // Non-root-node leaders stage; their members send to them.
        net.coll_rx_ns = 400;
        match compile_gather(&ctx(8, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Leader { members, root, node_base } => {
                assert_eq!(members, (9..16).collect::<Vec<_>>());
                assert_eq!((root, node_base), (0, 8));
            }
            _ => panic!("rank 8 must lead node 1"),
        }
        match compile_gather(&ctx(9, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Leaf { to } => assert_eq!(to, 8),
            _ => panic!("rank 9 must feed its leader"),
        }
    }

    #[test]
    fn sched_cache_hits_and_misses() {
        let cache = SchedCache::default();
        let key = SchedKey { kind: CollKind::Barrier, root: 0, shape: ShapeKey::None };
        let (_, hit) =
            cache.get_or_compile(&key, || CollPlan::Barrier(TokenPlan { rounds: vec![] }));
        assert!(!hit);
        let (_, hit) = cache.get_or_compile(&key, || unreachable!("must hit"));
        assert!(hit);
        assert_eq!(cache.len(), 1);
        let key2 = SchedKey { kind: CollKind::Bcast, root: 0, shape: ShapeKey::Bytes(32) };
        let (_, hit) = cache.get_or_compile(&key2, || {
            CollPlan::Bcast(TreePlan { recv_from: None, send_to: vec![] })
        });
        assert!(!hit);
        assert_eq!(cache.len(), 2);
    }
}
