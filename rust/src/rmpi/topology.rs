//! Topology-aware collective plans and the persistent schedule cache.
//!
//! PR 3's schedule engine treated the cluster as flat: binomial and
//! dissemination rounds crossed the node boundary as cheaply as they
//! stayed inside it, and every collective call recompiled its schedule
//! from scratch. This module separates *what a collective's rounds look
//! like* (a [`CollPlan`]: pure per-rank structure — peers, phases,
//! buffer regions — with no buffers bound) from *running them*
//! ([`super::coll_schedule`] instantiates a plan against the caller's
//! buffers and launches it), which buys two things at once:
//!
//! 1. **Node-hierarchical schedules.** The compiler knows the node
//!    hierarchy ([`super::universe::ClusterConfig`]'s `ranks_per_node`;
//!    the intra- vs inter-node link classes of [`NetworkModel`]) and
//!    emits leader-staged plans — intra-node gather/reduce to a node
//!    leader, an inter-node tree among leaders, intra-node bcast/scatter
//!    fan-out — the shape MPICH's collective extensions compile
//!    (arXiv:2402.12274).
//! 2. **Persistent schedules.** Plans are cached per communicator in a
//!    [`SchedCache`] keyed by `(collective kind, root, shape)` — the
//!    moral equivalent of MPI-4 persistent collectives
//!    (`MPI_Allreduce_init`): the per-iteration residual `iallreduce`
//!    of gauss_seidel/ifsker compiles once and every later call reuses
//!    the compiled rounds. Hits and misses are counted cluster-wide
//!    ([`crate::rmpi::RunStats::sched_cache`]) and each launch is traced as
//!    [`crate::trace::EventKind::CollScheduleCompiled`] `{ cached }`. The
//!    cache lives on the communicator handle, so dropping a
//!    communicator (or `dup`ing a fresh one) drops/starts its schedule
//!    store — the MPI persistent-request lifetime.
//!
//! ## Selection has no cost arithmetic of its own
//!
//! The flat-vs-hierarchical decision *is* the network model: each
//! candidate shape is lowered to the [`WireRound`] IR and replayed
//! through [`super::net::model::critical_path`] — the same link classes
//! and the same ingress-port serialization law
//! ([`super::net::ports::PortClock`]) the live engine charges message
//! by message. There are no closed-form estimates to drift out of sync:
//! compiler-estimated and engine-observed critical paths are equal (the
//! parity test in `tests/net_ports.rs` asserts this exactly, per
//! collective, with and without receiver processing), so
//! `TopologyMode::Hierarchical` can never lose to `Flat`. The replay
//! uses only values every rank agrees on (communicator size, node
//! shape, payload bytes), so all ranks of one collective always pick
//! the same plan shape — a mismatch would deadlock the rounds.
//!
//! The price of exactness is compile cost: selection builds *all-rank*
//! candidate plans and replays full wire schedules (O(n²) events for
//! alltoall), repeated by every rank's first cache miss per shape. The
//! per-communicator [`SchedCache`] amortizes every later call; see the
//! ROADMAP item on sharing the compiled result cluster-wide before
//! scaling rank counts further.
//!
//! ## Reduction bit-identity is a contract — unless the op opts out
//!
//! `reduce`/`allreduce` results must be bit-identical between flat and
//! hierarchical runs (and across delivery modes and wait styles), so
//! the combiner order is pinned to the flat binomial tree's fixed child
//! order. On the blocked rank layout the flat binomial tree is already
//! node-hierarchical whenever the node blocks align with its subtrees
//! (power-of-two ranks-per-node, root on a node boundary — always true
//! for allreduce's internal root-0 reduce): non-leaf edges stay
//! intra-node and leader-to-leader edges carry the inter-node traffic.
//! When the blocks do not align, restructuring the tree would change
//! the combine association (different floating-point rounding), so the
//! compiler keeps the flat tree by default.
//!
//! Ops wrapped in [`crate::rmpi::collectives::Commutative`] (the
//! `commutative()` marker) declare reordering safe, which frees the
//! compiler to re-root the combine tree hierarchically: members combine
//! into their node leader, leaders combine along an inter-node binomial
//! tree (the reverse of the hierarchical broadcast tree). Marked and
//! unmarked ops cache under distinct keys ([`CollKind::ReduceComm`] /
//! [`CollKind::AllreduceComm`]), and unmarked ops keep the flat tree in
//! every topology mode (asserted in tests).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::net::model::critical_path;
use super::net::{NetworkModel, WireOp, WireRound};

/// How the schedule compiler sees the cluster.
///
/// Carried by `ClusterConfig::topology` (default `Hierarchical`). Flat
/// reproduces the PR-3 schedules exactly; Hierarchical enables the
/// cost-driven node-aware shapes above (degenerating to flat when the
/// cluster has one node, one rank per node, or the wire replay says
/// flat is cheaper).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TopologyMode {
    /// Ignore the node boundary (PR-3 behaviour).
    Flat,
    /// Compile node-hierarchical schedules where the network model says
    /// they win.
    #[default]
    Hierarchical,
}

/// Collective algorithm identity (part of the cache key).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) enum CollKind {
    Barrier,
    Bcast,
    Reduce,
    /// Reduce with a [`commutative`](crate::rmpi::collectives::commutative)
    /// op: the combine tree may re-root, so plans are shape-dependent
    /// and cached separately from the pinned-order `Reduce`.
    ReduceComm,
    Allreduce,
    /// Allreduce over a commutative op (re-rootable combine half).
    AllreduceComm,
    Gather,
    Alltoall,
    Alltoallv,
}

/// Payload shape (the rest of the cache key): what a compiled plan
/// depends on besides the algorithm and root — byte sizes, so the
/// critical-path comparison is exact for any element type. Alltoallv
/// carries no shape at all: its counts are per-rank values the plan
/// shape must not depend on (see [`compile_plan`]), so every signature
/// shares the one pairwise plan (and the key stays O(1) — no cloned
/// count vectors in the cache). Pinned-order `Reduce` is also
/// shapeless (its binomial tree depends only on size and root);
/// `ReduceComm` carries bytes because re-rooting is cost-driven.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) enum ShapeKey {
    /// Shapeless (barrier, pinned-order reduce, alltoallv).
    None,
    /// Byte length of the single buffer (bcast/reduce-comm/allreduce).
    Bytes(usize),
    /// Per-rank chunk byte length (gather, uniform alltoall).
    ChunkBytes(usize),
}

/// Cache key of one compiled schedule: `(collective kind, root, shape)`
/// on one communicator (the cache itself is per-communicator).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub(crate) struct SchedKey {
    pub kind: CollKind,
    pub root: usize,
    pub shape: ShapeKey,
}

/// One dissemination/fan round of a token collective (barrier): token
/// sends and receives with their tag phases.
pub(crate) struct TokenRound {
    pub sends: Vec<(usize, u32)>,
    pub recvs: Vec<(usize, u32)>,
}

/// Barrier plan: a list of token rounds.
pub(crate) struct TokenPlan {
    pub rounds: Vec<TokenRound>,
}

/// Broadcast plan: receive the payload from one parent (None at the
/// root), then forward it to a fixed child list in one send round.
pub(crate) struct TreePlan {
    pub recv_from: Option<usize>,
    pub send_to: Vec<usize>,
}

/// Reduce plan: receive child contributions (combined *in this exact
/// order* — the bit-identity contract), then forward the partial to the
/// parent (None at the root).
pub(crate) struct ReducePlan {
    pub children: Vec<usize>,
    pub parent: Option<usize>,
}

/// One aggregated node block arriving at the gather root.
pub(crate) struct GatherBlock {
    pub leader: usize,
    pub first_rank: usize,
    pub nranks: usize,
}

/// Gather plan, by role.
pub(crate) enum GatherPlan {
    /// Send the chunk to `to` (the root, or this node's leader under
    /// the staged plan).
    Leaf { to: usize },
    /// Stage the node's chunks (members excludes self) and forward the
    /// contiguous block to the root.
    Leader { members: Vec<usize>, root: usize, node_base: usize },
    /// Receive direct chunks plus aggregated node blocks.
    Root { direct: Vec<usize>, blocks: Vec<GatherBlock> },
}

/// Leader-staged uniform alltoall plan (flat alltoall(v) needs no plan
/// data beyond the shape; the element chunk binds at instantiation).
pub(crate) struct AlltoallHier {
    /// Rank lists per node, ascending (uniform, contiguous).
    pub nodes_list: Vec<Vec<usize>>,
    pub my_node: usize,
    pub is_leader: bool,
}

/// A compiled per-rank collective plan.
pub(crate) enum CollPlan {
    Barrier(TokenPlan),
    Bcast(TreePlan),
    Reduce(ReducePlan),
    Allreduce { reduce: ReducePlan, bcast: TreePlan },
    Gather(GatherPlan),
    /// Pairwise exchange; shape (counts/displacements) supplied at
    /// instantiation time. Used by alltoallv always and by uniform
    /// alltoall when staging would not pay.
    AlltoallvFlat,
    AlltoallHier(AlltoallHier),
}

/// Per-communicator persistent schedule store (MPI persistent-request
/// analogue). Shared by clones of one rank's communicator handle;
/// `Comm::dup` starts a fresh one and dropping the communicator drops
/// its plans.
#[derive(Default)]
pub(crate) struct SchedCache {
    map: Mutex<HashMap<SchedKey, Arc<CollPlan>>>,
}

impl SchedCache {
    /// Look the key up, compiling (and storing) on a miss. Returns the
    /// plan and whether this was a cache hit.
    pub fn get_or_compile(
        &self,
        key: &SchedKey,
        compile: impl FnOnce() -> CollPlan,
    ) -> (Arc<CollPlan>, bool) {
        let mut g = self.map.lock().unwrap();
        if let Some(p) = g.get(key) {
            return (p.clone(), true);
        }
        let p = Arc::new(compile());
        g.insert(*key, p.clone());
        (p, false)
    }

    /// Distinct plans currently cached.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

/// Everything the compiler may depend on. All fields are identical on
/// every rank except `rank` itself, and plan-shape decisions never use
/// `rank` (only roles derived from it), so all ranks agree on shapes.
pub(crate) struct TopoCtx<'a> {
    pub rank: usize,
    pub size: usize,
    pub node_of: &'a [usize],
    pub mode: TopologyMode,
    pub net: &'a NetworkModel,
}

/// ceil(log2(n)) for n >= 1.
fn ceil_log2(n: usize) -> u64 {
    debug_assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()) as u64
}

impl TopoCtx<'_> {
    /// Rank lists per node, ascending within each node.
    fn nodes_list(&self) -> Vec<Vec<usize>> {
        let n_nodes = self.node_of.iter().copied().max().unwrap_or(0) + 1;
        let mut nodes: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
        for (r, &nd) in self.node_of.iter().enumerate() {
            nodes[nd].push(r);
        }
        nodes
    }

    /// The hierarchy the compiler may exploit: `Some((nodes_list, rpn))`
    /// when hierarchical mode is on and the layout is uniform blocked
    /// (equal-size nodes of contiguous ranks) with more than one node
    /// and more than one rank per node.
    fn hierarchy(&self) -> Option<(Vec<Vec<usize>>, usize)> {
        if self.mode != TopologyMode::Hierarchical {
            return None;
        }
        let nodes = self.nodes_list();
        if nodes.len() < 2 {
            return None;
        }
        let rpn = nodes[0].len();
        if rpn < 2 {
            return None;
        }
        for (b, members) in nodes.iter().enumerate() {
            if members.len() != rpn {
                return None;
            }
            for (i, &r) in members.iter().enumerate() {
                if r != b * rpn + i {
                    return None;
                }
            }
        }
        Some((nodes, rpn))
    }

    /// Replay a candidate's wire schedules through the network model —
    /// the compiler's only cost oracle (see module docs).
    fn cost(&self, scheds: &[Vec<WireRound>]) -> u64 {
        critical_path(scheds, self.node_of, self.net)
    }
}

/// Compile the plan for `key` on `ctx.rank`. Pure: same inputs, same
/// plan — which is what makes the cache sound.
pub(crate) fn compile_plan(key: &SchedKey, ctx: &TopoCtx) -> CollPlan {
    match (key.kind, key.shape) {
        (CollKind::Barrier, _) => {
            CollPlan::Barrier(barrier_plans(ctx).swap_remove(ctx.rank))
        }
        (CollKind::Bcast, ShapeKey::Bytes(b)) => CollPlan::Bcast(plan_from_parents(
            &bcast_parents_selected(ctx, key.root, b),
            ctx.rank,
        )),
        (CollKind::Reduce, _) => {
            CollPlan::Reduce(flat_reduce_plan(ctx.rank, ctx.size, key.root))
        }
        (CollKind::ReduceComm, ShapeKey::Bytes(b)) => {
            CollPlan::Reduce(reduce_comm_plans(ctx, key.root, b).swap_remove(ctx.rank))
        }
        (CollKind::Allreduce, ShapeKey::Bytes(b)) => CollPlan::Allreduce {
            reduce: flat_reduce_plan(ctx.rank, ctx.size, 0),
            bcast: plan_from_parents(&bcast_parents_selected(ctx, 0, b), ctx.rank),
        },
        (CollKind::AllreduceComm, ShapeKey::Bytes(b)) => CollPlan::Allreduce {
            reduce: reduce_comm_plans(ctx, 0, b).swap_remove(ctx.rank),
            bcast: plan_from_parents(&bcast_parents_selected(ctx, 0, b), ctx.rank),
        },
        (CollKind::Gather, ShapeKey::ChunkBytes(cb)) => {
            CollPlan::Gather(gather_plans(ctx, key.root, cb).swap_remove(ctx.rank))
        }
        (CollKind::Alltoall, ShapeKey::ChunkBytes(cb)) => match alltoall_shape(ctx, cb) {
            Some(nodes) => {
                let my_node = ctx.node_of[ctx.rank];
                CollPlan::AlltoallHier(AlltoallHier {
                    is_leader: ctx.rank == nodes[my_node][0],
                    my_node,
                    nodes_list: nodes,
                })
            }
            None => CollPlan::AlltoallvFlat,
        },
        // Alltoallv counts are per-rank values: basing the plan shape on
        // them would let ranks disagree (deadlock), and leaders cannot
        // size staging buffers without a count exchange — the same
        // reason real MPI ships hierarchical alltoall but not
        // alltoallv. Always pairwise.
        (CollKind::Alltoallv, _) => CollPlan::AlltoallvFlat,
        other => unreachable!("inconsistent schedule key: {other:?}"),
    }
}

/// Compiler-side critical-path estimate of one blocking collective on a
/// `nodes x ranks_per_node` cluster, all ranks entering at t = 0: the
/// virtual instant the last rank's schedule completes. This is the
/// exact quantity the live engine produces for the same run (with CPU
/// call costs zeroed — the estimate prices the wire schedule, not
/// caller-side library overhead), because both go through the identical
/// selection and the identical port law; `tests/net_ports.rs` pins the
/// equality per collective. `payload_bytes` is the buffer byte length
/// (bcast/reduce/allreduce) or the per-rank chunk byte length
/// (gather/alltoall); ignored for barrier. `reduce-comm` /
/// `allreduce-comm` estimate the commutative (re-rootable) variants.
pub fn estimate_critical_path(
    collective: &str,
    root: usize,
    payload_bytes: usize,
    nodes: usize,
    ranks_per_node: usize,
    mode: TopologyMode,
    net: &NetworkModel,
) -> u64 {
    let size = nodes * ranks_per_node;
    let node_of: Vec<usize> = (0..size).map(|r| r / ranks_per_node).collect();
    let ctx = TopoCtx { rank: 0, size, node_of: &node_of, mode, net };
    let b = payload_bytes;
    let scheds = match collective {
        "barrier" => token_wire(&barrier_plans(&ctx)),
        "bcast" => tree_wire(&bcast_parents_selected(&ctx, root, b), b),
        "reduce" => reduce_wire(&flat_reduce_plans(size, root), b),
        "reduce-comm" => reduce_wire(&reduce_comm_plans(&ctx, root, b), b),
        "allreduce" | "allreduce-comm" => {
            let reduce = if collective == "allreduce" {
                flat_reduce_plans(size, 0)
            } else {
                reduce_comm_plans(&ctx, 0, b)
            };
            let mut w = reduce_wire(&reduce, b);
            for (r, tree) in tree_wire(&bcast_parents_selected(&ctx, 0, b), b)
                .into_iter()
                .enumerate()
            {
                w[r].extend(tree);
            }
            w
        }
        "gather" => gather_wire(&gather_plans(&ctx, root, b), b),
        "alltoall" => match alltoall_shape(&ctx, b) {
            Some(nodes_list) => alltoall_hier_wire(&nodes_list, size, b),
            None => alltoall_flat_wire(size, b),
        },
        other => panic!("unknown collective {other}"),
    };
    ctx.cost(&scheds)
}

// ---------------------------------------------------------------------
// Wire lowerings: candidate plans -> the net::model IR. Pure structure
// (peers and byte counts per round), mirroring the coll_schedule
// instantiators one-to-one; all timing lives in net::model.
// ---------------------------------------------------------------------

fn token_wire(plans: &[TokenPlan]) -> Vec<Vec<WireRound>> {
    plans
        .iter()
        .map(|p| {
            p.rounds
                .iter()
                .map(|r| WireRound {
                    sends: r.sends.iter().map(|&(to, _)| WireOp { peer: to, bytes: 1 }).collect(),
                    recvs: r
                        .recvs
                        .iter()
                        .map(|&(from, _)| WireOp { peer: from, bytes: 1 })
                        .collect(),
                })
                .collect()
        })
        .collect()
}

/// Tree lowering (broadcast shape): a receive round below the root,
/// then one send round to all children — exactly
/// [`super::coll_schedule::instantiate_bcast`]'s rounds.
fn tree_wire(parents: &[Option<usize>], bytes: usize) -> Vec<Vec<WireRound>> {
    let n = parents.len();
    (0..n)
        .map(|r| {
            if n == 1 {
                return Vec::new();
            }
            let mut rounds = Vec::new();
            if let Some(p) = parents[r] {
                rounds.push(WireRound {
                    sends: vec![],
                    recvs: vec![WireOp { peer: p, bytes }],
                });
            }
            rounds.push(WireRound {
                sends: (0..n)
                    .filter(|&c| parents[c] == Some(r))
                    .map(|c| WireOp { peer: c, bytes })
                    .collect(),
                recvs: vec![],
            });
            rounds
        })
        .collect()
}

/// Reduce lowering: child receives, then the combine/forward round —
/// exactly [`super::coll_schedule::instantiate_reduce`]'s rounds.
fn reduce_wire(plans: &[ReducePlan], bytes: usize) -> Vec<Vec<WireRound>> {
    let n = plans.len();
    plans
        .iter()
        .map(|p| {
            if n == 1 {
                return Vec::new();
            }
            let mut rounds = Vec::new();
            if !p.children.is_empty() {
                rounds.push(WireRound {
                    sends: vec![],
                    recvs: p.children.iter().map(|&c| WireOp { peer: c, bytes }).collect(),
                });
            }
            rounds.push(WireRound {
                sends: p.parent.iter().map(|&pa| WireOp { peer: pa, bytes }).collect(),
                recvs: vec![],
            });
            rounds
        })
        .collect()
}

fn gather_wire(plans: &[GatherPlan], cb: usize) -> Vec<Vec<WireRound>> {
    plans
        .iter()
        .map(|p| match p {
            GatherPlan::Leaf { to } => vec![WireRound {
                sends: vec![WireOp { peer: *to, bytes: cb }],
                recvs: vec![],
            }],
            GatherPlan::Leader { members, root, .. } => vec![
                WireRound {
                    sends: vec![],
                    recvs: members.iter().map(|&m| WireOp { peer: m, bytes: cb }).collect(),
                },
                WireRound {
                    sends: vec![WireOp { peer: *root, bytes: (members.len() + 1) * cb }],
                    recvs: vec![],
                },
            ],
            GatherPlan::Root { direct, blocks } => {
                let mut recvs: Vec<WireOp> =
                    direct.iter().map(|&r| WireOp { peer: r, bytes: cb }).collect();
                recvs.extend(
                    blocks.iter().map(|b| WireOp { peer: b.leader, bytes: b.nranks * cb }),
                );
                vec![WireRound { sends: vec![], recvs }]
            }
        })
        .collect()
}

/// Pairwise uniform alltoall: one round of all-to-all sends/receives
/// (the self chunk is a local copy) — the flat alltoallv shape.
fn alltoall_flat_wire(n: usize, cb: usize) -> Vec<Vec<WireRound>> {
    (0..n)
        .map(|r| {
            vec![WireRound {
                sends: (0..n).filter(|&d| d != r).map(|d| WireOp { peer: d, bytes: cb }).collect(),
                recvs: (0..n).filter(|&s| s != r).map(|s| WireOp { peer: s, bytes: cb }).collect(),
            }]
        })
        .collect()
}

/// Leader-staged uniform alltoall — exactly
/// [`super::coll_schedule::instantiate_alltoall_hier`]'s three phases.
fn alltoall_hier_wire(nodes_list: &[Vec<usize>], n: usize, cb: usize) -> Vec<Vec<WireRound>> {
    let l = nodes_list.len();
    let rpn = nodes_list[0].len();
    (0..n)
        .map(|r| {
            let my_node = r / rpn;
            let leader = nodes_list[my_node][0];
            if r != leader {
                return vec![WireRound {
                    sends: vec![WireOp { peer: leader, bytes: n * cb }],
                    recvs: vec![WireOp { peer: leader, bytes: n * cb }],
                }];
            }
            let members: Vec<usize> = nodes_list[my_node][1..].to_vec();
            let peers: Vec<usize> = (0..l)
                .filter(|&b| b != my_node)
                .map(|b| nodes_list[b][0])
                .collect();
            vec![
                WireRound {
                    sends: vec![],
                    recvs: members.iter().map(|&m| WireOp { peer: m, bytes: n * cb }).collect(),
                },
                WireRound {
                    sends: peers
                        .iter()
                        .map(|&p| WireOp { peer: p, bytes: rpn * rpn * cb })
                        .collect(),
                    recvs: peers
                        .iter()
                        .map(|&p| WireOp { peer: p, bytes: rpn * rpn * cb })
                        .collect(),
                },
                WireRound {
                    sends: members.iter().map(|&m| WireOp { peer: m, bytes: n * cb }).collect(),
                    recvs: vec![],
                },
            ]
        })
        .collect()
}

// ---------------------------------------------------------------------
// Barrier
// ---------------------------------------------------------------------

/// Flat dissemination barrier: round k exchanges a token with the rank
/// `2^k` away (phase = round index).
fn flat_barrier(rank: usize, n: usize) -> TokenPlan {
    let mut rounds = Vec::new();
    let mut d = 1usize;
    let mut phase = 0u32;
    while d < n {
        rounds.push(TokenRound {
            sends: vec![((rank + d) % n, phase)],
            recvs: vec![((rank + n - d) % n, phase)],
        });
        d <<= 1;
        phase += 1;
    }
    TokenPlan { rounds }
}

/// Leader-staged barrier for one rank: members check in with their
/// leader (phase 0), the leaders run a dissemination barrier among
/// themselves (phases 1..=log2(L)), then each leader releases its
/// members (the final phase).
fn hier_barrier(rank: usize, nodes: &[Vec<usize>], node_of: &[usize]) -> TokenPlan {
    let l = nodes.len();
    let my_node = node_of[rank];
    let leaders: Vec<usize> = nodes.iter().map(|m| m[0]).collect();
    let leader = leaders[my_node];
    let release = 1 + ceil_log2(l) as u32;
    if rank != leader {
        return TokenPlan {
            rounds: vec![TokenRound {
                sends: vec![(leader, 0)],
                recvs: vec![(leader, release)],
            }],
        };
    }
    let mut rounds = Vec::new();
    let members: Vec<usize> = nodes[my_node][1..].to_vec();
    rounds.push(TokenRound {
        sends: Vec::new(),
        recvs: members.iter().map(|&m| (m, 0)).collect(),
    });
    let li = my_node;
    let mut d = 1usize;
    let mut phase = 1u32;
    while d < l {
        rounds.push(TokenRound {
            sends: vec![(leaders[(li + d) % l], phase)],
            recvs: vec![(leaders[(li + l - d) % l], phase)],
        });
        d <<= 1;
        phase += 1;
    }
    rounds.push(TokenRound {
        sends: members.iter().map(|&m| (m, release)).collect(),
        recvs: Vec::new(),
    });
    TokenPlan { rounds }
}

/// All-rank barrier plans of the selected shape (flat unless the
/// staged candidate's wire replay is strictly cheaper).
fn barrier_plans(ctx: &TopoCtx) -> Vec<TokenPlan> {
    let n = ctx.size;
    if n == 1 {
        return vec![TokenPlan { rounds: Vec::new() }];
    }
    let flat: Vec<TokenPlan> = (0..n).map(|r| flat_barrier(r, n)).collect();
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return flat;
    };
    let hier: Vec<TokenPlan> = (0..n).map(|r| hier_barrier(r, &nodes, ctx.node_of)).collect();
    if ctx.cost(&token_wire(&hier)) < ctx.cost(&token_wire(&flat)) {
        hier
    } else {
        flat
    }
}

#[cfg(test)]
pub(crate) fn compile_barrier(ctx: &TopoCtx) -> TokenPlan {
    barrier_plans(ctx).swap_remove(ctx.rank)
}

// ---------------------------------------------------------------------
// Bcast
// ---------------------------------------------------------------------

/// Binomial children of position `i` among `m` positions (increasing
/// distance — the fixed combine order), and its parent.
fn binomial_children(i: usize, m: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut k = 1usize;
    while i + k < m && (i & k) == 0 {
        out.push(i + k);
        k <<= 1;
    }
    out
}

fn binomial_parent(i: usize) -> Option<usize> {
    if i == 0 {
        None
    } else {
        Some(i & (i - 1))
    }
}

/// Flat binary broadcast tree in virtual-rank space (PR-3 shape), as a
/// parent array.
fn flat_bcast_parents(n: usize, root: usize) -> Vec<Option<usize>> {
    (0..n)
        .map(|rank| {
            let vr = (rank + n - root) % n;
            if vr == 0 {
                None
            } else {
                Some(((vr - 1) / 2 + root) % n)
            }
        })
        .collect()
}

/// Hierarchical broadcast tree: the root represents its own node,
/// other nodes are represented by their leader; representatives form a
/// binomial tree in virtual-node space and each runs a binomial tree
/// over its node's members.
fn hier_bcast_parents(
    n: usize,
    root: usize,
    nodes: &[Vec<usize>],
    node_of: &[usize],
) -> Vec<Option<usize>> {
    let l = nodes.len();
    let root_node = node_of[root];
    let rep = |node: usize| if node == root_node { root } else { nodes[node][0] };
    (0..n)
        .map(|rank| {
            let my_node = node_of[rank];
            if rank == rep(my_node) {
                let vnode = (my_node + l - root_node) % l;
                return binomial_parent(vnode).map(|pv| rep((pv + root_node) % l));
            }
            // Intra order: representative first, then the remaining
            // members ascending.
            let mut intra: Vec<usize> = vec![rep(my_node)];
            intra.extend(nodes[my_node].iter().copied().filter(|&r| r != rep(my_node)));
            let pos = intra.iter().position(|&r| r == rank).unwrap();
            Some(intra[binomial_parent(pos).unwrap()])
        })
        .collect()
}

/// Plan view of a parent array for one rank: receive from the parent,
/// forward to the children (ascending — sends post concurrently, so
/// the order carries no semantics).
fn plan_from_parents(parents: &[Option<usize>], rank: usize) -> TreePlan {
    TreePlan {
        recv_from: parents[rank],
        send_to: (0..parents.len()).filter(|&c| parents[c] == Some(rank)).collect(),
    }
}

/// The selected broadcast tree as a parent array: flat unless the
/// hierarchical tree's wire replay is strictly cheaper at the exact
/// payload byte size (the shape key carries bytes, not elements).
fn bcast_parents_selected(ctx: &TopoCtx, root: usize, bytes: usize) -> Vec<Option<usize>> {
    let n = ctx.size;
    if n == 1 {
        return vec![None];
    }
    let flat = flat_bcast_parents(n, root);
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return flat;
    };
    let hier = hier_bcast_parents(n, root, &nodes, ctx.node_of);
    if ctx.cost(&tree_wire(&hier, bytes)) < ctx.cost(&tree_wire(&flat, bytes)) {
        hier
    } else {
        flat
    }
}

// ---------------------------------------------------------------------
// Reduce
// ---------------------------------------------------------------------

/// Binomial reduce tree in virtual-rank space. The child order *is* the
/// combine order, and (see module docs) it is pinned for unmarked ops:
/// on blocked layouts with aligned node blocks this tree is already
/// node-hierarchical, and restructuring it otherwise would change the
/// floating-point association. Identical under both topology modes.
fn flat_reduce_plan(rank: usize, n: usize, root: usize) -> ReducePlan {
    if n == 1 {
        return ReducePlan { children: Vec::new(), parent: None };
    }
    let vr = (rank + n - root) % n;
    let children = binomial_children(vr, n).into_iter().map(|c| (c + root) % n).collect();
    let parent = binomial_parent(vr).map(|p| (p + root) % n);
    ReducePlan { children, parent }
}

fn flat_reduce_plans(n: usize, root: usize) -> Vec<ReducePlan> {
    (0..n).map(|r| flat_reduce_plan(r, n, root)).collect()
}

/// Reduce plans from an arbitrary parent tree (the commutative
/// relaxation): children ascending — a deterministic combine order,
/// valid because the op declared reordering safe.
fn reduce_plans_from_parents(parents: &[Option<usize>]) -> Vec<ReducePlan> {
    let n = parents.len();
    (0..n)
        .map(|r| ReducePlan {
            children: (0..n).filter(|&c| parents[c] == Some(r)).collect(),
            parent: parents[r],
        })
        .collect()
}

/// All-rank reduce plans for a [`commutative`] op: the flat binomial
/// tree unless re-rooting through node leaders (the reverse of the
/// hierarchical broadcast tree) is strictly cheaper under the wire
/// replay.
///
/// [`commutative`]: crate::rmpi::collectives::commutative
fn reduce_comm_plans(ctx: &TopoCtx, root: usize, bytes: usize) -> Vec<ReducePlan> {
    let n = ctx.size;
    let flat = flat_reduce_plans(n, root);
    if n == 1 {
        return flat;
    }
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return flat;
    };
    let hier = reduce_plans_from_parents(&hier_bcast_parents(n, root, &nodes, ctx.node_of));
    if ctx.cost(&reduce_wire(&hier, bytes)) < ctx.cost(&reduce_wire(&flat, bytes)) {
        hier
    } else {
        flat
    }
}

// ---------------------------------------------------------------------
// Gather
// ---------------------------------------------------------------------

fn flat_gather_plans(n: usize, root: usize) -> Vec<GatherPlan> {
    (0..n)
        .map(|r| {
            if r == root {
                GatherPlan::Root {
                    direct: (0..n).filter(|&x| x != root).collect(),
                    blocks: Vec::new(),
                }
            } else {
                GatherPlan::Leaf { to: root }
            }
        })
        .collect()
}

/// All-rank gather plans: flat single-hop fan-in unless leader staging
/// is strictly cheaper under the wire replay. Flat pays one inter-node
/// hop but the root's port processes n-1 messages; staging absorbs the
/// fan-in at node leaders, so the root sees one block per node — worth
/// it exactly when per-message processing dominates.
fn gather_plans(ctx: &TopoCtx, root: usize, cb: usize) -> Vec<GatherPlan> {
    let n = ctx.size;
    let flat = flat_gather_plans(n, root);
    let Some((nodes, _rpn)) = ctx.hierarchy() else {
        return flat;
    };
    let root_node = ctx.node_of[root];
    let staged: Vec<GatherPlan> = (0..n)
        .map(|r| {
            let my_node = ctx.node_of[r];
            if r == root {
                GatherPlan::Root {
                    direct: nodes[root_node].iter().copied().filter(|&x| x != root).collect(),
                    blocks: nodes
                        .iter()
                        .enumerate()
                        .filter(|&(b, _)| b != root_node)
                        .map(|(_, members)| GatherBlock {
                            leader: members[0],
                            first_rank: members[0],
                            nranks: members.len(),
                        })
                        .collect(),
                }
            } else if my_node == root_node {
                GatherPlan::Leaf { to: root }
            } else if r == nodes[my_node][0] {
                GatherPlan::Leader {
                    members: nodes[my_node][1..].to_vec(),
                    root,
                    node_base: nodes[my_node][0],
                }
            } else {
                GatherPlan::Leaf { to: nodes[my_node][0] }
            }
        })
        .collect();
    if ctx.cost(&gather_wire(&staged, cb)) < ctx.cost(&gather_wire(&flat, cb)) {
        staged
    } else {
        flat
    }
}

#[cfg(test)]
pub(crate) fn compile_gather(ctx: &TopoCtx, root: usize, cb: usize) -> GatherPlan {
    gather_plans(ctx, root, cb).swap_remove(ctx.rank)
}

// ---------------------------------------------------------------------
// Alltoall
// ---------------------------------------------------------------------

/// `Some(nodes_list)` when the leader-staged uniform alltoall is
/// strictly cheaper than pairwise under the wire replay. Flat: every
/// rank's port processes n-1 incoming messages in one round. Staged:
/// three rounds with inflated payloads but O(rpn + nodes) messages per
/// port.
fn alltoall_shape(ctx: &TopoCtx, cb: usize) -> Option<Vec<Vec<usize>>> {
    let n = ctx.size;
    let (nodes, _rpn) = ctx.hierarchy()?;
    let hier = alltoall_hier_wire(&nodes, n, cb);
    if ctx.cost(&hier) < ctx.cost(&alltoall_flat_wire(n, cb)) {
        Some(nodes)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(
        rank: usize,
        node_of: &'a [usize],
        mode: TopologyMode,
        net: &'a NetworkModel,
    ) -> TopoCtx<'a> {
        TopoCtx { rank, size: node_of.len(), node_of, mode, net }
    }

    fn blocked(nodes: usize, rpn: usize) -> Vec<usize> {
        (0..nodes * rpn).map(|r| r / rpn).collect()
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn hierarchy_degenerates_to_flat() {
        let net = NetworkModel::default();
        // One rank per node: no hierarchy to exploit.
        let node_of = blocked(8, 1);
        for r in 0..8 {
            let c = ctx(r, &node_of, TopologyMode::Hierarchical, &net);
            assert!(c.hierarchy().is_none());
            let p = compile_barrier(&c);
            assert_eq!(p.rounds.len(), 3, "flat dissemination on rank {r}");
        }
        // One node: likewise.
        let node_of = blocked(1, 8);
        assert!(ctx(0, &node_of, TopologyMode::Hierarchical, &net).hierarchy().is_none());
    }

    #[test]
    fn hierarchical_barrier_round_shape() {
        let net = NetworkModel::default();
        let node_of = blocked(4, 4);
        // Leader: check-in + log2(4) dissemination rounds + release.
        let leader = compile_barrier(&ctx(4, &node_of, TopologyMode::Hierarchical, &net));
        assert_eq!(leader.rounds.len(), 1 + 2 + 1);
        // Member: one round (token out, release in).
        let member = compile_barrier(&ctx(5, &node_of, TopologyMode::Hierarchical, &net));
        assert_eq!(member.rounds.len(), 1);
        assert_eq!(member.rounds[0].sends, vec![(4, 0)]);
        assert_eq!(member.rounds[0].recvs, vec![(4, 3)]);
    }

    #[test]
    fn reduce_plan_identical_across_modes() {
        // The pinned-order (unmarked-op) reduce never re-roots: the
        // combine order is a bit-identity contract.
        let node_of = blocked(2, 4);
        for r in 0..8 {
            let f = flat_reduce_plan(r, node_of.len(), 0);
            let key = SchedKey { kind: CollKind::Reduce, root: 0, shape: ShapeKey::None };
            let net = NetworkModel { rx_ns: 400, ..NetworkModel::default() };
            let c = ctx(r, &node_of, TopologyMode::Hierarchical, &net);
            let CollPlan::Reduce(h) = compile_plan(&key, &c) else {
                panic!("reduce plan")
            };
            assert_eq!(f.children, h.children, "combine order is a contract (rank {r})");
            assert_eq!(f.parent, h.parent);
        }
    }

    #[test]
    fn commutative_reduce_reroots_when_cheaper() {
        // Non-power-of-two ranks-per-node (2 nodes x 6): the flat
        // binomial tree is not node-aligned and chains member partials
        // through serial intra hops, so with per-message processing the
        // leader-rooted tree is strictly cheaper and a commutative op
        // is allowed to take it.
        let node_of = blocked(2, 6);
        let net = NetworkModel { rx_ns: 400, ..NetworkModel::default() };
        let c = ctx(0, &node_of, TopologyMode::Hierarchical, &net);
        let comm = reduce_comm_plans(&c, 0, 8);
        let flat = flat_reduce_plans(node_of.len(), 0);
        let rerooted = (0..node_of.len())
            .any(|r| comm[r].parent != flat[r].parent || comm[r].children != flat[r].children);
        assert!(rerooted, "commutative reduce must re-root in the fan-in regime");
        // Every node-1 member hangs off its leader in the re-rooted
        // tree (flat binomial gives 7 the parent 6 too, but 8's flat
        // parent is 0 — the re-rooted tree pulls it under leader 6).
        assert_eq!(comm[7].parent, Some(6), "member 7 -> leader 6");
        assert_eq!(comm[8].parent, Some(6), "member 8 -> leader 6");
        // The estimate agrees the re-rooted tree is not slower.
        let est_comm = estimate_critical_path(
            "reduce-comm",
            0,
            8,
            2,
            6,
            TopologyMode::Hierarchical,
            &net,
        );
        let est_flat =
            estimate_critical_path("reduce", 0, 8, 2, 6, TopologyMode::Hierarchical, &net);
        assert!(est_comm <= est_flat, "comm {est_comm} vs flat {est_flat}");
    }

    #[test]
    fn gather_stages_only_when_rx_pays() {
        let mut net = NetworkModel::default();
        let node_of = blocked(4, 8);
        // Free receiver processing: flat single-hop wins (8-byte chunk).
        net.rx_ns = 0;
        match compile_gather(&ctx(0, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Root { blocks, direct } => {
                assert!(blocks.is_empty());
                assert_eq!(direct.len(), 31);
            }
            _ => panic!("rank 0 must be the root"),
        }
        // Costly fan-in: the staged plan wins. Set through the
        // back-compat alias on purpose — same knob.
        net.set_coll_rx_ns(400);
        match compile_gather(&ctx(0, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Root { blocks, direct } => {
                assert_eq!(blocks.len(), 3);
                assert_eq!(direct.len(), 7);
            }
            _ => panic!("rank 0 must be the root"),
        }
        // Non-root-node leaders stage; their members send to them.
        match compile_gather(&ctx(8, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Leader { members, root, node_base } => {
                assert_eq!(members, (9..16).collect::<Vec<_>>());
                assert_eq!((root, node_base), (0, 8));
            }
            _ => panic!("rank 8 must lead node 1"),
        }
        match compile_gather(&ctx(9, &node_of, TopologyMode::Hierarchical, &net), 0, 8) {
            GatherPlan::Leaf { to } => assert_eq!(to, 8),
            _ => panic!("rank 9 must feed its leader"),
        }
    }

    #[test]
    fn sched_cache_hits_and_misses() {
        let cache = SchedCache::default();
        let key = SchedKey { kind: CollKind::Barrier, root: 0, shape: ShapeKey::None };
        let (_, hit) =
            cache.get_or_compile(&key, || CollPlan::Barrier(TokenPlan { rounds: vec![] }));
        assert!(!hit);
        let (_, hit) = cache.get_or_compile(&key, || unreachable!("must hit"));
        assert!(hit);
        assert_eq!(cache.len(), 1);
        let key2 = SchedKey { kind: CollKind::Bcast, root: 0, shape: ShapeKey::Bytes(32) };
        let (_, hit) = cache.get_or_compile(&key2, || {
            CollPlan::Bcast(TreePlan { recv_from: None, send_to: vec![] })
        });
        assert!(!hit);
        assert_eq!(cache.len(), 2);
        // Commutative variants cache under their own kind.
        let key3 =
            SchedKey { kind: CollKind::AllreduceComm, root: 0, shape: ShapeKey::Bytes(32) };
        let (_, hit) = cache.get_or_compile(&key3, || {
            CollPlan::Reduce(ReducePlan { children: vec![], parent: None })
        });
        assert!(!hit);
        assert_eq!(cache.len(), 3);
    }
}
