//! The schedule-driven collective engine.
//!
//! Every collective runs from a compiled plan ([`super::topology`]): a
//! per-rank list of *rounds*, where each round posts a set of
//! point-to-point operations (sends, receives, local copies, reduction
//! combines) and the next round is posted when the previous round's
//! completions fire through [`Request::on_complete`]. The caller gets
//! back a single [`CollRequest`] the moment round 0 is posted; from
//! then on the *progress engine* drives the collective:
//!
//! * under [`crate::progress::DeliveryMode::Sharded`] the round's
//!   completion wave lands as one batch on the owning rank's shard and
//!   the drain (on the clock thread) advances the schedule;
//! * under `Direct` the continuations fire inline at each completion
//!   point — same virtual instants, same data, different real threads.
//!
//! No OS thread ever parks inside a collective round. This is what makes
//! the non-blocking surface (`ibarrier`/`ibcast`/`iallreduce`/…,
//! Section 6.1's interception extended to collectives) possible: the
//! returned `CollRequest` composes with [`Request::wait`] /
//! [`Request::wait_any`], with TAMPI `iwait`/`iwaitall` (task
//! external-event binding, Section 6.2), and with plain `test`. The
//! blocking entry points in [`super::collectives`] are thin wrappers
//! that launch a schedule and wait on its final request — one engine
//! serves both paths, so Direct-vs-Sharded and blocking-vs-non-blocking
//! runs stay bit-identical in application results.
//!
//! ## Compile once, instantiate per call
//!
//! Plans carry no buffers — just peers, phases and regions — so they
//! persist in the communicator's plan index
//! ([`super::topology::SchedCache`], the MPI persistent-collective
//! analogue); the index entries are per-rank views of cluster plans
//! compiled once per universe by the plan compilation service
//! ([`super::topology::PlanStore`] — see `topology`'s three-tier
//! story), and each call only *instantiates* the plan against the
//! caller's buffers and a fresh sequence number. Each launch is traced
//! as [`EventKind::CollScheduleCompiled`] `{ cached }`, each round
//! advance as [`EventKind::CollRoundAdvanced`]; both carry the
//! `(comm, seq)` identity that the stall diagnostic
//! ([`crate::trace::stalls`]) groups by.
//!
//! ## Rounds, tags and determinism
//!
//! Each collective call consumes one sequence number per phase group
//! from the communicator's collective counter ([`coll_tag`] packs
//! `(seq, phase)` into an `i32` tag), so any number of collectives may
//! be in flight on one communicator: messages of different calls,
//! rounds or hierarchy stages can never be confused because every
//! `(source, tag)` pair in a schedule is unique. Reduction combiners
//! run at a fixed child order (the binomial-tree order pinned by the
//! plan compiler — see the bit-identity contract in
//! [`super::topology`]), independent of arrival order and of the
//! topology mode, so floating-point results are bit-identical across
//! delivery modes, wait styles and flat/hierarchical schedules.
//!
//! ## Virtual-time accounting
//!
//! Rounds after the first are posted by whichever thread delivers the
//! last completion of the previous round (a rank thread under `Direct`,
//! the clock thread under `Sharded`). The per-call CPU debt those posts
//! would accrue is discarded uniformly ([`CollSchedule::advance`]): the
//! engine models an asynchronous progress thread (the shape argued for
//! by arXiv:2112.11978 and arXiv:2405.13807), and charging the debt to
//! an arbitrary delivering thread would make virtual time depend on the
//! delivery mode. Receiver-side message processing — the message-rate
//! term [`crate::rmpi::NetworkModel::rx_ns`] — is *not* charged here:
//! every send a round posts goes through the ordinary
//! [`crate::rmpi::net`] delivery path, so its deadline already includes
//! the destination rank's serialized ingress-port processing, priced by
//! exactly the same code p2p traffic pays (and the same code the
//! topology compiler's critical-path estimates replay). Round advances
//! therefore see fan-in congestion without any schedule-level
//! bookkeeping, and the deadlines are deterministic (resolved on the
//! clock thread in arrival/key order), so both delivery modes and both
//! wait styles observe identical virtual instants.

use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::Clock;
use crate::trace::{EventKind, Record};

use super::comm::Comm;
use super::p2p::Ctx;
use super::request::Request;
use super::topology::{AlltoallHier, GatherPlan, ReducePlan, TokenPlan, TreePlan};
use super::Pod;

/// Tag-space stride per collective sequence number: one sub-tag per
/// schedule phase (dissemination barriers use one phase per round;
/// hierarchical plans one per stage; tree collectives need only phase 0
/// because every `(src, dst)` pair is level-unique). 64 phases cover
/// dissemination on any cluster size.
const PHASE_STRIDE: u64 = 64;

/// Pack a collective sequence number and phase into an `i32` tag on the
/// collective match context.
pub(crate) fn coll_tag(seq: u64, phase: u32) -> i32 {
    ((seq * PHASE_STRIDE + phase as u64) % i32::MAX as u64) as i32
}

/// Raw view of a caller-owned buffer a schedule reads/writes across
/// rounds. MPI non-blocking-collective contract: the buffer must stay
/// valid and untouched from the `i*` call until the `CollRequest`
/// completes; rounds are ordered by request completion, so accesses are
/// data-race-free under that contract (same discipline as
/// [`super::match_engine::RecvBuf`]).
pub(crate) struct UserBuf<T> {
    ptr: *mut T,
    len: usize,
}

impl<T> Clone for UserBuf<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for UserBuf<T> {}

// SAFETY: accesses are serialized by round completion order plus the
// caller's buffer contract (see type docs).
unsafe impl<T: Send> Send for UserBuf<T> {}

impl<T> UserBuf<T> {
    pub(crate) fn new(s: &mut [T]) -> UserBuf<T> {
        UserBuf { ptr: s.as_mut_ptr(), len: s.len() }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// # Safety
    /// Caller must hold the schedule's round-ordering guarantee (no
    /// concurrent access to the aliased region).
    pub(crate) unsafe fn slice(&self) -> &[T] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// # Safety
    /// See [`UserBuf::slice`].
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice_mut(&self) -> &mut [T] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    /// Disjoint sub-region as its own `&mut` (used for scatter-style
    /// destinations so outstanding receives never share a Rust borrow).
    ///
    /// # Safety
    /// `[offset, offset + len)` must be in bounds and disjoint from any
    /// other live region of this buffer.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn region_mut(&self, offset: usize, len: usize) -> &mut [T] {
        debug_assert!(offset + len <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(offset), len) }
    }
}

/// Read-only raw view of a caller-owned send buffer (the read side of
/// the [`UserBuf`] contract). Schedules dereference it only while
/// posting round 0 — i.e. inside the `i*` call, while the caller's
/// borrow is still live — so no copy of the payload is ever made beyond
/// `isend`'s own eager copy (or an explicit staging copy taken at
/// launch by hierarchical plans).
pub(crate) struct UserRef<T> {
    ptr: *const T,
    len: usize,
}

impl<T> Clone for UserRef<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for UserRef<T> {}

// SAFETY: see the type docs — reads are confined to round posting under
// the caller's buffer contract.
unsafe impl<T: Send> Send for UserRef<T> {}

impl<T> UserRef<T> {
    pub(crate) fn new(s: &[T]) -> UserRef<T> {
        UserRef { ptr: s.as_ptr(), len: s.len() }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// # Safety
    /// Caller must hold the buffer contract (no concurrent mutation, the
    /// allocation outlives this use).
    pub(crate) unsafe fn slice(&self) -> &[T] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

/// Inline small-vec for round posting. Most collective rounds post at
/// most four requests (tree fan-in/out edges, a leader's up/down pair),
/// so the common case allocates nothing on the heap; wide rounds (flat
/// alltoallv, large leader exchanges) spill into a plain `Vec` and
/// behave exactly as before. Hand-rolled rather than pulled from a
/// crate: the repo carries no external small-vec dependency.
pub(crate) struct ReqVec {
    inline: [Option<Request>; ReqVec::INLINE],
    len: usize,
    spill: Vec<Request>,
}

impl ReqVec {
    const INLINE: usize = 4;

    pub(crate) fn new() -> ReqVec {
        ReqVec { inline: [None, None, None, None], len: 0, spill: Vec::new() }
    }

    /// A single-request round (the overwhelmingly common leaf case).
    pub(crate) fn one(r: Request) -> ReqVec {
        let mut v = ReqVec::new();
        v.push(r);
        v
    }

    pub(crate) fn push(&mut self, r: Request) {
        if self.len < Self::INLINE {
            self.inline[self.len] = Some(r);
        } else {
            self.spill.push(r);
        }
        self.len += 1;
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Whether this round overflowed the inline slots — the complement
    /// drives the `rounds_posted_inline` allocation-reuse counter.
    pub(crate) fn spilled(&self) -> bool {
        !self.spill.is_empty()
    }
}

impl IntoIterator for ReqVec {
    type Item = Request;
    type IntoIter = std::iter::Chain<
        std::iter::Flatten<std::array::IntoIter<Option<Request>, { ReqVec::INLINE }>>,
        std::vec::IntoIter<Request>,
    >;

    fn into_iter(self) -> Self::IntoIter {
        self.inline.into_iter().flatten().chain(self.spill)
    }
}

/// What one round produced: the requests gating the next round, plus
/// buffers that must stay alive until this round's requests complete
/// (kept on the schedule, freed at final completion).
pub(crate) struct RoundPost {
    pub reqs: ReqVec,
    pub retain: Vec<Box<dyn Any + Send>>,
}

impl RoundPost {
    fn bare(reqs: ReqVec) -> RoundPost {
        RoundPost { reqs, retain: Vec::new() }
    }
}

/// One round of a schedule: posts its operations and returns the
/// requests whose completions trigger the next round. Receiver-side
/// processing needs no per-round bookkeeping: each posted operation's
/// deadline already carries its ingress-port charge (see module docs).
pub(crate) type Round = Box<dyn FnOnce() -> RoundPost + Send>;

/// A compiled, in-flight collective: the remaining rounds plus the final
/// completion request. Shared between the [`CollRequest`] handle and the
/// advance continuations attached to round requests, so a schedule stays
/// alive (and keeps progressing) even if the caller drops its handle
/// before completion — true fire-and-forget.
pub(crate) struct CollSchedule {
    comm: Comm,
    kind: &'static str,
    /// `(comm context, first sequence number)` — the collective's
    /// cluster-wide identity in trace records.
    comm_id: u32,
    seq: u64,
    rounds: Mutex<VecDeque<Round>>,
    /// Round-owned buffers pinned until the collective completes.
    retain: Mutex<Vec<Box<dyn Any + Send>>>,
    total: u32,
    advanced: AtomicU32,
    /// Virtual instant of the previous round advance (launch instant for
    /// round 1) — the left edge of each `CollRound` span.
    last_advance_ns: std::sync::atomic::AtomicU64,
    /// Final completion request (created through the rank's [`Comm`], so
    /// its continuations route through the rank's shard like any other
    /// request's).
    req: Request,
}

impl CollSchedule {
    /// Instantiate `rounds`, post round 0 on the calling thread, and
    /// hand back the composable request. `seq` is the call's first
    /// collective sequence number and `cached` whether the plan came
    /// from the schedule cache (both traced).
    pub(crate) fn launch(
        comm: &Comm,
        kind: &'static str,
        seq: u64,
        cached: bool,
        rounds: Vec<Round>,
    ) -> CollRequest {
        let sched = Arc::new(CollSchedule {
            comm: comm.clone(),
            kind,
            comm_id: comm.ctx_p2p_id as u32,
            seq,
            total: rounds.len() as u32,
            rounds: Mutex::new(rounds.into()),
            retain: Mutex::new(Vec::new()),
            advanced: AtomicU32::new(0),
            last_advance_ns: std::sync::atomic::AtomicU64::new(
                comm.uni.clock.now(),
            ),
            req: Request(comm.mk_req_state("coll")),
        });
        sched.trace(EventKind::CollScheduleCompiled {
            comm: sched.comm_id,
            seq,
            cached,
            rounds: sched.total,
        });
        sched.advance();
        CollRequest { req: sched.req.clone(), sched }
    }

    /// Post the next round; attach an advance continuation to its
    /// pending requests; loop through rounds that complete at post time.
    /// Runs on the launching thread for round 0 and afterwards on
    /// whichever thread delivers the previous round's last completion —
    /// a completion-deadline callback on the clock thread, or a shard
    /// drain (also the clock thread) under Sharded delivery. Completion
    /// instants come from the network layer's port deadlines, so they
    /// are identical whichever thread advances the schedule.
    fn advance(self: &Arc<Self>) {
        loop {
            let next = self.rounds.lock().unwrap().pop_front();
            let Some(round) = next else {
                self.finish();
                return;
            };
            // Neutralize the per-call CPU debt of engine-driven posts so
            // virtual time cannot depend on which thread advances the
            // schedule (see module docs).
            let caller_debt = Clock::take_debt();
            let post = round();
            let _engine_debt = Clock::take_debt();
            Clock::add_debt(caller_debt);
            let n = self.advanced.fetch_add(1, Ordering::AcqRel) + 1;
            self.trace(EventKind::CollRoundAdvanced {
                comm: self.comm_id,
                seq: self.seq,
                round: n,
                total: self.total,
            });
            let obs = &self.comm.uni.obs;
            if obs.enabled() {
                // One span per round on the rank's collective track,
                // chained round→round by flow ids (the 0xC011 tag keeps
                // round flows disjoint from message-key flows).
                let t = self.comm.uni.clock.now();
                let prev = self.last_advance_ns.swap(t, Ordering::AcqRel);
                let mut span = crate::obs::Span::interval(
                    crate::obs::Track::Coll { rank: self.comm.rank as u32 },
                    crate::obs::SpanKind::CollRound,
                    prev,
                    t,
                    self.kind,
                    n as u64,
                );
                if n < self.total {
                    span = span.with_flow_out(crate::obs::fid(&[
                        0xC011,
                        self.comm_id as u64,
                        self.seq,
                        n as u64,
                    ]));
                }
                if n > 1 {
                    span = span.with_flow_in(crate::obs::fid(&[
                        0xC011,
                        self.comm_id as u64,
                        self.seq,
                        (n - 1) as u64,
                    ]));
                }
                obs.record(span);
            }
            if !post.retain.is_empty() {
                self.retain.lock().unwrap().extend(post.retain);
            }
            if !post.reqs.spilled() {
                // Host-side diagnostic: this round's requests fit the
                // inline slots, so posting allocated no request vector.
                self.comm.uni.reuse_rounds_inline.fetch_add(1, Ordering::Relaxed);
            }
            let mut pending: Vec<Request> = Vec::with_capacity(post.reqs.len());
            for r in post.reqs {
                // A constituent that already failed (rank death at post
                // time) is complete; record its error before filtering
                // it out.
                if let Some(e) = r.error() {
                    self.req.0.poison(e);
                }
                if !r.test() {
                    pending.push(r);
                }
            }
            if pending.is_empty() {
                // Round satisfied at post time: fall through.
                continue;
            }
            let remaining = Arc::new(AtomicUsize::new(pending.len()));
            for r in &pending {
                let sched = self.clone();
                let remaining = remaining.clone();
                let req = r.clone();
                r.on_complete(move |_| {
                    // A failed constituent (RankFailed timeout) poisons
                    // the outer request: the schedule still runs its
                    // remaining rounds — their payload is garbage, but
                    // every peer's schedule keeps advancing, so one
                    // death never cascades into a cluster-wide hang —
                    // and `finish` completes the collective with the
                    // error attached.
                    if let Some(e) = req.error() {
                        sched.req.0.poison(e);
                    }
                    if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        sched.advance();
                    }
                });
            }
            return;
        }
    }

    /// All rounds done: release pinned buffers and complete the final
    /// request (waking Park waiters and firing TAMPI/event continuations
    /// through the normal completion pipeline). If a constituent failed
    /// along the way, the poison stays attached: waiters wake into
    /// `Err(RankFailed)` from [`Request::result`] instead of hanging.
    fn finish(&self) {
        self.retain.lock().unwrap().clear();
        self.req.0.complete(&self.comm.uni.clock, None);
    }

    fn trace(&self, kind: EventKind) {
        if let Some(tr) = &self.comm.uni.tracer {
            tr.emit(Record {
                t: self.comm.uni.clock.now(),
                rank: self.comm.rank as u32,
                // Annotation record; may be stamped from the clock
                // thread (see `trace::Record::worker` sentinel docs).
                worker: u32::MAX,
                kind,
                label: self.kind.to_string(),
                task_id: 0,
            });
        }
    }
}

/// Handle to an in-flight collective (MPI's request-returning `MPI_I*`
/// collectives, Section 6.1). Derefs to the underlying [`Request`], so
/// it composes with `Request::wait` / `wait_any`, `Tampi::iwait[all]`,
/// and task external-event binding exactly like a point-to-point
/// request.
#[derive(Clone)]
pub struct CollRequest {
    req: Request,
    sched: Arc<CollSchedule>,
}

impl CollRequest {
    /// The composable completion request (clone it into `wait_any`
    /// slices or hand it to `Tampi::iwait`).
    pub fn request(&self) -> &Request {
        &self.req
    }

    /// Consume the handle, keeping only the completion request. The
    /// schedule keeps advancing regardless (its continuations own it).
    pub fn into_request(self) -> Request {
        self.req
    }

    /// Park the calling OS thread until the collective completes.
    pub fn wait(&self) {
        self.req.wait(&self.sched.comm.uni.clock);
    }

    /// Algorithm name ("barrier", "bcast", ...).
    pub fn kind(&self) -> &'static str {
        self.sched.kind
    }

    /// Rounds in this rank's schedule.
    pub fn rounds_total(&self) -> u32 {
        self.sched.total
    }

    /// Rounds posted so far.
    pub fn rounds_advanced(&self) -> u32 {
        self.sched.advanced.load(Ordering::Acquire)
    }
}

impl std::ops::Deref for CollRequest {
    type Target = Request;
    fn deref(&self) -> &Request {
        &self.req
    }
}

impl std::fmt::Debug for CollRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CollRequest({} round {}/{}, completed={})",
            self.sched.kind,
            self.rounds_advanced(),
            self.rounds_total(),
            self.req.test()
        )
    }
}

// ---------------------------------------------------------------------
// Plan instantiators: bind a compiled plan to the caller's buffers and
// a fresh sequence number. `CollSchedule::launch` posts round 0
// immediately, so `UserRef` send views are read while the caller's
// borrow is live.
// ---------------------------------------------------------------------

/// Barrier: one round per [`TokenPlan`] round, exchanging 1-byte
/// tokens on the plan's `(peer, phase)` edges.
pub(crate) fn instantiate_barrier(comm: &Comm, plan: &TokenPlan, seq: u64) -> Vec<Round> {
    plan.rounds
        .iter()
        .map(|r| {
            let comm = comm.clone();
            let sends: Vec<(usize, i32)> =
                r.sends.iter().map(|&(to, ph)| (to, coll_tag(seq, ph))).collect();
            let recvs: Vec<(usize, i32)> =
                r.recvs.iter().map(|&(from, ph)| (from, coll_tag(seq, ph))).collect();
            let run: Round = Box::new(move || {
                let mut reqs = ReqVec::new();
                let mut retain: Vec<Box<dyn Any + Send>> = Vec::new();
                for &(to, tag) in &sends {
                    reqs.push(comm.isend_ctx(&[1u8], to, tag, false, Ctx::Coll));
                }
                for &(from, tag) in &recvs {
                    let mut buf = Box::new([0u8; 1]);
                    reqs.push(comm.irecv_ctx(&mut buf[..], from as i32, tag, Ctx::Coll));
                    retain.push(buf as Box<dyn Any + Send>);
                }
                RoundPost { reqs, retain }
            });
            run
        })
        .collect()
}

/// Broadcast: receive the payload from the plan's parent (round 0 on
/// non-roots), then forward it to the plan's children.
pub(crate) fn instantiate_bcast<T: Pod>(
    comm: &Comm,
    plan: &TreePlan,
    buf: UserBuf<T>,
    seq: u64,
) -> Vec<Round> {
    let n = comm.size;
    let mut rounds: Vec<Round> = Vec::new();
    if n == 1 {
        return rounds;
    }
    let tag = coll_tag(seq, 0);
    if let Some(parent) = plan.recv_from {
        let comm = comm.clone();
        rounds.push(Box::new(move || {
            // SAFETY: i-collective buffer contract (untouched by the
            // caller until completion); no prior round aliases it.
            let dst = unsafe { buf.slice_mut() };
            RoundPost::bare(ReqVec::one(comm.irecv_ctx(dst, parent as i32, tag, Ctx::Coll)))
        }));
    }
    {
        let comm = comm.clone();
        let children = plan.send_to.clone();
        rounds.push(Box::new(move || {
            let mut reqs = ReqVec::new();
            for &dst in &children {
                // SAFETY: the parent's payload landed in the previous
                // round (or this is the root's own data).
                let src = unsafe { buf.slice() };
                reqs.push(comm.isend_ctx(src, dst, tag, false, Ctx::Coll));
            }
            RoundPost::bare(reqs)
        }));
    }
    rounds
}

/// Reduce: round 0 posts the plan's child receives into temporaries;
/// round 1 folds them into the user buffer *in plan order* (the
/// bit-identity contract) and forwards the partial to the parent.
pub(crate) fn instantiate_reduce<T: Pod>(
    comm: &Comm,
    plan: &ReducePlan,
    buf: UserBuf<T>,
    seq: u64,
    op: Box<dyn Fn(&mut [T], &[T]) + Send>,
) -> Vec<Round> {
    let n = comm.size;
    let mut rounds: Vec<Round> = Vec::new();
    if n == 1 {
        return rounds;
    }
    let tag = coll_tag(seq, 0);
    let children = plan.children.clone();
    let parent = plan.parent;
    let temps: Arc<Mutex<Vec<Vec<T>>>> = Arc::new(Mutex::new(Vec::new()));
    if !children.is_empty() {
        let comm = comm.clone();
        let temps = temps.clone();
        let children = children.clone();
        let run: Round = Box::new(move || {
            let len = buf.len();
            // SAFETY: contract; seed value only (recv overwrites).
            // `None` only for zero-length buffers (legal; empty temps).
            let seed = unsafe { buf.slice() }.first().copied();
            let mut g = temps.lock().unwrap();
            for _ in &children {
                g.push(seed.map_or_else(Vec::new, |s| vec![s; len]));
            }
            let mut reqs = ReqVec::new();
            for (i, &child) in children.iter().enumerate() {
                reqs.push(comm.irecv_ctx(&mut g[i][..], child as i32, tag, Ctx::Coll));
            }
            RoundPost::bare(reqs)
        });
        rounds.push(run);
    }
    {
        let comm = comm.clone();
        let run: Round = Box::new(move || {
            // SAFETY: children's contributions landed in round 0; the
            // caller holds the buffer untouched.
            let acc = unsafe { buf.slice_mut() };
            let g = temps.lock().unwrap();
            for t in g.iter() {
                op(&mut *acc, &t[..]); // fixed child order: deterministic rounding
            }
            drop(g);
            let mut reqs = ReqVec::new();
            if let Some(parent) = parent {
                let src = unsafe { buf.slice() };
                reqs.push(comm.isend_ctx(src, parent, tag, false, Ctx::Coll));
            }
            RoundPost::bare(reqs)
        });
        rounds.push(run);
    }
    rounds
}

/// Gather to the plan's root: leaves send one chunk; staging leaders
/// collect their node's chunks and forward one contiguous block; the
/// root receives direct chunks and node blocks straight into their
/// final offsets, so the result bytes are identical to the flat plan's.
pub(crate) fn instantiate_gather<T: Pod>(
    comm: &Comm,
    plan: &GatherPlan,
    send: UserRef<T>,
    recv: Option<UserBuf<T>>,
    seq: u64,
) -> Vec<Round> {
    let tag = coll_tag(seq, 0);
    let chunk = send.len();
    match plan {
        GatherPlan::Leaf { to } => {
            let comm = comm.clone();
            let to = *to;
            let run: Round = Box::new(move || {
                // SAFETY: read during launch; isend copies eagerly.
                let src = unsafe { send.slice() };
                RoundPost::bare(ReqVec::one(comm.isend_ctx(src, to, tag, false, Ctx::Coll)))
            });
            vec![run]
        }
        GatherPlan::Leader { members, root, node_base } => {
            // Round 0: stage the node's chunks (own chunk copied at
            // launch, members received). Round 1: forward the block.
            let temps: Arc<Mutex<Vec<Vec<T>>>> = Arc::new(Mutex::new(Vec::new()));
            let (members, root, node_base) = (members.clone(), *root, *node_base);
            let leader = comm.rank;
            let c0 = comm.clone();
            let t0 = temps.clone();
            let r0: Round = Box::new(move || {
                let mut g = t0.lock().unwrap();
                // SAFETY: launch-time read of the caller's send buffer.
                g.push(unsafe { send.slice() }.to_vec());
                // `None` only for zero-length chunks, whose staging
                // buffers are empty anyway (zero-count MPI collectives
                // are legal).
                let seed = g[0].first().copied();
                for _ in &members {
                    g.push(seed.map_or_else(Vec::new, |s| vec![s; chunk]));
                }
                let mut reqs = ReqVec::new();
                for (i, &m) in members.iter().enumerate() {
                    reqs.push(c0.irecv_ctx(&mut g[i + 1][..], m as i32, tag, Ctx::Coll));
                }
                RoundPost::bare(reqs)
            });
            let c1 = comm.clone();
            let r1: Round = Box::new(move || {
                let g = temps.lock().unwrap();
                // Assemble the node block in rank order: the leader is
                // the node's first rank, members ascend after it.
                let mut block = Vec::with_capacity((g.len()) * chunk);
                debug_assert_eq!(leader, node_base);
                for part in g.iter() {
                    block.extend_from_slice(part);
                }
                drop(g);
                RoundPost::bare(ReqVec::one(c1.isend_ctx(&block, root, tag, false, Ctx::Coll)))
            });
            vec![r0, r1]
        }
        GatherPlan::Root { direct, blocks } => {
            let recv = recv.expect("root must pass a receive buffer");
            assert_eq!(recv.len(), chunk * comm.size);
            let comm = comm.clone();
            let root = comm.rank;
            let direct = direct.clone();
            let blocks: Vec<(usize, usize, usize)> =
                blocks.iter().map(|b| (b.leader, b.first_rank, b.nranks)).collect();
            let run: Round = Box::new(move || {
                let mut reqs = ReqVec::new();
                // SAFETY: per-rank regions are disjoint by construction;
                // the send view is read during launch only.
                let own = unsafe { recv.region_mut(root * chunk, chunk) };
                own.copy_from_slice(unsafe { send.slice() });
                for &r in &direct {
                    let dst = unsafe { recv.region_mut(r * chunk, chunk) };
                    reqs.push(comm.irecv_ctx(dst, r as i32, tag, Ctx::Coll));
                }
                for &(leader, first, nranks) in &blocks {
                    let dst = unsafe { recv.region_mut(first * chunk, nranks * chunk) };
                    reqs.push(comm.irecv_ctx(dst, leader as i32, tag, Ctx::Coll));
                }
                RoundPost::bare(reqs)
            });
            vec![run]
        }
    }
}

/// Pairwise alltoallv (the flat plan): a single round posting all
/// receives (in displacement order, like the PR-3 algorithm) followed
/// by all sends.
#[allow(clippy::too_many_arguments)]
pub(crate) fn instantiate_alltoallv_flat<T: Pod>(
    comm: &Comm,
    send: UserRef<T>,
    scounts: Vec<usize>,
    sdispls: Vec<usize>,
    recv: UserBuf<T>,
    rcounts: Vec<usize>,
    rdispls: Vec<usize>,
    seq: u64,
) -> Vec<Round> {
    let n = comm.size;
    assert!(scounts.len() == n && rcounts.len() == n);
    // Validate the receive regions are disjoint and in bounds (the
    // blocking algorithm enforced this through split_at_mut arithmetic).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&r| rdispls[r]);
    let mut end = 0usize;
    for &r in &order {
        assert!(rdispls[r] >= end, "overlapping alltoallv receive regions");
        end = rdispls[r] + rcounts[r];
    }
    assert!(end <= recv.len(), "alltoallv receive buffer too small");

    let tag = coll_tag(seq, 0);
    let comm = comm.clone();
    let run: Round = Box::new(move || {
        let rank = comm.rank;
        // SAFETY: read during launch only; isend copies eagerly.
        let send = unsafe { send.slice() };
        let mut reqs = ReqVec::new(); // spills past 4: wide pairwise round
        // Receives first (deterministic matching), in displacement order.
        for &r in &order {
            // SAFETY: regions validated disjoint above; caller contract.
            let dst = unsafe { recv.region_mut(rdispls[r], rcounts[r]) };
            if r == rank {
                dst.copy_from_slice(&send[sdispls[r]..sdispls[r] + rcounts[r]]);
            } else {
                reqs.push(comm.irecv_ctx(dst, r as i32, tag, Ctx::Coll));
            }
        }
        for r in 0..n {
            if r != rank {
                reqs.push(comm.isend_ctx(
                    &send[sdispls[r]..sdispls[r] + scounts[r]],
                    r,
                    tag,
                    false,
                    Ctx::Coll,
                ));
            }
        }
        RoundPost::bare(reqs)
    });
    vec![run]
}

/// Leader-staged uniform alltoall. Three phases (tag phases 0/1/2):
/// members ship their whole send buffer to the node leader; leaders
/// exchange per-node-pair blocks laid out `(src member, dst member)`;
/// leaders scatter each member's assembled result. Every element lands
/// at the same offset the flat plan would put it — placement only, no
/// combining — so results are bit-identical.
pub(crate) fn instantiate_alltoall_hier<T: Pod>(
    comm: &Comm,
    plan: &AlltoallHier,
    send: UserRef<T>,
    recv: UserBuf<T>,
    chunk: usize,
    seq: u64,
) -> Vec<Round> {
    let n = comm.size;
    assert_eq!(send.len(), n * chunk);
    assert_eq!(recv.len(), n * chunk);
    let (t_up, t_x, t_down) = (coll_tag(seq, 0), coll_tag(seq, 1), coll_tag(seq, 2));

    if !plan.is_leader {
        let leader = plan.nodes_list[plan.my_node][0];
        let comm = comm.clone();
        let run: Round = Box::new(move || {
            // SAFETY: send read at launch; recv held until completion
            // (i-collective contract).
            let s = unsafe { send.slice() };
            let r = unsafe { recv.slice_mut() };
            let mut reqs = ReqVec::one(comm.isend_ctx(s, leader, t_up, false, Ctx::Coll));
            reqs.push(comm.irecv_ctx(r, leader as i32, t_down, Ctx::Coll));
            RoundPost::bare(reqs)
        });
        return vec![run];
    }

    // Leader. Staging: `gathered[i]` = member i's full send buffer
    // (own first, rank order); `inbound[b]` = node b's block.
    let members: Vec<usize> = plan.nodes_list[plan.my_node].clone();
    let my_node = plan.my_node;
    let nodes_list = plan.nodes_list.clone();
    let rpn = members.len();
    let gathered: Arc<Mutex<Vec<Vec<T>>>> = Arc::new(Mutex::new(Vec::new()));
    let inbound: Arc<Mutex<Vec<Vec<T>>>> = Arc::new(Mutex::new(Vec::new()));

    let c0 = comm.clone();
    let g0 = gathered.clone();
    let m0 = members.clone();
    let r0: Round = Box::new(move || {
        let mut g = g0.lock().unwrap();
        // SAFETY: launch-time read of the caller's send buffer.
        g.push(unsafe { send.slice() }.to_vec());
        // `None` only for chunk == 0 (legal, empty staging throughout).
        let seed = g[0].first().copied();
        for _ in 1..m0.len() {
            g.push(seed.map_or_else(Vec::new, |s| vec![s; n * chunk]));
        }
        let mut reqs = ReqVec::new();
        for (i, &m) in m0.iter().enumerate().skip(1) {
            reqs.push(c0.irecv_ctx(&mut g[i][..], m as i32, t_up, Ctx::Coll));
        }
        RoundPost::bare(reqs)
    });

    let c1 = comm.clone();
    let g1 = gathered.clone();
    let i1 = inbound.clone();
    let nl1 = nodes_list.clone();
    let r1: Round = Box::new(move || {
        let g = g1.lock().unwrap();
        let mut reqs = ReqVec::new();
        // Post the inbound block receives first (deterministic
        // matching), then ship ours. Peers send from their own round 1,
        // which they reach independently of ours — no circular wait.
        let mut inb = i1.lock().unwrap();
        let seed = g[0].first().copied();
        for (b, dst_members) in nl1.iter().enumerate() {
            if b == my_node {
                inb.push(Vec::new());
            } else {
                let len = g.len() * dst_members.len() * chunk;
                inb.push(seed.map_or_else(Vec::new, |s| vec![s; len]));
            }
        }
        for (b, dst_members) in nl1.iter().enumerate() {
            if b != my_node {
                let peer = dst_members[0];
                reqs.push(c1.irecv_ctx(&mut inb[b][..], peer as i32, t_x, Ctx::Coll));
            }
        }
        drop(inb);
        for (b, dst_members) in nl1.iter().enumerate() {
            if b == my_node {
                continue;
            }
            let mut block = Vec::with_capacity(g.len() * dst_members.len() * chunk);
            for src in g.iter() {
                for &d in dst_members.iter() {
                    block.extend_from_slice(&src[d * chunk..(d + 1) * chunk]);
                }
            }
            reqs.push(c1.isend_ctx(&block, dst_members[0], t_x, false, Ctx::Coll));
        }
        RoundPost::bare(reqs)
    });

    let c2 = comm.clone();
    let r2: Round = Box::new(move || {
        let g = gathered.lock().unwrap();
        let inb = inbound.lock().unwrap();
        let idx_in = |b: usize, r: usize| r - nodes_list[b][0];
        let mut reqs = ReqVec::new();
        for (j, &m) in members.iter().enumerate() {
            let mut out: Vec<T> = Vec::with_capacity(n * chunk);
            for s in 0..n {
                let b = s / rpn; // uniform blocked layout (plan contract)
                let si = idx_in(b, s);
                if b == my_node {
                    out.extend_from_slice(&g[si][m * chunk..(m + 1) * chunk]);
                } else {
                    let off = (si * rpn + j) * chunk;
                    out.extend_from_slice(&inb[b][off..off + chunk]);
                }
            }
            if j == 0 {
                // SAFETY: the leader's own result region; no other round
                // touches the recv buffer.
                unsafe { recv.slice_mut() }.copy_from_slice(&out);
            } else {
                reqs.push(c2.isend_ctx(&out, m, t_down, false, Ctx::Coll));
            }
        }
        RoundPost::bare(reqs)
    });

    vec![r0, r1, r2]
}
