//! The schedule-driven collective engine.
//!
//! Every collective compiles into a [`CollSchedule`]: a per-rank DAG of
//! *rounds*, where each round posts a set of point-to-point operations
//! (sends, receives, local copies, reduction combines) and the next
//! round is posted when the previous round's completions fire through
//! [`Request::on_complete`]. The caller gets back a single
//! [`CollRequest`] the moment round 0 is posted; from then on the
//! *progress engine* drives the collective:
//!
//! * under [`crate::progress::DeliveryMode::Sharded`] the round's
//!   completion wave lands as one batch on the owning rank's shard and
//!   the drain (on the clock thread) advances the schedule;
//! * under `Direct` the continuations fire inline at each completion
//!   point — same virtual instants, same data, different real threads.
//!
//! No OS thread ever parks inside a collective round. This is what makes
//! the non-blocking surface (`ibarrier`/`ibcast`/`iallreduce`/…,
//! Section 6.1's interception extended to collectives) possible: the
//! returned `CollRequest` composes with [`Request::wait`] /
//! [`Request::wait_any`], with TAMPI `iwait`/`iwaitall` (task
//! external-event binding, Section 6.2), and with plain `test`. The
//! blocking entry points in [`super::collectives`] are thin wrappers
//! that launch a schedule and wait on its final request — one engine
//! serves both paths, so Direct-vs-Sharded and blocking-vs-non-blocking
//! runs stay bit-identical in application results.
//!
//! ## Rounds, tags and determinism
//!
//! Each collective call consumes one sequence number per phase from the
//! communicator's collective counter ([`coll_tag`] packs `(seq, phase)`
//! into an `i32` tag), so any number of collectives may be in flight on
//! one communicator: messages of different calls or rounds can never be
//! confused because every `(source, tag)` pair in a schedule is unique.
//! Reduction combiners run at a fixed child order (the binomial-tree
//! order the blocking algorithms used), independent of arrival order, so
//! floating-point results are bit-identical across delivery modes and
//! wait styles.
//!
//! ## Virtual-time accounting
//!
//! Rounds after the first are posted by whichever thread delivers the
//! last completion of the previous round (a rank thread under `Direct`,
//! the clock thread under `Sharded`). The per-call CPU debt those posts
//! would accrue is discarded uniformly ([`CollSchedule::advance`]): the
//! engine models an asynchronous progress thread (the shape argued for
//! by arXiv:2112.11978 and arXiv:2405.13807), and charging the debt to
//! an arbitrary delivering thread would make virtual time depend on the
//! delivery mode.

use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::Clock;
use crate::trace::{EventKind, Record};

use super::comm::Comm;
use super::p2p::Ctx;
use super::request::Request;
use super::Pod;

/// Tag-space stride per collective sequence number: one sub-tag per
/// schedule phase (dissemination barriers use one phase per round; tree
/// collectives need only phase 0 because every `(src, dst)` pair is
/// level-unique). 64 phases cover dissemination on any cluster size.
const PHASE_STRIDE: u64 = 64;

/// Pack a collective sequence number and phase into an `i32` tag on the
/// collective match context.
pub(crate) fn coll_tag(seq: u64, phase: u32) -> i32 {
    ((seq * PHASE_STRIDE + phase as u64) % i32::MAX as u64) as i32
}

/// Raw view of a caller-owned buffer a schedule reads/writes across
/// rounds. MPI non-blocking-collective contract: the buffer must stay
/// valid and untouched from the `i*` call until the `CollRequest`
/// completes; rounds are ordered by request completion, so accesses are
/// data-race-free under that contract (same discipline as
/// [`super::match_engine::RecvBuf`]).
pub(crate) struct UserBuf<T> {
    ptr: *mut T,
    len: usize,
}

impl<T> Clone for UserBuf<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for UserBuf<T> {}

// SAFETY: accesses are serialized by round completion order plus the
// caller's buffer contract (see type docs).
unsafe impl<T: Send> Send for UserBuf<T> {}

impl<T> UserBuf<T> {
    pub(crate) fn new(s: &mut [T]) -> UserBuf<T> {
        UserBuf { ptr: s.as_mut_ptr(), len: s.len() }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// # Safety
    /// Caller must hold the schedule's round-ordering guarantee (no
    /// concurrent access to the aliased region).
    pub(crate) unsafe fn slice(&self) -> &[T] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// # Safety
    /// See [`UserBuf::slice`].
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice_mut(&self) -> &mut [T] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    /// Disjoint sub-region as its own `&mut` (used for scatter-style
    /// destinations so outstanding receives never share a Rust borrow).
    ///
    /// # Safety
    /// `[offset, offset + len)` must be in bounds and disjoint from any
    /// other live region of this buffer.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn region_mut(&self, offset: usize, len: usize) -> &mut [T] {
        debug_assert!(offset + len <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(offset), len) }
    }
}

/// Read-only raw view of a caller-owned send buffer (the read side of
/// the [`UserBuf`] contract). Single-round schedules (gather,
/// alltoall(v)) dereference it only while posting round 0 — i.e. inside
/// the `i*` call, while the caller's borrow is still live — so no copy
/// of the payload is ever made beyond `isend`'s own eager copy.
pub(crate) struct UserRef<T> {
    ptr: *const T,
    len: usize,
}

impl<T> Clone for UserRef<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for UserRef<T> {}

// SAFETY: see the type docs — reads are confined to round posting under
// the caller's buffer contract.
unsafe impl<T: Send> Send for UserRef<T> {}

impl<T> UserRef<T> {
    pub(crate) fn new(s: &[T]) -> UserRef<T> {
        UserRef { ptr: s.as_ptr(), len: s.len() }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// # Safety
    /// Caller must hold the buffer contract (no concurrent mutation, the
    /// allocation outlives this use).
    pub(crate) unsafe fn slice(&self) -> &[T] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

/// What one round produced: the requests gating the next round, plus
/// buffers that must stay alive until this round's requests complete
/// (kept on the schedule, freed at final completion).
pub(crate) struct RoundPost {
    pub reqs: Vec<Request>,
    pub retain: Vec<Box<dyn Any + Send>>,
}

impl RoundPost {
    fn bare(reqs: Vec<Request>) -> RoundPost {
        RoundPost { reqs, retain: Vec::new() }
    }
}

/// One round of a schedule: posts its operations and returns the
/// requests whose completions trigger the next round.
pub(crate) type RoundFn = Box<dyn FnOnce() -> RoundPost + Send>;

/// A compiled, in-flight collective: the remaining rounds plus the final
/// completion request. Shared between the [`CollRequest`] handle and the
/// advance continuations attached to round requests, so a schedule stays
/// alive (and keeps progressing) even if the caller drops its handle
/// before completion — true fire-and-forget.
pub(crate) struct CollSchedule {
    comm: Comm,
    kind: &'static str,
    rounds: Mutex<VecDeque<RoundFn>>,
    /// Round-owned buffers pinned until the collective completes.
    retain: Mutex<Vec<Box<dyn Any + Send>>>,
    total: u32,
    advanced: AtomicU32,
    /// Final completion request (created through the rank's [`Comm`], so
    /// its continuations route through the rank's shard like any other
    /// request's).
    req: Request,
}

impl CollSchedule {
    /// Compile `rounds` into a schedule, post round 0 on the calling
    /// thread, and hand back the composable request.
    pub(crate) fn launch(comm: &Comm, kind: &'static str, rounds: Vec<RoundFn>) -> CollRequest {
        let sched = Arc::new(CollSchedule {
            comm: comm.clone(),
            kind,
            total: rounds.len() as u32,
            rounds: Mutex::new(rounds.into()),
            retain: Mutex::new(Vec::new()),
            advanced: AtomicU32::new(0),
            req: Request(comm.mk_req_state()),
        });
        sched.advance();
        CollRequest { req: sched.req.clone(), sched }
    }

    /// Post the next round; attach an advance continuation to its
    /// pending requests; loop through rounds that complete at post time.
    /// Runs on the launching thread for round 0 and afterwards on
    /// whichever thread delivers the previous round's last completion (a
    /// shard drain on the clock thread under Sharded delivery).
    fn advance(self: &Arc<Self>) {
        loop {
            let next = self.rounds.lock().unwrap().pop_front();
            let Some(round) = next else {
                self.finish();
                return;
            };
            // Neutralize the per-call CPU debt of engine-driven posts so
            // virtual time cannot depend on which thread advances the
            // schedule (see module docs).
            let caller_debt = Clock::take_debt();
            let post = round();
            let _engine_debt = Clock::take_debt();
            Clock::add_debt(caller_debt);
            let n = self.advanced.fetch_add(1, Ordering::AcqRel) + 1;
            self.trace_round(n);
            if !post.retain.is_empty() {
                self.retain.lock().unwrap().extend(post.retain);
            }
            let pending: Vec<Request> =
                post.reqs.into_iter().filter(|r| !r.test()).collect();
            if pending.is_empty() {
                continue; // round satisfied at post time: fall through
            }
            let remaining = Arc::new(AtomicUsize::new(pending.len()));
            for r in &pending {
                let sched = self.clone();
                let remaining = remaining.clone();
                r.on_complete(move |_| {
                    if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        sched.advance();
                    }
                });
            }
            return;
        }
    }

    /// All rounds done: release pinned buffers and complete the final
    /// request (waking Park waiters and firing TAMPI/event continuations
    /// through the normal completion pipeline).
    fn finish(&self) {
        self.retain.lock().unwrap().clear();
        self.req.0.complete(&self.comm.uni.clock, None);
    }

    fn trace_round(&self, round: u32) {
        if let Some(tr) = &self.comm.uni.tracer {
            tr.emit(Record {
                t: self.comm.uni.clock.now(),
                rank: self.comm.rank as u32,
                // Annotation record; may be stamped from the clock
                // thread (see `trace::Record::worker` sentinel docs).
                worker: u32::MAX,
                kind: EventKind::CollRoundAdvanced { round, total: self.total },
                label: self.kind.to_string(),
                task_id: 0,
            });
        }
    }
}

/// Handle to an in-flight collective (MPI's request-returning `MPI_I*`
/// collectives, Section 6.1). Derefs to the underlying [`Request`], so
/// it composes with `Request::wait` / `wait_any`, `Tampi::iwait[all]`,
/// and task external-event binding exactly like a point-to-point
/// request.
#[derive(Clone)]
pub struct CollRequest {
    req: Request,
    sched: Arc<CollSchedule>,
}

impl CollRequest {
    /// The composable completion request (clone it into `wait_any`
    /// slices or hand it to `Tampi::iwait`).
    pub fn request(&self) -> &Request {
        &self.req
    }

    /// Consume the handle, keeping only the completion request. The
    /// schedule keeps advancing regardless (its continuations own it).
    pub fn into_request(self) -> Request {
        self.req
    }

    /// Park the calling OS thread until the collective completes.
    pub fn wait(&self) {
        self.req.wait(&self.sched.comm.uni.clock);
    }

    /// Algorithm name ("barrier", "bcast", ...).
    pub fn kind(&self) -> &'static str {
        self.sched.kind
    }

    /// Rounds in this rank's schedule.
    pub fn rounds_total(&self) -> u32 {
        self.sched.total
    }

    /// Rounds posted so far.
    pub fn rounds_advanced(&self) -> u32 {
        self.sched.advanced.load(Ordering::Acquire)
    }
}

impl std::ops::Deref for CollRequest {
    type Target = Request;
    fn deref(&self) -> &Request {
        &self.req
    }
}

impl std::fmt::Debug for CollRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CollRequest({} round {}/{}, completed={})",
            self.sched.kind,
            self.rounds_advanced(),
            self.rounds_total(),
            self.req.test()
        )
    }
}

// ---------------------------------------------------------------------
// Schedule builders: one per collective algorithm. Each returns this
// rank's round list; `CollSchedule::launch` posts round 0 immediately.
// ---------------------------------------------------------------------

/// Dissemination barrier: round k exchanges a token with the rank
/// `2^k` away; log2(size) rounds, each gated on the previous.
pub(crate) fn barrier_schedule(comm: &Comm) -> Vec<RoundFn> {
    let n = comm.size;
    let mut rounds: Vec<RoundFn> = Vec::new();
    if n == 1 {
        return rounds;
    }
    let seq = comm.next_coll_seq();
    let mut round = 1usize;
    let mut phase = 0u32;
    while round < n {
        let comm = comm.clone();
        let tag = coll_tag(seq, phase);
        let dist = round;
        rounds.push(Box::new(move || {
            let n = comm.size;
            let to = (comm.rank + dist) % n;
            let from = (comm.rank + n - dist) % n;
            let mut buf = Box::new([0u8; 1]);
            let s = comm.isend_ctx(&[1u8], to, tag, false, Ctx::Coll);
            let r = comm.irecv_ctx(&mut buf[..], from as i32, tag, Ctx::Coll);
            RoundPost { reqs: vec![s, r], retain: vec![buf as Box<dyn Any + Send>] }
        }));
        round <<= 1;
        phase += 1;
    }
    rounds
}

/// Binomial-tree broadcast rooted at `root`: non-root ranks receive from
/// their parent (round 0), then forward to their children (round 1);
/// the root forwards immediately.
pub(crate) fn bcast_schedule<T: Pod>(
    comm: &Comm,
    buf: UserBuf<T>,
    root: usize,
    seq: u64,
) -> Vec<RoundFn> {
    let n = comm.size;
    let mut rounds: Vec<RoundFn> = Vec::new();
    if n == 1 {
        return rounds;
    }
    let tag = coll_tag(seq, 0);
    let vr = (comm.rank + n - root) % n; // virtual rank, root -> 0
    if vr != 0 {
        let comm = comm.clone();
        rounds.push(Box::new(move || {
            let parent = ((vr - 1) / 2 + root) % n;
            // SAFETY: i-collective buffer contract (untouched by the
            // caller until completion); no prior round aliases it.
            let dst = unsafe { buf.slice_mut() };
            RoundPost::bare(vec![comm.irecv_ctx(dst, parent as i32, tag, Ctx::Coll)])
        }));
    }
    {
        let comm = comm.clone();
        rounds.push(Box::new(move || {
            let mut reqs = Vec::new();
            for child in [2 * vr + 1, 2 * vr + 2] {
                if child < n {
                    let dst = (child + root) % n;
                    // SAFETY: the parent's payload landed in round 0 (or
                    // this is the root's own data).
                    let src = unsafe { buf.slice() };
                    reqs.push(comm.isend_ctx(src, dst, tag, false, Ctx::Coll));
                }
            }
            RoundPost::bare(reqs)
        }));
    }
    rounds
}

/// Binomial-tree reduction to `root`: round 0 posts all child receives
/// into temporaries; round 1 folds them into the user buffer in fixed
/// child order (bit-identical to the sequential blocking algorithm) and
/// forwards the partial result to the parent.
pub(crate) fn reduce_schedule<T: Pod>(
    comm: &Comm,
    buf: UserBuf<T>,
    root: usize,
    seq: u64,
    op: Box<dyn Fn(&mut [T], &[T]) + Send>,
) -> Vec<RoundFn> {
    let n = comm.size;
    let mut rounds: Vec<RoundFn> = Vec::new();
    if n == 1 {
        return rounds;
    }
    let tag = coll_tag(seq, 0);
    let vr = (comm.rank + n - root) % n;
    // Binomial children: vr + 2^k while valid.
    let mut children = Vec::new();
    let mut k = 1usize;
    while vr + k < n && (vr & k) == 0 {
        children.push(((vr + k) + root) % n);
        k <<= 1;
    }
    let temps: Arc<Mutex<Vec<Vec<T>>>> = Arc::new(Mutex::new(Vec::new()));
    if !children.is_empty() {
        let comm = comm.clone();
        let temps = temps.clone();
        let children = children.clone();
        rounds.push(Box::new(move || {
            let len = buf.len();
            // SAFETY: contract; seed value only (recv overwrites).
            let seed = unsafe { buf.slice()[0] };
            let mut g = temps.lock().unwrap();
            for _ in &children {
                g.push(vec![seed; len]);
            }
            let mut reqs = Vec::new();
            for (i, &child) in children.iter().enumerate() {
                reqs.push(comm.irecv_ctx(&mut g[i][..], child as i32, tag, Ctx::Coll));
            }
            RoundPost::bare(reqs)
        }));
    }
    {
        let comm = comm.clone();
        rounds.push(Box::new(move || {
            // SAFETY: children's contributions landed in round 0; the
            // caller holds the buffer untouched.
            let acc = unsafe { buf.slice_mut() };
            let g = temps.lock().unwrap();
            for t in g.iter() {
                op(&mut *acc, &t[..]); // fixed child order: deterministic rounding
            }
            drop(g);
            let mut reqs = Vec::new();
            if vr != 0 {
                let parent_vr = vr & (vr - 1);
                let parent = (parent_vr + root) % n;
                let src = unsafe { buf.slice() };
                reqs.push(comm.isend_ctx(src, parent, tag, false, Ctx::Coll));
            }
            RoundPost::bare(reqs)
        }));
    }
    rounds
}

/// Allreduce = reduce-to-0 then bcast-from-0, chained in one schedule
/// (two sequence numbers, matching the blocking composition).
pub(crate) fn allreduce_schedule<T: Pod>(
    comm: &Comm,
    buf: UserBuf<T>,
    op: Box<dyn Fn(&mut [T], &[T]) + Send>,
) -> Vec<RoundFn> {
    let seq_reduce = comm.next_coll_seq();
    let seq_bcast = comm.next_coll_seq();
    let mut rounds = reduce_schedule(comm, buf, 0, seq_reduce, op);
    rounds.extend(bcast_schedule(comm, buf, 0, seq_bcast));
    rounds
}

/// Flat gather to `root`: one round (root posts all receives and copies
/// its own chunk; leaves send). Round 0 posts at launch, so `send` is
/// read zero-copy while the caller's borrow is live.
pub(crate) fn gather_schedule<T: Pod>(
    comm: &Comm,
    send: UserRef<T>,
    recv: Option<UserBuf<T>>,
    root: usize,
) -> Vec<RoundFn> {
    let n = comm.size;
    let seq = comm.next_coll_seq();
    let tag = coll_tag(seq, 0);
    let mut rounds: Vec<RoundFn> = Vec::new();
    if comm.rank == root {
        let recv = recv.expect("root must pass a receive buffer");
        assert_eq!(recv.len(), send.len() * n);
        let comm = comm.clone();
        rounds.push(Box::new(move || {
            let chunk = send.len();
            let mut reqs = Vec::new();
            for r in 0..n {
                // SAFETY: per-rank regions are disjoint by construction;
                // the send view is read during launch only.
                let dst = unsafe { recv.region_mut(r * chunk, chunk) };
                if r == root {
                    dst.copy_from_slice(unsafe { send.slice() });
                } else {
                    reqs.push(comm.irecv_ctx(dst, r as i32, tag, Ctx::Coll));
                }
            }
            RoundPost::bare(reqs)
        }));
    } else {
        let comm = comm.clone();
        rounds.push(Box::new(move || {
            // SAFETY: read during launch; isend copies eagerly.
            let src = unsafe { send.slice() };
            RoundPost::bare(vec![comm.isend_ctx(src, root, tag, false, Ctx::Coll)])
        }));
    }
    rounds
}

/// Alltoallv: a single round posting all receives (in displacement
/// order, like the blocking algorithm) followed by all sends. Round 0
/// posts at launch, so `send` is read zero-copy while the caller's
/// borrow is live.
#[allow(clippy::too_many_arguments)]
pub(crate) fn alltoallv_schedule<T: Pod>(
    comm: &Comm,
    send: UserRef<T>,
    scounts: Vec<usize>,
    sdispls: Vec<usize>,
    recv: UserBuf<T>,
    rcounts: Vec<usize>,
    rdispls: Vec<usize>,
) -> Vec<RoundFn> {
    let n = comm.size;
    assert!(scounts.len() == n && rcounts.len() == n);
    // Validate the receive regions are disjoint and in bounds (the
    // blocking algorithm enforced this through split_at_mut arithmetic).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&r| rdispls[r]);
    let mut end = 0usize;
    for &r in &order {
        assert!(rdispls[r] >= end, "overlapping alltoallv receive regions");
        end = rdispls[r] + rcounts[r];
    }
    assert!(end <= recv.len(), "alltoallv receive buffer too small");

    let seq = comm.next_coll_seq();
    let tag = coll_tag(seq, 0);
    let comm = comm.clone();
    let round: RoundFn = Box::new(move || {
        let rank = comm.rank;
        // SAFETY: read during launch only; isend copies eagerly.
        let send = unsafe { send.slice() };
        let mut reqs = Vec::with_capacity(2 * n);
        // Receives first (deterministic matching), in displacement order.
        for &r in &order {
            // SAFETY: regions validated disjoint above; caller contract.
            let dst = unsafe { recv.region_mut(rdispls[r], rcounts[r]) };
            if r == rank {
                dst.copy_from_slice(&send[sdispls[r]..sdispls[r] + rcounts[r]]);
            } else {
                reqs.push(comm.irecv_ctx(dst, r as i32, tag, Ctx::Coll));
            }
        }
        for r in 0..n {
            if r != rank {
                reqs.push(comm.isend_ctx(
                    &send[sdispls[r]..sdispls[r] + scounts[r]],
                    r,
                    tag,
                    false,
                    Ctx::Coll,
                ));
            }
        }
        RoundPost::bare(reqs)
    });
    vec![round]
}
