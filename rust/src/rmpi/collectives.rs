//! Collectives over p2p on the dedicated collective context — all built
//! on the schedule-driven engine in [`super::coll_schedule`], compiled
//! by the topology-aware planner in [`super::topology`].
//!
//! Two surfaces over ONE engine:
//!
//! * **Non-blocking** (`ibarrier`, `ibcast`, `ireduce`, `iallreduce`,
//!   `igather`, `ialltoall`, `ialltoallv`): look the collective's plan
//!   up in the communicator's persistent schedule cache (compiling on a
//!   miss — MPI persistent-collective semantics), instantiate it into a
//!   [`CollSchedule`] and return a [`CollRequest`] immediately. The
//!   progress engine advances the rounds; the request composes with
//!   [`Request::wait`]/[`Request::wait_any`], TAMPI `iwait`/`iwaitall`,
//!   and task external-event binding (Section 6.1/6.2 extended to
//!   collectives). MPI contract: the buffers passed to an `i*` call must
//!   stay valid and untouched until the request completes.
//! * **Blocking** (`barrier`, `bcast`, …, plus the `*_with(WaitMode)`
//!   variants TAMPI uses): thin wrappers that launch the same schedule
//!   and wait on its final request. `WaitMode::Park` blocks the OS
//!   thread; `WaitMode::TaskAware` routes the single wait through
//!   `tampi`-style pause/resume. Because rounds advance on the engine —
//!   never on the waiting thread — even a Park-mode collective inside a
//!   task cannot stall the collective's own progress.
//!
//! Plan lookups charge the model's compile cost on a miss
//! ([`crate::rmpi::NetworkModel::sched_compile_ns`]) and the much
//! smaller lookup cost on a hit (`sched_cache_hit_ns`), bump the
//! cluster-wide counters surfaced as
//! [`crate::rmpi::RunStats::sched_cache`], and stamp the launch with a
//! [`crate::trace::EventKind::CollScheduleCompiled`] `{ cached }` record.
//!
//! Collective-internal requests are created through the calling rank's
//! [`Comm`], so under [`crate::progress::DeliveryMode::Sharded`] a
//! round's completion wave — e.g. the `2(n-1)` requests of an alltoallv
//! landing at one virtual instant — is delivered as *one* batch per
//! participating rank's shard, and the shard drain itself posts the next
//! round (see the `progress` and `coll_schedule` module docs).

use crate::nanos::CompletionMode;

use super::coll_schedule::{
    instantiate_alltoall_hier, instantiate_alltoallv_flat, instantiate_barrier,
    instantiate_bcast, instantiate_gather, instantiate_reduce, CollSchedule, UserBuf,
    UserRef,
};
use super::comm::Comm;
use super::request::Request;
use super::topology::{CollKind, CollPlan, SchedKey, ShapeKey};
use super::Pod;

pub use super::coll_schedule::CollRequest;

/// A reduction combiner `op(acc, incoming)`, with an opt-in
/// commutativity declaration.
///
/// Plain closures implement this with `commutative() == false`: the
/// compiler pins the flat binomial combine order so results are
/// bit-identical across topology modes, delivery modes and wait styles
/// (the contract documented in [`super::topology`]). Wrapping the
/// closure in [`commutative`] declares reordering safe
/// (commutative + associative, e.g. integer sum/min/max, bitwise ops),
/// which frees the compiler to re-root the combine tree through node
/// leaders when the network model says that wins — at the price of a
/// different (but still deterministic) combine association.
///
/// The plain `reduce`/`allreduce` entry points keep their direct
/// `Fn(&mut [T], &[T])` bounds (unannotated closures infer there); the
/// `*_op` variants take any [`Combiner`] — that is where a
/// [`commutative`]-wrapped op goes (annotate its closure's parameter
/// types: the marker's indirection defeats closure-signature
/// inference).
pub trait Combiner<T>: Send + 'static {
    /// Fold `incoming` into `acc`, element-wise.
    fn combine(&self, acc: &mut [T], incoming: &[T]);

    /// Whether the op declared reordering safe (default: no).
    fn commutative(&self) -> bool {
        false
    }
}

impl<T, F: Fn(&mut [T], &[T]) + Send + 'static> Combiner<T> for F {
    fn combine(&self, acc: &mut [T], incoming: &[T]) {
        self(acc, incoming)
    }
}

/// The commutativity marker (see [`Combiner`]): `commutative(op)`
/// opts `op` into hierarchical combine-tree re-rooting.
pub struct Commutative<F>(pub F);

impl<T, F: Fn(&mut [T], &[T]) + Send + 'static> Combiner<T> for Commutative<F> {
    fn combine(&self, acc: &mut [T], incoming: &[T]) {
        (self.0)(acc, incoming)
    }

    fn commutative(&self) -> bool {
        true
    }
}

/// Mark a reduction op as commutative + associative (MPI's
/// `MPI_Op_create(…, commute = true)`): the ROADMAP's commutative-op
/// relaxation. Goes through the `*_op` entry points:
/// `comm.allreduce_op(&mut v, commutative(|a: &mut [u64], b: &[u64]| a[0] += b[0]))`.
pub fn commutative<F>(f: F) -> Commutative<F> {
    Commutative(f)
}

/// How a blocking collective waits for its final request.
#[derive(Clone, Copy, Default)]
pub enum WaitMode {
    /// Block the calling OS thread (plain MPI behaviour).
    #[default]
    Park,
    /// Pause the calling task instead (requires TAMPI blocking mode;
    /// degrades to `Park` outside a task). Carries an optional
    /// completion-mode override: `None` follows the runtime's configured
    /// mode; `Some` pins the pipeline (set by [`crate::tampi::Tampi`]
    /// handles created with `init_with_mode`, so a per-handle override
    /// also governs the handle's collective waits).
    TaskAware(Option<CompletionMode>),
}

impl Comm {
    fn coll_wait(&self, mode: WaitMode, reqs: &[Request]) {
        match mode {
            WaitMode::Park => Request::wait_all(&self.uni.clock, reqs),
            WaitMode::TaskAware(over) => {
                crate::tampi::task_aware_wait_all_with(self, reqs, over)
            }
        }
    }

    // ----- non-blocking surface: plan lookup, schedule launch -----

    /// Non-blocking barrier (MPI_Ibarrier): dissemination rounds, flat
    /// or leader-staged per the topology compiler.
    pub fn ibarrier(&self) -> CollRequest {
        let key =
            SchedKey { kind: CollKind::Barrier, root: 0, shape: ShapeKey::None, avoid: 0 };
        let (plan, cached) = self.plan_for(key);
        let seq = self.next_coll_seq();
        let CollPlan::Barrier(p) = &*plan else { unreachable!("barrier plan") };
        CollSchedule::launch(self, "barrier", seq, cached, instantiate_barrier(self, p, seq))
    }

    /// Non-blocking broadcast (MPI_Ibcast): binomial/hierarchical tree
    /// rooted at `root`. `buf` must stay untouched until the request
    /// completes.
    pub fn ibcast<T: Pod>(&self, buf: &mut [T], root: usize) -> CollRequest {
        let shape = ShapeKey::Bytes(std::mem::size_of_val::<[T]>(buf));
        let key = SchedKey { kind: CollKind::Bcast, root, shape, avoid: 0 };
        let (plan, cached) = self.plan_for(key);
        let seq = self.next_coll_seq();
        let CollPlan::Bcast(p) = &*plan else { unreachable!("bcast plan") };
        CollSchedule::launch(
            self,
            "bcast",
            seq,
            cached,
            instantiate_bcast(self, p, UserBuf::new(buf), seq),
        )
    }

    /// Non-blocking reduction (MPI_Ireduce) with combiner
    /// `op(acc, incoming)`, applied in a fixed deterministic order.
    pub fn ireduce<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) -> CollRequest {
        self.ireduce_op(buf, root, op)
    }

    /// [`Comm::ireduce`] over any [`Combiner`]: wrapping the op in
    /// [`commutative`] frees the compiler to re-root the combine tree
    /// hierarchically.
    pub fn ireduce_op<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Combiner<T>,
    ) -> CollRequest {
        // Pinned-order reduce plans are shape-independent (the binomial
        // tree depends only on size and root), so their key is
        // shapeless: every payload size shares one cached plan per
        // root. Commutative ops cache per payload size — re-rooting is
        // cost-driven, and cost depends on bytes.
        let key = if op.commutative() {
            let shape = ShapeKey::Bytes(std::mem::size_of_val::<[T]>(buf));
            SchedKey { kind: CollKind::ReduceComm, root, shape, avoid: 0 }
        } else {
            SchedKey { kind: CollKind::Reduce, root, shape: ShapeKey::None, avoid: 0 }
        };
        let (plan, cached) = self.plan_for(key);
        let seq = self.next_coll_seq();
        let CollPlan::Reduce(p) = &*plan else { unreachable!("reduce plan") };
        let f = Box::new(move |a: &mut [T], b: &[T]| op.combine(a, b));
        CollSchedule::launch(
            self,
            "reduce",
            seq,
            cached,
            instantiate_reduce(self, p, UserBuf::new(buf), seq, f),
        )
    }

    /// Non-blocking allreduce (MPI_Iallreduce) = reduce-to-0 + bcast-
    /// from-0 chained in one schedule (two sequence numbers, one plan).
    pub fn iallreduce<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) -> CollRequest {
        self.iallreduce_op(buf, op)
    }

    /// [`Comm::iallreduce`] over any [`Combiner`]: a
    /// [`commutative`]-marked op re-roots the combine half where the
    /// network model says it wins.
    pub fn iallreduce_op<T: Pod>(&self, buf: &mut [T], op: impl Combiner<T>) -> CollRequest {
        let shape = ShapeKey::Bytes(std::mem::size_of_val::<[T]>(buf));
        let kind = if op.commutative() {
            CollKind::AllreduceComm
        } else {
            CollKind::Allreduce
        };
        let key = SchedKey { kind, root: 0, shape, avoid: 0 };
        let (plan, cached) = self.plan_for(key);
        let seq_reduce = self.next_coll_seq();
        let seq_bcast = self.next_coll_seq();
        let CollPlan::Allreduce { reduce, bcast } = &*plan else {
            unreachable!("allreduce plan")
        };
        let ub = UserBuf::new(buf);
        let f = Box::new(move |a: &mut [T], b: &[T]| op.combine(a, b));
        let mut rounds = instantiate_reduce(self, reduce, ub, seq_reduce, f);
        rounds.extend(instantiate_bcast(self, bcast, ub, seq_bcast));
        CollSchedule::launch(self, "allreduce", seq_reduce, cached, rounds)
    }

    /// Non-blocking gather (MPI_Igather): fixed-size contribution per
    /// rank into root's buffer (leader-staged when fan-in processing
    /// dominates).
    pub fn igather<T: Pod>(
        &self,
        send: &[T],
        recv: Option<&mut [T]>,
        root: usize,
    ) -> CollRequest {
        let shape = ShapeKey::ChunkBytes(std::mem::size_of_val::<[T]>(send));
        let key = SchedKey { kind: CollKind::Gather, root, shape, avoid: 0 };
        let (plan, cached) = self.plan_for(key);
        let seq = self.next_coll_seq();
        let CollPlan::Gather(p) = &*plan else { unreachable!("gather plan") };
        CollSchedule::launch(
            self,
            "gather",
            seq,
            cached,
            instantiate_gather(self, p, UserRef::new(send), recv.map(UserBuf::new), seq),
        )
    }

    /// Non-blocking alltoall (MPI_Ialltoall): equal-size blocks,
    /// pairwise or leader-staged per the topology compiler.
    pub fn ialltoall<T: Pod>(&self, send: &[T], recv: &mut [T]) -> CollRequest {
        let n = self.size;
        assert_eq!(send.len() % n, 0);
        assert_eq!(recv.len(), send.len());
        let chunk = send.len() / n;
        let shape = ShapeKey::ChunkBytes(chunk * std::mem::size_of::<T>());
        let key = SchedKey { kind: CollKind::Alltoall, root: 0, shape, avoid: 0 };
        let (plan, cached) = self.plan_for(key);
        let seq = self.next_coll_seq();
        let rounds = match &*plan {
            CollPlan::AlltoallHier(h) => instantiate_alltoall_hier(
                self,
                h,
                UserRef::new(send),
                UserBuf::new(recv),
                chunk,
                seq,
            ),
            CollPlan::AlltoallvFlat => {
                let counts: Vec<usize> = vec![chunk; n];
                let displs: Vec<usize> = (0..n).map(|i| i * chunk).collect();
                instantiate_alltoallv_flat(
                    self,
                    UserRef::new(send),
                    counts.clone(),
                    displs.clone(),
                    UserBuf::new(recv),
                    counts,
                    displs,
                    seq,
                )
            }
            _ => unreachable!("alltoall plan"),
        };
        CollSchedule::launch(self, "alltoall", seq, cached, rounds)
    }

    /// Non-blocking alltoallv (MPI_Ialltoallv): variable blocks; the
    /// transposition primitive IFSKer uses between grid-point and
    /// spectral distributions (Section 7.2). Always pairwise: counts
    /// are per-rank values, so a staged plan could not be agreed on (or
    /// sized) without an extra count exchange — see
    /// [`super::topology::compile_plan`]. The plan is therefore
    /// count-independent and the cache key shapeless (no O(ranks)
    /// count vectors cloned or stored per signature); counts bind at
    /// instantiation.
    #[allow(clippy::too_many_arguments)]
    pub fn ialltoallv<T: Pod>(
        &self,
        send: &[T],
        scounts: &[usize],
        sdispls: &[usize],
        recv: &mut [T],
        rcounts: &[usize],
        rdispls: &[usize],
    ) -> CollRequest {
        let key =
            SchedKey { kind: CollKind::Alltoallv, root: 0, shape: ShapeKey::None, avoid: 0 };
        let (plan, cached) = self.plan_for(key);
        let seq = self.next_coll_seq();
        debug_assert!(matches!(&*plan, CollPlan::AlltoallvFlat));
        CollSchedule::launch(
            self,
            "alltoallv",
            seq,
            cached,
            instantiate_alltoallv_flat(
                self,
                UserRef::new(send),
                scounts.to_vec(),
                sdispls.to_vec(),
                UserBuf::new(recv),
                rcounts.to_vec(),
                rdispls.to_vec(),
                seq,
            ),
        )
    }

    // ----- blocking surface: wrappers over the same schedules -----

    /// MPI_Barrier.
    pub fn barrier(&self) {
        self.barrier_with(WaitMode::Park)
    }

    pub fn barrier_with(&self, mode: WaitMode) {
        let cr = self.ibarrier();
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Bcast (tree rooted at `root`).
    pub fn bcast<T: Pod>(&self, buf: &mut [T], root: usize) {
        self.bcast_with(buf, root, WaitMode::Park)
    }

    pub fn bcast_with<T: Pod>(&self, buf: &mut [T], root: usize, mode: WaitMode) {
        let cr = self.ibcast(buf, root);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Reduce with a user combiner `op(acc, incoming)` (the pinned
    /// combine order; see [`commutative`] and [`Comm::reduce_op`]).
    pub fn reduce<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) {
        self.reduce_op_with(buf, root, op, WaitMode::Park)
    }

    pub fn reduce_with<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
        mode: WaitMode,
    ) {
        self.reduce_op_with(buf, root, op, mode)
    }

    /// Blocking reduce over any [`Combiner`].
    pub fn reduce_op<T: Pod>(&self, buf: &mut [T], root: usize, op: impl Combiner<T>) {
        self.reduce_op_with(buf, root, op, WaitMode::Park)
    }

    pub fn reduce_op_with<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Combiner<T>,
        mode: WaitMode,
    ) {
        let cr = self.ireduce_op(buf, root, op);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Allreduce = reduce to 0 + bcast from 0.
    pub fn allreduce<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) {
        self.allreduce_op_with(buf, op, WaitMode::Park)
    }

    pub fn allreduce_with<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
        mode: WaitMode,
    ) {
        self.allreduce_op_with(buf, op, mode)
    }

    /// Blocking allreduce over any [`Combiner`] (the [`commutative`]
    /// entry point).
    pub fn allreduce_op<T: Pod>(&self, buf: &mut [T], op: impl Combiner<T>) {
        self.allreduce_op_with(buf, op, WaitMode::Park)
    }

    pub fn allreduce_op_with<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Combiner<T>,
        mode: WaitMode,
    ) {
        let cr = self.iallreduce_op(buf, op);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Gather: fixed-size contribution per rank into root's buffer.
    pub fn gather<T: Pod>(&self, send: &[T], recv: Option<&mut [T]>, root: usize) {
        self.gather_with(send, recv, root, WaitMode::Park)
    }

    pub fn gather_with<T: Pod>(
        &self,
        send: &[T],
        recv: Option<&mut [T]>,
        root: usize,
        mode: WaitMode,
    ) {
        let cr = self.igather(send, recv, root);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Alltoall: equal-size blocks to/from every rank.
    pub fn alltoall<T: Pod>(&self, send: &[T], recv: &mut [T]) {
        let cr = self.ialltoall(send, recv);
        self.coll_wait(WaitMode::Park, std::slice::from_ref(cr.request()));
    }

    /// MPI_Alltoallv: variable blocks.
    #[allow(clippy::too_many_arguments)]
    pub fn alltoallv<T: Pod>(
        &self,
        send: &[T],
        scounts: &[usize],
        sdispls: &[usize],
        recv: &mut [T],
        rcounts: &[usize],
        rdispls: &[usize],
        mode: WaitMode,
    ) {
        let cr = self.ialltoallv(send, scounts, sdispls, recv, rcounts, rdispls);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }
}
