//! Collectives over p2p on the dedicated collective context.
//!
//! Every collective is expressed through a *wait strategy* so the TAMPI
//! layer can reuse the same algorithms with task-aware waiting (the paper
//! intercepts collective operations too, Section 6.1): `WaitMode::Park`
//! blocks the OS thread; `WaitMode::TaskAware` routes each internal wait
//! through `tampi`-style pause/resume (installed by the tampi module).
//!
//! Collective-internal requests are created through the calling rank's
//! [`Comm`], so under [`crate::progress::DeliveryMode::Sharded`] a
//! collective's completion wave — e.g. the `2(n-1)` requests of an
//! alltoallv landing at one virtual instant — is delivered as *one*
//! batch per participating rank's shard, not one scheduler-lock
//! acquisition per request (see the `progress` module docs).

use crate::nanos::CompletionMode;

use super::comm::Comm;
use super::p2p::Ctx;
use super::request::Request;
use super::Pod;

/// How a collective waits for its internal requests.
#[derive(Clone, Copy, Default)]
pub enum WaitMode {
    /// Block the calling OS thread (plain MPI behaviour).
    #[default]
    Park,
    /// Pause the calling task instead (requires TAMPI blocking mode;
    /// panics outside a task). Carries an optional completion-mode
    /// override: `None` follows the runtime's configured mode; `Some`
    /// pins the pipeline (set by [`crate::tampi::Tampi`] handles created
    /// with `init_with_mode`, so a per-handle override also governs the
    /// handle's collective waits).
    TaskAware(Option<CompletionMode>),
}

impl Comm {
    fn coll_wait(&self, mode: WaitMode, reqs: &[Request]) {
        match mode {
            WaitMode::Park => Request::wait_all(&self.uni.clock, reqs),
            WaitMode::TaskAware(over) => {
                crate::tampi::task_aware_wait_all_with(self, reqs, over)
            }
        }
    }

    /// MPI_Barrier (dissemination algorithm, log2(size) rounds).
    pub fn barrier(&self) {
        self.barrier_with(WaitMode::Park)
    }

    pub fn barrier_with(&self, mode: WaitMode) {
        let tag = self.next_coll_tag();
        let n = self.size;
        if n == 1 {
            return;
        }
        let token = [1u8];
        let mut round = 1usize;
        while round < n {
            let to = (self.rank + round) % n;
            let from = (self.rank + n - round % n) % n;
            let mut buf = [0u8];
            let s = self.isend_ctx(&token, to, tag, false, Ctx::Coll);
            let r = self.irecv_ctx(&mut buf, from as i32, tag, Ctx::Coll);
            self.coll_wait(mode, &[s, r]);
            round <<= 1;
        }
    }

    /// MPI_Bcast (binomial tree rooted at `root`).
    pub fn bcast<T: Pod>(&self, buf: &mut [T], root: usize) {
        self.bcast_with(buf, root, WaitMode::Park)
    }

    pub fn bcast_with<T: Pod>(&self, buf: &mut [T], root: usize, mode: WaitMode) {
        let tag = self.next_coll_tag();
        let n = self.size;
        if n == 1 {
            return;
        }
        let vr = (self.rank + n - root) % n; // virtual rank, root -> 0
        if vr != 0 {
            let parent = ((vr - 1) / 2 + root) % n;
            let r = self.irecv_ctx(buf, parent as i32, tag, Ctx::Coll);
            self.coll_wait(mode, &[r]);
        }
        let mut reqs = Vec::new();
        for child in [2 * vr + 1, 2 * vr + 2] {
            if child < n {
                let dst = (child + root) % n;
                reqs.push(self.isend_ctx(&*buf, dst, tag, false, Ctx::Coll));
            }
        }
        if !reqs.is_empty() {
            self.coll_wait(mode, &reqs);
        }
    }

    /// MPI_Reduce with a user combiner `op(acc, incoming)`.
    pub fn reduce<T: Pod>(&self, buf: &mut [T], root: usize, op: impl Fn(&mut [T], &[T])) {
        self.reduce_with(buf, root, op, WaitMode::Park)
    }

    pub fn reduce_with<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Fn(&mut [T], &[T]),
        mode: WaitMode,
    ) {
        let tag = self.next_coll_tag();
        let n = self.size;
        if n == 1 {
            return;
        }
        let vr = (self.rank + n - root) % n;
        // Receive from children (binomial: children are vr + 2^k while valid).
        let mut k = 1usize;
        while vr + k < n && (vr & k) == 0 {
            let child = ((vr + k) + root) % n;
            let mut tmp = vec![buf[0]; buf.len()];
            let r = self.irecv_ctx(&mut tmp, child as i32, tag, Ctx::Coll);
            self.coll_wait(mode, &[r]);
            op(buf, &tmp);
            k <<= 1;
        }
        if vr != 0 {
            // Parent: clear the lowest set bit of vr.
            let parent_vr = vr & (vr - 1);
            let parent = (parent_vr + root) % n;
            let s = self.isend_ctx(&*buf, parent, tag, false, Ctx::Coll);
            self.coll_wait(mode, &[s]);
        }
    }

    /// MPI_Allreduce = reduce to 0 + bcast from 0.
    pub fn allreduce<T: Pod>(&self, buf: &mut [T], op: impl Fn(&mut [T], &[T])) {
        self.allreduce_with(buf, op, WaitMode::Park)
    }

    pub fn allreduce_with<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]),
        mode: WaitMode,
    ) {
        self.reduce_with(buf, 0, op, mode);
        self.bcast_with(buf, 0, mode);
    }

    /// MPI_Gather: fixed-size contribution per rank into root's buffer.
    pub fn gather<T: Pod>(&self, send: &[T], recv: Option<&mut [T]>, root: usize) {
        self.gather_with(send, recv, root, WaitMode::Park)
    }

    pub fn gather_with<T: Pod>(
        &self,
        send: &[T],
        recv: Option<&mut [T]>,
        root: usize,
        mode: WaitMode,
    ) {
        let tag = self.next_coll_tag();
        let n = self.size;
        if self.rank == root {
            let recv = recv.expect("root must pass a receive buffer");
            assert_eq!(recv.len(), send.len() * n);
            let chunk = send.len();
            let mut reqs = Vec::new();
            for r in 0..n {
                if r == root {
                    recv[r * chunk..(r + 1) * chunk].copy_from_slice(send);
                } else {
                    reqs.push(self.irecv_ctx(
                        &mut recv[r * chunk..(r + 1) * chunk],
                        r as i32,
                        tag,
                        Ctx::Coll,
                    ));
                }
            }
            self.coll_wait(mode, &reqs);
        } else {
            let s = self.isend_ctx(send, root, tag, false, Ctx::Coll);
            self.coll_wait(mode, &[s]);
        }
    }

    /// MPI_Alltoall: equal-size blocks to/from every rank.
    pub fn alltoall<T: Pod>(&self, send: &[T], recv: &mut [T]) {
        let n = self.size;
        assert_eq!(send.len() % n, 0);
        assert_eq!(recv.len(), send.len());
        let chunk = send.len() / n;
        let scounts: Vec<usize> = vec![chunk; n];
        let sdispls: Vec<usize> = (0..n).map(|i| i * chunk).collect();
        self.alltoallv(send, &scounts, &sdispls, recv, &scounts, &sdispls, WaitMode::Park);
    }

    /// MPI_Alltoallv: variable blocks; the transposition primitive IFSKer
    /// uses between grid-point and spectral distributions (Section 7.2).
    #[allow(clippy::too_many_arguments)]
    pub fn alltoallv<T: Pod>(
        &self,
        send: &[T],
        scounts: &[usize],
        sdispls: &[usize],
        recv: &mut [T],
        rcounts: &[usize],
        rdispls: &[usize],
        mode: WaitMode,
    ) {
        let tag = self.next_coll_tag();
        let n = self.size;
        assert!(scounts.len() == n && rcounts.len() == n);
        let mut reqs = Vec::with_capacity(2 * n);
        // Post all receives first (deterministic matching), then sends.
        // Split recv into disjoint slices.
        let mut rest: &mut [T] = recv;
        let mut offset = 0usize;
        let mut rslices: Vec<(usize, &mut [T])> = Vec::new(); // (rank, slice)
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&r| rdispls[r]);
        for &r in &order {
            let skip = rdispls[r] - offset;
            let (_, tail) = rest.split_at_mut(skip);
            let (slice, tail) = tail.split_at_mut(rcounts[r]);
            rest = tail;
            offset = rdispls[r] + rcounts[r];
            rslices.push((r, slice));
        }
        for (r, slice) in rslices.iter_mut() {
            if *r == self.rank {
                slice.copy_from_slice(&send[sdispls[*r]..sdispls[*r] + rcounts[*r]]);
            } else {
                reqs.push(self.irecv_ctx(slice, *r as i32, tag, Ctx::Coll));
            }
        }
        for r in 0..n {
            if r != self.rank {
                reqs.push(self.isend_ctx(
                    &send[sdispls[r]..sdispls[r] + scounts[r]],
                    r,
                    tag,
                    false,
                    Ctx::Coll,
                ));
            }
        }
        self.coll_wait(mode, &reqs);
    }
}
