//! Collectives over p2p on the dedicated collective context — all built
//! on the schedule-driven engine in [`super::coll_schedule`].
//!
//! Two surfaces over ONE engine:
//!
//! * **Non-blocking** (`ibarrier`, `ibcast`, `ireduce`, `iallreduce`,
//!   `igather`, `ialltoall`, `ialltoallv`): compile the collective into
//!   a [`CollSchedule`] and return a [`CollRequest`] immediately. The
//!   progress engine advances the rounds; the request composes with
//!   [`Request::wait`]/[`Request::wait_any`], TAMPI `iwait`/`iwaitall`,
//!   and task external-event binding (Section 6.1/6.2 extended to
//!   collectives). MPI contract: the buffers passed to an `i*` call must
//!   stay valid and untouched until the request completes.
//! * **Blocking** (`barrier`, `bcast`, …, plus the `*_with(WaitMode)`
//!   variants TAMPI uses): thin wrappers that launch the same schedule
//!   and wait on its final request. `WaitMode::Park` blocks the OS
//!   thread; `WaitMode::TaskAware` routes the single wait through
//!   `tampi`-style pause/resume. Because rounds advance on the engine —
//!   never on the waiting thread — even a Park-mode collective inside a
//!   task cannot stall the collective's own progress.
//!
//! Collective-internal requests are created through the calling rank's
//! [`Comm`], so under [`crate::progress::DeliveryMode::Sharded`] a
//! round's completion wave — e.g. the `2(n-1)` requests of an alltoallv
//! landing at one virtual instant — is delivered as *one* batch per
//! participating rank's shard, and the shard drain itself posts the next
//! round (see the `progress` and `coll_schedule` module docs).

use crate::nanos::CompletionMode;

use super::coll_schedule::{
    allreduce_schedule, alltoallv_schedule, barrier_schedule, bcast_schedule,
    gather_schedule, reduce_schedule, CollSchedule, UserBuf, UserRef,
};
use super::comm::Comm;
use super::request::Request;
use super::Pod;

pub use super::coll_schedule::CollRequest;

/// How a blocking collective waits for its final request.
#[derive(Clone, Copy, Default)]
pub enum WaitMode {
    /// Block the calling OS thread (plain MPI behaviour).
    #[default]
    Park,
    /// Pause the calling task instead (requires TAMPI blocking mode;
    /// degrades to `Park` outside a task). Carries an optional
    /// completion-mode override: `None` follows the runtime's configured
    /// mode; `Some` pins the pipeline (set by [`crate::tampi::Tampi`]
    /// handles created with `init_with_mode`, so a per-handle override
    /// also governs the handle's collective waits).
    TaskAware(Option<CompletionMode>),
}

impl Comm {
    fn coll_wait(&self, mode: WaitMode, reqs: &[Request]) {
        match mode {
            WaitMode::Park => Request::wait_all(&self.uni.clock, reqs),
            WaitMode::TaskAware(over) => {
                crate::tampi::task_aware_wait_all_with(self, reqs, over)
            }
        }
    }

    // ----- non-blocking surface: schedule launch, request back -----

    /// Non-blocking barrier (MPI_Ibarrier): dissemination algorithm,
    /// log2(size) engine-driven rounds.
    pub fn ibarrier(&self) -> CollRequest {
        CollSchedule::launch(self, "barrier", barrier_schedule(self))
    }

    /// Non-blocking broadcast (MPI_Ibcast): binomial tree rooted at
    /// `root`. `buf` must stay untouched until the request completes.
    pub fn ibcast<T: Pod>(&self, buf: &mut [T], root: usize) -> CollRequest {
        let seq = self.next_coll_seq();
        CollSchedule::launch(
            self,
            "bcast",
            bcast_schedule(self, UserBuf::new(buf), root, seq),
        )
    }

    /// Non-blocking reduction (MPI_Ireduce) with combiner
    /// `op(acc, incoming)`, applied in a fixed deterministic order.
    pub fn ireduce<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) -> CollRequest {
        let seq = self.next_coll_seq();
        CollSchedule::launch(
            self,
            "reduce",
            reduce_schedule(self, UserBuf::new(buf), root, seq, Box::new(op)),
        )
    }

    /// Non-blocking allreduce (MPI_Iallreduce) = reduce-to-0 + bcast-
    /// from-0 chained in one schedule.
    pub fn iallreduce<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) -> CollRequest {
        CollSchedule::launch(
            self,
            "allreduce",
            allreduce_schedule(self, UserBuf::new(buf), Box::new(op)),
        )
    }

    /// Non-blocking gather (MPI_Igather): fixed-size contribution per
    /// rank into root's buffer.
    pub fn igather<T: Pod>(
        &self,
        send: &[T],
        recv: Option<&mut [T]>,
        root: usize,
    ) -> CollRequest {
        CollSchedule::launch(
            self,
            "gather",
            gather_schedule(self, UserRef::new(send), recv.map(UserBuf::new), root),
        )
    }

    /// Non-blocking alltoall (MPI_Ialltoall): equal-size blocks.
    pub fn ialltoall<T: Pod>(&self, send: &[T], recv: &mut [T]) -> CollRequest {
        let n = self.size;
        assert_eq!(send.len() % n, 0);
        assert_eq!(recv.len(), send.len());
        let chunk = send.len() / n;
        let counts: Vec<usize> = vec![chunk; n];
        let displs: Vec<usize> = (0..n).map(|i| i * chunk).collect();
        self.ialltoallv(send, &counts, &displs, recv, &counts, &displs)
    }

    /// Non-blocking alltoallv (MPI_Ialltoallv): variable blocks; the
    /// transposition primitive IFSKer uses between grid-point and
    /// spectral distributions (Section 7.2).
    #[allow(clippy::too_many_arguments)]
    pub fn ialltoallv<T: Pod>(
        &self,
        send: &[T],
        scounts: &[usize],
        sdispls: &[usize],
        recv: &mut [T],
        rcounts: &[usize],
        rdispls: &[usize],
    ) -> CollRequest {
        CollSchedule::launch(
            self,
            "alltoallv",
            alltoallv_schedule(
                self,
                UserRef::new(send),
                scounts.to_vec(),
                sdispls.to_vec(),
                UserBuf::new(recv),
                rcounts.to_vec(),
                rdispls.to_vec(),
            ),
        )
    }

    // ----- blocking surface: wrappers over the same schedules -----

    /// MPI_Barrier (dissemination algorithm, log2(size) rounds).
    pub fn barrier(&self) {
        self.barrier_with(WaitMode::Park)
    }

    pub fn barrier_with(&self, mode: WaitMode) {
        let cr = self.ibarrier();
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Bcast (binomial tree rooted at `root`).
    pub fn bcast<T: Pod>(&self, buf: &mut [T], root: usize) {
        self.bcast_with(buf, root, WaitMode::Park)
    }

    pub fn bcast_with<T: Pod>(&self, buf: &mut [T], root: usize, mode: WaitMode) {
        let cr = self.ibcast(buf, root);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Reduce with a user combiner `op(acc, incoming)`.
    pub fn reduce<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) {
        self.reduce_with(buf, root, op, WaitMode::Park)
    }

    pub fn reduce_with<T: Pod>(
        &self,
        buf: &mut [T],
        root: usize,
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
        mode: WaitMode,
    ) {
        let cr = self.ireduce(buf, root, op);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Allreduce = reduce to 0 + bcast from 0.
    pub fn allreduce<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) {
        self.allreduce_with(buf, op, WaitMode::Park)
    }

    pub fn allreduce_with<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
        mode: WaitMode,
    ) {
        let cr = self.iallreduce(buf, op);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Gather: fixed-size contribution per rank into root's buffer.
    pub fn gather<T: Pod>(&self, send: &[T], recv: Option<&mut [T]>, root: usize) {
        self.gather_with(send, recv, root, WaitMode::Park)
    }

    pub fn gather_with<T: Pod>(
        &self,
        send: &[T],
        recv: Option<&mut [T]>,
        root: usize,
        mode: WaitMode,
    ) {
        let cr = self.igather(send, recv, root);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }

    /// MPI_Alltoall: equal-size blocks to/from every rank.
    pub fn alltoall<T: Pod>(&self, send: &[T], recv: &mut [T]) {
        let cr = self.ialltoall(send, recv);
        self.coll_wait(WaitMode::Park, std::slice::from_ref(cr.request()));
    }

    /// MPI_Alltoallv: variable blocks.
    #[allow(clippy::too_many_arguments)]
    pub fn alltoallv<T: Pod>(
        &self,
        send: &[T],
        scounts: &[usize],
        sdispls: &[usize],
        recv: &mut [T],
        rcounts: &[usize],
        rdispls: &[usize],
        mode: WaitMode,
    ) {
        let cr = self.ialltoallv(send, scounts, sdispls, recv, rcounts, rdispls);
        self.coll_wait(mode, std::slice::from_ref(cr.request()));
    }
}
