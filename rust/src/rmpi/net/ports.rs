//! Per-rank ingress ports: deterministic serialization of message
//! processing.
//!
//! Every rank owns one [`Port`]. Every message addressed to it — p2p
//! eager, p2p rendezvous, any collective round — is *booked* at send
//! time with its arrival instant and a [`MsgKey`]. The port services
//! bookings one at a time, each occupying it for
//! [`super::NetworkModel::rx_ns`], in a deterministic FIFO order:
//! arrival instant first, same-instant ties in `MsgKey` order. The
//! serialized service instant (`ready`) is the message's delivery
//! deadline; completion fires at `max(ready, match instant)`.
//!
//! ## Why the two-phase resolve
//!
//! Bookings race in *real* time (any rank thread may post a send), but
//! the deadline must be a pure function of *virtual* history. The port
//! therefore never assigns a deadline at booking time when `rx_ns > 0`:
//! it parks the booking and schedules a resolve pass on the clock
//! thread at the arrival instant. Because a message is always booked at
//! its send instant and arrives strictly later (every link class has
//! non-zero latency), all bookings that share an arrival instant are
//! already parked when the clock reaches it — the resolve pass sees the
//! complete same-instant set and services it in key order, so the
//! assigned deadlines are independent of thread scheduling, delivery
//! mode, and worker counts. (A zero-latency [`super::NetworkModel`]
//! combined with `rx_ns > 0` would void the strictly-later argument;
//! `NetworkModel::instant()` keeps `rx_ns = 0`.)
//!
//! With `rx_ns == 0` the port is transparent: bookings resolve inline
//! to their arrival instant, no clock event is scheduled, and the
//! timeline is bit-identical to the pre-port implementation.
//!
//! [`PortClock`] — the three-line service law — is shared verbatim with
//! the topology compiler's critical-path estimator
//! ([`super::model::critical_path`]), which is what makes
//! compiler-estimated and engine-observed times equal by construction.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::{Clock, VNanos};

thread_local! {
    /// Reusable resolve-pass buffer (populated per thread that runs
    /// resolve passes — in practice the clock lane drivers): avoids one
    /// `Vec` allocation per pass on the hot delivery path. Taken with
    /// `mem::take` for the duration of a pass and put back afterwards
    /// with its grown capacity retained.
    static DUE_SCRATCH: std::cell::RefCell<Vec<(Booking, VNanos)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Deterministic identity of one booked message. Orders same-instant
/// arrivals: the send instant, then source rank, then tag, then the
/// source's send sequence number (program order for same-thread sends;
/// concurrent same-`(vtime, src, tag)` sends are unordered in MPI too).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub(crate) struct MsgKey {
    pub sender_vtime: VNanos,
    pub src: u32,
    pub tag: i32,
    pub seq: u64,
}

/// The serialization law of one ingress port: each serviced message
/// occupies the port for `rx_ns` starting no earlier than its arrival
/// and no earlier than the previous service's end. Shared verbatim by
/// the live [`Port`] and the compiler's wire-schedule estimator.
#[derive(Clone, Copy, Default, Debug)]
pub(crate) struct PortClock {
    busy_until: VNanos,
}

impl PortClock {
    /// Service one message arriving at `arrival`; returns the instant
    /// its processing is done (the delivery deadline).
    pub fn service(&mut self, arrival: VNanos, rx_ns: u64) -> VNanos {
        let ready = arrival.max(self.busy_until) + rx_ns;
        self.busy_until = ready;
        ready
    }
}

type ReadyFn = Box<dyn FnOnce(VNanos) + Send>;

#[derive(Default)]
pub(crate) struct SlotState {
    ready: Option<VNanos>,
    waiters: Vec<ReadyFn>,
}

/// Handle to one booked message's port slot. The match engine parks the
/// completion on it ([`Booking::on_ready`]); the port resolve pass
/// fires it with the serialized deadline. The transparent-port case
/// (`rx_ns == 0` — every default configuration) is a plain value, so
/// the hot send path allocates nothing the pre-port implementation did
/// not.
#[derive(Clone)]
pub(crate) enum Booking {
    /// Deadline known at booking time (transparent port, unit tests).
    Resolved(VNanos),
    /// Awaiting the resolve pass at the arrival instant.
    Pending(Arc<Mutex<SlotState>>),
}

impl Booking {
    fn pending() -> Booking {
        Booking::Pending(Arc::new(Mutex::new(SlotState::default())))
    }

    /// A booking whose deadline is already known (transparent-port fast
    /// path, and unit-test envelopes).
    pub fn resolved(ready: VNanos) -> Booking {
        Booking::Resolved(ready)
    }

    /// Run `f(ready)` once the deadline is known — inline if it already
    /// is. `f` may run on the clock thread (resolve pass) and must not
    /// block on sim primitives; scheduling via `Clock::call_at` is safe.
    pub fn on_ready(&self, f: impl FnOnce(VNanos) + Send + 'static) {
        let slot = match self {
            Booking::Resolved(t) => return f(*t),
            Booking::Pending(slot) => slot,
        };
        let mut g = slot.lock().unwrap();
        match g.ready {
            Some(t) => {
                drop(g);
                f(t);
            }
            None => g.waiters.push(Box::new(f)),
        }
    }

    fn resolve(&self, t: VNanos) {
        let Booking::Pending(slot) = self else {
            unreachable!("resolve on a pre-resolved booking")
        };
        let waiters = {
            let mut g = slot.lock().unwrap();
            debug_assert!(g.ready.is_none(), "booking resolved twice");
            g.ready = Some(t);
            std::mem::take(&mut g.waiters)
        };
        for w in waiters {
            w(t);
        }
    }
}

#[derive(Default)]
struct PortInner {
    clock: PortClock,
    /// Bookings awaiting their resolve pass, in service order.
    pending: BTreeMap<(VNanos, MsgKey), Booking>,
}

/// One rank's ingress port (see module docs).
pub(crate) struct Port {
    inner: Mutex<PortInner>,
    /// Owning rank (span track identity).
    rank: u32,
    /// Observability bundle: `PortBusy` service spans when a sink is
    /// attached, queueing-delay histogram + backlog gauge always.
    obs: Arc<crate::obs::RunObs>,
    /// Universe-wide scratch-reuse counter (shared with [`Ports`];
    /// surfaced as `RunStats::alloc_reuse.booking_scratch_reuses`).
    scratch_reuses: Arc<AtomicU64>,
}

impl Port {
    fn new(rank: u32, obs: Arc<crate::obs::RunObs>, scratch_reuses: Arc<AtomicU64>) -> Port {
        Port { inner: Mutex::new(PortInner::default()), rank, obs, scratch_reuses }
    }

    fn book(
        self: Arc<Self>,
        clock: &Arc<Clock>,
        lane: usize,
        rx_ns: u64,
        key: MsgKey,
        arrival: VNanos,
    ) -> Booking {
        if rx_ns == 0 {
            // Transparent port: the pure latency model, bit-identical to
            // the pre-port timeline (no extra clock events either).
            return Booking::resolved(arrival);
        }
        let b = Booking::pending();
        {
            let mut g = self.inner.lock().unwrap();
            g.pending.insert((arrival, key), b.clone());
            self.obs.port_backlog.set(g.pending.len() as u64);
        }
        let clock2 = clock.clone();
        // The resolve pass runs on the *destination* rank's clock lane:
        // its `now()` is then the port owner's virtual time, and the
        // conservative horizon guarantees every same-instant booking
        // (cross-lane ones arrive >= send + lookahead) is already
        // parked when the pass fires.
        clock.call_at_on(lane, arrival, move || self.resolve_due(&clock2, rx_ns));
        b
    }

    /// Resolve every booking whose arrival instant has been reached, in
    /// service order. Runs on the clock thread only, so assigned
    /// deadlines are a pure function of virtual history.
    fn resolve_due(&self, clock: &Clock, rx_ns: u64) {
        let now = clock.now();
        // Reuse the thread's scratch buffer instead of allocating per
        // pass (a warm buffer's capacity survives the round trip).
        let mut due = DUE_SCRATCH.with(|s| std::mem::take(&mut *s.borrow_mut()));
        if due.capacity() > 0 {
            self.scratch_reuses.fetch_add(1, Ordering::Relaxed);
        }
        {
            let mut g = self.inner.lock().unwrap();
            while let Some((&(arrival, _), _)) = g.pending.first_key_value() {
                if arrival > now {
                    break;
                }
                let ((arrival, key), b) = g.pending.pop_first().unwrap();
                let ready = g.clock.service(arrival, rx_ns);
                // Queueing delay: how long the message waited behind
                // earlier arrivals before its service began.
                self.obs.port_queue_ns.record((ready - rx_ns).saturating_sub(arrival));
                if self.obs.enabled() {
                    self.obs.record(crate::obs::Span::interval(
                        crate::obs::Track::Port { rank: self.rank },
                        crate::obs::SpanKind::PortBusy,
                        ready - rx_ns,
                        ready,
                        "rx",
                        key.seq,
                    ));
                }
                due.push((b, ready));
            }
        }
        // Fire outside the port lock: waiters may complete requests,
        // whose continuations may post new sends (which book ports).
        for (b, ready) in due.drain(..) {
            b.resolve(ready);
        }
        DUE_SCRATCH.with(|s| *s.borrow_mut() = due);
    }
}

/// The universe's port table: one ingress [`Port`] per rank plus the
/// per-source send sequence counters that finish [`MsgKey`]s.
pub(crate) struct Ports {
    rx_ns: u64,
    /// Per-rank *extra* ingress service time (straggler injection; all
    /// zeros when no fault plan is active). Added to `rx_ns` for every
    /// message addressed to that rank, so straggler slowness compounds
    /// through the identical queueing law.
    rx_extra: Vec<u64>,
    ports: Vec<Arc<Port>>,
    send_seq: Vec<AtomicU64>,
    /// rank -> clock lane (all zeros on a single-lane clock).
    lane_of: Vec<usize>,
    /// Resolve passes that reused a warm scratch buffer (see
    /// [`Port::resolve_due`]); per-universe, shared by every port.
    scratch_reuses: Arc<AtomicU64>,
}

impl Ports {
    pub fn new(
        size: usize,
        net: &super::NetworkModel,
        lane_of: Vec<usize>,
        rx_extra: Vec<u64>,
        obs: Arc<crate::obs::RunObs>,
    ) -> Ports {
        // Determinism precondition (see module docs): with rx_ns > 0, a
        // message must arrive strictly after it was booked, so every
        // same-instant booking set is complete when its resolve pass
        // runs. Zero-latency links would void that silently — fail fast
        // instead. Straggler rx extras engage the same two-phase resolve
        // machinery, so they carry the same precondition.
        let any_rx = net.rx_ns > 0 || rx_extra.iter().any(|&e| e > 0);
        assert!(
            !any_rx || (net.intra_latency_ns > 0 && net.inter_latency_ns > 0),
            "rx service time > 0 requires non-zero link latencies for deterministic port order"
        );
        assert_eq!(lane_of.len(), size, "lane map must cover every rank");
        assert_eq!(rx_extra.len(), size, "rx extras must cover every rank");
        let scratch_reuses = Arc::new(AtomicU64::new(0));
        Ports {
            rx_ns: net.rx_ns,
            rx_extra,
            ports: (0..size)
                .map(|r| Arc::new(Port::new(r as u32, obs.clone(), scratch_reuses.clone())))
                .collect(),
            send_seq: (0..size).map(|_| AtomicU64::new(0)).collect(),
            lane_of,
            scratch_reuses,
        }
    }

    /// Resolve passes that reused a warm scratch buffer (surfaced as
    /// `RunStats::alloc_reuse.booking_scratch_reuses`).
    pub fn scratch_reuses(&self) -> u64 {
        self.scratch_reuses.load(Ordering::Relaxed)
    }

    /// Next send sequence number of `src` (program order per thread).
    pub fn next_seq(&self, src: usize) -> u64 {
        self.send_seq[src].fetch_add(1, Ordering::Relaxed)
    }

    /// Book one message on `dst`'s ingress port. `key.sender_vtime`
    /// must be the current virtual instant and `arrival` the link
    /// model's arrival instant for it.
    pub fn book(&self, dst: usize, clock: &Arc<Clock>, key: MsgKey, arrival: VNanos) -> Booking {
        self.ports[dst].clone().book(
            clock,
            self.lane_of[dst],
            self.rx_ns + self.rx_extra[dst],
            key,
            arrival,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(svt: VNanos, src: u32, tag: i32, seq: u64) -> MsgKey {
        MsgKey { sender_vtime: svt, src, tag, seq }
    }

    #[test]
    fn port_clock_serializes_with_gaps() {
        let mut p = PortClock::default();
        // Idle port: arrival + rx.
        assert_eq!(p.service(1000, 400), 1400);
        // Back-to-back arrival queues behind the previous service.
        assert_eq!(p.service(1000, 400), 1800);
        // A later arrival after an idle gap starts fresh.
        assert_eq!(p.service(5000, 400), 5400);
        // rx = 0 is transparent even through the same law.
        let mut q = PortClock::default();
        assert_eq!(q.service(700, 0), 700);
        assert_eq!(q.service(700, 0), 700);
    }

    #[test]
    fn msg_key_orders_by_vtime_src_tag_seq() {
        let mut keys = [key(5, 0, 0, 0), key(1, 9, 9, 9), key(1, 2, 0, 0), key(1, 2, 0, 1)];
        keys.sort();
        assert_eq!(keys, [key(1, 2, 0, 0), key(1, 2, 0, 1), key(1, 9, 9, 9), key(5, 0, 0, 0)]);
    }

    #[test]
    fn resolved_booking_fires_inline() {
        let b = Booking::resolved(123);
        let cell = std::sync::Arc::new(Mutex::new(None));
        let c2 = cell.clone();
        b.on_ready(move |t| *c2.lock().unwrap() = Some(t));
        assert_eq!(*cell.lock().unwrap(), Some(123));
    }

    #[test]
    fn pending_booking_fires_at_resolve_with_deadline() {
        let b = Booking::pending();
        let cell = std::sync::Arc::new(Mutex::new(Vec::new()));
        let c2 = cell.clone();
        b.on_ready(move |t| c2.lock().unwrap().push(t));
        assert!(cell.lock().unwrap().is_empty());
        b.resolve(777);
        assert_eq!(cell.lock().unwrap().as_slice(), &[777]);
        // Late attach sees the resolved deadline inline.
        let c3 = cell.clone();
        b.on_ready(move |t| c3.lock().unwrap().push(t + 1));
        assert_eq!(cell.lock().unwrap().as_slice(), &[777, 778]);
    }
}
