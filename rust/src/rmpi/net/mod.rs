//! The unified, congestion-aware network subsystem.
//!
//! Before this module existed, delivery time was computed in three
//! unrelated places: `p2p.rs` inlined `latency + bytes/bw` into each
//! send, the collective engine charged a structural per-round receiver
//! cost (`coll_rx_ns` × receives, deferred between rounds), and the
//! topology compiler re-derived both with private closed-form
//! estimates. Message *rate* was therefore visible only inside
//! collective schedules: a 1000-way p2p incast onto one rank cost the
//! same as a single message, and nothing guaranteed the compiler's
//! arithmetic agreed with what the engine actually charged.
//!
//! This module is now the only place virtual delivery time is computed,
//! in two layers:
//!
//! * [`model`] — the link model ([`NetworkModel`]: per-class latency and
//!   bandwidth, protocol thresholds, CPU costs) plus the *wire-schedule
//!   estimator* ([`model::critical_path`]): a deterministic replay of an
//!   abstract per-rank round schedule through the same port law the live
//!   engine uses. The topology compiler's flat-vs-hierarchical decision
//!   is this replay — it has no cost formulas of its own, so
//!   compiler-estimated and engine-observed critical paths are equal by
//!   construction (asserted per collective in `tests/net_ports.rs`).
//! * [`ports`] — the live side: every rank owns one ingress [`Port`]
//!   that serializes message processing. Each message occupies the port
//!   for [`NetworkModel::rx_ns`] after it arrives, in a deterministic
//!   FIFO order — arrival instant first, ties broken by the message key
//!   `(sender_vtime, src, tag, seq)` — resolved on the clock thread, so
//!   the resulting virtual instants can never depend on which OS thread
//!   happened to advance the simulation (the Direct-vs-Sharded and
//!   park-vs-taskaware invariance the test suite pins).
//!
//! Every delivery — p2p eager, p2p rendezvous, and each round of every
//! collective schedule — books its deadline through the same
//! [`ports::Ports::book`] path. That is the point: incast congestion is
//! one phenomenon with one price, wherever the messages come from. This
//! is the shape "MPI Progress For All" (arXiv:2405.13807) argues for —
//! completion progress is a per-endpoint resource that serializes — and
//! it is what makes the paper's overlap results (arXiv:1901.03271)
//! respond to message rate, not just latency.
//!
//! `rx_ns` defaults to 0, which makes the port transparent (pure
//! latency model): deadlines, event counts and deadlock instants are
//! bit-identical to the pre-port implementation, so all published
//! figures reproduce unchanged at the defaults. `coll_rx_ns`, the PR-4
//! name from when the term was charged only inside collective
//! schedules, survives as an accessor alias on [`NetworkModel`].

pub mod model;
pub mod ports;

pub use model::NetworkModel;
pub(crate) use model::{WireOp, WireRound};
pub(crate) use ports::{Booking, MsgKey, Ports};
