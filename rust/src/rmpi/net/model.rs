//! The link model and the wire-schedule critical-path estimator.
//!
//! MareNostrum 4's fabric is 100 Gbit/s Intel Omni-Path; intra-node
//! communication goes through shared memory. Each message costs
//! `latency(class) + bytes / bandwidth(class)` on the wire, then
//! [`NetworkModel::rx_ns`] of serialized processing on the receiving
//! rank's ingress port ([`super::ports`]); rendezvous-size messages
//! additionally tie the *sender's* completion to the delivery
//! (synchronous behaviour above the eager threshold, like MPICH).
//!
//! [`critical_path`] replays an abstract per-rank round schedule — the
//! [`WireRound`] IR the topology compiler lowers its candidate plans to
//! — through this exact model, port law included ([`PortClock`]). It is
//! the compiler's only cost oracle, which is why compiler-estimated and
//! engine-observed virtual times agree exactly (`tests/net_ports.rs`).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::sim::VNanos;

use super::ports::PortClock;

/// Link classes and protocol thresholds of the simulated cluster.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// One-way latency between ranks on the same node (shared memory).
    pub intra_latency_ns: u64,
    /// Shared-memory copy bandwidth, bytes/s.
    pub intra_bw_bytes_per_s: u64,
    /// One-way latency across nodes (Omni-Path class fabric).
    pub inter_latency_ns: u64,
    /// Network bandwidth, bytes/s.
    pub inter_bw_bytes_per_s: u64,
    /// Messages larger than this use the rendezvous protocol: the sender's
    /// request completes only when the receive is matched and the transfer
    /// done (plain `send` behaves like `ssend`).
    pub eager_threshold: usize,
    /// CPU time one MPI call burns on the calling core (library overhead,
    /// matching, copies). Charged as virtual-time debt to the caller.
    pub call_cpu_ns: u64,
    /// Receiver-side processing per message — the message-rate term.
    /// Every delivery (p2p and collective alike) occupies the receiving
    /// rank's ingress port for this long, serialized in deterministic
    /// FIFO order ([`super::ports`]), so fan-in congestion is visible
    /// wherever the messages come from. Default 0: the port is
    /// transparent (pure latency model, pre-port timelines reproduce
    /// bit-identically). Known as `coll_rx_ns` while it was charged
    /// only inside collective schedules; see the accessor alias.
    pub rx_ns: u64,
    /// CPU cost of compiling a collective schedule (charged to the
    /// caller on a schedule-cache miss).
    pub sched_compile_ns: u64,
    /// CPU cost of a schedule-cache hit (key hash + lookup).
    pub sched_cache_hit_ns: u64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            intra_latency_ns: 400,                        // shared-memory hop
            intra_bw_bytes_per_s: 8_000_000_000,          // 8 GB/s memcpy
            inter_latency_ns: 1_500,                      // Omni-Path ~1.5 us
            inter_bw_bytes_per_s: 12_500_000_000,         // 100 Gbit/s
            eager_threshold: 64 * 1024,
            call_cpu_ns: 400,                             // per-call library cost
            rx_ns: 0,                                     // pure latency model
            sched_compile_ns: 1_000,                      // rounds + trees + regions
            sched_cache_hit_ns: 50,                       // hash + lookup
        }
    }
}

impl NetworkModel {
    /// A zero-cost network (unit tests of matching logic).
    pub fn instant() -> Self {
        NetworkModel {
            intra_latency_ns: 0,
            intra_bw_bytes_per_s: u64::MAX,
            inter_latency_ns: 0,
            inter_bw_bytes_per_s: u64::MAX,
            eager_threshold: usize::MAX,
            call_cpu_ns: 0,
            rx_ns: 0,
            sched_compile_ns: 0,
            sched_cache_hit_ns: 0,
        }
    }

    /// Virtual transfer duration of a message of `bytes` over the class.
    pub fn transfer_ns(&self, bytes: usize, same_node: bool) -> VNanos {
        let (lat, bw) = if same_node {
            (self.intra_latency_ns, self.intra_bw_bytes_per_s)
        } else {
            (self.inter_latency_ns, self.inter_bw_bytes_per_s)
        };
        if bw == u64::MAX {
            return lat;
        }
        lat + (bytes as u128 * 1_000_000_000u128 / bw as u128) as u64
    }

    /// Whether a message of `bytes` is eager (sender completes at once).
    pub fn is_eager(&self, bytes: usize) -> bool {
        bytes <= self.eager_threshold
    }

    /// Back-compat alias of [`NetworkModel::rx_ns`]: the PR-4 name, from
    /// when receiver processing was charged only inside collective
    /// schedules. Same knob, unified meaning.
    pub fn coll_rx_ns(&self) -> u64 {
        self.rx_ns
    }

    /// Back-compat setter alias of [`NetworkModel::rx_ns`].
    pub fn set_coll_rx_ns(&mut self, v: u64) {
        self.rx_ns = v;
    }

    /// Order-sensitive FNV-1a digest over every field that can change a
    /// compiled plan or its critical path. Part of the cluster-wide
    /// plan-store key ([`crate::rmpi::topology::PlanStore`]): two
    /// communicators share compiled plans only when their network
    /// models fingerprint identically.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        };
        mix(self.intra_latency_ns);
        mix(self.intra_bw_bytes_per_s);
        mix(self.inter_latency_ns);
        mix(self.inter_bw_bytes_per_s);
        mix(self.eager_threshold as u64);
        mix(self.call_cpu_ns);
        mix(self.rx_ns);
        mix(self.sched_compile_ns);
        mix(self.sched_cache_hit_ns);
        h
    }
}

// ---------------------------------------------------------------------
// The wire-schedule IR and its deterministic replay.
// ---------------------------------------------------------------------

/// One point-to-point operation of a wire round: the peer rank and the
/// payload size in bytes.
#[derive(Clone, Copy, Debug)]
pub(crate) struct WireOp {
    pub peer: usize,
    pub bytes: usize,
}

/// One round of an abstract per-rank schedule: the sends and receives a
/// rank posts together, gating the next round on their completion —
/// exactly the engine's [`crate::rmpi::coll_schedule`] round contract.
#[derive(Clone, Debug, Default)]
pub(crate) struct WireRound {
    pub sends: Vec<WireOp>,
    pub recvs: Vec<WireOp>,
}

/// In-flight message of the replay.
struct Msg {
    src: usize,
    rendezvous: bool,
}

/// Per-rank replay state.
struct RankState {
    cur: usize,
    /// Unresolved requirements of the current round (pending receives
    /// plus pending rendezvous sends).
    pending: usize,
    /// Latest completion instant folded into the current round.
    done_at: VNanos,
    finish: Option<VNanos>,
}

/// Replay `scheds` (one round list per rank, all ranks entering at
/// t = 0) under `net` and return the critical path: the latest instant
/// any rank's last round completes. Semantics mirror the live engine
/// exactly —
///
/// * each send arrives `transfer_ns` after its round is posted and then
///   occupies the destination's ingress port ([`PortClock`]) in
///   deterministic arrival order (ties by send instant, then source);
/// * a receive completes at `max(port deadline, its post instant)`;
/// * eager sends complete at post, rendezvous sends at delivery;
/// * a round completes at the max of its requirements' completions and
///   the next round posts at that instant.
pub(crate) fn critical_path(
    scheds: &[Vec<WireRound>],
    node_of: &[usize],
    net: &NetworkModel,
) -> u64 {
    critical_path_counted(scheds, node_of, net).0
}

/// [`critical_path`] plus the number of replay events processed (heap
/// pops: arrival services and round posts). The event count is the
/// host-side cost of one exact estimate — the quantity the plan
/// compilation service's memo and closed-form tiers exist to remove
/// (fig21 reports it per compile strategy).
pub(crate) fn critical_path_counted(
    scheds: &[Vec<WireRound>],
    node_of: &[usize],
    net: &NetworkModel,
) -> (u64, u64) {
    let mut replay_events = 0u64;
    let n = scheds.len();
    assert_eq!(n, node_of.len());
    let mut ranks: Vec<RankState> = (0..n)
        .map(|_| RankState { cur: 0, pending: 0, done_at: 0, finish: None })
        .collect();
    let mut ports: Vec<PortClock> = vec![PortClock::default(); n];
    // Bookings parked at each destination port, in service order:
    // (arrival, sender_vtime, src, emission seq) — the same order the
    // live port's `(arrival, MsgKey)` map yields, since within one
    // collective no two messages share (arrival, sender_vtime, src).
    let mut parked: Vec<std::collections::BTreeMap<(VNanos, VNanos, usize, u64), Msg>> =
        (0..n).map(|_| std::collections::BTreeMap::new()).collect();
    let mut emission = 0u64;
    // Serviced-but-unmatched messages / posted-but-unserved receives,
    // FIFO per (src, dst) pair (MPI non-overtaking; within one
    // collective each pair carries at most one message per round, in
    // round order).
    let mut ready_q: HashMap<(usize, usize), VecDeque<(VNanos, Msg)>> = HashMap::new();
    let mut recv_q: HashMap<(usize, usize), VecDeque<VNanos>> = HashMap::new();

    // Event heap: (time, kind, rank); kind 0 = arrivals due at `rank`'s
    // port, kind 1 = post `rank`'s next round. Arrival-before-post at
    // equal instants mirrors the engine (port deadlines with rx > 0 are
    // strictly later than arrivals, and with rx = 0 the order is
    // immaterial: completions fold through max()).
    let mut events: BinaryHeap<Reverse<(VNanos, u8, usize)>> = BinaryHeap::new();
    for r in 0..n {
        if scheds[r].is_empty() {
            ranks[r].finish = Some(0);
        } else {
            events.push(Reverse((0, 1, r)));
        }
    }

    // Resolve one requirement of rank `r`'s current round at instant
    // `c`; returns true if the round completed.
    fn complete_op(
        ranks: &mut [RankState],
        events: &mut BinaryHeap<Reverse<(VNanos, u8, usize)>>,
        scheds: &[Vec<WireRound>],
        r: usize,
        c: VNanos,
    ) {
        let st = &mut ranks[r];
        st.done_at = st.done_at.max(c);
        st.pending -= 1;
        if st.pending == 0 {
            st.cur += 1;
            if st.cur < scheds[r].len() {
                events.push(Reverse((st.done_at, 1, r)));
            } else {
                st.finish = Some(st.done_at);
            }
        }
    }

    // Deliver one serviced message to `dst` (completion at
    // `max(ready, recv post)`), or park it until the receive posts.
    #[allow(clippy::too_many_arguments)]
    fn deliver(
        ranks: &mut [RankState],
        events: &mut BinaryHeap<Reverse<(VNanos, u8, usize)>>,
        scheds: &[Vec<WireRound>],
        recv_q: &mut HashMap<(usize, usize), VecDeque<VNanos>>,
        ready_q: &mut HashMap<(usize, usize), VecDeque<(VNanos, Msg)>>,
        dst: usize,
        ready: VNanos,
        msg: Msg,
    ) {
        if let Some(post) = recv_q.get_mut(&(msg.src, dst)).and_then(|q| q.pop_front()) {
            let c = ready.max(post);
            if msg.rendezvous {
                complete_op(ranks, events, scheds, msg.src, c);
            }
            complete_op(ranks, events, scheds, dst, c);
        } else {
            ready_q.entry((msg.src, dst)).or_default().push_back((ready, msg));
        }
    }

    while let Some(Reverse((t, kind, r))) = events.pop() {
        replay_events += 1;
        if kind == 0 {
            // Service every parked booking due at this port, in order.
            while let Some((&(arrival, _, _, _), _)) = parked[r].first_key_value() {
                if arrival > t {
                    break;
                }
                let (_, msg) = parked[r].pop_first().unwrap();
                let ready = ports[r].service(arrival, net.rx_ns);
                deliver(
                    &mut ranks,
                    &mut events,
                    scheds,
                    &mut recv_q,
                    &mut ready_q,
                    r,
                    ready,
                    msg,
                );
            }
            continue;
        }
        // Post rank r's round `cur` at instant t.
        let k = ranks[r].cur;
        ranks[r].pending = 0;
        ranks[r].done_at = t;
        let round = &scheds[r][k];
        for op in &round.recvs {
            if let Some((ready, msg)) =
                ready_q.get_mut(&(op.peer, r)).and_then(|q| q.pop_front())
            {
                // Already serviced: completes at max(deadline, post).
                let c = ready.max(t);
                ranks[r].done_at = ranks[r].done_at.max(c);
                if msg.rendezvous {
                    complete_op(&mut ranks, &mut events, scheds, msg.src, c);
                }
            } else {
                ranks[r].pending += 1;
                recv_q.entry((op.peer, r)).or_default().push_back(t);
            }
        }
        for op in &round.sends {
            let same = node_of[r] == node_of[op.peer];
            let arrival = t + net.transfer_ns(op.bytes, same);
            let rendezvous = !net.is_eager(op.bytes);
            if rendezvous {
                ranks[r].pending += 1;
            }
            parked[op.peer].insert((arrival, t, r, emission), Msg { src: r, rendezvous });
            emission += 1;
            events.push(Reverse((arrival, 0, op.peer)));
        }
        if ranks[r].pending == 0 {
            let done = ranks[r].done_at;
            ranks[r].cur += 1;
            if ranks[r].cur < scheds[r].len() {
                events.push(Reverse((done, 1, r)));
            } else {
                ranks[r].finish = Some(done);
            }
        }
    }
    (ranks.iter().map(|s| s.finish.unwrap_or(0)).max().unwrap_or(0), replay_events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_scales_with_size_and_class() {
        let m = NetworkModel::default();
        let small_intra = m.transfer_ns(8, true);
        let small_inter = m.transfer_ns(8, false);
        assert!(small_inter > small_intra);
        let big_inter = m.transfer_ns(1 << 20, false);
        assert!(big_inter > small_inter);
        // 1 MiB at 12.5 GB/s ~ 84 us
        assert!((80_000..100_000).contains(&big_inter));
    }

    #[test]
    fn eager_threshold() {
        let m = NetworkModel::default();
        assert!(m.is_eager(1024));
        assert!(!m.is_eager(1 << 20));
    }

    #[test]
    fn instant_is_free() {
        let m = NetworkModel::instant();
        assert_eq!(m.transfer_ns(1 << 30, false), 0);
    }

    #[test]
    fn coll_rx_ns_aliases_rx_ns() {
        let mut m = NetworkModel::default();
        assert_eq!(m.coll_rx_ns(), 0);
        m.set_coll_rx_ns(300);
        assert_eq!(m.rx_ns, 300);
        assert_eq!(m.coll_rx_ns(), 300);
    }

    fn two_rank_ping(net: &NetworkModel, bytes: usize) -> u64 {
        let scheds = vec![
            vec![WireRound { sends: vec![WireOp { peer: 1, bytes }], recvs: vec![] }],
            vec![WireRound { sends: vec![], recvs: vec![WireOp { peer: 0, bytes }] }],
        ];
        critical_path(&scheds, &[0, 1], net)
    }

    #[test]
    fn replay_single_message_is_transfer_plus_rx() {
        let mut net = NetworkModel::default();
        assert_eq!(two_rank_ping(&net, 8), net.transfer_ns(8, false));
        net.rx_ns = 400;
        assert_eq!(two_rank_ping(&net, 8), net.transfer_ns(8, false) + 400);
    }

    #[test]
    fn replay_incast_serializes_on_the_port() {
        // 4 senders, one receiver, same arrival instant: the port
        // serializes — last deadline = arrival + 4 * rx.
        let mut net = NetworkModel::default();
        net.rx_ns = 250;
        let n = 5usize;
        let mut scheds = vec![vec![WireRound {
            sends: vec![],
            recvs: (1..n).map(|s| WireOp { peer: s, bytes: 8 }).collect(),
        }]];
        for _ in 1..n {
            scheds.push(vec![WireRound {
                sends: vec![WireOp { peer: 0, bytes: 8 }],
                recvs: vec![],
            }]);
        }
        let node_of = vec![0; n];
        let got = critical_path(&scheds, &node_of, &net);
        assert_eq!(got, net.transfer_ns(8, true) + 4 * 250);
    }

    #[test]
    fn replay_rendezvous_ties_sender_to_delivery() {
        // Above the eager threshold the sender's round only completes
        // at delivery: a two-round sender schedule reflects it.
        let net = NetworkModel::default();
        let big = net.eager_threshold + 1;
        let deliver = net.transfer_ns(big, false);
        let scheds = vec![
            vec![
                WireRound { sends: vec![WireOp { peer: 1, bytes: big }], recvs: vec![] },
                // Second round: an eager ping that can only start after
                // the rendezvous completed.
                WireRound { sends: vec![WireOp { peer: 1, bytes: 1 }], recvs: vec![] },
            ],
            vec![
                WireRound { sends: vec![], recvs: vec![WireOp { peer: 0, bytes: big }] },
                WireRound { sends: vec![], recvs: vec![WireOp { peer: 0, bytes: 1 }] },
            ],
        ];
        let got = critical_path(&scheds, &[0, 1], &net);
        assert_eq!(got, deliver + net.transfer_ns(1, false));
    }

    #[test]
    fn replay_round_gating_chains_completions() {
        // r0 -> r1 -> r2 relay: second hop posts only after the first
        // completes at r1.
        let net = NetworkModel::default();
        let scheds = vec![
            vec![WireRound { sends: vec![WireOp { peer: 1, bytes: 8 }], recvs: vec![] }],
            vec![
                WireRound { sends: vec![], recvs: vec![WireOp { peer: 0, bytes: 8 }] },
                WireRound { sends: vec![WireOp { peer: 2, bytes: 8 }], recvs: vec![] },
            ],
            vec![WireRound { sends: vec![], recvs: vec![WireOp { peer: 1, bytes: 8 }] }],
        ];
        let hop = net.transfer_ns(8, false);
        assert_eq!(critical_path(&scheds, &[0, 1, 2], &net), 2 * hop);
    }
}
