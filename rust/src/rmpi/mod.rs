//! `rmpi` — an MPI-like message-passing substrate.
//!
//! Implements the slice of MPI the paper exercises (Sections 2.2, 5, 6),
//! with MPI's semantics where they matter for TAMPI:
//!
//! * **Matching**: per (communicator, destination) posted-receive and
//!   unexpected-message queues, matched by `(source | ANY_SOURCE,
//!   tag | ANY_TAG)` in posting order — the MPI §3.5 non-overtaking rule.
//! * **Point-to-point**: `send`/`ssend`/`recv` (blocking; park the OS
//!   thread — which is exactly what makes untamed blocking calls inside
//!   tasks deadlock, Section 5) and `isend`/`issend`/`irecv` plus
//!   `test`/`wait`/`waitall` over [`request::Request`]s.
//! * **Collectives**: barrier, bcast, reduce, allreduce, gather, alltoall
//!   and alltoallv, built over p2p on a separate match context — each
//!   compiled by the topology-aware planner ([`topology`]: flat or
//!   node-hierarchical shapes, chosen by cost under the network model,
//!   cached per communicator like MPI persistent collectives) into a
//!   schedule of engine-driven rounds ([`coll_schedule`]) with a
//!   first-class non-blocking surface (`ibarrier`, `ibcast`,
//!   `iallreduce`, `ialltoallv`, …) returning a [`CollRequest`] that
//!   composes with waits and task external events; the blocking calls
//!   are wrappers waiting on the same schedule.
//! * **Threading levels**: `Single`..`Multiple` plus the paper's proposed
//!   `TaskMultiple` (Section 6.3), which [`crate::tampi`] turns on.
//! * **Congestion-aware network subsystem** ([`net`]): per-message
//!   arrival `latency(class) + bytes / bandwidth(class)`, class ∈
//!   {intra-node, inter-node}, followed by serialized receiver
//!   processing on the destination rank's ingress port
//!   ([`NetworkModel::rx_ns`] per message, deterministic FIFO order) —
//!   one deadline path shared by p2p and every collective round, and
//!   replayed identically by the topology compiler's critical-path
//!   estimates ([`topology::estimate_critical_path`]).
//!
//! Ranks are threads of one process under one [`crate::sim::Clock`]; the
//! cluster shape (nodes × ranks-per-node × cores) is configured in
//! [`universe::ClusterConfig`].

pub mod coll_schedule;
pub mod collectives;
pub mod comm;
pub mod faults;
pub mod match_engine;
pub mod net;
pub mod p2p;
pub mod request;
pub mod topology;
pub mod universe;

pub use coll_schedule::CollRequest;
pub use collectives::{commutative, Combiner, Commutative};
pub use comm::Comm;
pub use faults::{
    Detection, DetectionKind, DetectorConfig, DropSpec, FaultStats, FaultsConfig, RankFail,
    Straggler,
};
pub use net::NetworkModel;
pub use request::{ReqError, Request, Status};
pub use topology::{estimate_critical_path, TopologyMode};
pub use universe::{ClusterConfig, PlanStoreStats, RankCtx, RunStats, SchedCacheStats, Universe};

/// Completion-delivery knob (defined in [`crate::progress`], re-exported
/// here next to [`ClusterConfig`], which carries it).
pub use crate::progress::DeliveryMode;

/// Wildcard source.
pub const ANY_SOURCE: i32 = -1;
/// Wildcard tag.
pub const ANY_TAG: i32 = -1;

/// MPI threading levels, including the paper's proposal (Section 6.3).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum ThreadLevel {
    Single,
    Funneled,
    Serialized,
    Multiple,
    /// Monotonically greater than `Multiple` (Section 6.3): blocking MPI
    /// calls inside tasks become task-aware.
    TaskMultiple,
}

/// Plain-old-data element types that can travel through messages.
///
/// # Safety
/// Implementors must be bit-copyable with no padding or invalid values.
pub unsafe trait Pod: Copy + Send + 'static {}
unsafe impl Pod for u8 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}
unsafe impl Pod for usize {}

pub(crate) fn as_bytes<T: Pod>(s: &[T]) -> &[u8] {
    // SAFETY: T is Pod (bit-copyable, no padding).
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, std::mem::size_of_val(s)) }
}

pub(crate) fn as_bytes_mut<T: Pod>(s: &mut [T]) -> &mut [u8] {
    // SAFETY: T is Pod.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut u8, std::mem::size_of_val(s)) }
}
