//! Communicators: per-rank handles over shared matching state.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::progress::{DeliveryMode, EngineStats, ProgressEngine, ShardStats};
use crate::sim::Clock;
use crate::trace::Tracer;

use super::match_engine::ContextQueues;
use super::net::{NetworkModel, Ports};
use super::request::ReqState;
use super::topology::{
    compile_cluster_plans, compile_plan, CollPlan, PlanStore, SchedCache, SchedKey, TopoCtx,
    TopologyMode,
};

/// Shared cluster state (one per [`super::Universe`]).
pub(crate) struct UniState {
    pub clock: Arc<Clock>,
    pub net: NetworkModel,
    /// Per-rank ingress ports: every message delivery books its
    /// deadline here (see [`crate::rmpi::net::ports`]).
    pub ports: Ports,
    /// rank -> node id.
    pub node_of: Vec<usize>,
    /// rank -> clock lane (all zeros on a single-lane clock). Up to the
    /// node count, nodes are partitioned into contiguous lane blocks;
    /// beyond it, ranks are split directly (finer-than-node lanes) —
    /// every lane pair is bounded by its entry in the clock's per-pair
    /// lookahead matrix (intra-node wire latency for lanes sharing a
    /// node, inter-node otherwise).
    pub lane_of: Vec<usize>,
    /// How the collective schedule compiler sees the node hierarchy.
    pub topology: TopologyMode,
    /// Whether compiled schedules persist in per-communicator caches
    /// (`false` forces a recompile per call — the fig17 cold baseline).
    pub sched_cache_on: bool,
    /// Cluster-wide schedule-cache hit/miss counters (surfaced as
    /// [`super::RunStats::sched_cache`]).
    pub sched_hits: AtomicU64,
    pub sched_misses: AtomicU64,
    /// Universe-level plan compilation service: cluster plans compiled
    /// once per `SchedKey` and shared by every congruent communicator
    /// (surfaced as [`super::RunStats::plan_store`]).
    pub plan_store: PlanStore,
    /// Match contexts; a communicator owns two (p2p + collectives).
    pub contexts: Mutex<Vec<Arc<ContextQueues>>>,
    /// (parent ctx, dup seq) -> allocated context pair.
    pub dup_map: Mutex<std::collections::HashMap<(usize, u64), (usize, usize)>>,
    /// Completion-delivery engine (per-rank shards under
    /// [`DeliveryMode::Sharded`]; empty under `Direct`).
    pub progress: Arc<ProgressEngine>,
    /// Cluster tracer (annotation records from the collective engine's
    /// round advances are stamped here).
    pub tracer: Option<Arc<Tracer>>,
    /// Observability bundle: metrics always, spans when the run asked
    /// for them. Emission sites only read `Clock::now()` — recording
    /// never perturbs virtual time.
    pub obs: Arc<crate::obs::RunObs>,
    /// Fault-injection state (`None` on fault-free runs: every check
    /// below is a single `Option` branch on the hot path).
    pub faults: Option<Arc<super::faults::FaultState>>,
    /// (parent ctx, survivor-set digest) -> context pair of the shrunk
    /// communicator — the collective-safe allocation rule of
    /// [`Comm::comm_shrink`], mirroring `dup_map`.
    pub shrink_map: Mutex<std::collections::HashMap<(usize, u64), (usize, usize)>>,
    /// `ReqState` allocations served from the thread-local recycle pool
    /// (surfaced as [`super::RunStats::alloc_reuse`]). Per-universe, not
    /// global: concurrent test universes must not cross-count.
    pub reuse_req_states: AtomicU64,
    /// Collective rounds posted entirely inline (no small-vec spill;
    /// surfaced as [`super::RunStats::alloc_reuse`]).
    pub reuse_rounds_inline: AtomicU64,
}

impl UniState {
    pub fn alloc_context_pair(&self, size: usize) -> (usize, usize) {
        let mut g = self.contexts.lock().unwrap();
        let base = g.len();
        g.push(Arc::new(ContextQueues::new(size)));
        g.push(Arc::new(ContextQueues::new(size)));
        (base, base + 1)
    }

    /// Collective-safe duplication: the pair for (parent, seq) is
    /// allocated once; every rank calling dup in the same order resolves
    /// to the same contexts.
    pub fn dup_context_pair(&self, parent: usize, seq: u64, size: usize) -> (usize, usize) {
        let mut m = self.dup_map.lock().unwrap();
        if let Some(&pair) = m.get(&(parent, seq)) {
            return pair;
        }
        let pair = self.alloc_context_pair(size);
        m.insert((parent, seq), pair);
        pair
    }

    /// Context pair for a shrunk communicator: allocated once per
    /// (parent, survivor set); every survivor resolves to the same
    /// contexts without the dead rank's participation. Queues are sized
    /// to the *world* (p2p indexes them by world rank) — the dead
    /// rank's slots simply stay empty.
    pub fn shrink_context_pair(&self, parent: usize, digest: u64, world: usize) -> (usize, usize) {
        let mut m = self.shrink_map.lock().unwrap();
        if let Some(&pair) = m.get(&(parent, digest)) {
            return pair;
        }
        let pair = self.alloc_context_pair(world);
        m.insert((parent, digest), pair);
        pair
    }

    pub fn context(&self, id: usize) -> Arc<ContextQueues> {
        self.contexts.lock().unwrap()[id].clone()
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }
}

/// A communicator handle bound to one rank (like an `MPI_Comm` plus the
/// implicit rank of the caller). Cheap to clone; clones share matching
/// state and the collective sequence counter.
#[derive(Clone)]
pub struct Comm {
    pub(crate) uni: Arc<UniState>,
    pub(crate) rank: usize,
    pub(crate) size: usize,
    pub(crate) ctx_p2p_id: usize,
    pub(crate) ctx_p2p: Arc<ContextQueues>,
    pub(crate) ctx_coll: Arc<ContextQueues>,
    /// Collective call sequence of this rank (tags collective rounds;
    /// MPI requires all ranks to call collectives in the same order).
    pub(crate) coll_seq: Arc<AtomicU64>,
    /// Dup call sequence of this rank on this communicator.
    pub(crate) dup_seq: Arc<AtomicU64>,
    /// Persistent schedule store of this communicator (shared by
    /// clones; a `dup` starts fresh, and dropping the communicator
    /// drops its compiled plans — MPI persistent-request lifetime).
    pub(crate) sched_cache: Arc<SchedCache>,
    /// comm rank -> world rank. `None` for the world communicator and
    /// its dups (identity mapping, no indirection on the hot path);
    /// `Some` after [`Comm::comm_shrink`]. Translation to world ranks
    /// happens exactly once, at the p2p boundary.
    pub(crate) group: Option<Arc<Vec<usize>>>,
    /// comm rank -> node id under `group` (what the schedule compiler
    /// sees for a shrunk communicator). `None` iff `group` is `None`.
    pub(crate) group_nodes: Option<Arc<Vec<usize>>>,
    /// Comm-rank bitset of ranks the topology compiler should route
    /// collective trees away from (stall-driven adaptation). Part of
    /// every [`SchedKey`], so raising it invalidates cached plans
    /// through the ordinary PlanStore/SchedCache key path.
    pub(crate) avoid: Arc<AtomicU64>,
}

impl Comm {
    pub(crate) fn world(uni: Arc<UniState>, rank: usize, size: usize) -> Comm {
        // World always owns contexts 0/1 (allocated by the universe).
        let ctx_p2p = uni.context(0);
        let ctx_coll = uni.context(1);
        Comm {
            uni,
            rank,
            size,
            ctx_p2p_id: 0,
            ctx_p2p,
            ctx_coll,
            coll_seq: Arc::new(AtomicU64::new(0)),
            dup_seq: Arc::new(AtomicU64::new(0)),
            sched_cache: Arc::new(SchedCache::default()),
            group: None,
            group_nodes: None,
            avoid: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Rank of the caller within this communicator.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks.
    pub fn size(&self) -> usize {
        self.size
    }

    /// World rank of the caller (identical to [`Comm::rank`] on the
    /// world communicator and its dups).
    pub(crate) fn world_rank(&self) -> usize {
        match &self.group {
            Some(g) => g[self.rank],
            None => self.rank,
        }
    }

    /// World rank of communicator rank `r`.
    pub(crate) fn world_rank_of(&self, r: usize) -> usize {
        match &self.group {
            Some(g) => g[r],
            None => r,
        }
    }

    /// Node housing comm rank `rank` (the interconnect class boundary).
    pub fn node_of(&self, rank: usize) -> usize {
        self.uni.node_of[self.world_rank_of(rank)]
    }

    pub fn clock(&self) -> &Arc<Clock> {
        &self.uni.clock
    }

    /// Duplicate the communicator: fresh matching contexts, same group.
    /// Collective — every rank must call it in the same order.
    /// (MPI_Comm_dup — isolates library traffic.)
    pub fn dup(&self) -> Comm {
        let seq = self.dup_seq.fetch_add(1, Ordering::Relaxed);
        let (p, c) = self.uni.dup_context_pair(self.ctx_p2p_id, seq, self.size);
        Comm {
            uni: self.uni.clone(),
            rank: self.rank,
            size: self.size,
            ctx_p2p_id: p,
            ctx_p2p: self.uni.context(p),
            ctx_coll: self.uni.context(c),
            coll_seq: Arc::new(AtomicU64::new(0)),
            dup_seq: Arc::new(AtomicU64::new(0)),
            // A fresh per-comm plan index: the dup's index dies with it.
            // The compiled cluster plans themselves live in the
            // universe [`PlanStore`], so a congruent dup resolves its
            // index misses without recompiling (and without counting
            // compile misses — see `plan_for`).
            sched_cache: Arc::new(SchedCache::default()),
            group: self.group.clone(),
            group_nodes: self.group_nodes.clone(),
            // Cluster health is a property of the machine, not the
            // communicator: a dup shares its parent's avoid mask so
            // one straggler detection adapts library traffic too.
            avoid: self.avoid.clone(),
        }
    }

    /// Look up (or compile) the plan for one collective call: the
    /// persistent-collective fast path, now backed by the cluster-wide
    /// [`PlanStore`]. A per-comm index hit charges
    /// [`NetworkModel::sched_cache_hit_ns`] of caller CPU; an index
    /// miss consults the store and takes this rank's view of the
    /// (possibly already compiled) cluster plan. The per-call hit/miss
    /// accounting keys off the cluster plan's per-rank first-touch bit,
    /// which is deterministic per rank program order: a rank's first
    /// view is exactly the call that would have compiled before the
    /// service existed (same `sched_compile_ns` virtual-time debt, same
    /// miss count), while later views — a congruent dup — are hits.
    /// With the cache off the store is bypassed entirely (a recompile
    /// per call — the fig17 cold baseline).
    pub(crate) fn plan_for(&self, key: SchedKey) -> (Arc<CollPlan>, bool) {
        // Stall-driven adaptation: the avoid mask is part of the plan
        // key, so raising it retires every cached plan — per-comm index
        // and cluster store alike — through the ordinary key path, with
        // no explicit flush.
        let key = SchedKey { avoid: self.avoid_mask(), ..key };
        if let Some(nodes) = &self.group_nodes {
            // Shrunk communicator: its shape is not the universe shape,
            // so the cluster-wide store (keyed by the world shape
            // signature) must not serve it — and the store's replay
            // memo holds structural schedule digests with no node map,
            // which would poison costs across shapes. Compile against
            // the group view; cache per-comm only.
            let ctx = TopoCtx::service(
                self.rank,
                self.size,
                nodes,
                self.uni.topology,
                &self.uni.net,
            );
            let (plan, cached) = if self.uni.sched_cache_on {
                self.sched_cache
                    .get_or_compile(&key, || Arc::new(compile_plan(&key, &ctx)))
            } else {
                (Arc::new(compile_plan(&key, &ctx)), false)
            };
            if cached {
                self.uni.sched_hits.fetch_add(1, Ordering::Relaxed);
                Clock::add_debt(self.uni.net.sched_cache_hit_ns);
            } else {
                self.uni.sched_misses.fetch_add(1, Ordering::Relaxed);
                Clock::add_debt(self.uni.net.sched_compile_ns);
            }
            return (plan, cached);
        }
        let store = &self.uni.plan_store;
        let mut ctx = TopoCtx::service(
            self.rank,
            self.size,
            &self.uni.node_of,
            self.uni.topology,
            &self.uni.net,
        );
        ctx.memo = Some(&store.memo);
        ctx.stats = Some(&store.stats);
        let (plan, cached) = if self.uni.sched_cache_on {
            let mut first_touch = false;
            let (plan, index_hit) = self.sched_cache.get_or_compile(&key, || {
                let (cluster, _) =
                    store.get_or_compile(key, || compile_cluster_plans(&key, &ctx));
                first_touch = cluster.first_touch(self.rank);
                cluster.view(self.rank)
            });
            (plan, index_hit || !first_touch)
        } else {
            (Arc::new(compile_plan(&key, &ctx)), false)
        };
        if cached {
            self.uni.sched_hits.fetch_add(1, Ordering::Relaxed);
            Clock::add_debt(self.uni.net.sched_cache_hit_ns);
        } else {
            self.uni.sched_misses.fetch_add(1, Ordering::Relaxed);
            Clock::add_debt(self.uni.net.sched_compile_ns);
        }
        (plan, cached)
    }

    /// How the schedule compiler sees this universe's node hierarchy.
    pub fn topology(&self) -> TopologyMode {
        self.uni.topology
    }

    /// Compiled plans currently held by this communicator's persistent
    /// schedule store.
    pub fn sched_cache_len(&self) -> usize {
        self.sched_cache.len()
    }

    /// Consume one collective sequence number. MPI requires all ranks to
    /// issue collectives on a communicator in the same order, so equal
    /// call indices resolve to equal sequence numbers on every rank; the
    /// schedule engine packs `(seq, phase)` into per-round message tags
    /// (see [`super::coll_schedule::coll_tag`]).
    pub(crate) fn next_coll_seq(&self) -> u64 {
        self.coll_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Allocate request state for an operation *owned by this rank*,
    /// routed through the rank's completion shard when the universe runs
    /// sharded delivery. Every request born through a `Comm` (p2p and
    /// collective-internal alike) goes through here, so a wildcard-source
    /// receive is always delivered on its poster's shard no matter which
    /// thread completes it.
    pub(crate) fn mk_req_state(&self, label: &'static str) -> Arc<ReqState> {
        let wrank = self.world_rank();
        // Hot path: recycle a completed, unaliased ReqState from the
        // thread-local pool when one is available (see `rmpi::request`);
        // fall back to a fresh allocation otherwise.
        let s = match ReqState::recycled() {
            Some(s) => {
                self.uni.reuse_req_states.fetch_add(1, Ordering::Relaxed);
                s
            }
            None => Arc::new(ReqState::default()),
        };
        s.set_lane(self.uni.lane_of[wrank]);
        if let Some(shard) = self.uni.progress.shard_for(wrank) {
            s.route_through(shard);
        }
        // Always stamped: the completion-latency histogram is part of
        // every run's metrics; the span itself is dropped by `RunObs`
        // when no sink is attached.
        s.set_obs(self.uni.obs.clone(), wrank as u32, self.uni.clock.now(), label);
        if let Some(fs) = &self.uni.faults {
            // Every completion on this rank bumps its progress gauge —
            // what the live stall detector reads.
            s.set_fault_gauge(fs.clone(), wrank);
        }
        s
    }

    /// How this universe delivers completion continuations.
    pub fn delivery_mode(&self) -> DeliveryMode {
        self.uni.progress.mode()
    }

    /// Aggregate sharded-delivery statistics (zeros under
    /// [`DeliveryMode::Direct`]).
    pub fn progress_stats(&self) -> EngineStats {
        self.uni.progress.stats()
    }

    /// Sharded-delivery statistics of one rank's shard.
    pub fn progress_shard_stats(&self, rank: usize) -> ShardStats {
        self.uni.progress.shard_stats(rank)
    }

    /// Current comm-rank avoid bitset steering the schedule compiler
    /// (see [`Comm::set_avoid`]).
    pub fn avoid_mask(&self) -> u64 {
        self.avoid.load(Ordering::Relaxed)
    }

    /// Steer the topology compiler away from the comm ranks in `mask`
    /// (bit `r` = comm rank `r`; ranks ≥ 64 are not representable and
    /// never avoided). Takes effect on the next collective call: the
    /// mask is folded into every [`SchedKey`], so plans compiled under
    /// the old mask stay cached but stop being selected. Local — call
    /// it with the same mask on every rank ([`Comm::detect_stragglers`]
    /// does) or subsequent collectives will tear.
    pub fn set_avoid(&self, mask: u64) {
        self.avoid.store(mask, Ordering::Relaxed);
    }

    /// Straggler agreement: a commutative max-allreduce of per-rank
    /// collective entry times. Every rank contributes `clock.now()` at
    /// its own entry; a rank whose entry trails the earliest by more
    /// than `threshold_ns` is voted a straggler. The combine is
    /// deterministic and the result identical on every rank, so the
    /// avoid mask this installs (via [`Comm::set_avoid`]) is agreed by
    /// construction — the control-plane analogue of the live detector's
    /// per-lane suspicion bits, which stay diagnostic. Collective;
    /// returns the mask.
    pub fn detect_stragglers(&self, threshold_ns: u64) -> u64 {
        let mut entry = vec![0u64; self.size];
        // `max(1)`: 0 marks "no vote", and virtual time can still be 0
        // at the first call.
        entry[self.rank] = self.uni.clock.now().max(1);
        self.allreduce_op(
            &mut entry,
            super::collectives::commutative(|a: &mut [u64], b: &[u64]| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x = (*x).max(*y);
                }
            }),
        );
        let earliest = entry.iter().copied().filter(|&t| t > 0).min().unwrap_or(0);
        let mut mask = 0u64;
        for (r, &t) in entry.iter().enumerate() {
            if r < 64 && t > earliest && t - earliest > threshold_ns {
                mask |= 1 << r;
            }
        }
        self.set_avoid(mask);
        if let Some(fs) = &self.uni.faults {
            fs.note_agreed_mask(mask);
        }
        mask
    }

    /// Compute-cost multiplier for this rank under straggler injection
    /// (1 with no faults configured). Applications scale their modelled
    /// per-task `clock.work` costs by this, so a persistent straggler
    /// slows *compute* as well as ingress (the `rx_extra` half lives in
    /// the `Ports` law).
    pub fn compute_mult(&self) -> u64 {
        match &self.uni.faults {
            Some(fs) => fs.cfg.compute_mult(self.world_rank()),
            None => 1,
        }
    }

    /// The rank-failure oracle: `Some(comm rank)` once the injected
    /// failure instant has passed for a member of this communicator.
    /// Stands in for a ULFM-style agreement protocol
    /// (`MPIX_Comm_agree`): the fault plan is shared config, so every
    /// rank reads the same verdict at the same virtual instant without
    /// extra messages — the agreement round's cost is not modelled.
    pub fn confirmed_dead(&self) -> Option<usize> {
        let fs = self.uni.faults.as_ref()?;
        let f = fs.cfg.rank_fail?;
        let now = self.uni.clock.now();
        (0..self.size)
            .find(|&r| self.world_rank_of(r) == f.rank && fs.cfg.dead_at(f.rank, now))
    }

    /// Shrink to the surviving ranks (ULFM `MPIX_Comm_shrink`): a new,
    /// smaller communicator over the members not (yet) dead per the
    /// fault oracle. Collective among the survivors — the dead rank
    /// does not call, which is exactly why context allocation goes
    /// through the survivor-set digest ([`UniState::shrink_context_pair`])
    /// rather than the dup path. The caller must be a survivor. Fresh
    /// contexts, collective sequence, plan caches, and avoid mask; the
    /// schedule compiler sees the surviving group's node map.
    pub fn comm_shrink(&self) -> Comm {
        let now = self.uni.clock.now();
        let group: Vec<usize> = (0..self.size)
            .map(|r| self.world_rank_of(r))
            .filter(|&w| {
                !self
                    .uni
                    .faults
                    .as_ref()
                    .is_some_and(|fs| fs.cfg.dead_at(w, now))
            })
            .collect();
        let my_world = self.world_rank();
        let rank = group
            .iter()
            .position(|&w| w == my_world)
            .expect("comm_shrink called by a dead rank");
        let digest = group_digest(&group);
        let world = self.uni.node_of.len();
        let (p, c) = self.uni.shrink_context_pair(self.ctx_p2p_id, digest, world);
        let group_nodes: Vec<usize> = group.iter().map(|&w| self.uni.node_of[w]).collect();
        let size = group.len();
        Comm {
            uni: self.uni.clone(),
            rank,
            size,
            ctx_p2p_id: p,
            ctx_p2p: self.uni.context(p),
            ctx_coll: self.uni.context(c),
            coll_seq: Arc::new(AtomicU64::new(0)),
            dup_seq: Arc::new(AtomicU64::new(0)),
            sched_cache: Arc::new(SchedCache::default()),
            group: Some(Arc::new(group)),
            group_nodes: Some(Arc::new(group_nodes)),
            avoid: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// FNV-1a digest of a survivor set (the shrink-context key).
fn group_digest(group: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in group {
        h ^= w as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}
