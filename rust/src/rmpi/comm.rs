//! Communicators: per-rank handles over shared matching state.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::progress::{DeliveryMode, EngineStats, ProgressEngine, ShardStats};
use crate::sim::Clock;
use crate::trace::Tracer;

use super::match_engine::ContextQueues;
use super::net::{NetworkModel, Ports};
use super::request::ReqState;
use super::topology::{
    compile_cluster_plans, compile_plan, CollPlan, PlanStore, SchedCache, SchedKey, TopoCtx,
    TopologyMode,
};

/// Shared cluster state (one per [`super::Universe`]).
pub(crate) struct UniState {
    pub clock: Arc<Clock>,
    pub net: NetworkModel,
    /// Per-rank ingress ports: every message delivery books its
    /// deadline here (see [`crate::rmpi::net::ports`]).
    pub ports: Ports,
    /// rank -> node id.
    pub node_of: Vec<usize>,
    /// rank -> clock lane (all zeros on a single-lane clock). Nodes are
    /// partitioned into contiguous lane blocks, so cross-lane traffic
    /// is always inter-node (the lookahead precondition).
    pub lane_of: Vec<usize>,
    /// How the collective schedule compiler sees the node hierarchy.
    pub topology: TopologyMode,
    /// Whether compiled schedules persist in per-communicator caches
    /// (`false` forces a recompile per call — the fig17 cold baseline).
    pub sched_cache_on: bool,
    /// Cluster-wide schedule-cache hit/miss counters (surfaced as
    /// [`super::RunStats::sched_cache`]).
    pub sched_hits: AtomicU64,
    pub sched_misses: AtomicU64,
    /// Universe-level plan compilation service: cluster plans compiled
    /// once per `SchedKey` and shared by every congruent communicator
    /// (surfaced as [`super::RunStats::plan_store`]).
    pub plan_store: PlanStore,
    /// Match contexts; a communicator owns two (p2p + collectives).
    pub contexts: Mutex<Vec<Arc<ContextQueues>>>,
    /// (parent ctx, dup seq) -> allocated context pair.
    pub dup_map: Mutex<std::collections::HashMap<(usize, u64), (usize, usize)>>,
    /// Completion-delivery engine (per-rank shards under
    /// [`DeliveryMode::Sharded`]; empty under `Direct`).
    pub progress: Arc<ProgressEngine>,
    /// Cluster tracer (annotation records from the collective engine's
    /// round advances are stamped here).
    pub tracer: Option<Arc<Tracer>>,
    /// Observability bundle: metrics always, spans when the run asked
    /// for them. Emission sites only read `Clock::now()` — recording
    /// never perturbs virtual time.
    pub obs: Arc<crate::obs::RunObs>,
}

impl UniState {
    pub fn alloc_context_pair(&self, size: usize) -> (usize, usize) {
        let mut g = self.contexts.lock().unwrap();
        let base = g.len();
        g.push(Arc::new(ContextQueues::new(size)));
        g.push(Arc::new(ContextQueues::new(size)));
        (base, base + 1)
    }

    /// Collective-safe duplication: the pair for (parent, seq) is
    /// allocated once; every rank calling dup in the same order resolves
    /// to the same contexts.
    pub fn dup_context_pair(&self, parent: usize, seq: u64, size: usize) -> (usize, usize) {
        let mut m = self.dup_map.lock().unwrap();
        if let Some(&pair) = m.get(&(parent, seq)) {
            return pair;
        }
        let pair = self.alloc_context_pair(size);
        m.insert((parent, seq), pair);
        pair
    }

    pub fn context(&self, id: usize) -> Arc<ContextQueues> {
        self.contexts.lock().unwrap()[id].clone()
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }
}

/// A communicator handle bound to one rank (like an `MPI_Comm` plus the
/// implicit rank of the caller). Cheap to clone; clones share matching
/// state and the collective sequence counter.
#[derive(Clone)]
pub struct Comm {
    pub(crate) uni: Arc<UniState>,
    pub(crate) rank: usize,
    pub(crate) size: usize,
    pub(crate) ctx_p2p_id: usize,
    pub(crate) ctx_p2p: Arc<ContextQueues>,
    pub(crate) ctx_coll: Arc<ContextQueues>,
    /// Collective call sequence of this rank (tags collective rounds;
    /// MPI requires all ranks to call collectives in the same order).
    pub(crate) coll_seq: Arc<AtomicU64>,
    /// Dup call sequence of this rank on this communicator.
    pub(crate) dup_seq: Arc<AtomicU64>,
    /// Persistent schedule store of this communicator (shared by
    /// clones; a `dup` starts fresh, and dropping the communicator
    /// drops its compiled plans — MPI persistent-request lifetime).
    pub(crate) sched_cache: Arc<SchedCache>,
}

impl Comm {
    pub(crate) fn world(uni: Arc<UniState>, rank: usize, size: usize) -> Comm {
        // World always owns contexts 0/1 (allocated by the universe).
        let ctx_p2p = uni.context(0);
        let ctx_coll = uni.context(1);
        Comm {
            uni,
            rank,
            size,
            ctx_p2p_id: 0,
            ctx_p2p,
            ctx_coll,
            coll_seq: Arc::new(AtomicU64::new(0)),
            dup_seq: Arc::new(AtomicU64::new(0)),
            sched_cache: Arc::new(SchedCache::default()),
        }
    }

    /// Rank of the caller within this communicator.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Node housing `rank` (the interconnect class boundary).
    pub fn node_of(&self, rank: usize) -> usize {
        self.uni.node_of[rank]
    }

    pub fn clock(&self) -> &Arc<Clock> {
        &self.uni.clock
    }

    /// Duplicate the communicator: fresh matching contexts, same group.
    /// Collective — every rank must call it in the same order.
    /// (MPI_Comm_dup — isolates library traffic.)
    pub fn dup(&self) -> Comm {
        let seq = self.dup_seq.fetch_add(1, Ordering::Relaxed);
        let (p, c) = self.uni.dup_context_pair(self.ctx_p2p_id, seq, self.size);
        Comm {
            uni: self.uni.clone(),
            rank: self.rank,
            size: self.size,
            ctx_p2p_id: p,
            ctx_p2p: self.uni.context(p),
            ctx_coll: self.uni.context(c),
            coll_seq: Arc::new(AtomicU64::new(0)),
            dup_seq: Arc::new(AtomicU64::new(0)),
            // A fresh per-comm plan index: the dup's index dies with it.
            // The compiled cluster plans themselves live in the
            // universe [`PlanStore`], so a congruent dup resolves its
            // index misses without recompiling (and without counting
            // compile misses — see `plan_for`).
            sched_cache: Arc::new(SchedCache::default()),
        }
    }

    /// Look up (or compile) the plan for one collective call: the
    /// persistent-collective fast path, now backed by the cluster-wide
    /// [`PlanStore`]. A per-comm index hit charges
    /// [`NetworkModel::sched_cache_hit_ns`] of caller CPU; an index
    /// miss consults the store and takes this rank's view of the
    /// (possibly already compiled) cluster plan. The per-call hit/miss
    /// accounting keys off the cluster plan's per-rank first-touch bit,
    /// which is deterministic per rank program order: a rank's first
    /// view is exactly the call that would have compiled before the
    /// service existed (same `sched_compile_ns` virtual-time debt, same
    /// miss count), while later views — a congruent dup — are hits.
    /// With the cache off the store is bypassed entirely (a recompile
    /// per call — the fig17 cold baseline).
    pub(crate) fn plan_for(&self, key: SchedKey) -> (Arc<CollPlan>, bool) {
        let store = &self.uni.plan_store;
        let mut ctx = TopoCtx::service(
            self.rank,
            self.size,
            &self.uni.node_of,
            self.uni.topology,
            &self.uni.net,
        );
        ctx.memo = Some(&store.memo);
        ctx.stats = Some(&store.stats);
        let (plan, cached) = if self.uni.sched_cache_on {
            let mut first_touch = false;
            let (plan, index_hit) = self.sched_cache.get_or_compile(&key, || {
                let (cluster, _) =
                    store.get_or_compile(key, || compile_cluster_plans(&key, &ctx));
                first_touch = cluster.first_touch(self.rank);
                cluster.view(self.rank)
            });
            (plan, index_hit || !first_touch)
        } else {
            (Arc::new(compile_plan(&key, &ctx)), false)
        };
        if cached {
            self.uni.sched_hits.fetch_add(1, Ordering::Relaxed);
            Clock::add_debt(self.uni.net.sched_cache_hit_ns);
        } else {
            self.uni.sched_misses.fetch_add(1, Ordering::Relaxed);
            Clock::add_debt(self.uni.net.sched_compile_ns);
        }
        (plan, cached)
    }

    /// How the schedule compiler sees this universe's node hierarchy.
    pub fn topology(&self) -> TopologyMode {
        self.uni.topology
    }

    /// Compiled plans currently held by this communicator's persistent
    /// schedule store.
    pub fn sched_cache_len(&self) -> usize {
        self.sched_cache.len()
    }

    /// Consume one collective sequence number. MPI requires all ranks to
    /// issue collectives on a communicator in the same order, so equal
    /// call indices resolve to equal sequence numbers on every rank; the
    /// schedule engine packs `(seq, phase)` into per-round message tags
    /// (see [`super::coll_schedule::coll_tag`]).
    pub(crate) fn next_coll_seq(&self) -> u64 {
        self.coll_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Allocate request state for an operation *owned by this rank*,
    /// routed through the rank's completion shard when the universe runs
    /// sharded delivery. Every request born through a `Comm` (p2p and
    /// collective-internal alike) goes through here, so a wildcard-source
    /// receive is always delivered on its poster's shard no matter which
    /// thread completes it.
    pub(crate) fn mk_req_state(&self, label: &'static str) -> Arc<ReqState> {
        let s = Arc::new(ReqState::default());
        s.set_lane(self.uni.lane_of[self.rank]);
        if let Some(shard) = self.uni.progress.shard_for(self.rank) {
            s.route_through(shard);
        }
        // Always stamped: the completion-latency histogram is part of
        // every run's metrics; the span itself is dropped by `RunObs`
        // when no sink is attached.
        s.set_obs(
            self.uni.obs.clone(),
            self.rank as u32,
            self.uni.clock.now(),
            label,
        );
        s
    }

    /// How this universe delivers completion continuations.
    pub fn delivery_mode(&self) -> DeliveryMode {
        self.uni.progress.mode()
    }

    /// Aggregate sharded-delivery statistics (zeros under
    /// [`DeliveryMode::Direct`]).
    pub fn progress_stats(&self) -> EngineStats {
        self.uni.progress.stats()
    }

    /// Sharded-delivery statistics of one rank's shard.
    pub fn progress_shard_stats(&self, rank: usize) -> ShardStats {
        self.uni.progress.shard_stats(rank)
    }
}
