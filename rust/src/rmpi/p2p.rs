//! Point-to-point operations.
//!
//! Blocking variants park the calling OS thread (in virtual time) until
//! the request completes — when called from inside a task *without* TAMPI
//! this steals the hardware thread from the runtime, which is the failure
//! mode of Section 5.

use std::sync::Arc;

use super::comm::Comm;
use super::match_engine::{Envelope, PostedRecv, RecvBuf};
use super::request::{ReqState, Request, Status};
use super::{as_bytes, as_bytes_mut, Pod, ANY_SOURCE, ANY_TAG};

/// Which p2p context a transfer uses.
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum Ctx {
    P2p,
    Coll,
}

impl Comm {
    fn ctx(&self, c: Ctx) -> &super::match_engine::ContextQueues {
        match c {
            Ctx::P2p => &self.ctx_p2p,
            Ctx::Coll => &self.ctx_coll,
        }
    }

    pub(crate) fn isend_ctx<T: Pod>(
        &self,
        buf: &[T],
        dst: usize,
        tag: i32,
        sync: bool,
        ctx: Ctx,
    ) -> Request {
        assert!(dst < self.size, "send to rank {dst} of {}", self.size);
        crate::sim::Clock::add_debt(self.uni.net.call_cpu_ns);
        // Everything below the comm API boundary — ports, lanes, node
        // map, match queues, message keys — speaks *world* ranks, so a
        // shrunk communicator's group translates exactly once, here.
        let wsrc = self.world_rank();
        let wdst = self.world_rank_of(dst);
        let bytes = as_bytes(buf);
        let same_node = self.uni.same_node(wsrc, wdst);
        let net = &self.uni.net;
        // Book the delivery deadline on the destination rank's ingress
        // port: arrival per the link model, then serialized receiver
        // processing (`NetworkModel::rx_ns`) in deterministic FIFO
        // order — the same path every collective round charges through.
        let sender_vtime = self.uni.clock.now();
        if let Some(fs) = &self.uni.faults {
            // A dead sender reaches no wire: fail the operation so the
            // victim's thread observes its own death at the next wait
            // and can unwind. (`dead_at` is a pure function of the
            // shared config — no cross-lane flag read.)
            if fs.cfg.dead_at(wsrc, sender_vtime) {
                let r = Request::new();
                r.0.complete_failed(
                    &self.uni.clock,
                    super::request::ReqError::RankFailed { rank: wsrc },
                );
                return r;
            }
        }
        let mut arrive_at = sender_vtime + net.transfer_ns(bytes.len(), same_node);
        let key = super::net::MsgKey {
            sender_vtime,
            src: wsrc as u32,
            tag,
            seq: self.uni.ports.next_seq(wsrc),
        };
        if let Some(fs) = &self.uni.faults {
            if fs.cfg.dead_at(wdst, sender_vtime) {
                // Destination already dead. Eager sends are
                // fire-and-forget: locally buffered, then lost — they
                // complete successfully, as on a real fabric.
                // Rendezvous sends would wait for a receive that can
                // never be posted: time them out.
                if !(sync || !net.is_eager(bytes.len())) {
                    return Request::done();
                }
                let sender_req = self.mk_req_state("send");
                let timeout = fs.cfg.rank_fail.map(|f| f.timeout_ns).unwrap_or(0);
                fs.fail_at(
                    &self.uni.clock,
                    self.uni.lane_of[wsrc],
                    sender_vtime + timeout,
                    Arc::downgrade(&sender_req),
                    wdst,
                );
                return Request(sender_req);
            }
            if fs.should_drop(wsrc, wdst, tag, key.seq) {
                // Dropped on the wire: model the (single) sender
                // retransmission as a delayed departure — the surviving
                // copy takes the normal ingress path, so delivery stays
                // exactly-once by construction.
                arrive_at += fs.note_drop();
            }
        }
        let booking = self.uni.ports.book(wdst, &self.uni.clock, key, arrive_at);
        // Flow id derived from the message key: the send point carries it
        // as `flow_out`, the matching delivery on the receiver's port
        // closes it as `flow_in` (the send→recv arrow in Perfetto).
        let flow = if self.uni.obs.enabled() {
            crate::obs::fid(&[key.sender_vtime, key.src as u64, key.tag as u64, key.seq])
        } else {
            0
        };
        if flow != 0 {
            let wid = crate::nanos::worker::worker_id();
            let w = if wid == usize::MAX { u32::MAX } else { wid as u32 };
            self.uni.obs.record(
                crate::obs::Span::point(
                    crate::obs::Track::Worker { rank: wsrc as u32, worker: w },
                    crate::obs::SpanKind::Send,
                    sender_vtime,
                    "isend",
                    key.seq,
                )
                .with_flow_out(flow),
            );
        }
        let rendezvous = sync || !net.is_eager(bytes.len());
        // Rendezvous sender requests are owned by (and shard-routed to)
        // the *sending* rank.
        let sender_req: Option<Arc<ReqState>> = if rendezvous {
            // Cross-lane rendezvous: the sender completion is
            // zero-latency feedback from the receiver's lane back to
            // ours at the delivery instant — register the clock
            // obligation covering it now, while this (active) thread
            // still pins our lane's lower bound. Released in
            // `match_engine::complete_at_deadline` once the completion
            // event is in our lane's heap.
            let send_lane = self.uni.lane_of[wsrc];
            let recv_lane = self.uni.lane_of[wdst];
            if send_lane != recv_lane {
                self.uni.clock.begin_feedback(recv_lane, send_lane);
            }
            let s = self.mk_req_state("send");
            if let Some(fs) = &self.uni.faults {
                // If the destination dies mid-flight, the death sweep
                // times this sender out.
                fs.track(send_lane, wsrc, Some(wdst), &s);
            }
            Some(s)
        } else {
            None
        };
        let req = match &sender_req {
            Some(s) => Request(s.clone()),
            None => Request::done(),
        };
        let mut q = self.ctx(ctx).dst[wdst].lock().unwrap();
        if let Some(posted) = q.match_posted(wsrc, tag) {
            // Fast path: copy straight into the posted receive buffer
            // (no envelope allocation, §Perf opt-3).
            drop(q);
            super::match_engine::deliver_direct(
                &self.uni.clock,
                bytes,
                wsrc,
                tag,
                booking,
                sender_req,
                posted,
                flow,
            );
            return req;
        }
        let env = Envelope {
            src: wsrc,
            tag,
            data: bytes.to_vec().into_boxed_slice(),
            booking,
            sender_req,
            flow,
        };
        q.unexpected.push_back(env);
        drop(q);
        req
    }

    pub(crate) fn irecv_ctx<T: Pod>(
        &self,
        buf: &mut [T],
        src: i32,
        tag: i32,
        ctx: Ctx,
    ) -> Request {
        crate::sim::Clock::add_debt(self.uni.net.call_cpu_ns);
        let wrank = self.world_rank();
        let now = self.uni.clock.now();
        if let Some(fs) = &self.uni.faults {
            // A dead rank posts nothing: fail immediately so its thread
            // can unwind.
            if fs.cfg.dead_at(wrank, now) {
                let r = Request::new();
                r.0.complete_failed(
                    &self.uni.clock,
                    super::request::ReqError::RankFailed { rank: wrank },
                );
                return r;
            }
        }
        // Owned by the posting rank: completions (wherever they are
        // delivered from) route to this rank's shard.
        let req = Request(self.mk_req_state("recv"));
        let bytes = as_bytes_mut(buf);
        let wsrc = if src == ANY_SOURCE {
            None
        } else {
            assert!((src as usize) < self.size);
            Some(self.world_rank_of(src as usize))
        };
        if let Some(fs) = &self.uni.faults {
            let lane = self.uni.lane_of[wrank];
            // Sweep coverage for a source that dies later; wildcard
            // receives have no single peer and only fail if the owner
            // itself dies (or the run's deadline catches the hang).
            fs.track(lane, wrank, wsrc, &req.0);
            if let (Some(s), Some(f)) = (wsrc, fs.cfg.rank_fail) {
                if fs.cfg.dead_at(s, now) {
                    // Posted after the peer's death: still enter the
                    // match queue (an in-flight pre-death envelope may
                    // legitimately match), but time out otherwise.
                    fs.fail_at(
                        &self.uni.clock,
                        lane,
                        now + f.timeout_ns,
                        Arc::downgrade(&req.0),
                        s,
                    );
                }
            }
        }
        let posted = PostedRecv {
            src: wsrc,
            tag: if tag == ANY_TAG { None } else { Some(tag) },
            buf: RecvBuf { ptr: bytes.as_mut_ptr(), len: bytes.len() },
            req: req.0.clone(),
        };
        let matched = {
            let mut q = self.ctx(ctx).dst[wrank].lock().unwrap();
            q.post(posted)
        };
        if let Some((env, posted)) = matched {
            super::match_engine::deliver(&self.uni.clock, env, posted);
        }
        req
    }

    /// Non-blocking standard send (MPI_Isend): eager messages complete
    /// immediately; rendezvous-size messages complete at delivery.
    pub fn isend<T: Pod>(&self, buf: &[T], dst: usize, tag: i32) -> Request {
        self.isend_ctx(buf, dst, tag, false, Ctx::P2p)
    }

    /// Non-blocking synchronous send (MPI_Issend): completes only once the
    /// matching receive was posted and the transfer is done.
    pub fn issend<T: Pod>(&self, buf: &[T], dst: usize, tag: i32) -> Request {
        self.isend_ctx(buf, dst, tag, true, Ctx::P2p)
    }

    /// Non-blocking receive (MPI_Irecv). The buffer must stay untouched
    /// until the request completes.
    pub fn irecv<T: Pod>(&self, buf: &mut [T], src: i32, tag: i32) -> Request {
        self.irecv_ctx(buf, src, tag, Ctx::P2p)
    }

    /// Blocking standard send (MPI_Send).
    pub fn send<T: Pod>(&self, buf: &[T], dst: usize, tag: i32) {
        self.isend(buf, dst, tag).wait(&self.uni.clock);
    }

    /// Blocking synchronous send (MPI_Ssend).
    pub fn ssend<T: Pod>(&self, buf: &[T], dst: usize, tag: i32) {
        self.issend(buf, dst, tag).wait(&self.uni.clock);
    }

    /// Blocking receive (MPI_Recv).
    pub fn recv<T: Pod>(&self, buf: &mut [T], src: i32, tag: i32) -> Status {
        let r = self.irecv(buf, src, tag);
        r.wait(&self.uni.clock);
        r.status()
    }

    /// MPI_Wait.
    pub fn wait(&self, req: &Request) {
        req.wait(&self.uni.clock);
    }

    /// MPI_Waitall.
    pub fn wait_all(&self, reqs: &[Request]) {
        Request::wait_all(&self.uni.clock, reqs);
    }
}
