//! MPI request objects: completion state, passive waiting, and completion
//! continuations.
//!
//! Completion is delivered two ways:
//!
//! * **Pull** — [`Request::test`] / [`Request::wait`] (the MPI_Test /
//!   MPI_Wait shapes), used by plain MPI code and by TAMPI's poll-scan
//!   baseline ([`crate::nanos::CompletionMode::Polling`]).
//! * **Push** — [`Request::on_complete`] attaches a *continuation* (the
//!   MPI Continuations proposal's `MPIX_Continue` shape) that runs with
//!   the request's final [`Status`] at the exact virtual instant the
//!   operation completes. TAMPI's callback pipeline
//!   ([`crate::nanos::CompletionMode::Callback`]) is built on this.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::progress::Shard;
use crate::sim::{Clock, WaitQueue};

/// Sentinel for "no clock lane stamped" (bare requests, unit tests).
const NO_LANE: usize = usize::MAX;

/// Max recycled `ReqState`s parked per thread (bounds idle memory).
const REQ_POOL_CAP: usize = 64;

thread_local! {
    /// Recycle pool for completed, fully-unaliased request states: the
    /// hot p2p/collective paths allocate one `Arc<ReqState>` per
    /// operation, and virtually all of them die completed with no
    /// outstanding clones — `Drop for Request` resets and parks them
    /// here, `Comm::mk_req_state` reuses them. Thread-local so no lock
    /// is ever taken; entries are only ever pre-reset and unaliased
    /// (`Arc::get_mut` proved sole ownership at park time).
    static REQ_POOL: std::cell::RefCell<Vec<Arc<ReqState>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Completion status of a receive (source/tag/len of the matched message).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Status {
    pub source: i32,
    pub tag: i32,
    pub bytes: usize,
}

/// Why a request completed unsuccessfully. Error-carrying completions
/// flow through the *same* [`ReqState::complete`] path as successes —
/// waiters wake, continuations fire, TAMPI external events decrement —
/// so a failure releases task dependencies exactly like a completion;
/// only [`Request::result`] tells them apart.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqError {
    /// The peer (or a collective participant) died before the
    /// operation could complete; `rank` is the failed world rank.
    RankFailed { rank: usize },
}

/// A completion continuation: runs exactly once with the request's final
/// [`Status`].
pub(crate) type Continuation = Box<dyn FnOnce(Status) + Send>;

pub(crate) struct ReqState {
    completed: AtomicBool,
    waiters: WaitQueue,
    status: Mutex<Status>,
    /// Clock lane of the request's *owning* rank (the rank whose thread
    /// may park on it), stamped once at creation by
    /// [`crate::rmpi::Comm`]. Completions are routed to this lane so
    /// that every wake stays intra-lane on a sharded clock. `NO_LANE`
    /// (bare requests, unit tests) means "whatever lane completes it".
    lane: AtomicUsize,
    /// Continuations to fire at completion time. Race-free protocol:
    /// `attach` pushes only while holding this lock *and* observing
    /// `completed == false`; `complete` stores `completed = true` before
    /// draining under the same lock. A continuation is therefore either
    /// drained-and-fired by `complete` or run inline by `attach` — never
    /// both, never lost.
    on_complete: Mutex<Vec<Continuation>>,
    /// Sharded-delivery route, stamped once at creation by
    /// [`crate::rmpi::Comm`] on a `DeliveryMode::Sharded` universe: the
    /// completion shard of the request's *owning* rank. `None` (bare
    /// requests, `DeliveryMode::Direct`) fires continuations inline at
    /// the completion point.
    shard: Mutex<Option<Arc<Shard>>>,
    /// Observability stamp (obs bundle, owning rank, post instant, label),
    /// set once at creation by [`crate::rmpi::Comm`] when spans are on.
    /// `complete` turns it into one `MpiReq` lifetime span.
    obs: Mutex<Option<(Arc<crate::obs::RunObs>, u32, u64, &'static str)>>,
    /// `Some` after an error-carrying completion ([`ReqError`]);
    /// published before `completed` flips so readers that observe
    /// completion also observe the error.
    error: Mutex<Option<ReqError>>,
    /// Live-detector progress gauge: `(fault state, owning world rank)`,
    /// stamped at creation when fault injection is active. `complete`
    /// records the completion instant as the rank's last progress.
    fault_gauge: Mutex<Option<(Arc<super::faults::FaultState>, usize)>>,
}

impl Default for ReqState {
    fn default() -> Self {
        ReqState {
            completed: AtomicBool::new(false),
            waiters: WaitQueue::new(),
            status: Mutex::new(Status::default()),
            lane: AtomicUsize::new(NO_LANE),
            on_complete: Mutex::new(Vec::new()),
            shard: Mutex::new(None),
            obs: Mutex::new(None),
            error: Mutex::new(None),
            fault_gauge: Mutex::new(None),
        }
    }
}

impl ReqState {
    /// Stamp the owning rank's clock lane (once, at creation).
    pub(crate) fn set_lane(&self, lane: usize) {
        self.lane.store(lane, Ordering::Release);
    }

    /// Clock lane of the owning rank, if stamped.
    pub(crate) fn lane(&self) -> Option<usize> {
        match self.lane.load(Ordering::Acquire) {
            NO_LANE => None,
            l => Some(l),
        }
    }

    /// Mark the operation complete: publish the status, wake parked
    /// waiters, and fire attached continuations. Called from the thread
    /// that delivers the completion — a rank main, a worker, or the clock
    /// thread for deferred network deliveries (`Clock::call_at` in
    /// `match_engine::deliver`/`deliver_direct`).
    /// Stamp the observability bundle for one request-lifetime span
    /// (once, at creation): owning rank, post instant, span label.
    pub(crate) fn set_obs(
        &self,
        obs: Arc<crate::obs::RunObs>,
        rank: u32,
        born: u64,
        label: &'static str,
    ) {
        *self.obs.lock().unwrap() = Some((obs, rank, born, label));
    }

    /// Peek the observability bundle + owning rank (for delivery-point
    /// spans emitted by the match engine) without consuming the stamp.
    pub(crate) fn obs_stamp(&self) -> Option<(Arc<crate::obs::RunObs>, u32)> {
        self.obs.lock().unwrap().as_ref().map(|(o, r, _, _)| (o.clone(), *r))
    }

    pub(crate) fn complete(&self, clock: &Clock, status: Option<Status>) {
        // Idempotent: a fault timeout and a late in-flight delivery can
        // both target the same request. All completions for a request
        // run on its owning lane (or its owning thread), so this check
        // is ordered, not racy — the loser simply returns.
        if self.completed.load(Ordering::Acquire) {
            return;
        }
        if let Some(s) = status {
            *self.status.lock().unwrap() = s;
        }
        if let Some((fs, rank)) = self.fault_gauge.lock().unwrap().as_ref() {
            fs.note_progress(*rank, clock.now());
        }
        if let Some((obs, rank, born, label)) = self.obs.lock().unwrap().take() {
            // Unique id: the exporter pairs `b`/`e` async events by id,
            // so same-instant requests must not collide.
            static REQ_SPAN_ID: AtomicUsize = AtomicUsize::new(1);
            let id = REQ_SPAN_ID.fetch_add(1, Ordering::Relaxed) as u64;
            let now = clock.now();
            obs.completion_latency_ns.record(now.saturating_sub(born));
            obs.record(crate::obs::Span::interval(
                crate::obs::Track::Reqs { rank },
                crate::obs::SpanKind::MpiReq,
                born,
                now,
                label,
                id,
            ));
        }
        self.completed.store(true, Ordering::Release);
        self.waiters.notify_all(clock);
        let cbs = std::mem::take(&mut *self.on_complete.lock().unwrap());
        if !cbs.is_empty() {
            let st = *self.status.lock().unwrap();
            let route = self.shard.lock().unwrap().clone();
            match route {
                // Sharded delivery: deposit for a same-instant batched
                // drain on the owning rank's shard (one scheduler-lock
                // acquisition per shard-batch; see `crate::progress`).
                Some(shard) => shard.deposit(clock, cbs, st),
                // Direct delivery: fire inline at the completion point.
                None => {
                    for f in cbs {
                        f(st);
                    }
                }
            }
        }
    }

    /// Route this request's completion through `shard` (sharded
    /// delivery). Called once, at creation, before the request can
    /// complete.
    pub(crate) fn route_through(&self, shard: Arc<Shard>) {
        *self.shard.lock().unwrap() = Some(shard);
    }

    /// Stamp the live-detector progress gauge (once, at creation, when
    /// fault injection is active).
    pub(crate) fn set_fault_gauge(&self, fs: Arc<super::faults::FaultState>, rank: usize) {
        *self.fault_gauge.lock().unwrap() = Some((fs, rank));
    }

    /// Completion check for fault-path events (same semantics as
    /// [`Request::test`]).
    pub(crate) fn is_completed(&self) -> bool {
        self.completed.load(Ordering::Acquire)
    }

    /// Error-carrying completion: publish `err`, then complete normally
    /// so every downstream consumer (waiters, continuations, TAMPI
    /// external-event decrements) runs unchanged.
    pub(crate) fn complete_failed(&self, clock: &Clock, err: ReqError) {
        if self.completed.load(Ordering::Acquire) {
            return;
        }
        *self.error.lock().unwrap() = Some(err);
        self.complete(clock, None);
    }

    /// The error published by an error-carrying completion, if any.
    pub(crate) fn error(&self) -> Option<ReqError> {
        *self.error.lock().unwrap()
    }

    /// Mark this request as failed with `err` without completing it —
    /// used by collective schedules to accumulate constituent failures
    /// until the final round's `finish` completes the outer request.
    pub(crate) fn poison(&self, err: ReqError) {
        let mut g = self.error.lock().unwrap();
        if g.is_none() {
            *g = Some(err);
        }
    }

    /// Reset a sole-owned state back to its `Default` shape so it can be
    /// recycled. Requires `&mut self` (the caller proved sole ownership
    /// via `Arc::get_mut`): every lock is uncontended by construction.
    /// Clearing `waiters` is sound because a completed request's
    /// `notify_all` already woke every queued token; clears retain the
    /// vector capacities, which is the point of recycling.
    fn reset(&mut self) {
        *self.completed.get_mut() = false;
        self.waiters.clear();
        *self.status.get_mut().unwrap() = Status::default();
        *self.lane.get_mut() = NO_LANE;
        self.on_complete.get_mut().unwrap().clear();
        *self.shard.get_mut().unwrap() = None;
        *self.obs.get_mut().unwrap() = None;
        *self.error.get_mut().unwrap() = None;
        *self.fault_gauge.get_mut().unwrap() = None;
    }

    /// Pop a recycled state from the calling thread's pool, if any.
    /// Entries are already reset; the caller re-stamps lane/shard/obs
    /// exactly as it would on a fresh allocation.
    pub(crate) fn recycled() -> Option<Arc<ReqState>> {
        let s = REQ_POOL.try_with(|p| p.borrow_mut().pop()).ok().flatten();
        if let Some(s) = &s {
            debug_assert!(!s.is_completed(), "recycled ReqState not reset");
        }
        s
    }

    /// Attach a continuation; runs it inline if the request has already
    /// completed (see the field docs for the race-free protocol).
    pub(crate) fn attach(&self, f: Continuation) {
        {
            let mut g = self.on_complete.lock().unwrap();
            if !self.completed.load(Ordering::Acquire) {
                g.push(f);
                return;
            }
        }
        let st = *self.status.lock().unwrap();
        f(st);
    }
}

/// Handle to an in-flight operation. Clone freely; all clones observe the
/// same completion.
#[derive(Clone, Default)]
pub struct Request(pub(crate) Arc<ReqState>);

impl Request {
    pub(crate) fn new() -> Self {
        Request(Arc::new(ReqState::default()))
    }

    /// A request born completed (e.g. self-sends resolved inline).
    pub(crate) fn done() -> Self {
        let r = Request::new();
        r.0.completed.store(true, Ordering::Release);
        r
    }

    /// Non-blocking completion check (MPI_Test without side effects; our
    /// requests are not invalidated by testing).
    pub fn test(&self) -> bool {
        self.0.completed.load(Ordering::Acquire)
    }

    /// Status of a completed receive.
    pub fn status(&self) -> Status {
        *self.0.status.lock().unwrap()
    }

    /// `true` when the request completed with an error (e.g. a peer
    /// died — [`ReqError::RankFailed`]).
    pub fn failed(&self) -> bool {
        self.0.error().is_some()
    }

    /// The completion error, if the request failed.
    pub fn error(&self) -> Option<ReqError> {
        self.0.error()
    }

    /// Completed-state outcome: `Ok(status)` for a successful
    /// completion, `Err` for an error-carrying one. Meaningful once
    /// [`Request::test`] returns true (or after [`Request::wait`]).
    pub fn result(&self) -> Result<Status, ReqError> {
        match self.0.error() {
            Some(e) => Err(e),
            None => Ok(self.status()),
        }
    }

    /// Attach a completion continuation: `f` runs exactly once with the
    /// request's final [`Status`] — inline on the calling thread if the
    /// request already completed, otherwise at the virtual instant the
    /// operation completes.
    ///
    /// The continuation may run on any thread, including the clock thread
    /// for deferred network deliveries, so it must not block on
    /// simulation primitives; waking tasks through the `nanos` APIs
    /// (`unblock_task`, `decrease_task_event_counter`) is safe.
    pub fn on_complete(&self, f: impl FnOnce(Status) + Send + 'static) {
        self.0.attach(Box::new(f));
    }

    /// Blocking wait: parks the calling OS thread in virtual time.
    /// This is the hardware-thread-stealing behaviour Section 5 warns
    /// about when used inside tasks without TAMPI.
    pub fn wait(&self, clock: &Clock) {
        // Settle accumulated MPI-call CPU debt before blocking.
        clock.flush_debt();
        loop {
            // Enqueue first, then re-check: completion after the check
            // would otherwise drain the queue before we park.
            if self.test() {
                return;
            }
            let tok = self.0.waiters.enqueue();
            if self.test() {
                // Completion's notify_all already drained the queue
                // before our enqueue: sweep the stale token rather than
                // pinning it for the request's remaining lifetime.
                self.0.waiters.remove(&tok);
                return;
            }
            clock.passive_wait(&tok);
        }
    }

    /// Wait for all requests.
    pub fn wait_all(clock: &Clock, reqs: &[Request]) {
        for r in reqs {
            r.wait(clock);
        }
    }

    /// Index of some completed request, waiting if none is (MPI_Waitany).
    pub fn wait_any(clock: &Clock, reqs: &[Request]) -> usize {
        assert!(!reqs.is_empty());
        loop {
            if let Some(i) = reqs.iter().position(|r| r.test()) {
                return i;
            }
            // One shared token enqueued on every incomplete request:
            // whichever completes first wakes us (idempotent wakes).
            let tok = crate::sim::Token::new();
            let mut enqueued: Vec<&Request> = Vec::with_capacity(reqs.len());
            for r in reqs {
                if !r.test() {
                    r.0.waiters.enqueue_token(tok.clone());
                    enqueued.push(r);
                }
            }
            let early = reqs.iter().position(|r| r.test());
            if early.is_none() {
                clock.passive_wait(&tok);
            }
            // Drain the stale token from every request that did not wake
            // us: a completing request pops its own copy in `notify_all`,
            // but without this sweep each waitany round would pin one
            // token per still-pending request for the request's remaining
            // lifetime (repeated waitany loops leak queue entries).
            for r in enqueued {
                r.0.waiters.remove(&tok);
            }
            if let Some(i) = early {
                return i;
            }
        }
    }
}

impl Drop for Request {
    fn drop(&mut self) {
        // Recycle completed, fully-unaliased states: `Arc::get_mut`
        // succeeding proves this is the last strong ref *and* no weak
        // ref (e.g. the fault tracker's `Weak<ReqState>`) is
        // outstanding, so nobody can ever reach the state again —
        // resetting and re-issuing it is invisible. Aliased or
        // incomplete states just drop normally. `try_with` guards
        // against TLS teardown order on exiting threads.
        if !self.0.is_completed() {
            return;
        }
        if let Some(st) = Arc::get_mut(&mut self.0) {
            st.reset();
            let _ = REQ_POOL.try_with(|p| {
                let mut p = p.borrow_mut();
                if p.len() < REQ_POOL_CAP {
                    p.push(self.0.clone());
                }
            });
        }
    }
}

impl std::fmt::Debug for Request {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Request(completed={})", self.test())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_and_done() {
        let r = Request::new();
        assert!(!r.test());
        let d = Request::done();
        assert!(d.test());
    }

    #[test]
    fn continuation_on_completed_request_runs_inline() {
        let d = Request::done();
        let hit = Arc::new(AtomicBool::new(false));
        let h = hit.clone();
        d.on_complete(move |_| h.store(true, Ordering::Relaxed));
        assert!(hit.load(Ordering::Relaxed), "must fire inline at attach");
    }

    #[test]
    fn continuation_fires_at_completion_with_final_status() {
        let (clock, h) = Clock::start();
        let r = Request::new();
        let seen: Arc<Mutex<Vec<Status>>> = Arc::new(Mutex::new(Vec::new()));
        let s2 = seen.clone();
        r.on_complete(move |st| s2.lock().unwrap().push(st));
        assert!(seen.lock().unwrap().is_empty(), "must not fire before completion");
        let st = Status { source: 3, tag: 9, bytes: 4 };
        r.0.complete(&clock, Some(st));
        assert!(r.test());
        assert_eq!(seen.lock().unwrap().as_slice(), &[st]);
        // A second attach after completion fires inline with the same status.
        let s3 = seen.clone();
        r.on_complete(move |st| s3.lock().unwrap().push(st));
        assert_eq!(seen.lock().unwrap().as_slice(), &[st, st]);
        clock.stop();
        h.join().unwrap();
    }

    #[test]
    fn failed_completion_fires_continuations_and_reports_error() {
        let (clock, h) = Clock::start();
        let r = Request::new();
        let hit = Arc::new(AtomicBool::new(false));
        let h2 = hit.clone();
        r.on_complete(move |_| h2.store(true, Ordering::Relaxed));
        r.0.complete_failed(&clock, ReqError::RankFailed { rank: 3 });
        assert!(r.test(), "a failed request still completes");
        assert!(hit.load(Ordering::Relaxed), "continuations fire on failure too");
        assert!(r.failed());
        assert_eq!(r.result(), Err(ReqError::RankFailed { rank: 3 }));
        // Late duplicate completions (e.g. an in-flight delivery racing
        // a fault timeout) are idempotent no-ops.
        r.0.complete(&clock, Some(Status { source: 1, tag: 2, bytes: 3 }));
        assert_eq!(r.result(), Err(ReqError::RankFailed { rank: 3 }));
        clock.stop();
        h.join().unwrap();
    }

    #[test]
    fn wait_any_drains_stale_tokens() {
        let (clock, h) = Clock::start();
        clock.register_thread();
        let a = Request::new();
        let b = Request::new();
        let a2 = a.clone();
        let c2 = clock.clone();
        clock.call_at(100, move || a2.0.complete(&c2, None));
        let i = Request::wait_any(&clock, &[a.clone(), b.clone()]);
        assert_eq!(i, 0);
        // The shared token must not stay parked on the still-pending
        // request (the continuation/token leak a repeated waitany loop
        // would otherwise accumulate).
        assert_eq!(b.0.waiters.len(), 0, "stale waitany token leaked");
        assert_eq!(a.0.waiters.len(), 0);
        // An immediately-satisfiable waitany leaves no residue either.
        assert_eq!(Request::wait_any(&clock, &[b.clone(), a.clone()]), 1);
        assert_eq!(b.0.waiters.len(), 0);
        clock.deregister_thread();
        clock.stop();
        h.join().unwrap();
    }
}
