//! MPI request objects: completion state + passive waiting.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::sim::{Clock, WaitQueue};

/// Completion status of a receive (source/tag/len of the matched message).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Status {
    pub source: i32,
    pub tag: i32,
    pub bytes: usize,
}

#[derive(Default)]
pub(crate) struct ReqState {
    completed: AtomicBool,
    waiters: WaitQueue,
    status: std::sync::Mutex<Status>,
}

impl ReqState {
    pub(crate) fn complete(&self, clock: &Clock, status: Option<Status>) {
        if let Some(s) = status {
            *self.status.lock().unwrap() = s;
        }
        self.completed.store(true, Ordering::Release);
        self.waiters.notify_all(clock);
    }
}

/// Handle to an in-flight operation. Clone freely; all clones observe the
/// same completion.
#[derive(Clone, Default)]
pub struct Request(pub(crate) Arc<ReqState>);

impl Request {
    pub(crate) fn new() -> Self {
        Request(Arc::new(ReqState::default()))
    }

    /// A request born completed (e.g. self-sends resolved inline).
    pub(crate) fn done() -> Self {
        let r = Request::new();
        r.0.completed.store(true, Ordering::Release);
        r
    }

    /// Non-blocking completion check (MPI_Test without side effects; our
    /// requests are not invalidated by testing).
    pub fn test(&self) -> bool {
        self.0.completed.load(Ordering::Acquire)
    }

    /// Status of a completed receive.
    pub fn status(&self) -> Status {
        *self.0.status.lock().unwrap()
    }

    /// Blocking wait: parks the calling OS thread in virtual time.
    /// This is the hardware-thread-stealing behaviour Section 5 warns
    /// about when used inside tasks without TAMPI.
    pub fn wait(&self, clock: &Clock) {
        // Settle accumulated MPI-call CPU debt before blocking.
        clock.flush_debt();
        loop {
            // Enqueue first, then re-check: completion after the check
            // would otherwise drain the queue before we park.
            if self.test() {
                return;
            }
            let tok = self.0.waiters.enqueue();
            if self.test() {
                return;
            }
            clock.passive_wait(&tok);
        }
    }

    /// Wait for all requests.
    pub fn wait_all(clock: &Clock, reqs: &[Request]) {
        for r in reqs {
            r.wait(clock);
        }
    }

    /// Index of some completed request, waiting if none is (MPI_Waitany).
    pub fn wait_any(clock: &Clock, reqs: &[Request]) -> usize {
        assert!(!reqs.is_empty());
        loop {
            if let Some(i) = reqs.iter().position(|r| r.test()) {
                return i;
            }
            // One shared token enqueued on every incomplete request:
            // whichever completes first wakes us (idempotent wakes).
            let tok = crate::sim::Token::new();
            for r in reqs {
                if !r.test() {
                    r.0.waiters.enqueue_token(tok.clone());
                }
            }
            if let Some(i) = reqs.iter().position(|r| r.test()) {
                return i;
            }
            clock.passive_wait(&tok);
        }
    }
}

impl std::fmt::Debug for Request {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Request(completed={})", self.test())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_and_done() {
        let r = Request::new();
        assert!(!r.test());
        let d = Request::done();
        assert!(d.test());
    }
}
