//! Interconnect model: delivery deadlines in virtual time.
//!
//! MareNostrum 4's fabric is 100 Gbit/s Intel Omni-Path; intra-node
//! communication goes through shared memory. The model assigns each
//! message `latency(class) + bytes / bandwidth(class)`; rendezvous-size
//! messages additionally tie the *sender's* completion to the match
//! (synchronous behaviour above the eager threshold, like MPICH).

use crate::sim::VNanos;

/// Link classes and protocol thresholds of the simulated cluster.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// One-way latency between ranks on the same node (shared memory).
    pub intra_latency_ns: u64,
    /// Shared-memory copy bandwidth, bytes/s.
    pub intra_bw_bytes_per_s: u64,
    /// One-way latency across nodes (Omni-Path class fabric).
    pub inter_latency_ns: u64,
    /// Network bandwidth, bytes/s.
    pub inter_bw_bytes_per_s: u64,
    /// Messages larger than this use the rendezvous protocol: the sender's
    /// request completes only when the receive is matched and the transfer
    /// done (plain `send` behaves like `ssend`).
    pub eager_threshold: usize,
    /// CPU time one MPI call burns on the calling core (library overhead,
    /// matching, copies). Charged as virtual-time debt to the caller.
    pub call_cpu_ns: u64,
    /// Receiver-side processing per message *within a collective
    /// schedule round* (the message-rate term): a round that posted `k`
    /// receives defers the next round's post by `k x` this. Default 0
    /// (pure latency model); setting it makes fan-in visible, which is
    /// what the topology compiler's leader staging buys back (see
    /// `rmpi::topology`). Applied structurally from the plan, so both
    /// delivery modes observe identical virtual instants.
    pub coll_rx_ns: u64,
    /// CPU cost of compiling a collective schedule (charged to the
    /// caller on a schedule-cache miss).
    pub sched_compile_ns: u64,
    /// CPU cost of a schedule-cache hit (key hash + lookup).
    pub sched_cache_hit_ns: u64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            intra_latency_ns: 400,                        // shared-memory hop
            intra_bw_bytes_per_s: 8_000_000_000,          // 8 GB/s memcpy
            inter_latency_ns: 1_500,                      // Omni-Path ~1.5 us
            inter_bw_bytes_per_s: 12_500_000_000,         // 100 Gbit/s
            eager_threshold: 64 * 1024,
            call_cpu_ns: 400,                             // per-call library cost
            coll_rx_ns: 0,                                // pure latency model
            sched_compile_ns: 1_000,                      // rounds + trees + regions
            sched_cache_hit_ns: 50,                       // hash + lookup
        }
    }
}

impl NetworkModel {
    /// A zero-cost network (unit tests of matching logic).
    pub fn instant() -> Self {
        NetworkModel {
            intra_latency_ns: 0,
            intra_bw_bytes_per_s: u64::MAX,
            inter_latency_ns: 0,
            inter_bw_bytes_per_s: u64::MAX,
            eager_threshold: usize::MAX,
            call_cpu_ns: 0,
            coll_rx_ns: 0,
            sched_compile_ns: 0,
            sched_cache_hit_ns: 0,
        }
    }

    /// Virtual transfer duration of a message of `bytes` over the class.
    pub fn transfer_ns(&self, bytes: usize, same_node: bool) -> VNanos {
        let (lat, bw) = if same_node {
            (self.intra_latency_ns, self.intra_bw_bytes_per_s)
        } else {
            (self.inter_latency_ns, self.inter_bw_bytes_per_s)
        };
        if bw == u64::MAX {
            return lat;
        }
        lat + (bytes as u128 * 1_000_000_000u128 / bw as u128) as u64
    }

    /// Whether a message of `bytes` is eager (sender completes at once).
    pub fn is_eager(&self, bytes: usize) -> bool {
        bytes <= self.eager_threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_scales_with_size_and_class() {
        let m = NetworkModel::default();
        let small_intra = m.transfer_ns(8, true);
        let small_inter = m.transfer_ns(8, false);
        assert!(small_inter > small_intra);
        let big_inter = m.transfer_ns(1 << 20, false);
        assert!(big_inter > small_inter);
        // 1 MiB at 12.5 GB/s ~ 84 us
        assert!((80_000..100_000).contains(&big_inter));
    }

    #[test]
    fn eager_threshold() {
        let m = NetworkModel::default();
        assert!(m.is_eager(1024));
        assert!(!m.is_eager(1 << 20));
    }

    #[test]
    fn instant_is_free() {
        let m = NetworkModel::instant();
        assert_eq!(m.transfer_ns(1 << 30, false), 0);
    }
}
