//! The simulated cluster: clock + ranks + runtimes + teardown.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::nanos::runtime::RuntimeCosts;
use crate::nanos::{CompletionMode, Runtime, RuntimeConfig};
use crate::progress::{DeliveryMode, ProgressEngine};
use crate::sim::{Clock, ClockQueueKind, VNanos};
use crate::trace::{GraphRecorder, Tracer};

use super::comm::{Comm, UniState};
use super::match_engine::ContextQueues;
use super::net::NetworkModel;
use super::topology::{PlanStore, TopologyMode};

/// Shape and knobs of the simulated cluster.
#[derive(Clone)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub ranks_per_node: usize,
    /// Worker threads (virtual cores) per rank's task runtime.
    /// `0` means no task runtime (pure-MPI ranks).
    pub cores_per_rank: usize,
    pub net: NetworkModel,
    /// Polling-leader period (virtual ns).
    pub poll_interval: VNanos,
    pub tracer: Option<Arc<Tracer>>,
    pub graph: Option<Arc<GraphRecorder>>,
    /// Virtual-time budget; exceeding it aborts the run (hang detector).
    pub deadline: Option<VNanos>,
    /// Stack size for rank main threads.
    pub rank_stack: usize,
    /// Stack size for runtime worker threads.
    pub worker_stack: usize,
    /// Modeled runtime-operation costs (default: realistic Nanos6-class).
    pub costs: RuntimeCosts,
    /// How TAMPI is notified of MPI completions (default: callback
    /// continuations; `Polling` is the paper-faithful baseline).
    pub completion_mode: CompletionMode,
    /// How completion continuations are delivered (default: the sharded
    /// progress engine; `Direct` preserves the PR-1 inline-firing
    /// baseline). See [`crate::progress`].
    pub delivery_mode: DeliveryMode,
    /// How the collective schedule compiler sees the node hierarchy
    /// (default: `Hierarchical` — node-aware plans wherever the network
    /// model says they win; `Flat` reproduces the PR-3 schedules).
    /// See [`crate::rmpi::TopologyMode`].
    pub topology: TopologyMode,
    /// Whether compiled collective schedules persist per communicator
    /// (default `true`; `false` recompiles every call — the cold
    /// baseline of fig17's cache sweep).
    pub sched_cache: bool,
    /// Clock lanes the simulated ranks are sharded over (default 1 —
    /// the classic single-queue engine). Up to the node count, nodes
    /// are partitioned into contiguous blocks, one lane per block;
    /// beyond it, ranks are partitioned directly (finer-than-node
    /// lanes), which is legal because the conservative lookahead is a
    /// per-lane-pair matrix derived from the `NetworkModel` (intra-node
    /// wire latency for lanes sharing a node, inter-node otherwise).
    /// Results are bit-identical to 1 lane at equal seeds. Clamped to
    /// the rank count (to the node count when the intra-node latency is
    /// zero, e.g. [`NetworkModel::ideal`]). See [`crate::sim`].
    pub clock_shards: usize,
    /// Event-queue implementation of each clock lane (default
    /// [`ClockQueueKind::Calendar`]; `BinaryHeap` keeps the PR-6 engine
    /// selectable for A/B benchmarking — fig23 asserts they are
    /// bit-identical).
    pub clock_queue: ClockQueueKind,
    /// Span sink for the observability layer (default `None` — no span
    /// recording; the metrics registry runs regardless). Attaching one
    /// never changes results: emission sites only read virtual time.
    /// See [`crate::obs`].
    pub spans: Option<Arc<crate::obs::SpanSink>>,
    /// Fault & straggler injection plan (default `None` — fault-free;
    /// the injection hooks cost one `Option` branch each). The same
    /// plan on the same workload replays bit-identically. See
    /// [`crate::rmpi::faults`].
    pub faults: Option<super::faults::FaultsConfig>,
}

impl ClusterConfig {
    pub fn new(nodes: usize, ranks_per_node: usize, cores_per_rank: usize) -> Self {
        ClusterConfig {
            nodes,
            ranks_per_node,
            cores_per_rank,
            net: NetworkModel::default(),
            poll_interval: crate::sim::us(50),
            tracer: None,
            graph: None,
            deadline: None,
            rank_stack: 1024 * 1024,
            worker_stack: 512 * 1024,
            costs: RuntimeCosts::realistic(),
            completion_mode: CompletionMode::default(),
            delivery_mode: DeliveryMode::default(),
            topology: TopologyMode::default(),
            sched_cache: true,
            clock_shards: 1,
            clock_queue: ClockQueueKind::default(),
            spans: None,
            faults: None,
        }
    }

    /// Builder-style fault-plan attachment (bench/test convenience).
    pub fn with_faults(mut self, faults: super::faults::FaultsConfig) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Builder-style span-sink attachment (bench/test convenience).
    pub fn with_spans(mut self, sink: Arc<crate::obs::SpanSink>) -> Self {
        self.spans = Some(sink);
        self
    }

    /// Builder-style clock-shard override (bench/test convenience).
    pub fn with_clock_shards(mut self, shards: usize) -> Self {
        self.clock_shards = shards;
        self
    }

    /// Builder-style clock-queue override (bench/test convenience).
    pub fn with_clock_queue(mut self, queue: ClockQueueKind) -> Self {
        self.clock_queue = queue;
        self
    }

    /// Builder-style completion-mode override (bench/test convenience).
    pub fn with_completion_mode(mut self, mode: CompletionMode) -> Self {
        self.completion_mode = mode;
        self
    }

    /// Builder-style delivery-mode override (bench/test convenience).
    pub fn with_delivery_mode(mut self, mode: DeliveryMode) -> Self {
        self.delivery_mode = mode;
        self
    }

    /// Builder-style topology-mode override (bench/test convenience).
    pub fn with_topology(mut self, mode: TopologyMode) -> Self {
        self.topology = mode;
        self
    }

    /// Builder-style schedule-cache toggle (bench/test convenience).
    pub fn with_sched_cache(mut self, on: bool) -> Self {
        self.sched_cache = on;
        self
    }

    pub fn size(&self) -> usize {
        self.nodes * self.ranks_per_node
    }
}

/// Everything a rank's main function gets.
pub struct RankCtx {
    pub rank: usize,
    pub size: usize,
    pub node: usize,
    pub comm: Comm,
    /// Task runtime (None when `cores_per_rank == 0`).
    pub rt: Option<Runtime>,
    pub clock: Arc<Clock>,
}

/// Outcome of a completed run.
#[derive(Clone, Debug)]
pub struct RunStats {
    /// Virtual makespan: max over ranks of their finish time.
    pub vtime_ns: u64,
    /// Total tasks created across ranks.
    pub tasks: u64,
    /// Total task pauses (blocking-mode cost metric, Section 6.2).
    pub pauses: u64,
    /// Total worker threads ever spawned (cores + substitutes).
    pub workers: usize,
    /// Sharded-delivery batches drained across all shards (0 under
    /// [`DeliveryMode::Direct`]).
    pub delivery_batches: u64,
    /// Continuations delivered through shards (0 under `Direct`).
    pub deliveries: u64,
    /// Largest single shard batch (a same-instant completion wave).
    pub max_batch: u64,
    /// Scheduler queue-lock acquisitions that inserted task resumes,
    /// summed over ranks: O(resumes) under `Direct`, O(shard-batches)
    /// under `Sharded` — the serialization the progress engine removes.
    pub resume_lock_ops: u64,
    /// Ready-queue items stolen across workers' local deques.
    pub steals: u64,
    /// Failed steal probes (a victim deque locked and found empty) —
    /// the waste the adaptive last-victim steal order reduces.
    pub steal_probes: u64,
    /// External-event decrement operations applied to task counters:
    /// O(events) under `Direct`; under `Sharded` a drain coalesces all
    /// same-task decrements of one batch into a single `dec_events(n)`.
    pub event_dec_ops: u64,
    /// Persistent-schedule cache traffic, summed over ranks: a repeated
    /// same-shape collective should show `hits >= calls - 1` per rank
    /// (the MPI persistent-collective win; see `rmpi::topology`).
    pub sched_cache: SchedCacheStats,
    /// Plan compilation service counters: cluster-plan store traffic
    /// plus the compile-tier instrumentation (replay heap events, memo
    /// hits, closed-form hits). `misses` is the number of compiles that
    /// actually ran — O(1) per `SchedKey` cluster-wide, not O(ranks).
    pub plan_store: PlanStoreStats,
    /// Clock events fired across all lanes (simulator throughput).
    pub clock_events: u64,
    /// Same-instant clock batches fired across all lanes.
    pub clock_batches: u64,
    /// Events pushed into a clock lane other than the pusher's own
    /// (0 on a single-lane clock).
    pub cross_shard_events: u64,
    /// Staged cross-lane flush batches: each covers one lock
    /// acquisition and one notify for a whole group of same-batch
    /// events into one destination lane (0 on a single-lane clock).
    pub cross_shard_batches: u64,
    /// Allocation-reuse counters from the simulator's hot paths (the
    /// PR-10 allocation-free-hot-paths work): how often a pooled or
    /// scratch structure was reused instead of freshly allocated.
    pub alloc_reuse: AllocReuseStats,
    /// Host wall-clock time of the run in ns (setup through clock
    /// teardown) — the denominator of simulator throughput.
    pub elapsed_host_ns: u64,
    /// Fault-injection counters (`None` on fault-free runs). See
    /// [`crate::rmpi::faults::FaultStats`].
    pub faults: Option<super::faults::FaultStats>,
    /// Per-rank user-defined counters merged by key.
    pub counters: HashMap<String, u64>,
    /// Snapshot of the run's metrics registry: counters, gauges, and
    /// log2-bucket histograms (completion latency, port queueing delay,
    /// pause duration). Always populated; see [`crate::obs::metrics`].
    pub metrics: crate::obs::metrics::MetricsSnapshot,
}

/// Hot-path allocation-reuse counters (host-side diagnostics — reuse
/// never feeds virtual time; bit-identity is guarded by the clock-shard
/// tests regardless of pool hit rates).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocReuseStats {
    /// `ReqState` allocations satisfied from the thread-local recycle
    /// pool instead of a fresh `Arc` (see `rmpi::request`).
    pub req_states_recycled: u64,
    /// `Ports::resolve_due` passes that reused the thread-local due
    /// buffer's retained capacity instead of allocating.
    pub booking_scratch_reuses: u64,
    /// Collective rounds whose request set fit the inline small-vec
    /// (no spill allocation; see `rmpi::coll_schedule`).
    pub rounds_posted_inline: u64,
}

/// Cluster-wide schedule-cache counters (see
/// [`crate::rmpi::topology::SchedCache`]'s module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedCacheStats {
    /// Collective calls that reused a cached plan.
    pub hits: u64,
    /// Collective calls that compiled (and, cache permitting, stored)
    /// their plan.
    pub misses: u64,
}

/// Plan compilation service counters (see `rmpi::topology`'s module
/// docs, "three tiers"). All host-side diagnostics — never inputs to
/// virtual time; `hits`/`replay_memo_hits` depend on how concurrent
/// first calls interleave on the host, while `misses` (one compile per
/// distinct key, coalesced) is deterministic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanStoreStats {
    /// Store lookups satisfied by an already-compiled cluster plan.
    pub hits: u64,
    /// Store lookups that ran the compiler — one per distinct key.
    pub misses: u64,
    /// Candidate replays answered by the structural-digest memo.
    pub replay_memo_hits: u64,
    /// Event-heap pops spent in exact candidate replays.
    pub replay_events: u64,
    /// Candidate costs answered by a closed form instead of a replay.
    pub closed_form_hits: u64,
}

/// Why a run did not complete.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RunError {
    /// Quiescence with no pending events before all ranks finished —
    /// the Section 5 deadlock.
    Deadlock { vtime_ns: u64 },
    /// The virtual deadline elapsed (livelock / runaway).
    DeadlineExceeded { deadline_ns: u64 },
}

impl std::fmt::Display for RunError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunError::Deadlock { vtime_ns } => {
                write!(f, "global deadlock at t={} ns (Section 5 scenario)", vtime_ns)
            }
            RunError::DeadlineExceeded { deadline_ns } => {
                write!(f, "virtual deadline of {} ns exceeded", deadline_ns)
            }
        }
    }
}

impl std::error::Error for RunError {}

/// Handle used by rank code to bump named counters into [`RunStats`].
#[derive(Clone, Default)]
pub struct Counters(Arc<Mutex<HashMap<String, u64>>>);

impl Counters {
    pub fn add(&self, key: &str, v: u64) {
        *self.0.lock().unwrap().entry(key.to_string()).or_insert(0) += v;
    }
}

/// The simulated cluster. Build with [`Universe::run`].
pub struct Universe;

impl Universe {
    /// Run `f` as the main function of every rank and tear the cluster
    /// down. `f(ctx)` is executed on one thread per rank under virtual
    /// time. Returns the run statistics, or an error if the cluster
    /// deadlocked / overran its deadline (threads are leaked in that case
    /// — acceptable for tests, mirrors a hung MPI job being killed).
    pub fn run<F>(cfg: ClusterConfig, f: F) -> Result<RunStats, RunError>
    where
        F: Fn(&RankCtx) + Send + Sync + 'static,
    {
        Self::run_with_counters(cfg, move |ctx, _c| f(ctx))
    }

    /// Like [`Universe::run`], with a [`Counters`] sink for app metrics.
    pub fn run_with_counters<F>(cfg: ClusterConfig, f: F) -> Result<RunStats, RunError>
    where
        F: Fn(&RankCtx, &Counters) + Send + Sync + 'static,
    {
        let size = cfg.size();
        assert!(size > 0, "empty cluster");
        let host_start = std::time::Instant::now();
        // Shard the clock over contiguous rank blocks. Up to the node
        // count, lanes align with node blocks (cross-lane traffic is
        // then always inter-node); beyond it, ranks are split directly
        // and intra-node lane pairs are bounded by the intra-node wire
        // via the per-pair lookahead matrix below. A zero intra-node
        // latency (the ideal network) cannot bound an intra-node pair,
        // so lanes then clamp to node granularity as before.
        let max_shards = if cfg.net.intra_latency_ns == 0 { cfg.nodes } else { size };
        let shards = cfg.clock_shards.clamp(1, max_shards.max(1));

        let node_of: Vec<usize> = (0..size).map(|r| r / cfg.ranks_per_node).collect();
        let lane_of: Vec<usize> = (0..size)
            .map(|r| {
                if shards <= cfg.nodes {
                    node_of[r] * shards / cfg.nodes
                } else {
                    r * shards / size
                }
            })
            .collect();
        // Per-pair conservative lookahead: any event lane `a` creates in
        // lane `b` rides a wire — intra-node (when the lanes share a
        // node) or inter-node — and `transfer_ns` never undercuts the
        // wire's base latency, so the matrix below is a sound minimum.
        let lookahead: Vec<VNanos> = {
            let mut nodes_of_lane: Vec<std::collections::HashSet<usize>> =
                (0..shards).map(|_| std::collections::HashSet::new()).collect();
            for r in 0..size {
                nodes_of_lane[lane_of[r]].insert(node_of[r]);
            }
            let intra = cfg.net.intra_latency_ns.min(cfg.net.inter_latency_ns);
            let mut la = vec![0u64; shards * shards];
            for a in 0..shards {
                for b in 0..shards {
                    if a != b {
                        la[a * shards + b] = if nodes_of_lane[a].is_disjoint(&nodes_of_lane[b]) {
                            cfg.net.inter_latency_ns
                        } else {
                            intra
                        };
                    }
                }
            }
            la
        };
        let (clock, clock_handles) = Clock::start_lanes(shards, lookahead, cfg.clock_queue);
        clock.set_panic_on_deadlock(false);
        // Keep the clock pinned during setup: workers park before any rank
        // thread registers, which must not read as quiescence/deadlock.
        let setup_hold = clock.hold();
        let obs = crate::obs::RunObs::new(cfg.spans.clone());
        if obs.enabled() {
            // Clock-lane lookahead-wait spans (only worth the driver-loop
            // bookkeeping when a sink is attached).
            clock.set_obs(obs.clone());
        }
        // The plan compilation service registers its instruments
        // (plan_store_hits / plan_store_misses / plan_compile_ns) in
        // the run's metrics registry up front.
        let plan_store = PlanStore::new(&node_of, &cfg.net, cfg.topology, &obs.metrics);
        let faults = cfg
            .faults
            .as_ref()
            .filter(|f| f.enabled() || f.detector.is_some())
            .map(|f| Arc::new(super::faults::FaultState::new(f.clone(), size)));
        // Straggler ingress extras ride the same Ports law as the base
        // rx_ns — all zeros without a fault plan.
        let rx_extra = faults
            .as_ref()
            .map(|fs| fs.cfg.rx_extras(size))
            .unwrap_or_else(|| vec![0; size]);
        let uni = Arc::new(UniState {
            clock: clock.clone(),
            net: cfg.net,
            ports: crate::rmpi::net::Ports::new(
                size,
                &cfg.net,
                lane_of.clone(),
                rx_extra,
                obs.clone(),
            ),
            node_of,
            lane_of: lane_of.clone(),
            topology: cfg.topology,
            sched_cache_on: cfg.sched_cache,
            sched_hits: AtomicU64::new(0),
            sched_misses: AtomicU64::new(0),
            plan_store,
            contexts: Mutex::new(Vec::new()),
            dup_map: Mutex::new(HashMap::new()),
            progress: ProgressEngine::new(size, cfg.delivery_mode, cfg.tracer.clone()),
            tracer: cfg.tracer.clone(),
            obs: obs.clone(),
            faults: faults.clone(),
            shrink_map: Mutex::new(HashMap::new()),
            reuse_req_states: AtomicU64::new(0),
            reuse_rounds_inline: AtomicU64::new(0),
        });
        {
            // World communicator owns contexts 0 (p2p) and 1 (collectives).
            let mut g = uni.contexts.lock().unwrap();
            g.push(Arc::new(ContextQueues::new(size)));
            g.push(Arc::new(ContextQueues::new(size)));
        }

        // Per-rank task runtimes.
        let runtimes: Vec<Option<Runtime>> = (0..size)
            .map(|r| {
                if cfg.cores_per_rank == 0 {
                    None
                } else {
                    let mut rc = RuntimeConfig::new(cfg.cores_per_rank);
                    rc.poll_interval = cfg.poll_interval;
                    rc.label = format!("r{r}");
                    rc.rank = r as u32;
                    rc.clock_lane = lane_of[r];
                    rc.worker_stack = cfg.worker_stack;
                    rc.costs = cfg.costs;
                    rc.completion_mode = cfg.completion_mode;
                    rc.tracer = cfg.tracer.clone();
                    rc.graph = cfg.graph.clone();
                    rc.obs = Some(obs.clone());
                    Some(Runtime::new(clock.clone(), rc))
                }
            })
            .collect();

        let done = Arc::new(AtomicUsize::new(0));
        let finish_vtime = Arc::new(AtomicU64::new(0));
        let timed_out = Arc::new(AtomicBool::new(false));
        let panics: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let counters = Counters::default();
        let f = Arc::new(f);

        if let Some(dl) = cfg.deadline {
            // One flag event per lane: whichever lane's virtual time hits
            // the deadline first trips the (real-time-polled) flag, even
            // when the livelock is confined to a single lane.
            for lane in 0..clock.num_lanes() {
                let t = timed_out.clone();
                clock.call_at_on(lane, dl, move || {
                    t.store(true, Ordering::Release);
                });
            }
        }

        if let Some(fs) = &faults {
            if let Some(rf) = fs.cfg.rank_fail {
                // Death sweep, one event per lane at the death instant
                // (same per-lane pattern as the deadline flags): each
                // lane times out its own slice of the tracked-request
                // registry, so completions stay on their owners' lanes.
                for lane in 0..clock.num_lanes() {
                    let fs2 = fs.clone();
                    let ck = clock.clone();
                    clock.call_at_on(lane, rf.at_ns, move || {
                        fs2.sweep_dead(&ck, lane);
                    });
                }
            }
            if let Some(dl) = cfg.deadline {
                // The live detector needs the run deadline as its tick
                // horizon: an unbounded self-rescheduling tick would
                // keep lanes advancing forever and defeat virtual-time
                // deadlock detection. Without a deadline it stays off
                // (the post-run stall report still covers diagnosis).
                fs.install_detector(&clock, &lane_of, dl);
            }
        }

        let mut handles = Vec::with_capacity(size);
        for rank in 0..size {
            let ctx = RankCtx {
                rank,
                size,
                node: uni.node_of[rank],
                comm: Comm::world(uni.clone(), rank, size),
                rt: runtimes[rank].clone(),
                clock: clock.clone(),
            };
            let f = f.clone();
            let done = done.clone();
            let finish_vtime = finish_vtime.clone();
            let clock2 = clock.clone();
            let counters2 = counters.clone();
            let lane = lane_of[rank];
            // Activity credit for the new thread, on the lane it will run
            // under (the credit and the thread's debits must hit the same
            // lane's counter).
            clock.register_thread_on(lane);
            let panics2 = panics.clone();
            let h = std::thread::Builder::new()
                .name(format!("rank{rank}"))
                .stack_size(cfg.rank_stack)
                .spawn(move || {
                    Clock::bind_lane(lane);
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        if let Some(rt) = &ctx.rt {
                            rt.attach();
                        }
                        f(&ctx, &counters2);
                        if let Some(rt) = &ctx.rt {
                            // Quiesce this rank's tasks before declaring done.
                            rt.taskwait();
                            rt.detach();
                        }
                    }));
                    match result {
                        Ok(()) => {
                            finish_vtime.fetch_max(clock2.now(), Ordering::AcqRel);
                            done.fetch_add(1, Ordering::AcqRel);
                            clock2.deregister_thread();
                        }
                        Err(e) => {
                            let msg = e
                                .downcast_ref::<String>()
                                .cloned()
                                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                                .unwrap_or_else(|| "unknown panic".into());
                            panics2.lock().unwrap().push(format!("rank {rank}: {msg}"));
                            // Do not deregister: the sim state is broken;
                            // the orchestrator aborts the run below.
                        }
                    }
                })
                .expect("spawn rank thread");
            handles.push(h);
        }

        drop(setup_hold);

        // The orchestrating thread is *not* part of the simulation: poll
        // for completion in real time.
        let outcome = loop {
            {
                let p = panics.lock().unwrap();
                if !p.is_empty() {
                    // Propagate the first rank failure to the caller's
                    // thread (leaking the rest of the cluster, as a
                    // failed test/job would).
                    panic!("rank panicked: {}", p.join(" | "));
                }
            }
            let d = done.load(Ordering::Acquire);
            if d == size {
                break Ok(());
            }
            if timed_out.load(Ordering::Acquire) {
                break Err(RunError::DeadlineExceeded {
                    deadline_ns: cfg.deadline.unwrap(),
                });
            }
            if clock.deadlocked() {
                // Grace re-check: the last rank may have just finished.
                if done.load(Ordering::Acquire) == size {
                    break Ok(());
                }
                break Err(RunError::Deadlock { vtime_ns: clock.max_now() });
            }
            std::thread::sleep(Duration::from_micros(500));
        };

        match outcome {
            Ok(()) => {
                for h in handles {
                    h.join().expect("rank thread panicked");
                }
                for rt in runtimes.iter().flatten() {
                    rt.shutdown();
                }
                clock.stop();
                for h in clock_handles {
                    h.join().expect("clock thread panicked");
                }
                // Sample counters only after the clock thread exited:
                // its stop-drain may fire final-instant shard drains
                // (observer continuations only — every task settled
                // before its rank declared done), and scheduler and
                // engine counters must come from the same cut.
                let mut tasks = 0;
                let mut pauses = 0;
                let mut workers = 0;
                let mut resume_lock_ops = 0;
                let mut steals = 0;
                let mut steal_probes = 0;
                let mut event_dec_ops = 0;
                for rt in runtimes.iter().flatten() {
                    let (t, p, w) = rt.stats();
                    tasks += t;
                    pauses += p;
                    workers += w;
                    let (rl, _bulk, st, pr) = rt.sched_counters();
                    resume_lock_ops += rl;
                    steals += st;
                    steal_probes += pr;
                    event_dec_ops += rt.event_dec_ops();
                }
                let counters = counters.0.lock().unwrap().clone();
                let pstats = uni.progress.stats();
                let cc = clock.counters();
                Ok(RunStats {
                    vtime_ns: finish_vtime.load(Ordering::Acquire),
                    tasks,
                    pauses,
                    workers,
                    delivery_batches: pstats.batches,
                    deliveries: pstats.delivered,
                    max_batch: pstats.max_batch,
                    resume_lock_ops,
                    steals,
                    steal_probes,
                    event_dec_ops,
                    sched_cache: SchedCacheStats {
                        hits: uni.sched_hits.load(Ordering::Relaxed),
                        misses: uni.sched_misses.load(Ordering::Relaxed),
                    },
                    plan_store: {
                        let ps = &uni.plan_store;
                        let stats = PlanStoreStats {
                            hits: ps.hit_count(),
                            misses: ps.miss_count(),
                            replay_memo_hits: ps.stats.memo_hits(),
                            replay_events: ps.stats.replay_events(),
                            closed_form_hits: ps.stats.closed_form_hits(),
                        };
                        // Mirror the compile-tier counts into the
                        // registry before the snapshot below, so the
                        // metrics view carries the full service story.
                        obs.metrics.counter("plan_replay_memo_hits").add(stats.replay_memo_hits);
                        obs.metrics.counter("plan_replay_events").add(stats.replay_events);
                        obs.metrics.counter("plan_closed_form_hits").add(stats.closed_form_hits);
                        stats
                    },
                    clock_events: cc.events,
                    clock_batches: cc.batches,
                    cross_shard_events: cc.cross_lane,
                    cross_shard_batches: cc.cross_batches,
                    alloc_reuse: AllocReuseStats {
                        req_states_recycled: uni.reuse_req_states.load(Ordering::Relaxed),
                        booking_scratch_reuses: uni.ports.scratch_reuses(),
                        rounds_posted_inline: uni.reuse_rounds_inline.load(Ordering::Relaxed),
                    },
                    elapsed_host_ns: host_start.elapsed().as_nanos() as u64,
                    faults: faults.as_ref().map(|fs| fs.stats()),
                    counters,
                    metrics: obs.metrics.snapshot(),
                })
            }
            Err(e) => {
                // Leak the parked threads (the hung-job case); the clock
                // thread is also left behind intentionally.
                Err(e)
            }
        }
    }
}
