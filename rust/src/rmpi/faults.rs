//! Deterministic fault & straggler injection.
//!
//! Three injection families, all seed-replayable — the same
//! [`FaultsConfig`] on the same workload reproduces the same virtual
//! timeline bit for bit, on any clock-shard count:
//!
//! * **Rank failure** ([`RankFail`]): a rank dies at a configured
//!   virtual instant. Death is a *pure function* of the config and the
//!   current virtual time ([`FaultsConfig::dead_at`]), so every rank —
//!   on any clock lane — agrees on liveness without cross-lane reads.
//!   A per-lane sweep event fails the victim's outstanding requests at
//!   the death instant and times out survivors' requests against the
//!   victim `timeout_ns` later; both paths flow through the normal
//!   [`ReqState::complete`] machinery with [`ReqError::RankFailed`]
//!   attached, so `on_complete` continuations fire, TAMPI external
//!   events decrement, and task dependencies release exactly as for a
//!   successful completion.
//! * **Message drop + retransmit** ([`DropSpec`]): a per-message coin
//!   flip hashed from `(seed, src, dst, tag, seq)` — virtual time never
//!   enters the hash, so the decision replays even across refactors
//!   that shift timestamps. A dropped message is modeled as *one*
//!   retransmission after `retransmit_ns`: the original transmission is
//!   lost on the wire, the sender's (implicit) timer fires, and the
//!   retransmitted copy takes the normal [`Ports`] ingress path.
//!   Exactly-once delivery holds by construction — only the
//!   retransmitted copy is ever booked.
//! * **Stragglers** ([`Straggler`]): a persistent slow rank. Its
//!   ingress port charges `rx_extra_ns` extra per message (threaded
//!   through the [`Ports`] law, so queueing effects compound exactly as
//!   for the base `rx_ns`), and apps multiply their compute cost by
//!   `compute_mult`. The compiler's wire replay deliberately does *not*
//!   model straggler slowness — the compiler/engine cost-parity
//!   contract is scoped to fault-free runs — which is precisely why the
//!   live detector + avoid-mask feedback loop (below) exists.
//!
//! # Detection and feedback
//!
//! [`FaultState`] also hosts the *live* side of `trace/stalls.rs`: a
//! per-lane detector tick (scheduled on each clock lane, reading only
//! progress stamps written by that lane) raises suspicion bits and a
//! detection log. Control decisions never read another lane's gauges —
//! adaptation is agreed through a collective
//! (`Comm::detect_stragglers`), so the resulting avoid mask is
//! bit-identical on every rank and keys recompiled plans through
//! `SchedKey::avoid` (the PlanStore/SchedCache invalidation path).
//!
//! [`Ports`]: super::net::Ports
//! [`ReqState::complete`]: super::request::ReqState

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::sim::Clock;

use super::request::{ReqError, ReqState};

/// Default wait after a rank's death instant before survivors' requests
/// against it complete with [`ReqError::RankFailed`].
pub const DEFAULT_FAIL_TIMEOUT_NS: u64 = 100_000;

/// Default sender retransmission delay for dropped messages.
pub const DEFAULT_RETRANSMIT_NS: u64 = 50_000;

/// Default live-detector tick interval.
pub const DEFAULT_DETECT_INTERVAL_NS: u64 = 50_000;

/// Default no-progress window before the detector suspects a rank.
pub const DEFAULT_DETECT_THRESHOLD_NS: u64 = 200_000;

/// One rank dying at a virtual instant.
#[derive(Clone, Copy, Debug)]
pub struct RankFail {
    pub rank: usize,
    /// Virtual instant of death.
    pub at_ns: u64,
    /// Survivors' requests against the victim fail at `at_ns +
    /// timeout_ns` (the victim's own requests fail at `at_ns`).
    pub timeout_ns: u64,
}

/// Per-link message drop with retransmit-after-timeout.
#[derive(Clone, Copy, Debug)]
pub struct DropSpec {
    /// Drop probability in parts per million (1_000_000 = drop every
    /// message once).
    pub prob_ppm: u32,
    /// Sender retransmission delay: the surviving copy departs this
    /// many virtual nanoseconds after the original.
    pub retransmit_ns: u64,
}

/// A persistently slow rank.
#[derive(Clone, Copy, Debug)]
pub struct Straggler {
    pub rank: usize,
    /// Extra ingress-port service time per message delivered *to* this
    /// rank, on top of the model's `rx_ns`.
    pub rx_extra_ns: u64,
    /// Multiplier the apps apply to this rank's compute cost.
    pub compute_mult: u32,
}

/// Live-detector knobs (`trace/stalls.rs` grown onto the clock thread).
#[derive(Clone, Copy, Debug)]
pub struct DetectorConfig {
    /// Virtual time between detector ticks on each clock lane.
    pub interval_ns: u64,
    /// A rank that has started but shown no request completion for this
    /// long is suspected.
    pub threshold_ns: u64,
}

impl Default for DetectorConfig {
    fn default() -> Self {
        DetectorConfig {
            interval_ns: DEFAULT_DETECT_INTERVAL_NS,
            threshold_ns: DEFAULT_DETECT_THRESHOLD_NS,
        }
    }
}

/// The full injection plan. Identical on every rank (it rides on
/// `ClusterConfig`), which is what makes liveness queries and the
/// shrink agreement deterministic without cross-lane communication.
#[derive(Clone, Debug, Default)]
pub struct FaultsConfig {
    /// Seed for the per-message drop hash.
    pub seed: u64,
    pub rank_fail: Option<RankFail>,
    pub drop: Option<DropSpec>,
    pub stragglers: Vec<Straggler>,
    /// `Some`: install the per-lane live detector.
    pub detector: Option<DetectorConfig>,
}

impl FaultsConfig {
    pub fn new(seed: u64) -> FaultsConfig {
        FaultsConfig { seed, ..FaultsConfig::default() }
    }

    pub fn with_rank_fail(mut self, rank: usize, at_ns: u64) -> Self {
        self.rank_fail = Some(RankFail { rank, at_ns, timeout_ns: DEFAULT_FAIL_TIMEOUT_NS });
        self
    }

    pub fn with_drop(mut self, prob_ppm: u32) -> Self {
        self.drop = Some(DropSpec { prob_ppm, retransmit_ns: DEFAULT_RETRANSMIT_NS });
        self
    }

    pub fn with_straggler(mut self, rank: usize, rx_extra_ns: u64, compute_mult: u32) -> Self {
        self.stragglers.push(Straggler { rank, rx_extra_ns, compute_mult });
        self
    }

    pub fn with_detector(mut self) -> Self {
        self.detector = Some(DetectorConfig::default());
        self
    }

    /// Any injection active?
    pub fn enabled(&self) -> bool {
        self.rank_fail.is_some() || self.drop.is_some() || !self.stragglers.is_empty()
    }

    /// Is `rank` dead at virtual instant `t`? Pure — every rank and
    /// every lane computes the same answer from the shared config, so
    /// no cross-lane flag read (which would race inside the lookahead
    /// window) is ever needed.
    pub fn dead_at(&self, rank: usize, t: u64) -> bool {
        matches!(self.rank_fail, Some(f) if f.rank == rank && t >= f.at_ns)
    }

    /// Compute-cost multiplier for `rank` (1 = healthy).
    pub fn compute_mult(&self, rank: usize) -> u64 {
        self.stragglers
            .iter()
            .find(|s| s.rank == rank)
            .map(|s| s.compute_mult.max(1) as u64)
            .unwrap_or(1)
    }

    /// Extra ingress service time for messages delivered to `rank`.
    pub fn rx_extra(&self, rank: usize) -> u64 {
        self.stragglers.iter().find(|s| s.rank == rank).map(|s| s.rx_extra_ns).unwrap_or(0)
    }

    /// Per-rank ingress extras vector for [`Ports`] construction.
    ///
    /// [`Ports`]: super::net::Ports
    pub fn rx_extras(&self, size: usize) -> Vec<u64> {
        (0..size).map(|r| self.rx_extra(r)).collect()
    }
}

/// One live-detector verdict (diagnostics; sorted by `(t_ns, rank)` in
/// the final log).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Detection {
    pub t_ns: u64,
    pub rank: usize,
    pub kind: DetectionKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectionKind {
    /// No request completion within the detector threshold.
    Stalled,
    /// The rank's configured death instant passed (confirmed by the
    /// sweep event on its own lane).
    Dead,
}

impl DetectionKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            DetectionKind::Stalled => "stalled",
            DetectionKind::Dead => "dead",
        }
    }
}

/// Injection counters snapshot for `RunStats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Messages the drop hash selected (each was retransmitted once).
    pub drops: u64,
    /// Retransmissions performed (equals `drops` in this model).
    pub retransmits: u64,
    /// Requests completed with `RankFailed`.
    pub failed_reqs: u64,
    /// Live-detector verdicts recorded.
    pub detections: u64,
    /// Suspicion bitmask the detector raised (diagnostics only;
    /// control decisions use the agreed avoid mask).
    pub suspect_mask: u64,
    /// Union of avoid masks installed through the straggler-agreement
    /// collective (the control-plane decisions actually taken).
    pub agreed_avoid_mask: u64,
}

/// A request the death sweep may need to time out: registered at post
/// time (only when a rank failure is configured), swept on the owning
/// lane at the death instant.
struct Tracked {
    /// Clock lane the request completes on (its owner's lane).
    lane: usize,
    /// World rank that owns the request.
    owner: usize,
    /// World-rank peer (`None`: no single peer, e.g. a collective's
    /// outer request).
    peer: Option<usize>,
    req: Weak<ReqState>,
}

/// Runtime injection state, shared by every rank through `UniState`.
pub(crate) struct FaultState {
    pub cfg: FaultsConfig,
    pub drops: AtomicU64,
    pub retransmits: AtomicU64,
    pub failed_reqs: AtomicU64,
    /// Outstanding-request registry (empty unless `rank_fail` is set).
    tracked: Mutex<Vec<Tracked>>,
    /// Per-rank last-completion virtual instant, written by the owning
    /// rank's lane ([`FaultState::note_progress`]), read by that lane's
    /// detector tick.
    progress: Vec<AtomicU64>,
    /// Detector suspicion bits (rank < 64; diagnostics).
    suspects: AtomicU64,
    /// Union of agreement-collective avoid masks (control plane).
    agreed: AtomicU64,
    detections: Mutex<Vec<Detection>>,
}

impl FaultState {
    pub fn new(cfg: FaultsConfig, size: usize) -> FaultState {
        FaultState {
            cfg,
            drops: AtomicU64::new(0),
            retransmits: AtomicU64::new(0),
            failed_reqs: AtomicU64::new(0),
            tracked: Mutex::new(Vec::new()),
            progress: (0..size).map(|_| AtomicU64::new(0)).collect(),
            suspects: AtomicU64::new(0),
            agreed: AtomicU64::new(0),
            detections: Mutex::new(Vec::new()),
        }
    }

    /// Deterministic per-message drop decision: FNV-1a over
    /// `(seed, src, dst, tag, seq)`. Virtual time is deliberately
    /// excluded so the coin flip survives timing-shifting refactors.
    pub fn should_drop(&self, src: usize, dst: usize, tag: i32, seq: u64) -> bool {
        let Some(d) = self.cfg.drop else { return false };
        if d.prob_ppm == 0 {
            return false;
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for w in [self.cfg.seed, src as u64, dst as u64, tag as u32 as u64, seq] {
            for b in w.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        (h % 1_000_000) < d.prob_ppm as u64
    }

    /// Record a drop + its retransmission; returns the extra departure
    /// delay the surviving copy pays.
    pub fn note_drop(&self) -> u64 {
        self.drops.fetch_add(1, Ordering::Relaxed);
        self.retransmits.fetch_add(1, Ordering::Relaxed);
        self.cfg.drop.map(|d| d.retransmit_ns).unwrap_or(0)
    }

    /// Register an outstanding request for the death sweep. No-op
    /// unless a rank failure is configured.
    pub fn track(&self, lane: usize, owner: usize, peer: Option<usize>, req: &Arc<ReqState>) {
        if self.cfg.rank_fail.is_none() {
            return;
        }
        self.tracked.lock().unwrap().push(Tracked {
            lane,
            owner,
            peer,
            req: Arc::downgrade(req),
        });
    }

    /// Fail `req` at virtual instant `at` on its own lane unless it
    /// completed first. All of a request's completions run on its lane,
    /// so the `done` check inside the event is race-free.
    pub fn fail_at(
        self: &Arc<Self>,
        clock: &Arc<Clock>,
        lane: usize,
        at: u64,
        req: Weak<ReqState>,
        failed_rank: usize,
    ) {
        let fs = Arc::clone(self);
        let ck = Arc::clone(clock);
        clock.call_at_on(lane, at, move || {
            let Some(req) = req.upgrade() else { return };
            if req.is_completed() {
                return;
            }
            fs.failed_reqs.fetch_add(1, Ordering::Relaxed);
            req.complete_failed(&ck, ReqError::RankFailed { rank: failed_rank });
        });
    }

    /// The death sweep for one lane, run at the victim's death instant:
    /// the victim's own requests on this lane fail now; survivors'
    /// requests against the victim fail after the configured timeout.
    /// Requests posted *after* the death instant are handled at post
    /// time (`dead_at` is already true there), so every request is
    /// failed exactly once.
    pub fn sweep_dead(self: &Arc<Self>, clock: &Arc<Clock>, lane: usize) {
        let Some(f) = self.cfg.rank_fail else { return };
        let entries: Vec<(usize, Weak<ReqState>, u64)> = {
            let tracked = self.tracked.lock().unwrap();
            tracked
                .iter()
                .filter(|t| t.lane == lane)
                .filter_map(|t| {
                    if t.owner == f.rank {
                        Some((f.rank, t.req.clone(), f.at_ns))
                    } else if t.peer == Some(f.rank) {
                        Some((f.rank, t.req.clone(), f.at_ns + f.timeout_ns))
                    } else {
                        None
                    }
                })
                .collect()
        };
        for (failed_rank, req, at) in entries {
            self.fail_at(clock, lane, at, req, failed_rank);
        }
        if lane == 0 {
            self.detections.lock().unwrap().push(Detection {
                t_ns: f.at_ns,
                rank: f.rank,
                kind: DetectionKind::Dead,
            });
        }
    }

    /// Stamp a completion for `rank` at virtual instant `t` (the live
    /// detector's progress gauge). Monotonic; written on the rank's own
    /// lane by the completion machinery.
    pub fn note_progress(&self, rank: usize, t: u64) {
        if rank < self.progress.len() {
            self.progress[rank].fetch_max(t.max(1), Ordering::Relaxed);
        }
    }

    /// Install the per-lane live detector: a self-rescheduling tick on
    /// each clock lane that inspects only the progress gauges of ranks
    /// bound to that lane. Lane-local reads are exactly ordered against
    /// that lane's completions, so detections replay deterministically;
    /// collective-finish stamps may land a tick late (they run on
    /// worker threads), which can shift a *diagnostic* verdict but
    /// never a control decision — those go through the agreement
    /// collective.
    pub fn install_detector(
        self: &Arc<Self>,
        clock: &Arc<Clock>,
        lane_of: &[usize],
        deadline: u64,
    ) {
        let Some(d) = self.cfg.detector else { return };
        let interval = d.interval_ns.max(1);
        for lane in 0..clock.num_lanes() {
            let ranks: Vec<usize> =
                (0..lane_of.len()).filter(|&r| lane_of[r] == lane).collect();
            if ranks.is_empty() {
                continue;
            }
            schedule_tick(self, clock, lane, interval, ranks, d.threshold_ns, deadline);
        }
    }

    /// Detector suspicion mask (diagnostics).
    pub fn suspect_mask(&self) -> u64 {
        self.suspects.load(Ordering::Relaxed)
    }

    /// Record an avoid mask agreed through `Comm::detect_stragglers`
    /// (every rank calls with the identical mask; the union is what
    /// `RunStats` reports).
    pub fn note_agreed_mask(&self, mask: u64) {
        self.agreed.fetch_or(mask, Ordering::Relaxed);
    }

    /// The detection log, sorted by `(t_ns, rank)`.
    pub fn detections(&self) -> Vec<Detection> {
        let mut v = self.detections.lock().unwrap().clone();
        v.sort_by_key(|d| (d.t_ns, d.rank));
        v
    }

    /// Counters snapshot for `RunStats`.
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            drops: self.drops.load(Ordering::Relaxed),
            retransmits: self.retransmits.load(Ordering::Relaxed),
            failed_reqs: self.failed_reqs.load(Ordering::Relaxed),
            detections: self.detections.lock().unwrap().len() as u64,
            suspect_mask: self.suspect_mask(),
            agreed_avoid_mask: self.agreed.load(Ordering::Relaxed),
        }
    }
}

/// One detector tick on `lane` at `k * interval`: suspect every
/// started-but-silent rank, then reschedule until the deadline (ticks
/// must not outlive the run — an unbounded self-rescheduling event
/// would defeat virtual-time deadlock detection).
fn schedule_tick(
    fs: &Arc<FaultState>,
    clock: &Arc<Clock>,
    lane: usize,
    interval: u64,
    ranks: Vec<usize>,
    threshold: u64,
    deadline: u64,
) {
    let fs2 = Arc::clone(fs);
    let ck = Arc::clone(clock);
    let at = clock.now().saturating_add(interval);
    if at >= deadline {
        return;
    }
    clock.call_at_on(lane, at, move || {
        for &r in &ranks {
            let last = fs2.progress[r].load(Ordering::Relaxed);
            if last == 0 || fs2.cfg.dead_at(r, at) {
                continue;
            }
            if at.saturating_sub(last) > threshold {
                let bit = 1u64 << (r.min(63));
                if fs2.suspects.fetch_or(bit, Ordering::Relaxed) & bit == 0 {
                    fs2.detections.lock().unwrap().push(Detection {
                        t_ns: at,
                        rank: r,
                        kind: DetectionKind::Stalled,
                    });
                }
            }
        }
        schedule_tick(&fs2, &ck, lane, interval, ranks, threshold, deadline);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_decision_is_deterministic_and_seeded() {
        let mut cfg = FaultsConfig::new(7).with_drop(500_000);
        let fs = FaultState::new(cfg.clone(), 4);
        let a: Vec<bool> = (0..64).map(|s| fs.should_drop(0, 1, 5, s)).collect();
        let fs2 = FaultState::new(cfg.clone(), 4);
        let b: Vec<bool> = (0..64).map(|s| fs2.should_drop(0, 1, 5, s)).collect();
        assert_eq!(a, b, "same seed, same coin flips");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "ppm 500k mixes both");
        cfg.seed = 8;
        let fs3 = FaultState::new(cfg, 4);
        let c: Vec<bool> = (0..64).map(|s| fs3.should_drop(0, 1, 5, s)).collect();
        assert_ne!(a, c, "different seed, different flips");
    }

    #[test]
    fn dead_at_is_a_pure_threshold() {
        let cfg = FaultsConfig::new(0).with_rank_fail(2, 1000);
        assert!(!cfg.dead_at(2, 999));
        assert!(cfg.dead_at(2, 1000));
        assert!(cfg.dead_at(2, u64::MAX));
        assert!(!cfg.dead_at(1, u64::MAX));
    }

    #[test]
    fn straggler_lookups() {
        let cfg = FaultsConfig::new(0).with_straggler(3, 2500, 4);
        assert_eq!(cfg.rx_extra(3), 2500);
        assert_eq!(cfg.rx_extra(0), 0);
        assert_eq!(cfg.compute_mult(3), 4);
        assert_eq!(cfg.compute_mult(1), 1);
        assert_eq!(cfg.rx_extras(4), vec![0, 0, 0, 2500]);
    }
}
