//! Message matching: posted-receive + unexpected-message queues with MPI
//! ordering semantics (first match in posting/arrival order).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::sim::Clock;

use super::net::Booking;
use super::request::{ReqState, Status};

/// Raw destination buffer of a posted receive. The receiver guarantees the
/// buffer outlives the request (MPI contract).
pub(crate) struct RecvBuf {
    pub ptr: *mut u8,
    pub len: usize,
}
// SAFETY: the buffer is only written while the receive request is pending,
// during which the owning thread may not touch it (MPI contract).
unsafe impl Send for RecvBuf {}

pub(crate) struct PostedRecv {
    pub src: Option<usize>,
    pub tag: Option<i32>,
    pub buf: RecvBuf,
    pub req: Arc<ReqState>,
}

pub(crate) struct Envelope {
    pub src: usize,
    pub tag: i32,
    /// Eagerly-copied payload.
    pub data: Box<[u8]>,
    /// Ingress-port slot of this message: resolves to the delivery
    /// deadline (arrival + serialized receiver processing, see
    /// [`crate::rmpi::net::ports`]).
    pub booking: Booking,
    /// Rendezvous/ssend: the sender's request completes at delivery.
    pub sender_req: Option<Arc<ReqState>>,
    /// Flow id tying this message's delivery back to its send point in
    /// the exported trace (0 = no flow; see [`crate::obs::fid`]).
    pub flow: u64,
}

#[derive(Default)]
pub(crate) struct DstQueues {
    pub posted: VecDeque<PostedRecv>,
    pub unexpected: VecDeque<Envelope>,
}

/// Matching state of one communicator context: one queue pair per
/// destination rank.
pub(crate) struct ContextQueues {
    pub dst: Vec<Mutex<DstQueues>>,
}

impl ContextQueues {
    pub fn new(size: usize) -> Self {
        ContextQueues {
            dst: (0..size).map(|_| Mutex::new(DstQueues::default())).collect(),
        }
    }
}

fn matches(psrc: Option<usize>, ptag: Option<i32>, src: usize, tag: i32) -> bool {
    psrc.map(|s| s == src).unwrap_or(true) && ptag.map(|t| t == tag).unwrap_or(true)
}

/// Complete a matched delivery at the message's port deadline: parks on
/// the envelope's [`Booking`] until the ingress port has assigned it
/// (`ready`), then completes both requests at `max(ready, now)` — the
/// actual delivery instant when the receive was posted after the
/// message was already processed.
///
/// Completion runs [`ReqState::complete`], which wakes parked waiters
/// *and* fires any attached continuations (`Request::on_complete`) — on
/// this thread for already-processed payloads, or on the clock thread
/// via `Clock::call_at` for in-flight ones. Both paths deliver at the
/// exact virtual completion instant, which is what gives TAMPI's
/// callback mode zero notification latency. With `rx_ns == 0` the
/// booking is pre-resolved to the arrival instant, so this is exactly
/// the pre-port delivery timeline.
/// On a sharded clock the two completions are routed to their owning
/// ranks' lanes (`ReqState::lane`), so the wakes stay intra-lane: the
/// receive completes on the receiver's lane, and a rendezvous sender
/// completion is pushed into the sender's lane as a cross-shard event —
/// the zero-latency feedback path whose in-flight window is covered by
/// a clock feedback obligation (registered at send time in
/// `Comm::isend_ctx`, released here once the event is in the sender
/// lane's heap). With a single lane both route inline/at-`ready` on the
/// one lane, exactly the classic timeline.
fn complete_at_deadline(
    clock: &Arc<Clock>,
    booking: Booking,
    status: Status,
    req: Arc<ReqState>,
    sender: Option<Arc<ReqState>>,
    flow: u64,
) {
    let clock = clock.clone();
    booking.on_ready(move |ready| {
        // The virtual completion instant: the port deadline, or the
        // match instant when the receive was posted after the message
        // was already processed (the caller's lane is then the
        // receiver's own lane, so `now()` is the match instant).
        let t_c = ready.max(clock.now());
        // Delivery point on the receiver's port track, closing the
        // send→recv flow arrow. Emitted before the completions below
        // (same virtual instant; emission only reads time).
        if flow != 0 {
            if let Some((obs, rank)) = req.obs_stamp() {
                if obs.enabled() {
                    obs.record(
                        crate::obs::Span::point(
                            crate::obs::Track::Port { rank },
                            crate::obs::SpanKind::Deliver,
                            t_c,
                            "deliver",
                            flow,
                        )
                        .with_flow_in(flow),
                    );
                }
            }
        }
        let recv_lane = req.lane();
        match sender {
            None => {
                let c = clock.clone();
                clock.run_at_on(recv_lane, t_c, move || {
                    req.complete(&c, Some(status));
                });
            }
            Some(s) if s.lane() == recv_lane => {
                // Co-located (or unrouted) pair: one event, both
                // completions at the same instant — the classic shape.
                let c = clock.clone();
                clock.run_at_on(recv_lane, t_c, move || {
                    req.complete(&c, Some(status));
                    s.complete(&c, None);
                });
            }
            Some(s) => {
                let send_lane = s.lane();
                let c = clock.clone();
                clock.run_at_on(recv_lane, t_c, move || {
                    req.complete(&c, Some(status));
                });
                let c2 = clock.clone();
                clock.run_at_on(send_lane, t_c, move || {
                    s.complete(&c2, None);
                });
                // The sender-lane event is in its heap: the feedback
                // obligation registered at send time can be released.
                if let (Some(r), Some(sn)) = (recv_lane, send_lane) {
                    clock.end_feedback(r, sn);
                }
            }
        }
    });
}

/// Deliver a matched (envelope, posted-recv) pair: copy now (invisible
/// to the receiver until completion), complete both requests at the
/// port deadline (see [`complete_at_deadline`]).
pub(crate) fn deliver(
    clock: &Arc<Clock>,
    env: Envelope,
    posted: PostedRecv,
) {
    assert!(
        env.data.len() <= posted.buf.len,
        "message truncation: {} bytes into {}-byte buffer (src {} tag {})",
        env.data.len(),
        posted.buf.len,
        env.src,
        env.tag
    );
    // SAFETY: RecvBuf contract (see above).
    unsafe {
        std::ptr::copy_nonoverlapping(env.data.as_ptr(), posted.buf.ptr, env.data.len());
    }
    let status = Status {
        source: env.src as i32,
        tag: env.tag,
        bytes: env.data.len(),
    };
    complete_at_deadline(clock, env.booking, status, posted.req, env.sender_req, env.flow);
}

/// Direct delivery (send fast path): the payload goes straight from the
/// sender's buffer into the posted receive — no envelope allocation
/// (§Perf opt-3). Completion semantics (including continuation firing)
/// identical to [`deliver`].
pub(crate) fn deliver_direct(
    clock: &Arc<Clock>,
    bytes: &[u8],
    src: usize,
    tag: i32,
    booking: Booking,
    sender_req: Option<Arc<ReqState>>,
    posted: PostedRecv,
    flow: u64,
) {
    assert!(
        bytes.len() <= posted.buf.len,
        "message truncation: {} bytes into {}-byte buffer (src {src} tag {tag})",
        bytes.len(),
        posted.buf.len,
    );
    // SAFETY: RecvBuf contract (see above).
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), posted.buf.ptr, bytes.len());
    }
    let status = Status { source: src as i32, tag, bytes: bytes.len() };
    complete_at_deadline(clock, booking, status, posted.req, sender_req, flow);
}

impl DstQueues {
    /// Send fast path: pop the first posted receive matching (src, tag),
    /// if any.
    pub fn match_posted(&mut self, src: usize, tag: i32) -> Option<PostedRecv> {
        let pos = self
            .posted
            .iter()
            .position(|p| matches(p.src, p.tag, src, tag))?;
        self.posted.remove(pos)
    }

    /// An envelope arrives: match against posted receives (post order) or
    /// queue as unexpected. Returns the matched posted receive, if any.
    pub fn arrive(&mut self, env: Envelope) -> Option<(Envelope, PostedRecv)> {
        if let Some(pos) = self
            .posted
            .iter()
            .position(|p| matches(p.src, p.tag, env.src, env.tag))
        {
            let posted = self.posted.remove(pos).unwrap();
            Some((env, posted))
        } else {
            self.unexpected.push_back(env);
            None
        }
    }

    /// A receive is posted: match against unexpected messages (arrival
    /// order) or queue it.
    pub fn post(&mut self, p: PostedRecv) -> Option<(Envelope, PostedRecv)> {
        if let Some(pos) = self
            .unexpected
            .iter()
            .position(|e| matches(p.src, p.tag, e.src, e.tag))
        {
            let env = self.unexpected.remove(pos).unwrap();
            Some((env, p))
        } else {
            self.posted.push_back(p);
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(src: usize, tag: i32) -> Envelope {
        Envelope {
            src,
            tag,
            data: vec![0u8; 4].into_boxed_slice(),
            booking: Booking::resolved(0),
            sender_req: None,
            flow: 0,
        }
    }

    fn posted(src: Option<usize>, tag: Option<i32>, slot: &mut [u8]) -> PostedRecv {
        PostedRecv {
            src,
            tag,
            buf: RecvBuf { ptr: slot.as_mut_ptr(), len: slot.len() },
            req: Arc::new(ReqState::default()),
        }
    }

    #[test]
    fn unexpected_then_post_matches_in_arrival_order() {
        let mut q = DstQueues::default();
        assert!(q.arrive(env(0, 7)).is_none());
        assert!(q.arrive(env(0, 7)).is_none());
        let mut b = [0u8; 8];
        let m = q.post(posted(Some(0), Some(7), &mut b));
        assert!(m.is_some());
        assert_eq!(q.unexpected.len(), 1);
    }

    #[test]
    fn wildcard_src_and_tag() {
        let mut q = DstQueues::default();
        q.arrive(env(3, 9));
        let mut b = [0u8; 8];
        assert!(q.post(posted(None, None, &mut b)).is_some());
    }

    #[test]
    fn posted_matched_in_post_order() {
        let mut q = DstQueues::default();
        let mut b1 = [0u8; 8];
        let mut b2 = [0u8; 8];
        assert!(q.post(posted(None, Some(1), &mut b1)).is_none());
        assert!(q.post(posted(Some(0), None, &mut b2)).is_none());
        // tag 1 from rank 0 matches the *first* posted recv.
        let m = q.arrive(env(0, 1)).unwrap();
        assert_eq!(m.1.tag, Some(1));
        assert_eq!(q.posted.len(), 1);
    }

    #[test]
    fn no_match_on_wrong_tag() {
        let mut q = DstQueues::default();
        let mut b = [0u8; 8];
        q.post(posted(Some(0), Some(5), &mut b));
        assert!(q.arrive(env(0, 6)).is_none());
        assert_eq!(q.posted.len(), 1);
        assert_eq!(q.unexpected.len(), 1);
    }
}
