//! TAMPI — the Task-Aware MPI library (Section 6).
//!
//! Two interoperability mechanisms between `rmpi` and the `nanos` runtime:
//!
//! * **Blocking mode** (Section 6.1, enabled by requesting
//!   [`crate::rmpi::ThreadLevel::TaskMultiple`]): blocking MPI calls made
//!   inside a task are transparently transformed into their non-blocking
//!   counterparts; if not immediately complete, the task pauses,
//!   releasing its core, and resumes when the operations completed.
//!   This is the `MPI_Recv` flow of Fig 3.
//! * **Non-blocking mode** (Section 6.2): [`Tampi::iwait`] /
//!   [`Tampi::iwaitall`] bind in-flight requests to the calling task's
//!   dependency release through the external-events API; the task finishes
//!   without waiting, its stack is freed, and its successors run only when
//!   the requests complete.  This is the `TAMPI_Iwait` flow of Fig 4.
//!
//! Both modes coexist (Section 6.2).
//!
//! Collectives are intercepted too (Section 6.1), on both surfaces:
//! blocking collectives inside tasks pause once on the schedule engine's
//! final request ([`Tampi::barrier`]/[`Tampi::allreduce`]), and the
//! non-blocking [`Tampi::ibarrier`]/[`Tampi::ibcast`]/
//! [`Tampi::iallreduce`]/[`Tampi::ialltoallv`] bind a
//! [`crate::rmpi::CollRequest`]'s completion to the calling task's
//! dependency release through the external-events API — the `MPI_I*` +
//! `TAMPI_Iwait` fusion. The collective's rounds advance on the progress
//! engine either way (see `rmpi::coll_schedule`).
//!
//! In the real TAMPI these flows hide behind the PMPI interception layer;
//! here [`Tampi`] is an explicit wrapper handle over a [`Comm`], which is
//! the same integration surface without symbol interposition.
//!
//! ## Completion notification pipeline
//!
//! *How* the library learns that an in-flight operation completed is
//! selectable per runtime ([`CompletionMode`], default `Callback`; set
//! `RuntimeConfig::completion_mode` / `ClusterConfig::completion_mode`,
//! or override per handle with [`init_with_mode`]):
//!
//! * [`CompletionMode::Polling`] — the paper-faithful Section 6 baseline:
//!   every pending operation files a *ticket* (request + blocking context
//!   or event counter) in a shared vector, and a polling service re-scans
//!   that vector under a mutex on every pass — the leader tick plus
//!   opportunistic idle-worker passes (Section 4.5). O(pending) work per
//!   pass; completion latency is bounded by `poll_interval`. Preserved
//!   for reproducing the paper's figures.
//! * [`CompletionMode::Callback`] — request continuations (the MPI
//!   Continuations line of work: Schuchart et al., *"Callback-based
//!   Completion Notification using MPI Continuations"*, 2021): each
//!   pending request gets a continuation attached via
//!   [`crate::rmpi::Request::on_complete`] that unblocks the paused task
//!   or fulfils the external event directly at the virtual instant the
//!   operation completes. No tickets, no scan, no polling service, no
//!   polling latency. Multi-request waits share an atomic countdown so
//!   the last completing request performs the single unblock; a request
//!   that completes before its continuation is attached runs the
//!   continuation inline, which `block_current_task` absorbs as an
//!   early-unblock.
//!
//! Each delivered notification is traced as
//! [`EventKind::CompletionDelivered`] and counted per pipeline
//! ([`Tampi::mode_stats`]), so benches and traces can compare the two.
//!
//! ## Error-carrying completions
//!
//! Under fault injection ([`crate::rmpi::faults`]) a request can finish
//! in the *failed* state — [`crate::rmpi::ReqError::RankFailed`] when a
//! peer died before matching. A failed completion is still a
//! completion: `Request::test()` flips true, `on_complete`
//! continuations fire, and external-event counters decrement — so both
//! pipelines above unblock paused tasks and release successor
//! dependencies identically whether the operation succeeded or its peer
//! is dead. Nothing hangs; the *error* travels with the request instead
//! of stalling the schedule. Blocking-mode callers that need the
//! verdict use [`Tampi::wait_result`] / [`Tampi::waitall_result`];
//! non-blocking (`iwait`) callers inspect `Request::result()` from a
//! successor task. This is what lets an application observe
//! `RankFailed`, call [`crate::rmpi::Comm::comm_shrink`], and continue
//! on the survivors.
//!
//! ## Delivery: direct vs sharded
//!
//! Orthogonal to *how completions are discovered* (the pipeline above)
//! is *how continuation firings reach the scheduler*
//! ([`crate::progress::DeliveryMode`], default `Sharded`, carried by
//! `ClusterConfig::delivery_mode`). Under `Direct` (the PR-1 baseline)
//! each continuation fires inline at the completion point and each task
//! resume takes the scheduler lock individually; under `Sharded` the
//! continuations TAMPI attaches here are deposited into the owning
//! rank's completion shard, drained in same-instant batches (traced as
//! `EventKind::BatchDelivered`), and their resumes bulk-enqueued — one
//! scheduler-lock acquisition per shard-batch, which is what keeps an
//! alltoallv completion wave from serializing on one mutex. Both modes
//! are observationally identical to tasks (same statuses, same virtual
//! times); `mode_stats` counts deliveries the same way in both.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::nanos::runtime::Rt;
use crate::nanos::{self, BlockingContext, CompletionMode, EventCounter, Runtime};
use crate::rmpi::{Comm, Pod, Request, Status, ThreadLevel};
use crate::trace::EventKind;

/// A pending operation the polling service watches
/// ([`CompletionMode::Polling`] only; the callback pipeline has no
/// tickets).
enum Ticket {
    /// Blocking mode: unblock the paused task when all requests complete.
    Block { reqs: Vec<Request>, ctx: BlockingContext },
    /// Non-blocking mode: fulfil one external event per completed request.
    Event { req: Request, ec: EventCounter },
}

struct TampiState {
    /// Runtime owning the polling service (weak: the registry's closure
    /// holds this state, so a strong handle would cycle).
    rt: std::sync::Weak<Rt>,
    /// Which notification pipeline this handle uses.
    mode: CompletionMode,
    /// Polling mode only: pending tickets re-scanned by the service.
    tickets: Mutex<Vec<Ticket>>,
    /// Metrics for the evaluation (Section 7): how many operations took
    /// each path, and how many completed immediately.
    n_block: AtomicU64,
    n_event: AtomicU64,
    n_immediate: AtomicU64,
    /// Completions delivered by the poll-scan (polling mode).
    n_poll_delivered: AtomicU64,
    /// Completions delivered by request continuations (callback mode).
    n_callback_delivered: AtomicU64,
}

impl TampiState {
    /// Record one completion notification reaching the runtime and emit
    /// the [`EventKind::CompletionDelivered`] trace event, stamped on the
    /// delivering thread's lane (a worker for inline/poll deliveries,
    /// the clock thread for deferred network deliveries).
    fn record_delivery(&self, by_callback: bool, label: &str, task_id: u64) {
        if by_callback {
            self.n_callback_delivered.fetch_add(1, Ordering::Relaxed);
        } else {
            self.n_poll_delivered.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(rt) = self.rt.upgrade() {
            // Off-worker threads (the clock thread for deferred
            // deliveries, the polling leader, rank mains) carry the
            // worker_id sentinel usize::MAX, recorded as u32::MAX —
            // see `trace::Record::worker`. Lane-building consumers
            // ignore CompletionDelivered records entirely.
            let w = crate::nanos::worker::worker_id();
            rt.trace(EventKind::CompletionDelivered, w, label, task_id);
        }
    }

    /// One polling pass (the paper's `Interop::poll`, Figs 3-4).
    fn poll(&self) {
        let mut retired = 0usize;
        let mut g = self.tickets.lock().unwrap();
        g.retain(|t| {
            let done = match t {
                Ticket::Block { reqs, ctx } => {
                    if reqs.iter().all(|r| r.test()) {
                        self.record_delivery(false, &ctx.0.task_label, ctx.0.task_id);
                        nanos::unblock_task(ctx);
                        true
                    } else {
                        false
                    }
                }
                Ticket::Event { req, ec } => {
                    if req.test() {
                        self.record_delivery(false, &ec.0.label, ec.0.id);
                        nanos::decrease_task_event_counter(ec, 1);
                        true
                    } else {
                        false
                    }
                }
            };
            if done {
                retired += 1;
            }
            !done
        });
        drop(g);
        if retired > 0 {
            if let Some(rt) = self.rt.upgrade() {
                rt.polling.hint_sub(retired);
            }
        }
    }

    /// File a ticket and bump the leader's pending-work hint.
    fn push_ticket(&self, t: Ticket) {
        self.tickets.lock().unwrap().push(t);
        if let Some(rt) = self.rt.upgrade() {
            rt.polling.hint_add(1, &rt);
        }
    }
}

/// The Task-Aware MPI handle of one rank.
#[derive(Clone)]
pub struct Tampi {
    comm: Comm,
    state: Arc<TampiState>,
    enabled: bool,
}

/// Initialize TAMPI on this rank (the `MPI_Init_thread` moment, Fig 6),
/// using the runtime's configured completion mode.
///
/// Requesting [`ThreadLevel::TaskMultiple`] enables both interoperability
/// mechanisms; anything lower yields plain MPI behaviour
/// (`enabled() == false`), which is what portable applications test for
/// to decide whether to serialize communication tasks with a sentinel
/// (Section 6.3).
pub fn init(comm: &Comm, rt: &Runtime, requested: ThreadLevel) -> Tampi {
    init_with_mode(comm, rt, requested, rt.completion_mode())
}

/// Like [`init`], overriding the runtime's configured
/// [`CompletionMode`] — used by benches and tests comparing the two
/// notification pipelines on one cluster configuration.
///
/// In polling mode this registers the ticket-scan service with the
/// rank's runtime (hinted: with no tickets in flight the leader parks).
/// In callback mode no service is registered at all — completions are
/// pushed by request continuations.
pub fn init_with_mode(
    comm: &Comm,
    rt: &Runtime,
    requested: ThreadLevel,
    mode: CompletionMode,
) -> Tampi {
    let enabled = requested == ThreadLevel::TaskMultiple;
    let state = Arc::new(TampiState {
        rt: rt.downgrade(),
        mode,
        tickets: Mutex::new(Vec::new()),
        n_block: AtomicU64::new(0),
        n_event: AtomicU64::new(0),
        n_immediate: AtomicU64::new(0),
        n_poll_delivered: AtomicU64::new(0),
        n_callback_delivered: AtomicU64::new(0),
    });
    if enabled && mode == CompletionMode::Polling {
        let st = state.clone();
        // Hinted: the pending-ticket count drives the leader; with no
        // tickets in flight the leader parks (zero polling events).
        rt.register_polling_service_hinted(
            "tampi",
            Box::new(move || {
                st.poll();
                false // permanent service
            }),
        );
    }
    Tampi { comm: comm.clone(), state, enabled }
}

impl Tampi {
    /// The thread level actually granted.
    pub fn level(&self) -> ThreadLevel {
        if self.enabled {
            ThreadLevel::TaskMultiple
        } else {
            ThreadLevel::Multiple
        }
    }

    /// Whether task-aware interoperability is active (Fig 6's check).
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Which completion-notification pipeline this handle uses.
    pub fn mode(&self) -> CompletionMode {
        self.state.mode
    }

    /// How this handle's universe delivers completion continuations
    /// (see [`crate::progress::DeliveryMode`]).
    pub fn delivery(&self) -> crate::progress::DeliveryMode {
        self.comm.delivery_mode()
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    fn in_task(&self) -> bool {
        nanos::api::in_task()
    }

    /// (immediate completions, blocking-path operations, event-path
    /// operations).
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.state.n_immediate.load(Ordering::Relaxed),
            self.state.n_block.load(Ordering::Relaxed),
            self.state.n_event.load(Ordering::Relaxed),
        )
    }

    /// Per-pipeline delivery counts: (retired by the poll-scan, delivered
    /// by request continuations). Covers the intercepted point-to-point
    /// primitives and `iwait`/`iwaitall` event bindings; the internal
    /// waits of task-aware collectives are not counted (they run through
    /// [`task_aware_wait_all`], which has no handle state).
    pub fn mode_stats(&self) -> (u64, u64) {
        (
            self.state.n_poll_delivered.load(Ordering::Relaxed),
            self.state.n_callback_delivered.load(Ordering::Relaxed),
        )
    }

    /// Pause the current task until all `reqs` complete (blocking-mode
    /// core; the generic form of Fig 3 used by every intercepted call).
    fn block_on(&self, reqs: Vec<Request>) {
        let pending: Vec<Request> = reqs.into_iter().filter(|r| !r.test()).collect();
        if pending.is_empty() {
            self.state.n_immediate.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.state.n_block.fetch_add(1, Ordering::Relaxed);
        let ctx = nanos::get_current_blocking_context();
        match self.state.mode {
            CompletionMode::Polling => {
                self.state.push_ticket(Ticket::Block { reqs: pending, ctx: ctx.clone() });
            }
            CompletionMode::Callback => {
                attach_countdown_unblock(&pending, &ctx, Some(&self.state));
            }
        }
        nanos::block_current_task(&ctx);
    }

    // ----- blocking mode (Section 6.1): intercepted blocking primitives -----

    /// Task-aware `MPI_Recv` (Fig 3): inside a task with TAMPI enabled the
    /// call becomes irecv + test + notify + pause; otherwise PMPI_Recv.
    pub fn recv<T: Pod>(&self, buf: &mut [T], src: i32, tag: i32) -> Status {
        if !self.enabled || !self.in_task() {
            return self.comm.recv(buf, src, tag);
        }
        self.trace_mpi(true, "recv");
        let t0 = self.mpi_span_begin();
        let r = self.comm.irecv(buf, src, tag);
        if !r.test() {
            self.block_on(vec![r.clone()]);
        } else {
            self.state.n_immediate.fetch_add(1, Ordering::Relaxed);
        }
        self.mpi_span_end(t0, "recv");
        self.trace_mpi(false, "recv");
        r.status()
    }

    /// Task-aware `MPI_Send`.
    pub fn send<T: Pod>(&self, buf: &[T], dst: usize, tag: i32) {
        if !self.enabled || !self.in_task() {
            return self.comm.send(buf, dst, tag);
        }
        self.trace_mpi(true, "send");
        let t0 = self.mpi_span_begin();
        let r = self.comm.isend(buf, dst, tag);
        self.block_on(vec![r]);
        self.mpi_span_end(t0, "send");
        self.trace_mpi(false, "send");
    }

    /// Task-aware `MPI_Ssend`.
    pub fn ssend<T: Pod>(&self, buf: &[T], dst: usize, tag: i32) {
        if !self.enabled || !self.in_task() {
            return self.comm.ssend(buf, dst, tag);
        }
        self.trace_mpi(true, "ssend");
        let t0 = self.mpi_span_begin();
        let r = self.comm.issend(buf, dst, tag);
        self.block_on(vec![r]);
        self.mpi_span_end(t0, "ssend");
        self.trace_mpi(false, "ssend");
    }

    /// Task-aware `MPI_Wait`.
    pub fn wait(&self, req: &Request) {
        if !self.enabled || !self.in_task() {
            return req.wait(self.comm.clock());
        }
        self.block_on(vec![req.clone()]);
    }

    /// Task-aware `MPI_Waitall`.
    pub fn waitall(&self, reqs: &[Request]) {
        if !self.enabled || !self.in_task() {
            return Request::wait_all(self.comm.clock(), reqs);
        }
        self.block_on(reqs.to_vec());
    }

    /// [`Tampi::wait`] that surfaces the completion verdict: `Ok` with
    /// the status on success, `Err(RankFailed)` when fault injection
    /// killed the peer. The task unblocks either way (see the module's
    /// "Error-carrying completions"); this is the accessor that makes
    /// the error observable without touching raw request internals.
    pub fn wait_result(&self, req: &Request) -> Result<Status, crate::rmpi::ReqError> {
        self.wait(req);
        req.result()
    }

    /// [`Tampi::waitall`] returning the first failed request's error,
    /// if any completed with one. All requests are waited on regardless
    /// — a failure does not abandon its siblings.
    pub fn waitall_result(&self, reqs: &[Request]) -> Result<(), crate::rmpi::ReqError> {
        self.waitall(reqs);
        for r in reqs {
            if let Some(e) = r.error() {
                return Err(e);
            }
        }
        Ok(())
    }

    /// Task-aware `MPI_Barrier` (collectives are intercepted too,
    /// Section 6.1). The schedule engine drives the rounds; the task
    /// pauses once on the collective's final request, using this
    /// handle's completion mode.
    pub fn barrier(&self) {
        if !self.enabled || !self.in_task() {
            return self.comm.barrier();
        }
        let wm = crate::rmpi::collectives::WaitMode::TaskAware(Some(self.state.mode));
        self.comm.barrier_with(wm);
    }

    /// Task-aware `MPI_Allreduce`. (For an op marked with
    /// [`crate::rmpi::commutative`], use [`Tampi::allreduce_op`].)
    pub fn allreduce<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) {
        self.allreduce_op(buf, op)
    }

    /// [`Tampi::allreduce`] over any [`crate::rmpi::Combiner`]: a
    /// [`crate::rmpi::commutative`]-marked op re-roots its combine tree
    /// through the topology compiler here too.
    pub fn allreduce_op<T: Pod>(&self, buf: &mut [T], op: impl crate::rmpi::Combiner<T>) {
        if !self.enabled || !self.in_task() {
            return self.comm.allreduce_op(buf, op);
        }
        let wm = crate::rmpi::collectives::WaitMode::TaskAware(Some(self.state.mode));
        self.comm.allreduce_op_with(buf, op, wm);
    }

    // ----- non-blocking collectives (Section 6.1 interception extended
    // ----- to the request-returning MPI_I* collectives + TAMPI_Iwait) --

    /// Task-aware `MPI_Ibarrier` + `TAMPI_Iwait` fusion: bind the
    /// barrier's completion to the calling task's dependency release and
    /// return immediately. Outside a task (or with interop disabled)
    /// this degrades to the blocking barrier, like the paper's PMPI
    /// fallback.
    pub fn ibarrier(&self) {
        if !self.enabled || !self.in_task() {
            return self.comm.barrier();
        }
        let cr = self.comm.ibarrier();
        self.iwait(cr.request());
    }

    /// Task-aware `MPI_Ibcast` + `TAMPI_Iwait`: the buffer may only be
    /// consumed by successor tasks (released when the bcast completes).
    pub fn ibcast<T: Pod>(&self, buf: &mut [T], root: usize) {
        if !self.enabled || !self.in_task() {
            return self.comm.bcast(buf, root);
        }
        let cr = self.comm.ibcast(buf, root);
        self.iwait(cr.request());
    }

    /// Task-aware `MPI_Iallreduce` + `TAMPI_Iwait` (Fig 4's flow over a
    /// collective): the task finishes without waiting; its dependencies
    /// release when the engine-driven allreduce completes.
    pub fn iallreduce<T: Pod>(
        &self,
        buf: &mut [T],
        op: impl Fn(&mut [T], &[T]) + Send + 'static,
    ) {
        self.iallreduce_op(buf, op)
    }

    /// [`Tampi::iallreduce`] over any [`crate::rmpi::Combiner`].
    pub fn iallreduce_op<T: Pod>(&self, buf: &mut [T], op: impl crate::rmpi::Combiner<T>) {
        if !self.enabled || !self.in_task() {
            return self.comm.allreduce_op(buf, op);
        }
        let cr = self.comm.iallreduce_op(buf, op);
        self.iwait(cr.request());
    }

    /// Task-aware `MPI_Igather` + `TAMPI_Iwait`: the root's receive
    /// buffer (and the leaf's chunk) may only be consumed by successor
    /// tasks. The schedule runs the topology compiler's plan — leader-
    /// staged when the node hierarchy pays (see `rmpi::topology`).
    pub fn igather<T: Pod>(&self, send: &[T], recv: Option<&mut [T]>, root: usize) {
        if !self.enabled || !self.in_task() {
            return self.comm.gather(send, recv, root);
        }
        let cr = self.comm.igather(send, recv, root);
        self.iwait(cr.request());
    }

    /// Task-aware `MPI_Ialltoall` + `TAMPI_Iwait` (uniform blocks; the
    /// leader-staged hierarchical plan applies here too).
    pub fn ialltoall<T: Pod>(&self, send: &[T], recv: &mut [T]) {
        if !self.enabled || !self.in_task() {
            return self.comm.alltoall(send, recv);
        }
        let cr = self.comm.ialltoall(send, recv);
        self.iwait(cr.request());
    }

    /// Task-aware `MPI_Ialltoallv` + `TAMPI_Iwait`.
    #[allow(clippy::too_many_arguments)]
    pub fn ialltoallv<T: Pod>(
        &self,
        send: &[T],
        scounts: &[usize],
        sdispls: &[usize],
        recv: &mut [T],
        rcounts: &[usize],
        rdispls: &[usize],
    ) {
        if !self.enabled || !self.in_task() {
            return self.comm.alltoallv(
                send,
                scounts,
                sdispls,
                recv,
                rcounts,
                rdispls,
                crate::rmpi::collectives::WaitMode::Park,
            );
        }
        let cr = self.comm.ialltoallv(send, scounts, sdispls, recv, rcounts, rdispls);
        self.iwait(cr.request());
    }

    // ----- non-blocking mode (Section 6.2): TAMPI_Iwait / TAMPI_Iwaitall -----

    /// `TAMPI_Iwait` (Fig 4): asynchronously bind `req` to the calling
    /// task's dependency release. Returns immediately; the buffers tied to
    /// `req` may only be consumed by successor tasks.
    pub fn iwait(&self, req: &Request) {
        self.iwaitall(std::slice::from_ref(req));
    }

    /// `TAMPI_Iwaitall` (Fig 5).
    pub fn iwaitall(&self, reqs: &[Request]) {
        if !self.enabled || !self.in_task() {
            // Paper fallback: PMPI_Waitall.
            return Request::wait_all(self.comm.clock(), reqs);
        }
        let pending: Vec<&Request> = reqs.iter().filter(|r| !r.test()).collect();
        if pending.is_empty() {
            self.state.n_immediate.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let ec = nanos::get_current_event_counter();
        // Bind the events BEFORE attaching/filing: a continuation may
        // fire inline (its request completed concurrently), and the
        // decrease must never precede the increase.
        nanos::increase_current_task_event_counter(&ec, pending.len() as u32);
        for r in pending {
            self.state.n_event.fetch_add(1, Ordering::Relaxed);
            match self.state.mode {
                CompletionMode::Polling => {
                    self.state
                        .push_ticket(Ticket::Event { req: (*r).clone(), ec: ec.clone() });
                }
                CompletionMode::Callback => {
                    let st = self.state.clone();
                    let ec = ec.clone();
                    r.on_complete(move |_| {
                        st.record_delivery(true, &ec.0.label, ec.0.id);
                        nanos::decrease_task_event_counter(&ec, 1);
                    });
                }
            }
        }
    }

    fn trace_mpi(&self, start: bool, what: &str) {
        nanos::api::trace_current(
            if start { EventKind::MpiStart } else { EventKind::MpiEnd },
            what,
        );
    }

    /// Start of an intercepted blocking call's in-task window (span
    /// bookkeeping only; `None` when span recording is off).
    fn mpi_span_begin(&self) -> Option<u64> {
        if self.comm.uni.obs.enabled() {
            Some(self.comm.uni.clock.now())
        } else {
            None
        }
    }

    /// End of the window opened by [`Tampi::mpi_span_begin`]: one
    /// `MpiCall` interval on the calling worker's track.
    fn mpi_span_end(&self, t0: Option<u64>, what: &'static str) {
        let Some(t0) = t0 else { return };
        let wid = crate::nanos::worker::worker_id();
        let w = if wid == usize::MAX { u32::MAX } else { wid as u32 };
        let id = crate::nanos::worker::current().map_or(0, |(_, task)| task.id);
        self.comm.uni.obs.record(crate::obs::Span::interval(
            crate::obs::Track::Worker { rank: self.comm.rank as u32, worker: w },
            crate::obs::SpanKind::MpiCall,
            t0,
            self.comm.uni.clock.now(),
            what,
            id,
        ));
    }
}

/// Callback-pipeline core: attach a shared-countdown continuation to
/// every pending request; the last completing request performs the
/// single unblock. A request that completed between the caller's
/// pending-filter and this attach runs its continuation inline on the
/// calling thread; if that makes the countdown hit zero here, the early
/// unblock is consumed by the caller's `block_current_task` (no pause
/// happens). `state`, when present, records the delivery for
/// [`Tampi::mode_stats`] and the `CompletionDelivered` trace.
fn attach_countdown_unblock(
    pending: &[Request],
    ctx: &BlockingContext,
    state: Option<&Arc<TampiState>>,
) {
    let remaining = Arc::new(AtomicUsize::new(pending.len()));
    for r in pending {
        let remaining = remaining.clone();
        let ctx = ctx.clone();
        let st = state.cloned();
        r.on_complete(move |_| {
            if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                if let Some(st) = &st {
                    st.record_delivery(true, &ctx.0.task_label, ctx.0.task_id);
                }
                nanos::unblock_task(&ctx);
            }
        });
    }
}

/// Task-aware waitall used by collective algorithms running under
/// [`crate::rmpi::collectives::WaitMode::TaskAware`]. Outside a task this
/// degrades to a parking wait.
///
/// Uses the runtime's configured [`CompletionMode`]: continuations with a
/// shared countdown (callback mode), or a transient one-shot polling
/// service (polling mode; works even without a [`Tampi`] handle).
pub fn task_aware_wait_all(comm: &Comm, reqs: &[Request]) {
    task_aware_wait_all_with(comm, reqs, None)
}

/// [`task_aware_wait_all`] with an optional completion-mode override
/// (`Some` pins the pipeline — used by `WaitMode::TaskAware` waits issued
/// through a [`Tampi`] handle so per-handle overrides govern collectives
/// too; `None` follows the runtime's configured mode).
pub(crate) fn task_aware_wait_all_with(
    comm: &Comm,
    reqs: &[Request],
    mode_override: Option<CompletionMode>,
) {
    if !nanos::api::in_task() {
        return Request::wait_all(comm.clock(), reqs);
    }
    let pending: Vec<Request> = reqs.iter().filter(|r| !r.test()).cloned().collect();
    if pending.is_empty() {
        return;
    }
    let rt = nanos::api::current_runtime().expect("task without runtime");
    let ctx = nanos::get_current_blocking_context();
    match mode_override.unwrap_or_else(|| rt.completion_mode()) {
        CompletionMode::Callback => {
            // No TampiState here: collective internal waits are not
            // counted in mode_stats (see its docs) — this path also
            // serves WaitMode::TaskAware users without any handle.
            attach_countdown_unblock(&pending, &ctx, None);
        }
        CompletionMode::Polling => {
            let ctx2 = ctx.clone();
            let reqs2 = pending.clone();
            rt.register_polling_service(
                "tampi-collective-wait",
                Box::new(move || {
                    if reqs2.iter().all(|r| r.test()) {
                        nanos::unblock_task(&ctx2);
                        true // one-shot: unregister
                    } else {
                        false
                    }
                }),
            );
        }
    }
    nanos::block_current_task(&ctx);
}
