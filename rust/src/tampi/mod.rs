//! TAMPI — the Task-Aware MPI library (Section 6).
//!
//! Two interoperability mechanisms between `rmpi` and the `nanos` runtime:
//!
//! * **Blocking mode** (Section 6.1, enabled by requesting
//!   [`crate::rmpi::ThreadLevel::TaskMultiple`]): blocking MPI calls made
//!   inside a task are transparently transformed into their non-blocking
//!   counterparts; if not immediately complete, a *ticket* (request +
//!   blocking context) is filed and the task pauses, releasing its core.
//!   A polling service tests pending tickets and unblocks tasks whose
//!   operations completed.  This is the `MPI_Recv` flow of Fig 3.
//! * **Non-blocking mode** (Section 6.2): [`Tampi::iwait`] /
//!   [`Tampi::iwaitall`] bind in-flight requests to the calling task's
//!   dependency release through the external-events API; the task finishes
//!   without waiting, its stack is freed, and its successors run only when
//!   the requests complete.  This is the `TAMPI_Iwait` flow of Fig 4.
//!
//! Both modes coexist (Section 6.2) and both rely on one polling service
//! registered with the rank's runtime.
//!
//! In the real TAMPI these flows hide behind the PMPI interception layer;
//! here [`Tampi`] is an explicit wrapper handle over a [`Comm`], which is
//! the same integration surface without symbol interposition.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::nanos::{
    self, BlockingContext, EventCounter, Runtime,
};
use crate::rmpi::{Comm, Pod, Request, Status, ThreadLevel};
use crate::trace::EventKind;

/// A pending operation the polling service watches.
enum Ticket {
    /// Blocking mode: unblock the paused task when all requests complete.
    Block { reqs: Vec<Request>, ctx: BlockingContext },
    /// Non-blocking mode: fulfil one external event per completed request.
    Event { req: Request, ec: EventCounter },
}

struct TampiState {
    /// Runtime owning the polling service (weak: the registry's closure
    /// holds this state, so a strong handle would cycle).
    rt: std::sync::Weak<crate::nanos::runtime::Rt>,
    tickets: Mutex<Vec<Ticket>>,
    /// Metrics for the evaluation (Section 7): how many tickets took each
    /// path, and how many operations completed immediately.
    n_block_tickets: AtomicU64,
    n_event_tickets: AtomicU64,
    n_immediate: AtomicU64,
}

impl TampiState {
    /// One polling pass (the paper's `Interop::poll`, Figs 3-4).
    fn poll(&self) {
        let mut retired = 0usize;
        let mut g = self.tickets.lock().unwrap();
        g.retain(|t| {
            let done = match t {
                Ticket::Block { reqs, ctx } => {
                    if reqs.iter().all(|r| r.test()) {
                        nanos::unblock_task(ctx);
                        true
                    } else {
                        false
                    }
                }
                Ticket::Event { req, ec } => {
                    if req.test() {
                        nanos::decrease_task_event_counter(ec, 1);
                        true
                    } else {
                        false
                    }
                }
            };
            if done {
                retired += 1;
            }
            !done
        });
        drop(g);
        if retired > 0 {
            if let Some(rt) = self.rt.upgrade() {
                rt.polling.hint_sub(retired);
            }
        }
    }

    /// File a ticket and bump the leader's pending-work hint.
    fn push_ticket(&self, t: Ticket) {
        self.tickets.lock().unwrap().push(t);
        if let Some(rt) = self.rt.upgrade() {
            rt.polling.hint_add(1, &rt);
        }
    }
}

/// The Task-Aware MPI handle of one rank.
#[derive(Clone)]
pub struct Tampi {
    comm: Comm,
    state: Arc<TampiState>,
    enabled: bool,
}

/// Initialize TAMPI on this rank (the `MPI_Init_thread` moment, Fig 6).
///
/// Requesting [`ThreadLevel::TaskMultiple`] enables both interoperability
/// mechanisms and registers the polling service with the rank's runtime;
/// anything lower yields plain MPI behaviour (`enabled() == false`), which
/// is what portable applications test for to decide whether to serialize
/// communication tasks with a sentinel (Section 6.3).
pub fn init(comm: &Comm, rt: &Runtime, requested: ThreadLevel) -> Tampi {
    let enabled = requested == ThreadLevel::TaskMultiple;
    let state = Arc::new(TampiState {
        rt: rt.downgrade(),
        tickets: Mutex::new(Vec::new()),
        n_block_tickets: AtomicU64::new(0),
        n_event_tickets: AtomicU64::new(0),
        n_immediate: AtomicU64::new(0),
    });
    if enabled {
        let st = state.clone();
        // Hinted: the pending-ticket count drives the leader; with no
        // tickets in flight the leader parks (zero polling events).
        rt.register_polling_service_hinted("tampi", Box::new(move || {
            st.poll();
            false // permanent service
        }));
    }
    Tampi { comm: comm.clone(), state, enabled }
}

impl Tampi {
    /// The thread level actually granted.
    pub fn level(&self) -> ThreadLevel {
        if self.enabled {
            ThreadLevel::TaskMultiple
        } else {
            ThreadLevel::Multiple
        }
    }

    /// Whether task-aware interoperability is active (Fig 6's check).
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    fn in_task(&self) -> bool {
        nanos::api::in_task()
    }

    /// (immediate completions, blocking tickets, event tickets).
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.state.n_immediate.load(Ordering::Relaxed),
            self.state.n_block_tickets.load(Ordering::Relaxed),
            self.state.n_event_tickets.load(Ordering::Relaxed),
        )
    }

    /// Pause the current task until all `reqs` complete (blocking-mode
    /// core; the generic form of Fig 3 used by every intercepted call).
    fn block_on(&self, reqs: Vec<Request>) {
        let pending: Vec<Request> = reqs.into_iter().filter(|r| !r.test()).collect();
        if pending.is_empty() {
            self.state.n_immediate.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.state.n_block_tickets.fetch_add(1, Ordering::Relaxed);
        let ctx = nanos::get_current_blocking_context();
        self.state
            .push_ticket(Ticket::Block { reqs: pending, ctx: ctx.clone() });
        nanos::block_current_task(&ctx);
    }

    // ----- blocking mode (Section 6.1): intercepted blocking primitives -----

    /// Task-aware `MPI_Recv` (Fig 3): inside a task with TAMPI enabled the
    /// call becomes irecv + test + ticket + pause; otherwise PMPI_Recv.
    pub fn recv<T: Pod>(&self, buf: &mut [T], src: i32, tag: i32) -> Status {
        if !self.enabled || !self.in_task() {
            return self.comm.recv(buf, src, tag);
        }
        self.trace_mpi(true, "recv");
        let r = self.comm.irecv(buf, src, tag);
        if !r.test() {
            self.block_on(vec![r.clone()]);
        } else {
            self.state.n_immediate.fetch_add(1, Ordering::Relaxed);
        }
        self.trace_mpi(false, "recv");
        r.status()
    }

    /// Task-aware `MPI_Send`.
    pub fn send<T: Pod>(&self, buf: &[T], dst: usize, tag: i32) {
        if !self.enabled || !self.in_task() {
            return self.comm.send(buf, dst, tag);
        }
        self.trace_mpi(true, "send");
        let r = self.comm.isend(buf, dst, tag);
        self.block_on(vec![r]);
        self.trace_mpi(false, "send");
    }

    /// Task-aware `MPI_Ssend`.
    pub fn ssend<T: Pod>(&self, buf: &[T], dst: usize, tag: i32) {
        if !self.enabled || !self.in_task() {
            return self.comm.ssend(buf, dst, tag);
        }
        self.trace_mpi(true, "ssend");
        let r = self.comm.issend(buf, dst, tag);
        self.block_on(vec![r]);
        self.trace_mpi(false, "ssend");
    }

    /// Task-aware `MPI_Wait`.
    pub fn wait(&self, req: &Request) {
        if !self.enabled || !self.in_task() {
            return req.wait(self.comm.clock());
        }
        self.block_on(vec![req.clone()]);
    }

    /// Task-aware `MPI_Waitall`.
    pub fn waitall(&self, reqs: &[Request]) {
        if !self.enabled || !self.in_task() {
            return Request::wait_all(self.comm.clock(), reqs);
        }
        self.block_on(reqs.to_vec());
    }

    /// Task-aware `MPI_Barrier` (collectives are intercepted too).
    pub fn barrier(&self) {
        if !self.enabled || !self.in_task() {
            return self.comm.barrier();
        }
        self.comm.barrier_with(crate::rmpi::collectives::WaitMode::TaskAware);
    }

    /// Task-aware `MPI_Allreduce`.
    pub fn allreduce<T: Pod>(&self, buf: &mut [T], op: impl Fn(&mut [T], &[T])) {
        if !self.enabled || !self.in_task() {
            return self.comm.allreduce(buf, op);
        }
        self.comm
            .allreduce_with(buf, op, crate::rmpi::collectives::WaitMode::TaskAware);
    }

    // ----- non-blocking mode (Section 6.2): TAMPI_Iwait / TAMPI_Iwaitall -----

    /// `TAMPI_Iwait` (Fig 4): asynchronously bind `req` to the calling
    /// task's dependency release. Returns immediately; the buffers tied to
    /// `req` may only be consumed by successor tasks.
    pub fn iwait(&self, req: &Request) {
        if !self.enabled || !self.in_task() {
            // Paper fallback: PMPI_Wait.
            return req.wait(self.comm.clock());
        }
        if req.test() {
            self.state.n_immediate.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let ec = nanos::get_current_event_counter();
        nanos::increase_current_task_event_counter(&ec, 1);
        self.state.n_event_tickets.fetch_add(1, Ordering::Relaxed);
        self.state.push_ticket(Ticket::Event { req: req.clone(), ec });
    }

    /// `TAMPI_Iwaitall` (Fig 5).
    pub fn iwaitall(&self, reqs: &[Request]) {
        if !self.enabled || !self.in_task() {
            return Request::wait_all(self.comm.clock(), reqs);
        }
        let pending: Vec<&Request> = reqs.iter().filter(|r| !r.test()).collect();
        if pending.is_empty() {
            self.state.n_immediate.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let ec = nanos::get_current_event_counter();
        nanos::increase_current_task_event_counter(&ec, pending.len() as u32);
        for r in pending {
            self.state.n_event_tickets.fetch_add(1, Ordering::Relaxed);
            self.state
                .push_ticket(Ticket::Event { req: (*r).clone(), ec: ec.clone() });
        }
    }

    fn trace_mpi(&self, start: bool, what: &str) {
        nanos::api::trace_current(
            if start { EventKind::MpiStart } else { EventKind::MpiEnd },
            what,
        );
    }
}

/// Task-aware waitall used by collective algorithms running under
/// [`crate::rmpi::collectives::WaitMode::TaskAware`]. Outside a task this
/// degrades to a parking wait.
pub fn task_aware_wait_all(comm: &Comm, reqs: &[Request]) {
    if !nanos::api::in_task() {
        return Request::wait_all(comm.clock(), reqs);
    }
    let pending: Vec<Request> = reqs.iter().filter(|r| !r.test()).cloned().collect();
    if pending.is_empty() {
        return;
    }
    // A transient ticket served by a self-registered one-shot polling
    // service on the current runtime (works even without a Tampi handle).
    let rt = nanos::api::current_runtime().expect("task without runtime");
    let ctx = nanos::get_current_blocking_context();
    let ctx2 = ctx.clone();
    let reqs2 = pending.clone();
    rt.register_polling_service(
        "tampi-collective-wait",
        Box::new(move || {
            if reqs2.iter().all(|r| r.test()) {
                nanos::unblock_task(&ctx2);
                true // one-shot: unregister
            } else {
                false
            }
        }),
    );
    nanos::block_current_task(&ctx);
}
