//! Gauss-Seidel heat-equation solver — the paper's main benchmark
//! (Section 7.1), in six versions:
//!
//! | version           | parallelism            | MPI style                  |
//! |-------------------|------------------------|----------------------------|
//! | `PureMpi`         | 1 rank/core, seq.      | blocking send/recv         |
//! | `NBuffer`         | 1 rank/core, seq.      | isend/irecv + wait / block |
//! | `ForkJoin`        | tasks, per-iter sync   | blocking, funneled         |
//! | `Sentinel`        | tasks, full dep graph  | blocking inside tasks, serialized by a sentinel dep |
//! | `InteropBlk`      | tasks, full dep graph  | blocking inside tasks via TAMPI (MPI_TASK_MULTIPLE) |
//! | `InteropNonBlk`   | tasks, full dep graph  | isend/irecv + TAMPI_Iwait(all) |
//!
//! The 2-D domain (`rows x cols` interior, top boundary held at 1.0) is
//! split into `block x block` blocks; MPI ranks own horizontal bands of
//! block rows. Within a block the update is the classic in-place sweep
//!
//! ```text
//! u[i][j] = 0.25 * (u[i-1][j] + u[i+1][j] + u[i][j-1] + u[i][j+1])
//! ```
//!
//! which uses NEW values above/left and OLD values below/right — the exact
//! recurrence the Pallas kernel implements. All versions perform the same
//! arithmetic in an equivalent order, so (with the native backend) their
//! f32 grids are identical cell-for-cell; tests assert the checksums
//! agree to reduction-order rounding.

use std::sync::Arc;

use crate::nanos::{self, DepObj, Mode};
use crate::rmpi::universe::RunError;
use crate::rmpi::{ClusterConfig, RankCtx, RunStats, ThreadLevel, Universe};
use crate::rmpi::universe::Counters;
use crate::sim::VNanos;
use crate::tampi::{self, Tampi};
use crate::trace::{GraphRecorder, Tracer};

use super::store::BlockStore;
use super::{gs_cost, Compute, DEFAULT_GS_CELL_NS};

/// The six implementations of Section 7.1.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum GsVersion {
    PureMpi,
    NBuffer,
    ForkJoin,
    Sentinel,
    InteropBlk,
    InteropNonBlk,
}

impl GsVersion {
    pub fn all() -> [GsVersion; 6] {
        [
            GsVersion::PureMpi,
            GsVersion::NBuffer,
            GsVersion::ForkJoin,
            GsVersion::Sentinel,
            GsVersion::InteropBlk,
            GsVersion::InteropNonBlk,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            GsVersion::PureMpi => "pure-mpi",
            GsVersion::NBuffer => "nbuffer-mpi",
            GsVersion::ForkJoin => "fork-join",
            GsVersion::Sentinel => "sentinel",
            GsVersion::InteropBlk => "interop-blk",
            GsVersion::InteropNonBlk => "interop-nonblk",
        }
    }

    pub fn parse(s: &str) -> Option<GsVersion> {
        GsVersion::all().into_iter().find(|v| v.name() == s)
    }

    /// Hybrid versions run 1 rank per node with a task runtime; pure
    /// versions run 1 rank per core with no runtime.
    pub fn is_hybrid(self) -> bool {
        !matches!(self, GsVersion::PureMpi | GsVersion::NBuffer)
    }
}

/// Experiment parameters (one run = one version on one cluster shape).
#[derive(Clone)]
pub struct GsParams {
    pub rows: usize,
    pub cols: usize,
    /// Block size of the hybrid/N-Buffer decompositions.
    pub block: usize,
    pub iters: usize,
    pub nodes: usize,
    /// Cores per node: hybrid = OmpSs threads per rank; pure = ranks/node.
    pub cores_per_node: usize,
    pub version: GsVersion,
    pub compute: Compute,
    /// Cost-model coefficient (ns per cell update).
    pub cell_ns: f64,
    pub net: crate::rmpi::NetworkModel,
    pub poll_interval: VNanos,
    /// TAMPI completion-notification pipeline (default: callback
    /// continuations; set `Polling` for paper-faithful figure runs).
    pub completion_mode: crate::nanos::CompletionMode,
    /// Continuation delivery (default: sharded progress engine; set
    /// `Direct` for the PR-1 inline baseline). See [`crate::progress`].
    pub delivery_mode: crate::progress::DeliveryMode,
    /// Collective schedule topology (default: node-hierarchical plans
    /// where the network model says they win; `Flat` reproduces the
    /// PR-3 schedules). See [`crate::rmpi::TopologyMode`].
    pub topology: crate::rmpi::TopologyMode,
    /// Every `residual_every` iterations, allreduce the grid sum as a
    /// convergence residual (0 = off). Task versions only (Sentinel,
    /// Interop blk/non-blk): the residual task reads every block of the
    /// iteration.
    pub residual_every: usize,
    /// `false`: the residual task performs a blocking allreduce (pausing
    /// until the collective completes — the collective latency sits on
    /// the dependency critical path). `true`: the task posts
    /// `iallreduce` and finishes immediately; the engine-driven
    /// [`crate::rmpi::CollRequest`] rides alongside the next iterations'
    /// halo compute and is harvested after the final taskwait (fig16's
    /// overlap).
    pub residual_nonblocking: bool,
    /// Clock lanes the simulated nodes are sharded over (default 1 —
    /// the classic single-heap engine; results are bit-identical across
    /// values). See [`crate::rmpi::ClusterConfig::clock_shards`].
    pub clock_shards: usize,
    /// Event-queue implementation backing each clock lane (default:
    /// calendar queue; results are bit-identical across kinds). See
    /// [`crate::sim::ClockQueueKind`].
    pub clock_queue: crate::sim::ClockQueueKind,
    pub tracer: Option<Arc<Tracer>>,
    pub graph: Option<Arc<GraphRecorder>>,
    /// Typed span sink (Perfetto export / overlap profiler). Attaching
    /// one never changes results — see [`crate::obs`].
    pub spans: Option<Arc<crate::obs::SpanSink>>,
    pub deadline: Option<VNanos>,
}

impl GsParams {
    pub fn new(
        rows: usize,
        cols: usize,
        block: usize,
        iters: usize,
        nodes: usize,
        cores_per_node: usize,
        version: GsVersion,
    ) -> GsParams {
        GsParams {
            rows,
            cols,
            block,
            iters,
            nodes,
            cores_per_node,
            version,
            compute: Compute::Native,
            cell_ns: DEFAULT_GS_CELL_NS,
            net: crate::rmpi::NetworkModel::default(),
            poll_interval: crate::sim::us(50),
            completion_mode: crate::nanos::CompletionMode::default(),
            delivery_mode: crate::progress::DeliveryMode::default(),
            topology: crate::rmpi::TopologyMode::default(),
            residual_every: 0,
            residual_nonblocking: false,
            clock_shards: 1,
            clock_queue: crate::sim::ClockQueueKind::default(),
            tracer: None,
            graph: None,
            spans: None,
            deadline: None,
        }
    }

    fn ranks(&self) -> usize {
        if self.version.is_hybrid() {
            self.nodes
        } else {
            self.nodes * self.cores_per_node
        }
    }

    fn validate(&self) {
        let r = self.ranks();
        if self.version.is_hybrid() {
            assert_eq!(self.rows % self.block, 0, "rows % block != 0");
            assert_eq!(self.cols % self.block, 0, "cols % block != 0");
            let nbr = self.rows / self.block;
            assert_eq!(nbr % r, 0, "block rows ({nbr}) not divisible by ranks ({r})");
        } else {
            assert_eq!(self.rows % r, 0, "rows not divisible by ranks");
            if self.version == GsVersion::NBuffer {
                assert_eq!(self.cols % self.block, 0, "cols % block != 0");
            }
        }
        if self.compute == Compute::Pjrt {
            assert!(
                self.version.is_hybrid(),
                "PJRT backend requires a block-decomposed (hybrid) version"
            );
        }
        if self.residual_every > 0 {
            assert!(
                matches!(
                    self.version,
                    GsVersion::Sentinel | GsVersion::InteropBlk | GsVersion::InteropNonBlk
                ),
                "residual monitoring requires a task version with a full dep graph"
            );
        }
    }
}

/// Result of one run.
#[derive(Clone, Debug)]
pub struct GsOutcome {
    pub vtime_ns: u64,
    pub stats: RunStats,
    /// f64 sum of the final grid (0.0 under the Model backend).
    pub checksum: f64,
    /// Last residual allreduce value (0.0 when `residual_every == 0`).
    pub residual: f64,
}

impl GsOutcome {
    /// Throughput in cell updates per virtual second.
    pub fn cells_per_sec(&self, p: &GsParams) -> f64 {
        (p.rows as f64 * p.cols as f64 * p.iters as f64) / (self.vtime_ns as f64 / 1e9)
    }
}

/// Message tags: one pair per (iteration, column block).
fn tag_down(t: usize, j: usize, nbc: usize) -> i32 {
    (2 * (t * nbc + j)) as i32
}
fn tag_up(t: usize, j: usize, nbc: usize) -> i32 {
    (2 * (t * nbc + j) + 1) as i32
}

/// In-place Gauss-Seidel sweep over a `rows x cols` tile with halo
/// vectors. In-place update *is* the paper's recurrence: above/left reads
/// see new values, below/right see old ones.
pub fn sweep_native(
    u: &mut [f32],
    rows: usize,
    cols: usize,
    top: &[f32],
    bottom: &[f32],
    left: &[f32],
    right: &[f32],
) {
    debug_assert_eq!(u.len(), rows * cols);
    // §Perf opt-2: split the update into a vectorizable part and the
    // sequential left-to-right recurrence (the same decomposition the
    // Pallas kernel uses): base[j] = up_new + down_old + right_old,
    // then u[i][j] = 0.25 * (base[j] + u[i][j-1]).
    let mut base = vec![0f32; cols];
    for i in 0..rows {
        let off = i * cols;
        {
            let (head, tail) = u.split_at(off);
            let up: &[f32] = if i > 0 { &head[off - cols..] } else { top };
            let row = &tail[..cols];
            let down: &[f32] = if i < rows - 1 { &tail[cols..2 * cols] } else { bottom };
            for j in 0..cols - 1 {
                base[j] = up[j] + down[j] + row[j + 1];
            }
            base[cols - 1] = up[cols - 1] + down[cols - 1] + right[i];
        }
        // Sequential recurrence along the row.
        let row = &mut u[off..off + cols];
        let mut prev = left[i];
        for j in 0..cols {
            let v = 0.25 * (base[j] + prev);
            row[j] = v;
            prev = v;
        }
    }
}

/// Run one Gauss-Seidel experiment on a simulated cluster.
pub fn run(p: &GsParams) -> Result<GsOutcome, RunError> {
    p.validate();
    let mut cc = if p.version.is_hybrid() {
        ClusterConfig::new(p.nodes, 1, p.cores_per_node)
    } else {
        ClusterConfig::new(p.nodes, p.cores_per_node, 0)
    };
    cc.net = p.net;
    cc.poll_interval = p.poll_interval;
    cc.completion_mode = p.completion_mode;
    cc.delivery_mode = p.delivery_mode;
    cc.topology = p.topology;
    cc.tracer = p.tracer.clone();
    cc.graph = p.graph.clone();
    cc.spans = p.spans.clone();
    cc.deadline = p.deadline;
    cc.clock_shards = p.clock_shards;
    cc.clock_queue = p.clock_queue;
    let p2 = p.clone();
    let stats = Universe::run_with_counters(cc, move |ctx, counters| match p2.version {
        GsVersion::PureMpi => pure_mpi(ctx, &p2, counters),
        GsVersion::NBuffer => nbuffer(ctx, &p2, counters),
        _ => hybrid(ctx, &p2, counters),
    })?;
    let checksum = stats
        .counters
        .get("checksum_bits")
        .map(|&b| f64::from_bits(b))
        .unwrap_or(0.0);
    let residual = stats
        .counters
        .get("residual_bits")
        .map(|&b| f64::from_bits(b))
        .unwrap_or(0.0);
    Ok(GsOutcome { vtime_ns: stats.vtime_ns, stats, checksum, residual })
}

/// Reduce the local f64 sum and record it once.
fn record_checksum(ctx: &RankCtx, counters: &Counters, local: f64) {
    let mut v = [local];
    ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
    if ctx.rank == 0 {
        counters.add("checksum_bits", v[0].to_bits());
    }
}

// --------------------------------------------------------------------
// Pure MPI (Section 7.1): one block per rank, sequential compute,
// synchronous boundary exchange. The strong inter-rank serialization of
// Fig 8 (top) emerges from recv_top waiting for the upper rank's same-
// iteration row.
// --------------------------------------------------------------------
fn pure_mpi(ctx: &RankCtx, p: &GsParams, counters: &Counters) {
    let r = ctx.rank;
    let n = ctx.size;
    let trace = |kind: crate::trace::EventKind, label: &str| {
        if let Some(tr) = &p.tracer {
            tr.emit(crate::trace::Record {
                t: ctx.clock.now(),
                rank: r as u32,
                worker: 0,
                kind,
                label: label.to_string(),
                task_id: 0,
            });
        }
    };
    let band = p.rows / n;
    let cols = p.cols;
    let model = p.compute == Compute::Model;
    let mut u = vec![0f32; if model { 1 } else { band * cols }];
    let mut top = vec![if r == 0 { 1.0f32 } else { 0.0 }; cols];
    let mut bot = vec![0f32; cols];
    let zeros_side = vec![0f32; band];
    let row_buf = vec![0f32; cols];

    // Everyone pre-sends its initial first row upward (bottom halo seed).
    if r > 0 {
        let first: Vec<f32> = if model { row_buf.clone() } else { u[0..cols].to_vec() };
        ctx.comm.send(&first, r - 1, tag_up(0, 0, 1));
    }
    for t in 0..p.iters {
        if r > 0 {
            trace(crate::trace::EventKind::MpiStart, "recv_top");
            ctx.comm.recv(&mut top, (r - 1) as i32, tag_down(t, 0, 1));
            trace(crate::trace::EventKind::MpiEnd, "recv_top");
        }
        if r < n - 1 {
            trace(crate::trace::EventKind::MpiStart, "recv_bot");
            ctx.comm.recv(&mut bot, (r + 1) as i32, tag_up(t, 0, 1));
            trace(crate::trace::EventKind::MpiEnd, "recv_bot");
        }
        trace(crate::trace::EventKind::TaskStart, "sweep");
        if !model {
            sweep_native(&mut u, band, cols, &top, &bot, &zeros_side, &zeros_side);
        }
        ctx.clock.work(gs_cost(band * cols, p.cell_ns) * ctx.comm.compute_mult());
        trace(crate::trace::EventKind::TaskEnd, "sweep");
        if r < n - 1 {
            let last: Vec<f32> = if model {
                row_buf.clone()
            } else {
                u[(band - 1) * cols..].to_vec()
            };
            ctx.comm.send(&last, r + 1, tag_down(t, 0, 1));
        }
        if r > 0 && t + 1 < p.iters {
            let first: Vec<f32> = if model { row_buf.clone() } else { u[0..cols].to_vec() };
            ctx.comm.send(&first, r - 1, tag_up(t + 1, 0, 1));
        }
    }
    let local: f64 = if model { 0.0 } else { u.iter().map(|&x| x as f64).sum() };
    record_checksum(ctx, counters, local);
}

// --------------------------------------------------------------------
// N-Buffer MPI: the band is split into column blocks; boundary exchange
// per block with asynchronous primitives, waits just before each block's
// compute — partial comm/compute overlap, no tasks (Section 7.1).
// --------------------------------------------------------------------
fn nbuffer(ctx: &RankCtx, p: &GsParams, counters: &Counters) {
    let r = ctx.rank;
    let n = ctx.size;
    let trace = |kind: crate::trace::EventKind, label: &str| {
        if let Some(tr) = &p.tracer {
            tr.emit(crate::trace::Record {
                t: ctx.clock.now(),
                rank: r as u32,
                worker: 0,
                kind,
                label: label.to_string(),
                task_id: 0,
            });
        }
    };
    let band = p.rows / n;
    let cols = p.cols;
    let b = p.block;
    let nbc = cols / b;
    let model = p.compute == Compute::Model;
    let mut u = vec![0f32; if model { 1 } else { band * cols }];
    let mut tops: Vec<Vec<f32>> = (0..nbc)
        .map(|_| vec![if r == 0 { 1.0f32 } else { 0.0 }; b])
        .collect();
    let mut bots: Vec<Vec<f32>> = (0..nbc).map(|_| vec![0f32; b]).collect();
    let part_buf = vec![0f32; b];

    let row_part = |u: &[f32], row: usize, j: usize, model: bool| -> Vec<f32> {
        if model {
            part_buf.clone()
        } else {
            u[row * cols + j * b..row * cols + (j + 1) * b].to_vec()
        }
    };

    // Pre-send initial first-row parts upward; post the first receives.
    if r > 0 {
        for j in 0..nbc {
            let part = row_part(&u, 0, j, model);
            let _ = ctx.comm.isend(&part, r - 1, tag_up(0, j, nbc));
        }
    }
    let mut req_top: Vec<Option<crate::rmpi::Request>> = vec![None; nbc];
    let mut req_bot: Vec<Option<crate::rmpi::Request>> = vec![None; nbc];
    for j in 0..nbc {
        if r > 0 {
            req_top[j] = Some(ctx.comm.irecv(&mut tops[j], (r - 1) as i32, tag_down(0, j, nbc)));
        }
        if r < n - 1 {
            req_bot[j] = Some(ctx.comm.irecv(&mut bots[j], (r + 1) as i32, tag_up(0, j, nbc)));
        }
    }

    for t in 0..p.iters {
        for j in 0..nbc {
            // Wait for this block's boundary data (MPI_Wait, Section 7.1).
            if req_top[j].is_some() || req_bot[j].is_some() {
                trace(crate::trace::EventKind::MpiStart, "wait");
            }
            let waited = req_top[j].is_some() || req_bot[j].is_some();
            if let Some(req) = req_top[j].take() {
                req.wait(&ctx.clock);
            }
            if let Some(req) = req_bot[j].take() {
                req.wait(&ctx.clock);
            }
            if waited {
                trace(crate::trace::EventKind::MpiEnd, "wait");
            }
            trace(crate::trace::EventKind::TaskStart, "block");
            if !model {
                // Column block j of the band, in place. Left halo: new
                // values of block j-1 (already updated); right: old j+1.
                let (mut left, mut right) = (vec![0f32; band], vec![0f32; band]);
                if j > 0 {
                    for i in 0..band {
                        left[i] = u[i * cols + j * b - 1];
                    }
                }
                if j < nbc - 1 {
                    for i in 0..band {
                        right[i] = u[i * cols + (j + 1) * b];
                    }
                }
                // Extract, sweep, write back (keeps sweep_native generic).
                let mut tile = vec![0f32; band * b];
                for i in 0..band {
                    tile[i * b..(i + 1) * b]
                        .copy_from_slice(&u[i * cols + j * b..i * cols + (j + 1) * b]);
                }
                sweep_native(&mut tile, band, b, &tops[j], &bots[j], &left, &right);
                for i in 0..band {
                    u[i * cols + j * b..i * cols + (j + 1) * b]
                        .copy_from_slice(&tile[i * b..(i + 1) * b]);
                }
            }
            ctx.clock.work(gs_cost(band * b, p.cell_ns) * ctx.comm.compute_mult());
            trace(crate::trace::EventKind::TaskEnd, "block");
            // Exchange this block's boundaries as soon as possible.
            if r < n - 1 {
                let part = row_part(&u, band - 1, j, model);
                let _ = ctx.comm.isend(&part, r + 1, tag_down(t, j, nbc));
                if t + 1 < p.iters {
                    req_bot[j] = Some(ctx.comm.irecv(
                        &mut bots[j],
                        (r + 1) as i32,
                        tag_up(t + 1, j, nbc),
                    ));
                }
            }
            if r > 0 && t + 1 < p.iters {
                let part = row_part(&u, 0, j, model);
                let _ = ctx.comm.isend(&part, r - 1, tag_up(t + 1, j, nbc));
                req_top[j] = Some(ctx.comm.irecv(
                    &mut tops[j],
                    (r - 1) as i32,
                    tag_down(t + 1, j, nbc),
                ));
            }
        }
    }
    let local: f64 = if model { 0.0 } else { u.iter().map(|&x| x as f64).sum() };
    record_checksum(ctx, counters, local);
}

// --------------------------------------------------------------------
// Hybrid versions: Fork-Join, Sentinel, Interop(blk), Interop(non-blk).
// One rank per node, `cores_per_node` workers, B x B blocks.
// --------------------------------------------------------------------
struct HybridState {
    b: usize,
    nbc: usize,
    lbr: usize,
    rank: usize,
    ranks: usize,
    model: bool,
    blocks: Arc<BlockStore>,
    halo_top: Arc<BlockStore>,
    halo_bot: Arc<BlockStore>,
    kernel: Option<Arc<crate::runtime::GsKernel>>,
    cost: VNanos,
}

impl HybridState {
    fn blk(&self, bi: usize, bj: usize) -> usize {
        bi * self.nbc + bj
    }

    /// Gather the four halo vectors of block (bi, bj).
    /// SAFETY contract: caller's task holds deps on all read objects.
    unsafe fn halos(&self, bi: usize, bj: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let b = self.b;
        let top: Vec<f32> = if bi > 0 {
            let nb = unsafe { self.blocks.get(self.blk(bi - 1, bj)) };
            nb[(b - 1) * b..].to_vec()
        } else if self.rank > 0 {
            unsafe { self.halo_top.get(bj) }.clone()
        } else {
            vec![1.0; b] // global top boundary: heat source
        };
        let bottom: Vec<f32> = if bi < self.lbr - 1 {
            let nb = unsafe { self.blocks.get(self.blk(bi + 1, bj)) };
            nb[0..b].to_vec()
        } else if self.rank < self.ranks - 1 {
            unsafe { self.halo_bot.get(bj) }.clone()
        } else {
            vec![0.0; b]
        };
        let mut left = vec![0f32; b];
        if bj > 0 {
            let nb = unsafe { self.blocks.get(self.blk(bi, bj - 1)) };
            for i in 0..b {
                left[i] = nb[i * b + b - 1];
            }
        }
        let mut right = vec![0f32; b];
        if bj < self.nbc - 1 {
            let nb = unsafe { self.blocks.get(self.blk(bi, bj + 1)) };
            for i in 0..b {
                right[i] = nb[i * b];
            }
        }
        (top, bottom, left, right)
    }

    /// Compute body of one block task.
    fn compute_block(&self, bi: usize, bj: usize) {
        if !self.model {
            // SAFETY: the dependency annotations of the calling task order
            // this access (OmpSs memory model, see store.rs).
            let (top, bottom, left, right) = unsafe { self.halos(bi, bj) };
            let u = unsafe { self.blocks.get_mut(self.blk(bi, bj)) };
            match &self.kernel {
                Some(k) => {
                    let (new, _delta) = k
                        .sweep(u, &top, &bottom, &left, &right)
                        .expect("PJRT sweep");
                    u.copy_from_slice(&new);
                }
                None => sweep_native(u, self.b, self.b, &top, &bottom, &left, &right),
            }
        }
        nanos::work(self.cost);
    }

    /// Copy of a block's first/last row for sending (model: zeros).
    fn row_copy(&self, bi: usize, bj: usize, last: bool) -> Vec<f32> {
        if self.model {
            return vec![0f32; self.b];
        }
        let u = unsafe { self.blocks.get(self.blk(bi, bj)) };
        if last {
            u[(self.b - 1) * self.b..].to_vec()
        } else {
            u[0..self.b].to_vec()
        }
    }
}

fn hybrid(ctx: &RankCtx, p: &GsParams, counters: &Counters) {
    let rt = ctx.rt.as_ref().expect("hybrid versions need a task runtime");
    let level = match p.version {
        GsVersion::InteropBlk | GsVersion::InteropNonBlk => ThreadLevel::TaskMultiple,
        _ => ThreadLevel::Multiple,
    };
    let tm = tampi::init(&ctx.comm, rt, level);

    let r = ctx.rank;
    let n = ctx.size;
    let b = p.block;
    let nbc = p.cols / b;
    let nbr = p.rows / b;
    let lbr = nbr / n;
    let model = p.compute == Compute::Model;
    let st = Arc::new(HybridState {
        b,
        nbc,
        lbr,
        rank: r,
        ranks: n,
        model,
        blocks: BlockStore::zeros(lbr * nbc, if model { 1 } else { b * b }),
        halo_top: BlockStore::zeros(nbc, b),
        halo_bot: BlockStore::zeros(nbc, b),
        kernel: if p.compute == Compute::Pjrt {
            Some(Arc::new(crate::runtime::GsKernel::load(b).expect("gs kernel")))
        } else {
            None
        },
        // Straggler injection multiplies modelled compute (the ingress
        // half is charged by the Ports law, see rmpi::faults).
        cost: gs_cost(b * b, p.cell_ns) * ctx.comm.compute_mult(),
    });

    let obj_blk: Vec<DepObj> = (0..lbr * nbc)
        .map(|i| rt.dep(format!("r{r}b{i}")))
        .collect();
    let obj_ht: Vec<DepObj> = (0..nbc).map(|j| rt.dep(format!("r{r}ht{j}"))).collect();
    let obj_hb: Vec<DepObj> = (0..nbc).map(|j| rt.dep(format!("r{r}hb{j}"))).collect();
    let sentinel = rt.dep(format!("r{r}sentinel"));
    let use_sentinel = p.version == GsVersion::Sentinel;

    // Residual monitoring (fig16): one allreduce of the grid sum every
    // `residual_every` iterations. Slots are the collectives' stable
    // reduction buffers; requests of fire-and-forget iallreduces are
    // harvested after the final taskwait.
    let res_rounds = if p.residual_every > 0 { p.iters / p.residual_every } else { 0 };
    let res_store = super::store::ScalarStore::zeros(res_rounds.max(1));
    let res_reqs: Arc<std::sync::Mutex<Vec<crate::rmpi::Request>>> =
        Arc::new(std::sync::Mutex::new(Vec::new()));
    // InOut chain: successive residual tasks issue their collectives in
    // iteration order on every rank (MPI collective-ordering rule).
    let obj_res = rt.dep(format!("r{r}residual"));

    match p.version {
        GsVersion::ForkJoin => {
            // Sequential comm phases + parallel compute + taskwait per iter.
            for t in 0..p.iters {
                if r > 0 {
                    for j in 0..nbc {
                        let part = st.row_copy(0, j, false);
                        ctx.comm.send(&part, r - 1, tag_up(t, j, nbc));
                    }
                }
                if r < n - 1 {
                    for j in 0..nbc {
                        // SAFETY: main thread, between taskwaits.
                        let buf = unsafe { st.halo_bot.get_mut(j) };
                        ctx.comm.recv(buf, (r + 1) as i32, tag_up(t, j, nbc));
                    }
                }
                if r > 0 {
                    for j in 0..nbc {
                        let buf = unsafe { st.halo_top.get_mut(j) };
                        ctx.comm.recv(buf, (r - 1) as i32, tag_down(t, j, nbc));
                    }
                }
                for bi in 0..lbr {
                    for bj in 0..nbc {
                        spawn_compute(rt, &st, &obj_blk, &obj_ht, &obj_hb, bi, bj, t, false);
                    }
                }
                rt.taskwait();
                if r < n - 1 {
                    for j in 0..nbc {
                        let part = st.row_copy(lbr - 1, j, true);
                        ctx.comm.send(&part, r + 1, tag_down(t, j, nbc));
                    }
                }
            }
        }
        _ => {
            // Task versions: submit ALL iterations; dependencies (and, for
            // Sentinel, the artificial serialization) order execution.
            for t in 0..p.iters {
                if r > 0 {
                    for j in 0..nbc {
                        spawn_send(
                            rt, &tm, &st, &obj_blk, &sentinel, use_sentinel,
                            /*bi*/ 0, j, /*last*/ false, r - 1, tag_up(t, j, nbc), p.version,
                        );
                    }
                }
                if r < n - 1 {
                    for j in 0..nbc {
                        spawn_recv(
                            rt, &tm, &st, &obj_hb[j], &sentinel, use_sentinel,
                            st.halo_bot.clone(), j, (r + 1) as i32, tag_up(t, j, nbc), p.version,
                        );
                    }
                }
                if r > 0 {
                    for j in 0..nbc {
                        spawn_recv(
                            rt, &tm, &st, &obj_ht[j], &sentinel, use_sentinel,
                            st.halo_top.clone(), j, (r - 1) as i32, tag_down(t, j, nbc), p.version,
                        );
                    }
                }
                for bi in 0..lbr {
                    for bj in 0..nbc {
                        spawn_compute(rt, &st, &obj_blk, &obj_ht, &obj_hb, bi, bj, t, true);
                    }
                }
                if r < n - 1 {
                    for j in 0..nbc {
                        spawn_send(
                            rt, &tm, &st, &obj_blk, &sentinel, use_sentinel,
                            lbr - 1, j, /*last*/ true, r + 1, tag_down(t, j, nbc), p.version,
                        );
                    }
                }
                if p.residual_every > 0 && (t + 1) % p.residual_every == 0 {
                    let idx = (t + 1) / p.residual_every - 1;
                    spawn_residual(
                        rt, &tm, &st, &obj_blk, &obj_res, idx, t,
                        p.residual_nonblocking, &res_store, &res_reqs,
                    );
                }
            }
            rt.taskwait();
        }
    }

    // Harvest outstanding fire-and-forget residual collectives (they
    // progressed on the engine while later iterations computed).
    for req in res_reqs.lock().unwrap().iter() {
        req.wait(&ctx.clock);
    }
    if res_rounds > 0 && ctx.rank == 0 {
        // SAFETY: all residual collectives completed above.
        let last = unsafe { res_store.value(res_rounds - 1) };
        counters.add("residual_bits", last.to_bits());
    }

    let local = if model { 0.0 } else { st.blocks.checksum() };
    record_checksum(ctx, counters, local);
}

/// Spawn one residual-monitoring task: reads every block of the just-
/// finished iteration (In deps) and allreduces the grid sum. Blocking
/// variant: the task pauses on the collective, holding its block reads
/// — the collective's latency gates the next iteration's writers.
/// Non-blocking variant: the task stores its local sum into the round's
/// slot, posts `iallreduce` and finishes; dependencies release
/// immediately and the engine-driven collective overlaps the next
/// iterations' halo compute (its request is harvested post-taskwait).
#[allow(clippy::too_many_arguments)]
fn spawn_residual(
    rt: &crate::nanos::Runtime,
    tm: &Tampi,
    st: &Arc<HybridState>,
    obj_blk: &[DepObj],
    obj_res: &DepObj,
    idx: usize,
    t: usize,
    nonblocking: bool,
    res_store: &Arc<super::store::ScalarStore>,
    res_reqs: &Arc<std::sync::Mutex<Vec<crate::rmpi::Request>>>,
) {
    let mut tb = rt
        .task()
        .label(format!("residual[{t}]"))
        .dep(obj_res, Mode::InOut);
    for obj in obj_blk {
        tb = tb.dep(obj, Mode::In);
    }
    let st = st.clone();
    let tm = tm.clone();
    let res_store = res_store.clone();
    let res_reqs = res_reqs.clone();
    tb.spawn(move || {
        let local = if st.model { 0.0 } else { st.blocks.checksum() };
        if nonblocking {
            // SAFETY: slot `idx` is written only by this task (obj_res
            // chain) and read only after its collective completes.
            let slot = unsafe { res_store.get_mut(idx) };
            slot[0] = local;
            let cr = tm.comm().iallreduce(slot, |a, b| a[0] += b[0]);
            res_reqs.lock().unwrap().push(cr.into_request());
        } else {
            let mut v = [local];
            tm.allreduce(&mut v, |a, b| a[0] += b[0]);
            // SAFETY: as above; the collective completed in-task here.
            unsafe { res_store.get_mut(idx) }[0] = v[0];
        }
    });
}

/// Spawn one block-update task with the Fig 7 dependency pattern.
#[allow(clippy::too_many_arguments)]
fn spawn_compute(
    rt: &crate::nanos::Runtime,
    st: &Arc<HybridState>,
    obj_blk: &[DepObj],
    obj_ht: &[DepObj],
    obj_hb: &[DepObj],
    bi: usize,
    bj: usize,
    t: usize,
    with_halo_deps: bool,
) {
    let mut tb = rt
        .task()
        .label(format!("gs[{t}]({bi},{bj})"))
        .dep(&obj_blk[st.blk(bi, bj)], Mode::InOut);
    if bi > 0 {
        tb = tb.dep(&obj_blk[st.blk(bi - 1, bj)], Mode::In);
    } else if with_halo_deps && st.rank > 0 {
        tb = tb.dep(&obj_ht[bj], Mode::In);
    }
    if bi < st.lbr - 1 {
        tb = tb.dep(&obj_blk[st.blk(bi + 1, bj)], Mode::In);
    } else if with_halo_deps && st.rank < st.ranks - 1 {
        tb = tb.dep(&obj_hb[bj], Mode::In);
    }
    if bj > 0 {
        tb = tb.dep(&obj_blk[st.blk(bi, bj - 1)], Mode::In);
    }
    if bj < st.nbc - 1 {
        tb = tb.dep(&obj_blk[st.blk(bi, bj + 1)], Mode::In);
    }
    let st = st.clone();
    tb.spawn(move || st.compute_block(bi, bj));
}

/// Spawn a boundary-row send task.
#[allow(clippy::too_many_arguments)]
fn spawn_send(
    rt: &crate::nanos::Runtime,
    tm: &Tampi,
    st: &Arc<HybridState>,
    obj_blk: &[DepObj],
    sentinel: &DepObj,
    use_sentinel: bool,
    bi: usize,
    bj: usize,
    last: bool,
    dst: usize,
    tag: i32,
    version: GsVersion,
) {
    let mut tb = rt
        .task()
        .label(format!("send({bi},{bj})t{tag}"))
        .dep(&obj_blk[st.blk(bi, bj)], Mode::In);
    if use_sentinel {
        tb = tb.dep(sentinel, Mode::InOut);
    }
    let st = st.clone();
    let tm = tm.clone();
    tb.spawn(move || {
        let part = st.row_copy(bi, bj, last);
        match version {
            GsVersion::InteropNonBlk => {
                let req = tm.comm().isend(&part, dst, tag);
                tm.iwait(&req);
            }
            _ => tm.send(&part, dst, tag),
        }
    });
}

/// Spawn a halo receive task.
#[allow(clippy::too_many_arguments)]
fn spawn_recv(
    rt: &crate::nanos::Runtime,
    tm: &Tampi,
    st: &Arc<HybridState>,
    halo_obj: &DepObj,
    sentinel: &DepObj,
    use_sentinel: bool,
    halo_store: Arc<BlockStore>,
    j: usize,
    src: i32,
    tag: i32,
    version: GsVersion,
) {
    let _ = st;
    let mut tb = rt
        .task()
        .label(format!("recv(h{j})t{tag}"))
        .dep(halo_obj, Mode::Out);
    if use_sentinel {
        tb = tb.dep(sentinel, Mode::InOut);
    }
    let tm = tm.clone();
    tb.spawn(move || {
        // SAFETY: out-dependency on the halo object orders this write.
        let buf = unsafe { halo_store.get_mut(j) };
        match version {
            GsVersion::InteropNonBlk => {
                let req = tm.comm().irecv(buf, src, tag);
                tm.iwait(&req);
            }
            _ => {
                tm.recv(buf, src, tag);
            }
        }
    });
}
