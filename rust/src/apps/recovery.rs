//! Shrink-and-continue recovery drivers for the evaluation apps.
//!
//! The fault subsystem ([`crate::rmpi::faults`]) can kill a rank at a
//! virtual instant; this module is the application-side answer. Each
//! driver runs its solver in two phases on the same simulated cluster:
//!
//! 1. **Tolerant phase** on the world communicator: point-to-point
//!    boundary/transposition exchanges check
//!    [`crate::rmpi::Request::result`] and
//!    absorb `Err(RankFailed)` (a failed halo read keeps the stale
//!    values; a failed send is dropped). Nothing hangs — failed
//!    requests still complete (see `rmpi::request`), they just carry
//!    the error.
//! 2. **Recovery**: every rank advances past the configured failure
//!    instant (so the fault oracle's verdict is unanimous — the
//!    stand-in for a ULFM agreement round, see
//!    [`crate::rmpi::Comm::confirmed_dead`]), the dead rank drops out,
//!    and the survivors call [`crate::rmpi::Comm::comm_shrink`] and
//!    restart the solve from the initial condition on the smaller
//!    communicator.
//!
//! The restarted phase performs exactly the arithmetic of a clean run
//! on `survivors` ranks, and the final checksum is accumulated in rank
//! order over point-to-point messages (not an allreduce, whose combine
//! tree differs between a world and a shrunk communicator), so
//! recovery runs are **bit-identical** to a fault-free run of the same
//! driver at the survivor count — the property `tests/faults.rs` and
//! the fig22 bench assert. Drop and straggler injections change only
//! timing (retransmits, cost multipliers), never data, so the same
//! checksums hold under every `--inject` mode.

use crate::rmpi::universe::{Counters, RunError};
use crate::rmpi::{ClusterConfig, Comm, FaultsConfig, RankCtx, RunStats, Universe};
use crate::sim::VNanos;

use super::gauss_seidel::sweep_native;
use super::ifsker::{init_value, physics_native, spectral_native};
use super::{gs_cost, ifsker};

/// Tag spaces: solver tags stay far below these.
const SUM_TAG: i32 = 1_000_000;

/// Outcome of one shrink-and-continue run.
#[derive(Clone, Debug)]
pub struct ShrinkOutcome {
    pub vtime_ns: u64,
    pub stats: RunStats,
    /// Communicator size the recovered phase ran on.
    pub survivors: usize,
    /// Rank-ordered f64 sum of the recovered phase's final state.
    pub checksum: f64,
}

/// Parameters shared by the recovery drivers. `pre_iters` is the
/// tolerant world phase (0 skips it — used for clean reference runs);
/// `iters` is the recovered solve. With `faults: None` the "recovery"
/// phase simply runs on the world communicator, which is what makes a
/// fault-free reference at the survivor count directly comparable.
#[derive(Clone)]
pub struct ShrinkParams {
    pub nodes: usize,
    pub ranks_per_node: usize,
    pub pre_iters: usize,
    pub iters: usize,
    pub net: crate::rmpi::NetworkModel,
    pub clock_shards: usize,
    /// Per-lane event-queue implementation (bit-identical across kinds).
    pub clock_queue: crate::sim::ClockQueueKind,
    pub delivery_mode: crate::progress::DeliveryMode,
    pub deadline: Option<VNanos>,
    pub faults: Option<FaultsConfig>,
}

impl ShrinkParams {
    pub fn new(nodes: usize, ranks_per_node: usize, pre_iters: usize, iters: usize) -> Self {
        ShrinkParams {
            nodes,
            ranks_per_node,
            pre_iters,
            iters,
            net: crate::rmpi::NetworkModel::default(),
            clock_shards: 1,
            clock_queue: crate::sim::ClockQueueKind::default(),
            delivery_mode: crate::progress::DeliveryMode::default(),
            deadline: None,
            faults: None,
        }
    }

    fn ranks(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    fn cluster(&self) -> ClusterConfig {
        let mut cc = ClusterConfig::new(self.nodes, self.ranks_per_node, 0);
        cc.net = self.net;
        cc.clock_shards = self.clock_shards;
        cc.clock_queue = self.clock_queue;
        cc.delivery_mode = self.delivery_mode;
        cc.deadline = self.deadline;
        cc.faults = self.faults.clone();
        cc
    }
}

/// Send that absorbs a failed completion when `tolerant`.
fn xsend(ctx: &RankCtx, comm: &Comm, buf: &[f32], dst: usize, tag: i32, tolerant: bool) {
    let r = comm.isend(buf, dst, tag);
    r.wait(&ctx.clock);
    if !tolerant {
        r.result().expect("send failed outside the tolerant phase");
    }
}

/// Receive that absorbs a failed completion when `tolerant` (the
/// destination buffer keeps its previous — stale but deterministic —
/// values). Returns whether fresh data arrived.
fn xrecv(
    ctx: &RankCtx,
    comm: &Comm,
    buf: &mut [f32],
    src: usize,
    tag: i32,
    tolerant: bool,
) -> bool {
    let r = comm.irecv(buf, src as i32, tag);
    r.wait(&ctx.clock);
    match r.result() {
        Ok(_) => true,
        Err(e) => {
            if !tolerant {
                panic!("recv failed outside the tolerant phase: {e:?}");
            }
            false
        }
    }
}

/// Advance this rank's virtual clock past the configured failure
/// instant, then split: the dead rank returns `None` (its main exits),
/// survivors return the shrunk communicator. With no rank failure
/// configured, the world communicator is returned unchanged.
fn recover_comm(ctx: &RankCtx, faults: &Option<FaultsConfig>) -> Option<Comm> {
    let Some(rf) = faults.as_ref().and_then(|f| f.rank_fail) else {
        return Some(ctx.comm.clone());
    };
    let now = ctx.clock.now();
    if now <= rf.at_ns {
        // Unanimity by clock, not by messages: dead_at() is pure in
        // (rank, t), so once every rank is past at_ns they all read
        // the same verdict (the un-modelled agreement round).
        ctx.clock.work(rf.at_ns - now + 1);
    }
    if ctx.rank == rf.rank {
        return None;
    }
    Some(ctx.comm.comm_shrink())
}

/// Rank-order deterministic sum: rank 0 of `comm` accumulates every
/// rank's value in ascending rank order. Unlike an allreduce, the
/// addition order is independent of the communicator's plan topology,
/// so world-comm reference runs and shrunk-comm recovery runs produce
/// bit-identical totals.
fn ordered_sum(ctx: &RankCtx, comm: &Comm, local: f64) -> f64 {
    if comm.rank() == 0 {
        let mut acc = local;
        for p in 1..comm.size() {
            let mut v = [0f64];
            let r = comm.irecv(&mut v, p as i32, SUM_TAG);
            r.wait(&ctx.clock);
            r.result().expect("checksum gather on a healthy communicator");
            acc += v[0];
        }
        acc
    } else {
        let v = [local];
        let r = comm.isend(&v, 0, SUM_TAG);
        r.wait(&ctx.clock);
        0.0
    }
}

// --------------------------------------------------------------------
// Gauss-Seidel: banded 1-D decomposition, the pure-MPI exchange shape.
// --------------------------------------------------------------------

fn gs_tag_down(t: usize) -> i32 {
    (2 * t) as i32
}
fn gs_tag_up(t: usize) -> i32 {
    (2 * t + 1) as i32
}

/// Banded Gauss-Seidel solve on `comm` from the zero initial state.
/// Mirrors `gauss_seidel::pure_mpi`'s exchange order; `tolerant`
/// enables the failure-absorbing phase-1 behaviour.
fn gs_solve(
    ctx: &RankCtx,
    comm: &Comm,
    rows: usize,
    cols: usize,
    iters: usize,
    cell_ns: f64,
    tolerant: bool,
) -> Vec<f32> {
    let r = comm.rank();
    let n = comm.size();
    let band = rows / n;
    let mut u = vec![0f32; band * cols];
    let mut top = vec![if r == 0 { 1.0f32 } else { 0.0 }; cols];
    let mut bot = vec![0f32; cols];
    let side = vec![0f32; band];
    let mult = comm.compute_mult();

    if r > 0 {
        let first = u[0..cols].to_vec();
        xsend(ctx, comm, &first, r - 1, gs_tag_up(0), tolerant);
    }
    for t in 0..iters {
        if r > 0 {
            xrecv(ctx, comm, &mut top, r - 1, gs_tag_down(t), tolerant);
        }
        if r < n - 1 {
            xrecv(ctx, comm, &mut bot, r + 1, gs_tag_up(t), tolerant);
        }
        sweep_native(&mut u, band, cols, &top, &bot, &side, &side);
        ctx.clock.work(gs_cost(band * cols, cell_ns) * mult);
        if r < n - 1 {
            let last = u[(band - 1) * cols..].to_vec();
            xsend(ctx, comm, &last, r + 1, gs_tag_down(t), tolerant);
        }
        if r > 0 && t + 1 < iters {
            let first = u[0..cols].to_vec();
            xsend(ctx, comm, &first, r - 1, gs_tag_up(t + 1), tolerant);
        }
    }
    u
}

/// Gauss-Seidel parameters on top of [`ShrinkParams`].
#[derive(Clone)]
pub struct GsShrinkParams {
    pub base: ShrinkParams,
    pub rows: usize,
    pub cols: usize,
    pub cell_ns: f64,
}

impl GsShrinkParams {
    pub fn new(base: ShrinkParams, rows: usize, cols: usize) -> Self {
        GsShrinkParams { base, rows, cols, cell_ns: super::DEFAULT_GS_CELL_NS }
    }

    fn validate(&self) {
        let n = self.base.ranks();
        assert_eq!(self.rows % n, 0, "rows not divisible by ranks");
        if self.base.faults.as_ref().and_then(|f| f.rank_fail).is_some() {
            assert!(n > 1, "cannot shrink a single-rank world");
            assert_eq!(
                self.rows % (n - 1),
                0,
                "rows not divisible by the survivor count"
            );
        }
    }
}

/// Run the Gauss-Seidel shrink-and-continue experiment.
pub fn run_gs_shrink(p: &GsShrinkParams) -> Result<ShrinkOutcome, RunError> {
    p.validate();
    let p2 = p.clone();
    let stats = Universe::run_with_counters(p.base.cluster(), move |ctx, counters| {
        if p2.base.pre_iters > 0 {
            let _ = gs_solve(ctx, &ctx.comm, p2.rows, p2.cols, p2.base.pre_iters, p2.cell_ns, true);
        }
        let Some(comm) = recover_comm(ctx, &p2.base.faults) else {
            return; // this rank is dead: its main exits here
        };
        let u = gs_solve(ctx, &comm, p2.rows, p2.cols, p2.base.iters, p2.cell_ns, false);
        let local: f64 = u.iter().map(|&x| x as f64).sum();
        finish(ctx, &comm, counters, local);
    })?;
    Ok(outcome(stats))
}

// --------------------------------------------------------------------
// IFSKer: the per-field ordered all-to-all transposition cycle.
// --------------------------------------------------------------------

/// One tolerant ordered all-to-all of `portion`-sized pieces
/// (the shape of `ifsker::exchange_pure`).
#[allow(clippy::too_many_arguments)]
fn ifs_exchange(
    ctx: &RankCtx,
    comm: &Comm,
    src: &[f32],
    dst: &mut [f32],
    portion: usize,
    tag: i32,
    tolerant: bool,
) {
    let r = comm.rank();
    let n = comm.size();
    dst[r * portion..(r + 1) * portion].copy_from_slice(&src[r * portion..(r + 1) * portion]);
    for p in 0..n {
        if p == r {
            continue;
        }
        let piece = &src[p * portion..(p + 1) * portion];
        if r < p {
            xsend(ctx, comm, piece, p, tag, tolerant);
            xrecv(ctx, comm, &mut dst[p * portion..(p + 1) * portion], p, tag, tolerant);
        } else {
            xrecv(ctx, comm, &mut dst[p * portion..(p + 1) * portion], p, tag, tolerant);
            xsend(ctx, comm, piece, p, tag, tolerant);
        }
    }
}

/// IFS cycle on `comm` from the deterministic initial condition
/// (physics → transpose → spectral → transpose back, per field).
fn ifs_solve(
    ctx: &RankCtx,
    comm: &Comm,
    gridpoints: usize,
    nfields: usize,
    steps: usize,
    tolerant: bool,
) -> Vec<Vec<f32>> {
    let r = comm.rank();
    let n = comm.size();
    let chunk = gridpoints / n;
    let portion = chunk / n;
    let mult = comm.compute_mult();
    let mut fields: Vec<Vec<f32>> = (0..nfields)
        .map(|f| (0..chunk).map(|i| init_value(r, f, i)).collect())
        .collect();
    let mut spec = vec![0f32; chunk];

    for step in 0..steps {
        for f in 0..nfields {
            physics_native(&mut fields[f], 0.05);
            ctx.clock
                .work((chunk as f64 * ifsker::PHYSICS_NS_PER_CELL) as u64 * mult);
            let t0 = ((step * nfields + f) * 2) as i32;
            ifs_exchange(ctx, comm, &fields[f], &mut spec, portion, t0, tolerant);
            spectral_native(&mut spec);
            ctx.clock
                .work((chunk as f64 * ifsker::SPECTRAL_NS_PER_CELL) as u64 * mult);
            let mut back = std::mem::take(&mut fields[f]);
            ifs_exchange(ctx, comm, &spec, &mut back, portion, t0 + 1, tolerant);
            fields[f] = back;
        }
    }
    fields
}

/// IFSKer parameters on top of [`ShrinkParams`]. `gridpoints` must
/// satisfy the transposition divisibility for both the world size `n`
/// and (with a rank failure) the survivor count `n - 1`:
/// `gridpoints % (k * k) == 0` for each size `k` (e.g. 144 for 4 → 3).
#[derive(Clone)]
pub struct IfsShrinkParams {
    pub base: ShrinkParams,
    pub gridpoints: usize,
    pub fields: usize,
}

impl IfsShrinkParams {
    pub fn new(base: ShrinkParams, gridpoints: usize, fields: usize) -> Self {
        IfsShrinkParams { base, gridpoints, fields }
    }

    fn validate(&self) {
        let n = self.base.ranks();
        assert_eq!(self.gridpoints % (n * n), 0, "gridpoints % ranks^2 != 0");
        if self.base.faults.as_ref().and_then(|f| f.rank_fail).is_some() {
            assert!(n > 1, "cannot shrink a single-rank world");
            let s = n - 1;
            assert_eq!(self.gridpoints % (s * s), 0, "gridpoints % survivors^2 != 0");
        }
    }
}

/// Run the IFSKer shrink-and-continue experiment.
pub fn run_ifs_shrink(p: &IfsShrinkParams) -> Result<ShrinkOutcome, RunError> {
    p.validate();
    let p2 = p.clone();
    let stats = Universe::run_with_counters(p.base.cluster(), move |ctx, counters| {
        if p2.base.pre_iters > 0 {
            let _ = ifs_solve(ctx, &ctx.comm, p2.gridpoints, p2.fields, p2.base.pre_iters, true);
        }
        let Some(comm) = recover_comm(ctx, &p2.base.faults) else {
            return;
        };
        let fields = ifs_solve(ctx, &comm, p2.gridpoints, p2.fields, p2.base.iters, false);
        let local: f64 = fields.iter().flat_map(|v| v.iter()).map(|&x| x as f64).sum();
        finish(ctx, &comm, counters, local);
    })?;
    Ok(outcome(stats))
}

/// Gather the rank-ordered checksum and record the run's counters
/// (rank 0 of the recovered communicator only).
fn finish(ctx: &RankCtx, comm: &Comm, counters: &Counters, local: f64) {
    let sum = ordered_sum(ctx, comm, local);
    if comm.rank() == 0 {
        counters.add("survivor_checksum_bits", sum.to_bits());
        counters.add("survivors", comm.size() as u64);
    }
}

fn outcome(stats: RunStats) -> ShrinkOutcome {
    let checksum = stats
        .counters
        .get("survivor_checksum_bits")
        .map(|&b| f64::from_bits(b))
        .unwrap_or(0.0);
    let survivors = stats.counters.get("survivors").copied().unwrap_or(0) as usize;
    ShrinkOutcome { vtime_ns: stats.vtime_ns, stats, survivors, checksum }
}
