//! Shared mutable storage whose exclusivity is guaranteed by the task
//! dependency system — the OmpSs memory model.
//!
//! Tasks declare in/out/inout accesses over [`crate::nanos::DepObj`]s;
//! the runtime orders conflicting accesses, so the raw aliasing here is
//! sound *given correct dependency annotations* (exactly the contract an
//! OmpSs program has with its runtime).

use std::cell::UnsafeCell;
use std::sync::Arc;

/// A set of equally-sized f32 buffers ("blocks") with runtime-checked-by-
/// dependencies shared mutability.
pub struct BlockStore {
    blocks: Vec<UnsafeCell<Vec<f32>>>,
}

// SAFETY: concurrent access is serialized by the task dependency system.
unsafe impl Sync for BlockStore {}
unsafe impl Send for BlockStore {}

impl BlockStore {
    pub fn new(count: usize, len: usize, init: impl Fn(usize, usize) -> f32) -> Arc<Self> {
        let blocks = (0..count)
            .map(|b| UnsafeCell::new((0..len).map(|i| init(b, i)).collect()))
            .collect();
        Arc::new(BlockStore { blocks })
    }

    /// Zero-filled store.
    pub fn zeros(count: usize, len: usize) -> Arc<Self> {
        Self::new(count, len, |_, _| 0.0)
    }

    pub fn count(&self) -> usize {
        self.blocks.len()
    }

    /// Shared read access (caller must hold an `in` dependency).
    ///
    /// # Safety
    /// The calling task must have declared a dependency that orders this
    /// access against all writers.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, idx: usize) -> &mut Vec<f32> {
        unsafe { &mut *self.blocks[idx].get() }
    }

    /// # Safety
    /// See [`BlockStore::get_mut`].
    pub unsafe fn get(&self, idx: usize) -> &Vec<f32> {
        unsafe { &*self.blocks[idx].get() }
    }

    /// Sum of all elements in f64 (verification checksums). Only call
    /// after all tasks completed.
    pub fn checksum(&self) -> f64 {
        let mut acc = 0.0f64;
        for b in 0..self.count() {
            // SAFETY: quiescent (post-taskwait) access.
            for &v in unsafe { self.get(b) }.iter() {
                acc += v as f64;
            }
        }
        acc
    }
}

/// A set of single-f64 slots with dependency-guaranteed exclusivity —
/// the stable in-flight buffers of fire-and-forget `iallreduce` residual
/// monitoring (each slot is the reduction buffer of one collective and
/// must stay untouched until its `CollRequest` completes).
pub struct ScalarStore {
    slots: Vec<UnsafeCell<[f64; 1]>>,
}

// SAFETY: concurrent access is serialized by the task dependency system
// plus the i-collective buffer contract (see field docs).
unsafe impl Sync for ScalarStore {}
unsafe impl Send for ScalarStore {}

impl ScalarStore {
    pub fn zeros(count: usize) -> Arc<Self> {
        Arc::new(ScalarStore {
            slots: (0..count).map(|_| UnsafeCell::new([0.0])).collect(),
        })
    }

    pub fn count(&self) -> usize {
        self.slots.len()
    }

    /// # Safety
    /// The calling task must have declared dependencies ordering this
    /// access, and the slot must not be an in-flight collective buffer.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, idx: usize) -> &mut [f64] {
        unsafe { &mut (*self.slots[idx].get())[..] }
    }

    /// # Safety
    /// Only call after the slot's collective completed (quiescent read).
    pub unsafe fn value(&self, idx: usize) -> f64 {
        unsafe { (*self.slots[idx].get())[0] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_and_checksum() {
        let s = BlockStore::new(3, 4, |b, i| (b * 4 + i) as f32);
        assert_eq!(s.count(), 3);
        // 0+1+..+11 = 66
        assert_eq!(s.checksum(), 66.0);
    }

    #[test]
    fn scalar_store_slots() {
        let s = ScalarStore::zeros(2);
        assert_eq!(s.count(), 2);
        // SAFETY: single-threaded test.
        unsafe { s.get_mut(1)[0] = 4.5 };
        assert_eq!(unsafe { s.value(1) }, 4.5);
        assert_eq!(unsafe { s.value(0) }, 0.0);
    }
}
