//! IFSKer — mock-up of the IFS spectral-transform weather model
//! (Section 7.2).
//!
//! Time-step cycle: grid-point physics -> transposition (data
//! redistribution between the grid-point and spectral layouts) ->
//! spectral computation -> inverse transposition. One MPI rank per core;
//! fields are distributed by grid slice in grid-point space and by
//! portion in spectral space, so every phase transition is an
//! all-to-all-style exchange of `ranks x fields` *small* messages — the
//! many-small-messages regime where TAMPI's two modes differ most.
//!
//! Versions:
//! * `PureMpi`      — sequential per rank; per-field ordered blocking
//!   exchanges (the naive original-code structure).
//! * `InteropBlk`   — tasks per (field, peer) with blocking MPI via
//!   TAMPI's MPI_TASK_MULTIPLE.
//! * `InteropNonBlk`— tasks per (field, peer) with isend/irecv +
//!   TAMPI_Iwait.

use std::sync::Arc;

use crate::nanos::{self, DepObj, Mode};
use crate::rmpi::universe::Counters;
use crate::rmpi::universe::RunError;
use crate::rmpi::{ClusterConfig, RankCtx, RunStats, ThreadLevel, Universe};
use crate::sim::VNanos;
use crate::tampi::{self, Tampi};
use crate::trace::Tracer;

use super::store::BlockStore;
use super::Compute;

/// The three implementations of Section 7.2.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum IfsVersion {
    PureMpi,
    InteropBlk,
    InteropNonBlk,
}

impl IfsVersion {
    pub fn all() -> [IfsVersion; 3] {
        [IfsVersion::PureMpi, IfsVersion::InteropBlk, IfsVersion::InteropNonBlk]
    }

    pub fn name(self) -> &'static str {
        match self {
            IfsVersion::PureMpi => "pure-mpi",
            IfsVersion::InteropBlk => "interop-blk",
            IfsVersion::InteropNonBlk => "interop-nonblk",
        }
    }

    pub fn parse(s: &str) -> Option<IfsVersion> {
        IfsVersion::all().into_iter().find(|v| v.name() == s)
    }
}

/// Per-cell virtual costs of the two compute phases (ns). Physics is
/// cheap and element-wise; the spectral transform is matmul-shaped.
pub const PHYSICS_NS_PER_CELL: f64 = 2.0;
pub const SPECTRAL_NS_PER_CELL: f64 = 9.0;

/// Experiment parameters.
#[derive(Clone)]
pub struct IfsParams {
    /// Total grid points (split evenly across ranks).
    pub gridpoints: usize,
    /// Number of fields (one transposition message per field per peer).
    pub fields: usize,
    pub steps: usize,
    pub nodes: usize,
    /// Ranks per node (one rank per core, Section 7.2).
    pub cores_per_node: usize,
    pub version: IfsVersion,
    pub compute: Compute,
    pub net: crate::rmpi::NetworkModel,
    pub poll_interval: VNanos,
    /// TAMPI completion-notification pipeline (default: callback
    /// continuations; set `Polling` for paper-faithful figure runs).
    pub completion_mode: crate::nanos::CompletionMode,
    /// Continuation delivery (default: sharded progress engine; set
    /// `Direct` for the PR-1 inline baseline). See [`crate::progress`].
    pub delivery_mode: crate::progress::DeliveryMode,
    /// Collective schedule topology (IFSKer runs several ranks per
    /// node, so its residual allreduce exercises the hierarchical
    /// plans). See [`crate::rmpi::TopologyMode`].
    pub topology: crate::rmpi::TopologyMode,
    /// Every `residual_every` steps, allreduce the field sum as a
    /// diagnostic residual (0 = off; interop versions only).
    pub residual_every: usize,
    /// `false`: blocking in-task allreduce; `true`: fire-and-forget
    /// `iallreduce` whose engine-driven request overlaps later steps
    /// (see [`crate::apps::gauss_seidel::GsParams::residual_nonblocking`]).
    pub residual_nonblocking: bool,
    /// Clock lanes the simulated nodes are sharded over (default 1 —
    /// the classic single-heap engine; results are bit-identical across
    /// values). See [`crate::rmpi::ClusterConfig::clock_shards`].
    pub clock_shards: usize,
    /// Event-queue implementation backing each clock lane (default:
    /// calendar queue; results are bit-identical across kinds). See
    /// [`crate::sim::ClockQueueKind`].
    pub clock_queue: crate::sim::ClockQueueKind,
    pub tracer: Option<Arc<Tracer>>,
    /// Typed span sink (Perfetto export / overlap profiler). Attaching
    /// one never changes results — see [`crate::obs`].
    pub spans: Option<Arc<crate::obs::SpanSink>>,
    pub deadline: Option<VNanos>,
}

impl IfsParams {
    pub fn new(
        gridpoints: usize,
        fields: usize,
        steps: usize,
        nodes: usize,
        cores_per_node: usize,
        version: IfsVersion,
    ) -> IfsParams {
        IfsParams {
            gridpoints,
            fields,
            steps,
            nodes,
            cores_per_node,
            version,
            compute: Compute::Native,
            net: crate::rmpi::NetworkModel::default(),
            poll_interval: crate::sim::us(50),
            completion_mode: crate::nanos::CompletionMode::default(),
            delivery_mode: crate::progress::DeliveryMode::default(),
            topology: crate::rmpi::TopologyMode::default(),
            residual_every: 0,
            residual_nonblocking: false,
            clock_shards: 1,
            clock_queue: crate::sim::ClockQueueKind::default(),
            tracer: None,
            spans: None,
            deadline: None,
        }
    }

    fn ranks(&self) -> usize {
        self.nodes * self.cores_per_node
    }

    fn validate(&self) {
        let r = self.ranks();
        assert_eq!(self.gridpoints % r, 0, "gridpoints not divisible by ranks");
        let chunk = self.gridpoints / r;
        assert_eq!(chunk % r, 0, "chunk ({chunk}) not divisible by ranks ({r})");
        if self.residual_every > 0 {
            assert!(
                self.version != IfsVersion::PureMpi,
                "residual monitoring requires an interop (task) version"
            );
        }
    }
}

#[derive(Clone, Debug)]
pub struct IfsOutcome {
    pub vtime_ns: u64,
    pub stats: RunStats,
    pub checksum: f64,
    /// Last residual allreduce value (0.0 when `residual_every == 0`).
    pub residual: f64,
}

impl IfsOutcome {
    /// Gridpoint-steps per virtual second.
    pub fn throughput(&self, p: &IfsParams) -> f64 {
        (p.gridpoints as f64 * p.steps as f64) / (self.vtime_ns as f64 / 1e9)
    }
}

/// Native physics: logistic reaction (matches the Pallas kernel).
pub(crate) fn physics_native(u: &mut [f32], dt: f32) {
    for x in u.iter_mut() {
        *x += dt * *x * (1.0 - *x);
    }
}

/// Native "spectral" op on the transposed layout: per 64-wide segment,
/// damp towards the segment mean (deterministic, order-independent).
pub(crate) fn spectral_native(u: &mut [f32]) {
    for seg in u.chunks_mut(64) {
        let mean = seg.iter().sum::<f32>() / seg.len() as f32;
        for x in seg.iter_mut() {
            *x = 0.9 * *x + 0.1 * mean;
        }
    }
}

/// Tags: direction 0 = grid->spectral, 1 = spectral->grid.
fn tag(step: usize, field: usize, dir: usize, fields: usize) -> i32 {
    ((step * fields + field) * 2 + dir) as i32
}

/// Run one IFSKer experiment on a simulated cluster.
pub fn run(p: &IfsParams) -> Result<IfsOutcome, RunError> {
    p.validate();
    let cores = match p.version {
        IfsVersion::PureMpi => 0,
        _ => 1, // one core per rank; tasks provide in-flight MPI ops
    };
    let mut cc = ClusterConfig::new(p.nodes, p.cores_per_node, cores);
    cc.net = p.net;
    cc.poll_interval = p.poll_interval;
    cc.completion_mode = p.completion_mode;
    cc.delivery_mode = p.delivery_mode;
    cc.topology = p.topology;
    cc.tracer = p.tracer.clone();
    cc.spans = p.spans.clone();
    cc.deadline = p.deadline;
    cc.clock_shards = p.clock_shards;
    cc.clock_queue = p.clock_queue;
    let p2 = p.clone();
    let stats = Universe::run_with_counters(cc, move |ctx, counters| match p2.version {
        IfsVersion::PureMpi => pure(ctx, &p2, counters),
        _ => interop(ctx, &p2, counters),
    })?;
    let checksum = stats
        .counters
        .get("checksum_bits")
        .map(|&b| f64::from_bits(b))
        .unwrap_or(0.0);
    let residual = stats
        .counters
        .get("residual_bits")
        .map(|&b| f64::from_bits(b))
        .unwrap_or(0.0);
    Ok(IfsOutcome { vtime_ns: stats.vtime_ns, stats, checksum, residual })
}

fn record_checksum(ctx: &RankCtx, counters: &Counters, local: f64) {
    let mut v = [local];
    ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
    if ctx.rank == 0 {
        counters.add("checksum_bits", v[0].to_bits());
    }
}

pub(crate) fn init_value(rank: usize, field: usize, i: usize) -> f32 {
    // Deterministic, version-independent initial condition in (0, 1).
    let x = (rank * 131 + field * 17 + i) as f32;
    0.25 + 0.5 * ((x * 0.01).sin() * 0.5 + 0.5) * 0.9
}

// --------------------------------------------------------------------
// Pure MPI: sequential; per-field ordered blocking exchange per phase
// transition (the structure of the original non-tasked code).
// --------------------------------------------------------------------
fn pure(ctx: &RankCtx, p: &IfsParams, counters: &Counters) {
    let r = ctx.rank;
    let n = ctx.size;
    let chunk = p.gridpoints / n;
    let portion = chunk / n;
    let model = p.compute == Compute::Model;
    let alloc = if model { 1 } else { chunk };
    let mut fields: Vec<Vec<f32>> = (0..p.fields)
        .map(|f| {
            (0..alloc)
                .map(|i| if model { 0.0 } else { init_value(r, f, i) })
                .collect()
        })
        .collect();
    let mut spec = vec![0f32; if model { 1 } else { chunk }];
    let dummy = vec![0f32; portion];

    for step in 0..p.steps {
        for f in 0..p.fields {
            // 1. physics
            if !model {
                physics_native(&mut fields[f], 0.05);
            }
            ctx.clock
                .work((chunk as f64 * PHYSICS_NS_PER_CELL) as u64 * ctx.comm.compute_mult());
            // 2. transposition grid -> spectral: ordered blocking exchange
            let t = tag(step, f, 0, p.fields);
            exchange_pure(ctx, &fields[f], &mut spec, portion, t, model, &dummy);
            // 3. spectral computation
            if !model {
                spectral_native(&mut spec);
            }
            ctx.clock
                .work((chunk as f64 * SPECTRAL_NS_PER_CELL) as u64 * ctx.comm.compute_mult());
            // 4. transposition back
            let mut back = std::mem::take(&mut fields[f]);
            exchange_pure(ctx, &spec, &mut back, portion, tag(step, f, 1, p.fields), model, &dummy);
            fields[f] = back;
        }
    }
    let local: f64 = if model {
        0.0
    } else {
        fields.iter().flat_map(|v| v.iter()).map(|&x| x as f64).sum()
    };
    record_checksum(ctx, counters, local);
}

/// Ordered blocking all-to-all of `portion`-sized pieces (naive: one
/// peer at a time, send/recv ordered by rank to avoid deadlock).
fn exchange_pure(
    ctx: &RankCtx,
    src: &[f32],
    dst: &mut [f32],
    portion: usize,
    tag: i32,
    model: bool,
    dummy: &[f32],
) {
    let r = ctx.rank;
    let n = ctx.size;
    if !model {
        dst[r * portion..(r + 1) * portion].copy_from_slice(&src[r * portion..(r + 1) * portion]);
    }
    for p in 0..n {
        if p == r {
            continue;
        }
        if r < p {
            let piece = if model { dummy } else { &src[p * portion..(p + 1) * portion] };
            ctx.comm.send(piece, p, tag);
            if model {
                let mut scratch = vec![0f32; portion];
                ctx.comm.recv(&mut scratch, p as i32, tag);
            } else {
                ctx.comm.recv(&mut dst[p * portion..(p + 1) * portion], p as i32, tag);
            }
        } else {
            if model {
                let mut scratch = vec![0f32; portion];
                ctx.comm.recv(&mut scratch, p as i32, tag);
            } else {
                ctx.comm.recv(&mut dst[p * portion..(p + 1) * portion], p as i32, tag);
            }
            let piece = if model { dummy } else { &src[p * portion..(p + 1) * portion] };
            ctx.comm.send(piece, p, tag);
        }
    }
}

// --------------------------------------------------------------------
// Interop versions: tasks per field phase and per (field, peer) message;
// TAMPI makes the blocking variant safe and the non-blocking variant
// zero-pause. All steps are submitted up front; dependencies pipeline
// fields and steps against each other.
// --------------------------------------------------------------------
struct IfsState {
    chunk: usize,
    portion: usize,
    own_rank: usize,
    model: bool,
    /// Grid-point layout: one block per field.
    fields: Arc<BlockStore>,
    /// Spectral layout: one block per field.
    spec: Arc<BlockStore>,
    nranks: usize,
}

fn interop(ctx: &RankCtx, p: &IfsParams, counters: &Counters) {
    let rt = ctx.rt.as_ref().expect("interop needs a runtime");
    let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
    let r = ctx.rank;
    let n = ctx.size;
    let chunk = p.gridpoints / n;
    let portion = chunk / n;
    let model = p.compute == Compute::Model;
    let alloc = if model { 1 } else { chunk };
    let st = Arc::new(IfsState {
        chunk,
        portion,
        own_rank: r,
        model,
        fields: BlockStore::new(p.fields, alloc, |f, i| {
            if model { 0.0 } else { init_value(r, f, i) }
        }),
        // Model mode still allocates the spectral block as the request
        // target (chunk floats per field: tiny at any scale).
        spec: BlockStore::zeros(p.fields, chunk),
        nranks: n,
    });
    // One dependency object per field per layout (grid / spectral).
    let obj_field: Vec<DepObj> = (0..p.fields).map(|f| rt.dep(format!("r{r}f{f}"))).collect();
    let obj_spec: Vec<DepObj> = (0..p.fields).map(|f| rt.dep(format!("r{r}s{f}"))).collect();

    // Residual monitoring (fig16): see gauss_seidel::spawn_residual for
    // the blocking-vs-fire-and-forget shapes.
    let res_rounds = if p.residual_every > 0 { p.steps / p.residual_every } else { 0 };
    let res_store = super::store::ScalarStore::zeros(res_rounds.max(1));
    let res_reqs: Arc<std::sync::Mutex<Vec<crate::rmpi::Request>>> =
        Arc::new(std::sync::Mutex::new(Vec::new()));
    let obj_res = rt.dep(format!("r{r}residual"));

    let nonblk = p.version == IfsVersion::InteropNonBlk;
    for step in 0..p.steps {
        for f in 0..p.fields {
            // physics task: inout(field f)
            {
                let st = st.clone();
                let cost = (chunk as f64 * PHYSICS_NS_PER_CELL) as u64 * ctx.comm.compute_mult();
                rt.task()
                    .label(format!("phys[{step}]f{f}"))
                    .dep(&obj_field[f], Mode::InOut)
                    .spawn(move || {
                        if !st.model {
                            // SAFETY: inout dep on the field block.
                            physics_native(unsafe { st.fields.get_mut(f) }, 0.05);
                        }
                        nanos::work(cost);
                    });
            }
            // Forward transposition: ONE communication task per field
            // issuing isends to every peer and irecvs from every peer,
            // then TAMPI_Iwaitall / waitall — the Fig 5 pattern ("more
            // in-flight MPI operations" per task, Section 7.2).
            spawn_transpose(
                rt, &tm, &st, &obj_field[f], &obj_spec[f], f,
                tag(step, f, 0, p.fields), nonblk, Dir::GridToSpec,
            );
            // spectral task: inout(spec f)
            {
                let st2 = st.clone();
                let cost = (chunk as f64 * SPECTRAL_NS_PER_CELL) as u64 * ctx.comm.compute_mult();
                rt.task()
                    .label(format!("spec[{step}]f{f}"))
                    .dep(&obj_spec[f], Mode::InOut)
                    .spawn(move || {
                        if !st2.model {
                            // SAFETY: inout dep on the spec block.
                            spectral_native(unsafe { st2.spec.get_mut(f) });
                        }
                        nanos::work(cost);
                    });
            }
            // Backward transposition.
            spawn_transpose(
                rt, &tm, &st, &obj_field[f], &obj_spec[f], f,
                tag(step, f, 1, p.fields), nonblk, Dir::SpecToGrid,
            );
        }
        if p.residual_every > 0 && (step + 1) % p.residual_every == 0 {
            let idx = (step + 1) / p.residual_every - 1;
            let mut tb = rt
                .task()
                .label(format!("residual[{step}]"))
                .dep(&obj_res, Mode::InOut);
            for obj in &obj_field {
                tb = tb.dep(obj, Mode::In);
            }
            let st2 = st.clone();
            let tm2 = tm.clone();
            let store2 = res_store.clone();
            let reqs2 = res_reqs.clone();
            let nonblocking = p.residual_nonblocking;
            tb.spawn(move || {
                let local = if st2.model { 0.0 } else { st2.fields.checksum() };
                if nonblocking {
                    // SAFETY: slot idx written only by this task (obj_res
                    // chain), read only after its collective completes.
                    let slot = unsafe { store2.get_mut(idx) };
                    slot[0] = local;
                    let cr = tm2.comm().iallreduce(slot, |a, b| a[0] += b[0]);
                    reqs2.lock().unwrap().push(cr.into_request());
                } else {
                    let mut v = [local];
                    tm2.allreduce(&mut v, |a, b| a[0] += b[0]);
                    // SAFETY: as above; collective completed in-task.
                    unsafe { store2.get_mut(idx) }[0] = v[0];
                }
            });
        }
    }
    rt.taskwait();
    // Harvest outstanding fire-and-forget residual collectives.
    for req in res_reqs.lock().unwrap().iter() {
        req.wait(&ctx.clock);
    }
    if res_rounds > 0 && ctx.rank == 0 {
        // SAFETY: all residual collectives completed above.
        let last = unsafe { res_store.value(res_rounds - 1) };
        counters.add("residual_bits", last.to_bits());
    }
    let local: f64 = if model { 0.0 } else { st.fields.checksum() };
    record_checksum(ctx, counters, local);
}

#[derive(Clone, Copy)]
enum Dir {
    GridToSpec,
    SpecToGrid,
}

/// One transposition task: isend my portion to every peer, irecv each
/// peer's portion, copy the local one, then Iwaitall (non-blocking mode)
/// or a task-aware waitall (blocking mode).
#[allow(clippy::too_many_arguments)]
fn spawn_transpose(
    rt: &crate::nanos::Runtime,
    tm: &Tampi,
    st: &Arc<IfsState>,
    obj_field: &DepObj,
    obj_spec: &DepObj,
    f: usize,
    tag: i32,
    nonblk: bool,
    dir: Dir,
) {
    let (src_obj, dst_obj) = match dir {
        Dir::GridToSpec => (obj_field, obj_spec),
        Dir::SpecToGrid => (obj_spec, obj_field),
    };
    let st2 = st.clone();
    let tm2 = tm.clone();
    rt.task()
        .label(format!("xpose f{f} t{tag}"))
        .dep(src_obj, Mode::In)
        .dep(dst_obj, Mode::Out)
        .spawn(move || {
            let n = st2.nranks;
            let r = st2.own_rank;
            let po = st2.portion;
            let mut reqs = Vec::with_capacity(2 * (n - 1));
            // Post all receives into disjoint destination portions.
            // SAFETY: out-dep on the destination block; the buffer stays
            // valid until the task's dependencies release (Iwaitall
            // semantics, Fig 5) because successors are event-gated.
            let dst: &mut [f32] = match dir {
                Dir::GridToSpec => unsafe { st2.spec.get_mut(f) },
                Dir::SpecToGrid => {
                    if st2.model {
                        // model: recv into spec as scratch (field is 1-elem)
                        unsafe { st2.spec.get_mut(f) }
                    } else {
                        unsafe { st2.fields.get_mut(f) }
                    }
                }
            };
            for q in 0..n {
                if q != r {
                    reqs.push(tm2.comm().irecv(&mut dst[q * po..(q + 1) * po], q as i32, tag));
                }
            }
            // Send my portions (eagerly copied by rmpi).
            for q in 0..n {
                if q == r {
                    continue;
                }
                let piece: Vec<f32> = if st2.model {
                    vec![0f32; po]
                } else {
                    // SAFETY: in-dep on the source block.
                    let src: &Vec<f32> = match dir {
                        Dir::GridToSpec => unsafe { st2.fields.get(f) },
                        Dir::SpecToGrid => unsafe { st2.spec.get(f) },
                    };
                    src[q * po..(q + 1) * po].to_vec()
                };
                reqs.push(tm2.comm().isend(&piece, q, tag));
            }
            // Local portion.
            if !st2.model {
                let (src, dst): (&Vec<f32>, &mut Vec<f32>) = match dir {
                    // SAFETY: deps cover both blocks of field f.
                    Dir::GridToSpec => unsafe { (st2.fields.get(f), st2.spec.get_mut(f)) },
                    Dir::SpecToGrid => unsafe { (st2.spec.get(f), st2.fields.get_mut(f)) },
                };
                dst[r * po..(r + 1) * po].copy_from_slice(&src[r * po..(r + 1) * po]);
            }
            if nonblk {
                tm2.iwaitall(&reqs); // Fig 5: dependencies gate completion
            } else {
                tm2.waitall(&reqs); // blocking mode: single pause
            }
        });
}
