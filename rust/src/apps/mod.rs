//! The paper's evaluation applications (Section 7).
//!
//! * [`gauss_seidel`] — iterative Gauss-Seidel heat-equation solver in the
//!   paper's five versions plus the non-blocking-TAMPI variant:
//!   `Pure MPI`, `N-Buffer MPI`, `Fork-Join`, `Sentinel`, `Interop(blk)`,
//!   `Interop(non-blk)` (Section 7.1).
//! * [`ifsker`] — the IFS weather-model communication mock-up in
//!   `Pure MPI`, `Interop(blk)`, `Interop(non-blk)` (Section 7.2).
//! * [`recovery`] — shrink-and-continue drivers: both apps surviving a
//!   mid-run rank failure via `comm_shrink()` (see `rmpi::faults`).
//!
//! Both apps run on the simulated cluster with a choice of compute
//! backend: real numerics in native Rust, real numerics through the
//! AOT-compiled Pallas kernels via PJRT, or a pure cost model for
//! large-scale sweeps ([`Compute`]).

pub mod gauss_seidel;
pub mod ifsker;
pub mod recovery;
pub mod store;

use crate::sim::VNanos;

/// How task compute bodies are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compute {
    /// Real f32 numerics in native Rust; virtual time charged by the cost
    /// model (deterministic figures, verified results).
    Native,
    /// Real numerics through the PJRT-compiled Pallas kernel (the
    /// three-layer hot path); virtual time charged by the cost model.
    Pjrt,
    /// No data is touched; only the cost model advances virtual time.
    /// Used for cluster-scale parameter sweeps.
    Model,
}

/// Calibrated per-cell cost of one Gauss-Seidel update (ns). Measured on
/// the reproduction host with the native kernel (see EXPERIMENTS.md §Perf);
/// override via `GsConfig::cell_ns`.
pub const DEFAULT_GS_CELL_NS: f64 = 2.5;

/// Cost model helper: ns for `cells` Gauss-Seidel cell updates.
pub fn gs_cost(cells: usize, cell_ns: f64) -> VNanos {
    (cells as f64 * cell_ns) as VNanos
}
