//! Figure-regeneration harness: one entry point per paper figure.
//!
//! Every figure of Section 7 has a `figN` function that sweeps the same
//! parameter grid the paper does (scaled to the simulated cluster; use
//! [`Scale::Full`] for paper-scale runs) and returns rows with speed-up
//! and parallel efficiency computed exactly as the paper defines them:
//!
//! * speed-up: against *Pure MPI on one node* (Figs 9, 11 top, 14);
//!   against the same version's one-node run in Figs 12/13.
//! * parallel efficiency: each version against its own one-node run.
//!
//! The binaries in `rust/benches/` print these tables; `repro figures`
//! drives them from the CLI.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::apps::gauss_seidel::{self, GsParams, GsVersion};
use crate::apps::ifsker::{self, IfsParams, IfsVersion};
use crate::apps::Compute;
use crate::sim::ms;
use crate::trace::{GraphRecorder, Tracer};

/// Virtual-time completion→resume latency of one pending in-task recv
/// under `mode` and the default delivery (the completion-pipeline
/// micro-figure; shared by `benches/micro_runtime.rs` and
/// `tests/tampi_callback.rs` so the calibrated scenario exists exactly
/// once). See [`completion_latency_with`].
pub fn completion_latency_ns(mode: crate::nanos::CompletionMode) -> u64 {
    completion_latency_with(
        mode,
        crate::progress::DeliveryMode::default(),
        crate::sim::us(50),
    )
}

/// [`completion_latency_ns`] parameterized over the delivery mode and
/// poll interval (the Fig 15 sweep). Measured from the request's
/// completion instant — observed by an `on_complete` continuation, which
/// fires at that instant in every mode (under sharded delivery it is
/// drained at the *same* virtual instant it was deposited) — to the
/// paused task's resumption. Polling mode is bounded by `poll_interval`;
/// callback mode pays only the modeled resume cost, in both delivery
/// modes. Deterministic in virtual time.
pub fn completion_latency_with(
    mode: crate::nanos::CompletionMode,
    delivery: crate::progress::DeliveryMode,
    poll_interval: u64,
) -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};

    use crate::rmpi::{ClusterConfig, ThreadLevel, Universe};
    use crate::sim::us;

    let arrived = Arc::new(AtomicU64::new(0));
    let resumed = Arc::new(AtomicU64::new(0));
    let (a2, r2) = (arrived.clone(), resumed.clone());
    let mut cfg = ClusterConfig::new(2, 1, 1)
        .with_completion_mode(mode)
        .with_delivery_mode(delivery);
    cfg.poll_interval = poll_interval.max(us(1));
    Universe::run(cfg, move |ctx| {
        let rt = ctx.rt.as_ref().unwrap();
        let tm = crate::tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
        if ctx.rank == 0 {
            let (a, r) = (a2.clone(), r2.clone());
            let tm = tm.clone();
            let clock = ctx.clock.clone();
            rt.task().label("recv").spawn(move || {
                let mut b = [0u8];
                let req = tm.comm().irecv(&mut b, 1, 0);
                let c2 = clock.clone();
                let a = a.clone();
                req.on_complete(move |_| a.store(c2.now(), Ordering::Relaxed));
                tm.wait(&req);
                r.store(clock.now(), Ordering::Relaxed);
            });
        } else {
            // Offset so the arrival does not align with a poll tick.
            ctx.clock.sleep(ms(1) + us(17));
            ctx.comm.send(&[9u8], 0, 0);
        }
    })
    .expect("completion-latency scenario");
    let (a, r) = (arrived.load(Ordering::Relaxed), resumed.load(Ordering::Relaxed));
    assert!(a > 0 && r >= a, "latency bookkeeping broken: arrived={a} resumed={r}");
    r - a
}

/// Delivery-path cost of one same-instant completion wave.
#[derive(Clone, Copy, Debug)]
pub struct WaveStats {
    /// Requests in the wave (= blocked tasks resumed by it).
    pub n: usize,
    /// Scheduler queue-lock acquisitions that inserted resumes:
    /// O(n) under direct delivery, O(shards) under sharded delivery.
    pub resume_lock_ops: u64,
    /// Shard batches drained (0 under direct delivery).
    pub delivery_batches: u64,
    /// Continuations delivered through shards (0 under direct).
    pub deliveries: u64,
    /// Largest single batch (= n when the wave lands as one batch).
    pub max_batch: u64,
    /// Virtual makespan — identical across delivery modes.
    pub vtime_ns: u64,
}

/// Run a same-instant N-request completion wave under `delivery` and
/// report the delivery-path stats (the acceptance scenario of the
/// sharded progress engine; shared by `benches/micro_runtime.rs`, the
/// fig15 harness and `tests/progress_sharded.rs`).
///
/// Rank 0 spawns `n` tasks, each pausing in a task-aware recv of its own
/// tag; rank 1 first sleeps so every receive is posted and every task
/// paused, then launches all `n` eager isends back-to-back — zero
/// virtual time between them, so all completions land at one virtual
/// instant. Under `Direct` each of the `n` continuations takes the
/// scheduler lock for its resume; under `Sharded` the wave is drained as
/// one batch on rank 0's shard and bulk-enqueued with a single lock
/// acquisition. Virtual time is identical either way.
pub fn completion_wave(n: usize, delivery: crate::progress::DeliveryMode) -> WaveStats {
    use crate::rmpi::{ClusterConfig, ThreadLevel, Universe};

    let cfg = ClusterConfig::new(2, 1, 2).with_delivery_mode(delivery);
    let stats = Universe::run(cfg, move |ctx| {
        if ctx.rank == 0 {
            let rt = ctx.rt.as_ref().unwrap();
            let tm = crate::tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            for i in 0..n {
                let tm = tm.clone();
                rt.task().label(format!("wave{i}")).spawn(move || {
                    let mut b = [0u32];
                    tm.recv(&mut b, 1, i as i32);
                    assert_eq!(b[0], 1);
                });
            }
            rt.taskwait();
        } else {
            // Let every receiver post and pause first, then launch the
            // whole wave in one virtual instant. isend only: a blocking
            // send would flush debt and stagger the send instants.
            ctx.clock.sleep(ms(5));
            let reqs: Vec<_> =
                (0..n).map(|i| ctx.comm.isend(&[1u32], 0, i as i32)).collect();
            for r in &reqs {
                assert!(r.test(), "eager wave send must complete immediately");
            }
        }
    })
    .expect("completion wave scenario");
    WaveStats {
        n,
        resume_lock_ops: stats.resume_lock_ops,
        delivery_batches: stats.delivery_batches,
        deliveries: stats.deliveries,
        max_batch: stats.max_batch,
        vtime_ns: stats.vtime_ns,
    }
}

/// Fig 15 (paper extension): completion→resume notification latency of
/// the three pipelines — poll-scan (swept over poll intervals),
/// callback + direct delivery, callback + sharded delivery. Returns
/// `(series, poll_interval_ns (0 = n/a), latency_ns)` rows; speedups are
/// computed against the 50 us polling row by [`fig15_report`].
pub fn fig15(scale: Scale) -> Vec<(String, u64, u64)> {
    use crate::nanos::CompletionMode;
    use crate::progress::DeliveryMode;
    use crate::sim::us;

    let intervals: Vec<u64> = match scale {
        Scale::Quick => vec![us(50)],
        Scale::Default => vec![us(10), us(50), us(200)],
        Scale::Full => vec![us(10), us(50), us(200), us(1000)],
    };
    let mut rows = Vec::new();
    for &pi in &intervals {
        let lat = completion_latency_with(CompletionMode::Polling, DeliveryMode::Sharded, pi);
        rows.push(("polling".to_string(), pi, lat));
    }
    rows.push((
        "callback-direct".to_string(),
        0,
        completion_latency_with(CompletionMode::Callback, DeliveryMode::Direct, us(50)),
    ));
    rows.push((
        "callback-sharded".to_string(),
        0,
        completion_latency_with(CompletionMode::Callback, DeliveryMode::Sharded, us(50)),
    ));
    rows
}

/// Render the full Fig 15 report: the latency table plus the
/// same-instant completion-wave delivery-cost table (direct vs sharded).
pub fn fig15_report(scale: Scale) -> String {
    use crate::progress::DeliveryMode;
    use crate::sim::us;

    let rows = fig15(scale);
    let base = rows
        .iter()
        .find(|(s, pi, _)| s == "polling" && *pi == us(50))
        .map(|&(_, _, l)| l)
        .unwrap_or(1)
        .max(1) as f64;
    let mut out = String::from(
        "=== Figure 15: completion->resume notification latency (paper extension) ===\n",
    );
    out.push_str(&format!(
        "{:<18} {:>9} {:>13} {:>18}\n",
        "series", "poll_us", "latency_ns", "speedup_vs_poll50"
    ));
    for (series, pi, lat) in &rows {
        let pi_s = if *pi == 0 { "-".to_string() } else { (pi / 1_000).to_string() };
        out.push_str(&format!(
            "{:<18} {:>9} {:>13} {:>18.1}\n",
            series,
            pi_s,
            lat,
            base / (*lat).max(1) as f64
        ));
    }

    let n = match scale {
        Scale::Quick => 64,
        Scale::Default => 256,
        Scale::Full => 1024,
    };
    out.push_str(&format!(
        "\n=== same-instant completion wave (N={n}): scheduler-lock traffic ===\n"
    ));
    out.push_str(&format!(
        "{:<10} {:>16} {:>9} {:>10} {:>10}\n",
        "delivery", "resume_lock_ops", "batches", "max_batch", "vtime_us"
    ));
    for (name, mode) in [
        ("direct", DeliveryMode::Direct),
        ("sharded", DeliveryMode::Sharded),
    ] {
        let w = completion_wave(n, mode);
        out.push_str(&format!(
            "{:<10} {:>16} {:>9} {:>10} {:>10}\n",
            name,
            w.resume_lock_ops,
            w.delivery_batches,
            w.max_batch,
            w.vtime_ns / 1_000
        ));
    }
    out.push_str(
        "(direct: one lock acquisition per resumed task; sharded: one per shard-batch)\n",
    );

    // Rank-count sweep: the same total wave spread over more receiver
    // ranks/shards — resume-lock traffic is O(N) under Direct and
    // O(shards) under Sharded (the cluster-scale crossover).
    let total = match scale {
        Scale::Quick => 16usize,
        Scale::Default => 64,
        Scale::Full => 128,
    };
    let rank_counts: Vec<usize> = match scale {
        Scale::Quick => vec![1, 2, 4],
        _ => vec![1, 2, 4, 8],
    };
    out.push_str(&format!(
        "\n=== completion-wave rank sweep (N={total} total): lock ops vs shards ===\n"
    ));
    out.push_str(&format!(
        "{:<6} {:>9} {:>16} {:>17} {:>16}\n",
        "ranks", "per_rank", "direct_lock_ops", "sharded_lock_ops", "sharded_batches"
    ));
    for &r in &rank_counts {
        let per = total / r;
        let d = completion_wave_ranks(r, per, DeliveryMode::Direct);
        let s = completion_wave_ranks(r, per, DeliveryMode::Sharded);
        assert_eq!(d.vtime_ns, s.vtime_ns, "delivery mode must not change time");
        out.push_str(&format!(
            "{:<6} {:>9} {:>16} {:>17} {:>16}\n",
            r, per, d.resume_lock_ops, s.resume_lock_ops, s.delivery_batches
        ));
    }
    out.push_str(
        "(direct scales with the wave size N; sharded with the receiver/shard count)\n",
    );
    out
}

/// [`completion_wave`] generalized over the receiver-rank count (the
/// fig15 rank sweep): `receivers` ranks each run `per_rank` blocked
/// recv tasks; one extra sender rank launches the whole wave at a
/// single virtual instant. Under `Direct` the resume burst takes a
/// scheduler lock per task — O(receivers x per_rank); under `Sharded`
/// one bulk enqueue per receiver shard — O(receivers). This is the
/// O(N)→O(shards) crossover at cluster scale.
pub fn completion_wave_ranks(
    receivers: usize,
    per_rank: usize,
    delivery: crate::progress::DeliveryMode,
) -> WaveStats {
    use crate::rmpi::{ClusterConfig, ThreadLevel, Universe};

    let cfg = ClusterConfig::new(receivers + 1, 1, 2).with_delivery_mode(delivery);
    let stats = Universe::run(cfg, move |ctx| {
        let sender = receivers; // last rank
        if ctx.rank < receivers {
            let rt = ctx.rt.as_ref().unwrap();
            let tm = crate::tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            for i in 0..per_rank {
                let tm = tm.clone();
                let tag = (ctx.rank * per_rank + i) as i32;
                rt.task().label(format!("wave{tag}")).spawn(move || {
                    let mut b = [0u32];
                    tm.recv(&mut b, sender as i32, tag);
                    assert_eq!(b[0], 1);
                });
            }
            rt.taskwait();
        } else {
            // Every receiver posts and pauses first; then the whole wave
            // launches in one virtual instant (eager isends only).
            ctx.clock.sleep(ms(5));
            let reqs: Vec<_> = (0..receivers * per_rank)
                .map(|t| ctx.comm.isend(&[1u32], t / per_rank, t as i32))
                .collect();
            for r in &reqs {
                assert!(r.test(), "eager wave send must complete immediately");
            }
        }
    })
    .expect("completion wave rank sweep scenario");
    WaveStats {
        n: receivers * per_rank,
        resume_lock_ops: stats.resume_lock_ops,
        delivery_batches: stats.delivery_batches,
        deliveries: stats.deliveries,
        max_batch: stats.max_batch,
        vtime_ns: stats.vtime_ns,
    }
}

/// One row of the fig16 synthetic overlap scenario.
#[derive(Clone, Copy, Debug)]
pub struct OverlapStats {
    /// Virtual makespan of the whole run.
    pub vtime_ns: u64,
    /// Final residual value (must be identical across series).
    pub residual: f64,
}

/// Fig 16 core scenario: `iters` rounds of "halo compute + residual
/// allreduce" on `ranks` ranks (no task runtime — the collective's
/// progress needs no caller thread at all).
///
/// * blocking (`nonblocking = false`): compute, then a blocking
///   allreduce — per iteration the collective latency L sits entirely
///   after the compute C: t_iter ≈ C + L.
/// * non-blocking: post `iallreduce` first, compute C while the
///   schedule-driven rounds progress on the engine, then wait the
///   [`crate::rmpi::CollRequest`]: t_iter ≈ max(C, L).
///
/// Residual values are bit-identical across the two series (same
/// combine tree, same order).
pub fn coll_overlap(
    ranks: usize,
    iters: usize,
    compute_ns: u64,
    nonblocking: bool,
) -> OverlapStats {
    use std::sync::atomic::{AtomicU64, Ordering};

    use crate::rmpi::{ClusterConfig, Universe};

    let residual_bits = Arc::new(AtomicU64::new(0));
    let rb = residual_bits.clone();
    let cfg = ClusterConfig::new(ranks, 1, 0);
    let stats = Universe::run(cfg, move |ctx| {
        let mut last = 0.0f64;
        for t in 0..iters {
            let seed = ctx.rank as f64 + t as f64;
            if nonblocking {
                let mut slot = [seed];
                let cr = ctx.comm.iallreduce(&mut slot, |a, b| a[0] += b[0]);
                ctx.clock.work(compute_ns); // overlaps the engine-driven rounds
                cr.wait();
                last = slot[0];
            } else {
                ctx.clock.work(compute_ns);
                let mut v = [seed];
                ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
                last = v[0];
            }
        }
        if ctx.rank == 0 {
            rb.store(last.to_bits(), Ordering::Release);
        }
    })
    .expect("coll_overlap scenario");
    OverlapStats {
        vtime_ns: stats.vtime_ns,
        residual: f64::from_bits(residual_bits.load(std::sync::atomic::Ordering::Acquire)),
    }
}

/// Fig 16 (paper extension): blocking vs non-blocking collectives —
/// schedule-driven `iallreduce` overlapping compute. Returns
/// `(series, ranks, compute_us, vtime_ms, speedup_vs_blocking)` rows:
/// a synthetic compute sweep plus Gauss-Seidel residual-monitoring rows
/// (`gs-residual-*`, blocking vs fire-and-forget residual allreduce).
pub fn fig16(scale: Scale) -> Vec<(String, usize, f64, f64, f64)> {
    fig16_with_overlap(scale).0
}

/// [`fig16`] plus the overlap-profiler summary of its Gauss-Seidel
/// residual runs: `(rows, (blocking, nonblocking))` overlap fractions
/// (share of in-flight-communication time hidden under compute — see
/// [`crate::obs::overlap`]). Stamped into `BENCH_fig16.json` so the CI
/// trajectory tracks *why* the non-blocking residual is faster, not
/// just that it is.
pub fn fig16_with_overlap(scale: Scale) -> (Vec<(String, usize, f64, f64, f64)>, (f64, f64)) {
    use crate::sim::us;

    let (ranks, iters, compute_list): (usize, usize, Vec<u64>) = match scale {
        Scale::Quick => (4, 8, vec![0, us(25), us(100)]),
        Scale::Default => (8, 16, vec![0, us(10), us(25), us(50), us(100)]),
        Scale::Full => (16, 32, vec![0, us(10), us(25), us(50), us(100), us(250)]),
    };
    let mut rows = Vec::new();
    for &c in &compute_list {
        let blk = coll_overlap(ranks, iters, c, false);
        let nblk = coll_overlap(ranks, iters, c, true);
        assert_eq!(
            blk.residual.to_bits(),
            nblk.residual.to_bits(),
            "overlap must not change the reduction result"
        );
        let c_us = c as f64 / 1_000.0;
        rows.push((
            "allreduce-blocking".to_string(),
            ranks,
            c_us,
            blk.vtime_ns as f64 / 1e6,
            1.0,
        ));
        rows.push((
            "iallreduce-overlap".to_string(),
            ranks,
            c_us,
            nblk.vtime_ns as f64 / 1e6,
            blk.vtime_ns as f64 / nblk.vtime_ns.max(1) as f64,
        ));
    }

    // Application rows: Gauss-Seidel with per-iteration residual
    // monitoring, blocking vs fire-and-forget iallreduce.
    let (rows_g, iters_g, nodes) = match scale {
        Scale::Quick => (256usize, 6usize, 2usize),
        _ => (512, 10, 2),
    };
    let mk = |nonblocking: bool, sink: &Arc<crate::obs::SpanSink>| {
        let mut p = GsParams::new(rows_g, rows_g, rows_g / 4, iters_g, nodes, 2,
            GsVersion::InteropNonBlk);
        // Native numerics: the bit-identity assertion below compares real
        // residual values (Model would reduce all-zero sums vacuously).
        p.compute = Compute::Native;
        p.residual_every = 1;
        p.residual_nonblocking = nonblocking;
        p.spans = Some(sink.clone());
        p.deadline = Some(ms(600_000));
        p
    };
    let overlap_of = |sink: &crate::obs::SpanSink| {
        let per = crate::obs::overlap::overlap_by_rank(&sink.snapshot());
        crate::obs::overlap::overlap_summary(&per).overlap_frac()
    };
    let sink_blk = crate::obs::SpanSink::new(1 << 20);
    let sink_nblk = crate::obs::SpanSink::new(1 << 20);
    let blk = gauss_seidel::run(&mk(false, &sink_blk)).expect("fig16 gs blocking residual");
    let nblk = gauss_seidel::run(&mk(true, &sink_nblk)).expect("fig16 gs non-blocking residual");
    assert_eq!(
        blk.residual.to_bits(),
        nblk.residual.to_bits(),
        "gs residual must be identical across blocking/non-blocking"
    );
    rows.push((
        "gs-residual-blocking".to_string(),
        nodes,
        f64::NAN,
        blk.vtime_ns as f64 / 1e6,
        1.0,
    ));
    rows.push((
        "gs-residual-iallreduce".to_string(),
        nodes,
        f64::NAN,
        nblk.vtime_ns as f64 / 1e6,
        blk.vtime_ns as f64 / nblk.vtime_ns.max(1) as f64,
    ));
    (rows, (overlap_of(&sink_blk), overlap_of(&sink_nblk)))
}

/// Render the fig16 report table.
pub fn fig16_report(scale: Scale) -> String {
    let (rows, (ov_blk, ov_nblk)) = fig16_with_overlap(scale);
    let mut out = String::from(
        "=== Figure 16: blocking vs non-blocking collectives (schedule engine overlap) ===\n",
    );
    out.push_str(&format!(
        "{:<24} {:>6} {:>11} {:>11} {:>9}\n",
        "series", "ranks", "compute_us", "vtime_ms", "speedup"
    ));
    for (series, ranks, c_us, vtime_ms, speedup) in &rows {
        let c = if c_us.is_nan() { "-".to_string() } else { format!("{c_us:.0}") };
        out.push_str(&format!(
            "{:<24} {:>6} {:>11} {:>11.3} {:>9.2}\n",
            series, ranks, c, vtime_ms, speedup
        ));
    }
    out.push_str(
        "(blocking: allreduce latency adds to every iteration; iallreduce: the\n\
         schedule-driven collective progresses on the engine while compute runs)\n",
    );
    out.push_str(&format!(
        "gs residual overlap fraction (comm time hidden under compute): \
         blocking {:.3}, iallreduce {:.3}\n",
        ov_blk, ov_nblk
    ));
    out
}

/// Virtual makespan of `reps` back-to-back collectives of one kind on a
/// `nodes x rpn` cluster under `topo`, with the network model's
/// per-message receiver-processing term set to `rx_ns` (the fig17
/// measurement point; also the substrate of `tests/coll_topology.rs`'s
/// hierarchical-not-slower assertions). Roots are deliberately *not*
/// node-aligned (rank 1) for bcast/gather so the re-rooted hierarchical
/// trees are exercised.
pub fn coll_topology_vtime(
    collective: &str,
    nodes: usize,
    rpn: usize,
    reps: usize,
    topo: crate::rmpi::TopologyMode,
    rx_ns: u64,
) -> u64 {
    let net = crate::rmpi::NetworkModel { rx_ns, ..Default::default() };
    coll_topology_vtime_net(collective, nodes, rpn, reps, topo, net)
}

/// [`coll_topology_vtime`] under an arbitrary [`crate::rmpi::NetworkModel`]
/// (fig18 threads the CLI's `--net-rx`/`--eager` overrides through here).
pub fn coll_topology_vtime_net(
    collective: &str,
    nodes: usize,
    rpn: usize,
    reps: usize,
    topo: crate::rmpi::TopologyMode,
    net: crate::rmpi::NetworkModel,
) -> u64 {
    use crate::rmpi::{ClusterConfig, Universe};

    let mut cfg = ClusterConfig::new(nodes, rpn, 0).with_topology(topo);
    cfg.net = net;
    cfg.deadline = Some(ms(600_000));
    let collective = collective.to_string();
    let stats = Universe::run(cfg, move |ctx| {
        let n = ctx.size;
        for _ in 0..reps {
            match collective.as_str() {
                "barrier" => ctx.comm.barrier(),
                "bcast" => {
                    let mut b = vec![if ctx.rank == 1 { 7u64 } else { 0 }; 8];
                    ctx.comm.bcast(&mut b, 1);
                    assert_eq!(b[0], 7);
                }
                "reduce" => {
                    let mut v = [ctx.rank as f64 + 0.5];
                    ctx.comm.reduce(&mut v, 0, |a, b| a[0] += b[0]);
                }
                "allreduce" => {
                    let mut v = [ctx.rank as f64 + 1.0];
                    ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
                }
                "gather" => {
                    let mine = [ctx.rank as u64];
                    if ctx.rank == 1 {
                        let mut all = vec![0u64; n];
                        ctx.comm.gather(&mine, Some(&mut all), 1);
                        for (r, &v) in all.iter().enumerate() {
                            assert_eq!(v, r as u64);
                        }
                    } else {
                        ctx.comm.gather(&mine, None, 1);
                    }
                }
                "alltoall" => {
                    let send: Vec<u32> =
                        (0..n).map(|d| (ctx.rank * 1000 + d) as u32).collect();
                    let mut recv = vec![0u32; n];
                    ctx.comm.alltoall(&send, &mut recv);
                    for (s, &v) in recv.iter().enumerate() {
                        assert_eq!(v, (s * 1000 + ctx.rank) as u32);
                    }
                }
                other => panic!("unknown collective {other}"),
            }
        }
    })
    .expect("coll_topology scenario");
    stats.vtime_ns
}

/// The six collectives fig17 sweeps.
pub const COLL_TOPOLOGY_KINDS: [&str; 6] =
    ["barrier", "bcast", "reduce", "allreduce", "gather", "alltoall"];

/// One fig17 flat-vs-hierarchical row.
#[derive(Clone, Debug)]
pub struct TopoRow {
    pub collective: String,
    pub nodes: usize,
    pub rpn: usize,
    pub flat_us: f64,
    pub hier_us: f64,
    pub speedup: f64,
}

/// One fig17 schedule-cache row: `calls` repeated same-shape
/// `iallreduce` with the persistent cache on or off, plus the
/// plan-store traffic behind it (cluster-plan compiles are O(1) per
/// `SchedKey` with the cache on; the cache-off baseline bypasses the
/// store and recompiles per call).
#[derive(Clone, Copy, Debug)]
pub struct SchedCacheRow {
    pub calls: usize,
    pub cache: bool,
    pub vtime_us: f64,
    pub hits: u64,
    pub misses: u64,
    pub plan_store_hits: u64,
    pub plan_store_misses: u64,
}

/// Run `calls` same-shape blocking allreduces and report the cache
/// traffic (cold compile per call vs compile-once-reuse).
pub fn coll_cache_run(calls: usize, cache: bool) -> SchedCacheRow {
    use crate::rmpi::{ClusterConfig, Universe};

    let cfg = ClusterConfig::new(2, 2, 0).with_sched_cache(cache);
    let stats = Universe::run(cfg, move |ctx| {
        for i in 0..calls {
            let mut v = [ctx.rank as f64 + i as f64];
            ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
        }
    })
    .expect("coll_cache scenario");
    SchedCacheRow {
        calls,
        cache,
        vtime_us: stats.vtime_ns as f64 / 1_000.0,
        hits: stats.sched_cache.hits,
        misses: stats.sched_cache.misses,
        plan_store_hits: stats.plan_store.hits,
        plan_store_misses: stats.plan_store.misses,
    }
}

/// Fig 17 (paper extension): topology-aware hierarchical schedules —
/// flat vs hierarchical virtual time per collective across a
/// ranks-per-node sweep (with the message-rate term `rx_ns` = 300 ns
/// so fan-in is visible), plus the persistent-schedule-cache cold vs
/// cached compile-cost table.
pub fn fig17(scale: Scale) -> (Vec<TopoRow>, Vec<SchedCacheRow>) {
    let (nodes, rpns, reps): (usize, Vec<usize>, usize) = match scale {
        Scale::Quick => (3, vec![2, 4], 4),
        Scale::Default => (4, vec![2, 4, 8], 8),
        Scale::Full => (8, vec![2, 4, 8, 16], 8),
    };
    let rx = 300u64;
    let mut rows = Vec::new();
    for kind in COLL_TOPOLOGY_KINDS {
        for &rpn in &rpns {
            let flat = coll_topology_vtime(
                kind,
                nodes,
                rpn,
                reps,
                crate::rmpi::TopologyMode::Flat,
                rx,
            );
            let hier = coll_topology_vtime(
                kind,
                nodes,
                rpn,
                reps,
                crate::rmpi::TopologyMode::Hierarchical,
                rx,
            );
            rows.push(TopoRow {
                collective: kind.to_string(),
                nodes,
                rpn,
                flat_us: flat as f64 / 1_000.0,
                hier_us: hier as f64 / 1_000.0,
                speedup: flat as f64 / hier.max(1) as f64,
            });
        }
    }
    let calls = match scale {
        Scale::Quick => 8,
        _ => 32,
    };
    let cache_rows = vec![
        coll_cache_run(calls, false),
        coll_cache_run(calls, true),
        coll_cache_run(1, true),
    ];
    (rows, cache_rows)
}

/// Render the fig17 report tables.
pub fn fig17_report(scale: Scale) -> String {
    let (rows, cache) = fig17(scale);
    let mut out = String::from(
        "=== Figure 17: topology-aware hierarchical collective schedules ===\n\
         (rx_ns = 300: per-message ingress-port processing; hierarchical = \n\
         cost-driven leader staging, never chosen when flat is cheaper)\n",
    );
    out.push_str(&format!(
        "{:<12} {:>6} {:>5} {:>10} {:>10} {:>9}\n",
        "collective", "nodes", "rpn", "flat_us", "hier_us", "speedup"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<12} {:>6} {:>5} {:>10.1} {:>10.1} {:>9.2}\n",
            r.collective, r.nodes, r.rpn, r.flat_us, r.hier_us, r.speedup
        ));
    }
    out.push_str(
        "\n=== persistent schedule cache: cold vs cached compile cost ===\n",
    );
    out.push_str(&format!(
        "{:<18} {:>6} {:>10} {:>6} {:>8} {:>9} {:>9}\n",
        "series", "calls", "vtime_us", "hits", "misses", "ps_hits", "ps_miss"
    ));
    for c in &cache {
        let series = match (c.cache, c.calls) {
            (false, _) => "compile-per-call",
            (true, 1) => "cold-first-call",
            (true, _) => "cached-reuse",
        };
        out.push_str(&format!(
            "{:<18} {:>6} {:>10.1} {:>6} {:>8} {:>9} {:>9}\n",
            series, c.calls, c.vtime_us, c.hits, c.misses, c.plan_store_hits,
            c.plan_store_misses
        ));
    }
    out.push_str(
        "(cached-reuse: every call after the first hits the per-communicator\n\
         plan index — hits >= ranks x (calls - 1); ps_miss: cluster-plan\n\
         compiles through the universe PlanStore, O(1) per schedule key;\n\
         see RunStats::sched_cache / RunStats::plan_store)\n",
    );
    out
}

/// Last delivery instant of an (n-1)-to-one p2p incast under one
/// delivery/wait combo: every rank but 0 sends one 64-byte eager
/// message to rank 0 at a single virtual instant (1 virtual ms in, so
/// both wait styles have long posted their receives); the returned
/// value is the virtual instant the *last* receive completes — i.e.
/// when rank 0's ingress port has processed the whole wave. `taskaware`
/// runs the receive side inside a task through TAMPI's blocking mode;
/// `park` waits on the rank main. The instant is a pure function of
/// the network model: identical across {Direct, Sharded} x
/// {park, taskaware} and any worker count (asserted by [`fig18`] and
/// `tests/net_ports.rs`).
pub fn p2p_incast_instant(
    nodes: usize,
    rpn: usize,
    rx_ns: u64,
    delivery: crate::progress::DeliveryMode,
    taskaware: bool,
) -> u64 {
    let net = crate::rmpi::NetworkModel { rx_ns, ..Default::default() };
    p2p_incast_instant_net(nodes, rpn, net, delivery, taskaware)
}

/// [`p2p_incast_instant`] under an arbitrary [`crate::rmpi::NetworkModel`]
/// (fig18 threads the CLI's `--net-rx`/`--eager` overrides through here).
pub fn p2p_incast_instant_net(
    nodes: usize,
    rpn: usize,
    net: crate::rmpi::NetworkModel,
    delivery: crate::progress::DeliveryMode,
    taskaware: bool,
) -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};

    use crate::rmpi::{ClusterConfig, Request, ThreadLevel, Universe};

    let cores = if taskaware { 1 } else { 0 };
    let mut cfg = ClusterConfig::new(nodes, rpn, cores).with_delivery_mode(delivery);
    cfg.net = net;
    cfg.deadline = Some(ms(600_000));
    let last = Arc::new(AtomicU64::new(0));
    let l2 = last.clone();
    Universe::run(cfg, move |ctx| {
        let n = ctx.size;
        if ctx.rank != 0 {
            // One instant, one wave: eager sends complete immediately.
            ctx.clock.sleep(ms(1));
            ctx.comm.isend(&[7u8; 64], 0, ctx.rank as i32);
            return;
        }
        let last = l2.clone();
        let clock = ctx.clock.clone();
        let comm = ctx.comm.clone();
        // Returns the buffers alongside the requests: the MPI contract
        // pins them until every receive completes.
        let body = move || {
            let mut bufs = vec![[0u8; 64]; n - 1];
            let reqs: Vec<Request> = bufs
                .iter_mut()
                .enumerate()
                .map(|(i, b)| comm.irecv(&mut b[..], (i + 1) as i32, (i + 1) as i32))
                .collect();
            for r in &reqs {
                let last = last.clone();
                let c = clock.clone();
                r.on_complete(move |_| {
                    last.fetch_max(c.now(), Ordering::AcqRel);
                });
            }
            (bufs, reqs)
        };
        if taskaware {
            let rt = ctx.rt.as_ref().unwrap();
            let tm = crate::tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            rt.task().label("incast-sink").spawn(move || {
                let (bufs, reqs) = body();
                tm.waitall(&reqs);
                drop(bufs);
            });
            rt.taskwait();
        } else {
            let (bufs, reqs) = body();
            Request::wait_all(ctx.comm.clock(), &reqs);
            drop(bufs);
        }
    })
    .expect("p2p incast scenario");
    let t = last.load(std::sync::atomic::Ordering::Acquire);
    assert!(t > 0, "incast bookkeeping broken");
    t
}

/// One fig18 row: an incast series at one receiver-processing cost.
#[derive(Clone, Debug)]
pub struct IncastRow {
    pub series: String,
    pub rx_ns: u64,
    pub vtime_us: f64,
}

/// Fig 18 (paper extension): the unified congestion story — p2p fan-in
/// and collective gather priced by the same ingress-port model. Sweeps
/// `rx_ns` and reports, per value:
///
/// * `p2p-incast` — last delivery instant of the raw (n-1)->0 isend
///   wave: grows linearly with `rx_ns` (the port serializes the wave),
///   asserted identical across {Direct, Sharded} x {park, taskaware};
/// * `gather-flat` — the same fan-in through a collective with flat
///   topology: same linear degradation, same model;
/// * `gather-hier` — leader staging absorbs the fan-in at node leaders,
///   flattening the curve (never slower than flat: cost-driven
///   selection against the same model).
///
/// The first figure where p2p and collectives share one congestion
/// story. `rx_override` (the `--net-rx` CLI knob) replaces the sweep
/// with a single point; `eager_override` (`--eager`) moves the
/// rendezvous threshold for every run of the figure.
pub fn fig18(
    scale: Scale,
    rx_override: Option<u64>,
    eager_override: Option<usize>,
) -> Vec<IncastRow> {
    use crate::progress::DeliveryMode;
    use crate::rmpi::{NetworkModel, TopologyMode};

    let (nodes, rpn): (usize, usize) = match scale {
        Scale::Quick => (2, 4),
        Scale::Default => (4, 4),
        Scale::Full => (8, 8),
    };
    let sweep: Vec<u64> = match rx_override {
        Some(rx) => vec![rx],
        None => match scale {
            Scale::Quick => vec![0, 200, 800],
            Scale::Default => vec![0, 100, 200, 400, 800],
            Scale::Full => vec![0, 100, 200, 400, 800, 1600],
        },
    };
    let mut rows = Vec::new();
    let mut prev_p2p = 0u64;
    for &rx in &sweep {
        let mut net = NetworkModel { rx_ns: rx, ..Default::default() };
        if let Some(e) = eager_override {
            net.eager_threshold = e;
        }
        // The tentpole invariance: the wave's last delivery instant is
        // a pure function of the network model. (Sharded, park) is the
        // reference; the loop covers the other three combos.
        let reference = p2p_incast_instant_net(nodes, rpn, net, DeliveryMode::Sharded, false);
        for delivery in [DeliveryMode::Direct, DeliveryMode::Sharded] {
            for taskaware in [false, true] {
                if delivery == DeliveryMode::Sharded && !taskaware {
                    continue; // the reference run itself
                }
                let got = p2p_incast_instant_net(nodes, rpn, net, delivery, taskaware);
                assert_eq!(
                    got, reference,
                    "incast instant diverged at rx={rx} ({delivery:?}, taskaware={taskaware})"
                );
            }
        }
        assert!(reference >= prev_p2p, "p2p incast must degrade monotonically in rx");
        prev_p2p = reference;
        // Report the wave's delivery span from its launch instant (the
        // senders fire 1 virtual ms in; see `p2p_incast_instant`).
        rows.push(IncastRow {
            series: "p2p-incast".into(),
            rx_ns: rx,
            vtime_us: (reference - ms(1)) as f64 / 1_000.0,
        });
        let flat = coll_topology_vtime_net("gather", nodes, rpn, 1, TopologyMode::Flat, net);
        let hier =
            coll_topology_vtime_net("gather", nodes, rpn, 1, TopologyMode::Hierarchical, net);
        assert!(hier <= flat, "hierarchical gather slower at rx={rx}: {hier} vs {flat}");
        rows.push(IncastRow {
            series: "gather-flat".into(),
            rx_ns: rx,
            vtime_us: flat as f64 / 1_000.0,
        });
        rows.push(IncastRow {
            series: "gather-hier".into(),
            rx_ns: rx,
            vtime_us: hier as f64 / 1_000.0,
        });
    }
    rows
}

/// Render the fig18 report table.
pub fn fig18_report(
    scale: Scale,
    rx_override: Option<u64>,
    eager_override: Option<usize>,
) -> String {
    let rows = fig18(scale, rx_override, eager_override);
    let mut out = String::from(
        "=== Figure 18: incast congestion — one port model for p2p and collectives ===\n\
         (p2p-incast: delivery span of an (n-1)->0 eager wave, measured from its\n\
         launch instant; identical across {Direct,Sharded} x {park,taskaware}.\n\
         gather-*: the same fan-in through the collective engine, flat vs\n\
         leader-staged.)\n",
    );
    out.push_str(&format!("{:<12} {:>8} {:>12}\n", "series", "rx_ns", "vtime_us"));
    for r in &rows {
        out.push_str(&format!("{:<12} {:>8} {:>12.1}\n", r.series, r.rx_ns, r.vtime_us));
    }
    out.push_str(
        "(flat fan-in degrades linearly with rx_ns; hierarchical leader staging\n\
         flattens it — selected by the same NetworkModel the engine charges)\n",
    );
    out
}

/// Compiler-estimate vs engine-observation pair for one collective: the
/// parity contract of the unified network layer. The observed side runs
/// the blocking collective once on a `nodes x rpn` cluster with CPU
/// call costs zeroed (`call_cpu_ns`/`sched_*` — the estimate prices the
/// wire schedule, not caller-side library overhead) and `rx_ns` set;
/// the estimated side queries
/// [`crate::rmpi::estimate_critical_path`] with the same shape. The two
/// must be *equal* (asserted per collective in `tests/net_ports.rs`).
/// `kind` additionally accepts `"bcast-big"`: a rendezvous-size
/// broadcast (96 KiB > the 64 KiB eager threshold).
pub fn coll_parity_pair(
    kind: &str,
    nodes: usize,
    rpn: usize,
    topo: crate::rmpi::TopologyMode,
    rx_ns: u64,
) -> (u64, u64) {
    use crate::rmpi::{estimate_critical_path, ClusterConfig, NetworkModel, Universe};

    let net = NetworkModel {
        rx_ns,
        call_cpu_ns: 0,
        sched_compile_ns: 0,
        sched_cache_hit_ns: 0,
        ..NetworkModel::default()
    };
    // Canonical payloads per kind: (engine collective, root, bytes).
    let (coll, root, bytes) = match kind {
        "barrier" => ("barrier", 0, 0),
        "bcast" => ("bcast", 1, 64),
        "bcast-big" => ("bcast", 1, 96 * 1024),
        "reduce" => ("reduce", 0, 8),
        "allreduce" => ("allreduce", 0, 8),
        "allreduce-comm" => ("allreduce-comm", 0, 8),
        "gather" => ("gather", 1, 8),
        "alltoall" => ("alltoall", 0, 4),
        other => panic!("unknown parity kind {other}"),
    };
    let estimated = estimate_critical_path(coll, root, bytes, nodes, rpn, topo, &net);

    let mut cfg = ClusterConfig::new(nodes, rpn, 0).with_topology(topo);
    cfg.net = net;
    cfg.deadline = Some(ms(600_000));
    let kind_owned = kind.to_string();
    let stats = Universe::run(cfg, move |ctx| {
        let n = ctx.size;
        let r = ctx.rank;
        match kind_owned.as_str() {
            "barrier" => ctx.comm.barrier(),
            "bcast" => {
                let mut b = [if r == 1 { 9u64 } else { 0 }; 8];
                ctx.comm.bcast(&mut b, 1);
                assert_eq!(b[0], 9);
            }
            "bcast-big" => {
                let mut b = vec![if r == 1 { 3u8 } else { 0 }; 96 * 1024];
                ctx.comm.bcast(&mut b, 1);
                assert_eq!(b[0], 3);
            }
            "reduce" => {
                let mut v = [r as u64];
                ctx.comm.reduce(&mut v, 0, |a: &mut [u64], b: &[u64]| a[0] += b[0]);
                if r == 0 {
                    assert_eq!(v[0], (0..n as u64).sum::<u64>());
                }
            }
            "allreduce" => {
                let mut v = [r as u64];
                ctx.comm.allreduce(&mut v, |a: &mut [u64], b: &[u64]| a[0] += b[0]);
                assert_eq!(v[0], (0..n as u64).sum::<u64>());
            }
            "allreduce-comm" => {
                let mut v = [r as u64];
                ctx.comm.allreduce_op(
                    &mut v,
                    crate::rmpi::commutative(|a: &mut [u64], b: &[u64]| a[0] += b[0]),
                );
                assert_eq!(v[0], (0..n as u64).sum::<u64>());
            }
            "gather" => {
                let mine = [r as u64];
                if r == 1 {
                    let mut all = vec![0u64; n];
                    ctx.comm.gather(&mine, Some(&mut all), 1);
                } else {
                    ctx.comm.gather(&mine, None, 1);
                }
            }
            "alltoall" => {
                let send: Vec<u32> = (0..n).map(|d| (r * 101 + d) as u32).collect();
                let mut recv = vec![0u32; n];
                ctx.comm.alltoall(&send, &mut recv);
            }
            other => panic!("unknown parity kind {other}"),
        }
    })
    .expect("parity scenario");
    (estimated, stats.vtime_ns)
}

// ------------------------------------------------------------------
// Machine-readable figure output (the CI perf trajectory): one JSON
// document per figure, schema-checked by scripts/validate_bench.py.
// ------------------------------------------------------------------

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Wrap one figure's rows in the common document envelope.
/// `elapsed_host_ns` is the host wall-time the emitter spent producing
/// the rows (the CI perf-trajectory denominator; satellite of fig19's
/// simulator-throughput story).
fn json_doc(fig: u32, scale: Scale, elapsed_host_ns: u64, body: String) -> String {
    let scale = match scale {
        Scale::Quick => "quick",
        Scale::Default => "default",
        Scale::Full => "full",
    };
    format!(
        "{{\"schema_version\":1,\"fig\":{fig},\"scale\":\"{scale}\",\
         \"elapsed_host_ns\":{elapsed_host_ns},{body}}}\n"
    )
}

/// Fig 15 as JSON: `rows[] = {{series, poll_us|null, latency_ns}}`.
pub fn fig15_json(scale: Scale) -> String {
    let wall = std::time::Instant::now();
    let rows: Vec<String> = fig15(scale)
        .into_iter()
        .map(|(series, pi, lat)| {
            let poll = if pi == 0 { "null".to_string() } else { (pi / 1_000).to_string() };
            format!(
                "{{\"series\":\"{}\",\"poll_us\":{},\"latency_ns\":{}}}",
                json_escape(&series),
                poll,
                lat
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(15, scale, elapsed, format!("\"rows\":[{}]", rows.join(",")))
}

/// Fig 16 as JSON: `rows[] = {{series, ranks, compute_us|null, vtime_ms,
/// speedup}}` plus `overlap = {{blocking, nonblocking}}` (the overlap-
/// profiler summary of the gs residual runs).
pub fn fig16_json(scale: Scale) -> String {
    let wall = std::time::Instant::now();
    let (raw_rows, (ov_blk, ov_nblk)) = fig16_with_overlap(scale);
    let rows: Vec<String> = raw_rows
        .into_iter()
        .map(|(series, ranks, c_us, vtime_ms, speedup)| {
            let c = if c_us.is_nan() { "null".to_string() } else { format!("{c_us}") };
            format!(
                "{{\"series\":\"{}\",\"ranks\":{},\"compute_us\":{},\"vtime_ms\":{},\
                 \"speedup\":{}}}",
                json_escape(&series),
                ranks,
                c,
                vtime_ms,
                speedup
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(
        16,
        scale,
        elapsed,
        format!(
            "\"rows\":[{}],\"overlap\":{{\"blocking\":{},\"nonblocking\":{}}}",
            rows.join(","),
            ov_blk,
            ov_nblk
        ),
    )
}

/// Fig 17 as JSON: the topology sweep in `rows[]`, the cache table in
/// `cache[]`.
pub fn fig17_json(scale: Scale) -> String {
    let wall = std::time::Instant::now();
    let (rows, cache) = fig17(scale);
    let rows: Vec<String> = rows
        .into_iter()
        .map(|r| {
            format!(
                "{{\"collective\":\"{}\",\"nodes\":{},\"rpn\":{},\"flat_us\":{},\
                 \"hier_us\":{},\"speedup\":{}}}",
                json_escape(&r.collective),
                r.nodes,
                r.rpn,
                r.flat_us,
                r.hier_us,
                r.speedup
            )
        })
        .collect();
    let cache: Vec<String> = cache
        .into_iter()
        .map(|c| {
            format!(
                "{{\"calls\":{},\"cache\":{},\"vtime_us\":{},\"hits\":{},\"misses\":{},\
                 \"plan_store_hits\":{},\"plan_store_misses\":{}}}",
                c.calls, c.cache, c.vtime_us, c.hits, c.misses, c.plan_store_hits,
                c.plan_store_misses
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(
        17,
        scale,
        elapsed,
        format!("\"rows\":[{}],\"cache\":[{}]", rows.join(","), cache.join(",")),
    )
}

/// Fig 18 as JSON: `rows[] = {{series, rx_ns, vtime_us}}`.
pub fn fig18_json(
    scale: Scale,
    rx_override: Option<u64>,
    eager_override: Option<usize>,
) -> String {
    let wall = std::time::Instant::now();
    let rows: Vec<String> = fig18(scale, rx_override, eager_override)
        .into_iter()
        .map(|r| {
            format!(
                "{{\"series\":\"{}\",\"rx_ns\":{},\"vtime_us\":{}}}",
                json_escape(&r.series),
                r.rx_ns,
                r.vtime_us
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(18, scale, elapsed, format!("\"rows\":[{}]", rows.join(",")))
}

/// One fig19 row: the same deterministic run with the clock sharded
/// over `shards` lanes.
#[derive(Clone, Debug)]
pub struct ShardRow {
    pub nodes: usize,
    pub shards: usize,
    /// Virtual makespan — asserted identical across shard counts.
    pub vtime_ms: f64,
    /// Host wall-time of the run (the quantity fig19 sweeps).
    pub host_ms: f64,
    /// Clock events fired (identical work across shard counts up to
    /// per-lane deadline flags).
    pub clock_events: u64,
    /// Events pushed across lanes (0 at 1 shard).
    pub cross_shard_events: u64,
    /// Simulator throughput: clock events per host millisecond.
    pub events_per_host_ms: f64,
    /// Host wall-time speed-up vs the 1-lane run of the same shape.
    pub speedup_vs_1: f64,
}

/// Fig 19 (paper extension): the parallel discrete-event core — host
/// wall-time of one fixed Gauss-Seidel run as the clock is sharded over
/// 1/2/4/8 lanes (clamped to the node count). Every multi-lane run is
/// asserted bit-identical to the 1-lane run in its full deterministic
/// projection — checksum, virtual makespan, task and pause counts,
/// schedule-cache traffic — so the sweep measures host parallelism,
/// never semantic drift. (Host wall-times are machine-dependent and
/// noisy at `Quick` scale; the CI job only warns on regressions, see
/// `scripts/bench_delta.py`.)
pub fn fig19(scale: Scale) -> Vec<ShardRow> {
    let (rows_g, block, iters, nodes, cpn): (usize, usize, usize, usize, usize) = match scale {
        Scale::Quick => (512, 128, 8, 4, 2),
        Scale::Default => (2048, 256, 16, 8, 4),
        Scale::Full => (4096, 512, 32, 16, 8),
    };
    let mut out = Vec::new();
    // (checksum bits, vtime, tasks, pauses, cache, host_ns) of the
    // 1-lane reference.
    let mut base: Option<(u64, u64, u64, u64, crate::rmpi::SchedCacheStats, u64)> = None;
    for shards in [1usize, 2, 4, 8] {
        if shards > nodes {
            break;
        }
        let mut p = GsParams::new(
            rows_g,
            rows_g,
            block,
            iters,
            nodes,
            cpn,
            GsVersion::InteropNonBlk,
        );
        p.compute = Compute::Model;
        p.clock_shards = shards;
        p.deadline = Some(ms(600_000));
        let run = gauss_seidel::run(&p).expect("fig19 run");
        let s = &run.stats;
        let host_ns = s.elapsed_host_ns.max(1);
        match &base {
            None => {
                base = Some((
                    run.checksum.to_bits(),
                    s.vtime_ns,
                    s.tasks,
                    s.pauses,
                    s.sched_cache,
                    host_ns,
                ));
            }
            Some((ck, vt, tasks, pauses, cache, _)) => {
                // The tentpole guarantee: sharding changes host timing
                // only. Any divergence here is an engine bug, not noise.
                assert_eq!(run.checksum.to_bits(), *ck, "fig19: checksum diverged at {shards} lanes");
                assert_eq!(s.vtime_ns, *vt, "fig19: vtime diverged at {shards} lanes");
                assert_eq!(s.tasks, *tasks, "fig19: task count diverged at {shards} lanes");
                assert_eq!(s.pauses, *pauses, "fig19: pause count diverged at {shards} lanes");
                assert_eq!(s.sched_cache, *cache, "fig19: cache traffic diverged at {shards} lanes");
            }
        }
        let base_host = base.as_ref().unwrap().5;
        out.push(ShardRow {
            nodes,
            shards,
            vtime_ms: s.vtime_ns as f64 / 1e6,
            host_ms: host_ns as f64 / 1e6,
            clock_events: s.clock_events,
            cross_shard_events: s.cross_shard_events,
            events_per_host_ms: s.clock_events as f64 / (host_ns as f64 / 1e6),
            speedup_vs_1: base_host as f64 / host_ns as f64,
        });
    }
    out
}

/// Render the fig19 report table.
pub fn fig19_report(scale: Scale) -> String {
    let rows = fig19(scale);
    let mut out = String::from(
        "=== Figure 19: sharded simulation clock — host wall-time vs lanes ===\n\
         (one deterministic Gauss-Seidel run; every row asserted bit-identical\n\
         to the 1-lane run: checksum, vtime, tasks, pauses, cache traffic)\n",
    );
    out.push_str(&format!(
        "{:<6} {:>7} {:>10} {:>9} {:>12} {:>12} {:>13} {:>8}\n",
        "nodes", "shards", "vtime_ms", "host_ms", "clock_evts", "cross_shard", "evts/host_ms", "speedup"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<6} {:>7} {:>10.2} {:>9.1} {:>12} {:>12} {:>13.0} {:>8.2}\n",
            r.nodes,
            r.shards,
            r.vtime_ms,
            r.host_ms,
            r.clock_events,
            r.cross_shard_events,
            r.events_per_host_ms,
            r.speedup_vs_1
        ));
    }
    out.push_str(
        "(lanes advance concurrently under conservative lookahead = the\n\
         inter-node wire latency; merged event order is scheduling-independent)\n",
    );
    out
}

/// Fig 19 as JSON: `rows[] = {{nodes, shards, vtime_ms, host_ms,
/// clock_events, cross_shard_events, speedup_vs_1}}`.
pub fn fig19_json(scale: Scale) -> String {
    let wall = std::time::Instant::now();
    let rows: Vec<String> = fig19(scale)
        .into_iter()
        .map(|r| {
            format!(
                "{{\"nodes\":{},\"shards\":{},\"vtime_ms\":{},\"host_ms\":{},\
                 \"clock_events\":{},\"cross_shard_events\":{},\"speedup_vs_1\":{}}}",
                r.nodes,
                r.shards,
                r.vtime_ms,
                r.host_ms,
                r.clock_events,
                r.cross_shard_events,
                r.speedup_vs_1
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(19, scale, elapsed, format!("\"rows\":[{}]", rows.join(",")))
}

/// One fig20 row: the overlap-profiler summary of one app run.
#[derive(Clone, Debug)]
pub struct OverlapRow {
    /// Application: `gs` or `ifsker`.
    pub app: String,
    /// Version under test (`interop-blk` / `interop-nonblk`).
    pub series: String,
    pub ranks: usize,
    pub vtime_ms: f64,
    /// Fraction of the rank-summed timeline spent executing tasks.
    pub busy_frac: f64,
    /// Fraction of the timeline with communication in flight.
    pub comm_frac: f64,
    /// The headline: fraction of in-flight-communication time hidden
    /// under compute (`overlap / comm`, see [`crate::obs::overlap`]).
    pub overlap_frac: f64,
}

/// Fig 20 (paper extension): the overlap profiler — per-run
/// busy/comm/overlapped fractions of blocking vs non-blocking TAMPI on
/// both apps. This turns the paper's qualitative claim (Sections 4–6:
/// task-aware MPI "naturally overlaps computation and communication")
/// into one measured number per version, and asserts its direction:
/// the non-blocking gs run must hide strictly more of its
/// communication than the blocking one (ifsker: at least as much).
pub fn fig20(scale: Scale) -> Vec<OverlapRow> {
    let (rows_g, iters, nodes, cpn) = match scale {
        Scale::Quick => (256usize, 6usize, 2usize, 2usize),
        Scale::Default => (512, 10, 2, 4),
        Scale::Full => (1024, 16, 4, 8),
    };
    // One profiled run: fresh sink, run, integrate. The sink must not
    // overflow — a truncated timeline would silently understate comm.
    let profile = |sink: &Arc<crate::obs::SpanSink>, vtime_ns: u64| {
        assert_eq!(sink.dropped(), 0, "fig20: span sink overflowed");
        let per = crate::obs::overlap::overlap_by_rank(&sink.snapshot());
        let sum = crate::obs::overlap::overlap_summary(&per);
        (
            vtime_ns as f64 / 1e6,
            sum.busy_frac(),
            sum.comm_frac(),
            sum.overlap_frac(),
        )
    };
    let gs = |version: GsVersion| {
        let sink = crate::obs::SpanSink::new(1 << 20);
        let mut p = GsParams::new(rows_g, rows_g, rows_g / 4, iters, nodes, cpn, version);
        p.compute = Compute::Model;
        p.spans = Some(sink.clone());
        p.deadline = Some(ms(600_000));
        let run = gauss_seidel::run(&p).expect("fig20 gs");
        profile(&sink, run.vtime_ns)
    };
    let ifs = |version: IfsVersion| {
        let sink = crate::obs::SpanSink::new(1 << 20);
        let mut p = IfsParams::new(4 * nodes * cpn * nodes * cpn, 4, iters, nodes, cpn, version);
        p.compute = Compute::Model;
        p.spans = Some(sink.clone());
        p.deadline = Some(ms(600_000));
        let run = ifsker::run(&p).expect("fig20 ifsker");
        profile(&sink, run.vtime_ns)
    };
    let mut out = Vec::new();
    let mut push = |app: &str, series: &str, ranks: usize, r: (f64, f64, f64, f64)| {
        out.push(OverlapRow {
            app: app.to_string(),
            series: series.to_string(),
            ranks,
            vtime_ms: r.0,
            busy_frac: r.1,
            comm_frac: r.2,
            overlap_frac: r.3,
        });
    };
    let gs_blk = gs(GsVersion::InteropBlk);
    let gs_nblk = gs(GsVersion::InteropNonBlk);
    assert!(
        gs_nblk.3 > gs_blk.3,
        "fig20: non-blocking gs must overlap strictly more than blocking \
         (blk {:.4}, nonblk {:.4})",
        gs_blk.3,
        gs_nblk.3
    );
    push("gs", "interop-blk", nodes, gs_blk);
    push("gs", "interop-nonblk", nodes, gs_nblk);
    let ifs_blk = ifs(IfsVersion::InteropBlk);
    let ifs_nblk = ifs(IfsVersion::InteropNonBlk);
    assert!(
        ifs_nblk.3 >= ifs_blk.3,
        "fig20: non-blocking ifsker must overlap at least as much as blocking \
         (blk {:.4}, nonblk {:.4})",
        ifs_blk.3,
        ifs_nblk.3
    );
    push("ifsker", "interop-blk", nodes * cpn, ifs_blk);
    push("ifsker", "interop-nonblk", nodes * cpn, ifs_nblk);
    out
}

/// Render the fig20 report table.
pub fn fig20_report(scale: Scale) -> String {
    let rows = fig20(scale);
    let mut out = String::from(
        "=== Figure 20: comm/compute overlap profile — blocking vs non-blocking TAMPI ===\n",
    );
    out.push_str(&format!(
        "{:<8} {:<16} {:>6} {:>10} {:>10} {:>10} {:>12}\n",
        "app", "series", "ranks", "vtime_ms", "busy_frac", "comm_frac", "overlap_frac"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<8} {:<16} {:>6} {:>10.3} {:>10.3} {:>10.3} {:>12.3}\n",
            r.app, r.series, r.ranks, r.vtime_ms, r.busy_frac, r.comm_frac, r.overlap_frac
        ));
    }
    out.push_str(
        "(overlap_frac = share of in-flight-communication time spent computing;\n\
         blocking tasks pause inside each call, non-blocking requests ride\n\
         alongside other tasks' compute — Sections 4-6 of the paper, measured)\n",
    );
    out
}

/// Fig 20 as JSON: `rows[] = {{app, series, ranks, vtime_ms, busy_frac,
/// comm_frac, overlap_frac}}`.
pub fn fig20_json(scale: Scale) -> String {
    let wall = std::time::Instant::now();
    let rows: Vec<String> = fig20(scale)
        .into_iter()
        .map(|r| {
            format!(
                "{{\"app\":\"{}\",\"series\":\"{}\",\"ranks\":{},\"vtime_ms\":{},\
                 \"busy_frac\":{},\"comm_frac\":{},\"overlap_frac\":{}}}",
                json_escape(&r.app),
                json_escape(&r.series),
                r.ranks,
                r.vtime_ms,
                r.busy_frac,
                r.comm_frac,
                r.overlap_frac
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(20, scale, elapsed, format!("\"rows\":[{}]", rows.join(",")))
}

/// One fig21 plan-compilation row: host-side compile work for one cold
/// communicator of `ranks` ranks under one compile strategy.
#[derive(Clone, Debug)]
pub struct PlanCompileRow {
    pub collective: &'static str,
    pub nodes: usize,
    pub rpn: usize,
    pub ranks: usize,
    pub strategy: &'static str,
    /// Compiler invocations (per-rank: one per rank; service: one).
    pub compiles: u64,
    /// Event-heap pops across all candidate critical-path replays.
    pub replay_events: u64,
    pub memo_hits: u64,
    pub closed_form_hits: u64,
    pub host_us: f64,
}

/// Compile the cold-communicator alltoall plan for a `nodes x rpn`
/// blocked cluster under one strategy and report the work it took.
///
/// The strategies retrace the service's tiers: `per-rank` is the
/// pre-service baseline (every rank runs the full compiler — no store,
/// no memo, no closed forms), `cluster` compiles once for all ranks
/// with the tier-2 replay memo attached, `closed-form` adds the tier-3
/// fast paths. All three produce bit-identical plans; only the host
/// work differs.
fn plan_compile_probe(nodes: usize, rpn: usize, strategy: &'static str) -> PlanCompileRow {
    use crate::rmpi::topology::{
        compile_cluster_plans, compile_plan, CollKind, CompileStats, ReplayMemo, SchedKey,
        ShapeKey, TopoCtx,
    };
    use crate::rmpi::{NetworkModel, TopologyMode};

    let ranks = nodes * rpn;
    let node_of: Vec<usize> = (0..ranks).map(|r| r / rpn).collect();
    // Congested receiver ports so the flat-vs-hier comparison exercises
    // the full event-driven replay (rx-free replays are near-trivial).
    let net = NetworkModel { rx_ns: 400, ..NetworkModel::default() };
    let key = SchedKey {
        kind: CollKind::Alltoall,
        root: 0,
        shape: ShapeKey::ChunkBytes(4 * 1024),
        avoid: 0,
    };
    let stats = CompileStats::default();
    let memo = ReplayMemo::default();

    let t0 = std::time::Instant::now();
    let compiles = match strategy {
        "per-rank" => {
            for rank in 0..ranks {
                let mut ctx =
                    TopoCtx::service(rank, ranks, &node_of, TopologyMode::Hierarchical, &net);
                ctx.stats = Some(&stats);
                ctx.closed_form = false;
                std::hint::black_box(compile_plan(&key, &ctx));
            }
            ranks as u64
        }
        "cluster" => {
            let mut ctx = TopoCtx::service(0, ranks, &node_of, TopologyMode::Hierarchical, &net);
            ctx.stats = Some(&stats);
            ctx.memo = Some(&memo);
            ctx.closed_form = false;
            std::hint::black_box(compile_cluster_plans(&key, &ctx));
            1
        }
        _ => {
            // closed-form: `TopoCtx::service` already has tier 3 on.
            let mut ctx = TopoCtx::service(0, ranks, &node_of, TopologyMode::Hierarchical, &net);
            ctx.stats = Some(&stats);
            ctx.memo = Some(&memo);
            std::hint::black_box(compile_cluster_plans(&key, &ctx));
            1
        }
    };
    PlanCompileRow {
        collective: "alltoall",
        nodes,
        rpn,
        ranks,
        strategy,
        compiles,
        replay_events: stats.replay_events(),
        memo_hits: stats.memo_hits(),
        closed_form_hits: stats.closed_form_hits(),
        host_us: t0.elapsed().as_nanos() as f64 / 1_000.0,
    }
}

/// Fig 21 (repro extension): cold-communicator plan-compile cost over
/// rank counts, per-rank-compile vs cluster-wide vs closed-form — the
/// plan compilation service's host-side win, with virtual time held
/// bit-identical across strategies by construction.
pub fn fig21(scale: Scale) -> Vec<PlanCompileRow> {
    let shapes: &[(usize, usize)] = match scale {
        Scale::Quick => &[(4, 4), (8, 8)],
        Scale::Default => &[(4, 4), (8, 8), (16, 8)],
        Scale::Full => &[(4, 4), (8, 8), (16, 8), (16, 16)],
    };
    let mut rows = Vec::new();
    for &(nodes, rpn) in shapes {
        let per_rank = plan_compile_probe(nodes, rpn, "per-rank");
        let cluster = plan_compile_probe(nodes, rpn, "cluster");
        let closed = plan_compile_probe(nodes, rpn, "closed-form");
        // The service's whole point, checked in-harness: one compile
        // replaces `ranks` of them, dropping cold-start replay events
        // by at least the rank count (acceptance gate at >= 64 ranks),
        // and closed forms never add replays on a regular shape.
        let ranks = nodes * rpn;
        if ranks >= 64 {
            assert!(
                per_rank.replay_events >= cluster.replay_events + ranks as u64,
                "cluster-wide compile must save >= {} replay events (per-rank {}, cluster {})",
                ranks,
                per_rank.replay_events,
                cluster.replay_events
            );
        }
        assert!(closed.replay_events <= cluster.replay_events);
        rows.push(per_rank);
        rows.push(cluster);
        rows.push(closed);
    }
    rows
}

pub fn fig21_report(scale: Scale) -> String {
    let rows = fig21(scale);
    let mut out = String::from(
        "=== Figure 21: cold-communicator plan-compile cost — per-rank vs cluster-wide vs closed-form ===\n",
    );
    out.push_str(&format!(
        "{:<10} {:>5} {:>4} {:>6} {:<12} {:>9} {:>14} {:>10} {:>12} {:>10}\n",
        "collective",
        "nodes",
        "rpn",
        "ranks",
        "strategy",
        "compiles",
        "replay_events",
        "memo_hits",
        "closed_hits",
        "host_us"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<10} {:>5} {:>4} {:>6} {:<12} {:>9} {:>14} {:>10} {:>12} {:>10.1}\n",
            r.collective,
            r.nodes,
            r.rpn,
            r.ranks,
            r.strategy,
            r.compiles,
            r.replay_events,
            r.memo_hits,
            r.closed_form_hits,
            r.host_us
        ));
    }
    out.push_str(
        "(per-rank: pre-service baseline, every rank runs the full compiler;\n\
         cluster: one compile serves every rank through the universe\n\
         PlanStore, candidate replays memoized; closed-form: tier-3 exact\n\
         fast paths replace event-driven replays on regular shapes — host\n\
         cost only, the compiled plans are bit-identical across strategies)\n",
    );
    out
}

/// Fig 21 as JSON: `rows[] = {{collective, nodes, rpn, ranks, strategy,
/// compiles, replay_events, memo_hits, closed_form_hits, host_us}}`.
pub fn fig21_json(scale: Scale) -> String {
    let wall = std::time::Instant::now();
    let rows: Vec<String> = fig21(scale)
        .into_iter()
        .map(|r| {
            format!(
                "{{\"collective\":\"{}\",\"nodes\":{},\"rpn\":{},\"ranks\":{},\
                 \"strategy\":\"{}\",\"compiles\":{},\"replay_events\":{},\
                 \"memo_hits\":{},\"closed_form_hits\":{},\"host_us\":{}}}",
                json_escape(r.collective),
                r.nodes,
                r.rpn,
                r.ranks,
                json_escape(r.strategy),
                r.compiles,
                r.replay_events,
                r.memo_hits,
                r.closed_form_hits,
                r.host_us
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(21, scale, elapsed, format!("\"rows\":[{}]", rows.join(",")))
}

/// One fig 22 scenario row: an injected run against its baseline.
///
/// `vtime_us` is the injected (and, for the straggler probe, adaptive)
/// run; `baseline_us` is the comparison arm — the static-plan run for
/// the straggler probe, the fault-free reference at the same data size
/// (and at the survivor count, for rank failure) otherwise.
pub struct FaultRow {
    pub scenario: &'static str,
    pub app: &'static str,
    pub vtime_us: f64,
    pub baseline_us: f64,
    /// Ranks the measured phase ran on (world size, or world - 1 after
    /// a shrink).
    pub survivors: u64,
    /// Checksum bit-identical to the fault-free reference (straggler
    /// probe: the detector agreed on exactly the injected rank).
    pub converged: bool,
    /// Re-running with the same seed reproduced vtime and checksum
    /// bit-for-bit.
    pub replay_identical: bool,
}

/// The straggler arm of fig 22: a hierarchical 2x4 cluster where world
/// rank 4 — node 1's representative in every static tree — carries a
/// large ingress penalty. Warmup is a *direct* token from rank 0 to
/// every rank, so each rank's arrival skew carries only its own ingress
/// cost (a tree-shaped warmup would smear the straggler's delay over
/// its downstream neighbours and the detector would blame the whole
/// node). The adaptive arm then runs [`crate::rmpi::Comm::detect_stragglers`],
/// which re-roots the node's trees away from rank 4 through the
/// avoid-mask / `SchedKey` path; the static arm keeps the compiled
/// plans. Both arms time the same bcast + commutative-allreduce rounds.
///
/// Returns `(vtime_ns, agreed_avoid_mask)` (mask is 0 for the static arm).
fn fig22_straggler_probe(adaptive: bool, rounds: usize) -> (u64, u64) {
    use crate::rmpi::{commutative, ClusterConfig, FaultsConfig, TopologyMode, Universe};
    use std::sync::atomic::{AtomicU64, Ordering};

    let mut cfg = ClusterConfig::new(2, 4, 0).with_topology(TopologyMode::Hierarchical);
    cfg.deadline = Some(ms(60_000));
    cfg.faults = Some(FaultsConfig::new(7).with_straggler(4, 50_000, 1));
    let mask_out = Arc::new(AtomicU64::new(0));
    let mask_c = Arc::clone(&mask_out);
    let stats = Universe::run(cfg, move |ctx| {
        // Direct-token warmup: the straggler's entry to the next
        // collective lags by its rx_extra, everyone else's by wire
        // latency only.
        let tok = [0u8; 64];
        if ctx.rank == 0 {
            let reqs: Vec<_> = (1..ctx.size).map(|d| ctx.comm.isend(&tok, d, 9)).collect();
            for r in &reqs {
                r.wait(&ctx.clock);
            }
        } else {
            let mut rbuf = [0u8; 64];
            let r = ctx.comm.irecv(&mut rbuf, 0, 9);
            r.wait(&ctx.clock);
        }
        if adaptive {
            let m = ctx.comm.detect_stragglers(20_000);
            if ctx.rank == 0 {
                mask_c.store(m, Ordering::Relaxed);
            }
        }
        let mut buf = vec![0u8; 4 * 1024];
        let mut acc = [0u64; 1];
        for _ in 0..rounds {
            ctx.comm.bcast(&mut buf, 0);
            acc[0] = ctx.rank as u64;
            ctx.comm.allreduce_op(
                &mut acc,
                commutative(|a: &mut [u64], b: &[u64]| a[0] = a[0].max(b[0])),
            );
        }
    })
    .expect("straggler probe");
    (stats.vtime_ns, mask_out.load(Ordering::Relaxed))
}

/// Fold an injected run, its seed replay, and the fault-free reference
/// into one row. Convergence is checksum *bit* identity: rank-failure
/// runs restart from the initial condition on the shrunk communicator
/// and the checksum is gathered in rank order, so they reproduce a
/// clean run at the survivor count exactly; drop and straggler
/// injections perturb timing only (see `apps::recovery`).
fn fig22_shrink_row(
    scenario: &'static str,
    app: &'static str,
    run: &crate::apps::recovery::ShrinkOutcome,
    replay: &crate::apps::recovery::ShrinkOutcome,
    reference: &crate::apps::recovery::ShrinkOutcome,
) -> FaultRow {
    FaultRow {
        scenario,
        app,
        vtime_us: run.vtime_ns as f64 / 1_000.0,
        baseline_us: reference.vtime_ns as f64 / 1_000.0,
        survivors: run.survivors as u64,
        converged: run.checksum.is_finite()
            && run.checksum != 0.0
            && run.checksum.to_bits() == reference.checksum.to_bits(),
        replay_identical: run.vtime_ns == replay.vtime_ns
            && run.checksum.to_bits() == replay.checksum.to_bits(),
    }
}

/// Fig 22 (repro extension): fault injection and stall-driven adaptive
/// recovery. Three scenario families, each asserted in-harness:
///
/// * `straggler-reroot` — detector-driven tree re-rooting must strictly
///   beat the static plans under a persistent straggler, and the
///   agreement mask must name exactly the injected rank;
/// * `rank-fail` — both evaluation apps must converge bit-identically
///   to a fault-free run at the survivor count after a mid-run rank
///   failure plus `comm_shrink()`;
/// * `drop` / `straggler` (app rows) — lossy links and compute-cost
///   multipliers must change timing, never results.
///
/// Every scenario is run twice on the same seed; rows record that the
/// replay was bit-identical.
pub fn fig22(scale: Scale) -> Vec<FaultRow> {
    use crate::apps::recovery::{
        run_gs_shrink, run_ifs_shrink, GsShrinkParams, IfsShrinkParams, ShrinkParams,
    };
    use crate::rmpi::FaultsConfig;

    let (rounds, iters) = match scale {
        Scale::Quick => (10, 8),
        Scale::Default => (20, 16),
        Scale::Full => (40, 32),
    };

    let mut rows = Vec::new();

    // Straggler: static vs detector-re-rooted plans.
    let (static_ns, _) = fig22_straggler_probe(false, rounds);
    let (adaptive_ns, mask) = fig22_straggler_probe(true, rounds);
    let (static2_ns, _) = fig22_straggler_probe(false, rounds);
    let (adaptive2_ns, mask2) = fig22_straggler_probe(true, rounds);
    assert_eq!(
        mask,
        1 << 4,
        "detector must agree on exactly the injected straggler (rank 4)"
    );
    assert!(
        adaptive_ns < static_ns,
        "stall-driven re-rooting must beat the static plans under a \
         straggler (adaptive {} ns, static {} ns)",
        adaptive_ns,
        static_ns
    );
    rows.push(FaultRow {
        scenario: "straggler-reroot",
        app: "coll",
        vtime_us: adaptive_ns as f64 / 1_000.0,
        baseline_us: static_ns as f64 / 1_000.0,
        survivors: 8,
        converged: mask == 1 << 4,
        replay_identical: adaptive_ns == adaptive2_ns && static_ns == static2_ns && mask == mask2,
    });

    // Shrink-and-continue drivers: 4 single-rank nodes so a failure
    // costs a node; sizes divide both the world and the survivor count
    // (rows 24: bands 6 -> 8; gridpoints 144: 144 % 16 = 144 % 9 = 0).
    let base = |faults: Option<FaultsConfig>, pre: usize, nodes: usize| {
        let mut b = ShrinkParams::new(nodes, 1, pre, iters);
        b.deadline = Some(ms(60_000));
        b.faults = faults;
        b
    };
    let fail = || Some(FaultsConfig::new(42).with_rank_fail(1, 20_000));
    let drop = || Some(FaultsConfig::new(42).with_drop(200_000));
    let slow = || Some(FaultsConfig::new(42).with_straggler(1, 5_000, 2));

    let gs = |b: ShrinkParams| run_gs_shrink(&GsShrinkParams::new(b, 24, 64)).expect("gs shrink");
    let ifs =
        |b: ShrinkParams| run_ifs_shrink(&IfsShrinkParams::new(b, 144, 2)).expect("ifs shrink");

    // Rank failure: reference is a clean run on the survivor count.
    let r = gs(base(fail(), 3, 4));
    let rep = gs(base(fail(), 3, 4));
    let refr = gs(base(None, 0, 3));
    rows.push(fig22_shrink_row("rank-fail", "gs", &r, &rep, &refr));

    let r = ifs(base(fail(), 2, 4));
    let rep = ifs(base(fail(), 2, 4));
    let refr = ifs(base(None, 0, 3));
    rows.push(fig22_shrink_row("rank-fail", "ifsker", &r, &rep, &refr));

    // Drop and straggler: reference is the fault-free run at full size.
    let refr_gs = gs(base(None, 0, 4));
    let refr_ifs = ifs(base(None, 0, 4));

    let r = gs(base(drop(), 0, 4));
    let rep = gs(base(drop(), 0, 4));
    rows.push(fig22_shrink_row("drop", "gs", &r, &rep, &refr_gs));

    let r = ifs(base(drop(), 0, 4));
    let rep = ifs(base(drop(), 0, 4));
    rows.push(fig22_shrink_row("drop", "ifsker", &r, &rep, &refr_ifs));

    let r = gs(base(slow(), 0, 4));
    let rep = gs(base(slow(), 0, 4));
    let row = fig22_shrink_row("straggler", "gs", &r, &rep, &refr_gs);
    // A doubled compute cost must show up in virtual time.
    assert!(
        row.vtime_us > row.baseline_us,
        "straggler compute multiplier must slow the run"
    );
    rows.push(row);

    for r in &rows {
        assert!(r.converged, "{}/{} failed to converge", r.scenario, r.app);
        assert!(
            r.replay_identical,
            "{}/{} not bit-identical on seed replay",
            r.scenario, r.app
        );
    }
    rows
}

pub fn fig22_report(scale: Scale) -> String {
    let rows = fig22(scale);
    let mut out = String::from(
        "=== Figure 22: fault injection — stall-driven recovery vs static plans ===\n",
    );
    out.push_str(&format!(
        "{:<18} {:<8} {:>12} {:>12} {:>10} {:>10} {:>8}\n",
        "scenario", "app", "vtime_us", "baseline_us", "survivors", "converged", "replay"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<18} {:<8} {:>12.1} {:>12.1} {:>10} {:>10} {:>8}\n",
            r.scenario,
            r.app,
            r.vtime_us,
            r.baseline_us,
            r.survivors,
            r.converged,
            r.replay_identical
        ));
    }
    out.push_str(
        "(straggler-reroot: detector re-roots node trees away from the\n\
         injected straggler, baseline is the static-plan run; rank-fail:\n\
         mid-run failure + comm_shrink, baseline is a fault-free run at\n\
         the survivor count; drop/straggler app rows: injected timing vs\n\
         the fault-free run — converged means checksum bit-identity,\n\
         replay means a same-seed rerun was bit-identical)\n",
    );
    out
}

/// Fig 22 as JSON: `rows[] = {{scenario, app, vtime_us, baseline_us,
/// survivors, converged, replay_identical}}`.
pub fn fig22_json(scale: Scale) -> String {
    let wall = std::time::Instant::now();
    let rows: Vec<String> = fig22(scale)
        .into_iter()
        .map(|r| {
            format!(
                "{{\"scenario\":\"{}\",\"app\":\"{}\",\"vtime_us\":{},\
                 \"baseline_us\":{},\"survivors\":{},\"converged\":{},\
                 \"replay_identical\":{}}}",
                json_escape(r.scenario),
                json_escape(r.app),
                r.vtime_us,
                r.baseline_us,
                r.survivors,
                r.converged,
                r.replay_identical
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(22, scale, elapsed, format!("\"rows\":[{}]", rows.join(",")))
}

/// One fig23 row: the same deterministic run under one event-queue
/// implementation and lane count.
#[derive(Clone, Debug)]
pub struct QueueRow {
    /// Application: `gs` or `ifsker`.
    pub app: String,
    /// Per-lane event queue: `heap` or `calendar`.
    pub queue: &'static str,
    /// Requested clock lanes (the engine may clamp; identity still
    /// holds, so rows stay comparable).
    pub shards: usize,
    /// Virtual makespan — asserted identical across every configuration.
    pub vtime_ms: f64,
    pub host_ms: f64,
    pub clock_events: u64,
    pub cross_shard_events: u64,
    /// Batched cross-lane transfers (one lock + one notify each).
    pub cross_shard_batches: u64,
    /// The headline: simulator throughput in clock events per host ms.
    pub events_per_host_ms: f64,
    /// Throughput speed-up vs the same app's 1-lane binary-heap run
    /// (the PR-6 engine configuration).
    pub speedup_vs_baseline: f64,
}

/// Fig 23 (engine throughput overhaul): events per host millisecond as
/// the per-lane event queue ({binary heap, calendar queue}) and the
/// lane count (1 / 2 / 4 / finer-than-node) are swept over fixed
/// Gauss-Seidel and IFSKer runs. Every configuration is asserted
/// bit-identical to that app's 1-lane binary-heap baseline — checksum
/// bits, virtual makespan, task and pause counts, schedule-cache
/// traffic — so the sweep can only measure host-side speed, never
/// semantic drift. At `Default`/`Full` scale the best configuration
/// must clear a minimum throughput speed-up over the baseline (2x by
/// default; override with `TAMPI_FIG23_MIN_SPEEDUP`, e.g. on noisy
/// shared runners). `Quick` reports without gating — CI wall-times are
/// tracked by `scripts/bench_delta.py` instead.
pub fn fig23(scale: Scale) -> Vec<QueueRow> {
    use crate::sim::ClockQueueKind;

    let (rows_g, block, iters, grid, fields, steps, nodes, cpn) = match scale {
        Scale::Quick => (512usize, 128usize, 8usize, 4096usize, 2usize, 4usize, 4usize, 2usize),
        Scale::Default => (2048, 256, 16, 16384, 4, 8, 8, 4),
        Scale::Full => (4096, 512, 32, 65536, 8, 16, 16, 8),
    };
    // gs (hybrid) runs one rank per node, so its lanes cap at the node
    // count; ifsker runs cpn ranks per node, so `2*nodes` exercises the
    // finer-than-node lanes the per-pair lookahead matrix makes legal.
    let gs_shards: Vec<usize> = {
        let mut v = vec![1usize, 2, 4, nodes];
        v.dedup();
        v.retain(|&s| s <= nodes);
        v
    };
    let ifs_shards: Vec<usize> = vec![1, 2, 4, 2 * nodes];

    let run_gs = |queue: ClockQueueKind, shards: usize| {
        let mut p = GsParams::new(rows_g, rows_g, block, iters, nodes, cpn, GsVersion::InteropNonBlk);
        p.compute = Compute::Model;
        p.clock_shards = shards;
        p.clock_queue = queue;
        p.deadline = Some(ms(600_000));
        let run = gauss_seidel::run(&p).expect("fig23 gs");
        (run.checksum.to_bits(), run.stats)
    };
    let run_ifs = |queue: ClockQueueKind, shards: usize| {
        let mut p = IfsParams::new(grid, fields, steps, nodes, cpn, IfsVersion::InteropNonBlk);
        p.compute = Compute::Model;
        p.clock_shards = shards;
        p.clock_queue = queue;
        p.deadline = Some(ms(600_000));
        let run = ifsker::run(&p).expect("fig23 ifsker");
        (run.checksum.to_bits(), run.stats)
    };

    let mut out: Vec<QueueRow> = Vec::new();
    let apps: [(&str, &dyn Fn(ClockQueueKind, usize) -> (u64, crate::rmpi::RunStats), &[usize]); 2] =
        [("gs", &run_gs, &gs_shards), ("ifsker", &run_ifs, &ifs_shards)];
    for (app, run, shards_list) in apps {
        // (checksum bits, vtime, tasks, pauses, cache, events/host-ms)
        // of this app's 1-lane binary-heap baseline.
        let mut base: Option<(u64, u64, u64, u64, crate::rmpi::SchedCacheStats, f64)> = None;
        for queue in [ClockQueueKind::BinaryHeap, ClockQueueKind::Calendar] {
            for &shards in shards_list {
                let (ck, s) = run(queue, shards);
                let host_ns = s.elapsed_host_ns.max(1);
                let evts_ms = s.clock_events as f64 / (host_ns as f64 / 1e6);
                match &base {
                    None => {
                        debug_assert!(queue == ClockQueueKind::BinaryHeap && shards == 1);
                        base = Some((ck, s.vtime_ns, s.tasks, s.pauses, s.sched_cache, evts_ms));
                    }
                    Some((bck, vt, tasks, pauses, cache, _)) => {
                        // The tentpole guarantee: queue impl and lane
                        // count change host timing only. Any divergence
                        // is an engine bug, not noise.
                        let cfg = format!("{app}/{}/{shards}", queue.label());
                        assert_eq!(ck, *bck, "fig23: checksum diverged at {cfg}");
                        assert_eq!(s.vtime_ns, *vt, "fig23: vtime diverged at {cfg}");
                        assert_eq!(s.tasks, *tasks, "fig23: task count diverged at {cfg}");
                        assert_eq!(s.pauses, *pauses, "fig23: pause count diverged at {cfg}");
                        assert_eq!(s.sched_cache, *cache, "fig23: cache traffic diverged at {cfg}");
                    }
                }
                let base_evts_ms = base.as_ref().unwrap().5;
                out.push(QueueRow {
                    app: app.to_string(),
                    queue: queue.label(),
                    shards,
                    vtime_ms: s.vtime_ns as f64 / 1e6,
                    host_ms: host_ns as f64 / 1e6,
                    clock_events: s.clock_events,
                    cross_shard_events: s.cross_shard_events,
                    cross_shard_batches: s.cross_shard_batches,
                    events_per_host_ms: evts_ms,
                    speedup_vs_baseline: evts_ms / base_evts_ms,
                });
            }
        }
    }

    // Acceptance gate: the overhauled engine's best configuration must
    // beat the PR-6 baseline by the required factor. Host wall-times on
    // `Quick` CI runs are too short to gate on, so the threshold only
    // applies at `Default`/`Full` (and stays operator-overridable).
    let min_speedup: f64 = std::env::var("TAMPI_FIG23_MIN_SPEEDUP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(match scale {
            Scale::Quick => 0.0,
            Scale::Default | Scale::Full => 2.0,
        });
    if min_speedup > 0.0 {
        let best = out
            .iter()
            .map(|r| r.speedup_vs_baseline)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(
            best >= min_speedup,
            "fig23: best events/host-ms speedup {best:.2} below the required {min_speedup:.2}x \
             (set TAMPI_FIG23_MIN_SPEEDUP to adjust)"
        );
    }
    out
}

/// Render the fig23 report table.
pub fn fig23_report(scale: Scale) -> String {
    let rows = fig23(scale);
    let mut out = String::from(
        "=== Figure 23: event-queue and lane sweep — simulator throughput ===\n\
         (fixed gs + ifsker runs; every configuration asserted bit-identical to\n\
         the 1-lane binary-heap baseline: checksum, vtime, tasks, pauses, cache)\n",
    );
    out.push_str(&format!(
        "{:<8} {:<9} {:>7} {:>10} {:>9} {:>11} {:>11} {:>9} {:>13} {:>8}\n",
        "app", "queue", "shards", "vtime_ms", "host_ms", "clock_evts", "cross_evts", "batches",
        "evts/host_ms", "speedup"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<8} {:<9} {:>7} {:>10.2} {:>9.1} {:>11} {:>11} {:>9} {:>13.0} {:>8.2}\n",
            r.app,
            r.queue,
            r.shards,
            r.vtime_ms,
            r.host_ms,
            r.clock_events,
            r.cross_shard_events,
            r.cross_shard_batches,
            r.events_per_host_ms,
            r.speedup_vs_baseline
        ));
    }
    out.push_str(
        "(calendar queue: O(1) near-horizon buckets + far heap, popped in the\n\
         same (at, seq) total order as the binary heap; finer-than-node lanes\n\
         run under the per-lane-pair lookahead matrix)\n",
    );
    out
}

/// Fig 23 as JSON: `rows[] = {{app, queue, shards, vtime_ms, host_ms,
/// clock_events, cross_shard_events, cross_shard_batches,
/// events_per_host_ms, speedup_vs_baseline}}`.
pub fn fig23_json(scale: Scale) -> String {
    let wall = std::time::Instant::now();
    let rows: Vec<String> = fig23(scale)
        .into_iter()
        .map(|r| {
            format!(
                "{{\"app\":\"{}\",\"queue\":\"{}\",\"shards\":{},\"vtime_ms\":{},\
                 \"host_ms\":{},\"clock_events\":{},\"cross_shard_events\":{},\
                 \"cross_shard_batches\":{},\"events_per_host_ms\":{},\
                 \"speedup_vs_baseline\":{}}}",
                json_escape(&r.app),
                r.queue,
                r.shards,
                r.vtime_ms,
                r.host_ms,
                r.clock_events,
                r.cross_shard_events,
                r.cross_shard_batches,
                r.events_per_host_ms,
                r.speedup_vs_baseline
            )
        })
        .collect();
    let elapsed = wall.elapsed().as_nanos() as u64;
    json_doc(23, scale, elapsed, format!("\"rows\":[{}]", rows.join(",")))
}

/// Sweep presets. The simulated cluster reproduces the paper's *shape*;
/// `Full` runs the paper's actual sizes (64Kx64K, 48 cores/node, up to 64
/// nodes) and takes correspondingly long.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Scale {
    /// Seconds-fast smoke scale (CI).
    Quick,
    /// Default: minutes; enough nodes/blocks to show every crossover.
    Default,
    /// Paper scale.
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("TAMPI_BENCH_SCALE").as_deref() {
            Ok("quick") => Scale::Quick,
            Ok("full") => Scale::Full,
            _ => Scale::Default,
        }
    }

    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "quick" => Some(Scale::Quick),
            "default" => Some(Scale::Default),
            "full" => Some(Scale::Full),
            _ => None,
        }
    }
}

/// One measurement row.
#[derive(Clone, Debug)]
pub struct Row {
    pub fig: &'static str,
    pub version: String,
    pub nodes: usize,
    pub extra: String,
    pub vtime_ms: f64,
    pub speedup: f64,
    pub efficiency: f64,
}

/// Render rows as the paper-style table.
pub fn format_table(rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<16} {:>6} {:>10} {:>12} {:>9} {:>11}\n",
        "version", "nodes", "extra", "vtime_ms", "speedup", "efficiency"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<16} {:>6} {:>10} {:>12.2} {:>9.2} {:>11.3}\n",
            r.version, r.nodes, r.extra, r.vtime_ms, r.speedup, r.efficiency
        ));
    }
    s
}

/// Gauss-Seidel sweep configuration shared by Figs 9/11/12/13.
#[derive(Clone)]
pub struct GsSweep {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    pub iters: usize,
    pub cores_per_node: usize,
    pub node_counts: Vec<usize>,
}

impl GsSweep {
    pub fn strong(scale: Scale) -> GsSweep {
        match scale {
            Scale::Quick => GsSweep {
                rows: 1024,
                cols: 1024,
                block: 256,
                iters: 12,
                cores_per_node: 2,
                node_counts: vec![1, 2, 4],
            },
            Scale::Default => GsSweep {
                rows: 8192,
                cols: 8192,
                block: 512,
                iters: 50,
                cores_per_node: 4,
                node_counts: vec![1, 2, 4, 8, 16],
            },
            Scale::Full => GsSweep {
                rows: 65536,
                cols: 65536,
                block: 1024,
                iters: 1000,
                cores_per_node: 48,
                node_counts: vec![1, 2, 4, 8, 16, 32, 64],
            },
        }
    }

    /// Weak scaling: rows grow with the node count (paper: 32Kx32K/node).
    pub fn weak(scale: Scale) -> GsSweep {
        let mut s = GsSweep::strong(scale);
        match scale {
            Scale::Quick => {
                s.rows = 512;
                s.cols = 1024;
            }
            Scale::Default => {
                s.rows = 4096;
                s.cols = 8192;
            }
            Scale::Full => {
                s.rows = 32768;
                s.cols = 32768;
                s.iters = 1000;
            }
        }
        s
    }

    fn params(&self, v: GsVersion, nodes: usize, weak: bool) -> GsParams {
        let rows = if weak { self.rows * nodes } else { self.rows };
        let mut p = GsParams::new(
            rows,
            self.cols,
            self.block,
            self.iters,
            nodes,
            self.cores_per_node,
            v,
        );
        p.compute = Compute::Model;
        // Paper figures reproduce the published TAMPI, whose interop
        // layer discovers completions by polling.
        p.completion_mode = crate::nanos::CompletionMode::Polling;
        p.deadline = Some(ms(120_000_000)); // 120 virtual seconds
        p
    }
}

fn run_gs(p: &GsParams) -> f64 {
    match gauss_seidel::run(p) {
        Ok(out) => out.vtime_ns as f64 / 1e6,
        Err(e) => {
            eprintln!(
                "WARN: {} nodes={} failed: {e} (recorded as NaN)",
                p.version.name(),
                p.nodes
            );
            f64::NAN
        }
    }
}

/// Generic GS sweep -> rows (speedup base: Pure MPI @ 1 node).
fn gs_sweep_rows(
    fig: &'static str,
    sweep: &GsSweep,
    versions: &[GsVersion],
    weak: bool,
    block_sizes: Option<&[usize]>,
) -> Vec<Row> {
    let mut rows = Vec::new();
    // Baseline: Pure MPI on one node (always with the sweep's block).
    let base = run_gs(&sweep.params(GsVersion::PureMpi, 1, weak));
    let blocks: Vec<usize> = match block_sizes {
        Some(bs) => bs.to_vec(),
        None => vec![sweep.block],
    };
    for v in versions {
        for &b in &blocks {
            let mut own_base = f64::NAN;
            for &n in &sweep.node_counts {
                let mut s = sweep.clone();
                s.block = b;
                let p = s.params(*v, n, weak);
                let t = run_gs(&p);
                if n == sweep.node_counts[0] {
                    own_base = t;
                }
                // Weak scaling does N x the work of the 1-node problem.
                let work_factor = if weak { n as f64 } else { 1.0 };
                rows.push(Row {
                    fig,
                    version: v.name().to_string(),
                    nodes: n,
                    extra: if block_sizes.is_some() {
                        format!("{b}bs")
                    } else {
                        String::new()
                    },
                    vtime_ms: t,
                    speedup: base / t * work_factor,
                    efficiency: own_base / t * work_factor / (n as f64
                        / sweep.node_counts[0] as f64),
                });
            }
        }
    }
    rows
}

/// Fig 9: Gauss-Seidel strong scaling, five versions.
pub fn fig09(scale: Scale) -> Vec<Row> {
    let sweep = GsSweep::strong(scale);
    gs_sweep_rows(
        "fig09",
        &sweep,
        &[
            GsVersion::PureMpi,
            GsVersion::NBuffer,
            GsVersion::ForkJoin,
            GsVersion::Sentinel,
            GsVersion::InteropBlk,
        ],
        false,
        None,
    )
}

/// Fig 11: Gauss-Seidel weak scaling, five versions.
pub fn fig11(scale: Scale) -> Vec<Row> {
    let sweep = GsSweep::weak(scale);
    gs_sweep_rows(
        "fig11",
        &sweep,
        &[
            GsVersion::PureMpi,
            GsVersion::NBuffer,
            GsVersion::ForkJoin,
            GsVersion::Sentinel,
            GsVersion::InteropBlk,
        ],
        true,
        None,
    )
}

/// Block sizes for Figs 12/13 (paper: 256/512/1024, scaled 4x down).
pub fn fig12_blocks(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![128, 256],
        Scale::Default => vec![128, 256, 512],
        Scale::Full => vec![256, 512, 1024],
    }
}

/// Fig 12: Interop(blk) vs Interop(non-blk), strong scaling x block size.
pub fn fig12(scale: Scale) -> Vec<Row> {
    let sweep = GsSweep::strong(scale);
    let blocks = fig12_blocks(scale);
    gs_sweep_rows(
        "fig12",
        &sweep,
        &[GsVersion::InteropBlk, GsVersion::InteropNonBlk],
        false,
        Some(&blocks),
    )
}

/// Fig 13: Interop(blk) vs Interop(non-blk), weak scaling x block size.
pub fn fig13(scale: Scale) -> Vec<Row> {
    let sweep = GsSweep::weak(scale);
    let blocks = fig12_blocks(scale);
    gs_sweep_rows(
        "fig13",
        &sweep,
        &[GsVersion::InteropBlk, GsVersion::InteropNonBlk],
        true,
        Some(&blocks),
    )
}

/// Fig 14: IFSKer strong scaling (Pure, Interop blk, Interop non-blk).
pub fn fig14(scale: Scale) -> Vec<Row> {
    let (grid, fields, steps, cpn, node_counts) = match scale {
        Scale::Quick => (8 * 1024, 4, 4, 2, vec![1, 2, 4]),
        Scale::Default => (65536, 8, 10, 4, vec![1, 2, 4, 8, 16]),
        Scale::Full => (653_184, 16, 200, 48, vec![1, 2, 4, 8, 16, 32]),
    };
    let mk = |v: IfsVersion, nodes: usize| -> IfsParams {
        let mut p = IfsParams::new(grid, fields, steps, nodes, cpn, v);
        p.compute = Compute::Model;
        // Paper figures use the published polling interop layer.
        p.completion_mode = crate::nanos::CompletionMode::Polling;
        p.deadline = Some(ms(120_000_000));
        p
    };
    let run1 = |p: &IfsParams| match ifsker::run(p) {
        Ok(o) => o.vtime_ns as f64 / 1e6,
        Err(e) => {
            eprintln!("WARN: ifsker {} nodes={} failed: {e}", p.version.name(), p.nodes);
            f64::NAN
        }
    };
    let base = run1(&mk(IfsVersion::PureMpi, 1));
    let mut rows = Vec::new();
    for v in IfsVersion::all() {
        let mut own = f64::NAN;
        for &n in &node_counts {
            let t = run1(&mk(v, n));
            if n == node_counts[0] {
                own = t;
            }
            rows.push(Row {
                fig: "fig14",
                version: v.name().to_string(),
                nodes: n,
                extra: String::new(),
                vtime_ms: t,
                speedup: base / t,
                efficiency: own / t / n as f64,
            });
        }
    }
    rows
}

/// Fig 8: dependency graphs (DOT) of the Fig 7 domain (3x12 blocks, 4
/// ranks). Returns (version name, dot text, edge count).
pub fn fig08() -> Vec<(String, String, usize)> {
    let mut out = Vec::new();
    for v in [GsVersion::ForkJoin, GsVersion::Sentinel, GsVersion::InteropBlk] {
        let g = Arc::new(GraphRecorder::new());
        // Fig 7's domain: 12 block rows x 3 block cols over four ranks.
        let mut p = GsParams::new(384, 96, 32, 3, 4, 2, v);
        p.compute = Compute::Model;
        p.completion_mode = crate::nanos::CompletionMode::Polling;
        p.graph = Some(g.clone());
        p.deadline = Some(ms(600_000));
        gauss_seidel::run(&p).expect("fig08 run");
        out.push((v.name().to_string(), g.to_dot("sentinel"), g.edge_count()));
    }
    out
}

/// Fig 10: execution traces on four nodes. Returns (version, gantt text,
/// csv, busy fractions).
pub fn fig10(scale: Scale) -> Vec<(String, String, String, BTreeMap<u32, f64>)> {
    let (rows, cols, block, iters, cpn) = match scale {
        Scale::Quick => (512, 512, 128, 6, 2),
        _ => (2048, 2048, 256, 10, 4),
    };
    let mut out = Vec::new();
    for v in GsVersion::all() {
        if v == GsVersion::InteropNonBlk {
            continue; // Fig 10 shows the paper's five versions
        }
        let tracer = Arc::new(Tracer::new());
        let mut p = GsParams::new(rows, cols, block, iters, 4, cpn, v);
        p.compute = Compute::Model;
        p.completion_mode = crate::nanos::CompletionMode::Polling;
        p.tracer = Some(tracer.clone());
        p.deadline = Some(ms(60_000_000));
        gauss_seidel::run(&p).expect("fig10 run");
        let recs = tracer.snapshot();
        let gantt = crate::trace::render_gantt(&recs, 100);
        let busy = crate::trace::busy_fraction(&recs);
        out.push((v.name().to_string(), gantt, tracer.to_csv(), busy));
    }
    out
}

/// Write figure outputs under `bench_out/`.
pub fn write_output(name: &str, contents: &str) -> std::path::PathBuf {
    let dir = std::path::PathBuf::from("bench_out");
    std::fs::create_dir_all(&dir).expect("mkdir bench_out");
    let path = dir.join(name);
    std::fs::write(&path, contents).expect("write bench output");
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("quick"), Some(Scale::Quick));
        assert_eq!(Scale::parse("full"), Some(Scale::Full));
        assert_eq!(Scale::parse("bogus"), None);
    }

    #[test]
    fn table_formats() {
        let rows = vec![Row {
            fig: "fig09",
            version: "pure-mpi".into(),
            nodes: 1,
            extra: String::new(),
            vtime_ms: 12.5,
            speedup: 1.0,
            efficiency: 1.0,
        }];
        let t = format_table(&rows);
        assert!(t.contains("pure-mpi"));
        assert!(t.contains("12.50"));
    }
}
