//! cargo bench target regenerating extension Figure 21: cold-communicator
//! plan-compile cost over rank counts — per-rank-compile baseline vs the
//! cluster-wide plan compilation service vs its closed-form fast paths
//! (host compile work and replay-event counts; the compiled plans and
//! all virtual-time results are bit-identical across strategies). Scale
//! via TAMPI_BENCH_SCALE={quick,default,full}.

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let report = bench::fig21_report(scale);
    println!("{report}");
    bench::write_output("fig21_plan_compile.txt", &report);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
