//! cargo bench target regenerating extension Figure 19: the parallel
//! discrete-event core — host wall-time of one deterministic
//! Gauss-Seidel run as the simulation clock is sharded over 1/2/4/8
//! lanes under conservative lookahead. Every multi-lane run is asserted
//! bit-identical to the 1-lane run (checksum, virtual makespan, task
//! and pause counts, schedule-cache traffic). Scale via
//! TAMPI_BENCH_SCALE={quick,default,full}.

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let report = bench::fig19_report(scale);
    println!("{report}");
    bench::write_output("fig19_clock_shards.txt", &report);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
