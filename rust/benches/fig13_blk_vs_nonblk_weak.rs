//! cargo bench target regenerating paper Figure 13.
//! Scale via TAMPI_BENCH_SCALE={quick,default,full} (default: default).

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let rows = bench::fig13(scale);
    let table = bench::format_table(&rows);
    println!("=== Figure 13 ({scale:?}) ===\n{table}");
    bench::write_output("fig13.txt", &table);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
