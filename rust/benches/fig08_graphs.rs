//! cargo bench target regenerating paper Figure 8 (dependency graphs).

use tampi_repro::bench;

fn main() {
    let t = std::time::Instant::now();
    for (name, dot, edges) in bench::fig08() {
        let p = bench::write_output(&format!("fig08_{name}.dot"), &dot);
        println!("fig08 {name}: {edges} dependency edges -> {}", p.display());
    }
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
