//! cargo bench target regenerating paper Figure 11.
//! Scale via TAMPI_BENCH_SCALE={quick,default,full} (default: default).

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let rows = bench::fig11(scale);
    let table = bench::format_table(&rows);
    println!("=== Figure 11 ({scale:?}) ===\n{table}");
    bench::write_output("fig11.txt", &table);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
