//! cargo bench target regenerating extension Figure 18: the unified
//! congestion story — an (n-1)->0 p2p incast and the same fan-in
//! through flat vs leader-staged gather, all priced by the one
//! ingress-port model, swept over the per-message receiver cost
//! `rx_ns`. Scale via TAMPI_BENCH_SCALE={quick,default,full}.

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let report = bench::fig18_report(scale, None, None);
    println!("{report}");
    bench::write_output("fig18_incast.txt", &report);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
