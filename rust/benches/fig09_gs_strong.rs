//! cargo bench target regenerating paper Figure 9.
//! Scale via TAMPI_BENCH_SCALE={quick,default,full} (default: default).

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let rows = bench::fig09(scale);
    let table = bench::format_table(&rows);
    println!("=== Figure 9 ({scale:?}) ===\n{table}");
    bench::write_output("fig09.txt", &table);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
