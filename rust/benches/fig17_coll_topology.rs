//! cargo bench target regenerating extension Figure 17: topology-aware
//! hierarchical collective schedules (flat vs leader-staged virtual
//! time across a ranks-per-node sweep) and the persistent schedule
//! cache's cold vs cached compile cost. Scale via
//! TAMPI_BENCH_SCALE={quick,default,full}.

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let report = bench::fig17_report(scale);
    println!("{report}");
    bench::write_output("fig17_coll_topology.txt", &report);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
