//! cargo bench target regenerating extension Figure 15: completion→resume
//! notification latency (poll-scan vs callback continuations, direct vs
//! sharded delivery) plus the same-instant completion-wave delivery-cost
//! table. Scale via TAMPI_BENCH_SCALE={quick,default,full}.

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let report = bench::fig15_report(scale);
    println!("{report}");
    bench::write_output("fig15_completion_latency.txt", &report);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
