//! cargo bench target regenerating extension Figure 16: blocking vs
//! non-blocking collectives — the schedule-driven `iallreduce` riding
//! the progress engine while compute runs, on a synthetic compute sweep
//! and on Gauss-Seidel residual monitoring. Scale via
//! TAMPI_BENCH_SCALE={quick,default,full}.

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let report = bench::fig16_report(scale);
    println!("{report}");
    bench::write_output("fig16_coll_overlap.txt", &report);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
