//! cargo bench target regenerating paper Figure 10 (execution traces).

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    for (name, gantt, csv, busy) in bench::fig10(scale) {
        bench::write_output(&format!("fig10_{name}.csv"), &csv);
        bench::write_output(&format!("fig10_{name}.gantt.txt"), &gantt);
        println!("--- {name} ---\n{gantt}");
        for (rank, f) in busy {
            println!("  rank {rank}: busy {:.1}%", f * 100.0);
        }
    }
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
