//! cargo bench target regenerating extension Figure 23: the simulator
//! throughput overhaul — clock events per host millisecond as the
//! per-lane event queue (binary heap vs calendar queue) and the lane
//! count (1/2/4/finer-than-node) are swept over fixed Gauss-Seidel and
//! IFSKer runs. Every configuration is asserted bit-identical to the
//! 1-lane binary-heap baseline (checksum, virtual makespan, task and
//! pause counts, schedule-cache traffic). Scale via
//! TAMPI_BENCH_SCALE={quick,default,full}; the >=2x speed-up gate is
//! tunable with TAMPI_FIG23_MIN_SPEEDUP.

use tampi_repro::bench::{self, Scale};

fn main() {
    let scale = Scale::from_env();
    let t = std::time::Instant::now();
    let report = bench::fig23_report(scale);
    println!("{report}");
    bench::write_output("fig23_queue_throughput.txt", &report);
    println!("wall: {:.1}s", t.elapsed().as_secs_f64());
}
