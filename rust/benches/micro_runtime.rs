//! Micro-benchmarks of the runtime substrate (real wall-clock, not
//! virtual time): the costs behind Section 6.2's blocking-vs-events
//! comparison, plus rmpi message-path overheads.
//!
//! Hand-rolled harness (the offline registry has no criterion); each
//! benchmark reports ns/op over enough iterations to stabilize.
//!
//! CLI: `cargo bench --bench micro_runtime -- --delivery direct|sharded`
//! restricts the completion-wave section to one delivery mode (default:
//! both, with the O(shards)-vs-O(N) lock-traffic assertions).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use tampi_repro::nanos::{self, CompletionMode, Mode, Runtime, RuntimeConfig};
use tampi_repro::progress::DeliveryMode;
use tampi_repro::rmpi::{ClusterConfig, ThreadLevel, Universe};
use tampi_repro::sim::{us, Clock};
use tampi_repro::tampi;

fn bench(name: &str, ops: u64, f: impl FnOnce()) {
    let t = Instant::now();
    f();
    let total = t.elapsed();
    println!(
        "{name:<44} {:>10.0} ns/op ({ops} ops, {:.2} s)",
        total.as_nanos() as f64 / ops as f64,
        total.as_secs_f64()
    );
}

/// Spawn a runtime on a scratch clock, run `f` on an attached thread.
fn with_rt(cores: usize, f: impl FnOnce(&Runtime) + Send + 'static) {
    let (clock, h) = Clock::start();
    clock.set_panic_on_deadlock(false);
    let hold = clock.hold();
    let rt = Runtime::new(clock.clone(), RuntimeConfig::new(cores));
    clock.register_thread();
    drop(hold);
    let rt2 = rt.clone();
    let c2 = clock.clone();
    std::thread::spawn(move || {
        rt2.attach();
        f(&rt2);
        rt2.taskwait();
        rt2.detach();
        c2.deregister_thread();
    })
    .join()
    .unwrap();
    rt.shutdown();
    clock.stop();
    h.join().unwrap();
}

/// Which delivery modes the wave section runs (`--delivery` CLI).
fn delivery_filter() -> Vec<DeliveryMode> {
    let args: Vec<String> = std::env::args().collect();
    match args
        .iter()
        .position(|a| a == "--delivery")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
    {
        Some("direct") => vec![DeliveryMode::Direct],
        Some("sharded") => vec![DeliveryMode::Sharded],
        Some(other) => {
            eprintln!("unknown --delivery {other} (direct|sharded)");
            std::process::exit(2);
        }
        None => vec![DeliveryMode::Direct, DeliveryMode::Sharded],
    }
}

fn main() {
    println!("--- nanos task runtime ---");
    let n = 200_000u64;
    bench("task spawn+run (no deps, 2 cores)", n, || {
        with_rt(2, move |rt| {
            let c = Arc::new(AtomicU64::new(0));
            for _ in 0..n {
                let c = c.clone();
                rt.task().spawn(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            rt.taskwait();
            assert_eq!(c.load(Ordering::Relaxed), n);
        });
    });

    let n = 100_000u64;
    bench("task chain via inout dep (serialized)", n, || {
        with_rt(2, move |rt| {
            let obj = rt.dep("chain");
            for _ in 0..n {
                rt.task().dep(&obj, Mode::InOut).spawn(|| {});
            }
        });
    });

    let n = 50_000u64;
    bench("pause+resume round trip (ctx handoff)", n, || {
        with_rt(2, move |rt| {
            // Ping-pong: task A blocks; a polling-free unblocker task
            // wakes it; measures the full block/unblock/grant cycle.
            let slot: Arc<std::sync::Mutex<Option<nanos::BlockingContext>>> =
                Arc::new(std::sync::Mutex::new(None));
            for _ in 0..n {
                let s1 = slot.clone();
                rt.task().spawn(move || {
                    let ctx = nanos::get_current_blocking_context();
                    *s1.lock().unwrap() = Some(ctx.clone());
                    nanos::block_current_task(&ctx);
                });
                let s2 = slot.clone();
                rt.task().spawn(move || loop {
                    if let Some(ctx) = s2.lock().unwrap().take() {
                        nanos::unblock_task(&ctx);
                        break;
                    }
                    std::hint::spin_loop();
                });
                rt.taskwait();
            }
        });
    });

    let n = 200_000u64;
    bench("external event bind+fulfil", n, || {
        with_rt(2, move |rt| {
            for _ in 0..n {
                rt.task().spawn(|| {
                    let ec = nanos::get_current_event_counter();
                    nanos::increase_current_task_event_counter(&ec, 1);
                    nanos::decrease_task_event_counter(&ec, 1);
                });
            }
        });
    });

    println!("--- rmpi message path ---");
    let n = 50_000u64;
    bench("p2p eager send->recv (same node)", n, || {
        Universe::run(ClusterConfig::new(1, 2, 0), move |ctx| {
            let mut buf = [0u64; 4];
            if ctx.rank == 0 {
                for i in 0..n {
                    ctx.comm.send(&[i, i, i, i], 1, 0);
                }
            } else {
                for _ in 0..n {
                    ctx.comm.recv(&mut buf, 0, 0);
                }
            }
        })
        .unwrap();
    });

    let n = 20_000u64;
    bench("barrier (4 ranks)", n, || {
        Universe::run(ClusterConfig::new(4, 1, 0), move |ctx| {
            for _ in 0..n {
                ctx.comm.barrier();
            }
        })
        .unwrap();
    });

    println!("--- TAMPI modes (Section 6.2 cost comparison) ---");
    // Keep in-flight pauses below the substitute-worker cap: the paper's
    // blocking mode grows one thread per paused task ("threads and stacks
    // proportional to in-flight operations") and wedges past the cap.
    let n = 4_000u64;
    let run_mode = move |nonblk: bool, cmode: CompletionMode| {
        Universe::run(
            ClusterConfig::new(1, 2, 1).with_completion_mode(cmode),
            move |ctx| {
                let rt = ctx.rt.as_ref().unwrap();
                let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
                if ctx.rank == 0 {
                    for i in 0..n {
                        let tm = tm.clone();
                        rt.task().spawn(move || {
                            let mut b = [0u32];
                            if nonblk {
                                let r = tm.comm().irecv(&mut b, 1, i as i32);
                                tm.iwait(&r);
                            } else {
                                tm.recv(&mut b, 1, i as i32);
                            }
                        });
                    }
                    rt.taskwait();
                } else {
                    for i in 0..n {
                        ctx.comm.send(&[7u32], 0, i as i32);
                    }
                }
            },
        )
        .unwrap()
    };
    for cmode in [CompletionMode::Polling, CompletionMode::Callback] {
        bench(&format!("TAMPI blocking-mode recv task [{cmode:?}]"), n, || {
            let s = run_mode(false, cmode);
            println!(
                "    (pauses={} workers={} vtime={} us)",
                s.pauses,
                s.workers,
                s.vtime_ns / 1_000
            );
        });
        bench(&format!("TAMPI non-blocking recv task [{cmode:?}]"), n, || {
            let s = run_mode(true, cmode);
            println!(
                "    (pauses={} workers={} vtime={} us)",
                s.pauses,
                s.workers,
                s.vtime_ns / 1_000
            );
        });
    }

    println!("--- completion pipeline: poll-scan vs continuations ---");
    // Virtual-time notification latency of ONE pending recv inside a
    // task; the calibrated scenario lives in bench::completion_latency_ns
    // (shared with tests/tampi_callback.rs). Deterministic in virtual
    // time: Polling is bounded by the 50 us poll_interval, Callback pays
    // only the modeled resume cost.
    let poll_ns = tampi_repro::bench::completion_latency_ns(CompletionMode::Polling);
    let cb_ns = tampi_repro::bench::completion_latency_ns(CompletionMode::Callback);
    println!("completion->resume latency [Polling]  {poll_ns:>10} virtual ns");
    println!("completion->resume latency [Callback] {cb_ns:>10} virtual ns");
    assert!(
        cb_ns < us(50),
        "callback mode must retire a pending recv in under one poll_interval"
    );
    println!(
        "callback mode is {:.1}x faster to notify (poll_interval = 50 us)",
        poll_ns as f64 / cb_ns.max(1) as f64
    );

    println!("--- sharded progress engine: same-instant completion wave ---");
    // N tasks on rank 0 each blocked on its own recv; rank 1 launches all
    // N messages in one virtual instant. The delivery stats expose the
    // scheduler-lock traffic of the resume burst: O(N) acquisitions under
    // Direct (PR-1 baseline), O(shards) under Sharded — identical virtual
    // makespan either way (bench::completion_wave).
    let n = 256usize;
    let modes = delivery_filter();
    let mut results: Vec<(DeliveryMode, tampi_repro::bench::WaveStats)> = Vec::new();
    for &mode in &modes {
        let wall = Instant::now();
        let w = tampi_repro::bench::completion_wave(n, mode);
        println!(
            "wave N={n} [{mode:?}]: resume_lock_ops={} batches={} max_batch={} \
             vtime={} us ({:.2} s wall)",
            w.resume_lock_ops,
            w.delivery_batches,
            w.max_batch,
            w.vtime_ns / 1_000,
            wall.elapsed().as_secs_f64()
        );
        results.push((mode, w));
    }
    for (mode, w) in &results {
        match mode {
            DeliveryMode::Direct => {
                assert!(
                    w.resume_lock_ops >= n as u64,
                    "Direct delivery must take the scheduler lock O(N) times \
                     (got {} for N={n})",
                    w.resume_lock_ops
                );
                assert_eq!(w.delivery_batches, 0, "no shard batches under Direct");
            }
            DeliveryMode::Sharded => {
                // One bulk enqueue for the wave's shard, plus slack for
                // any straggler batch; far below N.
                assert!(
                    w.resume_lock_ops <= 4,
                    "Sharded delivery must take the scheduler lock O(shards) \
                     times (got {} for N={n})",
                    w.resume_lock_ops
                );
                assert_eq!(
                    w.max_batch, n as u64,
                    "the whole wave must land as one shard batch"
                );
                assert!(w.deliveries >= n as u64);
            }
        }
    }
    if let (Some((_, d)), Some((_, s))) = (
        results.iter().find(|(m, _)| *m == DeliveryMode::Direct),
        results.iter().find(|(m, _)| *m == DeliveryMode::Sharded),
    ) {
        assert_eq!(
            d.vtime_ns, s.vtime_ns,
            "delivery modes must not change virtual time"
        );
        println!(
            "sharded delivery: {}x fewer resume lock acquisitions at equal vtime",
            d.resume_lock_ops / s.resume_lock_ops.max(1)
        );
    }
}
