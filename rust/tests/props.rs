//! Property-based tests (hand-rolled generators over SplitMix64 — the
//! offline registry has no proptest): dependency-ordering invariants of
//! the runtime and matching invariants of rmpi under random workloads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use tampi_repro::nanos::{self, Mode, Runtime, RuntimeConfig};
use tampi_repro::rmpi::{ClusterConfig, Universe};
use tampi_repro::sim::{us, Clock};
use tampi_repro::util::SplitMix64;

/// Interval log of one task's access to one object.
#[derive(Clone, Copy, Debug)]
struct AccessLog {
    obj: usize,
    write: bool,
    start: u64,
    end: u64,
    task: u64,
}

/// Random task graphs: writers must be exclusive per object; readers may
/// overlap readers but not writers. 20 random graphs x ~40 tasks.
#[test]
fn prop_dependency_ordering_invariants() {
    for seed in 0..20u64 {
        let mut rng = SplitMix64::new(seed);
        let n_objs = 1 + rng.below(5) as usize;
        let n_tasks = 10 + rng.below(30) as usize;

        let (clock, h) = Clock::start();
        clock.set_panic_on_deadlock(false);
        let hold = clock.hold();
        let rt = Runtime::new(clock.clone(), RuntimeConfig::new(4));
        clock.register_thread();
        drop(hold);

        let log: Arc<Mutex<Vec<AccessLog>>> = Arc::new(Mutex::new(Vec::new()));
        let task_counter = Arc::new(AtomicU64::new(0));

        // Plan accesses on the test thread (deterministic from the seed).
        let mut plans: Vec<Vec<(usize, bool)>> = Vec::new();
        for _ in 0..n_tasks {
            let k = 1 + rng.below(3) as usize;
            let mut accesses = Vec::new();
            let perm = rng.permutation(n_objs);
            for &obj in perm.iter().take(k.min(n_objs)) {
                accesses.push((obj, rng.below(3) == 0)); // 1/3 writers
            }
            plans.push(accesses);
        }

        let rt2 = rt.clone();
        let clock2 = clock.clone();
        let log2 = log.clone();
        let tc = task_counter.clone();
        let j = std::thread::spawn(move || {
            rt2.attach();
            let objs: Vec<_> = (0..n_objs).map(|i| rt2.dep(format!("o{i}"))).collect();
            for accesses in plans {
                let mut tb = rt2.task();
                for &(obj, write) in &accesses {
                    tb = tb.dep(&objs[obj], if write { Mode::InOut } else { Mode::In });
                }
                let log = log2.clone();
                let tc = tc.clone();
                let acc = accesses.clone();
                tb.spawn(move || {
                    let id = tc.fetch_add(1, Ordering::Relaxed);
                    let start = nanos::current_clock().now();
                    nanos::work(us(10));
                    let end = nanos::current_clock().now();
                    let mut g = log.lock().unwrap();
                    for (obj, write) in acc {
                        g.push(AccessLog { obj, write, start, end, task: id });
                    }
                });
            }
            rt2.taskwait();
            rt2.detach();
            clock2.deregister_thread();
        });
        j.join().unwrap();
        rt.shutdown();
        clock.stop();
        h.join().unwrap();

        // Invariant: for each object, a writer's interval may not overlap
        // any other task's interval on the same object.
        let g = log.lock().unwrap();
        for a in g.iter() {
            for b in g.iter() {
                if a.task == b.task || a.obj != b.obj {
                    continue;
                }
                if a.write || b.write {
                    let overlap = a.start < b.end && b.start < a.end;
                    assert!(
                        !overlap,
                        "seed {seed}: conflicting access overlap on obj {}: {a:?} vs {b:?}",
                        a.obj
                    );
                }
            }
        }
    }
}

/// Random p2p traffic between two ranks: every message is received
/// exactly once, FIFO per (source, tag).
#[test]
fn prop_matching_fifo_per_tag() {
    for seed in 0..10u64 {
        let mut rng = SplitMix64::new(1000 + seed);
        let n_msgs = 20 + rng.below(40) as usize;
        let n_tags = 1 + rng.below(4) as i32;
        // Plan: sequence of (tag, value) sends by rank 0.
        let plan: Vec<(i32, u64)> = (0..n_msgs)
            .map(|i| (rng.below(n_tags as u64) as i32, (seed << 32) | i as u64))
            .collect();
        let plan2 = plan.clone();
        // Receiver draws tags in a (different) random order, per-tag FIFO.
        let mut rng2 = SplitMix64::new(2000 + seed);
        let mut recv_order: Vec<usize> = Vec::new(); // indices into per-tag queues
        let _ = &mut recv_order;
        let recv_tags: Vec<i32> = {
            // multiset of tags in plan, shuffled but per-tag order kept by
            // matching (we just receive tag-by-tag in shuffled positions)
            let mut tags: Vec<i32> = plan.iter().map(|&(t, _)| t).collect();
            // Fisher-Yates
            for i in (1..tags.len()).rev() {
                let j = rng2.below(i as u64 + 1) as usize;
                tags.swap(i, j);
            }
            tags
        };
        let got: Arc<Mutex<Vec<(i32, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        let got2 = got.clone();
        Universe::run(ClusterConfig::new(2, 1, 0), move |ctx| {
            if ctx.rank == 0 {
                for &(tag, val) in &plan2 {
                    ctx.comm.send(&[val], 1, tag);
                }
            } else {
                for &tag in &recv_tags {
                    let mut b = [0u64];
                    ctx.comm.recv(&mut b, 0, tag);
                    got2.lock().unwrap().push((tag, b[0]));
                }
            }
        })
        .unwrap();
        // Per-tag order of received values == per-tag order of sends.
        let g = got.lock().unwrap();
        for tag in 0..n_tags {
            let sent: Vec<u64> = plan
                .iter()
                .filter(|&&(t, _)| t == tag)
                .map(|&(_, v)| v)
                .collect();
            let recvd: Vec<u64> = g
                .iter()
                .filter(|&&(t, _)| t == tag)
                .map(|&(_, v)| v)
                .collect();
            assert_eq!(sent, recvd, "seed {seed} tag {tag}: FIFO violated");
        }
    }
}

/// Random external-event counts: dependencies release only after the
/// last event, regardless of interleaving with body completion.
#[test]
fn prop_external_events_release_after_last() {
    for seed in 0..10u64 {
        let mut rng = SplitMix64::new(3000 + seed);
        let n_events = 1 + rng.below(6) as u32;
        let delays: Vec<u64> = (0..n_events).map(|_| 1 + rng.below(20)).collect();
        let max_delay = *delays.iter().max().unwrap();

        let (clock, h) = Clock::start();
        clock.set_panic_on_deadlock(false);
        let hold = clock.hold();
        let rt = Runtime::new(clock.clone(), RuntimeConfig::new(2));
        clock.register_thread();
        drop(hold);

        let successor_at = Arc::new(AtomicU64::new(0));
        let sa = successor_at.clone();
        let rt2 = rt.clone();
        let clock2 = clock.clone();
        let j = std::thread::spawn(move || {
            rt2.attach();
            let obj = rt2.dep("x");
            let delays2 = delays.clone();
            rt2.task().dep(&obj, Mode::Out).spawn(move || {
                let ec = nanos::get_current_event_counter();
                nanos::increase_current_task_event_counter(&ec, n_events);
                let clock = nanos::current_clock();
                for &d in &delays2 {
                    let ec2 = ec.clone();
                    clock.call_at(us(d), move || {
                        nanos::decrease_task_event_counter(&ec2, 1);
                    });
                }
            });
            let sa2 = sa.clone();
            rt2.task().dep(&obj, Mode::In).spawn(move || {
                sa2.store(nanos::current_clock().now(), Ordering::Release);
            });
            rt2.taskwait();
            rt2.detach();
            clock2.deregister_thread();
        });
        j.join().unwrap();
        rt.shutdown();
        clock.stop();
        h.join().unwrap();

        assert_eq!(
            successor_at.load(Ordering::Acquire),
            us(max_delay),
            "seed {seed}: successor must run exactly at the last event"
        );
    }
}
