//! PJRT bridge: AOT artifacts load, execute, and agree with the native
//! kernels. Requires `make artifacts` (skipped gracefully otherwise).

use tampi_repro::apps::gauss_seidel::sweep_native;
use tampi_repro::runtime::{GsKernel, IfsKernel};
use tampi_repro::util::SplitMix64;

fn artifacts_present() -> bool {
    // Also false in stub builds (no `pjrt` feature), which fail every
    // load by design even when the artifact files exist on disk.
    tampi_repro::runtime::available("gs_block_32")
}

#[test]
fn gs_kernel_matches_native_sweep() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let b = 32;
    let k = GsKernel::load(b).expect("load gs_block_32");
    let mut rng = SplitMix64::new(42);
    let mut u: Vec<f32> = (0..b * b).map(|_| rng.next_f32()).collect();
    let top: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();
    let bottom: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();
    let left: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();
    let right: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();

    let (pjrt, delta) = k.sweep(&u, &top, &bottom, &left, &right).expect("sweep");
    let before = u.clone();
    sweep_native(&mut u, b, b, &top, &bottom, &left, &right);

    let mut max_err = 0f32;
    for (a, w) in pjrt.iter().zip(u.iter()) {
        max_err = max_err.max((a - w).abs());
    }
    assert!(max_err < 1e-3, "pjrt vs native max err {max_err}");

    let want_delta: f32 = u
        .iter()
        .zip(before.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    assert!(
        (delta - want_delta).abs() / want_delta.max(1e-6) < 1e-2,
        "delta {delta} vs {want_delta}"
    );
}

#[test]
fn gs_kernel_zero_fixed_point() {
    if !artifacts_present() {
        return;
    }
    let b = 32;
    let k = GsKernel::load(b).unwrap();
    let z = vec![0f32; b * b];
    let zh = vec![0f32; b];
    let (out, delta) = k.sweep(&z, &zh, &zh, &zh, &zh).unwrap();
    assert!(out.iter().all(|&x| x == 0.0));
    assert_eq!(delta, 0.0);
}

#[test]
fn gs_kernel_repeated_sweeps_converge() {
    if !artifacts_present() {
        return;
    }
    let b = 32;
    let k = GsKernel::load(b).unwrap();
    let mut u = vec![0.5f32; b * b];
    let zh = vec![0f32; b];
    let mut last_delta = f32::MAX;
    for _ in 0..20 {
        let (nu, delta) = k.sweep(&u, &zh, &zh, &zh, &zh).unwrap();
        u = nu;
        assert!(delta <= last_delta * 1.01, "delta must shrink");
        last_delta = delta;
    }
    assert!(last_delta < 1.0);
}

#[test]
fn ifs_kernel_runs_and_is_stable() {
    if !artifacts_present() {
        return;
    }
    let k = IfsKernel::load(8, 64).expect("load ifs_step_f8_n64");
    let mut rng = SplitMix64::new(7);
    let mut fields: Vec<f32> = (0..8 * 64).map(|_| rng.next_f32() * 0.5 + 0.25).collect();
    for _ in 0..5 {
        let (out, norm) = k.step(&fields).expect("step");
        assert!(norm.is_finite() && norm > 0.0);
        assert!(out.iter().all(|x| x.is_finite()));
        fields = out;
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    if !artifacts_present() {
        return;
    }
    let a = tampi_repro::runtime::load("gs_block_32").unwrap();
    let b = tampi_repro::runtime::load("gs_block_32").unwrap();
    assert!(std::ptr::eq(a, b), "same artifact must be cached");
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let msg = match tampi_repro::runtime::load("no_such_artifact") {
        Ok(_) => panic!("loading a missing artifact must fail"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("no_such_artifact"), "unhelpful error: {msg}");
}
