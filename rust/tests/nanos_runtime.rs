//! Task runtime semantics: deps, taskwait, pause/resume, external events,
//! polling services, virtual-core accounting.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use tampi_repro::nanos::{self, Mode, Runtime, RuntimeConfig};
use tampi_repro::sim::{ms, us, Clock};

/// Run `f` on an attached sim thread with a runtime of `cores` workers;
/// returns (f's result, final virtual time).
fn with_rt<T: Send + 'static>(
    cores: usize,
    f: impl FnOnce(&Runtime) -> T + Send + 'static,
) -> (T, u64) {
    let (clock, h) = Clock::start();
    clock.set_panic_on_deadlock(false);
    let hold = clock.hold(); // pin the clock during setup
    let rt = Runtime::new(clock.clone(), RuntimeConfig::new(cores));
    clock.register_thread();
    drop(hold);
    let c2 = clock.clone();
    let rt2 = rt.clone();
    let j = std::thread::spawn(move || {
        rt2.attach();
        let out = f(&rt2);
        rt2.taskwait();
        rt2.detach();
        let t = c2.now();
        c2.deregister_thread();
        (out, t)
    });
    let out = j.join().unwrap();
    rt.shutdown();
    clock.stop();
    h.join().unwrap();
    out
}

#[test]
fn tasks_run_to_completion() {
    let n = Arc::new(AtomicU32::new(0));
    let n2 = n.clone();
    let ((), _) = with_rt(4, move |rt| {
        for _ in 0..100 {
            let n = n2.clone();
            rt.task().spawn(move || {
                n.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    assert_eq!(n.load(Ordering::Relaxed), 100);
}

#[test]
fn virtual_work_overlaps_across_cores() {
    let ((), t) = with_rt(4, |rt| {
        for _ in 0..4 {
            rt.task().spawn(|| nanos::work(ms(10)));
        }
    });
    assert_eq!(t, ms(10), "4 tasks on 4 cores must overlap");
}

#[test]
fn virtual_work_serializes_on_one_core() {
    let ((), t) = with_rt(1, |rt| {
        for _ in 0..3 {
            rt.task().spawn(|| nanos::work(ms(10)));
        }
    });
    assert_eq!(t, ms(30), "3 tasks on 1 core must serialize");
}

#[test]
fn write_then_readers_then_writer_ordering() {
    let log = Arc::new(Mutex::new(Vec::new()));
    let log2 = log.clone();
    let ((), _) = with_rt(4, move |rt| {
        let obj = rt.dep("x");
        let l = log2.clone();
        rt.task().label("w1").dep(&obj, Mode::Out).spawn(move || {
            nanos::work(us(10));
            l.lock().unwrap().push("w1");
        });
        for i in 0..3 {
            let l = log2.clone();
            rt.task()
                .label(format!("r{i}"))
                .dep(&obj, Mode::In)
                .spawn(move || {
                    nanos::work(us(10));
                    l.lock().unwrap().push("r");
                });
        }
        let l = log2.clone();
        rt.task().label("w2").dep(&obj, Mode::InOut).spawn(move || {
            l.lock().unwrap().push("w2");
        });
    });
    let log = log.lock().unwrap();
    assert_eq!(log.len(), 5);
    assert_eq!(log[0], "w1");
    assert_eq!(log[4], "w2");
    assert!(log[1..4].iter().all(|s| *s == "r"));
}

#[test]
fn readers_run_concurrently() {
    // 3 readers of the same object on 3 cores, each 10 ms -> 10 ms total.
    let ((), t) = with_rt(3, |rt| {
        let obj = rt.dep("x");
        for _ in 0..3 {
            rt.task().dep(&obj, Mode::In).spawn(|| nanos::work(ms(10)));
        }
    });
    assert_eq!(t, ms(10));
}

#[test]
fn writers_serialize() {
    let ((), t) = with_rt(3, |rt| {
        let obj = rt.dep("x");
        for _ in 0..3 {
            rt.task().dep(&obj, Mode::InOut).spawn(|| nanos::work(ms(10)));
        }
    });
    assert_eq!(t, ms(30));
}

#[test]
fn pause_resume_roundtrip_on_one_core() {
    // Task A pauses; task B (same single core) unblocks it. Requires the
    // scheduler to run B while A is paused — the Section 4.1 mechanism.
    let slot: Arc<Mutex<Option<nanos::BlockingContext>>> = Arc::new(Mutex::new(None));
    let done = Arc::new(AtomicU32::new(0));
    let (s2, d2) = (slot.clone(), done.clone());
    let ((), _) = with_rt(1, move |rt| {
        let (s, d) = (s2.clone(), d2.clone());
        rt.task().label("A").spawn(move || {
            let ctx = nanos::get_current_blocking_context();
            *s.lock().unwrap() = Some(ctx.clone());
            nanos::block_current_task(&ctx);
            d.fetch_add(1, Ordering::Relaxed); // resumed
        });
        let (s, d) = (s2.clone(), d2.clone());
        rt.task().label("B").spawn(move || {
            nanos::work(ms(1));
            let ctx = s.lock().unwrap().take().expect("A must have parked");
            nanos::unblock_task(&ctx);
            d.fetch_add(10, Ordering::Relaxed);
        });
    });
    assert_eq!(done.load(Ordering::Relaxed), 11);
}

#[test]
fn unblock_before_block_is_consumed() {
    let done = Arc::new(AtomicU32::new(0));
    let d2 = done.clone();
    let ((), _) = with_rt(1, move |rt| {
        let d = d2.clone();
        rt.task().spawn(move || {
            let ctx = nanos::get_current_blocking_context();
            nanos::unblock_task(&ctx); // early
            nanos::block_current_task(&ctx); // must not park
            d.fetch_add(1, Ordering::Relaxed);
        });
    });
    assert_eq!(done.load(Ordering::Relaxed), 1);
}

#[test]
fn blocked_task_releases_core_to_other_tasks() {
    // One core: A pauses for 10 ms of virtual time (woken by a timer);
    // B runs meanwhile. Without core release, B could only run after A.
    let ((), t) = with_rt(1, |rt| {
        rt.task().label("A").spawn(|| {
            let ctx = nanos::get_current_blocking_context();
            let clock = nanos::current_clock();
            let ctx2 = ctx.clone();
            clock.call_at(ms(10), move || nanos::unblock_task(&ctx2));
            nanos::block_current_task(&ctx);
        });
        rt.task().label("B").spawn(|| nanos::work(ms(10)));
    });
    // A parks at ~0 and resumes at 10; B overlaps -> total 10, not 20.
    assert_eq!(t, ms(10));
}

#[test]
fn substitute_worker_is_spawned_on_block() {
    let ((), _) = with_rt(1, |rt| {
        rt.task().spawn(|| {
            let ctx = nanos::get_current_blocking_context();
            let clock = nanos::current_clock();
            let ctx2 = ctx.clone();
            clock.call_at(ms(5), move || nanos::unblock_task(&ctx2));
            nanos::block_current_task(&ctx);
        });
        rt.task().spawn(|| nanos::work(ms(1)));
    });
    // Can't read stats from inside the closure after the fact, so re-run
    // with explicit runtime access:
    let (stats, _) = with_rt(1, |rt| {
        rt.task().spawn(|| {
            let ctx = nanos::get_current_blocking_context();
            let clock = nanos::current_clock();
            let ctx2 = ctx.clone();
            clock.call_at(ms(5), move || nanos::unblock_task(&ctx2));
            nanos::block_current_task(&ctx);
        });
        rt.task().spawn(|| nanos::work(ms(1)));
        rt.clone()
    });
    let rt = stats;
    let (tasks, pauses, workers) = rt.stats();
    assert_eq!(tasks, 2);
    assert_eq!(pauses, 1);
    assert!(workers >= 2, "a substitute worker must have been spawned");
}

#[test]
fn external_events_defer_dependency_release() {
    // T binds an external event and finishes; successor S (in-dep) must
    // not run until the event is fulfilled at t=5ms.
    let s_started_at = Arc::new(AtomicU64::new(u64::MAX));
    let sa = s_started_at.clone();
    let ((), t) = with_rt(2, move |rt| {
        let obj = rt.dep("buf");
        rt.task().label("T").dep(&obj, Mode::Out).spawn(|| {
            let ec = nanos::get_current_event_counter();
            nanos::increase_current_task_event_counter(&ec, 1);
            let clock = nanos::current_clock();
            let ec2 = ec.clone();
            clock.call_at(ms(5), move || {
                nanos::decrease_task_event_counter(&ec2, 1);
            });
            // finish immediately; deps held by the pending event
        });
        let sa = sa.clone();
        rt.task().label("S").dep(&obj, Mode::In).spawn(move || {
            sa.store(nanos::current_clock().now(), Ordering::Release);
        });
    });
    assert_eq!(s_started_at.load(Ordering::Acquire), ms(5));
    assert_eq!(t, ms(5));
}

#[test]
fn event_fulfilled_before_finish_releases_at_finish() {
    let s_at = Arc::new(AtomicU64::new(u64::MAX));
    let sa = s_at.clone();
    let ((), _) = with_rt(2, move |rt| {
        let obj = rt.dep("buf");
        rt.task().dep(&obj, Mode::Out).spawn(|| {
            let ec = nanos::get_current_event_counter();
            nanos::increase_current_task_event_counter(&ec, 1);
            nanos::decrease_task_event_counter(&ec, 1); // fulfilled early
            nanos::work(ms(3)); // body continues
        });
        let sa = sa.clone();
        rt.task().dep(&obj, Mode::In).spawn(move || {
            sa.store(nanos::current_clock().now(), Ordering::Release);
        });
    });
    assert_eq!(s_at.load(Ordering::Acquire), ms(3));
}

#[test]
fn polling_service_runs_until_done() {
    let calls = Arc::new(AtomicU32::new(0));
    let c2 = calls.clone();
    let (rt_out, _) = with_rt(1, move |rt| {
        let c = c2.clone();
        rt.register_polling_service(
            "count3",
            Box::new(move || c.fetch_add(1, Ordering::Relaxed) + 1 >= 3),
        );
        // Burn virtual time so the leader polls a few times.
        rt.task().spawn(|| nanos::work(ms(2)));
        rt.clone()
    });
    assert!(calls.load(Ordering::Relaxed) >= 3);
    // Service unregistered itself: a few extra ms must not add calls.
    let before = calls.load(Ordering::Relaxed);
    drop(rt_out);
    assert_eq!(calls.load(Ordering::Relaxed), before);
}

#[test]
fn taskwait_returns_at_zero_pending() {
    let ((), t) = with_rt(2, |rt| {
        rt.task().spawn(|| nanos::work(ms(1)));
        rt.taskwait();
        assert_eq!(rt.pending_tasks(), 0);
        rt.task().spawn(|| nanos::work(ms(2)));
    });
    assert_eq!(t, ms(3));
}
