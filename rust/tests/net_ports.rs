//! The unified congestion-aware network layer (rmpi::net): p2p incast
//! deadline determinism across delivery modes, wait styles and worker
//! counts; exact compiler/engine critical-path parity per collective;
//! `coll_rx_ns` alias back-compat and default-transparency of the
//! ingress ports; and the commutative-op combine-tree relaxation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use tampi_repro::bench;
use tampi_repro::progress::DeliveryMode;
use tampi_repro::rmpi::{
    commutative, ClusterConfig, NetworkModel, ThreadLevel, TopologyMode, Universe,
};
use tampi_repro::sim::ms;
use tampi_repro::tampi;

/// The tentpole invariance: an (n-1)->0 incast's last delivery instant
/// is a pure function of the network model — identical across
/// {Direct, Sharded} x {park, taskaware}. With rx = 400 on a 2x2
/// cluster the exact value is pinned by the port law: the intra sender
/// arrives at +408 (64 B over shared memory) and is serviced at +808;
/// the two inter senders arrive together at +1505 and serialize to
/// +1905 and +2305 (ties broken src-ascending).
#[test]
fn p2p_incast_instant_deterministic_and_exact() {
    let expect = ms(1) + 2_305;
    for delivery in [DeliveryMode::Direct, DeliveryMode::Sharded] {
        for taskaware in [false, true] {
            let got = bench::p2p_incast_instant(2, 2, 400, delivery, taskaware);
            assert_eq!(
                got, expect,
                "incast instant diverged ({delivery:?}, taskaware={taskaware})"
            );
        }
    }
}

/// At the default `rx_ns = 0` the port is transparent: no serialization,
/// the incast's last delivery instant is exactly the launch instant
/// plus the slowest link transfer — the pre-port timeline (this is what
/// keeps all published figures bit-identical at the defaults).
#[test]
fn default_rx_keeps_ports_transparent() {
    let net = NetworkModel::default();
    let expect = ms(1) + net.transfer_ns(64, false);
    for delivery in [DeliveryMode::Direct, DeliveryMode::Sharded] {
        assert_eq!(bench::p2p_incast_instant(2, 4, 0, delivery, false), expect);
    }
    // And the alias still reads/writes the unified knob.
    let mut m = NetworkModel::default();
    assert_eq!(m.coll_rx_ns(), m.rx_ns);
    m.set_coll_rx_ns(250);
    assert_eq!((m.rx_ns, m.coll_rx_ns()), (250, 250));
}

/// Worker-count invariance: the same incast received by one task per
/// message, raced over 1, 2 and 4 workers under both delivery modes —
/// the completion instants come from the clock-thread port resolve, so
/// the last delivery instant cannot move.
#[test]
fn incast_instants_invariant_across_worker_counts() {
    let run = |cores: usize, delivery: DeliveryMode| -> u64 {
        let (nodes, rpn, rx) = (2usize, 2usize, 400u64);
        let mut cfg = ClusterConfig::new(nodes, rpn, cores).with_delivery_mode(delivery);
        cfg.net.rx_ns = rx;
        cfg.deadline = Some(ms(600_000));
        let last = Arc::new(AtomicU64::new(0));
        let l2 = last.clone();
        Universe::run(cfg, move |ctx| {
            let n = ctx.size;
            if ctx.rank != 0 {
                ctx.clock.sleep(ms(1));
                ctx.comm.isend(&[5u8; 64], 0, ctx.rank as i32);
                return;
            }
            let rt = ctx.rt.as_ref().unwrap();
            let tm = tampi::init(&ctx.comm, rt, ThreadLevel::TaskMultiple);
            for i in 1..n {
                let tm = tm.clone();
                let last = l2.clone();
                rt.task().label(format!("sink{i}")).spawn(move || {
                    let mut b = [0u8; 64];
                    let req = tm.comm().irecv(&mut b, i as i32, i as i32);
                    let c = tm.comm().clock().clone();
                    req.on_complete(move |_| {
                        last.fetch_max(c.now(), Ordering::AcqRel);
                    });
                    tm.wait(&req);
                });
            }
            rt.taskwait();
        })
        .expect("incast worker sweep");
        last.load(Ordering::Acquire)
    };
    let reference = run(1, DeliveryMode::Sharded);
    assert_eq!(reference, ms(1) + 2_305, "see p2p_incast_instant_deterministic_and_exact");
    for cores in [1usize, 2, 4] {
        for delivery in [DeliveryMode::Direct, DeliveryMode::Sharded] {
            let got = run(cores, delivery);
            assert_eq!(got, reference, "instants moved at cores={cores} {delivery:?}");
        }
    }
}

/// The acceptance criterion of the unified layer: the topology
/// compiler's critical-path estimate — a wire-schedule replay through
/// the same `NetworkModel`/port code the engine charges — equals the
/// engine-observed virtual time exactly, for every collective, in both
/// topology modes, with and without receiver processing. (`bcast-big`
/// additionally exercises the rendezvous protocol; `allreduce-comm`
/// the re-rooted combine tree.)
#[test]
fn compiler_engine_critical_path_parity() {
    let kinds = [
        "barrier",
        "bcast",
        "bcast-big",
        "reduce",
        "allreduce",
        "allreduce-comm",
        "gather",
        "alltoall",
    ];
    for (nodes, rpn, topo, rx) in [
        (2usize, 4usize, TopologyMode::Flat, 0u64),
        (2, 4, TopologyMode::Flat, 400),
        (2, 4, TopologyMode::Hierarchical, 0),
        (2, 4, TopologyMode::Hierarchical, 400),
        // Non-power-of-two ranks-per-node staging shapes.
        (4, 3, TopologyMode::Hierarchical, 400),
    ] {
        for kind in kinds {
            let (estimated, observed) = bench::coll_parity_pair(kind, nodes, rpn, topo, rx);
            assert_eq!(
                estimated, observed,
                "compiler/engine divergence: {kind} {nodes}x{rpn} {topo:?} rx={rx}"
            );
        }
    }
}

/// The commutative-op relaxation: marking an (exact, integer) sum as
/// commutative re-roots the combine tree where the model says it wins —
/// never slower, same result. Unmarked ops keep the flat binomial tree
/// (that contract is asserted in rmpi::topology's unit tests).
#[test]
fn commutative_allreduce_exact_and_not_slower() {
    let run = |comm_op: bool| -> (u64, u64) {
        let mut cfg = ClusterConfig::new(2, 6, 0).with_topology(TopologyMode::Hierarchical);
        cfg.net.rx_ns = 400;
        cfg.deadline = Some(ms(600_000));
        let sum = Arc::new(AtomicU64::new(0));
        let s2 = sum.clone();
        let stats = Universe::run(cfg, move |ctx| {
            let mut v = [(ctx.rank as u64 + 1) * 13];
            if comm_op {
                ctx.comm
                    .allreduce_op(&mut v, commutative(|a: &mut [u64], b: &[u64]| a[0] += b[0]));
            } else {
                ctx.comm.allreduce(&mut v, |a, b| a[0] += b[0]);
            }
            if ctx.rank == 0 {
                s2.store(v[0], Ordering::Release);
            }
        })
        .expect("commutative allreduce scenario");
        (sum.load(Ordering::Acquire), stats.vtime_ns)
    };
    let (sum_flat, t_flat) = run(false);
    let (sum_comm, t_comm) = run(true);
    let expect: u64 = (1..=12u64).map(|r| r * 13).sum();
    assert_eq!(sum_flat, expect);
    assert_eq!(sum_comm, expect, "re-rooted combine must be exact for integer sums");
    assert!(
        t_comm <= t_flat,
        "commutative re-rooting must not lose: {t_comm} vs {t_flat} ns"
    );
}
